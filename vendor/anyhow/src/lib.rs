//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The fitq workspace builds hermetically (no network, no registry), so the
//! error-context surface the codebase uses is implemented here from scratch
//! with the same names and call-site semantics as the real crate:
//!
//! - `Result<T>` / `Error`: a dynamic error carrying a context chain;
//! - `Context`: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! - `anyhow!` / `bail!`: ad-hoc error construction and early return;
//! - `{:#}` alternate `Display` prints the full chain outermost-first,
//!   matching how `fitq` renders fatal errors.
//!
//! Deliberately omitted (unused by fitq): backtraces, downcasting,
//! `ensure!`, and `#[source]` chaining beyond message capture.

use std::error::Error as StdError;
use std::fmt;

/// `Result` specialized to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a chain of human-readable messages, outermost context
/// first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, "outer: inner: root"
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context extension for `Result` and `Option`, like `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily evaluated context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tokens:tt)*) => {
        return Err($crate::anyhow!($($tokens)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err()
            .context("loading runtime");
        assert_eq!(format!("{e}"), "loading runtime");
        assert_eq!(format!("{e:#}"), "loading runtime: reading manifest: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: std::result::Result<u32, std::io::Error> = Ok(5);
        let out = r.with_context(|| -> String { panic!("must not evaluate") });
        assert_eq!(out.unwrap(), 5);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: usize) -> Result<()> {
            if n > 2 {
                bail!("too big: {n}");
            }
            Err(anyhow!("always {}", n))
        }
        assert_eq!(format!("{}", fails(7).unwrap_err()), "too big: 7");
        assert_eq!(format!("{}", fails(1).unwrap_err()), "always 1");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(format!("{from_string}"), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path/fitq")?;
            Ok(s)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_anyhow_result() {
        // the E = Error case of the blanket impl (via the reflexive From)
        let e: Result<()> = Err(Error::msg("root"));
        let e = e.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 2);
    }
}
