//! Vendored stub of the `xla` (xla-rs) PJRT API surface used by fitq.
//!
//! The fitq runtime layer (`fitq::runtime`) talks to XLA through exactly the
//! types and methods declared here: a CPU PJRT client, HLO-text parsing and
//! compilation, and literal transfer in both directions. This workspace
//! builds hermetically with no network access, so the real `xla` crate
//! (which downloads/links `xla_extension`) is replaced by this stub: every
//! entry point compiles and type-checks against the real signatures, and the
//! *first* runtime touch point — `PjRtClient::cpu()` — returns a descriptive
//! error instead of a client.
//!
//! Consequences, by design:
//! - `cargo build` / `cargo test` / `cargo doc` work with no toolchain
//!   beyond rustc — the pure-Rust substrates (data, quant, stats, metrics,
//!   search, parallel pool) are fully exercised;
//! - anything that needs a live PJRT dispatch (training, trace estimation,
//!   the experiment CLI against real artifacts) fails fast with
//!   "XLA/PJRT backend not available"; the integration tests detect the
//!   missing `artifacts/` directory first and skip themselves.
//!
//! To run against real artifacts, point the `xla` dependency of
//! `rust/Cargo.toml` at the actual xla-rs crate; no fitq source changes are
//! required (see DESIGN.md, "Runtime layer").

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?`-conversion into
/// the workspace error type.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` specialized to [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT backend not available in this build \
         (fitq was built against the vendored `xla` stub; swap in the real \
         xla-rs crate to dispatch — see DESIGN.md)"
    )))
}

/// Element types of the literals fitq transfers (f32 / s32 / u32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    S32,
    /// 32-bit unsigned integer.
    U32,
}

/// A host-side literal (typed buffer + shape).
pub struct Literal;

impl Literal {
    /// Allocate a literal of the given element type and dimensions from raw
    /// little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _untyped_data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    /// Refill this literal's buffer in place from a typed slice.
    pub fn copy_raw_from<T: Copy>(&mut self, _src: &[T]) -> Result<()> {
        unavailable("Literal::copy_raw_from")
    }

    /// Destructure a tuple-shaped literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Copy the buffer out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO *text* file (the interchange format aot.py emits).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device-resident buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal, synchronously.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals; one result buffer list per
    /// device (fitq always uses a single CPU device).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// The PJRT client owning devices and the compiler.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the vendored stub — this is the
    /// single runtime gate every real dispatch path goes through.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation for this client's devices.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable_backend() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("not available"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn stub_types_compose() {
        // the compile-time surface the runtime layer relies on
        let comp = XlaComputation::from_proto(&HloModuleProto);
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0; 16])
            .is_err());
        // no client exists, so exercise compile via the type only
        fn _typecheck(c: &PjRtClient, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            c.compile(comp)
        }
        let _ = comp;
    }
}
