#!/usr/bin/env bash
# Search-service smoke for `make check-serve` (the in-tree protocol and
# service suites run under `cargo test`; this drives the real binaries):
#
#   1. `fitq serve` on an ephemeral port over a temp results root
#   2. a cold streamed search (trains + traces once, streams `front`
#      events, answers with table residency cold+compute)
#   3. the same search again — must be served from the resident table
#      (residency warm, no second sensitivity computation)
#   4. a `score` and a `pareto` round-trip over a config extracted from
#      the search's own front (so the script needs no knowledge of the
#      model's block layout)
#   5. a malformed request: the server must answer a typed parse error
#      and the client must exit nonzero
#   6. `fitq serve --stats` must report the resident table and exactly
#      one sensitivity computation across everything above
set -euo pipefail

BIN=${FITQ_BIN:-target/release/fitq}
DIR=$(mktemp -d)
SERVER_PID=
cleanup() {
  [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
  rm -rf "$DIR"
}
trap cleanup EXIT

STUDY='{"model":"cnn_mnist","fp_epochs":1,"seed":0,"trace":{"batch":8,"min_iters":2,"max_iters":2}}'
SEARCH='{"method":"search","study":'$STUDY',"mode":"random","samples":2000,"seed":7,"shards":4,"stream":true}'

echo "== serve on an ephemeral port =="
FITQ_RESULTS="$DIR" "$BIN" serve --backend native --port 0 --jobs 2 \
  > "$DIR/serve.log" 2>&1 &
SERVER_PID=$!
ADDR=
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's/.*listening on \([^ ]*\) .*/\1/p' "$DIR/serve.log")
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$DIR/serve.log" >&2; exit 1; }
  sleep 0.2
done
[ -n "$ADDR" ] || { echo "error: server never announced its address" >&2; exit 1; }
echo "   listening on $ADDR"

"$BIN" query --connect "$ADDR" '{"method":"ping"}' | grep -q '"event":"done"' || {
  echo "error: ping got no done event" >&2
  exit 1
}

echo "== cold streamed search (trains once, streams fronts) =="
"$BIN" query --connect "$ADDR" "$SEARCH" > "$DIR/cold.jsonl"
grep -q '"event":"front"' "$DIR/cold.jsonl" || {
  echo "error: streamed search emitted no front events" >&2
  exit 1
}
grep -q '"table":"cold+compute"' "$DIR/cold.jsonl" || {
  echo "error: first search was not a cold computation" >&2
  exit 1
}

echo "== warm repeat (served from the resident table) =="
"$BIN" query --connect "$ADDR" "$SEARCH" > "$DIR/warm.jsonl"
grep -q '"table":"warm"' "$DIR/warm.jsonl" || {
  echo "error: repeat search did not hit the resident table" >&2
  exit 1
}

echo "== score + pareto over a config from the search's own front =="
python3 - "$DIR" "$STUDY" <<'EOF'
import json, sys
dir, study = sys.argv[1], sys.argv[2]
done = [json.loads(l) for l in open(f"{dir}/warm.jsonl") if '"event":"done"' in l][-1]
cfg = done["result"]["front"][0]["config"]
req = {"method": "score", "study": json.loads(study), "configs": [cfg, cfg]}
open(f"{dir}/score.json", "w").write(json.dumps(req))
req["method"] = "pareto"
del req["configs"]
req["configs"] = [cfg]
open(f"{dir}/pareto.json", "w").write(json.dumps(req))
EOF
"$BIN" query --connect "$ADDR" "$(cat "$DIR/score.json")" > "$DIR/score.jsonl"
grep -q '"scores":\[\[' "$DIR/score.jsonl" || {
  echo "error: score returned no score pairs" >&2
  exit 1
}
"$BIN" query --connect "$ADDR" "$(cat "$DIR/pareto.json")" | grep -q '"front":\[' || {
  echo "error: pareto returned no front" >&2
  exit 1
}

echo "== malformed request: typed error, nonzero client exit =="
if "$BIN" query --connect "$ADDR" 'this is not json' > "$DIR/bad.jsonl"; then
  echo "error: client exited zero on a server error event" >&2
  exit 1
fi
grep -q '"kind":"parse"' "$DIR/bad.jsonl" || {
  echo "error: malformed request did not get a typed parse error" >&2
  exit 1
}

echo "== stats: one resident table, exactly one sensitivity computation =="
"$BIN" serve --stats "$ADDR" > "$DIR/stats.txt"
grep -q 'stages.sensitivity_computed: 1$' "$DIR/stats.txt" || {
  cat "$DIR/stats.txt" >&2
  echo "error: expected exactly one sensitivity computation" >&2
  exit 1
}
grep -q 'resident tables (1)' "$DIR/stats.txt" || {
  cat "$DIR/stats.txt" >&2
  echo "error: expected one resident table" >&2
  exit 1
}
echo "check-serve: ok"
