#!/usr/bin/env python3
"""Validate the committed BENCH_*.json files (and generated report JSON
such as `fitq trace-report --json`) against their schemas.

CI runs this so a bench that writes malformed JSON (or a hand edit that
drops a field) fails loudly instead of silently breaking the perf
trajectory record. Values may be numbers or null (null = "awaiting the
first measurement on a capable host", which the status string must
explain); structure and types are what this enforces.
"""

import json
import sys

NUM = (int, float)


def fail(path, msg):
    print(f"{path}: {msg}", file=sys.stderr)
    sys.exit(1)


def num_or_null(path, obj, key):
    v = obj.get(key, "<missing>")
    if v != "<missing>" and (v is None or isinstance(v, NUM)):
        return
    fail(path, f"field {key!r} must be a number or null, got {v!r}")


def check_parallel_study(path, d):
    for key in ("bench", "status"):
        if not isinstance(d.get(key), str):
            fail(path, f"field {key!r} must be a string")
    if d.get("backend") is not None and not isinstance(d["backend"], str):
        fail(path, "field 'backend' must be a string or null")
    if d["bench"] != "parallel_study":
        fail(path, f"bench must be 'parallel_study', got {d['bench']!r}")
    nte = d.get("native_train_epoch")
    if nte is not None:
        if not isinstance(nte, list) or not nte:
            fail(path, "native_train_epoch must be null or a non-empty list")
        for row in nte:
            if not isinstance(row, dict) or not isinstance(row.get("model"), str):
                fail(path, "native_train_epoch rows must be objects with a 'model' string")
            for key in (
                "scalar_ms",
                "gemm_ms_t1",
                "gemm_ms_t2",
                "gemm_ms_t4",
                "speedup_scalar_to_gemm_t1",
                "intra_op_speedup_t1_to_t4",
            ):
                num_or_null(path, row, key)
    for key, jobs in (("pool_64x2M", [1, 2, 4, 8]), ("run_study_8cfg_cold", [1, 2, 4])):
        rows = d.get(key)
        if not isinstance(rows, list) or not all(isinstance(r, dict) for r in rows):
            fail(path, f"{key} must be a list of objects")
        if [r.get("jobs") for r in rows] != jobs:
            fail(path, f"{key} must list jobs {jobs}")
        for r in rows:
            num_or_null(path, r, "mean_s")
    num_or_null(path, d, "study_speedup_j1_to_j4")
    num_or_null(path, d, "run_study_warm_s")


def check_fit_scoring(path, d):
    if d.get("bench") != "fit_scoring":
        fail(path, f"bench must be 'fit_scoring', got {d.get('bench')!r}")
    if not isinstance(d.get("status"), str):
        fail(path, "status must be a string")
    shape = d.get("shape", {})
    for key in ("weight_blocks", "act_blocks"):
        if not isinstance(shape.get(key), int):
            fail(path, f"shape.{key} must be an int")
    if not isinstance(shape.get("precisions"), list):
        fail(path, "shape.precisions must be a list")
    for key in ("naive_ns_per_config", "table_ns_per_config", "speedup"):
        num_or_null(path, d.get("single", {}), key)
    batch = d.get("batch")
    if not isinstance(batch, list) or not batch:
        fail(path, "batch must be a non-empty list")
    for row in batch:
        if not isinstance(row, dict):
            fail(path, "batch rows must be objects")
        for key in ("n", "jobs"):
            if not isinstance(row.get(key), int):
                fail(path, f"batch rows need int {key!r}")
        num_or_null(path, row, "configs_per_sec")
    greedy = d.get("greedy", {})
    if not isinstance(greedy.get("blocks"), int):
        fail(path, "greedy.blocks must be an int")
    for key in ("naive_ns", "heap_ns", "speedup"):
        num_or_null(path, greedy, key)


def check_kernels(path, d):
    if d.get("bench") != "kernel_variants":
        fail(path, f"bench must be 'kernel_variants', got {d.get('bench')!r}")
    if not isinstance(d.get("status"), str):
        fail(path, "status must be a string")
    host = d.get("host")
    if not isinstance(host, dict) or not isinstance(host.get("arch"), str):
        fail(path, "host must be an object with an 'arch' string")
    if not isinstance(host.get("isas"), list) or not all(
        isinstance(i, str) for i in host["isas"]
    ):
        fail(path, "host.isas must be a list of strings")
    if not isinstance(host.get("cores"), int):
        fail(path, "host.cores must be an int")
    routes = d.get("routes")
    if not isinstance(routes, dict) or not routes:
        fail(path, "routes must be a non-empty object")
    for op, route in routes.items():
        if not isinstance(route, str):
            fail(path, f"routes.{op} must be a 'lowering/isa' string")
    kernels = d.get("kernels")
    if not isinstance(kernels, list) or not kernels:
        fail(path, "kernels must be a non-empty list")
    for row in kernels:
        if not isinstance(row, dict):
            fail(path, "kernels rows must be objects")
        for key in ("kernel", "shape"):
            if not isinstance(row.get(key), str):
                fail(path, f"kernels rows need a {key!r} string")
        variants = row.get("variants")
        if not isinstance(variants, dict) or not variants:
            fail(path, "kernels rows need a non-empty 'variants' object")
        for name, gflops in variants.items():
            if not isinstance(gflops, NUM):
                fail(path, f"variants.{name} must be a number (GFLOP/s)")
    train = d.get("train_epoch")
    if not isinstance(train, list) or not train:
        fail(path, "train_epoch must be a non-empty list")
    for row in train:
        if not isinstance(row, dict) or not isinstance(row.get("model"), str):
            fail(path, "train_epoch rows must be objects with a 'model' string")
        ms_keys = [k for k in row if k.endswith("_ms")]
        if "reference_ms" not in ms_keys or "scalar_ms" not in ms_keys:
            fail(path, "train_epoch rows need reference_ms and scalar_ms")
        for key in ms_keys:
            num_or_null(path, row, key)
        for key in ("speedup_auto_vs_reference", "speedup_auto_vs_scalar"):
            num_or_null(path, row, key)


def check_search_service(path, d):
    if d.get("bench") != "search_service":
        fail(path, f"bench must be 'search_service', got {d.get('bench')!r}")
    if not isinstance(d.get("status"), str):
        fail(path, "status must be a string")
    if not isinstance(d.get("model"), str):
        fail(path, "model must be a string")
    if not isinstance(d.get("samples"), int):
        fail(path, "samples must be an int")
    for key in ("cold_ms", "warm_ms", "served_vs_inprocess", "stream_overhead"):
        num_or_null(path, d, key)
    rows = d.get("throughput")
    if not isinstance(rows, list) or not rows:
        fail(path, "throughput must be a non-empty list")
    paths = set()
    for row in rows:
        if not isinstance(row, dict) or not isinstance(row.get("path"), str):
            fail(path, "throughput rows must be objects with a 'path' string")
        if not isinstance(row.get("jobs"), int):
            fail(path, "throughput rows need an int 'jobs'")
        num_or_null(path, row, "configs_per_sec")
        paths.add(row["path"])
    # the ratio is meaningless unless both sides of it are recorded
    for need in ("in_process_batch", "served_core", "served_tcp"):
        if need not in paths:
            fail(path, f"throughput must include a {need!r} row")


def check_trace_report(path, d):
    """`fitq trace-report --json` output (generated, not committed — the
    check-trace smoke runs this over a fresh report)."""
    if d.get("report") != "op_trace":
        fail(path, f"report must be 'op_trace', got {d.get('report')!r}")
    for key in ("model", "workload"):
        if not isinstance(d.get(key), str):
            fail(path, f"field {key!r} must be a string")
    if not isinstance(d.get("threads"), int):
        fail(path, "threads must be an int")
    if not isinstance(d.get("total_ms"), NUM):
        fail(path, "total_ms must be a number")
    rows = d.get("rows")
    if not isinstance(rows, list) or not rows:
        fail(path, "rows must be a non-empty list")
    for row in rows:
        if not isinstance(row, dict):
            fail(path, "rows must be objects")
        for key in ("op", "layer", "variant", "shape"):
            if not isinstance(row.get(key), str):
                fail(path, f"rows need a {key!r} string")
        if not isinstance(row.get("calls"), int):
            fail(path, "rows need an int 'calls'")
        for key in ("time_pct", "ms", "gflops", "gbs"):
            if not isinstance(row.get(key), NUM):
                fail(path, f"rows need a numeric {key!r}")
        # roofline is null for ops whose kernel family has no bench peak
        num_or_null(path, row, "roofline")


CHECKS = {
    "BENCH_parallel_study.json": check_parallel_study,
    "BENCH_fit_scoring.json": check_fit_scoring,
    "BENCH_kernels.json": check_kernels,
    "BENCH_search_service.json": check_search_service,
    "TRACE_report.json": check_trace_report,
}


def main(argv):
    # default run covers the committed records; TRACE_report.json is
    # generated on demand and checked explicitly by check_trace.sh
    paths = argv[1:] or [p for p in CHECKS if p.startswith("BENCH_")]
    for path in paths:
        name = path.rsplit("/", 1)[-1]
        if name not in CHECKS:
            fail(path, f"no schema registered (known: {sorted(CHECKS)})")
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            fail(path, f"unreadable or invalid JSON: {e}")
        CHECKS[name](path, d)
        print(f"{path}: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
