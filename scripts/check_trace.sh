#!/usr/bin/env bash
# Op-trace smoke for `make check-trace` (the in-tree trace suites run
# under `cargo test`; this drives the real binary end to end):
#
#   1. `fitq trace-report` before any traced run: actionable error
#      (naming --trace-ops) and a nonzero exit
#   2. `fitq train --trace-ops true` on cnn_mnist over the native
#      backend: trains, stores the `optrace` artifact, says so
#   3. `fitq trace-report`: the cost table must show conv rows with
#      GFLOP/s / GB/s / roofline columns, and the --json report must
#      pass scripts/check_bench_schema.py
#   4. `fitq tune --trace-model cnn_mnist`: the routing trailer checks
#      the tuned table against the stored trace's real shapes
#   5. a corrupted stored trace: trace-report must exit nonzero, never
#      render garbage
set -euo pipefail

BIN=${FITQ_BIN:-target/release/fitq}
DIR=$(mktemp -d)
cleanup() {
  rm -rf "$DIR"
}
trap cleanup EXIT

echo "== trace-report with no stored trace: actionable error, nonzero exit =="
if FITQ_RESULTS="$DIR" "$BIN" trace-report --model cnn_mnist > "$DIR/missing.txt" 2>&1; then
  echo "error: trace-report succeeded with no stored trace" >&2
  exit 1
fi
grep -q 'trace-ops' "$DIR/missing.txt" || {
  cat "$DIR/missing.txt" >&2
  echo "error: missing-trace error must tell the user to run --trace-ops" >&2
  exit 1
}

echo "== traced native train (writes the optrace artifact) =="
FITQ_RESULTS="$DIR" "$BIN" train --model cnn_mnist --backend native --epochs 1 \
  --trace-ops true > "$DIR/train.txt"
grep -q 'op trace:' "$DIR/train.txt" || {
  cat "$DIR/train.txt" >&2
  echo "error: traced train did not report a stored op trace" >&2
  exit 1
}
ls "$DIR"/cache/optrace_*.bin > /dev/null || {
  echo "error: no optrace artifact landed in the cache" >&2
  exit 1
}

echo "== cost report: conv rows, rate columns, JSON schema =="
FITQ_RESULTS="$DIR" "$BIN" trace-report --model cnn_mnist \
  --json "$DIR/TRACE_report.json" > "$DIR/report.txt"
for want in conv_fwd conv_bwd_w dense_fwd adam_step 'GFLOP/s' GB/s roofline; do
  grep -q "$want" "$DIR/report.txt" || {
    cat "$DIR/report.txt" >&2
    echo "error: cost report is missing $want" >&2
    exit 1
  }
done
python3 scripts/check_bench_schema.py "$DIR/TRACE_report.json"

echo "== tune trailer: routing check against the stored trace =="
FITQ_RESULTS="$DIR" "$BIN" tune --trace-model cnn_mnist > "$DIR/tune.txt"
grep -q 'routing check vs traced cnn_mnist/train_epoch' "$DIR/tune.txt" || {
  cat "$DIR/tune.txt" >&2
  echo "error: tune did not append the routing trailer" >&2
  exit 1
}
grep -q 'conv_fwd w' "$DIR/tune.txt" || {
  cat "$DIR/tune.txt" >&2
  echo "error: trailer has no per-op routing lines" >&2
  exit 1
}

echo "== corrupted stored trace: nonzero exit =="
python3 - "$DIR" <<'EOF'
import glob, sys
path = sorted(glob.glob(f"{sys.argv[1]}/cache/optrace_*.bin"))[0]
raw = bytearray(open(path, "rb").read())
raw[len(raw) // 2] ^= 0xFF
open(path, "wb").write(raw)
EOF
if FITQ_RESULTS="$DIR" "$BIN" trace-report --model cnn_mnist > "$DIR/corrupt.txt" 2>&1; then
  cat "$DIR/corrupt.txt" >&2
  echo "error: trace-report rendered a corrupted trace" >&2
  exit 1
fi

echo "check-trace: ok"
