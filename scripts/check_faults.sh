#!/usr/bin/env bash
# CLI-level fault drill for `make check-faults` (the deterministic
# in-tree suite runs first; see tests/fault_injection.rs):
#
#   1. an env-armed experiment: $FITQ_FAULTS corrupts the first cache
#      publish through the real CLI front door — the run must still
#      succeed (the store is an accelerator, not a correctness
#      dependency) and must announce the armed plan on stderr
#   2. `fitq cache verify` over that store must quarantine the corrupt
#      entry and exit nonzero
#   3. a second verify over the cleaned store must exit zero
set -euo pipefail

BIN=${FITQ_BIN:-target/release/fitq}
DIR=$(mktemp -d)
trap 'rm -rf "$DIR"' EXIT

echo "== armed run: first cache publish gets corrupt bytes =="
FITQ_FAULTS=cache.store.payload_corrupt FITQ_RESULTS="$DIR" \
  "$BIN" experiment table2 --backend native --jobs 1 \
  --configs 2 --fp-epochs 1 --qat-epochs 1 --eval-n 64 --only C \
  2> "$DIR/stderr.log" || { cat "$DIR/stderr.log" >&2; exit 1; }
grep -q "\[fault\] armed" "$DIR/stderr.log" || {
  echo "error: armed run never announced its fault plan" >&2
  exit 1
}

echo "== cache verify must quarantine and exit nonzero =="
if "$BIN" cache verify --results "$DIR"; then
  echo "error: verify exited zero over a corrupt store" >&2
  exit 1
fi
[ -n "$(ls -A "$DIR/cache/quarantine" 2>/dev/null)" ] || {
  echo "error: nothing was quarantined" >&2
  exit 1
}

echo "== verify over the cleaned store must pass =="
"$BIN" cache verify --results "$DIR"
echo "check-faults: ok"
