/* C mirror of the FIT scoring engine (rust/src/metrics/{fit,table}.rs and
 * the greedy allocators in coordinator/search.rs) — the measurement
 * harness behind the "c-mirror" numbers in BENCH_fit_scoring.json,
 * pending the first `make bench-scoring` on a cargo-equipped host.
 * Same algorithmic shapes: naive per-config noise_power/powf scoring vs
 * the precomputed per-block x per-precision gather table; clone-and-
 * rescore greedy vs the heap step-walk.
 *
 * gcc -O3 -std=c11 -ffp-contract=off -o scoring scoring.c -lm -pthread
 */
#include <math.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}
static uint64_t rng_state = 0xfeedbeef;
static uint64_t rng_u64(void) {
    uint64_t z = (rng_state += 0x9e3779b97f4a7c15ull);
    z = (z ^ (z >> 30)) * 0xbf58476d1ce4e5b9ull;
    z = (z ^ (z >> 27)) * 0x94d049bb133111ebull;
    return z ^ (z >> 31);
}
static double rng_f64(void) { return (rng_u64() >> 11) * (1.0 / 9007199254740992.0); }

/* quant/noise.rs */
static double noise_power(double lo, double hi, double bits) {
    double levels = pow(2.0, bits) - 1.0;
    if (hi <= lo || levels < 1.0) return 0.0;
    double delta = (hi - lo) / levels;
    return delta * delta / 12.0;
}

#define LW 48
#define LA 16
#define NP 4
static const uint32_t PRECS[NP] = {8, 6, 4, 3};

typedef struct {
    double w_traces[LW], w_lo[LW], w_hi[LW];
    double a_traces[LA], a_lo[LA], a_hi[LA];
    size_t block_sizes[LW];
} inputs_t;

typedef struct {
    double w_fit[LW * NP], a_fit[LA * NP];
    uint64_t w_bits[LW * NP];
    uint64_t base_bits;
} table_t;

static void table_new(const inputs_t *s, size_t n_unq, table_t *t) {
    for (size_t l = 0; l < LW; l++)
        for (size_t p = 0; p < NP; p++) {
            t->w_fit[l * NP + p] =
                s->w_traces[l] * noise_power(s->w_lo[l], s->w_hi[l], (double)PRECS[p]);
            t->w_bits[l * NP + p] = (uint64_t)s->block_sizes[l] * PRECS[p];
        }
    for (size_t l = 0; l < LA; l++)
        for (size_t p = 0; p < NP; p++)
            t->a_fit[l * NP + p] =
                s->a_traces[l] * noise_power(s->a_lo[l], s->a_hi[l], (double)PRECS[p]);
    t->base_bits = (uint64_t)n_unq * 32;
}

/* naive fit(): powf per block per call (metrics/fit.rs) */
static double fit_naive(const inputs_t *s, const uint8_t *idx) {
    double acc = 0.0;
    for (size_t l = 0; l < LW; l++)
        acc += s->w_traces[l] * noise_power(s->w_lo[l], s->w_hi[l], (double)PRECS[idx[l]]);
    double acc_a = 0.0;
    for (size_t l = 0; l < LA; l++)
        acc_a += s->a_traces[l] *
                 noise_power(s->a_lo[l], s->a_hi[l], (double)PRECS[idx[LW + l]]);
    return acc + acc_a;
}

/* table score: flat gather-sum (metrics/table.rs) */
static double fit_table(const table_t *t, const uint8_t *idx) {
    double acc = 0.0;
    for (size_t l = 0; l < LW; l++) acc += t->w_fit[l * NP + idx[l]];
    double acc_a = 0.0;
    for (size_t l = 0; l < LA; l++) acc_a += t->a_fit[l * NP + idx[LW + l]];
    return acc + acc_a;
}
static uint64_t size_table(const table_t *t, const uint8_t *idx) {
    uint64_t bits = t->base_bits;
    for (size_t l = 0; l < LW; l++) bits += t->w_bits[l * NP + idx[l]];
    return bits;
}

/* score_batch fan-out (4096-config chunks) */
typedef struct {
    const table_t *t;
    const uint8_t *idx;
    size_t n;
    double *out;
} batch_env;
typedef struct {
    batch_env *e;
    size_t base, len;
} bchunk_t;
static void *bchunk_main(void *p) {
    bchunk_t *c = p;
    for (size_t i = c->base; i < c->base + c->len; i++)
        c->e->out[i] = fit_table(c->e->t, c->e->idx + i * (LW + LA));
    return NULL;
}
static double batch_throughput(const table_t *t, const uint8_t *idx, size_t n, size_t jobs,
                               double *out) {
    double t0 = now_s();
    if (jobs <= 1) {
        for (size_t i = 0; i < n; i++) out[i] = fit_table(t, idx + i * (LW + LA));
    } else {
        bchunk_t ch[8];
        pthread_t tid[8];
        batch_env env = {t, idx, n, out};
        size_t base = 0;
        for (size_t j = 0; j < jobs; j++) {
            size_t len = n / jobs + (j < n % jobs ? 1 : 0);
            ch[j] = (bchunk_t){&env, base, len};
            base += len;
        }
        for (size_t j = 1; j < jobs; j++) pthread_create(&tid[j], NULL, bchunk_main, &ch[j]);
        bchunk_main(&ch[0]);
        for (size_t j = 1; j < jobs; j++) pthread_join(tid[j], NULL);
    }
    return (double)n / (now_s() - t0);
}

/* ---- greedy allocators over GB blocks (search.rs) ---- */
#define GB 64
typedef struct {
    double rate;
    int is_act, block, to_level;
    uint64_t d_bits;
} step_t;

/* naive: clone config + full rescore per candidate step */
static uint64_t model_bits_g(const size_t *sizes, uint64_t base, const uint32_t *bw) {
    uint64_t bits = base;
    for (size_t l = 0; l < GB; l++) bits += (uint64_t)sizes[l] * bw[l];
    return bits;
}
static double fit_g(const double *tr, const double *lo, const double *hi,
                    const uint32_t *bw) {
    double acc = 0.0;
    for (size_t l = 0; l < GB; l++) acc += tr[l] * noise_power(lo[l], hi[l], (double)bw[l]);
    return acc;
}
static double greedy_naive(const double *tr, const double *lo, const double *hi,
                           const size_t *sizes, uint64_t base, uint64_t budget,
                           uint32_t *bw) {
    for (size_t l = 0; l < GB; l++) bw[l] = PRECS[0];
    while (model_bits_g(sizes, base, bw) > budget) {
        double cur = fit_g(tr, lo, hi, bw);
        double best_rate = 0.0;
        int best_l = -1;
        uint32_t best_nb = 0;
        for (size_t l = 0; l < GB; l++) {
            uint32_t nb = 0;
            for (int p = NP - 1; p >= 0; p--)
                if (PRECS[p] < bw[l]) {
                    nb = PRECS[p];
                    break;
                }
            /* PRECS sorted descending here, find next lower */
            for (size_t p = 0; p < NP; p++)
                if (PRECS[p] < bw[l] && (nb == 0 || PRECS[p] > nb)) nb = PRECS[p];
            if (nb == 0) continue;
            uint32_t keep = bw[l];
            bw[l] = nb;
            double d_fit = fit_g(tr, lo, hi, bw) - cur;
            bw[l] = keep;
            uint64_t d_bits = (uint64_t)(keep - nb) * sizes[l];
            double rate = d_fit / (double)d_bits;
            if (best_l < 0 || rate < best_rate) {
                best_rate = rate;
                best_l = (int)l;
                best_nb = nb;
            }
        }
        if (best_l < 0) break;
        bw[best_l] = best_nb;
    }
    return fit_g(tr, lo, hi, bw);
}

/* heap: one candidate step per block, incremental bits (search.rs) */
static void heap_push(step_t *heap, size_t *n, step_t s) {
    size_t i = (*n)++;
    heap[i] = s;
    while (i > 0) {
        size_t par = (i - 1) / 2;
        if (heap[par].rate <= heap[i].rate) break;
        step_t tmp = heap[par];
        heap[par] = heap[i];
        heap[i] = tmp;
        i = par;
    }
}
static step_t heap_pop(step_t *heap, size_t *n) {
    step_t top = heap[0];
    heap[0] = heap[--(*n)];
    size_t i = 0;
    for (;;) {
        size_t l = 2 * i + 1, r = l + 1, m = i;
        if (l < *n && heap[l].rate < heap[m].rate) m = l;
        if (r < *n && heap[r].rate < heap[m].rate) m = r;
        if (m == i) break;
        step_t tmp = heap[m];
        heap[m] = heap[i];
        heap[i] = tmp;
        i = m;
    }
    return top;
}
int main(void) {
    inputs_t s;
    for (size_t l = 0; l < LW; l++) {
        s.w_traces[l] = rng_f64() * 10.0;
        s.w_lo[l] = -rng_f64();
        s.w_hi[l] = rng_f64() + 0.1;
        s.block_sizes[l] = 1000 + (rng_u64() % 50000);
    }
    for (size_t l = 0; l < LA; l++) {
        s.a_traces[l] = rng_f64() * 5.0;
        s.a_lo[l] = 0.0;
        s.a_hi[l] = rng_f64() * 4.0 + 0.1;
    }
    table_t tab;
    table_new(&s, 1234, &tab);

    /* single-score ns */
    size_t n1 = 200000;
    uint8_t *idx = malloc(n1 * (LW + LA));
    for (size_t i = 0; i < n1 * (LW + LA); i++) idx[i] = (uint8_t)(rng_u64() % NP);
    double acc = 0.0;
    double t0 = now_s();
    for (size_t i = 0; i < n1; i++) acc += fit_naive(&s, idx + i * (LW + LA));
    double naive_ns = (now_s() - t0) / n1 * 1e9;
    t0 = now_s();
    for (size_t i = 0; i < n1; i++) acc += fit_table(&tab, idx + i * (LW + LA));
    double table_ns = (now_s() - t0) / n1 * 1e9;
    printf("single: naive %.1f ns | table %.1f ns | speedup %.1fx (checksum %.3f)\n",
           naive_ns, table_ns, naive_ns / table_ns, acc);
    /* sanity: table == naive to near-ULP */
    for (size_t i = 0; i < 100; i++) {
        double a = fit_naive(&s, idx + i * (LW + LA));
        double b = fit_table(&tab, idx + i * (LW + LA));
        if (fabs(a - b) > 1e-15 * fabs(a)) {
            printf("TABLE MISMATCH %zu: %.17g vs %.17g\n", i, a, b);
            return 1;
        }
    }
    (void)size_table(&tab, idx);

    /* batch throughput at n = 1k / 100k / 1M, jobs 1 and 2 */
    double *out = malloc(1000000 * sizeof(double));
    uint8_t *big = malloc((size_t)1000000 * (LW + LA));
    for (size_t i = 0; i < (size_t)1000000 * (LW + LA); i++)
        big[i] = (uint8_t)(rng_u64() % NP);
    size_t ns[3] = {1000, 100000, 1000000};
    for (int c = 0; c < 3; c++) {
        for (size_t jobs = 1; jobs <= 2; jobs++) {
            batch_throughput(&tab, big, ns[c], jobs, out); /* warm */
            double sum = 0;
            for (int it = 0; it < 5; it++) sum += batch_throughput(&tab, big, ns[c], jobs, out);
            printf("batch n=%zu jobs=%zu: %.3fM configs/s\n", ns[c], jobs, sum / 5 / 1e6);
        }
    }

    /* greedy: naive vs heap, 64 blocks */
    double gtr[GB], glo[GB], ghi[GB];
    size_t gsz[GB];
    for (int l = 0; l < GB; l++) {
        gtr[l] = rng_f64() * 10.0;
        glo[l] = -rng_f64();
        ghi[l] = rng_f64() + 0.1;
        gsz[l] = 1000 + (rng_u64() % 50000);
    }
    /* GB == 64 > LW == 48: use dedicated flat tables for the heap walk */
    static double hw_fit[GB * NP];
    static uint64_t hw_bits[GB * NP];
    for (int l = 0; l < GB; l++)
        for (size_t p = 0; p < NP; p++) {
            hw_fit[l * NP + p] = gtr[l] * noise_power(glo[l], ghi[l], (double)PRECS[p]);
            hw_bits[l * NP + p] = (uint64_t)gsz[l] * PRECS[p];
        }
    uint64_t base = 1234ull * 32;
    uint64_t max_bits = base;
    for (int l = 0; l < GB; l++) max_bits += (uint64_t)gsz[l] * PRECS[0];
    uint64_t budget = max_bits / 2;
    uint32_t bw[GB];
    int level[GB];
    double tn = 0, th = 0, fn = 0, fh = 0;
    int iters = 200;
    t0 = now_s();
    for (int it = 0; it < iters; it++) fn = greedy_naive(gtr, glo, ghi, gsz, base, budget, bw);
    tn = (now_s() - t0) / iters;
    /* heap version over the flat GB arrays */
    t0 = now_s();
    for (int it = 0; it < iters; it++) {
        step_t heap[GB + 4];
        size_t hn = 0;
        for (int l = 0; l < GB; l++) level[l] = 0;
        for (int l = 0; l < GB; l++) {
            double d_fit = hw_fit[l * NP + 1] - hw_fit[l * NP + 0];
            uint64_t d_bits = hw_bits[l * NP + 0] - hw_bits[l * NP + 1];
            step_t st = {d_fit / (double)d_bits, 0, l, 1, d_bits};
            heap_push(heap, &hn, st);
        }
        uint64_t bits_now = base;
        for (int l = 0; l < GB; l++) bits_now += hw_bits[l * NP + 0];
        while (bits_now > budget && hn > 0) {
            step_t st = heap_pop(heap, &hn);
            level[st.block] = st.to_level;
            bits_now -= st.d_bits;
            if (st.to_level + 1 < NP) {
                double d_fit = hw_fit[st.block * NP + st.to_level + 1] -
                               hw_fit[st.block * NP + st.to_level];
                uint64_t d_bits = hw_bits[st.block * NP + st.to_level] -
                                  hw_bits[st.block * NP + st.to_level + 1];
                step_t nx = {d_fit / (double)d_bits, 0, st.block, st.to_level + 1, d_bits};
                heap_push(heap, &hn, nx);
            }
        }
        fh = 0;
        for (int l = 0; l < GB; l++) fh += hw_fit[l * NP + level[l]];
    }
    th = (now_s() - t0) / iters;
    printf("greedy %d blocks: naive %.1f us | heap %.1f us | speedup %.1fx "
           "(fit naive %.6g heap %.6g)\n",
           GB, tn * 1e6, th * 1e6, tn / th, fn, fh);
    if (fabs(fn - fh) > 1e-9 * fabs(fn)) printf("GREEDY RESULT MISMATCH\n");
    return 0;
}
