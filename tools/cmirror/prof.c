/* Per-op breakdown of the GEMM-path train step (experiment harness; not
 * part of the recorded benchmarks). Build:
 *   gcc -O3 -std=c11 -ffp-contract=off -DNO_MAIN -o prof prof.c kernels.c? no —
 *   gcc -O3 -std=c11 -ffp-contract=off -o prof prof.c -lm -pthread
 */
#define NO_MAIN
#include "kernels.c"

static double t_im2col, t_sgemm, t_atb, t_bwdx_gemm, t_col2im, t_transpose, t_rest;

static void breakdown(const cnn_t *spec, size_t threads, int iters) {
    plan_t p = plan_new(spec);
    size_t B = 32, sample = spec->h * spec->w * spec->cin;
    tape_t t = tape_new(&p, B);
    float *params = fmalloc(p.n_params), *g = fmalloc(p.n_params);
    he_init(&p, params);
    float *xs = fmalloc(B * sample);
    int32_t *ys = (int32_t *)malloc(B * 4);
    for (size_t i = 0; i < B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    t_im2col = t_sgemm = t_atb = t_bwdx_gemm = t_col2im = t_transpose = t_rest = 0;
    double total = 0;
    for (int it = 0; it < iters; it++) {
        double t0 = now_s();
        /* forward with instrumented ops */
        memcpy(t.xin[0], xs, B * sample * sizeof(float));
        for (int i = 0; i < 3; i++) {
            const layer_t *l = &p.conv[i];
            double a0 = now_s();
            im2col3x3(t.xin[i], B, l->h, l->w, l->cin, t.scratch_a);
            double a1 = now_s();
            sgemm(B * l->h * l->w, l->cout, 9 * l->cin, t.scratch_a, params + l->w_off,
                  params + l->b_off, t.buf1, threads);
            double a2 = now_s();
            t_im2col += a1 - a0;
            t_sgemm += a2 - a1;
            relu(t.buf1, t.act[i], B * l->h * l->w * l->cout);
            float *next = (i < 2) ? t.xin[i + 1] : t.feat;
            if (l->pooled) {
                max_pool(t.act[i], B, l->h, l->w, l->cout, t.pooled[i], t.pidx[i]);
                memcpy(next, t.pooled[i], B * (l->h / 2) * (l->w / 2) * l->cout * 4);
            } else
                memcpy(next, t.act[i], B * l->h * l->w * l->cout * 4);
        }
        double d0 = now_s();
        dense_gemm(t.feat, B, p.feat, params + p.fc_w_off, p.spec.ncls,
                   params + p.fc_b_off, t.logits, threads);
        t_sgemm += now_s() - d0;
        /* backward */
        memset(g, 0, p.n_params * 4);
        float per[64], dper[64];
        softmax_xent(t.logits, ys, B, p.spec.ncls, per);
        for (size_t i = 0; i < B; i++) dper[i] = 1.0f / B;
        softmax_xent_bwd(t.logits, ys, B, p.spec.ncls, dper, t.buf1);
        double e0 = now_s();
        dense_bwd_gemm(t.feat, params + p.fc_w_off, B, p.feat, p.spec.ncls, t.buf1,
                       g + p.fc_w_off, g + p.fc_b_off, t.buf2, t.scratch_b, threads);
        t_atb += now_s() - e0;
        float *da = t.buf2;
        for (int i = 2; i >= 0; i--) {
            const layer_t *l = &p.conv[i];
            if (l->pooled) {
                max_pool_bwd(da, t.pidx[i], B, l->h, l->w, l->cout, t.buf1);
                float *tmp = da; da = t.buf1; t.buf1 = tmp;
            }
            relu_bwd_inplace(t.act[i], da, B * l->h * l->w * l->cout);
            double b0 = now_s();
            im2col3x3(t.xin[i], B, l->h, l->w, l->cin, t.scratch_a);
            double b1 = now_s();
            sgemm_atb(B * l->h * l->w, l->cout, 9 * l->cin, t.scratch_a, da, g + l->w_off,
                      threads);
            for (size_t r = 0; r < B * l->h * l->w; r++)
                for (size_t o = 0; o < l->cout; o++) g[l->b_off + o] += da[r * l->cout + o];
            double b2 = now_s();
            t_im2col += b1 - b0;
            t_atb += b2 - b1;
            if (i > 0) {
                double c0 = now_s();
                size_t k = 9 * l->cin;
                transpose_mat(params + l->w_off, k, l->cout, t.scratch_b);
                double c1 = now_s();
                sgemm(B * l->h * l->w, k, l->cout, da, t.scratch_b, NULL, t.scratch_a,
                      threads);
                double c2 = now_s();
                col2im3x3(t.scratch_a, B, l->h, l->w, l->cin, t.buf1, threads);
                double c3 = now_s();
                t_transpose += c1 - c0;
                t_bwdx_gemm += c2 - c1;
                t_col2im += c3 - c2;
                float *tmp = da; da = t.buf1; t.buf1 = tmp;
            }
        }
        total += now_s() - t0;
    }
    double acct = t_im2col + t_sgemm + t_atb + t_bwdx_gemm + t_col2im + t_transpose;
    printf("%s t=%zu (per step, %d iters): total %.3f ms | im2col %.3f | sgemm %.3f | "
           "atb %.3f | bwdx-gemm %.3f | col2im %.3f | transp %.3f | other %.3f ms\n",
           spec->name, threads, iters, total / iters * 1e3, t_im2col / iters * 1e3,
           t_sgemm / iters * 1e3, t_atb / iters * 1e3, t_bwdx_gemm / iters * 1e3,
           t_col2im / iters * 1e3, t_transpose / iters * 1e3,
           (total - acct) / iters * 1e3);
}

int main(void) {
    breakdown(&CNN_MNIST, 1, 50);
    breakdown(&CNN_CIFAR, 1, 10);
    breakdown(&CNN_CIFAR, 2, 10);
    return 0;
}
