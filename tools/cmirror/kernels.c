/* C mirror of the native backend's math kernels (rust/src/native/{ops,gemm}.rs).
 *
 * Purpose (see tools/cmirror/README.md): the authoring container for this
 * repository ships no Rust toolchain, so this mirror is (a) the numeric
 * validation harness for the GEMM rewrite — it transcribes BOTH the scalar
 * reference loop nests and the im2col+GEMM path line-for-line and asserts
 * they agree to 0 ULP (bitwise) on random and ReLU-sparse data, through a
 * full multi-step train loop — and (b) the measurement harness behind the
 * "c-mirror" numbers committed in BENCH_parallel_study.json, pending the
 * first `make bench-native` on a cargo-equipped host.
 *
 * Fidelity rules: float for Rust f32, double for the f64 reduction
 * accumulators, identical loop orders, and NO fp contraction — build with
 *   gcc -O2 -std=c11 -ffp-contract=off -pthread kernels.c -lm
 * so `acc += a*b` rounds twice exactly like rustc emits it.  For SIMD
 * measurement use -O3 (gcc only autovectorizes at -O3; rustc -O always
 * does), which is safe here: autovectorization across independent output
 * elements is bit-exact and no reduction is ever contracted.
 *
 * PR 8 adds explicit SSE2/AVX2 variants of the hot kernels (mirroring
 * rust/src/native/simd.rs): each variant vectorizes across independent
 * output elements (the cout/n axis of the rank-1 updates) with separate
 * mul+add intrinsics — never FMA, whose single rounding would break the
 * two-rounding scalar chain — so every output element sees the exact
 * reference accumulation order and the zero-skip on the scalar A element
 * survives untouched.  A per-op route table (g_route, mirroring
 * tune::RouteTable) selects the variant at panel granularity, exactly
 * where rustc's #[target_feature] boundary sits.
 */
#define _USE_MATH_DEFINES
#include <assert.h>
#include <math.h>
#include <pthread.h>
#include <stdatomic.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#if defined(__x86_64__)
#include <immintrin.h>
#endif

/* ---------------- blocking parameters (gemm.rs) ---------------- */
#define MR 4
#define NR 8
#define KC 128
#define MC 64
#define PAR_FLOPS_PER_THREAD 4000000ull

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* splitmix-ish rng for data */
static uint64_t rng_state = 0x12345678;
static uint64_t rng_u64(void) {
    uint64_t z = (rng_state += 0x9e3779b97f4a7c15ull);
    z = (z ^ (z >> 30)) * 0xbf58476d1ce4e5b9ull;
    z = (z ^ (z >> 27)) * 0x94d049bb133111ebull;
    return z ^ (z >> 31);
}
static float rng_normal(void) {
    /* Box-Muller, like tensor::Pcg32::normal in spirit */
    double u1 = (rng_u64() >> 11) * (1.0 / 9007199254740992.0);
    double u2 = (rng_u64() >> 11) * (1.0 / 9007199254740992.0);
    if (u1 < 1e-300) u1 = 1e-300;
    return (float)(sqrt(-2.0 * log(u1)) * cos(2.0 * 3.14159265358979323846 * u2));
}

/* ---------------- run_static mirror (parallel.rs) ---------------- */
typedef void (*item_fn)(void *env, size_t index);
typedef struct {
    item_fn fn;
    void *env;
    size_t base, len;
} chunk_t;
static void *chunk_main(void *p) {
    chunk_t *c = (chunk_t *)p;
    for (size_t i = 0; i < c->len; i++) c->fn(c->env, c->base + i);
    return NULL;
}
/* static contiguous split; caller runs chunk 0 (run_static semantics) */
static void run_static(size_t n, size_t threads, item_fn fn, void *env) {
    if (threads < 1) threads = 1;
    if (threads > n) threads = n ? n : 1;
    if (threads <= 1) {
        for (size_t i = 0; i < n; i++) fn(env, i);
        return;
    }
    chunk_t chunks[64];
    pthread_t tids[64];
    size_t base = 0;
    for (size_t t = 0; t < threads; t++) {
        size_t len = n / threads + (t < n % threads ? 1 : 0);
        chunks[t] = (chunk_t){fn, env, base, len};
        base += len;
    }
    for (size_t t = 1; t < threads; t++) pthread_create(&tids[t], NULL, chunk_main, &chunks[t]);
    chunk_main(&chunks[0]);
    for (size_t t = 1; t < threads; t++) pthread_join(tids[t], NULL);
}

static size_t effective_threads(size_t budget, size_t panels, uint64_t flops) {
    size_t t = budget < 1 ? 1 : budget;
    if (panels < 1) panels = 1;
    if (t > panels) t = panels;
    uint64_t by_work = 1 + flops / PAR_FLOPS_PER_THREAD;
    if (t > by_work) t = (size_t)by_work;
    return t;
}

/* ---------------- ISA route table (tune.rs mirror) ---------------- */
/* 0 = scalar (compiler-autovectorized plain loops), 1 = SSE2 explicit,
 * 2 = AVX2 explicit.  One route slot per tunable kernel site, mirroring
 * tune::RouteTable; set_route_all() mirrors the FITQ_NATIVE_KERNEL
 * forced modes. */
enum { ISA_SCALAR = 0, ISA_SSE2 = 1, ISA_AVX2 = 2 };
enum { OP_CONV_FWD = 0, OP_CONV_BWD_W, OP_SGEMM, OP_ATB, OP_COL2IM, N_ROUTE_OPS };
static int g_route[N_ROUTE_OPS] = {0, 0, 0, 0, 0};
static void set_route_all(int isa) {
    for (int i = 0; i < N_ROUTE_OPS; i++) g_route[i] = isa;
}
static int isa_available(int isa) {
#if defined(__x86_64__)
    if (isa == ISA_AVX2) return __builtin_cpu_supports("avx2");
    return 1; /* scalar + SSE2 (x86_64 baseline) */
#else
    return isa == ISA_SCALAR;
#endif
}
static const char *isa_name(int isa) {
    return isa == ISA_AVX2 ? "avx2" : isa == ISA_SSE2 ? "sse2" : "scalar";
}

/* ---------------- reference kernels (ops::reference) ---------------- */
static void tap_range(size_t d, size_t len, size_t *lo, size_t *hi) {
    *lo = d == 0 ? 1 : 0;
    *hi = d == 2 ? len - 1 : len;
}

static void conv2d_ref(const float *x, size_t n, size_t h, size_t w, size_t cin,
                       const float *wgt, size_t cout, const float *bias, float *out) {
    for (size_t r = 0; r < n * h * w; r++) memcpy(out + r * cout, bias, cout * sizeof(float));
    for (size_t ni = 0; ni < n; ni++)
        for (size_t di = 0; di < 3; di++) {
            size_t i0, i1;
            tap_range(di, h, &i0, &i1);
            for (size_t dj = 0; dj < 3; dj++) {
                size_t j0, j1;
                tap_range(dj, w, &j0, &j1);
                for (size_t i = i0; i < i1; i++) {
                    size_t xi = i + di - 1;
                    for (size_t j = j0; j < j1; j++) {
                        size_t xj = j + dj - 1;
                        const float *xrow = x + ((ni * h + xi) * w + xj) * cin;
                        float *orow = out + ((ni * h + i) * w + j) * cout;
                        for (size_t ci = 0; ci < cin; ci++) {
                            const float *wrow = wgt + ((di * 3 + dj) * cin + ci) * cout;
                            float xv = xrow[ci];
                            for (size_t o = 0; o < cout; o++) orow[o] += xv * wrow[o];
                        }
                    }
                }
            }
        }
}

static void conv2d_bwd_w_ref(const float *x, size_t n, size_t h, size_t w, size_t cin,
                             const float *dout, size_t cout, float *dw, float *db) {
    for (size_t ni = 0; ni < n; ni++)
        for (size_t di = 0; di < 3; di++) {
            size_t i0, i1;
            tap_range(di, h, &i0, &i1);
            for (size_t dj = 0; dj < 3; dj++) {
                size_t j0, j1;
                tap_range(dj, w, &j0, &j1);
                for (size_t i = i0; i < i1; i++) {
                    size_t xi = i + di - 1;
                    for (size_t j = j0; j < j1; j++) {
                        size_t xj = j + dj - 1;
                        const float *xrow = x + ((ni * h + xi) * w + xj) * cin;
                        const float *drow = dout + ((ni * h + i) * w + j) * cout;
                        for (size_t ci = 0; ci < cin; ci++) {
                            float *dwrow = dw + ((di * 3 + dj) * cin + ci) * cout;
                            float xv = xrow[ci];
                            for (size_t o = 0; o < cout; o++) dwrow[o] += xv * drow[o];
                        }
                    }
                }
            }
        }
    for (size_t r = 0; r < n * h * w; r++)
        for (size_t o = 0; o < cout; o++) db[o] += dout[r * cout + o];
}

static void conv2d_bwd_x_ref(const float *wgt, size_t n, size_t h, size_t w, size_t cin,
                             const float *dout, size_t cout, float *dx) {
    memset(dx, 0, n * h * w * cin * sizeof(float));
    for (size_t ni = 0; ni < n; ni++)
        for (size_t di = 0; di < 3; di++) {
            size_t i0, i1;
            tap_range(di, h, &i0, &i1);
            for (size_t dj = 0; dj < 3; dj++) {
                size_t j0, j1;
                tap_range(dj, w, &j0, &j1);
                for (size_t i = i0; i < i1; i++) {
                    size_t xi = i + di - 1;
                    for (size_t j = j0; j < j1; j++) {
                        size_t xj = j + dj - 1;
                        const float *drow = dout + ((ni * h + i) * w + j) * cout;
                        float *dxrow = dx + ((ni * h + xi) * w + xj) * cin;
                        for (size_t ci = 0; ci < cin; ci++) {
                            const float *wrow = wgt + ((di * 3 + dj) * cin + ci) * cout;
                            float acc = 0.0f;
                            for (size_t o = 0; o < cout; o++) acc += wrow[o] * drow[o];
                            dxrow[ci] += acc;
                        }
                    }
                }
            }
        }
}

static void dense_ref(const float *x, size_t n, size_t fin, const float *wgt, size_t fout,
                      const float *bias, float *out) {
    for (size_t ni = 0; ni < n; ni++) {
        float *orow = out + ni * fout;
        memcpy(orow, bias, fout * sizeof(float));
        const float *xrow = x + ni * fin;
        for (size_t fi = 0; fi < fin; fi++) {
            const float *wrow = wgt + fi * fout;
            float xv = xrow[fi];
            for (size_t o = 0; o < fout; o++) orow[o] += xv * wrow[o];
        }
    }
}

static void dense_bwd_ref(const float *x, const float *wgt, size_t n, size_t fin, size_t fout,
                          const float *dout, float *dw, float *db, float *dx) {
    for (size_t ni = 0; ni < n; ni++) {
        const float *xrow = x + ni * fin;
        const float *drow = dout + ni * fout;
        for (size_t fi = 0; fi < fin; fi++) {
            float *dwrow = dw + fi * fout;
            float xv = xrow[fi];
            for (size_t o = 0; o < fout; o++) dwrow[o] += xv * drow[o];
        }
        for (size_t o = 0; o < fout; o++) db[o] += drow[o];
        float *dxrow = dx + ni * fin;
        for (size_t fi = 0; fi < fin; fi++) {
            const float *wrow = wgt + fi * fout;
            float acc = 0.0f;
            for (size_t o = 0; o < fout; o++) acc += wrow[o] * drow[o];
            dxrow[fi] = acc;
        }
    }
}

/* ---------------- explicit SIMD kernel bodies (simd.rs mirror) -------- */
/* Per-ISA axpy (dst += a*src) and vadd (dst += src) helpers plus whole
 * panel bodies.  mul+add, never FMA: each lane must round twice like the
 * scalar `d += a*s`.  The panel bodies repeat the exact scalar loop nests
 * with the innermost independent-output loop replaced by the helper, so
 * per output element the accumulation chain is unchanged. */
#if defined(__x86_64__)
static inline void axpy_sse2(float *dst, const float *src, size_t len, float a) {
    __m128 va = _mm_set1_ps(a);
    size_t i = 0;
    for (; i + 4 <= len; i += 4) {
        __m128 s = _mm_loadu_ps(src + i);
        __m128 d = _mm_loadu_ps(dst + i);
        _mm_storeu_ps(dst + i, _mm_add_ps(d, _mm_mul_ps(va, s)));
    }
    for (; i < len; i++) dst[i] += a * src[i];
}
static inline void vadd_sse2(float *dst, const float *src, size_t len) {
    size_t i = 0;
    for (; i + 4 <= len; i += 4)
        _mm_storeu_ps(dst + i, _mm_add_ps(_mm_loadu_ps(dst + i), _mm_loadu_ps(src + i)));
    for (; i < len; i++) dst[i] += src[i];
}
__attribute__((target("avx2"))) static inline void axpy_avx2(float *dst, const float *src,
                                                             size_t len, float a) {
    __m256 va = _mm256_set1_ps(a);
    size_t i = 0;
    for (; i + 8 <= len; i += 8) {
        __m256 s = _mm256_loadu_ps(src + i);
        __m256 d = _mm256_loadu_ps(dst + i);
        _mm256_storeu_ps(dst + i, _mm256_add_ps(d, _mm256_mul_ps(va, s)));
    }
    for (; i < len; i++) dst[i] += a * src[i];
}
__attribute__((target("avx2"))) static inline void vadd_avx2(float *dst, const float *src,
                                                             size_t len) {
    size_t i = 0;
    for (; i + 8 <= len; i += 8)
        _mm256_storeu_ps(dst + i,
                         _mm256_add_ps(_mm256_loadu_ps(dst + i), _mm256_loadu_ps(src + i)));
    for (; i < len; i++) dst[i] += src[i];
}

/* One macro instantiation per ISA so the helpers inline into the panel
 * bodies (the C analogue of same-#[target_feature] inlining in Rust). */
#define DEF_ISA_PANELS(SUF, TATTR)                                                            \
    TATTR static void sgemm_rows_##SUF(float *c, size_t row0, size_t rows, size_t n,          \
                                       size_t k, const float *a, const float *b,              \
                                       const float *bias) {                                   \
        for (size_t r = row0; r < row0 + rows; r++) {                                         \
            float *crow = c + r * n;                                                          \
            if (bias)                                                                         \
                memcpy(crow, bias, n * sizeof(float));                                        \
            else                                                                              \
                memset(crow, 0, n * sizeof(float));                                           \
            const float *arow = a + r * k;                                                    \
            for (size_t p = 0; p < k; p++) {                                                  \
                float av = arow[p];                                                           \
                if (av == 0.0f) continue;                                                     \
                axpy_##SUF(crow, b + p * n, n, av);                                           \
            }                                                                                 \
        }                                                                                     \
    }                                                                                         \
    TATTR static void atb_panel_##SUF(float *dw, size_t k0, size_t krows, size_t m,           \
                                      size_t n, size_t k, const float *a, const float *d) {   \
        for (size_t mi = 0; mi < m; mi++) {                                                   \
            const float *arow = a + mi * k + k0;                                              \
            const float *drow = d + mi * n;                                                   \
            for (size_t kk = 0; kk < krows; kk++) {                                           \
                float av = arow[kk];                                                          \
                if (av == 0.0f) continue;                                                     \
                axpy_##SUF(dw + (k0 + kk) * n, drow, n, av);                                  \
            }                                                                                 \
        }                                                                                     \
    }                                                                                         \
    TATTR static void conv_fwd_##SUF(const float *x, size_t n, size_t h, size_t w,            \
                                     size_t cin, const float *wgt, size_t cout,               \
                                     const float *bias, float *out) {                         \
        for (size_t r = 0; r < n * h * w; r++)                                                \
            memcpy(out + r * cout, bias, cout * sizeof(float));                               \
        for (size_t ni = 0; ni < n; ni++)                                                     \
            for (size_t di = 0; di < 3; di++) {                                               \
                size_t i0, i1;                                                                \
                tap_range(di, h, &i0, &i1);                                                   \
                for (size_t dj = 0; dj < 3; dj++) {                                           \
                    size_t j0, j1;                                                            \
                    tap_range(dj, w, &j0, &j1);                                               \
                    for (size_t i = i0; i < i1; i++) {                                        \
                        size_t xi = i + di - 1;                                               \
                        for (size_t j = j0; j < j1; j++) {                                    \
                            size_t xj = j + dj - 1;                                           \
                            const float *xrow = x + ((ni * h + xi) * w + xj) * cin;           \
                            float *orow = out + ((ni * h + i) * w + j) * cout;                \
                            for (size_t ci = 0; ci < cin; ci++) {                             \
                                const float *wrow = wgt + ((di * 3 + dj) * cin + ci) * cout;  \
                                axpy_##SUF(orow, wrow, cout, xrow[ci]);                       \
                            }                                                                 \
                        }                                                                     \
                    }                                                                         \
                }                                                                             \
            }                                                                                 \
    }                                                                                         \
    TATTR static void conv_bwd_w_tap_##SUF(const float *xall, const float *dall, size_t n,    \
                                           size_t h, size_t w, size_t cin, size_t cout,       \
                                           float *dw, size_t di, size_t dj) {                 \
        size_t i0, i1, j0, j1;                                                                \
        tap_range(di, h, &i0, &i1);                                                           \
        tap_range(dj, w, &j0, &j1);                                                           \
        for (size_t ni = 0; ni < n; ni++) {                                                   \
            const float *x = xall + ni * h * w * cin;                                         \
            const float *dout = dall + ni * h * w * cout;                                     \
            for (size_t i = i0; i < i1; i++) {                                                \
                size_t xi = i + di - 1;                                                       \
                for (size_t j = j0; j < j1; j++) {                                            \
                    size_t xj = j + dj - 1;                                                   \
                    const float *xrow = x + (xi * w + xj) * cin;                              \
                    const float *drow = dout + (i * w + j) * cout;                            \
                    for (size_t ci = 0; ci < cin; ci++) {                                     \
                        float xv = xrow[ci];                                                  \
                        if (xv == 0.0f) continue;                                             \
                        axpy_##SUF(dw + ((di * 3 + dj) * cin + ci) * cout, drow, cout, xv);   \
                    }                                                                         \
                }                                                                             \
            }                                                                                 \
        }                                                                                     \
    }                                                                                         \
    TATTR static void col2im_image_##SUF(const float *g, float *panel, size_t h, size_t w,    \
                                         size_t cin, size_t ni) {                             \
        size_t k = 9 * cin;                                                                   \
        for (size_t xi = 0; xi < h; xi++)                                                     \
            for (size_t xj = 0; xj < w; xj++) {                                               \
                float *drow = panel + (xi * w + xj) * cin;                                    \
                memset(drow, 0, cin * sizeof(float));                                         \
                for (size_t di = 0; di < 3; di++) {                                           \
                    if (xi + 1 < di || xi + 1 - di >= h) continue;                            \
                    size_t i = xi + 1 - di;                                                   \
                    for (size_t dj = 0; dj < 3; dj++) {                                       \
                        if (xj + 1 < dj || xj + 1 - dj >= w) continue;                        \
                        size_t j = xj + 1 - dj;                                               \
                        const float *grow =                                                   \
                            g + ((ni * h + i) * w + j) * k + (di * 3 + dj) * cin;             \
                        vadd_##SUF(drow, grow, cin);                                          \
                    }                                                                         \
                }                                                                             \
            }                                                                                 \
    }                                                                                         \
    TATTR static void col_sum_##SUF(float *db, const float *dout, size_t rows,                \
                                    size_t cout) {                                            \
        for (size_t r = 0; r < rows; r++) vadd_##SUF(db, dout + r * cout, cout);              \
    }

DEF_ISA_PANELS(sse2, )
DEF_ISA_PANELS(avx2, __attribute__((target("avx2"))))
#endif /* __x86_64__ */

/* db column sum at the routed ISA (same ascending-row chain per output) */
static void col_sum_dispatch(int isa, float *db, const float *dout, size_t rows,
                             size_t cout) {
#if defined(__x86_64__)
    if (isa == ISA_AVX2) {
        col_sum_avx2(db, dout, rows, cout);
        return;
    }
    if (isa == ISA_SSE2) {
        col_sum_sse2(db, dout, rows, cout);
        return;
    }
#else
    (void)isa;
#endif
    for (size_t r = 0; r < rows; r++)
        for (size_t o = 0; o < cout; o++) db[o] += dout[r * cout + o];
}

/* ---------------- gemm path (gemm.rs) ---------------- */
static void im2col3x3(const float *x, size_t n, size_t h, size_t w, size_t cin, float *out) {
    size_t k = 9 * cin;
    memset(out, 0, n * h * w * k * sizeof(float));
    for (size_t ni = 0; ni < n; ni++)
        for (size_t i = 0; i < h; i++)
            for (size_t j = 0; j < w; j++) {
                float *row = out + ((ni * h + i) * w + j) * k;
                for (size_t di = 0; di < 3; di++) {
                    size_t ii = i + di;
                    if (ii < 1 || ii - 1 >= h) continue;
                    size_t xi = ii - 1;
                    for (size_t dj = 0; dj < 3; dj++) {
                        size_t jj = j + dj;
                        if (jj < 1 || jj - 1 >= w) continue;
                        size_t xj = jj - 1;
                        memcpy(row + (di * 3 + dj) * cin,
                               x + ((ni * h + xi) * w + xj) * cin, cin * sizeof(float));
                    }
                }
            }
}

typedef struct {
    const float *g;
    size_t h, w, cin;
    float *dx;
} col2im_env;
static void col2im_item(void *envp, size_t ni) {
    col2im_env *e = (col2im_env *)envp;
    size_t h = e->h, w = e->w, cin = e->cin, k = 9 * cin;
    float *panel = e->dx + ni * h * w * cin;
#if defined(__x86_64__)
    if (g_route[OP_COL2IM] == ISA_AVX2) {
        col2im_image_avx2(e->g, panel, h, w, cin, ni);
        return;
    }
    if (g_route[OP_COL2IM] == ISA_SSE2) {
        col2im_image_sse2(e->g, panel, h, w, cin, ni);
        return;
    }
#endif
    for (size_t xi = 0; xi < h; xi++)
        for (size_t xj = 0; xj < w; xj++) {
            float *drow = panel + (xi * w + xj) * cin;
            memset(drow, 0, cin * sizeof(float));
            for (size_t di = 0; di < 3; di++) {
                if (xi + 1 < di || xi + 1 - di >= h) continue;
                size_t i = xi + 1 - di;
                for (size_t dj = 0; dj < 3; dj++) {
                    if (xj + 1 < dj || xj + 1 - dj >= w) continue;
                    size_t j = xj + 1 - dj;
                    const float *grow =
                        e->g + ((ni * h + i) * w + j) * k + (di * 3 + dj) * cin;
                    for (size_t ci = 0; ci < cin; ci++) drow[ci] += grow[ci];
                }
            }
        }
}
static void col2im3x3(const float *g, size_t n, size_t h, size_t w, size_t cin, float *dx,
                      size_t threads) {
    size_t k = 9 * cin;
    threads = effective_threads(threads, n, 2ull * n * h * w * k);
    col2im_env env = {g, h, w, cin, dx};
    run_static(n, threads, col2im_item, &env);
}

static void transpose_mat(const float *src, size_t rows, size_t cols, float *out) {
    for (size_t r = 0; r < rows; r++)
        for (size_t c = 0; c < cols; c++) out[c * rows + r] = src[r * cols + c];
}

/* rank-1 sgemm: per C row, bias/zero init then k-outer rank-1 updates
 * (ascending k per element; zero-skip on A — bit-exact, see gemm.rs);
 * M-panels of MC rows fanned over threads */
typedef struct {
    size_t m, n, k;
    const float *a, *b, *bias;
    float *c;
} sgemm_env;
static void sgemm_item(void *envp, size_t pi) {
    sgemm_env *e = (sgemm_env *)envp;
    size_t row0 = pi * MC;
    size_t rows = e->m - row0 < MC ? e->m - row0 : MC;
    size_t n = e->n, k = e->k;
    const float *a = e->a, *b = e->b, *bias = e->bias;
    float *c = e->c;
#if defined(__x86_64__)
    if (g_route[OP_SGEMM] == ISA_AVX2) {
        sgemm_rows_avx2(c, row0, rows, n, k, a, b, bias);
        return;
    }
    if (g_route[OP_SGEMM] == ISA_SSE2) {
        sgemm_rows_sse2(c, row0, rows, n, k, a, b, bias);
        return;
    }
#endif
    for (size_t r = row0; r < row0 + rows; r++) {
        float *crow = c + r * n;
        if (bias)
            memcpy(crow, bias, n * sizeof(float));
        else
            memset(crow, 0, n * sizeof(float));
        const float *arow = a + r * k;
        for (size_t p = 0; p < k; p++) {
            float av = arow[p];
            if (av == 0.0f) continue;
            const float *brow = b + p * n;
            for (size_t o = 0; o < n; o++) crow[o] += av * brow[o];
        }
    }
}
static void sgemm(size_t m, size_t n, size_t k, const float *a, const float *b,
                  const float *bias, float *c, size_t threads) {
    if (m == 0 || n == 0) return;
    size_t n_panels = (m + MC - 1) / MC;
    threads = effective_threads(threads, n_panels, 2ull * m * n * k);
    sgemm_env env = {m, n, k, a, b, bias, c};
    run_static(n_panels, threads, sgemm_item, &env);
}

/* direct conv forward, threaded over contiguous image ranges (each range
 * runs the exact reference loop; disjoint out slices) */
typedef struct {
    const float *x, *wgt, *bias;
    size_t n, h, w, cin, cout, per;
    float *out;
} dconv_env;
static void conv_fwd_range(const float *x, size_t n, size_t h, size_t w, size_t cin,
                           const float *wgt, size_t cout, const float *bias, float *out) {
#if defined(__x86_64__)
    if (g_route[OP_CONV_FWD] == ISA_AVX2) {
        conv_fwd_avx2(x, n, h, w, cin, wgt, cout, bias, out);
        return;
    }
    if (g_route[OP_CONV_FWD] == ISA_SSE2) {
        conv_fwd_sse2(x, n, h, w, cin, wgt, cout, bias, out);
        return;
    }
#endif
    conv2d_ref(x, n, h, w, cin, wgt, cout, bias, out);
}
static void dconv_item(void *envp, size_t t) {
    dconv_env *e = (dconv_env *)envp;
    size_t n0 = t * e->per;
    size_t nn = e->n - n0 < e->per ? e->n - n0 : e->per;
    conv_fwd_range(e->x + n0 * e->h * e->w * e->cin, nn, e->h, e->w, e->cin, e->wgt, e->cout,
                   e->bias, e->out + n0 * e->h * e->w * e->cout);
}
static void conv2d_direct(const float *x, size_t n, size_t h, size_t w, size_t cin,
                          const float *wgt, size_t cout, const float *bias, float *out,
                          size_t threads) {
    threads = effective_threads(threads, n, 2ull * n * h * w * 9 * cin * cout);
    if (threads <= 1) {
        conv_fwd_range(x, n, h, w, cin, wgt, cout, bias, out);
        return;
    }
    size_t per = (n + threads - 1) / threads;
    size_t chunks = (n + per - 1) / per;
    dconv_env env = {x, wgt, bias, n, h, w, cin, cout, per, out};
    run_static(chunks, threads, dconv_item, &env);
}

/* direct conv bwd_w, threaded over the 9 kernel taps: each tap owns the
 * contiguous dw rows [(di*3+dj)*cin, +cin) so writes never collide; per
 * dw element the (ni, i, j) scan order is the reference order */
typedef struct {
    const float *x, *dout;
    size_t n, h, w, cin, cout;
    float *dw;
} dwt_env;
static void dwt_item(void *envp, size_t tap) {
    dwt_env *e = (dwt_env *)envp;
    size_t di = tap / 3, dj = tap % 3;
    size_t h = e->h, w = e->w, cin = e->cin, cout = e->cout;
#if defined(__x86_64__)
    if (g_route[OP_CONV_BWD_W] == ISA_AVX2) {
        conv_bwd_w_tap_avx2(e->x, e->dout, e->n, h, w, cin, cout, e->dw, di, dj);
        return;
    }
    if (g_route[OP_CONV_BWD_W] == ISA_SSE2) {
        conv_bwd_w_tap_sse2(e->x, e->dout, e->n, h, w, cin, cout, e->dw, di, dj);
        return;
    }
#endif
    size_t i0, i1, j0, j1;
    tap_range(di, h, &i0, &i1);
    tap_range(dj, w, &j0, &j1);
    for (size_t ni = 0; ni < e->n; ni++) {
        const float *x = e->x + ni * h * w * cin;
        const float *dout = e->dout + ni * h * w * cout;
        for (size_t i = i0; i < i1; i++) {
            size_t xi = i + di - 1;
            for (size_t j = j0; j < j1; j++) {
                size_t xj = j + dj - 1;
                const float *xrow = x + (xi * w + xj) * cin;
                const float *drow = dout + (i * w + j) * cout;
                for (size_t ci = 0; ci < cin; ci++) {
                    float xv = xrow[ci];
                    if (xv == 0.0f) continue;
                    float *dwrow = e->dw + ((di * 3 + dj) * cin + ci) * cout;
                    for (size_t o = 0; o < cout; o++) dwrow[o] += xv * drow[o];
                }
            }
        }
    }
}
static void conv2d_bwd_w_direct(const float *x, size_t n, size_t h, size_t w, size_t cin,
                                const float *dout, size_t cout, float *dw, float *db,
                                size_t threads) {
    threads = effective_threads(threads, 9, 2ull * n * h * w * 9 * cin * cout);
    dwt_env env = {x, dout, n, h, w, cin, cout, dw};
    run_static(9, threads, dwt_item, &env);
    col_sum_dispatch(g_route[OP_CONV_BWD_W], db, dout, n * h * w, cout);
}

typedef struct {
    size_t m, n, k, panel_rows;
    const float *a, *d;
    float *dw;
} atb_env;
static void atb_item(void *envp, size_t pi) {
    atb_env *e = (atb_env *)envp;
    size_t k0 = pi * e->panel_rows;
    size_t krows = e->k - k0 < e->panel_rows ? e->k - k0 : e->panel_rows;
#if defined(__x86_64__)
    if (g_route[OP_ATB] == ISA_AVX2) {
        atb_panel_avx2(e->dw, k0, krows, e->m, e->n, e->k, e->a, e->d);
        return;
    }
    if (g_route[OP_ATB] == ISA_SSE2) {
        atb_panel_sse2(e->dw, k0, krows, e->m, e->n, e->k, e->a, e->d);
        return;
    }
#endif
    for (size_t mi = 0; mi < e->m; mi++) {
        const float *arow = e->a + mi * e->k + k0;
        const float *drow = e->d + mi * e->n;
        for (size_t kk = 0; kk < krows; kk++) {
            float av = arow[kk];
            if (av == 0.0f) continue;
            float *dwrow = e->dw + (k0 + kk) * e->n;
            for (size_t o = 0; o < e->n; o++) dwrow[o] += av * drow[o];
        }
    }
}
static void sgemm_atb(size_t m, size_t n, size_t k, const float *a, const float *d, float *dw,
                      size_t threads) {
    if (k == 0 || n == 0) return;
    size_t mc = MC < k ? MC : k;
    size_t n_panels = (k + mc - 1) / mc;
    threads = effective_threads(threads, n_panels, 2ull * m * n * k);
    size_t panel_rows = (k + threads - 1) / threads;
    size_t chunks = (k + panel_rows - 1) / panel_rows;
    atb_env env = {m, n, k, panel_rows, a, d, dw};
    run_static(chunks, threads, atb_item, &env);
}

/* gemm-path op wrappers (scratch passed in) */
static void conv2d_gemm(const float *x, size_t n, size_t h, size_t w, size_t cin,
                        const float *wgt, size_t cout, const float *bias, float *out,
                        float *scratch_a, size_t threads) {
    im2col3x3(x, n, h, w, cin, scratch_a);
    sgemm(n * h * w, cout, 9 * cin, scratch_a, wgt, bias, out, threads);
}
static void conv2d_bwd_w_gemm(const float *x, size_t n, size_t h, size_t w, size_t cin,
                              const float *dout, size_t cout, float *dw, float *db,
                              float *scratch_a, size_t threads) {
    im2col3x3(x, n, h, w, cin, scratch_a);
    sgemm_atb(n * h * w, cout, 9 * cin, scratch_a, dout, dw, threads);
    col_sum_dispatch(g_route[OP_CONV_BWD_W], db, dout, n * h * w, cout);
}
static void conv2d_bwd_x_gemm(const float *wgt, size_t n, size_t h, size_t w, size_t cin,
                              const float *dout, size_t cout, float *dx, float *scratch_a,
                              float *scratch_b, size_t threads) {
    size_t k = 9 * cin;
    transpose_mat(wgt, k, cout, scratch_b);
    sgemm(n * h * w, k, cout, dout, scratch_b, NULL, scratch_a, threads);
    col2im3x3(scratch_a, n, h, w, cin, dx, threads);
}
static void dense_gemm(const float *x, size_t n, size_t fin, const float *wgt, size_t fout,
                       const float *bias, float *out, size_t threads) {
    sgemm(n, fout, fin, x, wgt, bias, out, threads);
}
static void dense_bwd_gemm(const float *x, const float *wgt, size_t n, size_t fin, size_t fout,
                           const float *dout, float *dw, float *db, float *dx,
                           float *scratch_b, size_t threads) {
    sgemm_atb(n, fout, fin, x, dout, dw, threads);
    col_sum_dispatch(g_route[OP_ATB], db, dout, n, fout);
    transpose_mat(wgt, fin, fout, scratch_b);
    sgemm(n, fin, fout, dout, scratch_b, NULL, dx, threads);
}

/* ---------------- elementwise / pool / loss (ops.rs, unchanged) -------- */
static void relu(const float *x, float *out, size_t len) {
    for (size_t i = 0; i < len; i++) out[i] = x[i] > 0.0f ? x[i] : 0.0f;
}
static void relu_bwd_inplace(const float *act, float *da, size_t len) {
    for (size_t i = 0; i < len; i++)
        if (act[i] <= 0.0f) da[i] = 0.0f;
}
static void max_pool(const float *x, size_t n, size_t h, size_t w, size_t c, float *out,
                     uint8_t *idx) {
    size_t oh = h / 2, ow = w / 2;
    for (size_t ni = 0; ni < n; ni++)
        for (size_t oi = 0; oi < oh; oi++)
            for (size_t oj = 0; oj < ow; oj++) {
                size_t obase = ((ni * oh + oi) * ow + oj) * c;
                for (size_t ci = 0; ci < c; ci++) {
                    float best = -INFINITY;
                    uint8_t bk = 0;
                    for (size_t kk = 0; kk < 4; kk++) {
                        size_t di = kk / 2, dj = kk % 2;
                        float v = x[((ni * h + 2 * oi + di) * w + 2 * oj + dj) * c + ci];
                        if (v > best) {
                            best = v;
                            bk = (uint8_t)kk;
                        }
                    }
                    out[obase + ci] = best;
                    idx[obase + ci] = bk;
                }
            }
}
static void max_pool_bwd(const float *dout, const uint8_t *idx, size_t n, size_t h, size_t w,
                         size_t c, float *dx) {
    memset(dx, 0, n * h * w * c * sizeof(float));
    size_t oh = h / 2, ow = w / 2;
    for (size_t ni = 0; ni < n; ni++)
        for (size_t oi = 0; oi < oh; oi++)
            for (size_t oj = 0; oj < ow; oj++) {
                size_t obase = ((ni * oh + oi) * ow + oj) * c;
                for (size_t ci = 0; ci < c; ci++) {
                    size_t kk = idx[obase + ci];
                    size_t di = kk / 2, dj = kk % 2;
                    dx[((ni * h + 2 * oi + di) * w + 2 * oj + dj) * c + ci] +=
                        dout[obase + ci];
                }
            }
}
static void softmax_xent(const float *logits, const int32_t *labels, size_t n, size_t ncls,
                         float *per) {
    for (size_t ni = 0; ni < n; ni++) {
        const float *row = logits + ni * ncls;
        float mx = -INFINITY;
        for (size_t i = 0; i < ncls; i++)
            if (row[i] > mx) mx = row[i];
        double s = 0.0;
        for (size_t i = 0; i < ncls; i++) s += exp((double)(row[i] - mx));
        float lse = (float)log(s) + mx;
        per[ni] = lse - row[labels[ni]];
    }
}
static void softmax_xent_bwd(const float *logits, const int32_t *labels, size_t n, size_t ncls,
                             const float *dper, float *dl) {
    for (size_t ni = 0; ni < n; ni++) {
        const float *row = logits + ni * ncls;
        float *drow = dl + ni * ncls;
        float mx = -INFINITY;
        for (size_t i = 0; i < ncls; i++)
            if (row[i] > mx) mx = row[i];
        double s = 0.0;
        for (size_t i = 0; i < ncls; i++) s += exp((double)(row[i] - mx));
        float inv = (float)(1.0 / s);
        for (size_t i = 0; i < ncls; i++) drow[i] = expf(row[i] - mx) * inv * dper[ni];
        drow[labels[ni]] -= dper[ni];
    }
}
static void adam_update(float *params, float *m, float *v, const float *g, size_t len,
                        float step, float lr) {
    const float B1 = 0.9f, B2 = 0.999f, EPS = 1e-8f;
    float c1 = 1.0f - powf(B1, step);
    float c2 = 1.0f - powf(B2, step);
    for (size_t i = 0; i < len; i++) {
        float gi = g[i];
        m[i] = B1 * m[i] + (1.0f - B1) * gi;
        v[i] = B2 * v[i] + (1.0f - B2) * gi * gi;
        float mhat = m[i] / c1;
        float vhat = v[i] / c2;
        params[i] -= lr * mhat / (sqrtf(vhat) + EPS);
    }
}

/* ---------------- a study CNN (model.rs cnn_mnist / cnn_cifar) --------- */
typedef struct {
    const char *name;
    size_t h, w, cin;
    size_t filters[3];
    size_t ncls;
} cnn_t;
/* non-BN study models, pool after conv0 and conv1 (model.rs STUDY_CNNS) */
static const cnn_t CNN_MNIST = {"cnn_mnist", 16, 16, 1, {8, 16, 16}, 10};
static const cnn_t CNN_CIFAR = {"cnn_cifar", 32, 32, 3, {16, 32, 32}, 10};

typedef struct {
    size_t h, w, cin, cout, w_off, b_off;
    int pooled;
} layer_t;
typedef struct {
    cnn_t spec;
    layer_t conv[3];
    size_t feat, fc_w_off, fc_b_off, n_params;
} plan_t;

static plan_t plan_new(const cnn_t *spec) {
    plan_t p;
    p.spec = *spec;
    size_t h = spec->h, w = spec->w, cin = spec->cin, off = 0;
    for (int i = 0; i < 3; i++) {
        size_t cout = spec->filters[i];
        p.conv[i] = (layer_t){h, w, cin, cout, off, off + 9 * cin * cout, i < 2};
        off += 9 * cin * cout + cout;
        if (p.conv[i].pooled) {
            h /= 2;
            w /= 2;
        }
        cin = cout;
    }
    p.feat = h * w * cin;
    p.fc_w_off = off;
    off += p.feat * spec->ncls;
    p.fc_b_off = off;
    off += spec->ncls;
    p.n_params = off;
    return p;
}

/* tape buffers sized for the largest use; one set per net */
typedef struct {
    float *xin[3], *act[3], *pooled[3];
    uint8_t *pidx[3];
    float *feat, *logits;
    float *scratch_a, *scratch_b, *buf1, *buf2;
} tape_t;

static float *fmalloc(size_t n) {
    float *p = (float *)malloc(n * sizeof(float));
    assert(p);
    return p;
}

static tape_t tape_new(const plan_t *p, size_t batch) {
    tape_t t;
    size_t max_a = 0, max_b = 0;
    for (int i = 0; i < 3; i++) {
        const layer_t *l = &p->conv[i];
        size_t m = batch * l->h * l->w, k = 9 * l->cin;
        if (m * k > max_a) max_a = m * k;
        if (k * l->cout > max_b) max_b = k * l->cout;
        t.xin[i] = fmalloc(batch * l->h * l->w * l->cin);
        t.act[i] = fmalloc(batch * l->h * l->w * l->cout);
        t.pooled[i] = fmalloc(batch * l->h * l->w * l->cout);
        t.pidx[i] = (uint8_t *)malloc(batch * l->h * l->w * l->cout);
    }
    if (p->feat * p->spec.ncls > max_b) max_b = p->feat * p->spec.ncls;
    t.feat = fmalloc(batch * p->feat);
    t.logits = fmalloc(batch * p->spec.ncls);
    t.scratch_a = fmalloc(max_a);
    t.scratch_b = fmalloc(max_b);
    size_t max_hw = batch * p->conv[0].h * p->conv[0].w * 32;
    t.buf1 = fmalloc(max_hw);
    t.buf2 = fmalloc(max_hw);
    return t;
}

/* forward + backward + mean CE loss; gemm=0 -> reference kernels */
static float loss_grad(const plan_t *p, const float *params, const float *x,
                       const int32_t *y, size_t batch, float *gflat, int gemm,
                       size_t threads, tape_t *t) {
    size_t ncls = p->spec.ncls;
    memset(gflat, 0, p->n_params * sizeof(float));
    /* forward */
    memcpy(t->xin[0], x, batch * p->conv[0].h * p->conv[0].w * p->conv[0].cin * sizeof(float));
    for (int i = 0; i < 3; i++) {
        const layer_t *l = &p->conv[i];
        float *z = t->buf1;
        if (gemm)
            conv2d_direct(t->xin[i], batch, l->h, l->w, l->cin, params + l->w_off, l->cout,
                          params + l->b_off, z, threads);
        else
            conv2d_ref(t->xin[i], batch, l->h, l->w, l->cin, params + l->w_off, l->cout,
                       params + l->b_off, z);
        relu(z, t->act[i], batch * l->h * l->w * l->cout);
        const float *post = t->act[i];
        float *next = (i < 2) ? t->xin[i + 1] : t->feat;
        if (l->pooled) {
            max_pool(post, batch, l->h, l->w, l->cout, t->pooled[i], t->pidx[i]);
            memcpy(next, t->pooled[i],
                   batch * (l->h / 2) * (l->w / 2) * l->cout * sizeof(float));
        } else {
            memcpy(next, post, batch * l->h * l->w * l->cout * sizeof(float));
        }
    }
    if (gemm)
        dense_gemm(t->feat, batch, p->feat, params + p->fc_w_off, ncls, params + p->fc_b_off,
                   t->logits, threads);
    else
        dense_ref(t->feat, batch, p->feat, params + p->fc_w_off, ncls, params + p->fc_b_off,
                  t->logits);
    /* loss */
    float per[512];
    softmax_xent(t->logits, y, batch, ncls, per);
    double lsum = 0.0;
    for (size_t i = 0; i < batch; i++) lsum += (double)per[i];
    float loss = (float)(lsum / (double)batch);
    /* backward */
    float dper[512];
    for (size_t i = 0; i < batch; i++) dper[i] = 1.0f / (float)batch;
    float *dlogits = t->buf1;
    softmax_xent_bwd(t->logits, y, batch, ncls, dper, dlogits);
    float *da = t->buf2;
    if (gemm)
        dense_bwd_gemm(t->feat, params + p->fc_w_off, batch, p->feat, ncls, dlogits,
                       gflat + p->fc_w_off, gflat + p->fc_b_off, da, t->scratch_b, threads);
    else
        dense_bwd_ref(t->feat, params + p->fc_w_off, batch, p->feat, ncls, dlogits,
                      gflat + p->fc_w_off, gflat + p->fc_b_off, da);
    for (int i = 2; i >= 0; i--) {
        const layer_t *l = &p->conv[i];
        if (l->pooled) {
            max_pool_bwd(da, t->pidx[i], batch, l->h, l->w, l->cout, t->buf1);
            float *tmp = da;
            da = t->buf1;
            t->buf1 = tmp;
        }
        relu_bwd_inplace(t->act[i], da, batch * l->h * l->w * l->cout);
        if (gemm)
            conv2d_bwd_w_direct(t->xin[i], batch, l->h, l->w, l->cin, da, l->cout,
                                gflat + l->w_off, gflat + l->b_off, threads);
        else
            conv2d_bwd_w_ref(t->xin[i], batch, l->h, l->w, l->cin, da, l->cout,
                             gflat + l->w_off, gflat + l->b_off);
        if (i > 0) {
            if (gemm)
                conv2d_bwd_x_gemm(params + l->w_off, batch, l->h, l->w, l->cin, da, l->cout,
                                  t->buf1, t->scratch_a, t->scratch_b, threads);
            else
                conv2d_bwd_x_ref(params + l->w_off, batch, l->h, l->w, l->cin, da, l->cout,
                                 t->buf1);
            float *tmp = da;
            da = t->buf1;
            t->buf1 = tmp;
        }
    }
    if (da != t->buf2) { /* keep buffer identity stable across calls */
        float *tmp = t->buf2;
        t->buf2 = da;
        t->buf1 = tmp;
    }
    return loss;
}

/* K=10 scanned Adam steps (entries.rs run_train), B=32 */
static float train_epoch(const plan_t *p, float *params, float *m, float *v, float *step,
                         const float *xs, const int32_t *ys, size_t K, size_t B, int gemm,
                         size_t threads, tape_t *t, float *gflat) {
    size_t sample = p->conv[0].h * p->conv[0].w * p->conv[0].cin;
    double loss_sum = 0.0;
    for (size_t ki = 0; ki < K; ki++) {
        float loss = loss_grad(p, params, xs + ki * B * sample, ys + ki * B, B, gflat, gemm,
                               threads, t);
        *step += 1.0f;
        adam_update(params, m, v, gflat, p->n_params, *step, 1e-2f);
        loss_sum += (double)loss;
    }
    return (float)(loss_sum / (double)K);
}

static void he_init(const plan_t *p, float *params) {
    memset(params, 0, p->n_params * sizeof(float));
    for (int i = 0; i < 3; i++) {
        const layer_t *l = &p->conv[i];
        float std = (float)sqrt(2.0 / (9.0 * (double)l->cin));
        for (size_t j = 0; j < 9 * l->cin * l->cout; j++)
            params[l->w_off + j] = rng_normal() * std;
    }
    float std = (float)sqrt(2.0 / (double)p->feat);
    for (size_t j = 0; j < p->feat * p->spec.ncls; j++)
        params[p->fc_w_off + j] = rng_normal() * std;
}

/* ---------------- equivalence checks ---------------- */
static size_t check_op_equivalence(void) {
    size_t fails = 0;
    /* odd shapes straddling the tile sizes, matching tests/native_gemm.rs */
    size_t shapes[][5] = {{1, 2, 2, 1, 1},  {1, 5, 7, 3, 5},  {2, 4, 4, 1, 8},
                          {3, 6, 5, 2, 10}, {1, 3, 9, 4, 3},  {2, 16, 16, 8, 16}};
    for (size_t s = 0; s < 6; s++) {
        size_t n = shapes[s][0], h = shapes[s][1], w = shapes[s][2], cin = shapes[s][3],
               cout = shapes[s][4];
        size_t xl = n * h * w * cin, ol = n * h * w * cout, wl = 9 * cin * cout;
        float *x = fmalloc(xl), *wgt = fmalloc(wl), *bias = fmalloc(cout);
        float *dout = fmalloc(ol);
        for (size_t i = 0; i < xl; i++) {
            x[i] = rng_normal();
            if ((i % 3) == 0) x[i] = x[i] > 0 ? x[i] : 0.0f; /* exact zeros */
        }
        for (size_t i = 0; i < wl; i++) wgt[i] = rng_normal() * 0.4f;
        for (size_t i = 0; i < cout; i++) bias[i] = rng_normal() * 0.1f;
        for (size_t i = 0; i < ol; i++) dout[i] = rng_normal();
        float *scr_a = fmalloc(n * h * w * 9 * cin), *scr_b = fmalloc(wl);
        float *o1 = fmalloc(ol), *o2 = fmalloc(ol);
        for (size_t th = 1; th <= 4; th += 3) {
            conv2d_ref(x, n, h, w, cin, wgt, cout, bias, o1);
            conv2d_gemm(x, n, h, w, cin, wgt, cout, bias, o2, scr_a, th);
            if (memcmp(o1, o2, ol * sizeof(float))) {
                printf("FAIL conv2d fwd shape %zu threads %zu\n", s, th);
                fails++;
            }
            float *dw1 = fmalloc(wl), *dw2 = fmalloc(wl);
            float *db1 = fmalloc(cout), *db2 = fmalloc(cout);
            memset(dw1, 0, wl * 4);
            memset(dw2, 0, wl * 4);
            memset(db1, 0, cout * 4);
            memset(db2, 0, cout * 4);
            conv2d_bwd_w_ref(x, n, h, w, cin, dout, cout, dw1, db1);
            conv2d_bwd_w_gemm(x, n, h, w, cin, dout, cout, dw2, db2, scr_a, th);
            if (memcmp(dw1, dw2, wl * 4) || memcmp(db1, db2, cout * 4)) {
                printf("FAIL conv2d bwd_w shape %zu threads %zu\n", s, th);
                fails++;
            }
            float *dx1 = fmalloc(xl), *dx2 = fmalloc(xl);
            conv2d_bwd_x_ref(wgt, n, h, w, cin, dout, cout, dx1);
            conv2d_bwd_x_gemm(wgt, n, h, w, cin, dout, cout, dx2, scr_a, scr_b, th);
            if (memcmp(dx1, dx2, xl * 4)) {
                printf("FAIL conv2d bwd_x shape %zu threads %zu\n", s, th);
                fails++;
            }
            free(dw1);
            free(dw2);
            free(db1);
            free(db2);
            free(dx1);
            free(dx2);
        }
        free(x);
        free(wgt);
        free(bias);
        free(dout);
        free(scr_a);
        free(scr_b);
        free(o1);
        free(o2);
    }
    return fails;
}

static size_t check_train_equivalence(const cnn_t *spec) {
    /* full K=10 x several epochs train loop: params must stay bitwise
     * identical between the reference and GEMM paths (any 1-ULP drift
     * would compound and be caught here) */
    plan_t p = plan_new(spec);
    size_t B = 32, K = 10, sample = spec->h * spec->w * spec->cin;
    tape_t t1 = tape_new(&p, B), t2 = tape_new(&p, B);
    float *pa = fmalloc(p.n_params), *pb = fmalloc(p.n_params);
    float *ma = fmalloc(p.n_params), *mb = fmalloc(p.n_params);
    float *va = fmalloc(p.n_params), *vb = fmalloc(p.n_params);
    float *g = fmalloc(p.n_params);
    he_init(&p, pa);
    memcpy(pb, pa, p.n_params * 4);
    memset(ma, 0, p.n_params * 4);
    memset(mb, 0, p.n_params * 4);
    memset(va, 0, p.n_params * 4);
    memset(vb, 0, p.n_params * 4);
    float *xs = fmalloc(K * B * sample);
    int32_t *ys = (int32_t *)malloc(K * B * 4);
    for (size_t i = 0; i < K * B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < K * B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    size_t fails = 0;
    float sa = 0.0f, sb = 0.0f, last = 0.0f;
    for (int e = 0; e < 3; e++) {
        float la = train_epoch(&p, pa, ma, va, &sa, xs, ys, K, B, 0, 1, &t1, g);
        float lb = train_epoch(&p, pb, mb, vb, &sb, xs, ys, K, B, 1, 2, &t2, g);
        if (memcmp(pa, pb, p.n_params * 4) || memcmp(&la, &lb, 4) ||
            memcmp(ma, mb, p.n_params * 4) || memcmp(va, vb, p.n_params * 4)) {
            printf("FAIL %s train epoch %d: state or loss diverged\n", spec->name, e);
            fails++;
        }
        last = la;
    }
    printf("  %s: 3 epochs x K=10 steps bitwise identical (last loss %.6f)\n", spec->name,
           (double)last);
    return fails;
}

/* ---------------- SIMD variant equivalence (vs routed-scalar) --------- */
static size_t check_isa_equivalence(int isa) {
    /* every production op at the forced ISA must be bitwise identical to
     * the routed-scalar production path (itself pinned to ops::reference
     * by check_op_equivalence), on the same odd shapes, threads 1 and 4 */
    size_t fails = 0;
    size_t shapes[][5] = {{1, 2, 2, 1, 1},  {1, 5, 7, 3, 5},  {2, 4, 4, 1, 8},
                          {3, 6, 5, 2, 10}, {1, 3, 9, 4, 3},  {2, 16, 16, 8, 16}};
    for (size_t s = 0; s < 6; s++) {
        size_t n = shapes[s][0], h = shapes[s][1], w = shapes[s][2], cin = shapes[s][3],
               cout = shapes[s][4];
        size_t xl = n * h * w * cin, ol = n * h * w * cout, wl = 9 * cin * cout;
        float *x = fmalloc(xl), *wgt = fmalloc(wl), *bias = fmalloc(cout);
        float *dout = fmalloc(ol);
        for (size_t i = 0; i < xl; i++) {
            x[i] = rng_normal();
            if ((i % 3) == 0) x[i] = x[i] > 0 ? x[i] : 0.0f; /* exact zeros */
        }
        for (size_t i = 0; i < wl; i++) wgt[i] = rng_normal() * 0.4f;
        for (size_t i = 0; i < cout; i++) bias[i] = rng_normal() * 0.1f;
        for (size_t i = 0; i < ol; i++) dout[i] = rng_normal();
        float *scr_a = fmalloc(n * h * w * 9 * cin), *scr_b = fmalloc(wl);
        float *o1 = fmalloc(ol), *o2 = fmalloc(ol);
        float *dw1 = fmalloc(wl), *dw2 = fmalloc(wl);
        float *db1 = fmalloc(cout), *db2 = fmalloc(cout);
        float *dx1 = fmalloc(xl), *dx2 = fmalloc(xl);
        for (size_t th = 1; th <= 4; th += 3) {
            /* conv fwd: direct and im2col lowerings */
            set_route_all(ISA_SCALAR);
            conv2d_direct(x, n, h, w, cin, wgt, cout, bias, o1, th);
            set_route_all(isa);
            conv2d_direct(x, n, h, w, cin, wgt, cout, bias, o2, th);
            if (memcmp(o1, o2, ol * 4)) {
                printf("FAIL %s conv_fwd_direct shape %zu threads %zu\n", isa_name(isa), s, th);
                fails++;
            }
            set_route_all(ISA_SCALAR);
            conv2d_gemm(x, n, h, w, cin, wgt, cout, bias, o1, scr_a, th);
            set_route_all(isa);
            conv2d_gemm(x, n, h, w, cin, wgt, cout, bias, o2, scr_a, th);
            if (memcmp(o1, o2, ol * 4)) {
                printf("FAIL %s conv_fwd_im2col shape %zu threads %zu\n", isa_name(isa), s, th);
                fails++;
            }
            /* conv bwd_w: direct and im2col lowerings */
            memset(dw1, 0, wl * 4);
            memset(db1, 0, cout * 4);
            memset(dw2, 0, wl * 4);
            memset(db2, 0, cout * 4);
            set_route_all(ISA_SCALAR);
            conv2d_bwd_w_direct(x, n, h, w, cin, dout, cout, dw1, db1, th);
            set_route_all(isa);
            conv2d_bwd_w_direct(x, n, h, w, cin, dout, cout, dw2, db2, th);
            if (memcmp(dw1, dw2, wl * 4) || memcmp(db1, db2, cout * 4)) {
                printf("FAIL %s conv_bwd_w_direct shape %zu threads %zu\n", isa_name(isa), s,
                       th);
                fails++;
            }
            memset(dw1, 0, wl * 4);
            memset(db1, 0, cout * 4);
            memset(dw2, 0, wl * 4);
            memset(db2, 0, cout * 4);
            set_route_all(ISA_SCALAR);
            conv2d_bwd_w_gemm(x, n, h, w, cin, dout, cout, dw1, db1, scr_a, th);
            set_route_all(isa);
            conv2d_bwd_w_gemm(x, n, h, w, cin, dout, cout, dw2, db2, scr_a, th);
            if (memcmp(dw1, dw2, wl * 4) || memcmp(db1, db2, cout * 4)) {
                printf("FAIL %s conv_bwd_w_im2col shape %zu threads %zu\n", isa_name(isa), s,
                       th);
                fails++;
            }
            /* conv bwd_x (transpose + G-gemm + col2im) */
            set_route_all(ISA_SCALAR);
            conv2d_bwd_x_gemm(wgt, n, h, w, cin, dout, cout, dx1, scr_a, scr_b, th);
            set_route_all(isa);
            conv2d_bwd_x_gemm(wgt, n, h, w, cin, dout, cout, dx2, scr_a, scr_b, th);
            if (memcmp(dx1, dx2, xl * 4)) {
                printf("FAIL %s conv_bwd_x shape %zu threads %zu\n", isa_name(isa), s, th);
                fails++;
            }
            /* dense fwd/bwd on (n*h*w, cin) -> cout */
            set_route_all(ISA_SCALAR);
            dense_gemm(x, n * h * w, cin, wgt, cout, bias, o1, th);
            set_route_all(isa);
            dense_gemm(x, n * h * w, cin, wgt, cout, bias, o2, th);
            if (memcmp(o1, o2, ol * 4)) {
                printf("FAIL %s dense shape %zu threads %zu\n", isa_name(isa), s, th);
                fails++;
            }
            memset(dw1, 0, wl * 4);
            memset(db1, 0, cout * 4);
            memset(dw2, 0, wl * 4);
            memset(db2, 0, cout * 4);
            set_route_all(ISA_SCALAR);
            dense_bwd_gemm(x, wgt, n * h * w, cin, cout, dout, dw1, db1, dx1, scr_b, th);
            set_route_all(isa);
            dense_bwd_gemm(x, wgt, n * h * w, cin, cout, dout, dw2, db2, dx2, scr_b, th);
            if (memcmp(dw1, dw2, cin * cout * 4) || memcmp(db1, db2, cout * 4) ||
                memcmp(dx1, dx2, xl * 4)) {
                printf("FAIL %s dense_bwd shape %zu threads %zu\n", isa_name(isa), s, th);
                fails++;
            }
        }
        free(x);
        free(wgt);
        free(bias);
        free(dout);
        free(scr_a);
        free(scr_b);
        free(o1);
        free(o2);
        free(dw1);
        free(dw2);
        free(db1);
        free(db2);
        free(dx1);
        free(dx2);
    }
    set_route_all(ISA_SCALAR);
    return fails;
}

static size_t check_isa_train_equivalence(const cnn_t *spec, int isa) {
    /* whole-net train loop: routed-scalar vs forced-ISA production path,
     * bitwise on params/m/v/loss across 3 epochs x K=10 steps */
    plan_t p = plan_new(spec);
    size_t B = 32, K = 10, sample = spec->h * spec->w * spec->cin;
    tape_t t1 = tape_new(&p, B), t2 = tape_new(&p, B);
    float *pa = fmalloc(p.n_params), *pb = fmalloc(p.n_params);
    float *ma = fmalloc(p.n_params), *mb = fmalloc(p.n_params);
    float *va = fmalloc(p.n_params), *vb = fmalloc(p.n_params);
    float *g = fmalloc(p.n_params);
    he_init(&p, pa);
    memcpy(pb, pa, p.n_params * 4);
    memset(ma, 0, p.n_params * 4);
    memset(mb, 0, p.n_params * 4);
    memset(va, 0, p.n_params * 4);
    memset(vb, 0, p.n_params * 4);
    float *xs = fmalloc(K * B * sample);
    int32_t *ys = (int32_t *)malloc(K * B * 4);
    for (size_t i = 0; i < K * B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < K * B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    size_t fails = 0;
    float sa = 0.0f, sb = 0.0f;
    for (int e = 0; e < 3; e++) {
        set_route_all(ISA_SCALAR);
        float la = train_epoch(&p, pa, ma, va, &sa, xs, ys, K, B, 1, 1, &t1, g);
        set_route_all(isa);
        float lb = train_epoch(&p, pb, mb, vb, &sb, xs, ys, K, B, 1, 4, &t2, g);
        if (memcmp(pa, pb, p.n_params * 4) || memcmp(&la, &lb, 4) ||
            memcmp(ma, mb, p.n_params * 4) || memcmp(va, vb, p.n_params * 4)) {
            printf("FAIL %s %s train epoch %d: state or loss diverged\n", spec->name,
                   isa_name(isa), e);
            fails++;
        }
    }
    set_route_all(ISA_SCALAR);
    printf("  %s @ %s: 3 epochs x K=10 steps bitwise identical to scalar route\n",
           spec->name, isa_name(isa));
    return fails;
}

/* ---------------- timing ---------------- */
static double time_train_epoch(const cnn_t *spec, int gemm, size_t threads, int iters) {
    plan_t p = plan_new(spec);
    size_t B = 32, K = 10, sample = spec->h * spec->w * spec->cin;
    tape_t t = tape_new(&p, B);
    float *params = fmalloc(p.n_params), *m = fmalloc(p.n_params), *v = fmalloc(p.n_params);
    float *g = fmalloc(p.n_params);
    he_init(&p, params);
    memset(m, 0, p.n_params * 4);
    memset(v, 0, p.n_params * 4);
    float *xs = fmalloc(K * B * sample);
    int32_t *ys = (int32_t *)malloc(K * B * 4);
    for (size_t i = 0; i < K * B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < K * B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    float step = 0.0f;
    train_epoch(&p, params, m, v, &step, xs, ys, K, B, gemm, threads, &t, g); /* warmup */
    double best_sum = 0.0;
    for (int it = 0; it < iters; it++) {
        double t0 = now_s();
        train_epoch(&p, params, m, v, &step, xs, ys, K, B, gemm, threads, &t, g);
        best_sum += now_s() - t0;
    }
    return best_sum / iters;
}

/* like time_train_epoch but min-of-iters: the shared authoring box has
 * noisy neighbours; min is the honest per-variant throughput estimate */
static double time_train_epoch_min(const cnn_t *spec, int gemm, size_t threads, int iters) {
    plan_t p = plan_new(spec);
    size_t B = 32, K = 10, sample = spec->h * spec->w * spec->cin;
    tape_t t = tape_new(&p, B);
    float *params = fmalloc(p.n_params), *m = fmalloc(p.n_params), *v = fmalloc(p.n_params);
    float *g = fmalloc(p.n_params);
    he_init(&p, params);
    memset(m, 0, p.n_params * 4);
    memset(v, 0, p.n_params * 4);
    float *xs = fmalloc(K * B * sample);
    int32_t *ys = (int32_t *)malloc(K * B * 4);
    for (size_t i = 0; i < K * B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < K * B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    float step = 0.0f;
    train_epoch(&p, params, m, v, &step, xs, ys, K, B, gemm, threads, &t, g); /* warmup */
    double best = 1e30;
    for (int it = 0; it < iters; it++) {
        double t0 = now_s();
        train_epoch(&p, params, m, v, &step, xs, ys, K, B, gemm, threads, &t, g);
        double dt = now_s() - t0;
        if (dt < best) best = dt;
    }
    return best;
}

/* ---------------- per-kernel per-variant GFLOP/s (BENCH_kernels) ------ */
/* Nominal flop counts (borders included as if full) on study-model layer
 * shapes; data is dense-nonzero so the zero-skip never fires and the
 * numbers are pure kernel throughput.  threads=1: the SIMD win is the
 * single-thread axis (thread scaling is measured in BENCH_parallel_study).
 */
typedef struct {
    const char *label;
    size_t n, h, w, cin, cout;
} conv_shape_t;
static const conv_shape_t CONV_BENCH[3] = {
    {"b32 32x32 3->16 (cifar L0)", 32, 32, 32, 3, 16},
    {"b32 16x16 16->32 (cifar L1)", 32, 16, 16, 16, 32},
    {"b32 16x16 1->8 (mnist L0)", 32, 16, 16, 1, 8},
};

typedef struct {
    float *x, *wgt, *bias, *dout, *out, *dw, *db, *dx, *scr_a, *scr_b;
} kbufs_t;
static kbufs_t kbufs_new(const conv_shape_t *s) {
    kbufs_t b;
    size_t xl = s->n * s->h * s->w * s->cin, ol = s->n * s->h * s->w * s->cout;
    size_t wl = 9 * s->cin * s->cout;
    b.x = fmalloc(xl);
    b.wgt = fmalloc(wl);
    b.bias = fmalloc(s->cout);
    b.dout = fmalloc(ol);
    b.out = fmalloc(ol);
    b.dw = fmalloc(wl);
    b.db = fmalloc(s->cout);
    b.dx = fmalloc(xl);
    b.scr_a = fmalloc(s->n * s->h * s->w * 9 * s->cin);
    b.scr_b = fmalloc(wl);
    for (size_t i = 0; i < xl; i++) b.x[i] = rng_normal() + 0.001f; /* dense nonzero */
    for (size_t i = 0; i < wl; i++) b.wgt[i] = rng_normal() * 0.4f;
    for (size_t i = 0; i < s->cout; i++) b.bias[i] = rng_normal() * 0.1f;
    for (size_t i = 0; i < ol; i++) b.dout[i] = rng_normal() + 0.001f;
    return b;
}
static void kbufs_free(kbufs_t *b) {
    free(b->x);
    free(b->wgt);
    free(b->bias);
    free(b->dout);
    free(b->out);
    free(b->dw);
    free(b->db);
    free(b->dx);
    free(b->scr_a);
    free(b->scr_b);
}

/* kernel ids for bench_kernel_once */
enum {
    KB_CONV_FWD_DIRECT,
    KB_CONV_FWD_IM2COL,
    KB_CONV_BWD_W_DIRECT,
    KB_CONV_BWD_W_IM2COL,
    KB_CONV_BWD_X,
    KB_COL2IM,
    KB_IM2COL,
    N_KB
};
static const char *KB_NAME[N_KB] = {
    "conv2d_fwd_direct",  "conv2d_fwd_im2col", "conv2d_bwd_w_direct",
    "conv2d_bwd_w_im2col", "conv2d_bwd_x_gemm", "col2im3x3",
    "im2col3x3",
};
static double kb_flops(int kb, const conv_shape_t *s) {
    double conv = 2.0 * s->n * s->h * s->w * 9.0 * s->cin * s->cout;
    switch (kb) {
        case KB_CONV_BWD_X: return conv + 9.0 * s->n * s->h * s->w * s->cin; /* gemm+adds */
        case KB_COL2IM: return 9.0 * s->n * s->h * s->w * s->cin;            /* adds only */
        case KB_IM2COL: return 9.0 * s->n * s->h * s->w * s->cin;            /* copies */
        default: return conv;
    }
}
static void bench_kernel_once(int kb, const conv_shape_t *s, kbufs_t *b) {
    size_t n = s->n, h = s->h, w = s->w, cin = s->cin, cout = s->cout;
    switch (kb) {
        case KB_CONV_FWD_DIRECT:
            conv2d_direct(b->x, n, h, w, cin, b->wgt, cout, b->bias, b->out, 1);
            break;
        case KB_CONV_FWD_IM2COL:
            conv2d_gemm(b->x, n, h, w, cin, b->wgt, cout, b->bias, b->out, b->scr_a, 1);
            break;
        case KB_CONV_BWD_W_DIRECT:
            memset(b->dw, 0, 9 * cin * cout * 4);
            memset(b->db, 0, cout * 4);
            conv2d_bwd_w_direct(b->x, n, h, w, cin, b->dout, cout, b->dw, b->db, 1);
            break;
        case KB_CONV_BWD_W_IM2COL:
            memset(b->dw, 0, 9 * cin * cout * 4);
            memset(b->db, 0, cout * 4);
            conv2d_bwd_w_gemm(b->x, n, h, w, cin, b->dout, cout, b->dw, b->db, b->scr_a, 1);
            break;
        case KB_CONV_BWD_X:
            conv2d_bwd_x_gemm(b->wgt, n, h, w, cin, b->dout, cout, b->dx, b->scr_a, b->scr_b,
                              1);
            break;
        case KB_COL2IM:
            im2col3x3(b->x, n, h, w, cin, b->scr_a); /* input once; not timed separately */
            col2im3x3(b->scr_a, n, h, w, cin, b->dx, 1);
            break;
        case KB_IM2COL:
            im2col3x3(b->x, n, h, w, cin, b->scr_a);
            break;
    }
}
static double bench_kernel_gflops(int kb, const conv_shape_t *s, kbufs_t *b, int isa) {
    set_route_all(isa);
    bench_kernel_once(kb, s, b); /* warmup */
    double best = 1e30;
    for (int it = 0; it < 5; it++) {
        double t0 = now_s();
        bench_kernel_once(kb, s, b);
        double dt = now_s() - t0;
        if (dt < best) best = dt;
    }
    set_route_all(ISA_SCALAR);
    return kb_flops(kb, s) / best * 1e-9;
}

/* autotune mirror: min-time ISA per route slot on a representative study
 * shape (tune.rs does this per shape-class; one class suffices here) */
static void autotune_routes(void) {
    const conv_shape_t *rep = &CONV_BENCH[1]; /* cifar L1: widest conv */
    kbufs_t b = kbufs_new(rep);
    int kb_of_op[N_ROUTE_OPS] = {KB_CONV_FWD_DIRECT, KB_CONV_BWD_W_DIRECT, KB_CONV_FWD_IM2COL,
                                 KB_CONV_BWD_W_IM2COL, KB_CONV_BWD_X};
    int winners[N_ROUTE_OPS];
    for (int op = 0; op < N_ROUTE_OPS; op++) {
        double best = -1.0;
        winners[op] = ISA_SCALAR;
        for (int isa = 0; isa <= ISA_AVX2; isa++) {
            if (!isa_available(isa)) continue;
            set_route_all(ISA_SCALAR);
            g_route[op] = isa; /* only this slot forced; others scalar */
            bench_kernel_once(kb_of_op[op], rep, &b);
            double dt = 1e30;
            for (int it = 0; it < 3; it++) { /* min-of-3: robust to noise */
                double t0 = now_s();
                bench_kernel_once(kb_of_op[op], rep, &b);
                double d = now_s() - t0;
                if (d < dt) dt = d;
            }
            double gf = kb_flops(kb_of_op[op], rep) / dt * 1e-9;
            if (gf > best) {
                best = gf;
                winners[op] = isa;
            }
        }
    }
    kbufs_free(&b);
    const char *op_names[N_ROUTE_OPS] = {"conv_fwd", "conv_bwd_w", "sgemm", "atb", "col2im"};
    printf("autotuned routes:");
    for (int op = 0; op < N_ROUTE_OPS; op++) {
        g_route[op] = winners[op];
        printf(" %s=%s", op_names[op], isa_name(winners[op]));
    }
    printf("\n");
}

/* pool_64x2M mirror: 64 jobs x 2M LCG mixes (benches/parallel_study.rs) */
typedef struct {
    uint64_t out[64];
} pool_env;
static void pool_item(void *envp, size_t i) {
    pool_env *e = (pool_env *)envp;
    uint64_t x = 0x9e3779b97f4a7c15ull + (uint64_t)i * 0xbf58476d1ce4e5b9ull;
    for (int j = 0; j < 2000000; j++) x = x * 6364136223846793005ull + 1442695040888963407ull;
    e->out[i] = x;
}
static double time_pool(size_t jobs) {
    pool_env env;
    double t0 = now_s();
    run_static(64, jobs, pool_item, &env);
    return now_s() - t0;
}

#ifndef NO_MAIN
int main(int argc, char **argv) {
    (void)argc;
    (void)argv;
    printf("== equivalence: scalar reference vs im2col+GEMM (bitwise) ==\n");
    size_t fails = check_op_equivalence();
    fails += check_train_equivalence(&CNN_MNIST);
    fails += check_train_equivalence(&CNN_CIFAR);
    if (fails) {
        printf("EQUIVALENCE FAILURES: %zu\n", fails);
        return 1;
    }
    printf("all op-level and train-loop checks bitwise identical\n\n");

    printf("== equivalence: explicit SIMD variants vs scalar route (bitwise) ==\n");
    int have_avx2 = isa_available(ISA_AVX2);
    for (int isa = ISA_SSE2; isa <= ISA_AVX2; isa++) {
        if (!isa_available(isa)) {
            printf("  %s: not available on this host, skipped\n", isa_name(isa));
            continue;
        }
        fails += check_isa_equivalence(isa);
        fails += check_isa_train_equivalence(&CNN_MNIST, isa);
        fails += check_isa_train_equivalence(&CNN_CIFAR, isa);
    }
    if (fails) {
        printf("SIMD EQUIVALENCE FAILURES: %zu\n", fails);
        return 1;
    }
    printf("all SIMD variants bitwise identical to the scalar route\n\n");

    printf("== per-kernel per-variant GFLOP/s (threads=1, min of 5) ==\n");
    /* [kb][shape][isa]; -1 = not run */
    static double gf[N_KB][3][3];
    for (int kb = 0; kb < N_KB; kb++)
        for (int si = 0; si < 3; si++)
            for (int isa = 0; isa < 3; isa++) gf[kb][si][isa] = -1.0;
    for (int si = 0; si < 3; si++) {
        const conv_shape_t *s = &CONV_BENCH[si];
        kbufs_t b = kbufs_new(s);
        for (int kb = 0; kb < N_KB; kb++) {
            int n_isa = kb == KB_IM2COL ? 1 : 3; /* packer read side is pure memcpy */
            printf("  %-20s %-28s", KB_NAME[kb], s->label);
            for (int isa = 0; isa < n_isa; isa++) {
                if (!isa_available(isa)) continue;
                gf[kb][si][isa] = bench_kernel_gflops(kb, s, &b, isa);
                printf("  %s %6.2f", isa_name(isa), gf[kb][si][isa]);
            }
            printf("\n");
        }
        kbufs_free(&b);
    }
    printf("\n");

    autotune_routes();
    int tuned[N_ROUTE_OPS];
    memcpy(tuned, g_route, sizeof(tuned));
    printf("\n== timing: train_epoch (K=10, B=32), threads=1, min of 7 ==\n");
    static double tr[2][5]; /* [model][reference, scalar, sse2, avx2, auto] */
    const cnn_t *tmodels[2] = {&CNN_MNIST, &CNN_CIFAR};
    for (int mi = 0; mi < 2; mi++) {
        const cnn_t *s = tmodels[mi];
        set_route_all(ISA_SCALAR);
        tr[mi][0] = time_train_epoch_min(s, 0, 1, 7);
        tr[mi][1] = time_train_epoch_min(s, 1, 1, 7);
        set_route_all(ISA_SSE2);
        tr[mi][2] = time_train_epoch_min(s, 1, 1, 7);
        tr[mi][3] = -1.0;
        if (have_avx2) {
            set_route_all(ISA_AVX2);
            tr[mi][3] = time_train_epoch_min(s, 1, 1, 7);
        }
        memcpy(g_route, tuned, sizeof(tuned));
        tr[mi][4] = time_train_epoch_min(s, 1, 1, 7);
        set_route_all(ISA_SCALAR);
        double best = tr[mi][4];
        printf("%s: ref %.3f ms | scalar %.3f ms | sse2 %.3f ms | avx2 %.3f ms | auto %.3f "
               "ms (auto vs scalar %.2fx)\n",
               s->name, tr[mi][0] * 1e3, tr[mi][1] * 1e3, tr[mi][2] * 1e3, tr[mi][3] * 1e3,
               tr[mi][4] * 1e3, tr[mi][1] / best);
    }
    printf("\n=== BENCH_kernels.json payload ===\n");
    printf("{\n  \"kernels\": [\n");
    int first = 1;
    for (int kb = 0; kb < N_KB; kb++)
        for (int si = 0; si < 3; si++) {
            if (!first) printf(",\n");
            first = 0;
            printf("    {\"kernel\": \"%s\", \"shape\": \"%s\", \"variants\": {", KB_NAME[kb],
                   CONV_BENCH[si].label);
            int f2 = 1;
            for (int isa = 0; isa < 3; isa++) {
                if (gf[kb][si][isa] < 0.0) continue;
                printf("%s\"%s\": %.3f", f2 ? "" : ", ", isa_name(isa), gf[kb][si][isa]);
                f2 = 0;
            }
            printf("}}");
        }
    printf("\n  ],\n  \"train_epoch\": [\n");
    for (int mi = 0; mi < 2; mi++) {
        printf("    {\"model\": \"%s\", \"reference_ms\": %.3f, \"scalar_ms\": %.3f, "
               "\"sse2_ms\": %.3f, \"avx2_ms\": %.3f, \"auto_ms\": %.3f}%s\n",
               tmodels[mi]->name, tr[mi][0] * 1e3, tr[mi][1] * 1e3, tr[mi][2] * 1e3,
               tr[mi][3] * 1e3, tr[mi][4] * 1e3, mi == 0 ? "," : "");
    }
    printf("  ]\n}\n=== end payload ===\n\n");

    printf("== timing: train_epoch (K=10, B=32), mean of 5 ==\n");
    const cnn_t *models[2] = {&CNN_MNIST, &CNN_CIFAR};
    for (int mi = 0; mi < 2; mi++) {
        const cnn_t *s = models[mi];
        double ref = time_train_epoch(s, 0, 1, 5);
        double g1 = time_train_epoch(s, 1, 1, 5);
        double g2 = time_train_epoch(s, 1, 2, 5);
        double g4 = time_train_epoch(s, 1, 4, 5);
        printf("%s: scalar %.3f ms | gemm t1 %.3f ms (%.2fx) | t2 %.3f ms | t4 %.3f ms "
               "(intra t1->t4 %.2fx)\n",
               s->name, ref * 1e3, g1 * 1e3, ref / g1, g2 * 1e3, g4 * 1e3, g1 / g4);
    }

    printf("\n== pool 64x2M mixes (mean of 5, 1 warmup) ==\n");
    for (size_t jobs = 1; jobs <= 8; jobs *= 2) {
        time_pool(jobs); /* warmup */
        double sum = 0.0;
        for (int it = 0; it < 5; it++) sum += time_pool(jobs);
        printf("jobs=%zu: %.4f s\n", jobs, sum / 5.0);
    }
    return 0;
}
#endif /* NO_MAIN */
