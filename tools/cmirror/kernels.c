/* C mirror of the native backend's math kernels (rust/src/native/{ops,gemm}.rs).
 *
 * Purpose (see tools/cmirror/README.md): the authoring container for this
 * repository ships no Rust toolchain, so this mirror is (a) the numeric
 * validation harness for the GEMM rewrite — it transcribes BOTH the scalar
 * reference loop nests and the im2col+GEMM path line-for-line and asserts
 * they agree to 0 ULP (bitwise) on random and ReLU-sparse data, through a
 * full multi-step train loop — and (b) the measurement harness behind the
 * "c-mirror" numbers committed in BENCH_parallel_study.json, pending the
 * first `make bench-native` on a cargo-equipped host.
 *
 * Fidelity rules: float for Rust f32, double for the f64 reduction
 * accumulators, identical loop orders, and NO fp contraction — build with
 *   gcc -O2 -std=c11 -ffp-contract=off -pthread kernels.c -lm
 * so `acc += a*b` rounds twice exactly like rustc emits it.
 */
#define _USE_MATH_DEFINES
#include <assert.h>
#include <math.h>
#include <pthread.h>
#include <stdatomic.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* ---------------- blocking parameters (gemm.rs) ---------------- */
#define MR 4
#define NR 8
#define KC 128
#define MC 64
#define PAR_FLOPS_PER_THREAD 4000000ull

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* splitmix-ish rng for data */
static uint64_t rng_state = 0x12345678;
static uint64_t rng_u64(void) {
    uint64_t z = (rng_state += 0x9e3779b97f4a7c15ull);
    z = (z ^ (z >> 30)) * 0xbf58476d1ce4e5b9ull;
    z = (z ^ (z >> 27)) * 0x94d049bb133111ebull;
    return z ^ (z >> 31);
}
static float rng_normal(void) {
    /* Box-Muller, like tensor::Pcg32::normal in spirit */
    double u1 = (rng_u64() >> 11) * (1.0 / 9007199254740992.0);
    double u2 = (rng_u64() >> 11) * (1.0 / 9007199254740992.0);
    if (u1 < 1e-300) u1 = 1e-300;
    return (float)(sqrt(-2.0 * log(u1)) * cos(2.0 * 3.14159265358979323846 * u2));
}

/* ---------------- run_static mirror (parallel.rs) ---------------- */
typedef void (*item_fn)(void *env, size_t index);
typedef struct {
    item_fn fn;
    void *env;
    size_t base, len;
} chunk_t;
static void *chunk_main(void *p) {
    chunk_t *c = (chunk_t *)p;
    for (size_t i = 0; i < c->len; i++) c->fn(c->env, c->base + i);
    return NULL;
}
/* static contiguous split; caller runs chunk 0 (run_static semantics) */
static void run_static(size_t n, size_t threads, item_fn fn, void *env) {
    if (threads < 1) threads = 1;
    if (threads > n) threads = n ? n : 1;
    if (threads <= 1) {
        for (size_t i = 0; i < n; i++) fn(env, i);
        return;
    }
    chunk_t chunks[64];
    pthread_t tids[64];
    size_t base = 0;
    for (size_t t = 0; t < threads; t++) {
        size_t len = n / threads + (t < n % threads ? 1 : 0);
        chunks[t] = (chunk_t){fn, env, base, len};
        base += len;
    }
    for (size_t t = 1; t < threads; t++) pthread_create(&tids[t], NULL, chunk_main, &chunks[t]);
    chunk_main(&chunks[0]);
    for (size_t t = 1; t < threads; t++) pthread_join(tids[t], NULL);
}

static size_t effective_threads(size_t budget, size_t panels, uint64_t flops) {
    size_t t = budget < 1 ? 1 : budget;
    if (panels < 1) panels = 1;
    if (t > panels) t = panels;
    uint64_t by_work = 1 + flops / PAR_FLOPS_PER_THREAD;
    if (t > by_work) t = (size_t)by_work;
    return t;
}

/* ---------------- reference kernels (ops::reference) ---------------- */
static void tap_range(size_t d, size_t len, size_t *lo, size_t *hi) {
    *lo = d == 0 ? 1 : 0;
    *hi = d == 2 ? len - 1 : len;
}

static void conv2d_ref(const float *x, size_t n, size_t h, size_t w, size_t cin,
                       const float *wgt, size_t cout, const float *bias, float *out) {
    for (size_t r = 0; r < n * h * w; r++) memcpy(out + r * cout, bias, cout * sizeof(float));
    for (size_t ni = 0; ni < n; ni++)
        for (size_t di = 0; di < 3; di++) {
            size_t i0, i1;
            tap_range(di, h, &i0, &i1);
            for (size_t dj = 0; dj < 3; dj++) {
                size_t j0, j1;
                tap_range(dj, w, &j0, &j1);
                for (size_t i = i0; i < i1; i++) {
                    size_t xi = i + di - 1;
                    for (size_t j = j0; j < j1; j++) {
                        size_t xj = j + dj - 1;
                        const float *xrow = x + ((ni * h + xi) * w + xj) * cin;
                        float *orow = out + ((ni * h + i) * w + j) * cout;
                        for (size_t ci = 0; ci < cin; ci++) {
                            const float *wrow = wgt + ((di * 3 + dj) * cin + ci) * cout;
                            float xv = xrow[ci];
                            for (size_t o = 0; o < cout; o++) orow[o] += xv * wrow[o];
                        }
                    }
                }
            }
        }
}

static void conv2d_bwd_w_ref(const float *x, size_t n, size_t h, size_t w, size_t cin,
                             const float *dout, size_t cout, float *dw, float *db) {
    for (size_t ni = 0; ni < n; ni++)
        for (size_t di = 0; di < 3; di++) {
            size_t i0, i1;
            tap_range(di, h, &i0, &i1);
            for (size_t dj = 0; dj < 3; dj++) {
                size_t j0, j1;
                tap_range(dj, w, &j0, &j1);
                for (size_t i = i0; i < i1; i++) {
                    size_t xi = i + di - 1;
                    for (size_t j = j0; j < j1; j++) {
                        size_t xj = j + dj - 1;
                        const float *xrow = x + ((ni * h + xi) * w + xj) * cin;
                        const float *drow = dout + ((ni * h + i) * w + j) * cout;
                        for (size_t ci = 0; ci < cin; ci++) {
                            float *dwrow = dw + ((di * 3 + dj) * cin + ci) * cout;
                            float xv = xrow[ci];
                            for (size_t o = 0; o < cout; o++) dwrow[o] += xv * drow[o];
                        }
                    }
                }
            }
        }
    for (size_t r = 0; r < n * h * w; r++)
        for (size_t o = 0; o < cout; o++) db[o] += dout[r * cout + o];
}

static void conv2d_bwd_x_ref(const float *wgt, size_t n, size_t h, size_t w, size_t cin,
                             const float *dout, size_t cout, float *dx) {
    memset(dx, 0, n * h * w * cin * sizeof(float));
    for (size_t ni = 0; ni < n; ni++)
        for (size_t di = 0; di < 3; di++) {
            size_t i0, i1;
            tap_range(di, h, &i0, &i1);
            for (size_t dj = 0; dj < 3; dj++) {
                size_t j0, j1;
                tap_range(dj, w, &j0, &j1);
                for (size_t i = i0; i < i1; i++) {
                    size_t xi = i + di - 1;
                    for (size_t j = j0; j < j1; j++) {
                        size_t xj = j + dj - 1;
                        const float *drow = dout + ((ni * h + i) * w + j) * cout;
                        float *dxrow = dx + ((ni * h + xi) * w + xj) * cin;
                        for (size_t ci = 0; ci < cin; ci++) {
                            const float *wrow = wgt + ((di * 3 + dj) * cin + ci) * cout;
                            float acc = 0.0f;
                            for (size_t o = 0; o < cout; o++) acc += wrow[o] * drow[o];
                            dxrow[ci] += acc;
                        }
                    }
                }
            }
        }
}

static void dense_ref(const float *x, size_t n, size_t fin, const float *wgt, size_t fout,
                      const float *bias, float *out) {
    for (size_t ni = 0; ni < n; ni++) {
        float *orow = out + ni * fout;
        memcpy(orow, bias, fout * sizeof(float));
        const float *xrow = x + ni * fin;
        for (size_t fi = 0; fi < fin; fi++) {
            const float *wrow = wgt + fi * fout;
            float xv = xrow[fi];
            for (size_t o = 0; o < fout; o++) orow[o] += xv * wrow[o];
        }
    }
}

static void dense_bwd_ref(const float *x, const float *wgt, size_t n, size_t fin, size_t fout,
                          const float *dout, float *dw, float *db, float *dx) {
    for (size_t ni = 0; ni < n; ni++) {
        const float *xrow = x + ni * fin;
        const float *drow = dout + ni * fout;
        for (size_t fi = 0; fi < fin; fi++) {
            float *dwrow = dw + fi * fout;
            float xv = xrow[fi];
            for (size_t o = 0; o < fout; o++) dwrow[o] += xv * drow[o];
        }
        for (size_t o = 0; o < fout; o++) db[o] += drow[o];
        float *dxrow = dx + ni * fin;
        for (size_t fi = 0; fi < fin; fi++) {
            const float *wrow = wgt + fi * fout;
            float acc = 0.0f;
            for (size_t o = 0; o < fout; o++) acc += wrow[o] * drow[o];
            dxrow[fi] = acc;
        }
    }
}

/* ---------------- gemm path (gemm.rs) ---------------- */
static void im2col3x3(const float *x, size_t n, size_t h, size_t w, size_t cin, float *out) {
    size_t k = 9 * cin;
    memset(out, 0, n * h * w * k * sizeof(float));
    for (size_t ni = 0; ni < n; ni++)
        for (size_t i = 0; i < h; i++)
            for (size_t j = 0; j < w; j++) {
                float *row = out + ((ni * h + i) * w + j) * k;
                for (size_t di = 0; di < 3; di++) {
                    size_t ii = i + di;
                    if (ii < 1 || ii - 1 >= h) continue;
                    size_t xi = ii - 1;
                    for (size_t dj = 0; dj < 3; dj++) {
                        size_t jj = j + dj;
                        if (jj < 1 || jj - 1 >= w) continue;
                        size_t xj = jj - 1;
                        memcpy(row + (di * 3 + dj) * cin,
                               x + ((ni * h + xi) * w + xj) * cin, cin * sizeof(float));
                    }
                }
            }
}

typedef struct {
    const float *g;
    size_t h, w, cin;
    float *dx;
} col2im_env;
static void col2im_item(void *envp, size_t ni) {
    col2im_env *e = (col2im_env *)envp;
    size_t h = e->h, w = e->w, cin = e->cin, k = 9 * cin;
    float *panel = e->dx + ni * h * w * cin;
    for (size_t xi = 0; xi < h; xi++)
        for (size_t xj = 0; xj < w; xj++) {
            float *drow = panel + (xi * w + xj) * cin;
            memset(drow, 0, cin * sizeof(float));
            for (size_t di = 0; di < 3; di++) {
                if (xi + 1 < di || xi + 1 - di >= h) continue;
                size_t i = xi + 1 - di;
                for (size_t dj = 0; dj < 3; dj++) {
                    if (xj + 1 < dj || xj + 1 - dj >= w) continue;
                    size_t j = xj + 1 - dj;
                    const float *grow =
                        e->g + ((ni * h + i) * w + j) * k + (di * 3 + dj) * cin;
                    for (size_t ci = 0; ci < cin; ci++) drow[ci] += grow[ci];
                }
            }
        }
}
static void col2im3x3(const float *g, size_t n, size_t h, size_t w, size_t cin, float *dx,
                      size_t threads) {
    size_t k = 9 * cin;
    threads = effective_threads(threads, n, 2ull * n * h * w * k);
    col2im_env env = {g, h, w, cin, dx};
    run_static(n, threads, col2im_item, &env);
}

static void transpose_mat(const float *src, size_t rows, size_t cols, float *out) {
    for (size_t r = 0; r < rows; r++)
        for (size_t c = 0; c < cols; c++) out[c * rows + r] = src[r * cols + c];
}

/* rank-1 sgemm: per C row, bias/zero init then k-outer rank-1 updates
 * (ascending k per element; zero-skip on A — bit-exact, see gemm.rs);
 * M-panels of MC rows fanned over threads */
typedef struct {
    size_t m, n, k;
    const float *a, *b, *bias;
    float *c;
} sgemm_env;
static void sgemm_item(void *envp, size_t pi) {
    sgemm_env *e = (sgemm_env *)envp;
    size_t row0 = pi * MC;
    size_t rows = e->m - row0 < MC ? e->m - row0 : MC;
    size_t n = e->n, k = e->k;
    const float *a = e->a, *b = e->b, *bias = e->bias;
    float *c = e->c;
    for (size_t r = row0; r < row0 + rows; r++) {
        float *crow = c + r * n;
        if (bias)
            memcpy(crow, bias, n * sizeof(float));
        else
            memset(crow, 0, n * sizeof(float));
        const float *arow = a + r * k;
        for (size_t p = 0; p < k; p++) {
            float av = arow[p];
            if (av == 0.0f) continue;
            const float *brow = b + p * n;
            for (size_t o = 0; o < n; o++) crow[o] += av * brow[o];
        }
    }
}
static void sgemm(size_t m, size_t n, size_t k, const float *a, const float *b,
                  const float *bias, float *c, size_t threads) {
    if (m == 0 || n == 0) return;
    size_t n_panels = (m + MC - 1) / MC;
    threads = effective_threads(threads, n_panels, 2ull * m * n * k);
    sgemm_env env = {m, n, k, a, b, bias, c};
    run_static(n_panels, threads, sgemm_item, &env);
}

/* direct conv forward, threaded over contiguous image ranges (each range
 * runs the exact reference loop; disjoint out slices) */
typedef struct {
    const float *x, *wgt, *bias;
    size_t n, h, w, cin, cout, per;
    float *out;
} dconv_env;
static void dconv_item(void *envp, size_t t) {
    dconv_env *e = (dconv_env *)envp;
    size_t n0 = t * e->per;
    size_t nn = e->n - n0 < e->per ? e->n - n0 : e->per;
    conv2d_ref(e->x + n0 * e->h * e->w * e->cin, nn, e->h, e->w, e->cin, e->wgt, e->cout,
               e->bias, e->out + n0 * e->h * e->w * e->cout);
}
static void conv2d_direct(const float *x, size_t n, size_t h, size_t w, size_t cin,
                          const float *wgt, size_t cout, const float *bias, float *out,
                          size_t threads) {
    threads = effective_threads(threads, n, 2ull * n * h * w * 9 * cin * cout);
    if (threads <= 1) {
        conv2d_ref(x, n, h, w, cin, wgt, cout, bias, out);
        return;
    }
    size_t per = (n + threads - 1) / threads;
    size_t chunks = (n + per - 1) / per;
    dconv_env env = {x, wgt, bias, n, h, w, cin, cout, per, out};
    run_static(chunks, threads, dconv_item, &env);
}

/* direct conv bwd_w, threaded over the 9 kernel taps: each tap owns the
 * contiguous dw rows [(di*3+dj)*cin, +cin) so writes never collide; per
 * dw element the (ni, i, j) scan order is the reference order */
typedef struct {
    const float *x, *dout;
    size_t n, h, w, cin, cout;
    float *dw;
} dwt_env;
static void dwt_item(void *envp, size_t tap) {
    dwt_env *e = (dwt_env *)envp;
    size_t di = tap / 3, dj = tap % 3;
    size_t h = e->h, w = e->w, cin = e->cin, cout = e->cout;
    size_t i0, i1, j0, j1;
    tap_range(di, h, &i0, &i1);
    tap_range(dj, w, &j0, &j1);
    for (size_t ni = 0; ni < e->n; ni++) {
        const float *x = e->x + ni * h * w * cin;
        const float *dout = e->dout + ni * h * w * cout;
        for (size_t i = i0; i < i1; i++) {
            size_t xi = i + di - 1;
            for (size_t j = j0; j < j1; j++) {
                size_t xj = j + dj - 1;
                const float *xrow = x + (xi * w + xj) * cin;
                const float *drow = dout + (i * w + j) * cout;
                for (size_t ci = 0; ci < cin; ci++) {
                    float xv = xrow[ci];
                    if (xv == 0.0f) continue;
                    float *dwrow = e->dw + ((di * 3 + dj) * cin + ci) * cout;
                    for (size_t o = 0; o < cout; o++) dwrow[o] += xv * drow[o];
                }
            }
        }
    }
}
static void conv2d_bwd_w_direct(const float *x, size_t n, size_t h, size_t w, size_t cin,
                                const float *dout, size_t cout, float *dw, float *db,
                                size_t threads) {
    threads = effective_threads(threads, 9, 2ull * n * h * w * 9 * cin * cout);
    dwt_env env = {x, dout, n, h, w, cin, cout, dw};
    run_static(9, threads, dwt_item, &env);
    for (size_t r = 0; r < n * h * w; r++)
        for (size_t o = 0; o < cout; o++) db[o] += dout[r * cout + o];
}

typedef struct {
    size_t m, n, k, panel_rows;
    const float *a, *d;
    float *dw;
} atb_env;
static void atb_item(void *envp, size_t pi) {
    atb_env *e = (atb_env *)envp;
    size_t k0 = pi * e->panel_rows;
    size_t krows = e->k - k0 < e->panel_rows ? e->k - k0 : e->panel_rows;
    for (size_t mi = 0; mi < e->m; mi++) {
        const float *arow = e->a + mi * e->k + k0;
        const float *drow = e->d + mi * e->n;
        for (size_t kk = 0; kk < krows; kk++) {
            float av = arow[kk];
            if (av == 0.0f) continue;
            float *dwrow = e->dw + (k0 + kk) * e->n;
            for (size_t o = 0; o < e->n; o++) dwrow[o] += av * drow[o];
        }
    }
}
static void sgemm_atb(size_t m, size_t n, size_t k, const float *a, const float *d, float *dw,
                      size_t threads) {
    if (k == 0 || n == 0) return;
    size_t mc = MC < k ? MC : k;
    size_t n_panels = (k + mc - 1) / mc;
    threads = effective_threads(threads, n_panels, 2ull * m * n * k);
    size_t panel_rows = (k + threads - 1) / threads;
    size_t chunks = (k + panel_rows - 1) / panel_rows;
    atb_env env = {m, n, k, panel_rows, a, d, dw};
    run_static(chunks, threads, atb_item, &env);
}

/* gemm-path op wrappers (scratch passed in) */
static void conv2d_gemm(const float *x, size_t n, size_t h, size_t w, size_t cin,
                        const float *wgt, size_t cout, const float *bias, float *out,
                        float *scratch_a, size_t threads) {
    im2col3x3(x, n, h, w, cin, scratch_a);
    sgemm(n * h * w, cout, 9 * cin, scratch_a, wgt, bias, out, threads);
}
static void conv2d_bwd_w_gemm(const float *x, size_t n, size_t h, size_t w, size_t cin,
                              const float *dout, size_t cout, float *dw, float *db,
                              float *scratch_a, size_t threads) {
    im2col3x3(x, n, h, w, cin, scratch_a);
    sgemm_atb(n * h * w, cout, 9 * cin, scratch_a, dout, dw, threads);
    for (size_t r = 0; r < n * h * w; r++)
        for (size_t o = 0; o < cout; o++) db[o] += dout[r * cout + o];
}
static void conv2d_bwd_x_gemm(const float *wgt, size_t n, size_t h, size_t w, size_t cin,
                              const float *dout, size_t cout, float *dx, float *scratch_a,
                              float *scratch_b, size_t threads) {
    size_t k = 9 * cin;
    transpose_mat(wgt, k, cout, scratch_b);
    sgemm(n * h * w, k, cout, dout, scratch_b, NULL, scratch_a, threads);
    col2im3x3(scratch_a, n, h, w, cin, dx, threads);
}
static void dense_gemm(const float *x, size_t n, size_t fin, const float *wgt, size_t fout,
                       const float *bias, float *out, size_t threads) {
    sgemm(n, fout, fin, x, wgt, bias, out, threads);
}
static void dense_bwd_gemm(const float *x, const float *wgt, size_t n, size_t fin, size_t fout,
                           const float *dout, float *dw, float *db, float *dx,
                           float *scratch_b, size_t threads) {
    sgemm_atb(n, fout, fin, x, dout, dw, threads);
    for (size_t r = 0; r < n; r++)
        for (size_t o = 0; o < fout; o++) db[o] += dout[r * fout + o];
    transpose_mat(wgt, fin, fout, scratch_b);
    sgemm(n, fin, fout, dout, scratch_b, NULL, dx, threads);
}

/* ---------------- elementwise / pool / loss (ops.rs, unchanged) -------- */
static void relu(const float *x, float *out, size_t len) {
    for (size_t i = 0; i < len; i++) out[i] = x[i] > 0.0f ? x[i] : 0.0f;
}
static void relu_bwd_inplace(const float *act, float *da, size_t len) {
    for (size_t i = 0; i < len; i++)
        if (act[i] <= 0.0f) da[i] = 0.0f;
}
static void max_pool(const float *x, size_t n, size_t h, size_t w, size_t c, float *out,
                     uint8_t *idx) {
    size_t oh = h / 2, ow = w / 2;
    for (size_t ni = 0; ni < n; ni++)
        for (size_t oi = 0; oi < oh; oi++)
            for (size_t oj = 0; oj < ow; oj++) {
                size_t obase = ((ni * oh + oi) * ow + oj) * c;
                for (size_t ci = 0; ci < c; ci++) {
                    float best = -INFINITY;
                    uint8_t bk = 0;
                    for (size_t kk = 0; kk < 4; kk++) {
                        size_t di = kk / 2, dj = kk % 2;
                        float v = x[((ni * h + 2 * oi + di) * w + 2 * oj + dj) * c + ci];
                        if (v > best) {
                            best = v;
                            bk = (uint8_t)kk;
                        }
                    }
                    out[obase + ci] = best;
                    idx[obase + ci] = bk;
                }
            }
}
static void max_pool_bwd(const float *dout, const uint8_t *idx, size_t n, size_t h, size_t w,
                         size_t c, float *dx) {
    memset(dx, 0, n * h * w * c * sizeof(float));
    size_t oh = h / 2, ow = w / 2;
    for (size_t ni = 0; ni < n; ni++)
        for (size_t oi = 0; oi < oh; oi++)
            for (size_t oj = 0; oj < ow; oj++) {
                size_t obase = ((ni * oh + oi) * ow + oj) * c;
                for (size_t ci = 0; ci < c; ci++) {
                    size_t kk = idx[obase + ci];
                    size_t di = kk / 2, dj = kk % 2;
                    dx[((ni * h + 2 * oi + di) * w + 2 * oj + dj) * c + ci] +=
                        dout[obase + ci];
                }
            }
}
static void softmax_xent(const float *logits, const int32_t *labels, size_t n, size_t ncls,
                         float *per) {
    for (size_t ni = 0; ni < n; ni++) {
        const float *row = logits + ni * ncls;
        float mx = -INFINITY;
        for (size_t i = 0; i < ncls; i++)
            if (row[i] > mx) mx = row[i];
        double s = 0.0;
        for (size_t i = 0; i < ncls; i++) s += exp((double)(row[i] - mx));
        float lse = (float)log(s) + mx;
        per[ni] = lse - row[labels[ni]];
    }
}
static void softmax_xent_bwd(const float *logits, const int32_t *labels, size_t n, size_t ncls,
                             const float *dper, float *dl) {
    for (size_t ni = 0; ni < n; ni++) {
        const float *row = logits + ni * ncls;
        float *drow = dl + ni * ncls;
        float mx = -INFINITY;
        for (size_t i = 0; i < ncls; i++)
            if (row[i] > mx) mx = row[i];
        double s = 0.0;
        for (size_t i = 0; i < ncls; i++) s += exp((double)(row[i] - mx));
        float inv = (float)(1.0 / s);
        for (size_t i = 0; i < ncls; i++) drow[i] = expf(row[i] - mx) * inv * dper[ni];
        drow[labels[ni]] -= dper[ni];
    }
}
static void adam_update(float *params, float *m, float *v, const float *g, size_t len,
                        float step, float lr) {
    const float B1 = 0.9f, B2 = 0.999f, EPS = 1e-8f;
    float c1 = 1.0f - powf(B1, step);
    float c2 = 1.0f - powf(B2, step);
    for (size_t i = 0; i < len; i++) {
        float gi = g[i];
        m[i] = B1 * m[i] + (1.0f - B1) * gi;
        v[i] = B2 * v[i] + (1.0f - B2) * gi * gi;
        float mhat = m[i] / c1;
        float vhat = v[i] / c2;
        params[i] -= lr * mhat / (sqrtf(vhat) + EPS);
    }
}

/* ---------------- a study CNN (model.rs cnn_mnist / cnn_cifar) --------- */
typedef struct {
    const char *name;
    size_t h, w, cin;
    size_t filters[3];
    size_t ncls;
} cnn_t;
/* non-BN study models, pool after conv0 and conv1 (model.rs STUDY_CNNS) */
static const cnn_t CNN_MNIST = {"cnn_mnist", 16, 16, 1, {8, 16, 16}, 10};
static const cnn_t CNN_CIFAR = {"cnn_cifar", 32, 32, 3, {16, 32, 32}, 10};

typedef struct {
    size_t h, w, cin, cout, w_off, b_off;
    int pooled;
} layer_t;
typedef struct {
    cnn_t spec;
    layer_t conv[3];
    size_t feat, fc_w_off, fc_b_off, n_params;
} plan_t;

static plan_t plan_new(const cnn_t *spec) {
    plan_t p;
    p.spec = *spec;
    size_t h = spec->h, w = spec->w, cin = spec->cin, off = 0;
    for (int i = 0; i < 3; i++) {
        size_t cout = spec->filters[i];
        p.conv[i] = (layer_t){h, w, cin, cout, off, off + 9 * cin * cout, i < 2};
        off += 9 * cin * cout + cout;
        if (p.conv[i].pooled) {
            h /= 2;
            w /= 2;
        }
        cin = cout;
    }
    p.feat = h * w * cin;
    p.fc_w_off = off;
    off += p.feat * spec->ncls;
    p.fc_b_off = off;
    off += spec->ncls;
    p.n_params = off;
    return p;
}

/* tape buffers sized for the largest use; one set per net */
typedef struct {
    float *xin[3], *act[3], *pooled[3];
    uint8_t *pidx[3];
    float *feat, *logits;
    float *scratch_a, *scratch_b, *buf1, *buf2;
} tape_t;

static float *fmalloc(size_t n) {
    float *p = (float *)malloc(n * sizeof(float));
    assert(p);
    return p;
}

static tape_t tape_new(const plan_t *p, size_t batch) {
    tape_t t;
    size_t max_a = 0, max_b = 0;
    for (int i = 0; i < 3; i++) {
        const layer_t *l = &p->conv[i];
        size_t m = batch * l->h * l->w, k = 9 * l->cin;
        if (m * k > max_a) max_a = m * k;
        if (k * l->cout > max_b) max_b = k * l->cout;
        t.xin[i] = fmalloc(batch * l->h * l->w * l->cin);
        t.act[i] = fmalloc(batch * l->h * l->w * l->cout);
        t.pooled[i] = fmalloc(batch * l->h * l->w * l->cout);
        t.pidx[i] = (uint8_t *)malloc(batch * l->h * l->w * l->cout);
    }
    if (p->feat * p->spec.ncls > max_b) max_b = p->feat * p->spec.ncls;
    t.feat = fmalloc(batch * p->feat);
    t.logits = fmalloc(batch * p->spec.ncls);
    t.scratch_a = fmalloc(max_a);
    t.scratch_b = fmalloc(max_b);
    size_t max_hw = batch * p->conv[0].h * p->conv[0].w * 32;
    t.buf1 = fmalloc(max_hw);
    t.buf2 = fmalloc(max_hw);
    return t;
}

/* forward + backward + mean CE loss; gemm=0 -> reference kernels */
static float loss_grad(const plan_t *p, const float *params, const float *x,
                       const int32_t *y, size_t batch, float *gflat, int gemm,
                       size_t threads, tape_t *t) {
    size_t ncls = p->spec.ncls;
    memset(gflat, 0, p->n_params * sizeof(float));
    /* forward */
    memcpy(t->xin[0], x, batch * p->conv[0].h * p->conv[0].w * p->conv[0].cin * sizeof(float));
    for (int i = 0; i < 3; i++) {
        const layer_t *l = &p->conv[i];
        float *z = t->buf1;
        if (gemm)
            conv2d_direct(t->xin[i], batch, l->h, l->w, l->cin, params + l->w_off, l->cout,
                          params + l->b_off, z, threads);
        else
            conv2d_ref(t->xin[i], batch, l->h, l->w, l->cin, params + l->w_off, l->cout,
                       params + l->b_off, z);
        relu(z, t->act[i], batch * l->h * l->w * l->cout);
        const float *post = t->act[i];
        float *next = (i < 2) ? t->xin[i + 1] : t->feat;
        if (l->pooled) {
            max_pool(post, batch, l->h, l->w, l->cout, t->pooled[i], t->pidx[i]);
            memcpy(next, t->pooled[i],
                   batch * (l->h / 2) * (l->w / 2) * l->cout * sizeof(float));
        } else {
            memcpy(next, post, batch * l->h * l->w * l->cout * sizeof(float));
        }
    }
    if (gemm)
        dense_gemm(t->feat, batch, p->feat, params + p->fc_w_off, ncls, params + p->fc_b_off,
                   t->logits, threads);
    else
        dense_ref(t->feat, batch, p->feat, params + p->fc_w_off, ncls, params + p->fc_b_off,
                  t->logits);
    /* loss */
    float per[512];
    softmax_xent(t->logits, y, batch, ncls, per);
    double lsum = 0.0;
    for (size_t i = 0; i < batch; i++) lsum += (double)per[i];
    float loss = (float)(lsum / (double)batch);
    /* backward */
    float dper[512];
    for (size_t i = 0; i < batch; i++) dper[i] = 1.0f / (float)batch;
    float *dlogits = t->buf1;
    softmax_xent_bwd(t->logits, y, batch, ncls, dper, dlogits);
    float *da = t->buf2;
    if (gemm)
        dense_bwd_gemm(t->feat, params + p->fc_w_off, batch, p->feat, ncls, dlogits,
                       gflat + p->fc_w_off, gflat + p->fc_b_off, da, t->scratch_b, threads);
    else
        dense_bwd_ref(t->feat, params + p->fc_w_off, batch, p->feat, ncls, dlogits,
                      gflat + p->fc_w_off, gflat + p->fc_b_off, da);
    for (int i = 2; i >= 0; i--) {
        const layer_t *l = &p->conv[i];
        if (l->pooled) {
            max_pool_bwd(da, t->pidx[i], batch, l->h, l->w, l->cout, t->buf1);
            float *tmp = da;
            da = t->buf1;
            t->buf1 = tmp;
        }
        relu_bwd_inplace(t->act[i], da, batch * l->h * l->w * l->cout);
        if (gemm)
            conv2d_bwd_w_direct(t->xin[i], batch, l->h, l->w, l->cin, da, l->cout,
                                gflat + l->w_off, gflat + l->b_off, threads);
        else
            conv2d_bwd_w_ref(t->xin[i], batch, l->h, l->w, l->cin, da, l->cout,
                             gflat + l->w_off, gflat + l->b_off);
        if (i > 0) {
            if (gemm)
                conv2d_bwd_x_gemm(params + l->w_off, batch, l->h, l->w, l->cin, da, l->cout,
                                  t->buf1, t->scratch_a, t->scratch_b, threads);
            else
                conv2d_bwd_x_ref(params + l->w_off, batch, l->h, l->w, l->cin, da, l->cout,
                                 t->buf1);
            float *tmp = da;
            da = t->buf1;
            t->buf1 = tmp;
        }
    }
    if (da != t->buf2) { /* keep buffer identity stable across calls */
        float *tmp = t->buf2;
        t->buf2 = da;
        t->buf1 = tmp;
    }
    return loss;
}

/* K=10 scanned Adam steps (entries.rs run_train), B=32 */
static float train_epoch(const plan_t *p, float *params, float *m, float *v, float *step,
                         const float *xs, const int32_t *ys, size_t K, size_t B, int gemm,
                         size_t threads, tape_t *t, float *gflat) {
    size_t sample = p->conv[0].h * p->conv[0].w * p->conv[0].cin;
    double loss_sum = 0.0;
    for (size_t ki = 0; ki < K; ki++) {
        float loss = loss_grad(p, params, xs + ki * B * sample, ys + ki * B, B, gflat, gemm,
                               threads, t);
        *step += 1.0f;
        adam_update(params, m, v, gflat, p->n_params, *step, 1e-2f);
        loss_sum += (double)loss;
    }
    return (float)(loss_sum / (double)K);
}

static void he_init(const plan_t *p, float *params) {
    memset(params, 0, p->n_params * sizeof(float));
    for (int i = 0; i < 3; i++) {
        const layer_t *l = &p->conv[i];
        float std = (float)sqrt(2.0 / (9.0 * (double)l->cin));
        for (size_t j = 0; j < 9 * l->cin * l->cout; j++)
            params[l->w_off + j] = rng_normal() * std;
    }
    float std = (float)sqrt(2.0 / (double)p->feat);
    for (size_t j = 0; j < p->feat * p->spec.ncls; j++)
        params[p->fc_w_off + j] = rng_normal() * std;
}

/* ---------------- equivalence checks ---------------- */
static size_t check_op_equivalence(void) {
    size_t fails = 0;
    /* odd shapes straddling the tile sizes, matching tests/native_gemm.rs */
    size_t shapes[][5] = {{1, 2, 2, 1, 1},  {1, 5, 7, 3, 5},  {2, 4, 4, 1, 8},
                          {3, 6, 5, 2, 10}, {1, 3, 9, 4, 3},  {2, 16, 16, 8, 16}};
    for (size_t s = 0; s < 6; s++) {
        size_t n = shapes[s][0], h = shapes[s][1], w = shapes[s][2], cin = shapes[s][3],
               cout = shapes[s][4];
        size_t xl = n * h * w * cin, ol = n * h * w * cout, wl = 9 * cin * cout;
        float *x = fmalloc(xl), *wgt = fmalloc(wl), *bias = fmalloc(cout);
        float *dout = fmalloc(ol);
        for (size_t i = 0; i < xl; i++) {
            x[i] = rng_normal();
            if ((i % 3) == 0) x[i] = x[i] > 0 ? x[i] : 0.0f; /* exact zeros */
        }
        for (size_t i = 0; i < wl; i++) wgt[i] = rng_normal() * 0.4f;
        for (size_t i = 0; i < cout; i++) bias[i] = rng_normal() * 0.1f;
        for (size_t i = 0; i < ol; i++) dout[i] = rng_normal();
        float *scr_a = fmalloc(n * h * w * 9 * cin), *scr_b = fmalloc(wl);
        float *o1 = fmalloc(ol), *o2 = fmalloc(ol);
        for (size_t th = 1; th <= 4; th += 3) {
            conv2d_ref(x, n, h, w, cin, wgt, cout, bias, o1);
            conv2d_gemm(x, n, h, w, cin, wgt, cout, bias, o2, scr_a, th);
            if (memcmp(o1, o2, ol * sizeof(float))) {
                printf("FAIL conv2d fwd shape %zu threads %zu\n", s, th);
                fails++;
            }
            float *dw1 = fmalloc(wl), *dw2 = fmalloc(wl);
            float *db1 = fmalloc(cout), *db2 = fmalloc(cout);
            memset(dw1, 0, wl * 4);
            memset(dw2, 0, wl * 4);
            memset(db1, 0, cout * 4);
            memset(db2, 0, cout * 4);
            conv2d_bwd_w_ref(x, n, h, w, cin, dout, cout, dw1, db1);
            conv2d_bwd_w_gemm(x, n, h, w, cin, dout, cout, dw2, db2, scr_a, th);
            if (memcmp(dw1, dw2, wl * 4) || memcmp(db1, db2, cout * 4)) {
                printf("FAIL conv2d bwd_w shape %zu threads %zu\n", s, th);
                fails++;
            }
            float *dx1 = fmalloc(xl), *dx2 = fmalloc(xl);
            conv2d_bwd_x_ref(wgt, n, h, w, cin, dout, cout, dx1);
            conv2d_bwd_x_gemm(wgt, n, h, w, cin, dout, cout, dx2, scr_a, scr_b, th);
            if (memcmp(dx1, dx2, xl * 4)) {
                printf("FAIL conv2d bwd_x shape %zu threads %zu\n", s, th);
                fails++;
            }
            free(dw1);
            free(dw2);
            free(db1);
            free(db2);
            free(dx1);
            free(dx2);
        }
        free(x);
        free(wgt);
        free(bias);
        free(dout);
        free(scr_a);
        free(scr_b);
        free(o1);
        free(o2);
    }
    return fails;
}

static size_t check_train_equivalence(const cnn_t *spec) {
    /* full K=10 x several epochs train loop: params must stay bitwise
     * identical between the reference and GEMM paths (any 1-ULP drift
     * would compound and be caught here) */
    plan_t p = plan_new(spec);
    size_t B = 32, K = 10, sample = spec->h * spec->w * spec->cin;
    tape_t t1 = tape_new(&p, B), t2 = tape_new(&p, B);
    float *pa = fmalloc(p.n_params), *pb = fmalloc(p.n_params);
    float *ma = fmalloc(p.n_params), *mb = fmalloc(p.n_params);
    float *va = fmalloc(p.n_params), *vb = fmalloc(p.n_params);
    float *g = fmalloc(p.n_params);
    he_init(&p, pa);
    memcpy(pb, pa, p.n_params * 4);
    memset(ma, 0, p.n_params * 4);
    memset(mb, 0, p.n_params * 4);
    memset(va, 0, p.n_params * 4);
    memset(vb, 0, p.n_params * 4);
    float *xs = fmalloc(K * B * sample);
    int32_t *ys = (int32_t *)malloc(K * B * 4);
    for (size_t i = 0; i < K * B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < K * B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    size_t fails = 0;
    float sa = 0.0f, sb = 0.0f, last = 0.0f;
    for (int e = 0; e < 3; e++) {
        float la = train_epoch(&p, pa, ma, va, &sa, xs, ys, K, B, 0, 1, &t1, g);
        float lb = train_epoch(&p, pb, mb, vb, &sb, xs, ys, K, B, 1, 2, &t2, g);
        if (memcmp(pa, pb, p.n_params * 4) || memcmp(&la, &lb, 4) ||
            memcmp(ma, mb, p.n_params * 4) || memcmp(va, vb, p.n_params * 4)) {
            printf("FAIL %s train epoch %d: state or loss diverged\n", spec->name, e);
            fails++;
        }
        last = la;
    }
    printf("  %s: 3 epochs x K=10 steps bitwise identical (last loss %.6f)\n", spec->name,
           (double)last);
    return fails;
}

/* ---------------- timing ---------------- */
static double time_train_epoch(const cnn_t *spec, int gemm, size_t threads, int iters) {
    plan_t p = plan_new(spec);
    size_t B = 32, K = 10, sample = spec->h * spec->w * spec->cin;
    tape_t t = tape_new(&p, B);
    float *params = fmalloc(p.n_params), *m = fmalloc(p.n_params), *v = fmalloc(p.n_params);
    float *g = fmalloc(p.n_params);
    he_init(&p, params);
    memset(m, 0, p.n_params * 4);
    memset(v, 0, p.n_params * 4);
    float *xs = fmalloc(K * B * sample);
    int32_t *ys = (int32_t *)malloc(K * B * 4);
    for (size_t i = 0; i < K * B * sample; i++) xs[i] = rng_normal();
    for (size_t i = 0; i < K * B; i++) ys[i] = (int32_t)(rng_u64() % spec->ncls);
    float step = 0.0f;
    train_epoch(&p, params, m, v, &step, xs, ys, K, B, gemm, threads, &t, g); /* warmup */
    double best_sum = 0.0;
    for (int it = 0; it < iters; it++) {
        double t0 = now_s();
        train_epoch(&p, params, m, v, &step, xs, ys, K, B, gemm, threads, &t, g);
        best_sum += now_s() - t0;
    }
    return best_sum / iters;
}

/* pool_64x2M mirror: 64 jobs x 2M LCG mixes (benches/parallel_study.rs) */
typedef struct {
    uint64_t out[64];
} pool_env;
static void pool_item(void *envp, size_t i) {
    pool_env *e = (pool_env *)envp;
    uint64_t x = 0x9e3779b97f4a7c15ull + (uint64_t)i * 0xbf58476d1ce4e5b9ull;
    for (int j = 0; j < 2000000; j++) x = x * 6364136223846793005ull + 1442695040888963407ull;
    e->out[i] = x;
}
static double time_pool(size_t jobs) {
    pool_env env;
    double t0 = now_s();
    run_static(64, jobs, pool_item, &env);
    return now_s() - t0;
}

#ifndef NO_MAIN
int main(int argc, char **argv) {
    (void)argc;
    (void)argv;
    printf("== equivalence: scalar reference vs im2col+GEMM (bitwise) ==\n");
    size_t fails = check_op_equivalence();
    fails += check_train_equivalence(&CNN_MNIST);
    fails += check_train_equivalence(&CNN_CIFAR);
    if (fails) {
        printf("EQUIVALENCE FAILURES: %zu\n", fails);
        return 1;
    }
    printf("all op-level and train-loop checks bitwise identical\n\n");

    printf("== timing: train_epoch (K=10, B=32), mean of 5 ==\n");
    const cnn_t *models[2] = {&CNN_MNIST, &CNN_CIFAR};
    for (int mi = 0; mi < 2; mi++) {
        const cnn_t *s = models[mi];
        double ref = time_train_epoch(s, 0, 1, 5);
        double g1 = time_train_epoch(s, 1, 1, 5);
        double g2 = time_train_epoch(s, 1, 2, 5);
        double g4 = time_train_epoch(s, 1, 4, 5);
        printf("%s: scalar %.3f ms | gemm t1 %.3f ms (%.2fx) | t2 %.3f ms | t4 %.3f ms "
               "(intra t1->t4 %.2fx)\n",
               s->name, ref * 1e3, g1 * 1e3, ref / g1, g2 * 1e3, g4 * 1e3, g1 / g4);
    }

    printf("\n== pool 64x2M mixes (mean of 5, 1 warmup) ==\n");
    for (size_t jobs = 1; jobs <= 8; jobs *= 2) {
        time_pool(jobs); /* warmup */
        double sum = 0.0;
        for (int it = 0; it < 5; it++) sum += time_pool(jobs);
        printf("jobs=%zu: %.4f s\n", jobs, sum / 5.0);
    }
    return 0;
}
#endif /* NO_MAIN */
