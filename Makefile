# Convenience targets; the Rust error messages and the examples refer to
# `make artifacts`.

.PHONY: artifacts test bench bench-scoring bench-native

# Lower every L2 entry point to HLO text + manifest.json (requires the
# python/ toolchain: JAX CPU; see DESIGN.md "Compile side").
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Tier-1 verify.
test:
	cargo build --release && cargo test -q

bench:
	cargo bench

# Scoring-engine bench (pure Rust, no artifacts); refreshes
# BENCH_fit_scoring.json at the repo root.
bench-scoring:
	cargo bench --bench fit_scoring

# Serial-vs-parallel study + warm-cache bench on the native backend (no
# artifacts needed); refreshes BENCH_parallel_study.json at the repo root.
bench-native:
	FITQ_BACKEND=native cargo bench --bench parallel_study
