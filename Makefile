# Convenience targets; the Rust error messages and the examples refer to
# `make artifacts`.

.PHONY: artifacts test bench bench-scoring bench-native bench-kernels bench-search bench-smoke check-bench-schema check-manifests check-faults check-serve check-trace

# Lower every L2 entry point to HLO text + manifest.json (requires the
# python/ toolchain: JAX CPU; see DESIGN.md "Compile side").
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Tier-1 verify.
test:
	cargo build --release && cargo test -q

bench:
	cargo bench

# Scoring-engine bench (pure Rust, no artifacts); refreshes
# BENCH_fit_scoring.json at the repo root.
bench-scoring:
	cargo bench --bench fit_scoring

# Native kernel before/after (scalar reference vs GEMM layer) +
# serial-vs-parallel study + warm-cache bench on the native backend (no
# artifacts needed); refreshes BENCH_parallel_study.json at the repo root.
bench-native:
	FITQ_BACKEND=native cargo bench --bench parallel_study

# Per-kernel per-variant GFLOP/s + train_epoch wall across forced SIMD
# kernel variants (scalar/sse2/avx2/neon/auto; native backend, no
# artifacts needed); refreshes BENCH_kernels.json at the repo root.
bench-kernels:
	FITQ_BACKEND=native cargo bench --bench kernel_variants

# Search-service bench: cold vs warm request latency and served vs
# in-process scoring throughput (native backend, no artifacts needed;
# equivalence-gated — the served front must be bit-identical to the
# in-process sweep before anything is timed); refreshes
# BENCH_search_service.json at the repo root.
bench-search:
	FITQ_BACKEND=native cargo bench --bench search_service

# CI tripwire: 1-iteration timed native train_epoch, asserts the GEMM
# kernel layer still beats the scalar reference (does not touch the
# committed BENCH json).
bench-smoke:
	FITQ_BENCH_SMOKE=1 FITQ_BACKEND=native cargo bench --bench parallel_study

# Structural validation of the committed BENCH_*.json perf records.
check-bench-schema:
	python3 scripts/check_bench_schema.py BENCH_parallel_study.json BENCH_fit_scoring.json BENCH_kernels.json BENCH_search_service.json

# Fail-closed validation of every committed zoo model manifest
# (parse + compile; DESIGN.md "Model manifests").
check-manifests:
	cargo run --release --bin fitq -- zoo-check zoo/*.json

# Fault drills (DESIGN.md "Failure model"): the deterministic
# fault-injection suite — every registered site degrades to a recompute
# or a typed error, with recovery bit-identical to the fault-free
# baseline — then a CLI-level smoke where a $FITQ_FAULTS-armed run
# publishes one corrupt entry and `fitq cache verify` must quarantine
# it and exit nonzero.
check-faults:
	cargo test -q --test fault_injection
	cargo build --release
	bash scripts/check_faults.sh

# Search-service smoke (DESIGN.md "Search service"): a real `fitq serve`
# on an ephemeral port driven through `fitq query` — score/search/pareto
# round-trips, warm-table reuse, the streamed front tail, a malformed
# request answering with a typed error and a nonzero client exit, and
# `--stats` reporting the resident table.
check-serve:
	cargo build --release
	bash scripts/check_serve.sh

# Op-trace smoke (DESIGN.md "Op tracing & analysis"): a traced native
# train on cnn_mnist, `fitq trace-report` rendering conv rows with rate
# and roofline columns (JSON leg schema-checked), the `fitq tune`
# routing trailer, and a corrupted stored trace exiting nonzero.
check-trace:
	cargo build --release
	bash scripts/check_trace.sh
