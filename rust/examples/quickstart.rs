//! Quickstart: the FIT workflow in ~40 lines.
//!
//! Train a small model, estimate its per-block Fisher traces, and rank a
//! handful of mixed-precision configurations by FIT — all from Rust over
//! the AOT artifacts (`make artifacts` first, then
//! `cargo run --release --example quickstart`).

use fitq::coordinator::{dataset_for, gather, ModelState, TraceOptions, Trainer};
use fitq::data::EvalSet;
use fitq::metrics::fit;
use fitq::quant::{BitConfig, BitConfigSampler, PRECISIONS};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let model = "cnn_mnist";
    let mm = rt.model(model)?.clone();

    // 1. train a full-precision model
    let ds = dataset_for(&rt, model, 0xda7a)?;
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut state = ModelState::init(&rt, model, 0)?;
    let losses = trainer.train(&mut state, 20)?;
    let ev = EvalSet::materialize(ds.as_ref(), 512);
    let fp = trainer.evaluate(&state, &ev)?;
    println!(
        "trained {model}: loss {:.3} -> {:.3}, accuracy {:.3}",
        losses[0],
        losses.last().unwrap(),
        fp.score
    );

    // 2. gather FIT's inputs (EF traces via PJRT, ranges, BN scales)
    let sens = gather(&trainer, ds.as_ref(), &state, &ev, TraceOptions::default())?;
    println!(
        "EF trace converged in {} iterations; per-block traces: {:?}",
        sens.trace.iterations,
        sens.inputs.w_traces.iter().map(|t| format!("{t:.3}")).collect::<Vec<_>>()
    );

    // 3. rank candidate configs by FIT — no training needed per config
    let mut sampler =
        BitConfigSampler::new(mm.n_weight_blocks(), mm.n_act_blocks(), &PRECISIONS, 7);
    let mut ranked: Vec<(f64, BitConfig)> = sampler
        .take(8)
        .into_iter()
        .map(|c| (fit(&sens.inputs, &c), c))
        .collect();
    ranked.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    println!("\nconfigs ranked by FIT (lower = predicted better):");
    for (f, c) in &ranked {
        println!("  FIT {f:.5}  {}", c.label());
    }

    // 4. sanity: QAT-train the best and worst, compare
    for (tag, (_, cfg)) in [("best", &ranked[0]), ("worst", ranked.last().unwrap())] {
        let mut st = state.clone();
        st.reset_optimizer();
        trainer.qat_train(&mut st, cfg, &sens.act, 3)?;
        let q = trainer.evaluate_q(&st, &ev, cfg, &sens.act)?;
        println!("{tag} config by FIT -> quantized accuracy {:.3}", q.score);
    }
    Ok(())
}
