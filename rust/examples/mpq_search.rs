//! MPQ configuration search: sample the exponential config space, extract
//! the FIT-vs-size Pareto front, and run greedy budgeted allocation at
//! several compression targets (the HAWQ-style workflow FIT accelerates —
//! no per-config training anywhere in this binary).
//!
//! Usage: cargo run --release --example mpq_search [model] [samples]

use fitq::coordinator::experiments::get_trained;
use fitq::coordinator::{
    dataset_for, exact_allocate_table, gather, greedy_allocate_table, pareto_front_scores,
    TraceOptions, Trainer,
};
use fitq::data::EvalSet;
use fitq::metrics::{FitTable, PackedConfig};
use fitq::quant::{BitConfigSampler, PRECISIONS};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn_cifar".into());
    let samples: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(100_000);
    let rt = Runtime::from_env()?;
    let mm = rt.model(&model)?.clone();

    let st = get_trained(&rt, &model, 30, 0)?;
    let ds = dataset_for(&rt, &model, 0xda7a)?;
    let trainer = Trainer::new(&rt, ds.as_ref());
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, TraceOptions::default())?;

    let sizes = mm.block_sizes();
    let n_unq = mm.n_unquantized();
    let fp32_bits = mm.n_params as u64 * 32;
    let space = (PRECISIONS.len() as f64).powi((mm.n_weight_blocks() + mm.n_act_blocks()) as i32);
    println!(
        "{model}: config space |B|^(Lw+La) = {space:.2e}; sampling {samples} configs"
    );

    // the scoring table is built once; the sweep and both allocators
    // gather from it (see metrics::FitTable)
    let table = FitTable::new(&sens.inputs, &sizes, n_unq, &PRECISIONS);
    let mut sampler =
        BitConfigSampler::new(mm.n_weight_blocks(), mm.n_act_blocks(), &PRECISIONS, 42);
    let configs = sampler.take(samples);
    let packed: Vec<PackedConfig> = configs.iter().map(|c| table.pack(c)).collect();
    let t0 = std::time::Instant::now();
    let scores = table.score_batch(&packed, 0);
    let dt = t0.elapsed().as_secs_f64();
    let front = pareto_front_scores(&scores);
    println!(
        "Pareto front ({} points of {}, scored at {:.3e} configs/s):",
        front.len(),
        scores.len(),
        scores.len() as f64 / dt.max(1e-9)
    );
    println!("{:>10} {:>8} {:>12}  config", "bits", "comp", "FIT");
    for &i in &front {
        let (fit, size_bits) = scores[i];
        println!(
            "{:>10} {:>7.2}x {:>12.6}  {}",
            size_bits,
            fp32_bits as f64 / size_bits as f64,
            fit,
            configs[i].label()
        );
    }

    println!("\ngreedy allocation vs compression target:");
    for pct in [40u64, 25, 20, 16, 12, 10] {
        let budget = fp32_bits * pct / 100;
        let g = greedy_allocate_table(&table, budget);
        let e = exact_allocate_table(&table, budget);
        match (g, e) {
            (Some(g), Some(e)) => println!(
                "  {pct:>3}% budget -> greedy FIT {:.6} | exact FIT {:.6} ({})  {}",
                g.fit,
                e.fit,
                if (g.fit - e.fit).abs() < 1e-12 { "greedy optimal" } else { "exact wins" },
                e.cfg.label()
            ),
            _ => println!(
                "  {pct:>3}% budget -> no allocation (below the 3-bit floor, \
                 or non-finite sensitivity inputs)"
            ),
        }
    }
    Ok(())
}
