//! MPQ configuration search: sample the exponential config space, extract
//! the FIT-vs-size Pareto front, and run greedy budgeted allocation at
//! several compression targets (the HAWQ-style workflow FIT accelerates —
//! no per-config training anywhere in this binary).
//!
//! Usage: cargo run --release --example mpq_search [model] [samples]

use fitq::coordinator::{dataset_for, exact_allocate, gather, greedy_allocate, pareto_front, score, TraceOptions, Trainer};
use fitq::coordinator::experiments::get_trained;
use fitq::data::EvalSet;
use fitq::quant::{BitConfigSampler, PRECISIONS};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn_cifar".into());
    let samples: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(3000);
    let rt = Runtime::from_env()?;
    let mm = rt.model(&model)?.clone();

    let st = get_trained(&rt, &model, 30, 0)?;
    let ds = dataset_for(&rt, &model, 0xda7a)?;
    let trainer = Trainer::new(&rt, ds.as_ref());
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, TraceOptions::default())?;

    let sizes = mm.block_sizes();
    let n_unq = mm.n_unquantized();
    let fp32_bits = mm.n_params as u64 * 32;
    let space = (PRECISIONS.len() as f64).powi((mm.n_weight_blocks() + mm.n_act_blocks()) as i32);
    println!(
        "{model}: config space |B|^(Lw+La) = {space:.2e}; sampling {samples} configs"
    );

    let mut sampler =
        BitConfigSampler::new(mm.n_weight_blocks(), mm.n_act_blocks(), &PRECISIONS, 42);
    let pts: Vec<_> = sampler
        .take(samples)
        .into_iter()
        .map(|c| score(&sens.inputs, &sizes, n_unq, c))
        .collect();
    let front = pareto_front(&pts);
    println!("Pareto front ({} points of {}):", front.len(), pts.len());
    println!("{:>10} {:>8} {:>12}  config", "bits", "comp", "FIT");
    for &i in &front {
        println!(
            "{:>10} {:>7.2}x {:>12.6}  {}",
            pts[i].size_bits,
            fp32_bits as f64 / pts[i].size_bits as f64,
            pts[i].fit,
            pts[i].cfg.label()
        );
    }

    println!("\ngreedy allocation vs compression target:");
    for pct in [40u64, 25, 20, 16, 12, 10] {
        let budget = fp32_bits * pct / 100;
        let g = greedy_allocate(&sens.inputs, &sizes, n_unq, &PRECISIONS, budget);
        let e = exact_allocate(&sens.inputs, &sizes, n_unq, &PRECISIONS, budget);
        match (g, e) {
            (Some(g), Some(e)) => println!(
                "  {pct:>3}% budget -> greedy FIT {:.6} | exact FIT {:.6} ({})  {}",
                g.fit,
                e.fit,
                if (g.fit - e.fit).abs() < 1e-12 { "greedy optimal" } else { "exact wins" },
                e.cfg.label()
            ),
            _ => println!("  {pct:>3}% budget -> infeasible (below 3-bit floor)"),
        }
    }
    Ok(())
}
