//! Per-layer sensitivity report for one model — the practitioner-facing
//! view of Figs 1/7: converged EF traces per block (weights and
//! activations), quantization ranges, BN scales where present, and each
//! block's FIT contribution under a uniform 4-bit configuration.
//!
//! Usage: cargo run --release --example sensitivity_report [model]

use fitq::coordinator::experiments::get_trained;
use fitq::coordinator::{dataset_for, gather, TraceOptions, Trainer};
use fitq::data::EvalSet;
use fitq::quant::{noise_power, BitConfig};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn_cifar_bn".into());
    let rt = Runtime::from_env()?;
    let mm = rt.model(&model)?.clone();
    let st = get_trained(&rt, &model, 30, 0)?;
    let ds = dataset_for(&rt, &model, 0xda7a)?;
    let trainer = Trainer::new(&rt, ds.as_ref());
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, TraceOptions::default())?;
    let s = &sens.inputs;

    println!("sensitivity report: {model}");
    println!(
        "EF trace: {} iterations (tol {}), per-iteration {:.1} ms\n",
        sens.trace.iterations,
        0.01,
        sens.trace.iter_time_s * 1e3
    );

    let cfg4 = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 4);
    let total_fit: f64 = fitq::metrics::fit(s, &cfg4);

    println!("-- weight blocks (uniform 4-bit contribution breakdown) --");
    println!(
        "{:<12} {:>8} {:>12} {:>18} {:>10} {:>8}",
        "block", "params", "trace", "range", "fit@4b", "share"
    );
    for (i, wb) in mm.weight_blocks.iter().enumerate() {
        let contrib = s.w_traces[i] * noise_power(s.w_lo[i], s.w_hi[i], 4.0);
        let gamma = s.bn_gamma[i]
            .map(|g| format!(" γ={g:.3}"))
            .unwrap_or_default();
        println!(
            "{:<12} {:>8} {:>12.4} [{:>7.3}, {:>6.3}] {:>10.6} {:>7.1}%{}",
            wb.name,
            wb.size,
            s.w_traces[i],
            s.w_lo[i],
            s.w_hi[i],
            contrib,
            100.0 * contrib / total_fit,
            gamma
        );
    }

    println!("\n-- activation blocks --");
    println!(
        "{:<8} {:>14} {:>12} {:>18} {:>10} {:>8}",
        "block", "elems/sample", "trace", "range", "fit@4b", "share"
    );
    for (i, ab) in mm.act_blocks.iter().enumerate() {
        let contrib = s.a_traces[i] * noise_power(s.a_lo[i], s.a_hi[i], 4.0);
        println!(
            "{:<8} {:>14} {:>12.4} [{:>7.3}, {:>6.3}] {:>10.6} {:>7.1}%",
            format!("act{i}"),
            ab.size,
            s.a_traces[i],
            s.a_lo[i],
            s.a_hi[i],
            contrib,
            100.0 * contrib / total_fit
        );
    }

    println!("\ntotal FIT @ uniform 4-bit: {total_fit:.6}");
    println!("interpretation: blocks with the largest share should keep more bits;");
    println!("feed this into `fitq search --model {model}` for a budgeted allocation.");
    Ok(())
}
