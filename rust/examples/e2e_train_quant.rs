//! End-to-end driver (DESIGN.md §Deliverables): exercises every layer of
//! the stack on a real small workload and reports the paper's headline
//! metric.
//!
//! Pipeline: synthetic CIFAR-like data (Rust substrate) -> scanned Adam
//! training through the AOT-compiled L2 graph (whose QAT path runs the L1
//! Pallas fake-quant kernel) -> EF-trace estimation with fixed-tolerance
//! early stopping -> FIT scoring of candidate MPQ configs -> greedy
//! budgeted allocation -> QAT fine-tune of chosen vs baseline config ->
//! predicted-vs-measured comparison, plus training throughput numbers.
//!
//! Usage: cargo run --release --example e2e_train_quant [model] [fp_epochs]

use std::time::Instant;

use fitq::coordinator::{dataset_for, gather, greedy_allocate, ModelState, TraceOptions, Trainer};
use fitq::data::EvalSet;
use fitq::metrics::fit;
use fitq::quant::{compression_ratio, BitConfig, PRECISIONS};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn_cifar".into());
    let fp_epochs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(30);
    let rt = Runtime::from_env()?;
    let mm = rt.model(&model)?.clone();
    println!(
        "== e2e: {model} ({} params, {} weight blocks, {} act blocks) ==",
        mm.n_params,
        mm.n_weight_blocks(),
        mm.n_act_blocks()
    );

    // ---- 1. full-precision training with loss curve ----
    let ds = dataset_for(&rt, &model, 0xda7a)?;
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut state = ModelState::init(&rt, &model, 0)?;
    let t0 = Instant::now();
    let mut curve = Vec::new();
    for _ in 0..fp_epochs {
        curve.push(trainer.train(&mut state, 1)?[0]);
    }
    let train_time = t0.elapsed();
    let steps = fp_epochs * mm.train_k;
    println!("loss curve ({} steps of batch {}):", steps, mm.train_b);
    for (i, l) in curve.iter().enumerate() {
        if i % 5 == 0 || i + 1 == curve.len() {
            println!("  step {:>5}: loss {l:.4}", (i + 1) * mm.train_k);
        }
    }
    println!(
        "throughput: {:.1} steps/s ({:.1} samples/s), total {train_time:.1?}",
        steps as f64 / train_time.as_secs_f64(),
        (steps * mm.train_b) as f64 / train_time.as_secs_f64()
    );

    let ev = EvalSet::materialize(ds.as_ref(), 1024);
    let fp = trainer.evaluate(&state, &ev)?;
    println!("FP accuracy: {:.3} (eval n={})", fp.score, fp.n);

    // ---- 2. sensitivity gathering (EF trace early-stopped at tol) ----
    let t1 = Instant::now();
    let sens = gather(&trainer, ds.as_ref(), &state, &ev, TraceOptions::default())?;
    println!(
        "EF trace: {} iterations @ {:.1} ms/iter ({:.2?} total)",
        sens.trace.iterations,
        sens.trace.iter_time_s * 1e3,
        t1.elapsed()
    );

    // ---- 3. FIT-guided config selection under a 16% size budget ----
    let sizes = mm.block_sizes();
    let n_unq = mm.n_unquantized();
    let budget = ((mm.n_params as u64) * 32) * 16 / 100;
    let chosen = greedy_allocate(&sens.inputs, &sizes, n_unq, &PRECISIONS, budget)
        .expect("budget feasible");
    let uniform4 = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 4);
    println!(
        "greedy FIT config @16% budget: {} (FIT {:.5}, {:.2}x compression)",
        chosen.cfg.label(),
        chosen.fit,
        compression_ratio(&sizes, n_unq, &chosen.cfg)
    );
    println!(
        "uniform-4bit baseline:         {} (FIT {:.5}, {:.2}x compression)",
        uniform4.label(),
        fit(&sens.inputs, &uniform4),
        compression_ratio(&sizes, n_unq, &uniform4)
    );

    // ---- 4. QAT both, measure, compare with prediction ----
    let mut results = Vec::new();
    for (tag, cfg) in [("fit-greedy", &chosen.cfg), ("uniform-4bit", &uniform4)] {
        let mut st = state.clone();
        st.reset_optimizer();
        let t = Instant::now();
        trainer.qat_train(&mut st, cfg, &sens.act, 4)?;
        let q = trainer.evaluate_q(&st, &ev, cfg, &sens.act)?;
        println!(
            "{tag}: quantized accuracy {:.3} (drop {:+.3}) — QAT {:.1?}",
            q.score,
            q.score - fp.score,
            t.elapsed()
        );
        results.push((tag, fit(&sens.inputs, cfg), q.score));
    }
    let (t0n, f0, a0) = results[0];
    let (t1n, f1, a1) = results[1];
    let consistent = (f0 < f1) == (a0 >= a1);
    println!(
        "prediction check: FIT says {} degrades less than {} — measured winner {} ({})",
        if f0 < f1 { t0n } else { t1n },
        if f0 < f1 { t1n } else { t0n },
        if a0 >= a1 { t0n } else { t1n },
        if consistent { "CONSISTENT" } else { "INCONSISTENT" }
    );
    Ok(())
}
