//! Model size accounting under a mixed-precision configuration.
//!
//! Used to build the sensitivity-vs-size Pareto front (the HAWQ-style
//! configuration selection FIT plugs into) and to report compression
//! ratios next to accuracy in the experiments.

use super::BitConfig;

/// Total weight storage in bits for per-block sizes `block_sizes` (number
/// of parameters per quantizable block) under `cfg`. Non-quantized tensors
/// (biases, BN) are counted at 32-bit.
pub fn model_bits(block_sizes: &[usize], n_unquantized: usize, cfg: &BitConfig) -> u64 {
    assert_eq!(block_sizes.len(), cfg.bits_w.len());
    let q: u64 = block_sizes
        .iter()
        .zip(&cfg.bits_w)
        .map(|(&n, &b)| n as u64 * b as u64)
        .sum();
    q + n_unquantized as u64 * 32
}

pub fn model_bytes(block_sizes: &[usize], n_unquantized: usize, cfg: &BitConfig) -> f64 {
    model_bits(block_sizes, n_unquantized, cfg) as f64 / 8.0
}

/// Compression ratio vs full fp32 storage.
pub fn compression_ratio(block_sizes: &[usize], n_unquantized: usize, cfg: &BitConfig) -> f64 {
    let total_params: usize = block_sizes.iter().sum::<usize>() + n_unquantized;
    let fp32 = total_params as u64 * 32;
    fp32 as f64 / model_bits(block_sizes, n_unquantized, cfg) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_accounting() {
        let cfg = BitConfig { bits_w: vec![8, 4], bits_a: vec![] };
        let bits = model_bits(&[100, 200], 10, &cfg);
        assert_eq!(bits, 100 * 8 + 200 * 4 + 10 * 32);
    }

    #[test]
    fn uniform_8bit_is_4x_compression_without_overhead() {
        let cfg = BitConfig::uniform(2, 0, 8);
        let r = compression_ratio(&[1000, 1000], 0, &cfg);
        assert!((r - 4.0).abs() < 1e-12);
    }

    #[test]
    fn lower_bits_compress_more() {
        let sizes = [512usize, 2048];
        let c8 = BitConfig::uniform(2, 0, 8);
        let c3 = BitConfig::uniform(2, 0, 3);
        assert!(
            compression_ratio(&sizes, 16, &c3) > compression_ratio(&sizes, 16, &c8)
        );
    }
}
