//! The quantization noise-power model (paper Appendix E):
//!
//! ```text
//! E[dtheta^2] = delta^2 / 12,   delta = (hi - lo) / (2^b - 1)
//! ```
//!
//! This is the per-parameter noise power FIT multiplies against the
//! per-block Fisher trace. Bits are f64 here because configs are also
//! evaluated at fractional bit widths in the greedy search's relaxation.

/// delta^2 / 12 for a (lo, hi) range at `bits` precision.
pub fn noise_power(lo: f64, hi: f64, bits: f64) -> f64 {
    let levels = (2.0f64).powf(bits) - 1.0;
    if hi <= lo || levels < 1.0 {
        return 0.0;
    }
    let delta = (hi - lo) / levels;
    delta * delta / 12.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_quantizer_model() {
        let q = crate::quant::UniformQuantizer::new(-2.0, 2.0, 4);
        let np = noise_power(-2.0, 2.0, 4.0);
        // quantizer computes delta in f32; compare at f32 precision
        assert!((np - q.noise_power()).abs() / np < 1e-6);
    }

    #[test]
    fn halving_bits_quadruples_noise_asymptotically() {
        let n8 = noise_power(0.0, 1.0, 8.0);
        let n7 = noise_power(0.0, 1.0, 7.0);
        let ratio = n7 / n8;
        assert!((ratio - 4.0).abs() < 0.1, "{ratio}");
    }

    #[test]
    fn degenerate_cases_zero() {
        assert_eq!(noise_power(1.0, 1.0, 8.0), 0.0);
        assert_eq!(noise_power(2.0, 1.0, 8.0), 0.0);
        assert_eq!(noise_power(0.0, 1.0, 0.0), 0.0);
    }

    #[test]
    fn scales_with_range_squared() {
        let n1 = noise_power(0.0, 1.0, 5.0);
        let n3 = noise_power(0.0, 3.0, 5.0);
        assert!((n3 / n1 - 9.0).abs() < 1e-9);
    }
}
