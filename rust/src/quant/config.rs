//! Mixed-precision bit configurations.
//!
//! A `BitConfig` assigns one precision from the paper's candidate set
//! {8, 6, 4, 3} to every weight block and every activation block. The
//! Table-2 study samples these uniformly at random (paper Appendix D);
//! the search module additionally enumerates and greedily allocates them.

use crate::tensor::Pcg32;

/// The paper's candidate precisions (Appendix D).
pub const PRECISIONS: [u32; 4] = [8, 6, 4, 3];

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BitConfig {
    pub bits_w: Vec<u32>,
    pub bits_a: Vec<u32>,
}

impl BitConfig {
    /// The uniform configuration: every weight and activation block at the
    /// same precision.
    pub fn uniform(lw: usize, la: usize, bits: u32) -> Self {
        BitConfig { bits_w: vec![bits; lw], bits_a: vec![bits; la] }
    }

    /// Sample uniformly at random from `precisions^(lw+la)`.
    pub fn random(lw: usize, la: usize, precisions: &[u32], rng: &mut Pcg32) -> Self {
        BitConfig {
            bits_w: (0..lw).map(|_| *rng.choose(precisions)).collect(),
            bits_a: (0..la).map(|_| *rng.choose(precisions)).collect(),
        }
    }

    /// Number of weight blocks this configuration covers.
    pub fn n_weight_blocks(&self) -> usize {
        self.bits_w.len()
    }

    /// Number of activation blocks this configuration covers.
    pub fn n_act_blocks(&self) -> usize {
        self.bits_a.len()
    }

    /// f32 vectors in executable-input form.
    pub fn bits_w_f32(&self) -> Vec<f32> {
        self.bits_w.iter().map(|&b| b as f32).collect()
    }

    pub fn bits_a_f32(&self) -> Vec<f32> {
        self.bits_a.iter().map(|&b| b as f32).collect()
    }

    /// Mean bit width across all blocks (compression proxy for reports).
    /// A block-less configuration has mean 0.0 (not NaN from 0/0).
    pub fn mean_bits(&self) -> f64 {
        let n = self.bits_w.len() + self.bits_a.len();
        if n == 0 {
            return 0.0;
        }
        let total: u64 =
            self.bits_w.iter().chain(&self.bits_a).map(|&b| b as u64).sum();
        total as f64 / n as f64
    }

    /// Compact display form, e.g. "w[8,4,3,8] a[6,6,4]".
    pub fn label(&self) -> String {
        let j = |v: &[u32]| v.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",");
        format!("w[{}] a[{}]", j(&self.bits_w), j(&self.bits_a))
    }
}

/// Samples distinct random configurations (the Table-2 workload generator).
pub struct BitConfigSampler {
    lw: usize,
    la: usize,
    precisions: Vec<u32>,
    seen: std::collections::HashSet<BitConfig>,
    rng: Pcg32,
}

impl BitConfigSampler {
    pub fn new(lw: usize, la: usize, precisions: &[u32], seed: u64) -> Self {
        BitConfigSampler {
            lw,
            la,
            precisions: precisions.to_vec(),
            seen: Default::default(),
            rng: Pcg32::new(seed, 0xb17c0f16),
        }
    }

    /// Total size of the configuration space |B|^(Lw+La).
    pub fn space_size(&self) -> f64 {
        (self.precisions.len() as f64).powi((self.lw + self.la) as i32)
    }

    /// Next configuration not seen before (None once the space is exhausted).
    pub fn sample_distinct(&mut self) -> Option<BitConfig> {
        if (self.seen.len() as f64) >= self.space_size() {
            return None;
        }
        loop {
            let c = BitConfig::random(self.lw, self.la, &self.precisions, &mut self.rng);
            if self.seen.insert(c.clone()) {
                return Some(c);
            }
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<BitConfig> {
        (0..n).map_while(|_| self.sample_distinct()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_draws_only_allowed_precisions() {
        let mut r = Pcg32::new(1, 1);
        for _ in 0..50 {
            let c = BitConfig::random(5, 3, &PRECISIONS, &mut r);
            assert!(c.bits_w.iter().all(|b| PRECISIONS.contains(b)));
            assert!(c.bits_a.iter().all(|b| PRECISIONS.contains(b)));
            assert_eq!((c.n_weight_blocks(), c.n_act_blocks()), (5, 3));
        }
    }

    #[test]
    fn sampler_yields_distinct_configs() {
        let mut s = BitConfigSampler::new(4, 3, &PRECISIONS, 7);
        let configs = s.take(200);
        assert_eq!(configs.len(), 200);
        let set: std::collections::HashSet<_> = configs.iter().collect();
        assert_eq!(set.len(), 200);
    }

    #[test]
    fn sampler_exhausts_small_space() {
        // 2 precisions, 1+1 blocks -> 4 configs total
        let mut s = BitConfigSampler::new(1, 1, &[4, 8], 3);
        let configs = s.take(100);
        assert_eq!(configs.len(), 4);
        assert!(s.sample_distinct().is_none());
    }

    #[test]
    fn mean_bits_of_empty_config_is_zero() {
        // regression: 0/0 used to yield NaN for a block-less config
        let c = BitConfig { bits_w: vec![], bits_a: vec![] };
        assert_eq!(c.mean_bits(), 0.0);
    }

    #[test]
    fn mean_bits_and_label() {
        let c = BitConfig { bits_w: vec![8, 4], bits_a: vec![3, 3] };
        assert!((c.mean_bits() - 4.5).abs() < 1e-12);
        assert_eq!(c.label(), "w[8,4] a[3,3]");
    }

    #[test]
    fn uniform_config() {
        let c = BitConfig::uniform(3, 2, 8);
        assert_eq!(c.bits_w, vec![8, 8, 8]);
        assert_eq!(c.bits_a, vec![8, 8]);
        assert_eq!(c.mean_bits(), 8.0);
    }

    #[test]
    fn sampler_coverage_is_roughly_uniform() {
        let mut s = BitConfigSampler::new(1, 0, &PRECISIONS, 11);
        // only 4 possible configs; all must appear
        let configs = s.take(4);
        let mut bits: Vec<u32> = configs.iter().map(|c| c.bits_w[0]).collect();
        bits.sort();
        assert_eq!(bits, vec![3, 4, 6, 8]);
    }
}
