//! Uniform min-max quantizer (paper Appendix E):
//!
//! ```text
//! Q(x) = round((clip(x) - lo) / delta) * delta + lo
//! delta = (hi - lo) / (2^b - 1)
//! ```
//!
//! Semantics match the L1 Pallas `fake_quant` kernel and its jnp oracle
//! exactly (degenerate ranges pass through) — the Rust side uses this for
//! offline analysis: Fig. 9's noise-distribution study and Fig. 5a's
//! noise-vs-magnitude scatter, both computed on trained weights without a
//! PJRT dispatch.

#[derive(Debug, Clone, Copy)]
pub struct UniformQuantizer {
    pub lo: f32,
    pub hi: f32,
    pub bits: u32,
}

impl UniformQuantizer {
    pub fn new(lo: f32, hi: f32, bits: u32) -> Self {
        UniformQuantizer { lo, hi, bits }
    }

    /// Fit the range to the data (min-max calibration, paper Appendix A).
    pub fn fit(xs: &[f32], bits: u32) -> Self {
        let (lo, hi) = crate::tensor::min_max(xs).unwrap_or((0.0, 0.0));
        UniformQuantizer { lo, hi, bits }
    }

    pub fn levels(&self) -> u64 {
        (1u64 << self.bits) - 1
    }

    /// Quantization step width delta.
    pub fn delta(&self) -> f32 {
        if self.degenerate() {
            0.0
        } else {
            (self.hi - self.lo) / self.levels() as f32
        }
    }

    pub fn degenerate(&self) -> bool {
        self.hi <= self.lo || self.bits == 0
    }

    /// Quantize-dequantize one value.
    pub fn apply(&self, x: f32) -> f32 {
        if self.degenerate() {
            return x;
        }
        let d = self.delta();
        let c = x.clamp(self.lo, self.hi);
        ((c - self.lo) / d).round() * d + self.lo
    }

    /// Quantize-dequantize a slice into a new vector.
    pub fn apply_vec(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.apply(x)).collect()
    }

    /// Uniform-noise model power: E[(Q(x)-x)^2] = delta^2 / 12.
    pub fn noise_power(&self) -> f64 {
        let d = self.delta() as f64;
        d * d / 12.0
    }

    /// Empirical noise power over a sample.
    pub fn empirical_noise_power(&self, xs: &[f32]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|&x| {
                let e = (self.apply(x) - x) as f64;
                e * e
            })
            .sum::<f64>()
            / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn endpoints_are_fixed_points() {
        let q = UniformQuantizer::new(-1.5, 2.5, 3);
        assert_eq!(q.apply(-1.5), -1.5);
        assert_eq!(q.apply(2.5), 2.5);
    }

    #[test]
    fn error_bounded_by_half_delta() {
        let q = UniformQuantizer::new(-2.0, 2.0, 4);
        let mut r = Pcg32::new(1, 1);
        for _ in 0..2000 {
            let x = r.uniform_in(-2.0, 2.0);
            assert!((q.apply(x) - x).abs() <= q.delta() / 2.0 + 1e-6);
        }
    }

    #[test]
    fn idempotent() {
        let q = UniformQuantizer::new(-1.0, 1.0, 5);
        let mut r = Pcg32::new(2, 1);
        for _ in 0..500 {
            let x = r.normal();
            let once = q.apply(x);
            assert!((q.apply(once) - once).abs() < 1e-6);
        }
    }

    #[test]
    fn level_count_is_2_pow_b() {
        let q = UniformQuantizer::new(-1.0, 1.0, 2);
        let mut levels = std::collections::BTreeSet::new();
        for i in 0..=1000 {
            let x = -1.0 + 2.0 * i as f32 / 1000.0;
            levels.insert((q.apply(x) * 1e4).round() as i64);
        }
        assert_eq!(levels.len(), 4);
    }

    #[test]
    fn clips_out_of_range() {
        let q = UniformQuantizer::new(0.0, 1.0, 8);
        assert_eq!(q.apply(5.0), 1.0);
        assert_eq!(q.apply(-5.0), 0.0);
    }

    #[test]
    fn degenerate_passthrough() {
        let q = UniformQuantizer::new(1.0, 1.0, 8);
        assert_eq!(q.apply(3.7), 3.7);
        assert_eq!(q.noise_power(), 0.0);
    }

    #[test]
    fn noise_power_model_matches_empirical_for_uniform_data() {
        // Appendix E / Fig. 9: uniform inputs -> E[(Q(x)-x)^2] ~ delta^2/12
        let q = UniformQuantizer::new(-1.0, 3.0, 6);
        let mut r = Pcg32::new(3, 1);
        let xs: Vec<f32> = (0..200_000).map(|_| r.uniform_in(-1.0, 3.0)).collect();
        let emp = q.empirical_noise_power(&xs);
        let model = q.noise_power();
        assert!((emp - model).abs() / model < 0.05, "emp={emp} model={model}");
    }

    #[test]
    fn fit_covers_data() {
        let xs = [0.5, -1.25, 3.0, 0.0];
        let q = UniformQuantizer::fit(&xs, 8);
        assert_eq!((q.lo, q.hi), (-1.25, 3.0));
        // all data quantize within half-delta
        for &x in &xs {
            assert!((q.apply(x) - x).abs() <= q.delta() / 2.0 + 1e-6);
        }
    }

    #[test]
    fn more_bits_less_noise() {
        let mut r = Pcg32::new(4, 1);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal()).collect();
        let mut prev = f64::INFINITY;
        for bits in [2, 3, 4, 6, 8] {
            let q = UniformQuantizer::fit(&xs, bits);
            let e = q.empirical_noise_power(&xs);
            assert!(e < prev, "bits={bits} e={e} prev={prev}");
            prev = e;
        }
    }
}
