//! Quantization substrate: the uniform min-max quantizer (mirroring the L1
//! `fake_quant` kernel bit-for-bit in semantics), the uniform-noise power
//! model (paper Appendix E), mixed-precision bit configurations, and model
//! size accounting.

mod config;
mod noise;
mod size;
mod uniform;

pub use config::{BitConfig, BitConfigSampler, PRECISIONS};
pub use noise::noise_power;
pub use size::{model_bits, model_bytes, compression_ratio};
pub use uniform::UniformQuantizer;
