//! Minimal benchmark harness (criterion is not in the vendored dependency
//! set). `cargo bench` runs the registered `harness = false` binaries,
//! which use this: warmup, timed iterations, mean ± std, ns/op report.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` with `iters` measured iterations after `warmup` unmeasured
/// ones. Returns per-iteration statistics over per-iteration samples.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let r = BenchResult { name: name.to_string(), iters, mean_ns: mean, std_ns: var.sqrt() };
    println!(
        "{:<42} {:>12.2} us/iter (± {:>8.2} us, {} iters, {:>10.1} ops/s)",
        r.name,
        r.mean_ns / 1e3,
        r.std_ns / 1e3,
        r.iters,
        r.per_sec()
    );
    r
}

/// Keep a value from being optimized away.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 5);
    }
}
