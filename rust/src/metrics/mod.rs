//! Sensitivity metrics: FIT and the paper's comparison heuristics.
//!
//! FIT (paper §4.2 / Appendix E):
//!
//! ```text
//! FIT = sum_l Tr(I_hat(theta_l)) * [ (w_hi - w_lo) / (2^b_l - 1) ]^2 / 12
//!     + sum_l Tr(I_hat(a_l))     * [ (a_hi - a_lo) / (2^b_l - 1) ]^2 / 12
//! ```
//!
//! (the paper drops the constant 1/12 w.l.o.g.; we keep it so weight and
//! activation terms stay on the physical noise-power scale — it cancels in
//! every rank correlation.)
//!
//! Baselines (paper Appendix D.1): QR replaces the trace with the inverse
//! quantization range, BN with the inverse batch-norm scale, and Noise
//! drops the sensitivity weighting entirely. The _W / _A ablations keep
//! only the weight or activation term.
//!
//! For scoring configurations at scale, [`FitTable`] precomputes every
//! per-block × per-precision FIT contribution once so each score is a flat
//! gather-sum, bit-identical to [`fit()`] (see `table.rs`).

mod baselines;
mod fit;
mod table;

pub use baselines::{bn_metric, noise_metric, qr, qr_a, qr_w};
pub use fit::{fit, fit_a, fit_w};
pub use table::{FitTable, PackedConfig};

use crate::quant::BitConfig;

/// Everything a sensitivity metric needs, gathered once per trained model
/// by the coordinator (traces via the EF executables, ranges via the
/// range executables, gammas straight from the owned parameter buffer).
#[derive(Debug, Clone)]
pub struct SensitivityInputs {
    /// Per-weight-block EF traces Tr(I_hat(theta_l)).
    pub w_traces: Vec<f64>,
    /// Per-activation-block EF traces Tr(I_hat(a_l)).
    pub a_traces: Vec<f64>,
    /// Min-max weight ranges per block.
    pub w_lo: Vec<f64>,
    pub w_hi: Vec<f64>,
    /// Calibrated activation ranges per block.
    pub a_lo: Vec<f64>,
    pub a_hi: Vec<f64>,
    /// Mean |gamma| per weight block, None where the layer has no BN.
    pub bn_gamma: Vec<Option<f64>>,
}

impl SensitivityInputs {
    /// Number of quantizable weight blocks.
    pub fn n_weight_blocks(&self) -> usize {
        self.w_traces.len()
    }

    /// Number of activation blocks.
    pub fn n_act_blocks(&self) -> usize {
        self.a_traces.len()
    }

    /// Panic unless `cfg`'s block structure matches these inputs.
    pub fn validate(&self, cfg: &BitConfig) {
        assert_eq!(self.w_traces.len(), cfg.bits_w.len(), "weight block count");
        assert_eq!(self.a_traces.len(), cfg.bits_a.len(), "act block count");
        assert_eq!(self.w_lo.len(), self.w_traces.len());
        assert_eq!(self.w_hi.len(), self.w_traces.len());
        assert_eq!(self.a_lo.len(), self.a_traces.len());
        assert_eq!(self.a_hi.len(), self.a_traces.len());
        assert_eq!(self.bn_gamma.len(), self.w_traces.len());
    }

    /// Whether any weight block carries a batch-norm scale.
    pub fn has_bn(&self) -> bool {
        self.bn_gamma.iter().any(|g| g.is_some())
    }
}

/// The metric zoo of Table 2, as a closed enum so experiments can sweep it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    Fit,
    FitW,
    FitA,
    Qr,
    QrW,
    QrA,
    Noise,
    Bn,
}

impl Metric {
    /// Every metric of the Table-2 zoo, in the paper's column order.
    pub const ALL: [Metric; 8] = [
        Metric::Fit,
        Metric::Qr,
        Metric::Noise,
        Metric::FitW,
        Metric::QrW,
        Metric::FitA,
        Metric::QrA,
        Metric::Bn,
    ];

    /// Column name used in reports and CSVs.
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Fit => "FIT",
            Metric::FitW => "FIT_W",
            Metric::FitA => "FIT_A",
            Metric::Qr => "QR",
            Metric::QrW => "QR_W",
            Metric::QrA => "QR_A",
            Metric::Noise => "Noise",
            Metric::Bn => "BN",
        }
    }

    /// Evaluate the metric for one MPQ configuration. Returns None where
    /// the metric does not apply (BN metric on a BN-free architecture).
    pub fn eval(&self, s: &SensitivityInputs, cfg: &BitConfig) -> Option<f64> {
        s.validate(cfg);
        match self {
            Metric::Fit => Some(fit(s, cfg)),
            Metric::FitW => Some(fit_w(s, cfg)),
            Metric::FitA => Some(fit_a(s, cfg)),
            Metric::Qr => Some(qr(s, cfg)),
            Metric::QrW => Some(qr_w(s, cfg)),
            Metric::QrA => Some(qr_a(s, cfg)),
            Metric::Noise => Some(noise_metric(s, cfg)),
            Metric::Bn => bn_metric(s, cfg),
        }
    }
}

#[cfg(test)]
pub(crate) fn test_inputs() -> SensitivityInputs {
    SensitivityInputs {
        w_traces: vec![10.0, 2.0, 0.5],
        a_traces: vec![4.0, 1.0],
        w_lo: vec![-1.0, -0.5, -0.25],
        w_hi: vec![1.0, 0.5, 0.25],
        a_lo: vec![0.0, 0.0],
        a_hi: vec![6.0, 3.0],
        bn_gamma: vec![Some(1.0), Some(0.5), None],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_metrics_evaluate() {
        let s = test_inputs();
        let cfg = BitConfig::uniform(3, 2, 8);
        for m in Metric::ALL {
            let v = m.eval(&s, &cfg);
            assert!(v.is_some(), "{m:?}");
            assert!(v.unwrap().is_finite());
        }
    }

    #[test]
    fn bn_metric_is_none_without_gammas() {
        let mut s = test_inputs();
        s.bn_gamma = vec![None, None, None];
        let cfg = BitConfig::uniform(3, 2, 8);
        assert!(Metric::Bn.eval(&s, &cfg).is_none());
    }

    #[test]
    #[should_panic(expected = "weight block count")]
    fn mismatched_config_panics() {
        let s = test_inputs();
        let cfg = BitConfig::uniform(2, 2, 8);
        Metric::Fit.eval(&s, &cfg);
    }
}
