//! The FIT metric and its weight/activation components.

use super::SensitivityInputs;
use crate::quant::{noise_power, BitConfig};

/// Weight term: sum_l Tr(I_hat(theta_l)) * noise_power(range_l, b_l).
pub fn fit_w(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    s.w_traces
        .iter()
        .enumerate()
        .map(|(l, tr)| tr * noise_power(s.w_lo[l], s.w_hi[l], cfg.bits_w[l] as f64))
        .sum()
}

/// Activation term: sum_l Tr(I_hat(a_l)) * noise_power(range_l, b_l).
pub fn fit_a(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    s.a_traces
        .iter()
        .enumerate()
        .map(|(l, tr)| tr * noise_power(s.a_lo[l], s.a_hi[l], cfg.bits_a[l] as f64))
        .sum()
}

/// FIT = FIT_W + FIT_A (paper §3.2.1: weights and activations live in the
/// same extended neural manifold, so their contributions add directly —
/// this is the paper's headline "single metric" property).
pub fn fit(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    fit_w(s, cfg) + fit_a(s, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::test_inputs;

    #[test]
    fn fit_is_sum_of_components() {
        let s = test_inputs();
        let cfg = BitConfig { bits_w: vec![8, 4, 3], bits_a: vec![6, 3] };
        assert!((fit(&s, &cfg) - (fit_w(&s, &cfg) + fit_a(&s, &cfg))).abs() < 1e-15);
    }

    #[test]
    fn monotone_in_bits() {
        // lowering any single block's bits must not decrease FIT
        let s = test_inputs();
        let base = BitConfig::uniform(3, 2, 8);
        let fit0 = fit(&s, &base);
        for l in 0..3 {
            let mut c = base.clone();
            c.bits_w[l] = 3;
            assert!(fit(&s, &c) > fit0, "block {l}");
        }
        for l in 0..2 {
            let mut c = base.clone();
            c.bits_a[l] = 3;
            assert!(fit(&s, &c) > fit0, "act {l}");
        }
    }

    #[test]
    fn sensitive_blocks_dominate() {
        // dropping bits on the high-trace block must hurt more than on the
        // low-trace block (equal ranges)
        let s = SensitivityInputs {
            w_traces: vec![10.0, 0.1],
            a_traces: vec![],
            w_lo: vec![-1.0, -1.0],
            w_hi: vec![1.0, 1.0],
            a_lo: vec![],
            a_hi: vec![],
            bn_gamma: vec![None, None],
        };
        let hi_first = BitConfig { bits_w: vec![3, 8], bits_a: vec![] };
        let lo_first = BitConfig { bits_w: vec![8, 3], bits_a: vec![] };
        assert!(fit(&s, &hi_first) > fit(&s, &lo_first));
    }

    #[test]
    fn hand_computed_value() {
        let s = SensitivityInputs {
            w_traces: vec![3.0],
            a_traces: vec![],
            w_lo: vec![0.0],
            w_hi: vec![7.0],
            a_lo: vec![],
            a_hi: vec![],
            bn_gamma: vec![None],
        };
        let cfg = BitConfig { bits_w: vec![3], bits_a: vec![] };
        // delta = 7 / (2^3 - 1) = 1; noise = 1/12; fit = 3/12
        assert!((fit(&s, &cfg) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_range_block_contributes_nothing() {
        let s = SensitivityInputs {
            w_traces: vec![5.0],
            a_traces: vec![],
            w_lo: vec![1.0],
            w_hi: vec![1.0],
            a_lo: vec![],
            a_hi: vec![],
            bn_gamma: vec![None],
        };
        let cfg = BitConfig { bits_w: vec![3], bits_a: vec![] };
        assert_eq!(fit(&s, &cfg), 0.0);
    }
}
