//! Comparison heuristics (paper Appendix D.1).
//!
//! Each replaces the EF trace in the FIT sum with a cheaper sensitivity
//! proxy while keeping the same quantization noise model:
//!
//!   QR:    sens_l = 1 / |theta_max - theta_min|
//!   BN:    sens_l = 1 / gamma_l          (batch-norm scale, where present)
//!   Noise: sens_l = 1                    (isolated noise model, ablation)

use super::SensitivityInputs;
use crate::quant::{noise_power, BitConfig};

fn qr_sens(lo: f64, hi: f64) -> f64 {
    let r = (hi - lo).abs();
    if r > 0.0 {
        1.0 / r
    } else {
        0.0
    }
}

/// QR weight term.
pub fn qr_w(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    s.w_lo
        .iter()
        .zip(&s.w_hi)
        .zip(&cfg.bits_w)
        .map(|((&lo, &hi), &b)| qr_sens(lo, hi) * noise_power(lo, hi, b as f64))
        .sum()
}

/// QR activation term.
pub fn qr_a(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    s.a_lo
        .iter()
        .zip(&s.a_hi)
        .zip(&cfg.bits_a)
        .map(|((&lo, &hi), &b)| qr_sens(lo, hi) * noise_power(lo, hi, b as f64))
        .sum()
}

/// QR combined (the paper shows this combination is *not* well-scaled,
/// unlike FIT's — reproduced by the Table-2 experiment).
pub fn qr(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    qr_w(s, cfg) + qr_a(s, cfg)
}

/// Isolated quantization-noise model: sum of all block noise powers.
pub fn noise_metric(s: &SensitivityInputs, cfg: &BitConfig) -> f64 {
    let w: f64 = s
        .w_lo
        .iter()
        .zip(&s.w_hi)
        .zip(&cfg.bits_w)
        .map(|((&lo, &hi), &b)| noise_power(lo, hi, b as f64))
        .sum();
    let a: f64 = s
        .a_lo
        .iter()
        .zip(&s.a_hi)
        .zip(&cfg.bits_a)
        .map(|((&lo, &hi), &b)| noise_power(lo, hi, b as f64))
        .sum();
    w + a
}

/// BN-gamma heuristic (weight blocks that carry a BN layer only); None for
/// BN-free architectures, matching the dashes in the paper's Table 2.
pub fn bn_metric(s: &SensitivityInputs, cfg: &BitConfig) -> Option<f64> {
    if !s.has_bn() {
        return None;
    }
    Some(
        s.bn_gamma
            .iter()
            .enumerate()
            .filter_map(|(l, g)| {
                g.map(|gamma| {
                    let sens = if gamma.abs() > 1e-12 { 1.0 / gamma.abs() } else { 0.0 };
                    sens * noise_power(s.w_lo[l], s.w_hi[l], cfg.bits_w[l] as f64)
                })
            })
            .sum(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::test_inputs;

    #[test]
    fn qr_is_sum_of_components() {
        let s = test_inputs();
        let cfg = BitConfig { bits_w: vec![8, 4, 3], bits_a: vec![6, 3] };
        assert!((qr(&s, &cfg) - (qr_w(&s, &cfg) + qr_a(&s, &cfg))).abs() < 1e-15);
    }

    #[test]
    fn qr_hand_computed() {
        let s = SensitivityInputs {
            w_traces: vec![1.0],
            a_traces: vec![],
            w_lo: vec![0.0],
            w_hi: vec![2.0],
            a_lo: vec![],
            a_hi: vec![],
            bn_gamma: vec![None],
        };
        let cfg = BitConfig { bits_w: vec![3], bits_a: vec![] };
        // sens = 1/2, delta = 2/7, noise = (2/7)^2/12
        let expected = 0.5 * (2.0f64 / 7.0).powi(2) / 12.0;
        assert!((qr_w(&s, &cfg) - expected).abs() < 1e-15);
    }

    #[test]
    fn noise_metric_monotone_in_bits() {
        let s = test_inputs();
        let hi = BitConfig::uniform(3, 2, 8);
        let lo = BitConfig::uniform(3, 2, 3);
        assert!(noise_metric(&s, &lo) > noise_metric(&s, &hi));
    }

    #[test]
    fn bn_smaller_gamma_is_more_sensitive() {
        let mut s = test_inputs();
        let cfg = BitConfig::uniform(3, 2, 4);
        let base = bn_metric(&s, &cfg).unwrap();
        s.bn_gamma[1] = Some(0.1); // was 0.5: smaller gamma -> larger metric
        assert!(bn_metric(&s, &cfg).unwrap() > base);
    }

    #[test]
    fn bn_ignores_non_bn_blocks() {
        let s = test_inputs(); // block 2 has no BN
        let cfg_a = BitConfig { bits_w: vec![8, 8, 8], bits_a: vec![8, 8] };
        let mut cfg_b = cfg_a.clone();
        cfg_b.bits_w[2] = 3; // changing the BN-free block must not move BN metric
        assert_eq!(bn_metric(&s, &cfg_a), bn_metric(&s, &cfg_b));
    }
}
