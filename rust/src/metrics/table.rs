//! Table-driven FIT scoring engine.
//!
//! FIT and the model-size function are both *separable*: each is a sum of
//! per-block terms, and each block's term depends only on that block's own
//! precision choice. A [`FitTable`] therefore precomputes, once per
//! [`SensitivityInputs`], every per-block × per-precision contribution
//! (`w_traces[l] * noise_power(w_lo[l], w_hi[l], b)`, the activation
//! analogue, and the per-block storage bits), after which scoring any
//! configuration is a flat gather-sum over `Lw + La` table entries — no
//! `powf`, no range arithmetic, no branching on the hot path.
//!
//! **Bit-identity contract.** `FitTable::score` reproduces the naive
//! [`fit`](super::fit()) to 0 ULP: each table entry is computed by exactly
//! the expression the naive path evaluates per call, and the gather sums
//! entries in the same order (weight blocks in index order, then activation
//! blocks in index order, then one final add). The unit tests below and
//! `tests/fit_table_equivalence.rs` enforce this.
//!
//! [`PackedConfig`] is the cache-dense batch form of a
//! [`BitConfig`](crate::quant::BitConfig): one `u8` precision *index* per
//! block (weights first, then activations) instead of two `Vec<u32>` of
//! precision *values*, so `score_batch` streams configurations without
//! pointer-chasing two heap allocations per config for the lookup keys.

use super::SensitivityInputs;
use crate::coordinator::parallel::{effective_jobs, run_static};
use crate::quant::{noise_power, BitConfig, PRECISIONS};

/// A mixed-precision configuration in precision-index form: `idx[i]` is an
/// index into the owning table's precision set, with the `lw` weight blocks
/// first and the activation blocks after. Convert with
/// [`FitTable::pack`]/[`FitTable::unpack`] (table's own precision set) or
/// the `From` impls (the paper's [`PRECISIONS`] set).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PackedConfig {
    lw: usize,
    idx: Vec<u8>,
}

impl PackedConfig {
    /// Pack `cfg` against an explicit precision set. Panics if a block uses
    /// a precision outside the set (a packed index must round-trip).
    pub fn pack(cfg: &BitConfig, precisions: &[u32]) -> PackedConfig {
        assert!(
            precisions.len() <= u8::MAX as usize + 1,
            "precision set too large for u8 indices"
        );
        let index_of = |bits: u32| -> u8 {
            precisions
                .iter()
                .position(|&p| p == bits)
                .unwrap_or_else(|| panic!("precision {bits} not in candidate set {precisions:?}"))
                as u8
        };
        PackedConfig {
            lw: cfg.bits_w.len(),
            idx: cfg.bits_w.iter().chain(&cfg.bits_a).map(|&b| index_of(b)).collect(),
        }
    }

    /// Expand back to a [`BitConfig`] against an explicit precision set.
    pub fn unpack(&self, precisions: &[u32]) -> BitConfig {
        BitConfig {
            bits_w: self.idx[..self.lw].iter().map(|&i| precisions[i as usize]).collect(),
            bits_a: self.idx[self.lw..].iter().map(|&i| precisions[i as usize]).collect(),
        }
    }

    pub fn n_weight_blocks(&self) -> usize {
        self.lw
    }

    pub fn n_act_blocks(&self) -> usize {
        self.idx.len() - self.lw
    }

    /// Raw precision indices, weight blocks first then activation blocks.
    pub fn indices(&self) -> &[u8] {
        &self.idx
    }
}

impl From<&BitConfig> for PackedConfig {
    fn from(cfg: &BitConfig) -> PackedConfig {
        PackedConfig::pack(cfg, &PRECISIONS)
    }
}

impl From<&PackedConfig> for BitConfig {
    fn from(p: &PackedConfig) -> BitConfig {
        p.unpack(&PRECISIONS)
    }
}

/// Precomputed per-block × per-precision FIT contributions and storage
/// sizes for one set of sensitivity inputs (see the module docs).
///
/// Built once per study / search; every consumer (Pareto sweep, greedy and
/// exact allocators, the Table-2 evaluator) scores configurations through
/// it instead of recomputing `noise_power` per call.
#[derive(Debug, Clone)]
pub struct FitTable {
    precisions: Vec<u32>,
    lw: usize,
    la: usize,
    /// `lw × P` row-major: `w_traces[l] * noise_power(w_lo[l], w_hi[l], precisions[p])`.
    w_fit: Vec<f64>,
    /// `la × P` row-major activation analogue.
    a_fit: Vec<f64>,
    /// `lw × P` row-major: `block_sizes[l] * precisions[p]` storage bits.
    w_bits: Vec<u64>,
    /// Non-quantized parameters at fp32 (`n_unquantized * 32`).
    base_bits: u64,
}

impl FitTable {
    pub fn new(
        s: &SensitivityInputs,
        block_sizes: &[usize],
        n_unquantized: usize,
        precisions: &[u32],
    ) -> FitTable {
        assert!(!precisions.is_empty(), "empty precision set");
        assert!(
            precisions.len() <= u8::MAX as usize + 1,
            "precision set too large for u8 indices"
        );
        assert_eq!(block_sizes.len(), s.n_weight_blocks(), "weight block count");
        let np = precisions.len();
        let lw = s.n_weight_blocks();
        let la = s.n_act_blocks();
        let mut w_fit = Vec::with_capacity(lw * np);
        let mut w_bits = Vec::with_capacity(lw * np);
        for l in 0..lw {
            for &b in precisions {
                w_fit.push(s.w_traces[l] * noise_power(s.w_lo[l], s.w_hi[l], b as f64));
                w_bits.push(block_sizes[l] as u64 * b as u64);
            }
        }
        let mut a_fit = Vec::with_capacity(la * np);
        for l in 0..la {
            for &b in precisions {
                a_fit.push(s.a_traces[l] * noise_power(s.a_lo[l], s.a_hi[l], b as f64));
            }
        }
        FitTable {
            precisions: precisions.to_vec(),
            lw,
            la,
            w_fit,
            a_fit,
            w_bits,
            base_bits: n_unquantized as u64 * 32,
        }
    }

    pub fn precisions(&self) -> &[u32] {
        &self.precisions
    }

    pub fn n_weight_blocks(&self) -> usize {
        self.lw
    }

    pub fn n_act_blocks(&self) -> usize {
        self.la
    }

    /// Storage bits of the non-quantized tensors (counted at fp32).
    pub fn base_bits(&self) -> u64 {
        self.base_bits
    }

    /// FIT contribution of weight block `l` at precision index `p`.
    pub fn w_term(&self, l: usize, p: usize) -> f64 {
        self.w_fit[l * self.precisions.len() + p]
    }

    /// FIT contribution of activation block `l` at precision index `p`.
    pub fn a_term(&self, l: usize, p: usize) -> f64 {
        self.a_fit[l * self.precisions.len() + p]
    }

    /// Storage bits of weight block `l` at precision index `p`.
    pub fn w_size_bits(&self, l: usize, p: usize) -> u64 {
        self.w_bits[l * self.precisions.len() + p]
    }

    /// Pack against this table's precision set (asserts the block shape).
    pub fn pack(&self, cfg: &BitConfig) -> PackedConfig {
        assert_eq!(cfg.bits_w.len(), self.lw, "weight block count");
        assert_eq!(cfg.bits_a.len(), self.la, "act block count");
        PackedConfig::pack(cfg, &self.precisions)
    }

    /// Expand a packed configuration against this table's precision set.
    pub fn unpack(&self, p: &PackedConfig) -> BitConfig {
        p.unpack(&self.precisions)
    }

    /// Weight term `FIT_W` — bit-identical to [`fit_w`](super::fit_w).
    pub fn score_w(&self, p: &PackedConfig) -> f64 {
        assert_eq!(p.lw, self.lw, "weight block count");
        let np = self.precisions.len();
        let mut acc = 0.0;
        for (l, &ix) in p.idx[..self.lw].iter().enumerate() {
            acc += self.w_fit[l * np + ix as usize];
        }
        acc
    }

    /// Activation term `FIT_A` — bit-identical to [`fit_a`](super::fit_a).
    pub fn score_a(&self, p: &PackedConfig) -> f64 {
        assert_eq!(p.idx.len() - p.lw, self.la, "act block count");
        let np = self.precisions.len();
        let mut acc = 0.0;
        for (l, &ix) in p.idx[self.lw..].iter().enumerate() {
            acc += self.a_fit[l * np + ix as usize];
        }
        acc
    }

    /// Full FIT as a flat gather-sum — bit-identical to [`fit`](super::fit()).
    pub fn score(&self, p: &PackedConfig) -> f64 {
        self.score_w(p) + self.score_a(p)
    }

    /// Model storage bits — identical to
    /// [`model_bits`](crate::quant::model_bits) (exact integer arithmetic).
    pub fn size_bits(&self, p: &PackedConfig) -> u64 {
        assert_eq!(p.lw, self.lw, "weight block count");
        let np = self.precisions.len();
        let mut bits = self.base_bits;
        for (l, &ix) in p.idx[..self.lw].iter().enumerate() {
            bits += self.w_bits[l * np + ix as usize];
        }
        bits
    }

    /// `(fit, size_bits)` in one call — the batch scorer's element type.
    pub fn score_size(&self, p: &PackedConfig) -> (f64, u64) {
        (self.score(p), self.size_bits(p))
    }

    /// `(fit, size_bits)` straight from raw precision indices (weight
    /// blocks first, then activation blocks — the
    /// [`PackedConfig::indices`] layout), without materializing a
    /// `PackedConfig`. Summation order matches [`score_size`]
    /// (weight terms, activation terms, one final add), so the result is
    /// bit-identical — the search service's sampled shards score through
    /// this from one reused index buffer, allocating nothing per config.
    ///
    /// [`score_size`]: Self::score_size
    pub fn score_size_indices(&self, idx: &[u8]) -> (f64, u64) {
        assert_eq!(idx.len(), self.lw + self.la, "block count");
        let np = self.precisions.len();
        let mut acc_w = 0.0;
        let mut bits = self.base_bits;
        for (l, &ix) in idx[..self.lw].iter().enumerate() {
            acc_w += self.w_fit[l * np + ix as usize];
            bits += self.w_bits[l * np + ix as usize];
        }
        let mut acc_a = 0.0;
        for (l, &ix) in idx[self.lw..].iter().enumerate() {
            acc_a += self.a_fit[l * np + ix as usize];
        }
        (acc_w + acc_a, bits)
    }

    /// Batch chunk width: small enough that the static fan-out
    /// load-balances, large enough that per-chunk dispatch is noise.
    pub const SCORE_CHUNK: usize = 4096;

    /// Buffer-reusing batch scorer: clear `out` and fill it with
    /// `(fit, size_bits)` in input order. The parallel path hands workers
    /// disjoint `&mut` panels of the single output buffer
    /// ([`run_static`]'s contiguous schedule) instead of collecting
    /// per-chunk `Vec`s, so a caller looping over requests — the search
    /// service, `cmd_search` — reuses one allocation across its lifetime.
    /// Bit-identical at every `jobs` setting (per-config scoring is pure;
    /// the schedule only decides who computes a panel).
    pub fn score_batch_into(
        &self,
        configs: &[PackedConfig],
        jobs: usize,
        out: &mut Vec<(f64, u64)>,
    ) {
        out.clear();
        let n_chunks = configs.len().div_ceil(Self::SCORE_CHUNK);
        let threads = effective_jobs(jobs, n_chunks);
        if threads <= 1 {
            out.extend(configs.iter().map(|c| self.score_size(c)));
            return;
        }
        out.resize(configs.len(), (0.0, 0));
        let panels: Vec<(&[PackedConfig], &mut [(f64, u64)])> = configs
            .chunks(Self::SCORE_CHUNK)
            .zip(out.chunks_mut(Self::SCORE_CHUNK))
            .collect();
        run_static(panels, threads, |_, (cfgs, dst)| {
            for (c, d) in cfgs.iter().zip(dst.iter_mut()) {
                *d = self.score_size(c);
            }
        });
    }

    /// Score a batch of packed configurations into a fresh `Vec` —
    /// [`score_batch_into`](Self::score_batch_into) behind an allocating
    /// convenience signature. Returns `(fit, size_bits)` pairs in input
    /// order, identical at every `jobs` setting (`1` = serial reference,
    /// `0` = one worker per core).
    pub fn score_batch(&self, configs: &[PackedConfig], jobs: usize) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        self.score_batch_into(configs, jobs, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{fit, fit_a, fit_w, test_inputs};
    use crate::quant::{model_bits, BitConfigSampler};
    use crate::tensor::Pcg32;

    #[test]
    fn packed_round_trip_via_from() {
        let cfg = BitConfig { bits_w: vec![8, 4, 3], bits_a: vec![6, 3] };
        let packed = PackedConfig::from(&cfg);
        assert_eq!(packed.n_weight_blocks(), 3);
        assert_eq!(packed.n_act_blocks(), 2);
        assert_eq!(BitConfig::from(&packed), cfg);
    }

    #[test]
    fn pack_respects_table_precision_order() {
        // a table built over an ascending set packs/unpacks against it
        let s = test_inputs();
        let table = FitTable::new(&s, &[100, 400, 50], 10, &[3, 4, 6, 8]);
        let cfg = BitConfig { bits_w: vec![3, 8, 6], bits_a: vec![4, 3] };
        let packed = table.pack(&cfg);
        assert_eq!(packed.indices(), &[0, 3, 2, 1, 0]);
        assert_eq!(table.unpack(&packed), cfg);
    }

    #[test]
    #[should_panic(expected = "not in candidate set")]
    fn pack_rejects_unknown_precision() {
        let cfg = BitConfig { bits_w: vec![5], bits_a: vec![] };
        let _ = PackedConfig::from(&cfg);
    }

    #[test]
    fn score_matches_naive_fit_to_zero_ulp() {
        let s = test_inputs();
        let sizes = vec![100usize, 400, 50];
        let table = FitTable::new(&s, &sizes, 10, &PRECISIONS);
        let mut sampler = BitConfigSampler::new(3, 2, &PRECISIONS, 5);
        for cfg in sampler.take(64) {
            let p = table.pack(&cfg);
            assert_eq!(table.score(&p).to_bits(), fit(&s, &cfg).to_bits(), "{}", cfg.label());
            assert_eq!(table.score_w(&p).to_bits(), fit_w(&s, &cfg).to_bits());
            assert_eq!(table.score_a(&p).to_bits(), fit_a(&s, &cfg).to_bits());
            assert_eq!(table.size_bits(&p), model_bits(&sizes, 10, &cfg));
        }
    }

    #[test]
    fn randomized_inputs_match_to_zero_ulp() {
        // property check over randomized instances, including zero-range
        // blocks (hi == lo) and empty activation lists
        let mut rng = Pcg32::new(0xf17, 0x7ab1e);
        for case in 0..24u64 {
            let lw = 1 + rng.below(6) as usize;
            let la = rng.below(4) as usize; // 0 => empty activations
            let mut w_lo = Vec::with_capacity(lw);
            let mut w_hi = Vec::with_capacity(lw);
            for _ in 0..lw {
                let r = rng.uniform_in(0.0, 2.0) as f64;
                if rng.below(4) == 0 {
                    w_lo.push(r); // zero-range block
                    w_hi.push(r);
                } else {
                    w_lo.push(-r);
                    w_hi.push(r);
                }
            }
            let s = SensitivityInputs {
                w_traces: (0..lw).map(|_| rng.uniform_in(0.0, 20.0) as f64).collect(),
                a_traces: (0..la).map(|_| rng.uniform_in(0.0, 8.0) as f64).collect(),
                w_lo,
                w_hi,
                a_lo: vec![0.0; la],
                a_hi: (0..la).map(|_| rng.uniform_in(0.1, 8.0) as f64).collect(),
                bn_gamma: vec![None; lw],
            };
            let sizes: Vec<usize> = (0..lw).map(|_| 1 + rng.below(5000) as usize).collect();
            let n_unq = rng.below(20) as usize;
            let table = FitTable::new(&s, &sizes, n_unq, &PRECISIONS);
            let mut sampler = BitConfigSampler::new(lw, la, &PRECISIONS, 1000 + case);
            for cfg in sampler.take(16) {
                let p = table.pack(&cfg);
                assert_eq!(
                    table.score(&p).to_bits(),
                    fit(&s, &cfg).to_bits(),
                    "case {case}: {}",
                    cfg.label()
                );
                assert_eq!(table.size_bits(&p), model_bits(&sizes, n_unq, &cfg));
            }
        }
    }

    #[test]
    fn batch_matches_serial_and_every_jobs_setting() {
        let s = test_inputs();
        let sizes = vec![100usize, 400, 50];
        let table = FitTable::new(&s, &sizes, 10, &PRECISIONS);
        let mut sampler = BitConfigSampler::new(3, 2, &PRECISIONS, 9);
        // > 2 chunks so the pool path actually engages
        let packed: Vec<PackedConfig> =
            sampler.take(1000).iter().map(|c| table.pack(c)).collect();
        let packed: Vec<PackedConfig> =
            (0..10).flat_map(|_| packed.iter().cloned()).collect();
        let serial: Vec<(f64, u64)> = packed.iter().map(|p| table.score_size(p)).collect();
        for jobs in [1usize, 2, 4, 0] {
            let got = table.score_batch(&packed, jobs);
            assert_eq!(got.len(), serial.len());
            for (a, b) in got.iter().zip(&serial) {
                assert_eq!(a.0.to_bits(), b.0.to_bits());
                assert_eq!(a.1, b.1);
            }
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let s = test_inputs();
        let table = FitTable::new(&s, &[100, 400, 50], 10, &PRECISIONS);
        assert!(table.score_batch(&[], 4).is_empty());
        let mut out = vec![(1.0, 1u64); 3];
        table.score_batch_into(&[], 4, &mut out);
        assert!(out.is_empty(), "score_batch_into must clear stale contents");
    }

    #[test]
    fn score_batch_into_reuses_buffer_bit_identically() {
        let s = test_inputs();
        let sizes = vec![100usize, 400, 50];
        let table = FitTable::new(&s, &sizes, 10, &PRECISIONS);
        let mut sampler = BitConfigSampler::new(3, 2, &PRECISIONS, 21);
        let small: Vec<PackedConfig> = sampler.take(500).iter().map(|c| table.pack(c)).collect();
        let big: Vec<PackedConfig> =
            (0..20).flat_map(|_| small.iter().cloned()).collect();
        let serial: Vec<(f64, u64)> = big.iter().map(|p| table.score_size(p)).collect();
        let mut out = Vec::new();
        for jobs in [1usize, 2, 4, 0] {
            // reuse the same buffer across calls and batch sizes, like a
            // service looping over requests
            table.score_batch_into(&big, jobs, &mut out);
            assert_eq!(out.len(), serial.len(), "jobs={jobs}");
            for (a, b) in out.iter().zip(&serial) {
                assert_eq!(a.0.to_bits(), b.0.to_bits());
                assert_eq!(a.1, b.1);
            }
            table.score_batch_into(&big[..7], jobs, &mut out);
            assert_eq!(out.len(), 7, "shrinking batch must truncate the buffer");
        }
    }

    #[test]
    fn score_size_indices_matches_packed_path_to_zero_ulp() {
        let s = test_inputs();
        let sizes = vec![100usize, 400, 50];
        let table = FitTable::new(&s, &sizes, 10, &PRECISIONS);
        let mut sampler = BitConfigSampler::new(3, 2, &PRECISIONS, 33);
        for cfg in sampler.take(64) {
            let p = table.pack(&cfg);
            let (f_ref, b_ref) = table.score_size(&p);
            let (f, b) = table.score_size_indices(p.indices());
            assert_eq!(f.to_bits(), f_ref.to_bits(), "{}", cfg.label());
            assert_eq!(b, b_ref);
        }
    }

    #[test]
    #[should_panic(expected = "block count")]
    fn score_size_indices_rejects_wrong_block_count() {
        let s = test_inputs();
        let table = FitTable::new(&s, &[100, 400, 50], 10, &PRECISIONS);
        let _ = table.score_size_indices(&[0, 0]);
    }
}
