//! Synthetic dataset substrate.
//!
//! The paper trains on MNIST / CIFAR-10 / Cityscapes; this framework ships
//! deterministic synthetic equivalents (DESIGN.md substitutions) with the
//! statistical properties the experiments need: class-structured signal
//! that a small CNN can learn, plus pixel noise so quantization degrades
//! accuracy heterogeneously across layers and bit widths.
//!
//! Everything is seeded through `tensor::Pcg32` — a dataset is a pure
//! function of (seed, split, index), so every experiment replays exactly.

mod batcher;
mod synth_class;
mod synth_seg;
mod train_view;

pub use batcher::{EpochBatch, EvalBatch, EvalSet};
pub use synth_class::SynthClass;
pub use synth_seg::SynthSeg;
pub use train_view::TrainView;

/// A supervised example stream: fills caller-provided image/label buffers.
///
/// Implementations are immutable after construction (a dataset is a pure
/// function of `(seed, split, index)`), so the trait requires `Send + Sync`
/// and one dataset can feed every worker of a parallel study concurrently.
pub trait Dataset: Send + Sync {
    /// (H, W, C) per-sample image shape.
    fn input_shape(&self) -> (usize, usize, usize);
    /// Number of classes.
    fn n_classes(&self) -> usize;
    /// Label elements per sample: 1 for classification, H*W for segmentation.
    fn label_len(&self) -> usize;
    /// Generate sample `index` of `split` into the buffers.
    fn sample(&self, split: Split, index: u64, x: &mut [f32], y: &mut [i32]);

    fn sample_len(&self) -> usize {
        let (h, w, c) = self.input_shape();
        h * w * c
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    pub fn stream_id(&self) -> u64 {
        match self {
            Split::Train => 0x7261_494e,
            Split::Test => 0x7e57_0000,
        }
    }
}
