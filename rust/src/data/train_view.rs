//! Train-split-as-eval adapter.
//!
//! `EvalSet::materialize` always reads the *test* stream; wrapping a
//! dataset in [`TrainView`] redirects every sample request to the train
//! stream instead, so the evaluation machinery (padded batches, masks,
//! calibration prefixes) can be pointed at training data unchanged. The
//! study pipeline uses this for the Fig-5b overfitting analysis: the
//! train-split eval set samples the same indices the trainer consumed
//! first.

use super::{Dataset, Split};

/// A view of a dataset whose every split is the underlying train split.
pub struct TrainView<'a>(&'a dyn Dataset);

impl<'a> TrainView<'a> {
    pub fn new(ds: &'a dyn Dataset) -> TrainView<'a> {
        TrainView(ds)
    }
}

impl Dataset for TrainView<'_> {
    fn input_shape(&self) -> (usize, usize, usize) {
        self.0.input_shape()
    }

    fn n_classes(&self) -> usize {
        self.0.n_classes()
    }

    fn label_len(&self) -> usize {
        self.0.label_len()
    }

    fn sample(&self, _split: Split, index: u64, x: &mut [f32], y: &mut [i32]) {
        self.0.sample(Split::Train, index, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{EvalSet, SynthClass};

    #[test]
    fn delegates_shape_metadata() {
        let ds = SynthClass::synmnist(9);
        let view = TrainView::new(&ds);
        assert_eq!(view.input_shape(), ds.input_shape());
        assert_eq!(view.n_classes(), ds.n_classes());
        assert_eq!(view.label_len(), ds.label_len());
        assert_eq!(view.sample_len(), ds.sample_len());
    }

    #[test]
    fn every_split_reads_the_train_stream() {
        let ds = SynthClass::synmnist(10);
        let view = TrainView::new(&ds);
        let sl = ds.sample_len();
        let mut want = vec![0.0f32; sl];
        let mut want_y = vec![0i32; 1];
        let mut got = vec![0.0f32; sl];
        let mut got_y = vec![0i32; 1];
        for idx in [0u64, 7, 1000] {
            ds.sample(Split::Train, idx, &mut want, &mut want_y);
            view.sample(Split::Test, idx, &mut got, &mut got_y);
            assert_eq!(got, want, "index {idx}: test view must equal train");
            assert_eq!(got_y, want_y);
            view.sample(Split::Train, idx, &mut got, &mut got_y);
            assert_eq!(got, want, "index {idx}: train view must equal train");
        }
    }

    #[test]
    fn materialized_view_differs_from_test_split() {
        let ds = SynthClass::synmnist(11);
        let train_ev = EvalSet::materialize(&TrainView::new(&ds), 16);
        let test_ev = EvalSet::materialize(&ds, 16);
        assert_eq!(train_ev.len(), 16);
        let a: Vec<_> = train_ev.batches(16).collect();
        let b: Vec<_> = test_ev.batches(16).collect();
        assert_ne!(a[0].x, b[0].x, "train and test streams must differ");
    }
}
