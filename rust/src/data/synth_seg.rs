//! Synthetic shapes-segmentation dataset (the Cityscapes stand-in, Fig. 4).
//!
//! Each image contains 1-3 axis-aligned rectangles and discs on a noisy
//! background; each object class carries its own texture frequency so the
//! network must use local appearance (not just position) to label pixels.
//! Class 0 is background; classes 1..n are object types.

use super::{Dataset, Split};
use crate::tensor::Pcg32;

#[derive(Debug, Clone)]
pub struct SynthSeg {
    h: usize,
    w: usize,
    c: usize,
    n_classes: usize,
    noise: f32,
    seed: u64,
}

impl SynthSeg {
    pub fn new(shape: (usize, usize, usize), n_classes: usize, noise: f32, seed: u64) -> Self {
        assert!(n_classes >= 2);
        SynthSeg { h: shape.0, w: shape.1, c: shape.2, n_classes, noise, seed }
    }

    /// The Fig-4 study dataset matching the unet artifact (32x32x3, 4 cls).
    pub fn synthshapes(seed: u64) -> Self {
        SynthSeg::new((32, 32, 3), 4, 0.25, seed)
    }

    fn texture(&self, class: usize, i: usize, j: usize, ch: usize) -> f32 {
        // per-class frequency signature; brighter for higher classes so the
        // head has both colour and texture cues.
        let f = 1.5 + class as f32;
        let u = i as f32 / self.h as f32;
        let v = j as f32 / self.w as f32;
        let tau = std::f32::consts::TAU;
        0.7 * (tau * f * u + 0.9 * ch as f32).sin() * (tau * f * v).cos()
            + 0.3 * (class as f32 / self.n_classes as f32)
    }
}

impl Dataset for SynthSeg {
    fn input_shape(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn label_len(&self) -> usize {
        self.h * self.w
    }

    fn sample(&self, split: Split, index: u64, x: &mut [f32], y: &mut [i32]) {
        assert_eq!(x.len(), self.sample_len());
        assert_eq!(y.len(), self.label_len());
        let mut r = Pcg32::new(self.seed ^ index.wrapping_mul(0xd134_2543_de82_ef95), split.stream_id());

        // background
        y.fill(0);
        let mut k = 0;
        for i in 0..self.h {
            for j in 0..self.w {
                for ch in 0..self.c {
                    x[k] = self.texture(0, i, j, ch);
                    k += 1;
                }
            }
        }

        // objects (later objects overdraw earlier ones)
        let n_obj = 1 + r.below(3) as usize;
        for _ in 0..n_obj {
            let class = 1 + r.below((self.n_classes - 1) as u32) as usize;
            let ci = r.below(self.h as u32) as i64;
            let cj = r.below(self.w as u32) as i64;
            let radius = (2 + r.below((self.h as u32 / 4).max(1))) as i64;
            let is_disc = r.next_u32() & 1 == 0;
            for i in 0..self.h as i64 {
                for j in 0..self.w as i64 {
                    let inside = if is_disc {
                        (i - ci) * (i - ci) + (j - cj) * (j - cj) <= radius * radius
                    } else {
                        (i - ci).abs() <= radius && (j - cj).abs() <= radius
                    };
                    if inside {
                        y[(i as usize) * self.w + j as usize] = class as i32;
                        let base = ((i as usize) * self.w + j as usize) * self.c;
                        for ch in 0..self.c {
                            x[base + ch] = self.texture(class, i as usize, j as usize, ch);
                        }
                    }
                }
            }
        }

        // pixel noise on top of everything
        for v in x.iter_mut() {
            *v += self.noise * r.normal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(d: &SynthSeg, idx: u64) -> (Vec<f32>, Vec<i32>) {
        let mut x = vec![0.0; d.sample_len()];
        let mut y = vec![0i32; d.label_len()];
        d.sample(Split::Train, idx, &mut x, &mut y);
        (x, y)
    }

    #[test]
    fn deterministic() {
        let d = SynthSeg::synthshapes(5);
        assert_eq!(gen(&d, 3), gen(&d, 3));
        assert_ne!(gen(&d, 3).0, gen(&d, 4).0);
    }

    #[test]
    fn labels_in_range_and_foreground_present() {
        let d = SynthSeg::synthshapes(5);
        let mut any_fg = false;
        for idx in 0..20 {
            let (_, y) = gen(&d, idx);
            assert!(y.iter().all(|&c| c >= 0 && c < 4));
            any_fg |= y.iter().any(|&c| c > 0);
        }
        assert!(any_fg);
    }

    #[test]
    fn all_object_classes_appear_over_many_samples() {
        let d = SynthSeg::synthshapes(9);
        let mut seen = [false; 4];
        for idx in 0..100 {
            let (_, y) = gen(&d, idx);
            for &c in &y {
                seen[c as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn object_pixels_textured_differently_from_background() {
        let d = SynthSeg::synthshapes(2);
        // compare class textures directly (noise-free)
        let t0 = d.texture(0, 5, 5, 0);
        let t2 = d.texture(2, 5, 5, 0);
        assert_ne!(t0, t2);
    }

    #[test]
    fn background_fraction_reasonable() {
        let d = SynthSeg::synthshapes(3);
        let mut bg = 0usize;
        let mut total = 0usize;
        for idx in 0..30 {
            let (_, y) = gen(&d, idx);
            bg += y.iter().filter(|&&c| c == 0).count();
            total += y.len();
        }
        let f = bg as f64 / total as f64;
        assert!(f > 0.2 && f < 0.98, "background fraction {f}");
    }
}
