//! Batch assembly for the PJRT entry points.
//!
//! `EpochBatch` packs K microbatches of B samples into the contiguous
//! (K, B, H, W, C) / (K, B, ...) buffers the scanned train/qat executables
//! take per dispatch. `EvalSet` materializes a fixed test split once and
//! serves padded batches with 0/1 masks so partial tails evaluate exactly.

use super::{Dataset, Split};

/// One scanned-epoch input: xs (K*B*sample), ys (K*B*label).
#[derive(Debug, Clone)]
pub struct EpochBatch {
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    pub k: usize,
    pub b: usize,
}

impl EpochBatch {
    /// Fill from consecutive train-split indices starting at `cursor`;
    /// returns the advanced cursor.
    pub fn generate(ds: &dyn Dataset, k: usize, b: usize, cursor: u64) -> (EpochBatch, u64) {
        let sl = ds.sample_len();
        let ll = ds.label_len();
        let mut xs = vec![0.0f32; k * b * sl];
        let mut ys = vec![0i32; k * b * ll];
        let mut idx = cursor;
        for s in 0..k * b {
            ds.sample(
                Split::Train,
                idx,
                &mut xs[s * sl..(s + 1) * sl],
                &mut ys[s * ll..(s + 1) * ll],
            );
            idx += 1;
        }
        (EpochBatch { xs, ys, k, b }, idx)
    }
}

/// One padded eval batch with sample mask.
#[derive(Debug, Clone)]
pub struct EvalBatch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub mask: Vec<f32>,
    pub n_real: usize,
}

/// A fixed, materialized test set served in fixed-size padded batches.
#[derive(Debug)]
pub struct EvalSet {
    xs: Vec<f32>,
    ys: Vec<i32>,
    n: usize,
    sample_len: usize,
    label_len: usize,
}

impl EvalSet {
    pub fn materialize(ds: &dyn Dataset, n: usize) -> EvalSet {
        let sl = ds.sample_len();
        let ll = ds.label_len();
        let mut xs = vec![0.0f32; n * sl];
        let mut ys = vec![0i32; n * ll];
        for i in 0..n {
            ds.sample(
                Split::Test,
                i as u64,
                &mut xs[i * sl..(i + 1) * sl],
                &mut ys[i * ll..(i + 1) * ll],
            );
        }
        EvalSet { xs, ys, n, sample_len: sl, label_len: ll }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Iterate fixed-size batches; the last is zero-padded with mask 0.
    pub fn batches(&self, batch: usize) -> impl Iterator<Item = EvalBatch> + '_ {
        let n_batches = self.n.div_ceil(batch);
        (0..n_batches).map(move |bi| {
            let start = bi * batch;
            let n_real = batch.min(self.n - start);
            let mut x = vec![0.0f32; batch * self.sample_len];
            let mut y = vec![0i32; batch * self.label_len];
            let mut mask = vec![0.0f32; batch];
            x[..n_real * self.sample_len].copy_from_slice(
                &self.xs[start * self.sample_len..(start + n_real) * self.sample_len],
            );
            y[..n_real * self.label_len].copy_from_slice(
                &self.ys[start * self.label_len..(start + n_real) * self.label_len],
            );
            mask[..n_real].fill(1.0);
            EvalBatch { x, y, mask, n_real }
        })
    }

    /// First `n` raw images, e.g. as a calibration batch (x only).
    pub fn calibration(&self, n: usize) -> Vec<f32> {
        assert!(n <= self.n);
        self.xs[..n * self.sample_len].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthClass;

    #[test]
    fn epoch_batch_shapes_and_cursor() {
        let ds = SynthClass::synmnist(1);
        let (e, cur) = EpochBatch::generate(&ds, 3, 4, 100);
        assert_eq!(e.xs.len(), 3 * 4 * 256);
        assert_eq!(e.ys.len(), 12);
        assert_eq!(cur, 112);
        // consecutive call continues the stream without overlap
        let (e2, _) = EpochBatch::generate(&ds, 3, 4, cur);
        assert_ne!(e.xs, e2.xs);
    }

    #[test]
    fn eval_set_batches_cover_all_with_padding() {
        let ds = SynthClass::synmnist(2);
        let ev = EvalSet::materialize(&ds, 10);
        let batches: Vec<_> = ev.batches(4).collect();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].n_real, 4);
        assert_eq!(batches[2].n_real, 2);
        assert_eq!(batches[2].mask, vec![1.0, 1.0, 0.0, 0.0]);
        let total: usize = batches.iter().map(|b| b.n_real).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn eval_set_is_deterministic() {
        let ds = SynthClass::synmnist(3);
        let a = EvalSet::materialize(&ds, 8);
        let b = EvalSet::materialize(&ds, 8);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
    }

    #[test]
    fn calibration_prefix() {
        let ds = SynthClass::synmnist(4);
        let ev = EvalSet::materialize(&ds, 8);
        let c = ev.calibration(3);
        assert_eq!(c.len(), 3 * 256);
        assert_eq!(c[..256], ev.xs[..256]);
    }
}
