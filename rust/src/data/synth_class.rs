//! Class-conditional frequency-pattern classification dataset.
//!
//! Each class owns a deterministic 2-D interference pattern (two sinusoid
//! products with class-specific frequencies and phases); a sample is its
//! class pattern plus i.i.d. Gaussian pixel noise. The same construction
//! backs `synmnist` (16x16x1) and `syncifar` (32x32x3); python/tests uses
//! an equivalent generator for its tiny-model fixtures.

use super::{Dataset, Split};
use crate::tensor::Pcg32;

#[derive(Debug, Clone)]
pub struct SynthClass {
    h: usize,
    w: usize,
    c: usize,
    n_classes: usize,
    noise: f32,
    seed: u64,
    /// per class: (fx, fy, px, py, fx2, fy2, px2, py2)
    class_params: Vec<[f32; 8]>,
}

impl SynthClass {
    pub fn new(shape: (usize, usize, usize), n_classes: usize, noise: f32, seed: u64) -> Self {
        let class_params = (0..n_classes)
            .map(|cl| {
                let mut r = Pcg32::new(seed ^ 0xc1a5_5e5e, cl as u64 + 1);
                let tau = std::f32::consts::TAU;
                [
                    r.uniform_in(0.5, 3.0),
                    r.uniform_in(0.5, 3.0),
                    r.uniform_in(0.0, tau),
                    r.uniform_in(0.0, tau),
                    r.uniform_in(1.0, 4.0),
                    r.uniform_in(1.0, 4.0),
                    r.uniform_in(0.0, tau),
                    r.uniform_in(0.0, tau),
                ]
            })
            .collect();
        SynthClass { h: shape.0, w: shape.1, c: shape.2, n_classes, noise, seed, class_params }
    }

    /// The paper-study datasets.
    pub fn synmnist(seed: u64) -> Self {
        SynthClass::new((16, 16, 1), 10, 0.3, seed)
    }

    pub fn syncifar(seed: u64) -> Self {
        SynthClass::new((32, 32, 3), 10, 0.3, seed)
    }

    /// Noise-free class template value at (i, j, ch).
    pub fn pattern(&self, class: usize, i: usize, j: usize, ch: usize) -> f32 {
        let p = &self.class_params[class];
        let tau = std::f32::consts::TAU;
        let u = i as f32 / self.h as f32;
        let v = j as f32 / self.w as f32;
        let a = (tau * p[0] * u + p[2] + 0.7 * ch as f32).sin() * (tau * p[1] * v + p[3]).cos();
        let b = (tau * p[4] * v + p[6]).sin() * (tau * p[5] * u + p[7] + 0.4 * ch as f32).sin();
        0.6 * a + 0.4 * b
    }
}

impl Dataset for SynthClass {
    fn input_shape(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn label_len(&self) -> usize {
        1
    }

    fn sample(&self, split: Split, index: u64, x: &mut [f32], y: &mut [i32]) {
        assert_eq!(x.len(), self.sample_len());
        assert_eq!(y.len(), 1);
        let mut r = Pcg32::new(self.seed ^ index.wrapping_mul(0x9e37_79b9_7f4a_7c15), split.stream_id());
        let class = r.below(self.n_classes as u32) as usize;
        y[0] = class as i32;
        let mut k = 0;
        for i in 0..self.h {
            for j in 0..self.w {
                for ch in 0..self.c {
                    x[k] = self.pattern(class, i, j, ch) + self.noise * r.normal();
                    k += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let d = SynthClass::synmnist(42);
        let mut x1 = vec![0.0; d.sample_len()];
        let mut x2 = vec![0.0; d.sample_len()];
        let (mut y1, mut y2) = ([0i32], [0i32]);
        d.sample(Split::Train, 5, &mut x1, &mut y1);
        d.sample(Split::Train, 5, &mut x2, &mut y2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn train_and_test_streams_differ() {
        let d = SynthClass::synmnist(42);
        let mut xa = vec![0.0; d.sample_len()];
        let mut xb = vec![0.0; d.sample_len()];
        let (mut ya, mut yb) = ([0i32], [0i32]);
        d.sample(Split::Train, 5, &mut xa, &mut ya);
        d.sample(Split::Test, 5, &mut xb, &mut yb);
        assert_ne!(xa, xb);
    }

    #[test]
    fn labels_cover_all_classes() {
        let d = SynthClass::synmnist(1);
        let mut seen = vec![false; 10];
        let mut x = vec![0.0; d.sample_len()];
        let mut y = [0i32];
        for i in 0..500 {
            d.sample(Split::Train, i, &mut x, &mut y);
            seen[y[0] as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn images_are_bounded_and_nontrivial() {
        let d = SynthClass::syncifar(3);
        let mut x = vec![0.0; d.sample_len()];
        let mut y = [0i32];
        d.sample(Split::Train, 0, &mut x, &mut y);
        let (lo, hi) = crate::tensor::min_max(&x).unwrap();
        assert!(lo > -5.0 && hi < 5.0);
        assert!(hi - lo > 0.5, "image should have contrast");
    }

    #[test]
    fn class_patterns_are_separated() {
        // mean intra-class distance << inter-class distance on clean patterns
        let d = SynthClass::synmnist(7);
        let tpl = |cl: usize| -> Vec<f32> {
            let mut v = Vec::with_capacity(256);
            for i in 0..16 {
                for j in 0..16 {
                    v.push(d.pattern(cl, i, j, 0));
                }
            }
            v
        };
        let t0 = tpl(0);
        let t1 = tpl(1);
        let dist: f32 = t0.iter().zip(&t1).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(dist > 1.0, "templates of different classes must differ, d={dist}");
    }

    #[test]
    fn different_seeds_give_different_tasks() {
        let d1 = SynthClass::synmnist(1);
        let d2 = SynthClass::synmnist(2);
        let p1 = d1.pattern(0, 3, 3, 0);
        let p2 = d2.pattern(0, 3, 3, 0);
        assert_ne!(p1, p2);
    }
}
