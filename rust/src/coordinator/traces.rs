//! The trace-estimation engine (paper §3.3, §4.1).
//!
//! Streams estimator iterations through the EF / Hutchinson executables
//! and Welford-accumulates per-block values until the convergence monitor
//! (fixed relative tolerance on the moving standard error — paper §4.3)
//! fires or the iteration cap is reached. Each iteration draws a fresh
//! batch from the dataset's test stream (and a fresh Rademacher probe for
//! Hutchinson). Wall-clock per iteration is recorded so the Table-1/4
//! speedup s = (sigma_H^2 * t_H) / (sigma_EF^2 * t_EF) can be reported
//! from the same machinery.

use std::time::Instant;

use anyhow::{Context, Result};

use super::parallel;
use crate::data::{Dataset, Split};
use crate::runtime::{Arg, Runtime};
use crate::stats::ConvergenceMonitor;
use crate::tensor::Pcg32;

/// Which estimator to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Estimator {
    /// Empirical Fisher: B * ||batch gradient||^2 per block, one backward.
    EmpiricalFisher,
    /// Hutchinson: r^T H r per block, double backward per iteration.
    Hutchinson,
}

impl Estimator {
    /// Artifact entry-point name for this estimator at a given batch size.
    pub fn entry(&self, batch: usize) -> String {
        match self {
            Estimator::EmpiricalFisher => format!("ef_trace_bs{batch}"),
            Estimator::Hutchinson => format!("hutch_bs{batch}"),
        }
    }

    /// Display name used in reports ("EF" / "Hessian").
    pub fn name(&self) -> &'static str {
        match self {
            Estimator::EmpiricalFisher => "EF",
            Estimator::Hutchinson => "Hessian",
        }
    }
}

/// Stopping rule for a trace run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceOptions {
    pub batch: usize,
    /// Relative tolerance on each block mean's standard error (0 disables
    /// early stopping; the run uses exactly `max_iters` iterations).
    pub tol: f64,
    pub min_iters: u64,
    pub max_iters: u64,
    pub seed: u64,
}

impl Default for TraceOptions {
    fn default() -> Self {
        // tol = 0.01 is the paper's §4.3 setting.
        TraceOptions { batch: 32, tol: 0.01, min_iters: 8, max_iters: 1000, seed: 0 }
    }
}

impl TraceOptions {
    /// Exactly `iters` iterations, no early stopping (Table-1/3 protocol).
    pub fn fixed_iters(batch: usize, iters: u64, seed: u64) -> Self {
        TraceOptions { batch, tol: 0.0, min_iters: iters, max_iters: iters, seed }
    }
}

/// Result of one trace estimation run.
#[derive(Debug, Clone)]
pub struct TraceResult {
    pub estimator: Estimator,
    /// Converged per-weight-block trace means.
    pub w_traces: Vec<f64>,
    /// Per-activation-block trace means (EF only; empty for Hutchinson).
    pub a_traces: Vec<f64>,
    /// Standard errors of the weight-block means.
    pub w_std_errors: Vec<f64>,
    pub iterations: u64,
    /// Mean wall-clock per estimator iteration (seconds).
    pub iter_time_s: f64,
    /// Normalized estimator variance: mean over blocks of
    /// sample_variance / mean^2 (this is the Table-1/3 "estimator
    /// variance" statistic, deviation normalized w.r.t. trace magnitude).
    pub norm_variance: f64,
    /// Per-iteration running means of the *total* weight trace (Fig. 2).
    pub history_total: Vec<f64>,
}

/// Drives estimator executables over a dataset's test stream and
/// accumulates per-block trace statistics to convergence.
pub struct TraceEngine<'a> {
    rt: &'a Runtime,
    ds: &'a dyn Dataset,
}

impl<'a> TraceEngine<'a> {
    /// Engine over a runtime and the dataset whose test stream feeds it.
    pub fn new(rt: &'a Runtime, ds: &'a dyn Dataset) -> Self {
        TraceEngine { rt, ds }
    }

    /// Run one estimator to convergence on a trained parameter vector.
    pub fn run(
        &self,
        model: &str,
        params: &[f32],
        estimator: Estimator,
        opt: TraceOptions,
    ) -> Result<TraceResult> {
        let m = self.rt.model(model)?.clone();
        let exe = self
            .rt
            .load(model, &estimator.entry(opt.batch))
            .with_context(|| format!("{model}: estimator artifact for bs={}", opt.batch))?;

        let lw = m.n_weight_blocks();
        let la = m.n_act_blocks();
        let sl: usize = m.input_shape.iter().product();
        let ll = match m.task {
            crate::runtime::Task::Classify => 1,
            crate::runtime::Task::Segment => m.input_shape[0] * m.input_shape[1],
        };

        let mut rng = Pcg32::new(opt.seed ^ 0x7ace_5eed, 1);
        let mut x = vec![0.0f32; opt.batch * sl];
        let mut y = vec![0i32; opt.batch * ll];
        let mut monitor = if opt.tol > 0.0 {
            ConvergenceMonitor::new(lw, opt.tol, opt.min_iters, opt.max_iters)
        } else {
            ConvergenceMonitor::new(lw, 1e-30, opt.max_iters, opt.max_iters)
        };
        let mut a_stats = crate::stats::VecStats::new(la);
        let mut history_total = Vec::new();
        let mut data_cursor: u64 = rng.next_u32() as u64;

        let t0 = Instant::now();
        loop {
            // fresh batch from the test stream
            for i in 0..opt.batch {
                self.ds.sample(
                    Split::Test,
                    data_cursor,
                    &mut x[i * sl..(i + 1) * sl],
                    &mut y[i * ll..(i + 1) * ll],
                );
                data_cursor += 1;
            }
            let (w_vals, a_vals): (Vec<f32>, Vec<f32>) = match estimator {
                Estimator::EmpiricalFisher => {
                    let out = exe.run(&[Arg::F32(params), Arg::F32(&x), Arg::I32(&y)])?;
                    (out.f32("w_tr")?.to_vec(), out.f32("a_tr")?.to_vec())
                }
                Estimator::Hutchinson => {
                    let r = rng.rademacher_vec(params.len());
                    let out =
                        exe.run(&[Arg::F32(params), Arg::F32(&x), Arg::I32(&y), Arg::F32(&r)])?;
                    (out.f32("quad")?.to_vec(), vec![])
                }
            };
            if !a_vals.is_empty() {
                a_stats.push(&a_vals);
            }
            let done = monitor.push(&w_vals);
            history_total.push(monitor.means().iter().sum());
            if done {
                break;
            }
        }
        let iters = monitor.iterations();
        let iter_time_s = t0.elapsed().as_secs_f64() / iters as f64;

        let stats = monitor.stats();
        let norm_variance = (0..lw)
            .map(|i| {
                let c = stats.component(i);
                let mu = c.mean().abs().max(1e-12);
                c.sample_variance() / (mu * mu)
            })
            .sum::<f64>()
            / lw as f64;

        Ok(TraceResult {
            estimator,
            w_traces: monitor.means(),
            a_traces: a_stats.means(),
            w_std_errors: monitor.std_errors(),
            iterations: iters,
            iter_time_s,
            norm_variance,
            history_total,
        })
    }
}

impl TraceEngine<'_> {
    /// Run several independent trace estimations, fanned out over `jobs`
    /// worker threads (`coordinator::parallel`), returning results in the
    /// order of `specs`.
    ///
    /// Every run's stochastic stream depends only on its own
    /// `TraceOptions::seed`, so the numeric outputs are bit-identical to
    /// running the specs serially — only `iter_time_s` is a wall-clock
    /// measurement and will reflect core contention. Experiments whose
    /// *result* is a timing (Table 1/3 speedups) should keep `jobs = 1`.
    ///
    /// With `jobs <= 1` the engine's own runtime (and its warm executable
    /// cache) is reused; with more, each worker rebuilds its own runtime
    /// from this engine's backend spec.
    pub fn run_many(
        &self,
        model: &str,
        params: &[f32],
        specs: &[(Estimator, TraceOptions)],
        jobs: usize,
    ) -> Result<Vec<TraceResult>> {
        if parallel::effective_jobs(jobs, specs.len()) <= 1 {
            return specs.iter().map(|&(est, opt)| self.run(model, params, est, opt)).collect();
        }
        // intra-op GEMM threads off in workers: the trace fan-out owns
        // the cores (outputs are identical either way)
        let spec = self.rt.spec().intra_serial();
        let ds = self.ds;
        parallel::run_pool(
            specs.len(),
            jobs,
            || Runtime::from_spec(&spec),
            move |rt, i| {
                let (est, opt) = specs[i];
                TraceEngine::new(rt, ds).run(model, params, est, opt)
            },
        )
    }
}

/// Paper Appendix C speedup for a fixed tolerance:
/// s = (sigma_H^2 * t_H) / (sigma_EF^2 * t_EF).
pub fn relative_speedup(ef: &TraceResult, hess: &TraceResult) -> f64 {
    (hess.norm_variance * hess.iter_time_s) / (ef.norm_variance * ef.iter_time_s).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_entry_names() {
        assert_eq!(Estimator::EmpiricalFisher.entry(32), "ef_trace_bs32");
        assert_eq!(Estimator::Hutchinson.entry(4), "hutch_bs4");
    }

    #[test]
    fn fixed_iter_options() {
        let o = TraceOptions::fixed_iters(8, 100, 3);
        assert_eq!(o.batch, 8);
        assert_eq!((o.min_iters, o.max_iters), (100, 100));
        assert_eq!(o.tol, 0.0);
    }

    #[test]
    fn relative_speedup_formula() {
        let mk = |var: f64, t: f64| TraceResult {
            estimator: Estimator::EmpiricalFisher,
            w_traces: vec![],
            a_traces: vec![],
            w_std_errors: vec![],
            iterations: 1,
            iter_time_s: t,
            norm_variance: var,
            history_total: vec![],
        };
        let ef = mk(0.15, 0.05);
        let h = mk(1.05, 0.19);
        let s = relative_speedup(&ef, &h);
        assert!((s - (1.05 * 0.19) / (0.15 * 0.05)).abs() < 1e-12);
    }
}
