//! MPQ configuration search (the HAWQ-style use of FIT, paper §1-2).
//!
//! FIT gives every candidate configuration a scalar sensitivity score
//! without training it; combined with the size model this yields:
//!
//! - `pareto_front`: the size-vs-FIT front from a random sample of the
//!   exponential configuration space (the paper's "Pareto front ... used
//!   to quickly determine the best MPQ configuration for a given set of
//!   constraints").
//! - `greedy_allocate`: budgeted bit allocation — start everything at the
//!   highest precision and repeatedly take the cheapest FIT-per-bit-saved
//!   step until the size budget is met.

use crate::metrics::{fit, SensitivityInputs};
use crate::quant::{model_bits, BitConfig};

/// One scored configuration.
#[derive(Debug, Clone)]
pub struct ScoredConfig {
    pub cfg: BitConfig,
    pub fit: f64,
    pub size_bits: u64,
}

pub fn score(s: &SensitivityInputs, block_sizes: &[usize], n_unq: usize, cfg: BitConfig) -> ScoredConfig {
    let f = fit(s, &cfg);
    let size_bits = model_bits(block_sizes, n_unq, &cfg);
    ScoredConfig { cfg, fit: f, size_bits }
}

/// Indices of the non-dominated points (minimize both size and FIT).
/// O(n log n): sort by size, sweep for strictly improving FIT.
pub fn pareto_front(points: &[ScoredConfig]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .size_bits
            .cmp(&points[b].size_bits)
            .then(points[a].fit.partial_cmp(&points[b].fit).unwrap())
    });
    let mut front = Vec::new();
    let mut best_fit = f64::INFINITY;
    for &i in &idx {
        if points[i].fit < best_fit {
            front.push(i);
            best_fit = points[i].fit;
        }
    }
    front
}

/// Greedy budgeted allocation: all blocks start at `precisions.max()`;
/// each step lowers the precision of the block whose next step costs the
/// least FIT increase per bit of storage saved, until `budget_bits` is
/// met. Returns None if even the all-minimum config misses the budget.
pub fn greedy_allocate(
    s: &SensitivityInputs,
    block_sizes: &[usize],
    n_unq: usize,
    precisions: &[u32],
    budget_bits: u64,
) -> Option<ScoredConfig> {
    let mut prec = precisions.to_vec();
    prec.sort_unstable();
    let max_p = *prec.last().unwrap();
    let lw = s.n_weight_blocks();
    let la = s.n_act_blocks();
    let mut cfg = BitConfig::uniform(lw, la, max_p);

    let floor = {
        let min_p = prec[0];
        model_bits(block_sizes, n_unq, &BitConfig::uniform(lw, la, min_p))
    };
    if floor > budget_bits {
        return None;
    }

    let step_down = |b: u32| -> Option<u32> {
        prec.iter().rev().find(|&&p| p < b).copied()
    };

    while model_bits(block_sizes, n_unq, &cfg) > budget_bits {
        let cur_fit = fit(s, &cfg);
        let mut best: Option<(f64, bool, usize, u32)> = None; // (cost/bit, is_w, idx, new_bits)
        for l in 0..lw {
            if let Some(nb) = step_down(cfg.bits_w[l]) {
                let mut c = cfg.clone();
                c.bits_w[l] = nb;
                let d_fit = fit(s, &c) - cur_fit;
                let d_bits = (cfg.bits_w[l] - nb) as u64 * block_sizes[l] as u64;
                let rate = d_fit / d_bits as f64;
                if best.map_or(true, |(r, ..)| rate < r) {
                    best = Some((rate, true, l, nb));
                }
            }
        }
        for l in 0..la {
            if let Some(nb) = step_down(cfg.bits_a[l]) {
                let mut c = cfg.clone();
                c.bits_a[l] = nb;
                let d_fit = fit(s, &c) - cur_fit;
                // activations don't change stored model size; treat one
                // block-step as one "bit" so they still get lowered last
                // on pure-size budgets.
                let rate = d_fit;
                if best.map_or(true, |(r, ..)| rate < r) {
                    best = Some((rate, false, l, nb));
                }
            }
        }
        match best {
            Some((_, true, l, nb)) => cfg.bits_w[l] = nb,
            Some((_, false, l, nb)) => cfg.bits_a[l] = nb,
            None => break,
        }
    }
    Some(score(s, block_sizes, n_unq, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::test_inputs;
    use crate::quant::{BitConfigSampler, PRECISIONS};

    fn sample_scored(n: usize) -> (SensitivityInputs, Vec<usize>, Vec<ScoredConfig>) {
        let s = test_inputs();
        let sizes = vec![100usize, 400, 50];
        let mut sampler = BitConfigSampler::new(3, 2, &PRECISIONS, 1);
        let pts: Vec<_> = sampler
            .take(n)
            .into_iter()
            .map(|c| score(&s, &sizes, 10, c))
            .collect();
        (s, sizes, pts)
    }

    #[test]
    fn pareto_points_are_mutually_nondominated() {
        let (_, _, pts) = sample_scored(150);
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                if i != j {
                    let dom = pts[j].size_bits <= pts[i].size_bits && pts[j].fit <= pts[i].fit;
                    assert!(!dom, "{i} dominated by {j}");
                }
            }
        }
    }

    #[test]
    fn pareto_front_dominates_all_points() {
        let (_, _, pts) = sample_scored(150);
        let front = pareto_front(&pts);
        for (i, p) in pts.iter().enumerate() {
            if front.contains(&i) {
                continue;
            }
            // every non-front point is dominated or tied by some front point
            let covered = front.iter().any(|&f| {
                pts[f].size_bits <= p.size_bits && pts[f].fit <= p.fit
            });
            assert!(covered, "point {i} not covered");
        }
    }

    #[test]
    fn greedy_meets_budget_and_prefers_insensitive_blocks() {
        let (s, sizes, _) = sample_scored(1);
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let budget = full * 6 / 10;
        let out = greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget).unwrap();
        assert!(out.size_bits <= budget);
        // block 0 has the highest trace (10.0) -> should keep more bits
        // than block 1 (trace 2.0, bigger size)
        assert!(out.cfg.bits_w[0] >= out.cfg.bits_w[1]);
    }

    #[test]
    fn greedy_impossible_budget_is_none() {
        let (s, sizes, _) = sample_scored(1);
        assert!(greedy_allocate(&s, &sizes, 10, &PRECISIONS, 1).is_none());
    }

    #[test]
    fn greedy_trivial_budget_keeps_max_precision() {
        let (s, sizes, _) = sample_scored(1);
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let out = greedy_allocate(&s, &sizes, 10, &PRECISIONS, full).unwrap();
        assert_eq!(out.cfg.bits_w, vec![8, 8, 8]);
    }
}
