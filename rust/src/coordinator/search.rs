//! MPQ configuration search (the HAWQ-style use of FIT, paper §1-2).
//!
//! FIT gives every candidate configuration a scalar sensitivity score
//! without training it; combined with the size model this yields:
//!
//! - `pareto_front` / `pareto_front_scores`: the size-vs-FIT front from a
//!   random sample of the exponential configuration space (the paper's
//!   "Pareto front ... used to quickly determine the best MPQ configuration
//!   for a given set of constraints").
//! - `greedy_allocate`: budgeted bit allocation — start everything at the
//!   highest precision and repeatedly take the cheapest FIT-per-bit-saved
//!   step until the size budget is met.
//!
//! Both are table-driven: FIT and model size are separable per-block sums,
//! so [`FitTable`] precomputes every per-block × per-precision contribution
//! once and each step or configuration score is a flat gather (see
//! `metrics/table.rs`). The naive clone-and-rescore greedy is retained as
//! [`greedy_allocate_naive`] — the reference the equivalence tests and
//! `benches/fit_scoring.rs` compare against.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::metrics::{fit, FitTable, SensitivityInputs};
use crate::quant::{model_bits, BitConfig};

/// One scored configuration.
#[derive(Debug, Clone)]
pub struct ScoredConfig {
    pub cfg: BitConfig,
    pub fit: f64,
    pub size_bits: u64,
}

pub fn score(s: &SensitivityInputs, block_sizes: &[usize], n_unq: usize, cfg: BitConfig) -> ScoredConfig {
    let f = fit(s, &cfg);
    let size_bits = model_bits(block_sizes, n_unq, &cfg);
    ScoredConfig { cfg, fit: f, size_bits }
}

/// Indices of the non-dominated points (minimize both size and FIT).
pub fn pareto_front(points: &[ScoredConfig]) -> Vec<usize> {
    let pairs: Vec<(f64, u64)> = points.iter().map(|p| (p.fit, p.size_bits)).collect();
    pareto_front_scores(&pairs)
}

/// Pareto front over raw `(fit, size_bits)` pairs — the form
/// [`FitTable::score_batch`] streams out, so million-config sweeps never
/// materialize `ScoredConfig`s. O(n log n): sort by size, sweep for
/// strictly improving FIT. NaN fits order last (`total_cmp`) and never
/// enter the front, so a NaN trace degrades the ranking instead of
/// aborting the study.
pub fn pareto_front_scores(scores: &[(f64, u64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[a].1.cmp(&scores[b].1).then(scores[a].0.total_cmp(&scores[b].0))
    });
    let mut front = Vec::new();
    let mut best_fit = f64::INFINITY;
    for &i in &idx {
        if scores[i].0 < best_fit {
            front.push(i);
            best_fit = scores[i].0;
        }
    }
    front
}

/// O(n²) dominance-scan reference for [`pareto_front_scores`].
///
/// ISSUE 9 asked for the quadratic scan to be *replaced* by sort-then-sweep,
/// but the sweep has been the implementation since the scoring-engine PR —
/// so the quadratic direction is reversed: this is the naive ground truth,
/// written directly from the sweep's membership characterization, and the
/// regression pin (`naive == sweep == streaming accumulator`, including the
/// NaN/±0.0/duplicate corners) lives in the tests below and in
/// `tests/search_service.rs`.
///
/// Membership: order points by the lexicographic key
/// `(size_bits, fit via total_cmp, index)` — exactly the sweep's stable
/// sort. Point `i` is on the front iff `fit_i < +∞` (NaN and +∞ never
/// enter) and every point `j` ordered before it satisfies
/// `fit_j is NaN || fit_i < fit_j` (a NaN predecessor never raises the
/// sweep's running minimum, every other predecessor must be strictly
/// beaten).
pub fn pareto_front_scores_naive(scores: &[(f64, u64)]) -> Vec<usize> {
    let before = |j: usize, i: usize| -> bool {
        let (fj, sj) = scores[j];
        let (fi, si) = scores[i];
        match sj.cmp(&si).then(fj.total_cmp(&fi)) {
            Ordering::Less => true,
            Ordering::Greater => false,
            Ordering::Equal => j < i,
        }
    };
    let mut front: Vec<usize> = (0..scores.len())
        .filter(|&i| {
            let fi = scores[i].0;
            fi < f64::INFINITY
                && (0..scores.len())
                    .all(|j| j == i || !before(j, i) || scores[j].0.is_nan() || fi < scores[j].0)
        })
        .collect();
    // report in the sweep's output order (size ascending), not index order
    front.sort_by(|&a, &b| {
        scores[a].1.cmp(&scores[b].1).then(scores[a].0.total_cmp(&scores[b].0)).then(a.cmp(&b))
    });
    front
}

/// One point of a (possibly streamed) Pareto front: the *global* index of
/// the scored configuration plus its raw `(fit, size_bits)` pair. Shard
/// workers attach their range base to local indices, so folding fronts
/// from any shard split reproduces the indices of the one-shot path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrontPoint {
    pub index: usize,
    pub fit: f64,
    pub size_bits: u64,
}

/// The canonical front order — the key [`pareto_front_scores`]'s stable
/// sort realizes: size ascending, then fit by `total_cmp`, then index.
fn front_key(a: &FrontPoint, b: &FrontPoint) -> Ordering {
    a.size_bits.cmp(&b.size_bits).then(a.fit.total_cmp(&b.fit)).then(a.index.cmp(&b.index))
}

/// Online dominance-merge: fold points (or whole per-shard fronts) in any
/// order and read back, at any moment, the exact Pareto front of
/// everything absorbed so far — bit-identical, index-for-index, to running
/// [`pareto_front_scores`] once over the union. This is the streaming
/// front the search service emits as shards complete.
///
/// Why folding per-shard *fronts* loses nothing: membership of point `p`
/// depends only on the minimum fit among points keyed before `p`
/// (see [`pareto_front_scores_naive`]), and every absorbed point that is
/// *not* on the current front is witnessed by a current front point with a
/// smaller-or-equal key and a `<=` fit (witnesses chain through evictions),
/// so dropping it never changes that minimum. The same argument makes
/// [`push`](Self::push) order-invariant and idempotent. The front is kept
/// in canonical order with strictly increasing sizes and strictly
/// decreasing fits, so each push is a binary search plus a (rare) eviction
/// drain — O(log F) amortized, no sort on the service's hot path.
#[derive(Debug, Clone, Default)]
pub struct ParetoAccumulator {
    front: Vec<FrontPoint>,
}

impl ParetoAccumulator {
    pub fn new() -> ParetoAccumulator {
        ParetoAccumulator::default()
    }

    /// Absorb one scored point. NaN and +∞ fits are ignored (they can
    /// never enter a front), matching the one-shot sweep.
    pub fn push(&mut self, p: FrontPoint) {
        if !(p.fit < f64::INFINITY) {
            return;
        }
        let pos = match self.front.binary_search_by(|q| front_key(q, &p)) {
            Ok(_) => return, // exact duplicate (same index): idempotent
            Err(pos) => pos,
        };
        // the predecessor holds the minimum fit among everything absorbed
        // with a smaller key; non-strict improvement is rejection
        if pos > 0 && !(p.fit < self.front[pos - 1].fit) {
            return;
        }
        // points keyed after p survive only if they still strictly beat
        // p.fit; fits decrease along the front, so the evictions are a
        // contiguous run starting at pos
        let evict_end = self.front[pos..]
            .iter()
            .position(|q| q.fit < p.fit)
            .map_or(self.front.len(), |k| pos + k);
        self.front.splice(pos..evict_end, [p]);
    }

    /// Absorb a whole shard's raw scores; `base` is the global index of
    /// `scores[0]` (shards are contiguous index ranges).
    pub fn absorb_scores(&mut self, base: usize, scores: &[(f64, u64)]) {
        for (off, &(fit, size_bits)) in scores.iter().enumerate() {
            self.push(FrontPoint { index: base + off, fit, size_bits });
        }
    }

    /// Absorb another front (e.g. one shard's local front).
    pub fn absorb_front(&mut self, points: &[FrontPoint]) {
        for &p in points {
            self.push(p);
        }
    }

    /// The current front in canonical order (size ascending) — the same
    /// order [`pareto_front_scores`] reports.
    pub fn front(&self) -> &[FrontPoint] {
        &self.front
    }

    /// The current front's global indices, in canonical order.
    pub fn indices(&self) -> Vec<usize> {
        self.front.iter().map(|p| p.index).collect()
    }

    pub fn len(&self) -> usize {
        self.front.len()
    }

    pub fn is_empty(&self) -> bool {
        self.front.is_empty()
    }
}

/// One precision-lowering step of the heap greedy: `block` moves down to
/// `to_level` on the descending-precision ladder. Ordered by
/// `(rate, weights-before-activations, block index)` via `total_cmp`,
/// which reproduces the naive scan's first-strict-minimum tie-break; NaN
/// rates order last, so a NaN trace starves that block instead of
/// poisoning the comparison.
#[derive(Debug, Clone, Copy)]
struct Step {
    rate: f64,
    is_act: bool,
    block: usize,
    to_level: usize,
    d_bits: u64,
}

impl PartialEq for Step {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Step {}

impl PartialOrd for Step {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Step {
    fn cmp(&self, other: &Self) -> Ordering {
        self.rate
            .total_cmp(&other.rate)
            .then(self.is_act.cmp(&other.is_act))
            .then(self.block.cmp(&other.block))
    }
}

/// Greedy budgeted allocation: all blocks start at `precisions.max()`;
/// each step lowers the precision of the block whose next step is
/// cheapest, until `budget_bits` is met. Returns None if even the
/// all-minimum config misses the budget.
///
/// # Step ranking units
///
/// Weight steps are ranked by `Δfit / Δbits` — FIT increase per bit of
/// storage saved, with `Δbits = (b_cur - b_next) · block_size`. Activation
/// steps save no *stored* bits, so their rank key is the raw `Δfit` of the
/// step, compared directly against the weight steps' per-bit rates. The
/// pinned consequences (see `activation_steps_rank_by_raw_dfit_pinned`):
/// a high-trace activation block's raw Δfit exceeds every weight rate, so
/// on a pure-size budget it stays at max precision; a near-zero-trace
/// activation block ranks *below* every weight rate and is ground down
/// first, even though that frees no storage. Ties break in scan order:
/// weight blocks before activation blocks, lower index first.
///
/// # Complexity
///
/// Builds a [`FitTable`] (O(L·P)), then walks a binary heap holding one
/// candidate step per block: O(L + S log L) for S executed steps, with
/// `model_bits` tracked incrementally — vs the naive reference's
/// O(L²·P) full rescore per step ([`greedy_allocate_naive`]).
pub fn greedy_allocate(
    s: &SensitivityInputs,
    block_sizes: &[usize],
    n_unq: usize,
    precisions: &[u32],
    budget_bits: u64,
) -> Option<ScoredConfig> {
    let table = FitTable::new(s, block_sizes, n_unq, precisions);
    greedy_allocate_table(&table, budget_bits)
}

/// [`greedy_allocate`] over a prebuilt (shared) [`FitTable`].
pub fn greedy_allocate_table(table: &FitTable, budget_bits: u64) -> Option<ScoredConfig> {
    let precs = table.precisions();
    // the precision ladder: distinct precisions, descending, as indices
    // into the table's precision set
    let mut ladder: Vec<usize> = (0..precs.len()).collect();
    ladder.sort_by(|&a, &b| precs[b].cmp(&precs[a]));
    ladder.dedup_by(|a, b| precs[*a] == precs[*b]);
    let min_level = ladder.len() - 1;
    let lw = table.n_weight_blocks();
    let la = table.n_act_blocks();

    let floor: u64 = table.base_bits()
        + (0..lw).map(|l| table.w_size_bits(l, ladder[min_level])).sum::<u64>();
    if floor > budget_bits {
        return None;
    }

    let w_step = |l: usize, from: usize| -> Option<Step> {
        let to = from + 1;
        if to > min_level {
            return None;
        }
        let d_fit = table.w_term(l, ladder[to]) - table.w_term(l, ladder[from]);
        let d_bits = table.w_size_bits(l, ladder[from]) - table.w_size_bits(l, ladder[to]);
        Some(Step { rate: d_fit / d_bits as f64, is_act: false, block: l, to_level: to, d_bits })
    };
    let a_step = |l: usize, from: usize| -> Option<Step> {
        let to = from + 1;
        if to > min_level {
            return None;
        }
        let d_fit = table.a_term(l, ladder[to]) - table.a_term(l, ladder[from]);
        Some(Step { rate: d_fit, is_act: true, block: l, to_level: to, d_bits: 0 })
    };

    // one live candidate step per block, keyed by (rate, is_act, block)
    let mut heap: BinaryHeap<Reverse<Step>> = BinaryHeap::with_capacity(lw + la);
    for l in 0..lw {
        if let Some(st) = w_step(l, 0) {
            heap.push(Reverse(st));
        }
    }
    for l in 0..la {
        if let Some(st) = a_step(l, 0) {
            heap.push(Reverse(st));
        }
    }

    let mut w_level = vec![0usize; lw];
    let mut a_level = vec![0usize; la];
    let mut bits_now: u64 =
        table.base_bits() + (0..lw).map(|l| table.w_size_bits(l, ladder[0])).sum::<u64>();
    while bits_now > budget_bits {
        let Some(Reverse(st)) = heap.pop() else { break };
        if st.is_act {
            a_level[st.block] = st.to_level;
            if let Some(next) = a_step(st.block, st.to_level) {
                heap.push(Reverse(next));
            }
        } else {
            w_level[st.block] = st.to_level;
            bits_now -= st.d_bits;
            if let Some(next) = w_step(st.block, st.to_level) {
                heap.push(Reverse(next));
            }
        }
    }

    let cfg = BitConfig {
        bits_w: w_level.iter().map(|&k| precs[ladder[k]]).collect(),
        bits_a: a_level.iter().map(|&k| precs[ladder[k]]).collect(),
    };
    let packed = table.pack(&cfg);
    debug_assert_eq!(table.size_bits(&packed), bits_now);
    Some(ScoredConfig { fit: table.score(&packed), size_bits: bits_now, cfg })
}

/// Reference implementation of [`greedy_allocate`]: clone the whole config
/// and rescore full FIT for every candidate step — O(L²·P) per budget
/// step. Retained (not deprecated) as the ground truth the equivalence
/// tests and the old-vs-new benchmark compare the heap walk against; the
/// two produce identical configurations and bit-identical scores on every
/// seeded equivalence instance (`tests/fit_table_equivalence.rs`). One
/// caveat keeps that claim scoped to *seeded instances* rather than
/// universal: this path ranks a step by the full-sum difference
/// `fit(new) - fit(cur)` while the heap ranks by the exact per-term delta,
/// so two steps whose true rates are closer than this path's summation
/// rounding (~1 ULP of the total) could in principle be ordered
/// differently — both outcomes equally valid greedy choices. Exact ties
/// (e.g. duplicate blocks) break identically in both paths.
pub fn greedy_allocate_naive(
    s: &SensitivityInputs,
    block_sizes: &[usize],
    n_unq: usize,
    precisions: &[u32],
    budget_bits: u64,
) -> Option<ScoredConfig> {
    let mut prec = precisions.to_vec();
    prec.sort_unstable();
    let max_p = *prec.last().unwrap();
    let lw = s.n_weight_blocks();
    let la = s.n_act_blocks();
    let mut cfg = BitConfig::uniform(lw, la, max_p);

    let floor = {
        let min_p = prec[0];
        model_bits(block_sizes, n_unq, &BitConfig::uniform(lw, la, min_p))
    };
    if floor > budget_bits {
        return None;
    }

    let step_down = |b: u32| -> Option<u32> {
        prec.iter().rev().find(|&&p| p < b).copied()
    };

    while model_bits(block_sizes, n_unq, &cfg) > budget_bits {
        let cur_fit = fit(s, &cfg);
        let mut best: Option<(f64, bool, usize, u32)> = None; // (cost/bit, is_w, idx, new_bits)
        for l in 0..lw {
            if let Some(nb) = step_down(cfg.bits_w[l]) {
                let mut c = cfg.clone();
                c.bits_w[l] = nb;
                let d_fit = fit(s, &c) - cur_fit;
                let d_bits = (cfg.bits_w[l] - nb) as u64 * block_sizes[l] as u64;
                let rate = d_fit / d_bits as f64;
                if best.is_none_or(|(r, ..)| rate < r) {
                    best = Some((rate, true, l, nb));
                }
            }
        }
        for l in 0..la {
            if let Some(nb) = step_down(cfg.bits_a[l]) {
                let mut c = cfg.clone();
                c.bits_a[l] = nb;
                let d_fit = fit(s, &c) - cur_fit;
                // activations don't change stored model size; rank the step
                // by its raw Δfit (see `greedy_allocate` "Step ranking
                // units") so they still get lowered last on pure-size
                // budgets.
                let rate = d_fit;
                if best.is_none_or(|(r, ..)| rate < r) {
                    best = Some((rate, false, l, nb));
                }
            }
        }
        match best {
            Some((_, true, l, nb)) => cfg.bits_w[l] = nb,
            Some((_, false, l, nb)) => cfg.bits_a[l] = nb,
            None => break,
        }
    }
    Some(score(s, block_sizes, n_unq, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::test_inputs;
    use crate::quant::{BitConfigSampler, PRECISIONS};

    fn sample_scored(n: usize) -> (SensitivityInputs, Vec<usize>, Vec<ScoredConfig>) {
        let s = test_inputs();
        let sizes = vec![100usize, 400, 50];
        let mut sampler = BitConfigSampler::new(3, 2, &PRECISIONS, 1);
        let pts: Vec<_> = sampler
            .take(n)
            .into_iter()
            .map(|c| score(&s, &sizes, 10, c))
            .collect();
        (s, sizes, pts)
    }

    #[test]
    fn pareto_points_are_mutually_nondominated() {
        let (_, _, pts) = sample_scored(150);
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                if i != j {
                    let dom = pts[j].size_bits <= pts[i].size_bits && pts[j].fit <= pts[i].fit;
                    assert!(!dom, "{i} dominated by {j}");
                }
            }
        }
    }

    #[test]
    fn pareto_front_dominates_all_points() {
        let (_, _, pts) = sample_scored(150);
        let front = pareto_front(&pts);
        for (i, p) in pts.iter().enumerate() {
            if front.contains(&i) {
                continue;
            }
            // every non-front point is dominated or tied by some front point
            let covered = front.iter().any(|&f| {
                pts[f].size_bits <= p.size_bits && pts[f].fit <= p.fit
            });
            assert!(covered, "point {i} not covered");
        }
    }

    #[test]
    fn pareto_scores_agrees_with_struct_path() {
        let (_, _, pts) = sample_scored(200);
        let pairs: Vec<(f64, u64)> = pts.iter().map(|p| (p.fit, p.size_bits)).collect();
        assert_eq!(pareto_front(&pts), pareto_front_scores(&pairs));
    }

    #[test]
    fn pareto_front_tolerates_nan_fit() {
        // a NaN trace must degrade the ranking (NaN points never join the
        // front), not abort the study via a partial_cmp().unwrap() panic —
        // including on equal sizes, where the fit comparator actually runs
        let mk = |fit: f64, size: u64| ScoredConfig {
            cfg: BitConfig { bits_w: vec![8], bits_a: vec![] },
            fit,
            size_bits: size,
        };
        let pts = vec![mk(f64::NAN, 100), mk(1.0, 100), mk(0.5, 300), mk(f64::NAN, 300)];
        assert_eq!(pareto_front(&pts), vec![1, 2]);
    }

    #[test]
    fn greedy_meets_budget_and_prefers_insensitive_blocks() {
        let (s, sizes, _) = sample_scored(1);
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let budget = full * 6 / 10;
        let out = greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget).unwrap();
        assert!(out.size_bits <= budget);
        // block 0 has the highest trace (10.0) -> should keep more bits
        // than block 1 (trace 2.0, bigger size)
        assert!(out.cfg.bits_w[0] >= out.cfg.bits_w[1]);
    }

    #[test]
    fn greedy_impossible_budget_is_none() {
        let (s, sizes, _) = sample_scored(1);
        assert!(greedy_allocate(&s, &sizes, 10, &PRECISIONS, 1).is_none());
        assert!(greedy_allocate_naive(&s, &sizes, 10, &PRECISIONS, 1).is_none());
    }

    #[test]
    fn greedy_trivial_budget_keeps_max_precision() {
        let (s, sizes, _) = sample_scored(1);
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let out = greedy_allocate(&s, &sizes, 10, &PRECISIONS, full).unwrap();
        assert_eq!(out.cfg.bits_w, vec![8, 8, 8]);
    }

    #[test]
    fn heap_greedy_matches_naive_on_study_instance() {
        let (s, sizes, _) = sample_scored(1);
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        for num in [100u64, 95, 80, 65, 60, 55, 50, 45] {
            let budget = full * num / 100;
            let a = greedy_allocate_naive(&s, &sizes, 10, &PRECISIONS, budget).unwrap();
            let b = greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget).unwrap();
            assert_eq!(a.cfg, b.cfg, "at {num}%");
            assert_eq!(a.fit.to_bits(), b.fit.to_bits(), "at {num}%");
            assert_eq!(a.size_bits, b.size_bits, "at {num}%");
        }
    }

    #[test]
    fn activation_steps_rank_by_raw_dfit_pinned() {
        // Near-zero activation trace: its raw Δfit ranks below every
        // weight Δfit/Δbit rate, so the act block is ground to minimum
        // precision while weights are still being lowered — even though
        // act steps free no stored bits. Pinned (values hand-checked
        // against an exact f64 simulation) so the heap rewrite can't
        // silently change the rate-unit mismatch it inherits.
        let s = SensitivityInputs {
            w_traces: vec![1.0, 1.0],
            a_traces: vec![1e-12],
            w_lo: vec![-1.0, -1.0],
            w_hi: vec![1.0, 1.0],
            a_lo: vec![0.0],
            a_hi: vec![1.0],
            bn_gamma: vec![None, None],
        };
        let sizes = vec![100usize, 100];
        let full = model_bits(&sizes, 0, &BitConfig::uniform(2, 1, 8));
        assert_eq!(full, 1600);
        let out = greedy_allocate(&s, &sizes, 0, &PRECISIONS, full * 90 / 100).unwrap();
        assert_eq!(out.cfg.bits_w, vec![6, 8], "rate tie breaks to the lower block index");
        assert_eq!(out.cfg.bits_a, vec![3], "negligible-trace act block hits the floor first");
        assert_eq!(out.size_bits, 1400);
        let naive = greedy_allocate_naive(&s, &sizes, 0, &PRECISIONS, full * 90 / 100).unwrap();
        assert_eq!(naive.cfg, out.cfg);

        // the flip side: high-trace activations stay at max precision on a
        // pure-size budget (their raw Δfit exceeds every weight rate)
        let s2 = test_inputs();
        let sizes2 = vec![100usize, 400, 50];
        let full2 = model_bits(&sizes2, 10, &BitConfig::uniform(3, 2, 8));
        let out2 = greedy_allocate(&s2, &sizes2, 10, &PRECISIONS, full2 * 60 / 100).unwrap();
        assert_eq!(out2.cfg.bits_w, vec![6, 4, 3]);
        assert_eq!(out2.cfg.bits_a, vec![8, 8]);
    }

    /// Deterministic adversarial score clouds for the front-equivalence
    /// pins: duplicates, shared sizes, ±0.0, NaN, ±∞ all appear.
    fn score_cloud(n: usize, seed: u64) -> Vec<(f64, u64)> {
        let mut r = crate::tensor::Pcg32::new(seed, 0xf407);
        (0..n)
            .map(|_| {
                let fit = match r.below(16) {
                    0 => f64::NAN,
                    1 => f64::INFINITY,
                    2 => f64::NEG_INFINITY,
                    3 => 0.0,
                    4 => -0.0,
                    5 => 1.25, // exact duplicate fodder
                    _ => r.uniform_in(-2.0, 30.0) as f64,
                };
                (fit, r.below(12) as u64 * 100)
            })
            .collect()
    }

    #[test]
    fn naive_front_matches_sweep_on_adversarial_clouds() {
        for seed in 0..12u64 {
            let scores = score_cloud(120, seed);
            assert_eq!(
                pareto_front_scores_naive(&scores),
                pareto_front_scores(&scores),
                "seed {seed}"
            );
        }
        // the NaN corner pinned by the struct-path test, via the naive scan
        let pts = vec![(f64::NAN, 100), (1.0, 100), (0.5, 300), (f64::NAN, 300)];
        assert_eq!(pareto_front_scores_naive(&pts), vec![1, 2]);
        assert_eq!(pareto_front_scores_naive(&[]), Vec::<usize>::new());
    }

    #[test]
    fn accumulator_matches_one_shot_at_every_shard_split() {
        for seed in 0..8u64 {
            let scores = score_cloud(257, seed);
            let expect = pareto_front_scores(&scores);
            for shards in [1usize, 2, 3, 7, 16, 64, 257] {
                let mut acc = ParetoAccumulator::new();
                let per = scores.len().div_ceil(shards);
                // absorb shards back-to-front: order must not matter
                for s in (0..shards).rev() {
                    let lo = s * per;
                    let hi = (lo + per).min(scores.len());
                    if lo < hi {
                        acc.absorb_scores(lo, &scores[lo..hi]);
                    }
                }
                assert_eq!(acc.indices(), expect, "seed {seed} shards {shards}");
                for (p, &i) in acc.front().iter().zip(&expect) {
                    assert_eq!(p.fit.to_bits(), scores[i].0.to_bits());
                    assert_eq!(p.size_bits, scores[i].1);
                }
            }
        }
    }

    #[test]
    fn accumulator_folds_shard_fronts_not_just_raw_scores() {
        // the service folds per-shard *fronts*; dropping shard-dominated
        // points before the merge must lose nothing
        for seed in 0..8u64 {
            let scores = score_cloud(200, seed);
            let expect = pareto_front_scores(&scores);
            let mut acc = ParetoAccumulator::new();
            for (s, chunk) in scores.chunks(33).enumerate() {
                let base = s * 33;
                let local: Vec<FrontPoint> = pareto_front_scores(chunk)
                    .into_iter()
                    .map(|i| FrontPoint {
                        index: base + i,
                        fit: chunk[i].0,
                        size_bits: chunk[i].1,
                    })
                    .collect();
                acc.absorb_front(&local);
            }
            assert_eq!(acc.indices(), expect, "seed {seed}");
        }
    }

    #[test]
    fn accumulator_push_is_idempotent_and_incremental() {
        let scores = score_cloud(90, 3);
        let mut acc = ParetoAccumulator::new();
        for (i, &(fit, size_bits)) in scores.iter().enumerate() {
            acc.push(FrontPoint { index: i, fit, size_bits });
            // invariant at every step: the front equals the one-shot
            // front of the prefix absorbed so far
            assert_eq!(acc.indices(), pareto_front_scores(&scores[..=i]), "after {i}");
        }
        let snapshot = acc.indices();
        acc.absorb_scores(0, &scores); // absorb everything again
        assert_eq!(acc.indices(), snapshot, "re-absorption must be a no-op");
        assert_eq!(acc.len(), snapshot.len());
        assert!(!acc.is_empty());
    }

    #[test]
    fn greedy_with_nan_trace_does_not_panic() {
        let mut s = test_inputs();
        s.w_traces[1] = f64::NAN;
        let sizes = vec![100usize, 400, 50];
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        // NaN-rate steps order last but still execute once they're all
        // that's left, so the budget is met without a comparator panic
        let out = greedy_allocate(&s, &sizes, 10, &PRECISIONS, full * 6 / 10).unwrap();
        assert!(out.size_bits <= full * 6 / 10);
    }
}
