//! Cost-report analysis over op traces (`fitq trace-report`).
//!
//! Consumes two inputs, both already on disk:
//!
//! - an `optrace` artifact (kind `"optrace"`, schema v1 — encoded by
//!   [`pipeline::codec`](super::pipeline::codec), recorded by the native
//!   backend's opt-in profiler, [`native::trace`](crate::native::trace));
//! - the measured kernel peaks in `BENCH_kernels.json`.
//!
//! and renders a per-(op, layer, variant) cost table: wall-time share,
//! achieved GFLOP/s and GB/s, and — for ops whose kernels were bench-peaked
//! — the roofline ratio (achieved / best measured variant for that op).
//! The derived rates fall straight out of the trace units: the profiler
//! stores FLOPs and `f32` element counts per aggregate, so
//! `flops / wall_ns` *is* GFLOP/s and `4 * elems / wall_ns` *is* GB/s.
//!
//! Analysis is read-only and lossy by design (it never feeds anything back
//! into the pipeline, so nothing here may touch a stage digest), and every
//! failure mode is a typed [`AnalysisError`] — the fuzz harness
//! (`tests/fuzz_lite.rs`) pins that malformed bench files and corrupt
//! trace bytes surface as errors, never panics.

use std::fmt;

use crate::native::trace::{OpAggregate, OpTraceReport, TracedOp};
use crate::native::tune::{RouteTable, TunedOp};
use crate::runtime::Json;

/// Typed failure modes of the analysis layer. `kind()` strings are part
/// of the fuzz-harness stability pin (`tests/fuzz_lite.rs`) — extend the
/// enum freely, but never rename an existing kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AnalysisError {
    /// `BENCH_kernels.json` is not valid JSON.
    BenchParse(String),
    /// The bench file parsed but is missing/mistyping a required field.
    BenchSchema(String),
    /// The stored optrace artifact failed to decode.
    TraceDecode(String),
    /// The trace decoded but holds zero rows — nothing to report on.
    EmptyTrace,
}

impl AnalysisError {
    /// Stable machine-readable kind tag (pinned by `tests/fuzz_lite.rs`).
    pub fn kind(&self) -> &'static str {
        match self {
            AnalysisError::BenchParse(_) => "bench_parse",
            AnalysisError::BenchSchema(_) => "bench_schema",
            AnalysisError::TraceDecode(_) => "trace_decode",
            AnalysisError::EmptyTrace => "empty_trace",
        }
    }
}

impl fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AnalysisError::BenchParse(e) => write!(f, "bench file is not valid JSON: {e}"),
            AnalysisError::BenchSchema(e) => write!(f, "bench file schema: {e}"),
            AnalysisError::TraceDecode(e) => write!(f, "optrace artifact: {e}"),
            AnalysisError::EmptyTrace => write!(f, "trace holds zero op rows"),
        }
    }
}

impl std::error::Error for AnalysisError {}

/// Best measured GFLOP/s per kernel family, extracted from
/// `BENCH_kernels.json`. Dense ops have no bench rows (the bench mirrors
/// the conv kernels only), so their peak is `None` and the report prints
/// `-` in the roofline column instead of inventing a denominator.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchPeaks {
    rows: Vec<(String, f64)>,
}

impl BenchPeaks {
    /// The bench kernel-name prefix an op's measurements live under.
    fn prefix(op: TracedOp) -> Option<&'static str> {
        match op {
            TracedOp::ConvFwd => Some("conv2d_fwd_"),
            TracedOp::ConvBwdW => Some("conv2d_bwd_w_"),
            TracedOp::ConvBwdX => Some("conv2d_bwd_x_"),
            _ => None,
        }
    }

    /// Best measured GFLOP/s across every benched (shape, variant) for
    /// this op's kernel family, or `None` if the family was never benched.
    pub fn peak_gflops(&self, op: TracedOp) -> Option<f64> {
        let prefix = Self::prefix(op)?;
        self.rows
            .iter()
            .filter(|(kernel, _)| kernel.starts_with(prefix))
            .map(|(_, gflops)| *gflops)
            .fold(None, |best, g| Some(best.map_or(g, |b: f64| b.max(g))))
    }
}

/// Parse `BENCH_kernels.json` down to the per-kernel peak table.
///
/// Strict about what it reads (`kernels` must be an array of objects with
/// a string `kernel` and a numeric `variants` map) and silent about the
/// rest — extra top-level fields are the bench's business, not ours.
pub fn parse_bench_kernels(text: &str) -> Result<BenchPeaks, AnalysisError> {
    let json = Json::parse(text).map_err(AnalysisError::BenchParse)?;
    let kernels = json.arr_field("kernels").map_err(AnalysisError::BenchSchema)?;
    let mut rows = Vec::new();
    for (i, row) in kernels.iter().enumerate() {
        let kernel = row
            .str_field("kernel")
            .map_err(|e| AnalysisError::BenchSchema(format!("kernels[{i}]: {e}")))?;
        let variants = row
            .field("variants")
            .map_err(|e| AnalysisError::BenchSchema(format!("kernels[{i}]: {e}")))?
            .as_obj()
            .ok_or_else(|| {
                AnalysisError::BenchSchema(format!("kernels[{i}]: \"variants\" is not an object"))
            })?;
        for (isa, v) in variants {
            let gflops = v.as_f64().ok_or_else(|| {
                AnalysisError::BenchSchema(format!(
                    "kernels[{i}].variants.{isa} is not a number"
                ))
            })?;
            if !gflops.is_finite() || gflops < 0.0 {
                return Err(AnalysisError::BenchSchema(format!(
                    "kernels[{i}].variants.{isa} is not a finite non-negative number"
                )));
            }
            rows.push((kernel.to_string(), gflops));
        }
    }
    Ok(BenchPeaks { rows })
}

/// One rendered cost line: an aggregate plus its derived rates.
#[derive(Debug, Clone, PartialEq)]
pub struct CostRow {
    /// The underlying trace aggregate (op, layer, variant, counters).
    pub agg: OpAggregate,
    /// Share of the report's total wall time, in percent.
    pub time_pct: f64,
    /// Achieved GFLOP/s (`flops / wall_ns`); `0.0` when wall is zero
    /// (e.g. a normalized trace).
    pub gflops: f64,
    /// Achieved GB/s over `4 * (elems_read + elems_written)` bytes.
    pub gbs: f64,
    /// `gflops / peak` against the best benched variant of this op's
    /// kernel family; `None` when the family has no bench rows.
    pub roofline: Option<f64>,
}

/// The full cost report: labeled trace rows, sorted by wall time
/// descending (ties keep the trace's deterministic insertion order).
#[derive(Debug, Clone, PartialEq)]
pub struct CostReport {
    pub model: String,
    pub workload: String,
    pub threads: u32,
    pub total_wall_ns: u64,
    pub rows: Vec<CostRow>,
}

/// Derive the cost report from a decoded trace and the bench peaks.
///
/// Errors with [`AnalysisError::EmptyTrace`] on a rowless trace — an
/// armed profiler that never saw a dispatch is a usage error worth a
/// loud message, not an empty table.
pub fn cost_report(report: &OpTraceReport, peaks: &BenchPeaks) -> Result<CostReport, AnalysisError> {
    if report.rows.is_empty() {
        return Err(AnalysisError::EmptyTrace);
    }
    let total = report.total_wall_ns();
    let mut rows: Vec<CostRow> = report
        .rows
        .iter()
        .map(|agg| {
            let ns = agg.wall_ns as f64;
            let gflops = if agg.wall_ns == 0 { 0.0 } else { agg.flops as f64 / ns };
            let bytes = 4.0 * (agg.elems_read + agg.elems_written) as f64;
            let gbs = if agg.wall_ns == 0 { 0.0 } else { bytes / ns };
            let time_pct =
                if total == 0 { 0.0 } else { 100.0 * agg.wall_ns as f64 / total as f64 };
            let roofline = peaks
                .peak_gflops(agg.op)
                .filter(|p| *p > 0.0)
                .map(|p| gflops / p);
            CostRow { agg: agg.clone(), time_pct, gflops, gbs, roofline }
        })
        .collect();
    // stable sort: equal wall times keep first-recorded-first order, so
    // the report is deterministic even on a wall-normalized trace
    rows.sort_by(|a, b| b.agg.wall_ns.cmp(&a.agg.wall_ns));
    Ok(CostReport {
        model: report.model.clone(),
        workload: report.workload.clone(),
        threads: report.threads,
        total_wall_ns: total,
        rows,
    })
}

/// Render the cost report as a fixed-width text table (stdout surface of
/// `fitq trace-report`).
pub fn render_text(report: &CostReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "op trace: model={} workload={} threads={} total={:.3} ms\n",
        report.model,
        report.workload,
        report.threads,
        report.total_wall_ns as f64 / 1e6
    ));
    out.push_str(&format!(
        "{:<18} {:<6} {:<14} {:<22} {:>8} {:>7} {:>10} {:>8} {:>8} {:>9}\n",
        "op", "layer", "variant", "shape", "calls", "time%", "ms", "GFLOP/s", "GB/s", "roofline"
    ));
    for row in &report.rows {
        let roofline = match row.roofline {
            Some(r) => format!("{r:.2}"),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<18} {:<6} {:<14} {:<22} {:>8} {:>6.1}% {:>10.3} {:>8.2} {:>8.2} {:>9}\n",
            row.agg.op.name(),
            row.agg.layer,
            row.agg.variant_name(),
            row.agg.shape,
            row.agg.calls,
            row.time_pct,
            row.agg.wall_ns as f64 / 1e6,
            row.gflops,
            row.gbs,
            roofline,
        ));
    }
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render the cost report as the pinned machine-readable JSON shape
/// checked by `scripts/check_bench_schema.py` (`TRACE_report.json`).
pub fn render_json(report: &CostReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"report\": \"op_trace\",\n");
    out.push_str(&format!("  \"model\": {},\n", json_str(&report.model)));
    out.push_str(&format!("  \"workload\": {},\n", json_str(&report.workload)));
    out.push_str(&format!("  \"threads\": {},\n", report.threads));
    out.push_str(&format!("  \"total_ms\": {:.6},\n", report.total_wall_ns as f64 / 1e6));
    out.push_str("  \"rows\": [\n");
    for (i, row) in report.rows.iter().enumerate() {
        let roofline = match row.roofline {
            Some(r) => format!("{r:.6}"),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"op\": {}, \"layer\": {}, \"variant\": {}, \"shape\": {}, \
             \"calls\": {}, \"time_pct\": {:.6}, \"ms\": {:.6}, \"gflops\": {:.6}, \
             \"gbs\": {:.6}, \"roofline\": {}}}{}\n",
            json_str(row.agg.op.name()),
            json_str(&row.agg.layer),
            json_str(&row.agg.variant_name()),
            json_str(&row.agg.shape),
            row.agg.calls,
            row.time_pct,
            row.agg.wall_ns as f64 / 1e6,
            row.gflops,
            row.gbs,
            roofline,
            if i + 1 < report.rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Sanity-check the tuner's width-class routing against a real workload's
/// traced shape distribution — the optional trailer on `fitq tune`.
///
/// For every traced row of a tuned op, look up what the route table would
/// pick for that width today and report agreement or drift. A mismatch is
/// not an error (the trace may predate a re-tune; the table may have been
/// measured under a different thread budget) — it is exactly the signal
/// the trailer exists to surface.
pub fn routing_trailer(report: &OpTraceReport, table: &RouteTable) -> Vec<String> {
    let mut lines = Vec::new();
    for agg in &report.rows {
        let Some((isa, lowering)) = agg.variant else { continue };
        let Some(op) = TunedOp::from_u8(agg.op as u8) else { continue };
        let expect = table.choice(op, agg.width as usize);
        let traced = format!("{}/{}", lowering.name(), isa.name());
        let routed = format!("{}/{}", expect.lowering.name(), expect.isa.name());
        let verdict = if traced == routed { "ok" } else { "MISMATCH" };
        lines.push(format!(
            "{} w{} ({}): traced {traced}, table {routed} [{verdict}]",
            op.name(),
            agg.width,
            agg.shape,
        ));
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::simd::Isa;
    use crate::native::tune::Lowering;

    fn agg(
        op: TracedOp,
        layer: &str,
        variant: Option<(Isa, Lowering)>,
        wall_ns: u64,
        flops: u64,
    ) -> OpAggregate {
        OpAggregate {
            op,
            layer: layer.to_string(),
            variant,
            width: 16,
            shape: "b32 16x16 16->32".to_string(),
            calls: 10,
            elems_read: 1_000,
            elems_written: 500,
            flops,
            wall_ns,
        }
    }

    const BENCH: &str = r#"{
        "kernels": [
            {"kernel": "conv2d_fwd_direct", "shape": "s", "variants": {"scalar": 7.5, "avx2": 9.2}},
            {"kernel": "conv2d_fwd_im2col", "shape": "s", "variants": {"scalar": 6.3, "avx2": 13.9}},
            {"kernel": "conv2d_bwd_x_gemm", "shape": "s", "variants": {"avx2": 15.7}},
            {"kernel": "im2col3x3", "shape": "s", "variants": {"scalar": 0.7}}
        ]
    }"#;

    #[test]
    fn error_kinds_are_stable() {
        // these strings are pinned by tests/fuzz_lite.rs — renaming one
        // breaks the fuzz harness's error-kind stability contract
        assert_eq!(AnalysisError::BenchParse(String::new()).kind(), "bench_parse");
        assert_eq!(AnalysisError::BenchSchema(String::new()).kind(), "bench_schema");
        assert_eq!(AnalysisError::TraceDecode(String::new()).kind(), "trace_decode");
        assert_eq!(AnalysisError::EmptyTrace.kind(), "empty_trace");
    }

    #[test]
    fn peaks_take_the_family_max_across_kernels_and_variants() {
        let peaks = parse_bench_kernels(BENCH).unwrap();
        // conv_fwd family spans direct and im2col rows; max is im2col/avx2
        assert_eq!(peaks.peak_gflops(TracedOp::ConvFwd), Some(13.9));
        assert_eq!(peaks.peak_gflops(TracedOp::ConvBwdX), Some(15.7));
        // no bench rows for that family at all
        assert_eq!(peaks.peak_gflops(TracedOp::ConvBwdW), None);
        // dense and element-wise ops are never benched
        assert_eq!(peaks.peak_gflops(TracedOp::DenseFwd), None);
        assert_eq!(peaks.peak_gflops(TracedOp::Relu), None);
    }

    #[test]
    fn bench_parse_failures_are_typed() {
        assert_eq!(parse_bench_kernels("not json").unwrap_err().kind(), "bench_parse");
        assert_eq!(parse_bench_kernels("{}").unwrap_err().kind(), "bench_schema");
        assert_eq!(
            parse_bench_kernels(r#"{"kernels": [{"kernel": 3}]}"#).unwrap_err().kind(),
            "bench_schema"
        );
        assert_eq!(
            parse_bench_kernels(r#"{"kernels": [{"kernel": "k", "variants": {"scalar": "x"}}]}"#)
                .unwrap_err()
                .kind(),
            "bench_schema"
        );
    }

    #[test]
    fn cost_report_sorts_by_wall_and_derives_rates() {
        let peaks = parse_bench_kernels(BENCH).unwrap();
        let trace = OpTraceReport {
            model: "cnn_mnist".into(),
            workload: "train_epoch".into(),
            threads: 1,
            rows: vec![
                agg(TracedOp::Relu, "conv0", None, 1_000, 1_500),
                agg(
                    TracedOp::ConvFwd,
                    "conv0",
                    Some((Isa::Avx2, Lowering::Direct)),
                    3_000,
                    27_900,
                ),
            ],
        };
        let report = cost_report(&trace, &peaks).unwrap();
        assert_eq!(report.total_wall_ns, 4_000);
        // conv row (larger wall) sorts first
        assert_eq!(report.rows[0].agg.op, TracedOp::ConvFwd);
        assert!((report.rows[0].time_pct - 75.0).abs() < 1e-9);
        // 27_900 flops / 3_000 ns = 9.3 GFLOP/s; peak 13.9 → roofline ≈ 0.669
        assert!((report.rows[0].gflops - 9.3).abs() < 1e-9);
        let roofline = report.rows[0].roofline.unwrap();
        assert!((roofline - 9.3 / 13.9).abs() < 1e-9);
        // 1_500 f32 elems = 6_000 bytes over 3_000 ns = 2 GB/s
        assert!((report.rows[0].gbs - 2.0).abs() < 1e-9);
        // relu has no bench family → no roofline denominator
        assert_eq!(report.rows[1].roofline, None);
    }

    #[test]
    fn empty_and_normalized_traces_are_handled() {
        let peaks = parse_bench_kernels(BENCH).unwrap();
        let empty = OpTraceReport {
            model: String::new(),
            workload: String::new(),
            threads: 1,
            rows: vec![],
        };
        assert_eq!(cost_report(&empty, &peaks).unwrap_err(), AnalysisError::EmptyTrace);

        // a wall-normalized trace (codec byte-comparison form) must not
        // divide by zero anywhere
        let trace = OpTraceReport {
            model: "m".into(),
            workload: "w".into(),
            threads: 4,
            rows: vec![agg(TracedOp::ConvFwd, "conv0", None, 0, 100)],
        };
        let report = cost_report(&trace, &peaks).unwrap();
        assert_eq!(report.total_wall_ns, 0);
        assert_eq!(report.rows[0].time_pct, 0.0);
        assert_eq!(report.rows[0].gflops, 0.0);
        assert_eq!(report.rows[0].gbs, 0.0);
    }

    #[test]
    fn renders_are_deterministic_and_json_is_parseable() {
        let peaks = parse_bench_kernels(BENCH).unwrap();
        let trace = OpTraceReport {
            model: "cnn_mnist".into(),
            workload: "train_epoch".into(),
            threads: 2,
            rows: vec![agg(
                TracedOp::ConvFwd,
                "conv0",
                Some((Isa::Sse2, Lowering::Im2col)),
                2_000,
                10_000,
            )],
        };
        let report = cost_report(&trace, &peaks).unwrap();
        let text = render_text(&report);
        assert!(text.contains("conv_fwd"));
        assert!(text.contains("im2col/sse2"));
        assert!(text.contains("GFLOP/s"));
        assert_eq!(text, render_text(&report), "render must be pure");

        let json = render_json(&report);
        let parsed = Json::parse(&json).expect("render_json must emit valid JSON");
        assert_eq!(parsed.str_field("report").unwrap(), "op_trace");
        assert_eq!(parsed.str_field("model").unwrap(), "cnn_mnist");
        assert_eq!(parsed.usize_field("threads").unwrap(), 2);
        let rows = parsed.arr_field("rows").unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].str_field("op").unwrap(), "conv_fwd");
        // conv_fwd is bench-peaked, so roofline must be a number here
        assert!(rows[0].field("roofline").unwrap().as_f64().is_some());
    }

    #[test]
    fn routing_trailer_flags_drift_only() {
        let table = RouteTable::static_for(Isa::Scalar);
        let trace = OpTraceReport {
            model: "m".into(),
            workload: "w".into(),
            threads: 1,
            rows: vec![
                // scalar static table routes everything to direct/scalar
                agg(TracedOp::ConvFwd, "conv0", Some((Isa::Scalar, Lowering::Direct)), 1, 1),
                agg(TracedOp::ConvFwd, "conv1", Some((Isa::Avx2, Lowering::Im2col)), 1, 1),
                // untuned ops never appear in the trailer
                agg(TracedOp::Relu, "conv0", None, 1, 1),
            ],
        };
        let lines = routing_trailer(&trace, &table);
        assert_eq!(lines.len(), 2);
        assert!(lines[0].ends_with("[ok]"), "{}", lines[0]);
        assert!(lines[1].ends_with("[MISMATCH]"), "{}", lines[1]);
    }
}
