//! CSV / markdown report writers — every experiment drops its raw series
//! as CSV plus a human-readable markdown summary under results/.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub struct Reporter {
    dir: PathBuf,
}

impl Reporter {
    pub fn new(dir: impl AsRef<Path>) -> Result<Reporter> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating results dir {}", dir.display()))?;
        Ok(Reporter { dir })
    }

    /// Reporter over the shared results root (`$FITQ_RESULTS`, default
    /// `results/`) — the same resolution the pipeline cache uses, so
    /// reports and cached stages always land under one tree.
    pub fn from_env() -> Result<Reporter> {
        Reporter::new(super::pipeline::stages::results_root_from_env())
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Write a CSV with a header row and f64 cells (NaN -> empty).
    pub fn csv(&self, name: &str, header: &[&str], rows: &[Vec<f64>]) -> Result<PathBuf> {
        let path = self.path(name);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", header.join(","))?;
        for row in rows {
            let cells: Vec<String> = row
                .iter()
                .map(|v| if v.is_finite() { format!("{v}") } else { String::new() })
                .collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        Ok(path)
    }

    /// Write a CSV with string cells.
    pub fn csv_str(&self, name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<PathBuf> {
        let path = self.path(name);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", header.join(","))?;
        for row in rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }

    /// Write/overwrite a markdown summary.
    pub fn markdown(&self, name: &str, content: &str) -> Result<PathBuf> {
        let path = self.path(name);
        std::fs::write(&path, content)?;
        Ok(path)
    }
}

/// Render a markdown table.
pub fn md_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&format!("| {} |\n", header.join(" | ")));
    out.push_str(&format!("|{}\n", "---|".repeat(header.len())));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

pub fn fmt(v: Option<f64>, digits: usize) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x:.digits$}"),
        _ => "-".to_string(),
    }
}

/// Render a study's failure list as a markdown section — empty string for
/// a clean run, so reports can append it unconditionally.
pub fn degraded_section(scope: &str, failures: &[super::evaluator::ConfigFailure]) -> String {
    if failures.is_empty() {
        return String::new();
    }
    let rows: Vec<Vec<String>> = failures
        .iter()
        .map(|f| {
            vec![
                f.index.to_string(),
                f.label.clone(),
                if f.panicked { "panic" } else { "error" }.to_string(),
                f.error.clone(),
            ]
        })
        .collect();
    format!(
        "\n## Degraded configurations — {scope} ({} failed; correlations cover the survivors)\n\n{}",
        failures.len(),
        md_table(&["config", "bits", "kind", "cause"], &rows)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_markdown_roundtrip() {
        let dir = std::env::temp_dir().join("fitq_report_test");
        let r = Reporter::new(&dir).unwrap();
        let p = r
            .csv("t.csv", &["a", "b"], &[vec![1.0, 2.0], vec![f64::NAN, 3.0]])
            .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n,3\n");
        let md = md_table(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("| x | y |"));
        assert!(md.contains("| 1 | 2 |"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fmt_handles_missing() {
        assert_eq!(fmt(Some(0.8567), 2), "0.86");
        assert_eq!(fmt(None, 2), "-");
        assert_eq!(fmt(Some(f64::NAN), 2), "-");
    }

    #[test]
    fn degraded_section_empty_for_clean_run() {
        assert_eq!(degraded_section("exp A", &[]), "");
    }

    #[test]
    fn degraded_section_lists_each_failure() {
        use crate::coordinator::evaluator::ConfigFailure;
        let fs = vec![
            ConfigFailure {
                index: 3,
                label: "w[8,4] a[3]".into(),
                panicked: true,
                error: "boom".into(),
            },
            ConfigFailure {
                index: 7,
                label: "w[2,2] a[8]".into(),
                panicked: false,
                error: "io".into(),
            },
        ];
        let md = degraded_section("experiment B", &fs);
        assert!(md.contains("experiment B (2 failed"));
        assert!(md.contains("| 3 | w[8,4] a[3] | panic | boom |"));
        assert!(md.contains("| 7 | w[2,2] a[8] | error | io |"));
    }
}
