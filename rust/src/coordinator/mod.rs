//! L3 coordinator: the runtime orchestration of the FIT methodology.
//!
//! - `state` / `trainer`: owned flat model state; FP + QAT training and
//!   evaluation drivers over the AOT artifacts.
//! - `traces`: the EF / Hutchinson trace-estimation engine with the
//!   paper's fixed-tolerance early stopping.
//! - `sensitivity`: one-shot gathering of every metric's inputs.
//! - `evaluator`: the train-hundreds-of-configs rank-correlation pipeline.
//! - `parallel`: the scoped-thread worker pool the evaluator and trace
//!   engine fan out on, plus the deterministic per-job seed derivation.
//! - `pipeline`: the stage-graph experiment pipeline — content-addressed
//!   artifact cache, typed `train_fp → traces/sensitivity → study` stages,
//!   and the declarative experiment registry with cross-experiment
//!   stage-deduping scheduling.
//! - `search` / `allocate`: Pareto front + greedy and exact budgeted bit
//!   allocation, all table-driven over the shared `metrics::FitTable`.
//! - `service`: the long-running search service behind `fitq serve` —
//!   resident `FitTable` LRU, worker-sharded scoring, streamed
//!   incremental Pareto fronts over a line-JSON protocol.
//! - `experiments`: one module per paper table/figure.
//! - `report`: CSV/markdown emission under results/.
//! - `analysis`: read-only cost reports over native op traces
//!   (`fitq trace-report`) — per-(op, layer, variant) time/GFLOP/s/GB/s
//!   tables rooflined against the measured peaks in `BENCH_kernels.json`.

pub mod allocate;
pub mod analysis;
pub mod evaluator;
pub mod experiments;
pub mod parallel;
pub mod pipeline;
pub mod report;
pub mod search;
pub mod sensitivity;
pub mod service;
pub mod state;
pub mod traces;
pub mod trainer;

pub use allocate::{exact_allocate, exact_allocate_table};
pub use evaluator::{run_study, ConfigFailure, StudyOptions, StudyResult};
pub use parallel::{
    derive_seed, run_pool, run_pool_fallible, run_pool_streaming, run_serial_fallible,
    run_static_caught, JobError,
};
pub use pipeline::{FaultPlan, Pipeline, StageCounters, StageRequest};
pub use search::{
    greedy_allocate, greedy_allocate_naive, greedy_allocate_table, pareto_front,
    pareto_front_scores, pareto_front_scores_naive, score, FrontPoint, ParetoAccumulator,
    ScoredConfig,
};
pub use service::{ServiceConfig, ServiceCore, ServiceWorker};
pub use sensitivity::{gather, SensitivityReport};
pub use state::ModelState;
pub use traces::{relative_speedup, Estimator, TraceEngine, TraceOptions, TraceResult};
pub use trainer::{dataset_for, ActRanges, EvalResult, Trainer};
