//! Training, QAT and evaluation drivers over the PJRT artifacts.
//!
//! The trainer owns nothing but borrows the `Runtime` and a `Dataset`; all
//! state flows through `ModelState`. Data for the scanned epochs is
//! generated from the deterministic train stream (a cursor into the
//! index space), so any run replays exactly from (model seed, data seed).

use anyhow::{bail, Result};

use super::state::ModelState;
use crate::data::{Dataset, EpochBatch, EvalSet, SynthClass, SynthSeg};
use crate::quant::BitConfig;
use crate::runtime::{Arg, Runtime, Task};

/// Calibrated activation ranges (QAT + metric inputs).
#[derive(Debug, Clone)]
pub struct ActRanges {
    pub lo: Vec<f32>,
    pub hi: Vec<f32>,
}

/// Evaluation outcome; `score` is accuracy (classification) or mIoU
/// (segmentation) — the "final performance" axis of every paper figure.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub mean_loss: f64,
    pub score: f64,
    pub n: usize,
}

/// The canonical dataset for a model, derived from its manifest.
pub fn dataset_for(rt: &Runtime, model: &str, seed: u64) -> Result<Box<dyn Dataset>> {
    let m = rt.model(model)?;
    let shape = (m.input_shape[0], m.input_shape[1], m.input_shape[2]);
    Ok(match m.task {
        // 3-channel 32x32 inputs carry ~12x the signal redundancy of the
        // 1-channel task, so they get more pixel noise. Note the narrow
        // usable band (EXPERIMENTS.md Table 2): at 32x32x3 the template
        // task saturates (FP acc ~1.0, degenerate correlation spread) for
        // noise <= 2.2 yet the BN-free variant's optimization collapses by
        // noise 2.6 — the paper's CIFAR-10 sits in a regime this synthetic
        // substitute cannot reach; experiments C/D (16x16x1) and the
        // U-Net study are where the rank-correlation methodology
        // reproduces.
        Task::Classify => {
            let noise = if shape.2 >= 3 { 2.2 } else { 1.5 };
            Box::new(SynthClass::new(shape, m.n_classes, noise, seed))
        }
        Task::Segment => Box::new(SynthSeg::new(shape, m.n_classes, 0.6, seed)),
    })
}

pub struct Trainer<'a> {
    rt: &'a Runtime,
    ds: &'a dyn Dataset,
    cursor: u64,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, ds: &'a dyn Dataset) -> Self {
        Trainer { rt, ds, cursor: 0 }
    }

    /// Trainer whose train-stream cursor starts at `cursor` instead of 0.
    ///
    /// Parallel studies give every configuration its own trainer with a
    /// cursor derived from `(study seed, config index)` (see
    /// `coordinator::parallel::derive_seed`), so the data each
    /// configuration consumes is independent of sweep order and job count.
    pub fn with_cursor(rt: &'a Runtime, ds: &'a dyn Dataset, cursor: u64) -> Self {
        Trainer { rt, ds, cursor }
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }

    /// Run `n_epochs` scanned full-precision epochs (train_k steps each);
    /// returns the per-epoch mean losses.
    pub fn train(&mut self, state: &mut ModelState, n_epochs: usize) -> Result<Vec<f64>> {
        self.run_epochs(state, n_epochs, None, None)
    }

    /// QAT fine-tuning with a fixed MPQ config and calibrated act ranges.
    pub fn qat_train(
        &mut self,
        state: &mut ModelState,
        cfg: &BitConfig,
        act: &ActRanges,
        n_epochs: usize,
    ) -> Result<Vec<f64>> {
        self.run_epochs(state, n_epochs, Some(cfg), Some(act))
    }

    fn run_epochs(
        &mut self,
        state: &mut ModelState,
        n_epochs: usize,
        cfg: Option<&BitConfig>,
        act: Option<&ActRanges>,
    ) -> Result<Vec<f64>> {
        let m = self.rt.model(&state.model)?.clone();
        let entry = if cfg.is_some() { "qat_epoch" } else { "train_epoch" };
        let exe = self.rt.load(&state.model, entry)?;
        let (bits_w, bits_a) = match cfg {
            Some(c) => {
                if c.bits_w.len() != m.n_weight_blocks() || c.bits_a.len() != m.n_act_blocks() {
                    bail!("bit config shape does not match model {}", state.model);
                }
                (c.bits_w_f32(), c.bits_a_f32())
            }
            None => (vec![], vec![]),
        };
        let mut losses = Vec::with_capacity(n_epochs);
        for _ in 0..n_epochs {
            let (eb, next) = EpochBatch::generate(self.ds, m.train_k, m.train_b, self.cursor);
            self.cursor = next;
            let mut args = vec![
                Arg::F32(&state.params),
                Arg::F32(&state.m),
                Arg::F32(&state.v),
                Arg::F32Scalar(state.step),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ];
            if cfg.is_some() {
                let a = act.expect("QAT requires activation ranges");
                args.push(Arg::F32(&bits_w));
                args.push(Arg::F32(&bits_a));
                args.push(Arg::F32(&a.lo));
                args.push(Arg::F32(&a.hi));
            }
            let out = exe.run(&args)?;
            state.params.copy_from_slice(out.f32("params")?);
            state.m.copy_from_slice(out.f32("m")?);
            state.v.copy_from_slice(out.f32("v")?);
            state.step = out.scalar("step")?;
            losses.push(out.scalar("loss")? as f64);
        }
        Ok(losses)
    }

    /// Full-precision evaluation over a materialized test set.
    pub fn evaluate(&self, state: &ModelState, ev: &EvalSet) -> Result<EvalResult> {
        self.eval_impl(state, ev, None, None)
    }

    /// Quantized-model evaluation.
    pub fn evaluate_q(
        &self,
        state: &ModelState,
        ev: &EvalSet,
        cfg: &BitConfig,
        act: &ActRanges,
    ) -> Result<EvalResult> {
        self.eval_impl(state, ev, Some(cfg), Some(act))
    }

    fn eval_impl(
        &self,
        state: &ModelState,
        ev: &EvalSet,
        cfg: Option<&BitConfig>,
        act: Option<&ActRanges>,
    ) -> Result<EvalResult> {
        let m = self.rt.model(&state.model)?.clone();
        let entry = if cfg.is_some() { "qat_eval" } else { "eval" };
        let exe = self.rt.load(&state.model, entry)?;
        let (bits_w, bits_a) = match cfg {
            Some(c) => (c.bits_w_f32(), c.bits_a_f32()),
            None => (vec![], vec![]),
        };

        let mut loss_sum = 0.0f64;
        let mut n_total = 0usize;
        // classification: correct counts; segmentation: per-class I/U sums
        let mut correct = 0.0f64;
        let mut inter = vec![0.0f64; m.n_classes];
        let mut union = vec![0.0f64; m.n_classes];

        for batch in ev.batches(m.eval_b) {
            let mut args = vec![
                Arg::F32(&state.params),
                Arg::F32(&batch.x),
                Arg::I32(&batch.y),
                Arg::F32(&batch.mask),
            ];
            if cfg.is_some() {
                let a = act.expect("quantized eval requires activation ranges");
                args.push(Arg::F32(&bits_w));
                args.push(Arg::F32(&bits_a));
                args.push(Arg::F32(&a.lo));
                args.push(Arg::F32(&a.hi));
            }
            let out = exe.run(&args)?;
            loss_sum += out.scalar("loss_sum")? as f64;
            n_total += batch.n_real;
            match m.task {
                Task::Classify => correct += out.scalar("correct")? as f64,
                Task::Segment => {
                    for (acc, x) in inter.iter_mut().zip(out.f32("inter")?) {
                        *acc += *x as f64;
                    }
                    for (acc, x) in union.iter_mut().zip(out.f32("union")?) {
                        *acc += *x as f64;
                    }
                }
            }
        }
        let score = match m.task {
            Task::Classify => correct / n_total as f64,
            Task::Segment => {
                // mIoU over classes present in either prediction or truth
                let mut sum = 0.0;
                let mut cnt = 0usize;
                for c in 0..m.n_classes {
                    if union[c] > 0.0 {
                        sum += inter[c] / union[c];
                        cnt += 1;
                    }
                }
                if cnt == 0 { 0.0 } else { sum / cnt as f64 }
            }
        };
        Ok(EvalResult { mean_loss: loss_sum / n_total as f64, score, n: n_total })
    }

    /// Min-max weight ranges per quantizable block.
    pub fn param_ranges(&self, state: &ModelState) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.rt.load(&state.model, "param_ranges")?;
        let out = exe.run(&[Arg::F32(&state.params)])?;
        Ok((out.f32("lo")?.to_vec(), out.f32("hi")?.to_vec()))
    }

    /// Calibrate activation ranges on the first `calib_b` test images
    /// (paper Appendix A: ranges fixed from the FP model).
    pub fn calibrate(&self, state: &ModelState, ev: &EvalSet) -> Result<ActRanges> {
        let m = self.rt.model(&state.model)?.clone();
        let exe = self.rt.load(&state.model, "act_ranges")?;
        let x = ev.calibration(m.calib_b);
        let out = exe.run(&[Arg::F32(&state.params), Arg::F32(&x)])?;
        Ok(ActRanges { lo: out.f32("lo")?.to_vec(), hi: out.f32("hi")?.to_vec() })
    }

    /// Mean |gamma| per weight block (None where the layer has no BN) —
    /// the BN baseline's sensitivity signal, read off the owned buffer.
    pub fn bn_gammas(&self, state: &ModelState) -> Result<Vec<Option<f64>>> {
        let m = self.rt.model(&state.model)?;
        Ok(m.bn_gamma_views()
            .iter()
            .map(|t| {
                t.as_ref().map(|info| {
                    let slab = &state.params[info.offset..info.offset + info.size];
                    slab.iter().map(|g| g.abs() as f64).sum::<f64>() / info.size as f64
                })
            })
            .collect())
    }
}
