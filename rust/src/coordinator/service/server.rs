//! The TCP face of the search service: thread-per-connection serving
//! over `std::net`, plus the line-oriented client used by `fitq query`
//! and the smoke scripts.
//!
//! Framing is one JSON object per `\n`-terminated line, bounded at
//! [`MAX_LINE`] bytes. Blank lines are skipped (harmless shell framing);
//! everything else either parses or draws a typed error event. The
//! fail-closed split on errors:
//!
//! - **`parse`-kind failures close the connection** — invalid JSON,
//!   invalid UTF-8, or an oversized line means the byte stream can no
//!   longer be trusted to be line-framed, so the server answers once and
//!   hangs up.
//! - **Every other error kind keeps the connection open** — the line was
//!   well-framed JSON, the client merely asked for something invalid
//!   (unknown method, bad schema, unknown study, infeasible budget), and
//!   can try again on the same connection.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::core::ServiceCore;
use super::protocol::{error_line, parse_request, ErrorKind, ProtocolError};
use crate::runtime::Json;

/// Request-line size bound. Generous — a million explicit configs ships
/// comfortably — but finite, so a stray binary stream can't balloon the
/// server's line buffer.
pub const MAX_LINE: usize = 8 << 20;

/// Bind the serving socket. `port` 0 asks the OS for an ephemeral port
/// (the smoke script reads the resolved address from the `listening on`
/// line `fitq serve` prints).
pub fn bind(host: &str, port: u16) -> Result<TcpListener> {
    TcpListener::bind((host, port)).with_context(|| format!("binding {host}:{port}"))
}

/// Accept loop: one detached serving thread per connection, each with
/// its own [`ServiceWorker`](super::core::ServiceWorker) over the shared
/// core. Blocks for the life of the listener.
pub fn serve_on(core: Arc<ServiceCore>, listener: TcpListener) -> Result<()> {
    for stream in listener.incoming() {
        match stream {
            Ok(stream) => {
                let core = core.clone();
                std::thread::Builder::new()
                    .name("fitq-serve".into())
                    .spawn(move || handle_connection(&core, stream))
                    .context("spawning connection thread")?;
            }
            Err(e) => eprintln!("[serve] accept failed: {e}"),
        }
    }
    Ok(())
}

/// Read one `\n`-terminated line into `buf` (cleared first), reading at
/// most `MAX_LINE + 1` bytes so an unframed stream cannot grow the
/// buffer without bound. Returns the bytes read (0 = EOF); a result
/// longer than [`MAX_LINE`] means the bound was hit.
fn read_bounded_line(r: &mut impl BufRead, buf: &mut Vec<u8>) -> std::io::Result<usize> {
    buf.clear();
    r.by_ref().take(MAX_LINE as u64 + 1).read_until(b'\n', buf)
}

fn handle_connection(core: &ServiceCore, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".to_string());
    let reader = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("[serve] {peer}: socket clone failed: {e}");
            return;
        }
    };
    let mut reader = BufReader::new(reader);
    let mut writer = BufWriter::new(stream);
    let mut emit = |line: &str| -> Result<()> {
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        // flush per event: streamed fronts must reach the client as the
        // shards land, not when the buffer happens to fill
        writer.flush()?;
        Ok(())
    };
    let worker = match core.worker() {
        Ok(w) => w,
        Err(e) => {
            let err =
                ProtocolError::new(ErrorKind::Internal, format!("worker init failed: {e:#}"));
            let _ = emit(&error_line(&err));
            eprintln!("[serve] {peer}: worker init failed: {e:#}");
            return;
        }
    };
    let mut buf = Vec::new();
    loop {
        let n = match read_bounded_line(&mut reader, &mut buf) {
            Ok(0) => return, // client closed cleanly
            Ok(n) => n,
            Err(e) => {
                eprintln!("[serve] {peer}: read failed: {e}");
                return;
            }
        };
        if n > MAX_LINE {
            let err = ProtocolError::new(
                ErrorKind::Parse,
                format!("request line exceeds {MAX_LINE} bytes"),
            );
            let _ = emit(&error_line(&err));
            return;
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(s) => s.trim_end_matches(['\n', '\r']),
            Err(_) => {
                let err = ProtocolError::new(ErrorKind::Parse, "request line is not UTF-8");
                let _ = emit(&error_line(&err));
                return;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let req = match parse_request(line) {
            Ok(req) => req,
            Err(e) => {
                if emit(&error_line(&e)).is_err() || e.kind == ErrorKind::Parse {
                    return;
                }
                continue;
            }
        };
        if let Err(e) = core.execute(&worker, &req, &mut emit) {
            // transport failure: the client is gone, nothing left to say
            eprintln!("[serve] {peer}: write failed mid-request: {e:#}");
            return;
        }
    }
}

/// The `"event"` discriminator of a response line, if it parses.
fn event_of(line: &str) -> Option<String> {
    let json = Json::parse(line).ok()?;
    Some(json.str_field("event").ok()?.to_string())
}

/// Line-oriented client: send `requests` down one connection, copy every
/// response line to `out`, and return whether any terminal event was an
/// error — `fitq query`'s exit status, and what lets `check_serve.sh`
/// assert nonzero-exit on a malformed request. Errors out if the server
/// hangs up before answering every request (unless the hangup followed
/// an error event, which is the documented close-on-parse-error path).
pub fn query(addr: &str, requests: &[String], out: &mut dyn Write) -> Result<bool> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let mut writer = BufWriter::new(stream.try_clone().context("cloning socket")?);
    for req in requests {
        writer.write_all(req.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    writer.flush()?;
    // half-close: the server sees EOF once it has drained our requests,
    // so its connection loop (and thus our response stream) terminates
    let _ = writer.get_ref().shutdown(std::net::Shutdown::Write);
    let reader = BufReader::new(stream);
    let mut any_error = false;
    let mut terminals = 0usize;
    for line in reader.lines() {
        let line = line.context("reading response")?;
        writeln!(out, "{line}")?;
        match event_of(&line).as_deref() {
            Some("done") => terminals += 1,
            Some("error") => {
                terminals += 1;
                any_error = true;
            }
            _ => {}
        }
        if terminals == requests.len() {
            break;
        }
    }
    if terminals < requests.len() && !any_error {
        bail!("server closed after {terminals}/{} responses", requests.len());
    }
    Ok(any_error)
}

/// Fetch one `stats` snapshot and return the terminal line (the caller
/// pretty-prints the `result` object).
pub fn fetch_stats(addr: &str) -> Result<String> {
    let mut out = Vec::new();
    let any_error = query(addr, &["{\"method\":\"stats\"}".to_string()], &mut out)?;
    let text = String::from_utf8(out).context("stats response is not UTF-8")?;
    let line = text.lines().last().unwrap_or("").to_string();
    if any_error {
        bail!("stats request failed: {line}");
    }
    Ok(line)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn bounded_reader_frames_and_bounds() {
        let mut r = Cursor::new(b"abc\ndef".to_vec());
        let mut buf = Vec::new();
        assert_eq!(read_bounded_line(&mut r, &mut buf).unwrap(), 4);
        assert_eq!(buf, b"abc\n");
        assert_eq!(read_bounded_line(&mut r, &mut buf).unwrap(), 3);
        assert_eq!(buf, b"def"); // EOF without newline still yields the tail
        assert_eq!(read_bounded_line(&mut r, &mut buf).unwrap(), 0);

        // an unframed blob stops at the bound instead of buffering it all
        let blob = vec![b'x'; MAX_LINE + 100];
        let mut r = Cursor::new(blob);
        let n = read_bounded_line(&mut r, &mut buf).unwrap();
        assert_eq!(n, MAX_LINE + 1, "bound hit is detectable");
    }

    #[test]
    fn event_discriminator_reads_response_lines() {
        assert_eq!(event_of(r#"{"event":"done","method":"ping"}"#).as_deref(), Some("done"));
        assert_eq!(event_of(r#"{"event":"front","shard":0}"#).as_deref(), Some("front"));
        assert_eq!(event_of("not json"), None);
        assert_eq!(event_of(r#"{"no_event":1}"#), None);
    }
}
