//! The config-space search service: `fitq serve`.
//!
//! Scoring a quantization config against a built [`FitTable`] is
//! nanoseconds; building the table — train, trace, gather — is minutes.
//! The one-shot CLI pays that build on every invocation. This module
//! amortizes it: a long-running process keeps tables resident in an LRU
//! keyed by the study's stage digest and serves `score` / `search` /
//! `pareto` requests over a newline-delimited JSON protocol, sharding
//! each request across the `coordinator::parallel` pool and streaming
//! incremental Pareto fronts as shards complete.
//!
//! Layering (each layer is independently testable):
//!
//! - [`protocol`]: the wire format — strict fail-closed request decoding
//!   with typed error kinds, and the response-event encoders.
//! - [`core`]: execution — table residency, shard planning, index-pure
//!   sampling, the streamed dominance merge, per-request metrics. No I/O;
//!   responses leave through an `emit` callback.
//! - [`server`]: the TCP skin — thread-per-connection serving, the
//!   bounded line reader, and the line client behind `fitq query`.
//!
//! `fitq search` routes through the same [`ServiceCore`] with an
//! in-process worker, so the CLI and the server exercise one tested
//! path. Everything is std-only: `std::net` + scoped threads, no
//! external dependencies.
//!
//! [`FitTable`]: crate::metrics::FitTable

pub mod core;
pub mod protocol;
pub mod server;

pub use self::core::{
    plan_shards, sample_indices_into, sampled_config, ServiceConfig, ServiceCore, ServiceWorker,
    StudyTable, SAMPLE_STREAM,
};
pub use protocol::{
    parse_request, Budget, ErrorKind, ProtocolError, Request, RequestMetrics, SearchMode,
    StudySpec, TableResidency,
};
pub use server::{bind, fetch_stats, query, serve_on, MAX_LINE};
