//! Wire protocol of the search service: newline-delimited JSON, strict
//! and fail-closed in the manifest-error style.
//!
//! One request per line, one JSON object per request; responses are one
//! or more event lines (`front` progress events for streamed requests,
//! then exactly one terminal `done` or `error` event). The decoder
//! rejects — with a typed, stable error kind — anything it does not
//! fully understand: unknown methods, unknown or missing fields, wrong
//! types, out-of-range values. There is no lenient mode and no default
//! for a malformed field; a request either parses into a [`Request`]
//! or produces a [`ProtocolError`] naming what was wrong.
//!
//! Requests (see DESIGN.md "Search service" for the full grammar):
//!
//! ```json
//! {"method":"ping"}
//! {"method":"stats"}
//! {"method":"score","study":{...},"configs":[{"w":[8,4],"a":[3]}]}
//! {"method":"search","study":{...},"mode":"random","samples":100000,
//!  "seed":1,"shards":16,"stream":true}
//! {"method":"search","study":{...},"mode":"greedy","budget_ratio":0.15}
//! {"method":"pareto","study":{...},"configs":[...],"stream":true}
//! ```
//!
//! A study is named by its inputs — `{"model":M,"fp_epochs":E,"seed":S}`
//! plus an optional `"trace"` override object — which the service hashes
//! into the same stage digest the pipeline caches under, so "the same
//! study" means the same thing to the protocol, the resident-table LRU,
//! and the artifact store.

use std::collections::BTreeMap;

use crate::coordinator::traces::TraceOptions;
use crate::quant::BitConfig;
use crate::runtime::Json;

/// Largest integer JSON can carry exactly through the f64-backed parser.
const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0; // 2^53

/// Typed, stable failure classes. The `name()` strings are wire format
/// (clients and the smoke script match on them) — pinned by tests, never
/// renamed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The line is not a JSON object (bad JSON, wrong top-level type,
    /// invalid UTF-8, oversized line).
    Parse,
    /// The object shape is wrong: missing/unknown fields, wrong types,
    /// out-of-range values.
    Schema,
    /// Unknown `method` value.
    Method,
    /// The study could not be resolved (unknown model, pipeline failure).
    Study,
    /// A submitted configuration is invalid for the study's table
    /// (wrong block counts, precision outside the candidate set).
    Config,
    /// An infeasible allocation budget (below the all-minimum floor).
    Budget,
    /// Server-side failure unrelated to the request contents.
    Internal,
}

impl ErrorKind {
    pub fn name(&self) -> &'static str {
        match self {
            ErrorKind::Parse => "parse",
            ErrorKind::Schema => "schema",
            ErrorKind::Method => "method",
            ErrorKind::Study => "study",
            ErrorKind::Config => "config",
            ErrorKind::Budget => "budget",
            ErrorKind::Internal => "internal",
        }
    }
}

/// One protocol-level failure: the typed kind plus a human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtocolError {
    pub kind: ErrorKind,
    pub message: String,
}

impl ProtocolError {
    pub fn new(kind: ErrorKind, message: impl Into<String>) -> ProtocolError {
        ProtocolError { kind, message: message.into() }
    }
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind.name(), self.message)
    }
}

impl std::error::Error for ProtocolError {}

fn schema(msg: impl Into<String>) -> ProtocolError {
    ProtocolError::new(ErrorKind::Schema, msg)
}

/// The study a request scores against: exactly the inputs of
/// `stages::sensitivity_key`, so equal specs share one resident table
/// and one cache artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct StudySpec {
    pub model: String,
    pub fp_epochs: usize,
    pub seed: u64,
    pub trace: TraceOptions,
}

/// Allocation budget of a greedy/exact search: absolute bits or a
/// fraction of the model's fp32 size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Budget {
    Bits(u64),
    Ratio(f64),
}

/// Search flavor. `Random` samples the config space index-purely (see
/// `core::sample_indices_into`), which is what makes it shardable.
#[derive(Debug, Clone, PartialEq)]
pub enum SearchMode {
    Random { samples: u64, seed: u64 },
    Greedy(Budget),
    Exact(Budget),
}

/// A fully validated request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    Stats,
    Score {
        study: StudySpec,
        configs: Vec<BitConfig>,
    },
    Search {
        study: StudySpec,
        mode: SearchMode,
        shards: Option<usize>,
        stream: bool,
    },
    Pareto {
        study: StudySpec,
        configs: Vec<BitConfig>,
        shards: Option<usize>,
        stream: bool,
    },
}

impl Request {
    /// The wire name, echoed in the terminal `done` event.
    pub fn method(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Stats => "stats",
            Request::Score { .. } => "score",
            Request::Search { .. } => "search",
            Request::Pareto { .. } => "pareto",
        }
    }
}

/// Reject keys outside the allowed set — the fail-closed half of the
/// manifest-parsing idiom: a typo'd or future field is an error today,
/// never silently ignored.
fn check_keys(
    obj: &BTreeMap<String, Json>,
    allowed: &[&str],
    what: &str,
) -> Result<(), ProtocolError> {
    for key in obj.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(schema(format!("unknown {what} field {key:?}")));
        }
    }
    Ok(())
}

fn u64_field(obj: &Json, key: &str) -> Result<u64, ProtocolError> {
    let v = obj
        .field(key)
        .map_err(schema)?
        .as_f64()
        .ok_or_else(|| schema(format!("field {key:?} must be a number")))?;
    if v < 0.0 || v.fract() != 0.0 || v > MAX_SAFE_INT {
        return Err(schema(format!("field {key:?} must be an integer in [0, 2^53]")));
    }
    Ok(v as u64)
}

fn opt_u64_field(obj: &Json, key: &str, default: u64) -> Result<u64, ProtocolError> {
    if obj.get(key).is_none() {
        return Ok(default);
    }
    u64_field(obj, key)
}

fn bool_field(obj: &Json, key: &str, default: bool) -> Result<bool, ProtocolError> {
    match obj.get(key) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => Err(schema(format!("field {key:?} must be a boolean"))),
    }
}

/// Parse the `"study"` object (strict; `trace` overrides are optional
/// but individually strict, defaulting field-by-field to
/// [`TraceOptions::default`]).
fn parse_study(req: &Json) -> Result<StudySpec, ProtocolError> {
    let study = req.field("study").map_err(schema)?;
    let obj = study.as_obj().ok_or_else(|| schema("\"study\" must be an object"))?;
    check_keys(obj, &["model", "fp_epochs", "seed", "trace"], "study")?;
    let model = study.str_field("model").map_err(schema)?.to_string();
    if model.is_empty() {
        return Err(schema("study model must be non-empty"));
    }
    let fp_epochs = study.usize_field("fp_epochs").map_err(schema)?;
    let seed = u64_field(study, "seed")?;
    let mut trace = TraceOptions::default();
    if let Some(t) = study.get("trace") {
        let tobj = t.as_obj().ok_or_else(|| schema("\"trace\" must be an object"))?;
        check_keys(tobj, &["batch", "tol", "min_iters", "max_iters", "seed"], "trace")?;
        if t.get("batch").is_some() {
            trace.batch = t.usize_field("batch").map_err(schema)?;
            if trace.batch == 0 {
                return Err(schema("trace batch must be >= 1"));
            }
        }
        if let Some(tol) = t.get("tol") {
            trace.tol =
                tol.as_f64().ok_or_else(|| schema("field \"tol\" must be a number"))?;
            if !trace.tol.is_finite() || trace.tol < 0.0 {
                return Err(schema("trace tol must be finite and >= 0"));
            }
        }
        trace.min_iters = opt_u64_field(t, "min_iters", trace.min_iters)?;
        trace.max_iters = opt_u64_field(t, "max_iters", trace.max_iters)?;
        if trace.min_iters == 0 || trace.max_iters < trace.min_iters {
            return Err(schema("trace iters must satisfy 1 <= min_iters <= max_iters"));
        }
        trace.seed = opt_u64_field(t, "seed", trace.seed)?;
    }
    Ok(StudySpec { model, fp_epochs, seed, trace })
}

/// Parse the `"configs"` array: each element a strict
/// `{"w":[bits...],"a":[bits...]}` object. Precision *values* are only
/// type-checked here (u32 range); membership in the study's candidate
/// set is an execution-time [`ErrorKind::Config`] error, because it
/// depends on the table.
fn parse_configs(req: &Json) -> Result<Vec<BitConfig>, ProtocolError> {
    let arr = req.arr_field("configs").map_err(schema)?;
    let bits_list = |cfg: &Json, key: &str, at: usize| -> Result<Vec<u32>, ProtocolError> {
        cfg.arr_field(key)
            .map_err(schema)?
            .iter()
            .map(|v| {
                let n = v
                    .as_f64()
                    .ok_or_else(|| schema(format!("configs[{at}].{key}: not a number")))?;
                if n < 1.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
                    return Err(schema(format!(
                        "configs[{at}].{key}: precisions must be integers >= 1"
                    )));
                }
                Ok(n as u32)
            })
            .collect()
    };
    arr.iter()
        .enumerate()
        .map(|(at, cfg)| {
            let obj = cfg
                .as_obj()
                .ok_or_else(|| schema(format!("configs[{at}] must be an object")))?;
            check_keys(obj, &["w", "a"], "config")?;
            Ok(BitConfig { bits_w: bits_list(cfg, "w", at)?, bits_a: bits_list(cfg, "a", at)? })
        })
        .collect()
}

fn parse_shards(req: &Json) -> Result<Option<usize>, ProtocolError> {
    match req.get("shards") {
        None => Ok(None),
        Some(_) => {
            let n = req.usize_field("shards").map_err(schema)?;
            if n == 0 {
                return Err(schema("shards must be >= 1"));
            }
            Ok(Some(n))
        }
    }
}

/// Exactly one of `budget_bits` / `budget_ratio`, validated.
fn parse_budget(req: &Json) -> Result<Budget, ProtocolError> {
    match (req.get("budget_bits"), req.get("budget_ratio")) {
        (Some(_), Some(_)) => {
            Err(schema("give exactly one of budget_bits / budget_ratio, not both"))
        }
        (Some(_), None) => Ok(Budget::Bits(u64_field(req, "budget_bits")?)),
        (None, Some(r)) => {
            let ratio = r
                .as_f64()
                .ok_or_else(|| schema("field \"budget_ratio\" must be a number"))?;
            if !ratio.is_finite() || ratio <= 0.0 {
                return Err(schema("budget_ratio must be finite and > 0"));
            }
            Ok(Budget::Ratio(ratio))
        }
        (None, None) => Err(schema("greedy/exact search needs budget_bits or budget_ratio")),
    }
}

/// Decode one request line. Every failure is a typed [`ProtocolError`];
/// nothing is defaulted, coerced, or skipped.
pub fn parse_request(line: &str) -> Result<Request, ProtocolError> {
    let json =
        Json::parse(line).map_err(|e| ProtocolError::new(ErrorKind::Parse, e))?;
    let obj = json
        .as_obj()
        .ok_or_else(|| ProtocolError::new(ErrorKind::Parse, "request must be a JSON object"))?;
    let method = json.str_field("method").map_err(schema)?;
    match method {
        "ping" | "stats" => {
            check_keys(obj, &["method"], "request")?;
            Ok(if method == "ping" { Request::Ping } else { Request::Stats })
        }
        "score" => {
            check_keys(obj, &["method", "study", "configs"], "request")?;
            Ok(Request::Score { study: parse_study(&json)?, configs: parse_configs(&json)? })
        }
        "pareto" => {
            check_keys(obj, &["method", "study", "configs", "shards", "stream"], "request")?;
            Ok(Request::Pareto {
                study: parse_study(&json)?,
                configs: parse_configs(&json)?,
                shards: parse_shards(&json)?,
                stream: bool_field(&json, "stream", false)?,
            })
        }
        "search" => {
            check_keys(
                obj,
                &[
                    "method",
                    "study",
                    "mode",
                    "samples",
                    "seed",
                    "shards",
                    "stream",
                    "budget_bits",
                    "budget_ratio",
                ],
                "request",
            )?;
            let study = parse_study(&json)?;
            let mode = json.str_field("mode").map_err(schema)?;
            match mode {
                "random" => {
                    for key in ["budget_bits", "budget_ratio"] {
                        if obj.contains_key(key) {
                            return Err(schema(format!("random search does not take {key:?}")));
                        }
                    }
                    let samples = u64_field(&json, "samples")?;
                    if samples == 0 {
                        return Err(schema("samples must be >= 1"));
                    }
                    Ok(Request::Search {
                        study,
                        mode: SearchMode::Random { samples, seed: opt_u64_field(&json, "seed", 0)? },
                        shards: parse_shards(&json)?,
                        stream: bool_field(&json, "stream", false)?,
                    })
                }
                "greedy" | "exact" => {
                    for key in ["samples", "seed", "shards", "stream"] {
                        if obj.contains_key(key) {
                            return Err(schema(format!(
                                "{mode} search does not take {key:?} (nothing to shard)"
                            )));
                        }
                    }
                    let budget = parse_budget(&json)?;
                    let mode = if mode == "greedy" {
                        SearchMode::Greedy(budget)
                    } else {
                        SearchMode::Exact(budget)
                    };
                    Ok(Request::Search { study, mode, shards: None, stream: false })
                }
                other => Err(schema(format!(
                    "unknown search mode {other:?} (want random, greedy or exact)"
                ))),
            }
        }
        other => Err(ProtocolError::new(
            ErrorKind::Method,
            format!("unknown method {other:?} (want ping, stats, score, search or pareto)"),
        )),
    }
}

// ---------------------------------------------------------------------------
// Response encoding. Hand-rolled like the bench JSON writers: the event
// vocabulary is tiny and the hot path (front points) wants zero
// intermediate structure.

/// Escape a string for a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A finite f64 as a JSON number (Rust's shortest round-trip `Display`,
/// so equal bit patterns always serialize identically); NaN/±∞ — which
/// JSON cannot carry — as `null`. Front points never contain either
/// (the sweep excludes them), so `null` only ever appears in metrics.
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// How the request's study table was obtained — the residency half of
/// the metrics trailer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableResidency {
    /// LRU hit: the table was already resident.
    Warm,
    /// Built this request, sensitivity decoded from a published artifact.
    ColdCached,
    /// Built this request, sensitivity computed through the full
    /// train→trace pipeline (or loaded from a peer mid-lease).
    ColdComputed,
    /// No table involved (ping/stats).
    None,
}

impl TableResidency {
    pub fn name(&self) -> &'static str {
        match self {
            TableResidency::Warm => "warm",
            TableResidency::ColdCached => "cold+cache",
            TableResidency::ColdComputed => "cold+compute",
            TableResidency::None => "none",
        }
    }
}

/// Per-request measurements returned in the terminal event's `metrics`
/// trailer. Wall-clock fields vary run to run; everything under
/// `result` stays bit-identical — tests compare the line up to
/// `,"metrics":`.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub elapsed_ms: f64,
    pub configs_scored: u64,
    pub shards: usize,
    pub jobs: usize,
    pub table: TableResidency,
    /// Requests in flight (this one included) when this one started.
    pub queue_depth: usize,
}

impl RequestMetrics {
    pub fn to_json(&self) -> String {
        let per_sec = if self.configs_scored > 0 && self.elapsed_ms > 0.0 {
            json_num(self.configs_scored as f64 / (self.elapsed_ms / 1e3))
        } else {
            "null".to_string()
        };
        format!(
            "{{\"elapsed_ms\":{},\"configs_scored\":{},\"configs_per_sec\":{},\
             \"shards\":{},\"jobs\":{},\"table\":\"{}\",\"queue_depth\":{}}}",
            json_num(self.elapsed_ms),
            self.configs_scored,
            per_sec,
            self.shards,
            self.jobs,
            self.table.name(),
            self.queue_depth,
        )
    }
}

/// Terminal success event. `result_json` must already be valid JSON.
pub fn done_line(method: &str, result_json: &str, metrics: &RequestMetrics) -> String {
    format!(
        "{{\"event\":\"done\",\"method\":\"{method}\",\"result\":{result_json},\
         \"metrics\":{}}}",
        metrics.to_json()
    )
}

/// Streamed front-progress event: the accumulated front after folding
/// `shards_done` of `shards` shards (`shard` being the one that just
/// landed). Emission order is completion order — nondeterministic under
/// `jobs > 1` — but the *final* front, and therefore the `done` event,
/// is shard- and order-invariant.
pub fn front_line(shard: usize, shards_done: usize, shards: usize, front_json: &str) -> String {
    format!(
        "{{\"event\":\"front\",\"shard\":{shard},\"shards_done\":{shards_done},\
         \"shards\":{shards},\"front\":{front_json}}}"
    )
}

/// Terminal failure event.
pub fn error_line(e: &ProtocolError) -> String {
    format!(
        "{{\"event\":\"error\",\"kind\":\"{}\",\"message\":\"{}\"}}",
        e.kind.name(),
        json_escape(&e.message)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kind_of(line: &str) -> ErrorKind {
        parse_request(line).unwrap_err().kind
    }

    #[test]
    fn minimal_requests_parse() {
        assert_eq!(parse_request(r#"{"method":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(parse_request(r#"{"method":"stats"}"#).unwrap(), Request::Stats);
        let r = parse_request(
            r#"{"method":"search","study":{"model":"cnn_mnist","fp_epochs":1,"seed":0},
               "mode":"random","samples":100}"#,
        )
        .unwrap();
        match r {
            Request::Search {
                study,
                mode: SearchMode::Random { samples: 100, seed: 0 },
                shards: None,
                stream: false,
            } => {
                assert_eq!(study.model, "cnn_mnist");
                assert_eq!(study.trace, TraceOptions::default());
            }
            other => panic!("unexpected parse: {other:?}"),
        }
    }

    #[test]
    fn error_kinds_are_typed_and_pinned() {
        assert_eq!(kind_of("not json"), ErrorKind::Parse);
        assert_eq!(kind_of("[1,2]"), ErrorKind::Parse);
        assert_eq!(kind_of(r#"{"method":"frobnicate"}"#), ErrorKind::Method);
        assert_eq!(kind_of(r#"{"method":"ping","extra":1}"#), ErrorKind::Schema);
        assert_eq!(kind_of(r#"{"method":"score"}"#), ErrorKind::Schema);
        // the wire names are protocol surface
        for (kind, name) in [
            (ErrorKind::Parse, "parse"),
            (ErrorKind::Schema, "schema"),
            (ErrorKind::Method, "method"),
            (ErrorKind::Study, "study"),
            (ErrorKind::Config, "config"),
            (ErrorKind::Budget, "budget"),
            (ErrorKind::Internal, "internal"),
        ] {
            assert_eq!(kind.name(), name);
        }
    }

    #[test]
    fn encoding_helpers_are_json_safe() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        assert_eq!(json_num(1.0), "1");
        assert_eq!(json_num(0.1), "0.1");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        let m = RequestMetrics {
            elapsed_ms: 2.0,
            configs_scored: 1000,
            shards: 4,
            jobs: 2,
            table: TableResidency::Warm,
            queue_depth: 1,
        };
        let line = done_line("search", r#"{"x":1}"#, &m);
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.str_field("event").unwrap(), "done");
        assert_eq!(back.field("metrics").unwrap().usize_field("configs_scored").unwrap(), 1000);
        assert_eq!(
            back.field("metrics").unwrap().str_field("table").unwrap(),
            "warm"
        );
        let err = error_line(&ProtocolError::new(ErrorKind::Budget, "too \"low\""));
        let back = Json::parse(&err).unwrap();
        assert_eq!(back.str_field("kind").unwrap(), "budget");
        assert_eq!(back.str_field("message").unwrap(), "too \"low\"");
    }
}
