//! Request execution: resident tables, sharded scoring, streamed fronts.
//!
//! [`ServiceCore`] is the shared, thread-safe heart of both `fitq serve`
//! and `fitq search` — one instance per process, holding the resident
//! [`FitTable`] LRU, the aggregate [`ServeStats`], and the shared
//! [`StageCounters`]. Each serving thread (or the CLI's single thread)
//! builds one [`ServiceWorker`] — a `Runtime` + `Pipeline`, neither of
//! which is `Send` — and feeds validated [`Request`]s to
//! [`ServiceCore::execute`], which writes response lines through an
//! `emit` callback so the same code path serves TCP connections, the CLI,
//! and in-process tests.
//!
//! # Sharding and determinism
//!
//! A scoring request over `n` configs is split by [`plan_shards`] into
//! contiguous index ranges. Shard workers score their range and fold it
//! into a shard-local [`ParetoAccumulator`]; the request thread absorbs
//! per-shard fronts as they complete (streaming a `front` event after
//! each when asked). Because accumulator `push` is order-invariant and
//! absorbing a shard's *front* is equivalent to absorbing its raw scores
//! (see `search.rs`), the final front — and therefore the terminal `done`
//! line — is bit-identical to the serial one-shot sweep at every shard
//! count and jobs setting. Only the *interleaving* of `front` progress
//! events varies under `jobs > 1`.
//!
//! Random search stays shardable because sampling is index-pure: config
//! `i` is drawn from `Pcg32::new(derive_seed(seed, i), SAMPLE_STREAM)`
//! regardless of which shard or worker draws it, and is scored through
//! [`FitTable::score_size_indices`] from one reused per-worker index
//! buffer (no per-config allocation, no `PackedConfig` materialization).
//! Sampling is with replacement — unlike `BitConfigSampler`, which
//! dedups through a `HashSet` and is therefore inherently serial.
//!
//! # Table residency
//!
//! Tables are keyed by the study's `sensitivity_key` stage digest — the
//! same digest the artifact cache uses — in a small mutex-guarded MRU
//! list. A hit serves from memory ("warm"); a miss routes through the
//! lease-coordinated `Pipeline`, so N concurrent cold requests for one
//! study compute its sensitivity exactly once ("cold+cache" when a
//! published artifact was decodable beforehand, "cold+compute" when this
//! request had to run — or wait out — the train→trace pipeline).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::allocate::exact_allocate_table;
use crate::coordinator::parallel::{derive_seed, effective_jobs, run_pool_streaming};
use crate::coordinator::pipeline::stages::sensitivity_key;
use crate::coordinator::pipeline::{Digest, Pipeline, StageCounters};
use crate::coordinator::search::{greedy_allocate_table, FrontPoint, ParetoAccumulator};
use crate::coordinator::traces::TraceOptions;
use crate::metrics::{FitTable, PackedConfig};
use crate::quant::{BitConfig, PRECISIONS};
use crate::runtime::{BackendSpec, Runtime};
use crate::tensor::Pcg32;

use super::protocol::{
    done_line, error_line, front_line, json_escape, json_num, Budget, ErrorKind, ProtocolError,
    Request, RequestMetrics, SearchMode, StudySpec, TableResidency,
};

/// Tuning knobs of a [`ServiceCore`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads per request (0 = all cores) — same semantics as
    /// every other `--jobs` flag.
    pub jobs: usize,
    /// Resident-table LRU capacity (tables, not bytes — a table is a few
    /// hundred f64s per block).
    pub table_capacity: usize,
    /// Target configs per shard when the request doesn't pin `shards`.
    pub shard_target: u64,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig { jobs: 0, table_capacity: 8, shard_target: 65_536 }
    }
}

/// One resident study table: the stage digest it is keyed by, plus
/// everything needed to score and to convert budgets.
pub struct StudyTable {
    pub digest: Digest,
    pub model: String,
    pub table: FitTable,
    /// Full-model fp32 storage bits (`n_params * 32`) — the denominator
    /// of `budget_ratio`.
    pub fp32_bits: u64,
}

/// Monotone service-lifetime counters, aggregated by `stats` requests.
#[derive(Debug, Default)]
pub struct ServeStats {
    requests: AtomicU64,
    errors: AtomicU64,
    configs_scored: AtomicU64,
    table_hits: AtomicU64,
    table_misses: AtomicU64,
    /// Requests currently in flight (gauge, not a counter).
    active: AtomicUsize,
}

/// Per-thread execution state: a `Runtime` and a `Pipeline` are both
/// deliberately not `Send` (interned executables, memo `Rc`s), so every
/// serving thread builds its own pair via [`ServiceCore::worker`]. All
/// workers share the core's `StageCounters`, and cross-thread
/// exactly-once is the artifact store's lease protocol — same contract
/// as the experiment scheduler's worker pipelines.
pub struct ServiceWorker {
    pub rt: Runtime,
    pub pipe: Pipeline,
}

impl ServiceWorker {
    /// Wrap an existing runtime + pipeline (the CLI path, which already
    /// built both before deciding to route through the service core).
    pub fn new(rt: Runtime, pipe: Pipeline) -> ServiceWorker {
        ServiceWorker { rt, pipe }
    }
}

/// Internal failure split: protocol errors become an `error` event and
/// leave the connection open; transport errors (the client went away)
/// propagate and close it.
enum ExecError {
    Protocol(ProtocolError),
    Transport(anyhow::Error),
}

fn proto(kind: ErrorKind, e: impl std::fmt::Display) -> ExecError {
    ExecError::Protocol(ProtocolError::new(kind, format!("{e}")))
}

/// The shared state of a search service process. `Send + Sync`; wrap in
/// an `Arc` and hand a clone to every serving thread.
pub struct ServiceCore {
    spec: BackendSpec,
    results_root: PathBuf,
    cfg: ServiceConfig,
    /// MRU-ordered resident tables (front = most recently used).
    tables: Mutex<Vec<Arc<StudyTable>>>,
    counters: Arc<StageCounters>,
    stats: ServeStats,
    started: Instant,
}

impl ServiceCore {
    pub fn new(
        spec: BackendSpec,
        results_root: impl AsRef<Path>,
        cfg: ServiceConfig,
    ) -> ServiceCore {
        ServiceCore {
            spec,
            results_root: results_root.as_ref().to_path_buf(),
            cfg,
            tables: Mutex::new(Vec::new()),
            counters: Arc::new(StageCounters::default()),
            stats: ServeStats::default(),
            started: Instant::now(),
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    pub fn counters(&self) -> Arc<StageCounters> {
        self.counters.clone()
    }

    /// Build this thread's execution state (one runtime + one pipeline
    /// reporting into the shared counters).
    pub fn worker(&self) -> Result<ServiceWorker> {
        let rt = Runtime::from_spec(&self.spec)?;
        let pipe = Pipeline::with_counters(&self.results_root, self.counters.clone())?;
        Ok(ServiceWorker { rt, pipe })
    }

    /// Execute one validated request, writing every response line through
    /// `emit`. Protocol-level failures (unknown study, bad config,
    /// infeasible budget, worker panic) are emitted as a terminal `error`
    /// event and return `Ok` — the connection survives. An `Err` return
    /// means transport failure and the caller should drop the connection.
    pub fn execute(
        &self,
        w: &ServiceWorker,
        req: &Request,
        emit: &mut dyn FnMut(&str) -> Result<()>,
    ) -> Result<()> {
        let queue_depth = self.stats.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();
        let out = self.run(w, req, queue_depth, started, emit);
        self.stats.active.fetch_sub(1, Ordering::SeqCst);
        match out {
            Ok(()) => Ok(()),
            Err(ExecError::Protocol(e)) => {
                self.stats.errors.fetch_add(1, Ordering::Relaxed);
                emit(&error_line(&e))
            }
            Err(ExecError::Transport(e)) => Err(e),
        }
    }

    fn run(
        &self,
        w: &ServiceWorker,
        req: &Request,
        queue_depth: usize,
        started: Instant,
        emit: &mut dyn FnMut(&str) -> Result<()>,
    ) -> std::result::Result<(), ExecError> {
        match req {
            Request::Ping => {
                let m = self.metrics(started, 0, 0, 0, TableResidency::None, queue_depth);
                emit(&done_line("ping", "{\"ok\":true}", &m)).map_err(ExecError::Transport)
            }
            Request::Stats => {
                let result = self.stats_json();
                let m = self.metrics(started, 0, 0, 0, TableResidency::None, queue_depth);
                emit(&done_line("stats", &result, &m)).map_err(ExecError::Transport)
            }
            Request::Score { study, configs } => {
                let (entry, residency) = self.resolve(w, study)?;
                let packed = pack_all(&entry.table, configs).map_err(ExecError::Protocol)?;
                let mut scores = Vec::new();
                entry.table.score_batch_into(&packed, self.cfg.jobs, &mut scores);
                let shards = packed.len().div_ceil(FitTable::SCORE_CHUNK);
                let jobs = effective_jobs(self.cfg.jobs, shards);
                let result = scores_json(&scores);
                let m =
                    self.metrics(started, scores.len() as u64, shards, jobs, residency, queue_depth);
                emit(&done_line("score", &result, &m)).map_err(ExecError::Transport)
            }
            Request::Pareto { study, configs, shards, stream } => {
                let (entry, residency) = self.resolve(w, study)?;
                let packed = pack_all(&entry.table, configs).map_err(ExecError::Protocol)?;
                self.run_pareto(&entry, &packed, *shards, *stream, residency, queue_depth, started, emit)
            }
            Request::Search { study, mode, shards, stream } => {
                let (entry, residency) = self.resolve(w, study)?;
                match mode {
                    SearchMode::Random { samples, seed } => self.run_search_random(
                        &entry,
                        *samples,
                        *seed,
                        *shards,
                        *stream,
                        residency,
                        queue_depth,
                        started,
                        emit,
                    ),
                    SearchMode::Greedy(b) => {
                        self.run_alloc(&entry, "greedy", *b, residency, queue_depth, started, emit)
                    }
                    SearchMode::Exact(b) => {
                        self.run_alloc(&entry, "exact", *b, residency, queue_depth, started, emit)
                    }
                }
            }
        }
    }

    /// Resolve a study spec to a resident table: LRU hit, or build
    /// through the lease-coordinated pipeline (exactly-once across
    /// concurrent requests and across processes sharing the store).
    fn resolve(
        &self,
        w: &ServiceWorker,
        spec: &StudySpec,
    ) -> std::result::Result<(Arc<StudyTable>, TableResidency), ExecError> {
        let mm = w.rt.model(&spec.model).map_err(|e| proto(ErrorKind::Study, format!("{e:#}")))?;
        let digest =
            sensitivity_key(w.rt.backend_name(), mm, spec.fp_epochs, spec.seed, &spec.trace);
        if let Some(entry) = self.lookup(digest) {
            self.stats.table_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((entry, TableResidency::Warm));
        }
        self.stats.table_misses.fetch_add(1, Ordering::Relaxed);
        // Probe *before* computing: afterwards the artifact always exists,
        // so the probe is what distinguishes cache-hit from full-compute.
        let published = w
            .pipe
            .sensitivity_published(&w.rt, &spec.model, spec.fp_epochs, spec.seed, spec.trace)
            .map_err(|e| proto(ErrorKind::Internal, format!("{e:#}")))?;
        let sens = w
            .pipe
            .sensitivity(&w.rt, &spec.model, spec.fp_epochs, spec.seed, spec.trace)
            .map_err(|e| proto(ErrorKind::Study, format!("{e:#}")))?;
        let table = FitTable::new(&sens.inputs, &mm.block_sizes(), mm.n_unquantized(), &PRECISIONS);
        let entry = Arc::new(StudyTable {
            digest,
            model: spec.model.clone(),
            table,
            fp32_bits: mm.n_params as u64 * 32,
        });
        let entry = self.insert(entry);
        let residency =
            if published { TableResidency::ColdCached } else { TableResidency::ColdComputed };
        Ok((entry, residency))
    }

    fn lookup(&self, digest: Digest) -> Option<Arc<StudyTable>> {
        let mut tables = self.tables.lock().unwrap();
        let pos = tables.iter().position(|t| t.digest == digest)?;
        let entry = tables.remove(pos);
        tables.insert(0, entry.clone());
        Some(entry)
    }

    fn insert(&self, entry: Arc<StudyTable>) -> Arc<StudyTable> {
        let mut tables = self.tables.lock().unwrap();
        if let Some(pos) = tables.iter().position(|t| t.digest == entry.digest) {
            // Lost a build race to another request thread: keep the
            // incumbent so concurrent requests share one allocation.
            let incumbent = tables.remove(pos);
            tables.insert(0, incumbent.clone());
            return incumbent;
        }
        tables.insert(0, entry.clone());
        let cap = self.cfg.table_capacity.max(1);
        tables.truncate(cap);
        entry
    }

    #[allow(clippy::too_many_arguments)]
    fn run_pareto(
        &self,
        entry: &StudyTable,
        packed: &[PackedConfig],
        shards: Option<usize>,
        stream: bool,
        residency: TableResidency,
        queue_depth: usize,
        started: Instant,
        emit: &mut dyn FnMut(&str) -> Result<()>,
    ) -> std::result::Result<(), ExecError> {
        let table = &entry.table;
        let plan = plan_shards(packed.len() as u64, shards, self.cfg.shard_target);
        let jobs = effective_jobs(self.cfg.jobs, plan.len());
        let mut acc = ParetoAccumulator::new();
        let mut scored = 0u64;
        let mut shards_done = 0usize;
        let mut transport: Option<anyhow::Error> = None;
        let pool = run_pool_streaming(
            plan.len(),
            self.cfg.jobs,
            || Ok(Vec::<(f64, u64)>::new()),
            |scratch, i| {
                let (lo, hi) = plan[i];
                table.score_batch_into(&packed[lo as usize..hi as usize], 1, scratch);
                let mut local = ParetoAccumulator::new();
                local.absorb_scores(lo as usize, scratch);
                Ok((hi - lo, local))
            },
            |i, (count, local): (u64, ParetoAccumulator)| {
                scored += count;
                shards_done += 1;
                acc.absorb_front(local.front());
                if stream {
                    let fj = front_json(acc.front(), &mut |ix| table.unpack(&packed[ix]));
                    if let Err(e) = emit(&front_line(i, shards_done, plan.len(), &fj)) {
                        transport = Some(e);
                        anyhow::bail!("client write failed");
                    }
                }
                Ok(())
            },
        );
        if let Some(e) = transport {
            return Err(ExecError::Transport(e));
        }
        pool.map_err(|e| proto(ErrorKind::Internal, format!("{e:#}")))?;
        let fj = front_json(acc.front(), &mut |ix| table.unpack(&packed[ix]));
        let result = format!("{{\"front\":{fj}}}");
        let m = self.metrics(started, scored, plan.len(), jobs, residency, queue_depth);
        emit(&done_line("pareto", &result, &m)).map_err(ExecError::Transport)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_search_random(
        &self,
        entry: &StudyTable,
        samples: u64,
        seed: u64,
        shards: Option<usize>,
        stream: bool,
        residency: TableResidency,
        queue_depth: usize,
        started: Instant,
        emit: &mut dyn FnMut(&str) -> Result<()>,
    ) -> std::result::Result<(), ExecError> {
        let table = &entry.table;
        let n_blocks = table.n_weight_blocks() + table.n_act_blocks();
        let n_prec = table.precisions().len();
        let plan = plan_shards(samples, shards, self.cfg.shard_target);
        let jobs = effective_jobs(self.cfg.jobs, plan.len());
        let mut acc = ParetoAccumulator::new();
        let mut scored = 0u64;
        let mut shards_done = 0usize;
        let mut transport: Option<anyhow::Error> = None;
        let pool = run_pool_streaming(
            plan.len(),
            self.cfg.jobs,
            || Ok(Vec::<u8>::new()),
            |idx, i| {
                let (lo, hi) = plan[i];
                let mut local = ParetoAccumulator::new();
                for k in lo..hi {
                    sample_indices_into(n_blocks, n_prec, seed, k, idx);
                    let (fit, size_bits) = table.score_size_indices(idx);
                    local.push(FrontPoint { index: k as usize, fit, size_bits });
                }
                Ok((hi - lo, local))
            },
            |i, (count, local): (u64, ParetoAccumulator)| {
                scored += count;
                shards_done += 1;
                acc.absorb_front(local.front());
                if stream {
                    let fj =
                        front_json(acc.front(), &mut |ix| sampled_config(table, seed, ix as u64));
                    if let Err(e) = emit(&front_line(i, shards_done, plan.len(), &fj)) {
                        transport = Some(e);
                        anyhow::bail!("client write failed");
                    }
                }
                Ok(())
            },
        );
        if let Some(e) = transport {
            return Err(ExecError::Transport(e));
        }
        pool.map_err(|e| proto(ErrorKind::Internal, format!("{e:#}")))?;
        let fj = front_json(acc.front(), &mut |ix| sampled_config(table, seed, ix as u64));
        let result = format!("{{\"front\":{fj},\"samples\":{samples},\"seed\":{seed}}}");
        let m = self.metrics(started, scored, plan.len(), jobs, residency, queue_depth);
        emit(&done_line("search", &result, &m)).map_err(ExecError::Transport)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_alloc(
        &self,
        entry: &StudyTable,
        mode: &str,
        budget: Budget,
        residency: TableResidency,
        queue_depth: usize,
        started: Instant,
        emit: &mut dyn FnMut(&str) -> Result<()>,
    ) -> std::result::Result<(), ExecError> {
        let budget_bits = match budget {
            Budget::Bits(b) => b,
            Budget::Ratio(r) => (entry.fp32_bits as f64 * r) as u64,
        };
        let picked = match mode {
            "greedy" => greedy_allocate_table(&entry.table, budget_bits),
            _ => exact_allocate_table(&entry.table, budget_bits),
        };
        let sc = picked.ok_or_else(|| {
            proto(
                ErrorKind::Budget,
                format!("budget of {budget_bits} bits is below the all-minimum-precision floor"),
            )
        })?;
        let result = format!(
            "{{\"mode\":\"{mode}\",\"budget_bits\":{budget_bits},\"fit\":{},\"size_bits\":{},\
             \"config\":{}}}",
            json_num(sc.fit),
            sc.size_bits,
            config_json(&sc.cfg),
        );
        let m = self.metrics(started, 0, 0, 1, residency, queue_depth);
        emit(&done_line("search", &result, &m)).map_err(ExecError::Transport)
    }

    fn metrics(
        &self,
        started: Instant,
        scored: u64,
        shards: usize,
        jobs: usize,
        table: TableResidency,
        queue_depth: usize,
    ) -> RequestMetrics {
        self.stats.configs_scored.fetch_add(scored, Ordering::Relaxed);
        RequestMetrics {
            elapsed_ms: started.elapsed().as_secs_f64() * 1e3,
            configs_scored: scored,
            shards,
            jobs,
            table,
            queue_depth,
        }
    }

    /// The `stats` result object: lifetime counters, resident tables
    /// (MRU order), and the shared stage counters that pin exactly-once.
    pub fn stats_json(&self) -> String {
        let tables = self.tables.lock().unwrap();
        let resident: Vec<String> = tables
            .iter()
            .map(|t| {
                format!(
                    "{{\"model\":\"{}\",\"digest\":\"{}\"}}",
                    json_escape(&t.model),
                    &t.digest.hex()[..16]
                )
            })
            .collect();
        drop(tables);
        format!(
            "{{\"uptime_ms\":{},\"requests\":{},\"errors\":{},\"configs_scored\":{},\
             \"table_hits\":{},\"table_misses\":{},\"active\":{},\"tables\":[{}],\
             \"stages\":{{\"sensitivity_computed\":{},\"claims_won\":{},\"claim_waits\":{}}}}}",
            json_num(self.started.elapsed().as_secs_f64() * 1e3),
            self.stats.requests.load(Ordering::Relaxed),
            self.stats.errors.load(Ordering::Relaxed),
            self.stats.configs_scored.load(Ordering::Relaxed),
            self.stats.table_hits.load(Ordering::Relaxed),
            self.stats.table_misses.load(Ordering::Relaxed),
            self.stats.active.load(Ordering::SeqCst),
            resident.join(","),
            self.counters.sensitivity_computed(),
            self.counters.claims_won(),
            self.counters.claim_waits(),
        )
    }
}

/// Validate client configs against the study's table — block counts and
/// precision-set membership — then pack. Validation precedes packing
/// because `PackedConfig::pack` panics on a precision outside the set;
/// a client mistake must be a typed [`ErrorKind::Config`], not a worker
/// panic.
fn pack_all(
    table: &FitTable,
    configs: &[BitConfig],
) -> std::result::Result<Vec<PackedConfig>, ProtocolError> {
    let (lw, la) = (table.n_weight_blocks(), table.n_act_blocks());
    configs
        .iter()
        .enumerate()
        .map(|(at, cfg)| {
            if cfg.bits_w.len() != lw || cfg.bits_a.len() != la {
                return Err(ProtocolError::new(
                    ErrorKind::Config,
                    format!(
                        "configs[{at}]: study wants {lw} weight + {la} activation blocks, \
                         got {} + {}",
                        cfg.bits_w.len(),
                        cfg.bits_a.len()
                    ),
                ));
            }
            for &b in cfg.bits_w.iter().chain(cfg.bits_a.iter()) {
                if !table.precisions().contains(&b) {
                    return Err(ProtocolError::new(
                        ErrorKind::Config,
                        format!(
                            "configs[{at}]: precision {b} not in the candidate set {:?}",
                            table.precisions()
                        ),
                    ));
                }
            }
            Ok(table.pack(cfg))
        })
        .collect()
}

/// Split `[0, n)` into `k` contiguous ranges: the request's `shards`
/// when pinned, else `ceil(n / target)`, always clamped to `[1, n]`.
/// Earlier shards take the remainder (the `run_static` split), so sizes
/// differ by at most one and concatenating the ranges reproduces
/// `[0, n)` exactly — the property the sharding determinism contract
/// rests on.
pub fn plan_shards(n: u64, requested: Option<usize>, target: u64) -> Vec<(u64, u64)> {
    if n == 0 {
        return Vec::new();
    }
    let k = match requested {
        Some(k) => k as u64,
        None => n.div_ceil(target.max(1)),
    }
    .clamp(1, n);
    let (base, rem) = (n / k, n % k);
    let mut plan = Vec::with_capacity(k as usize);
    let mut lo = 0u64;
    for i in 0..k {
        let len = base + u64::from(i < rem);
        plan.push((lo, lo + len));
        lo += len;
    }
    plan
}

/// RNG stream of the service's index-pure config sampling. Distinct from
/// `BitConfigSampler`'s stream, so a served search and a sampler-driven
/// study with the same seed do not draw correlated configs.
pub const SAMPLE_STREAM: u64 = 0x5ea7_c4f6;

/// Draw sample `index` of a random search into a reused index buffer:
/// one precision index per block (weights first, then activations — the
/// `PackedConfig::indices` layout). Pure in `(seed, index)`: any worker,
/// any shard, any interleaving draws the same config for the same index.
pub fn sample_indices_into(
    n_blocks: usize,
    n_prec: usize,
    seed: u64,
    index: u64,
    out: &mut Vec<u8>,
) {
    out.clear();
    let mut rng = Pcg32::new(derive_seed(seed, index), SAMPLE_STREAM);
    for _ in 0..n_blocks {
        out.push(rng.below(n_prec as u32) as u8);
    }
}

/// Re-draw sample `index` as a [`BitConfig`] (front points carry global
/// sample indices; only the handful on the front ever need expanding).
pub fn sampled_config(table: &FitTable, seed: u64, index: u64) -> BitConfig {
    let lw = table.n_weight_blocks();
    let mut idx = Vec::new();
    sample_indices_into(lw + table.n_act_blocks(), table.precisions().len(), seed, index, &mut idx);
    let precs = table.precisions();
    BitConfig {
        bits_w: idx[..lw].iter().map(|&i| precs[i as usize]).collect(),
        bits_a: idx[lw..].iter().map(|&i| precs[i as usize]).collect(),
    }
}

/// `{"w":[...],"a":[...]}` — the same shape the request decoder accepts,
/// so responses round-trip into follow-up `score` requests.
pub fn config_json(cfg: &BitConfig) -> String {
    let join = |bits: &[u32]| {
        bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
    };
    format!("{{\"w\":[{}],\"a\":[{}]}}", join(&cfg.bits_w), join(&cfg.bits_a))
}

/// Encode a front as a JSON array, expanding each point's config through
/// `cfg_of` (table unpack for explicit configs, re-sampling for random
/// search). Fits are finite by the accumulator's invariant, so the
/// shortest-round-trip `json_num` encoding is bit-faithful.
pub fn front_json(points: &[FrontPoint], cfg_of: &mut dyn FnMut(usize) -> BitConfig) -> String {
    let items: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{{\"index\":{},\"fit\":{},\"size_bits\":{},\"config\":{}}}",
                p.index,
                json_num(p.fit),
                p.size_bits,
                config_json(&cfg_of(p.index))
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// `[[fit, size_bits], ...]` in request order (a NaN fit — possible when
/// a trace diverged — encodes as `null`, which the CLI renders as NaN).
fn scores_json(scores: &[(f64, u64)]) -> String {
    let items: Vec<String> =
        scores.iter().map(|&(f, s)| format!("[{},{}]", json_num(f), s)).collect();
    format!("{{\"scores\":[{}]}}", items.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shards_partitions_exactly() {
        for n in [1u64, 2, 7, 100, 65_536, 65_537, 1_000_000] {
            for req in [None, Some(1), Some(2), Some(3), Some(16), Some(10_000)] {
                let plan = plan_shards(n, req, 65_536);
                assert!(!plan.is_empty());
                let mut expect = 0u64;
                for &(lo, hi) in &plan {
                    assert_eq!(lo, expect, "contiguous");
                    assert!(hi > lo, "non-empty shard");
                    expect = hi;
                }
                assert_eq!(expect, n, "covers [0, n)");
                if let Some(k) = req {
                    assert_eq!(plan.len() as u64, (k as u64).clamp(1, n));
                }
                let sizes: Vec<u64> = plan.iter().map(|&(lo, hi)| hi - lo).collect();
                let (min, max) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "balanced: {sizes:?}");
            }
        }
        assert!(plan_shards(0, None, 65_536).is_empty());
        assert!(plan_shards(0, Some(8), 65_536).is_empty());
        // auto shard count tracks the target
        assert_eq!(plan_shards(65_536, None, 65_536).len(), 1);
        assert_eq!(plan_shards(65_537, None, 65_536).len(), 2);
        // degenerate target never divides by zero
        assert_eq!(plan_shards(10, None, 0).len(), 10);
    }

    #[test]
    fn sampling_is_index_pure_and_in_range() {
        let mut a = Vec::new();
        let mut b = vec![0xffu8; 64]; // stale contents must not leak
        for index in [0u64, 1, 17, 1 << 40] {
            sample_indices_into(12, 4, 7, index, &mut a);
            sample_indices_into(12, 4, 7, index, &mut b);
            assert_eq!(a, b, "pure in (seed, index)");
            assert_eq!(a.len(), 12);
            assert!(a.iter().all(|&i| i < 4), "indices in range: {a:?}");
        }
        // different indices / seeds draw different configs (overwhelmingly)
        sample_indices_into(12, 4, 7, 0, &mut a);
        sample_indices_into(12, 4, 7, 1, &mut b);
        assert_ne!(a, b);
        sample_indices_into(12, 4, 8, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn json_encoders_round_trip() {
        use crate::runtime::Json;
        let cfg = BitConfig { bits_w: vec![8, 4], bits_a: vec![3] };
        let j = Json::parse(&config_json(&cfg)).unwrap();
        assert_eq!(j.usize_array("w").unwrap(), vec![8, 4]);
        assert_eq!(j.usize_array("a").unwrap(), vec![3]);

        let pts = [
            FrontPoint { index: 3, fit: 0.125, size_bits: 100 },
            FrontPoint { index: 9, fit: 0.0625, size_bits: 200 },
        ];
        let fj = front_json(&pts, &mut |_| cfg.clone());
        let arr = Json::parse(&fj).unwrap();
        let arr = arr.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].usize_field("index").unwrap(), 3);
        assert_eq!(arr[0].field("fit").unwrap().as_f64().unwrap(), 0.125);
        assert_eq!(arr[1].usize_field("size_bits").unwrap(), 200);

        let sj = scores_json(&[(0.5, 10), (f64::NAN, 20)]);
        let back = Json::parse(&sj).unwrap();
        let scores = back.arr_field("scores").unwrap();
        assert_eq!(scores[0].as_arr().unwrap()[0].as_f64().unwrap(), 0.5);
        assert!(matches!(scores[1].as_arr().unwrap()[0], Json::Null));
    }
}
