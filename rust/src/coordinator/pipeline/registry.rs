//! Declarative experiment registry + cross-experiment scheduling.
//!
//! Each paper table/figure is one [`ExperimentSpec`]: a name, the flag
//! schema it accepts, a `stages` function declaring which stage-graph
//! outputs it depends on, and a `run` function that renders reports from
//! those (now warm) stages. The CLI dispatches through [`find`] /
//! [`run_all`] instead of a hand-maintained `match`, so adding an
//! experiment is one table row and unknown names/flags fail with the
//! generated usage text.
//!
//! `experiment all` is a DAG walk: the union of every selected
//! experiment's stage requests is deduped ([`StageRequest::plan`]),
//! executed rank-by-rank (checkpoints, then traces/sensitivity) with
//! independent stages fanned over `coordinator::parallel`, and the
//! experiments then run against the warm cache — light ones fanned as
//! whole units, `heavy_sweep` ones serially with the full `--jobs`
//! budget handed to their inner config sweep (see [`run_all`]). One
//! budget governs the whole walk, and every file an experiment writes is
//! a pure function of its options, so cached-vs-cold and
//! `jobs=1`-vs-`N` walks produce byte-identical results trees.

use anyhow::{bail, Result};

use super::stages::{Pipeline, StageRequest};
use crate::coordinator::experiments::{fig1, fig2, fig4, fig5, fig9, table1, table2, table3};
use crate::coordinator::parallel;
use crate::runtime::Runtime;

/// The uniform option schema every experiment parses its own options
/// from. `None` means "use the experiment's default" — defaults differ
/// per experiment (e.g. `fp_epochs` is 15 on the scale ladder, 40 for the
/// U-Net study), which is why these are overrides, not values.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub seed: u64,
    pub jobs: usize,
    pub iters: Option<u64>,
    pub runs: Option<usize>,
    pub configs: Option<usize>,
    pub fp_epochs: Option<usize>,
    pub qat_epochs: Option<usize>,
    pub eval_n: Option<usize>,
    /// table2: restrict to experiment ids (e.g. `["D"]`).
    pub only: Vec<String>,
    /// table3: restrict the model ladder.
    pub models: Vec<String>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            seed: 0,
            jobs: 1,
            iters: None,
            runs: None,
            configs: None,
            fp_epochs: None,
            qat_epochs: None,
            eval_n: None,
            only: Vec::new(),
            models: Vec::new(),
        }
    }
}

/// One registered experiment.
pub struct ExperimentSpec {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub about: &'static str,
    /// Flags this experiment accepts beyond the global `--seed`/`--jobs`.
    pub flags: &'static [&'static str],
    /// Whether the experiment's own inner sweep (QAT fine-tunes) dominates
    /// its cost. The `all` walk runs these serially with the *full*
    /// `--jobs` budget handed to the sweep, instead of fanning them as
    /// whole experiments with serial insides — the sweep is where the
    /// parallelism pays.
    pub heavy_sweep: bool,
    /// Stage-graph dependencies as a function of the parsed options.
    pub stages: fn(&ExpOptions) -> Vec<StageRequest>,
    pub run: fn(&Runtime, &Pipeline, &ExpOptions) -> Result<()>,
}

/// Flags accepted by every experiment.
pub const GLOBAL_FLAGS: &[&str] = &["seed", "jobs", "backend"];

/// All experiments, in `experiment all` execution order (cheapest first,
/// matching the pre-registry serial loop).
pub const REGISTRY: &[ExperimentSpec] = &[
    ExperimentSpec {
        name: "fig9",
        aliases: &[],
        about: "quantization-error uniformity histograms (Appendix E)",
        flags: &["fp-epochs"],
        heavy_sweep: false,
        stages: stages_fig9,
        run: run_fig9,
    },
    ExperimentSpec {
        name: "fig5",
        aliases: &[],
        about: "quantization noise vs parameter magnitude",
        flags: &["configs", "fp-epochs"],
        heavy_sweep: false,
        stages: stages_fig5,
        run: run_fig5,
    },
    ExperimentSpec {
        name: "table1",
        aliases: &[],
        about: "EF vs Hessian estimator variance/time/speedup",
        flags: &["iters", "runs", "fp-epochs"],
        heavy_sweep: false,
        stages: stages_table1,
        run: run_table1,
    },
    ExperimentSpec {
        name: "fig1",
        aliases: &["fig7"],
        about: "per-block EF vs Hessian trace profiles",
        flags: &["fp-epochs"],
        heavy_sweep: false,
        stages: stages_fig1,
        run: run_fig1,
    },
    ExperimentSpec {
        name: "fig2",
        aliases: &[],
        about: "trace-estimate convergence, EF vs Hessian",
        flags: &["iters", "fp-epochs"],
        heavy_sweep: false,
        stages: stages_fig2,
        run: run_fig2,
    },
    ExperimentSpec {
        name: "table3",
        aliases: &["table4"],
        about: "estimator variance/time vs batch size (Appendix C)",
        flags: &["iters", "runs", "models", "fp-epochs"],
        heavy_sweep: false,
        stages: stages_table3,
        run: run_table3,
    },
    ExperimentSpec {
        name: "table2",
        aliases: &["fig3"],
        about: "rank-correlation study over random MPQ configs",
        flags: &["configs", "fp-epochs", "qat-epochs", "eval-n", "only"],
        heavy_sweep: true,
        stages: stages_table2,
        run: run_table2,
    },
    ExperimentSpec {
        name: "fig4",
        aliases: &[],
        about: "U-Net segmentation study (traces + FIT vs mIoU)",
        flags: &["configs", "fp-epochs", "qat-epochs", "eval-n"],
        heavy_sweep: true,
        stages: stages_fig4,
        run: run_fig4,
    },
];

/// Look up an experiment by name or alias.
pub fn find(name: &str) -> Option<&'static ExperimentSpec> {
    REGISTRY.iter().find(|s| s.name == name || s.aliases.contains(&name))
}

/// Generated usage text for `fitq experiment` (also the error payload for
/// unknown names/flags).
pub fn usage() -> String {
    let mut s = String::from(
        "usage: fitq experiment <name>|all [--seed N] [--jobs N] [--backend native|pjrt] [flags]\n",
    );
    let mut specs: Vec<&ExperimentSpec> = REGISTRY.iter().collect();
    specs.sort_by_key(|spec| spec.name);
    for spec in specs {
        let flags: String = spec.flags.iter().map(|f| format!(" [--{f} V]")).collect();
        s.push_str(&format!("  {:<7}— {}{}\n", spec.name, spec.about, flags));
    }
    s.push_str("  all    — every experiment once, deduping shared pipeline stages\n");
    s
}

/// Run a set of experiments as one scheduled walk (a single spec is the
/// degenerate walk). Phase 1 plans and materializes the deduped stage
/// union; phase 2 runs the experiments against the warm cache. All of it
/// spends the one `--jobs` budget where it pays: stage batches fan over
/// the pool, light experiments fan as whole units (their insides go
/// serial), and `heavy_sweep` experiments run one at a time with the full
/// budget handed to their inner config sweep — the dominant cost of the
/// walk, which fanning-with-serial-insides would starve. Every output
/// file is keyed by experiment and jobs-invariant, so the schedule shape
/// never changes the results tree.
pub fn run_all(
    rt: &Runtime,
    pipe: &Pipeline,
    specs: &[&'static ExperimentSpec],
    o: &ExpOptions,
) -> Result<()> {
    // capability filter: the native backend implements the study models
    // only, so PJRT-only experiments (scale ladder, U-Net, Hutchinson)
    // are skipped under a wider walk and fail actionably when requested
    // directly, instead of aborting mid-prepass on a missing model
    let (specs, skipped): (Vec<&'static ExperimentSpec>, Vec<&'static ExperimentSpec>) =
        specs.iter().copied().partition(|s| spec_supported(rt, s, o));
    if !skipped.is_empty() {
        let names: Vec<&str> = skipped.iter().map(|s| s.name).collect();
        if specs.is_empty() {
            bail!(
                "experiment(s) {} need models the {} backend does not provide — rerun \
                 with `--backend pjrt` over artifacts from `make artifacts`",
                names.join(", "),
                rt.backend_name()
            );
        }
        eprintln!(
            "  [skip] {}: models not in the {} backend (PJRT-only; rerun with --backend pjrt)",
            names.join(", "),
            rt.backend_name()
        );
    }
    let plan = StageRequest::plan(specs.iter().flat_map(|s| (s.stages)(o)).collect());
    for rank in 0..=1u8 {
        let batch: Vec<&StageRequest> = plan.iter().filter(|r| r.rank() == rank).collect();
        run_stage_batch(rt, pipe, &batch, o.jobs)?;
    }
    let light: Vec<&'static ExperimentSpec> =
        specs.iter().copied().filter(|s| !s.heavy_sweep).collect();
    let heavy: Vec<&'static ExperimentSpec> =
        specs.iter().copied().filter(|s| s.heavy_sweep).collect();

    // Wave 1: light experiments, fanned as whole write-disjoint units
    // (inner work serial so the budget is spent once).
    if parallel::effective_jobs(o.jobs, light.len()) <= 1 {
        for spec in &light {
            (spec.run)(rt, pipe, o)?;
        }
    } else {
        let inner = ExpOptions { jobs: 1, ..o.clone() };
        // workers run serial inside: the outer fan-out owns the cores
        let spec = rt.spec().intra_serial();
        let results_root = pipe.results_root().to_path_buf();
        let counters = pipe.counters();
        parallel::run_pool(
            light.len(),
            o.jobs,
            || -> Result<(Runtime, Pipeline)> {
                let wrt = Runtime::from_spec(&spec)?;
                let wp = Pipeline::with_counters(&results_root, counters.clone())?;
                Ok((wrt, wp))
            },
            |w, i| (light[i].run)(&w.0, &w.1, &inner),
        )?;
    }

    // Wave 2: sweep-heavy experiments serially, full budget to the sweep.
    for spec in &heavy {
        (spec.run)(rt, pipe, o)?;
    }
    Ok(())
}

/// Whether every stage model this experiment declares exists in the
/// runtime's manifest.
fn spec_supported(rt: &Runtime, spec: &ExperimentSpec, o: &ExpOptions) -> bool {
    (spec.stages)(o).iter().all(|r| rt.model(r.model()).is_ok())
}

fn run_stage_batch(
    rt: &Runtime,
    pipe: &Pipeline,
    batch: &[&StageRequest],
    jobs: usize,
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    if parallel::effective_jobs(jobs, batch.len()) <= 1 {
        for req in batch {
            pipe.ensure(rt, req)?;
        }
        return Ok(());
    }
    let spec = rt.spec().intra_serial();
    let results_root = pipe.results_root().to_path_buf();
    let counters = pipe.counters();
    parallel::run_pool(
        batch.len(),
        jobs,
        || -> Result<(Runtime, Pipeline)> {
            let wrt = Runtime::from_spec(&spec)?;
            let wp = Pipeline::with_counters(&results_root, counters.clone())?;
            Ok((wrt, wp))
        },
        |w, i| w.1.ensure(&w.0, batch[i]),
    )?;
    Ok(())
}

// --- per-experiment adapters: uniform options -> typed options ---

fn run_table1(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    table1::run(rt, p, &table1::Table1Options::from_exp(e)).map(|_| ())
}

fn stages_table1(e: &ExpOptions) -> Vec<StageRequest> {
    table1::stages(&table1::Table1Options::from_exp(e))
}

fn run_table2(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    table2::run(rt, p, &table2::Table2Options::from_exp(e)).map(|_| ())
}

fn stages_table2(e: &ExpOptions) -> Vec<StageRequest> {
    table2::stages(&table2::Table2Options::from_exp(e))
}

fn run_table3(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    table3::run(rt, p, &table3::Table3Options::from_exp(e))
}

fn stages_table3(e: &ExpOptions) -> Vec<StageRequest> {
    table3::stages(&table3::Table3Options::from_exp(e))
}

fn run_fig1(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    fig1::run(rt, p, &fig1::Fig1Options::from_exp(e))
}

fn stages_fig1(e: &ExpOptions) -> Vec<StageRequest> {
    fig1::stages(&fig1::Fig1Options::from_exp(e))
}

fn run_fig2(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    fig2::run(rt, p, &fig2::Fig2Options::from_exp(e))
}

fn stages_fig2(e: &ExpOptions) -> Vec<StageRequest> {
    fig2::stages(&fig2::Fig2Options::from_exp(e))
}

fn run_fig4(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    fig4::run(rt, p, &fig4::Fig4Options::from_exp(e))
}

fn stages_fig4(e: &ExpOptions) -> Vec<StageRequest> {
    fig4::stages(&fig4::Fig4Options::from_exp(e))
}

fn run_fig5(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    fig5::run(rt, p, &fig5::Fig5Options::from_exp(e))
}

fn stages_fig5(e: &ExpOptions) -> Vec<StageRequest> {
    fig5::stages(&fig5::Fig5Options::from_exp(e))
}

fn run_fig9(rt: &Runtime, p: &Pipeline, e: &ExpOptions) -> Result<()> {
    fig9::run(rt, p, &fig9::Fig9Options::from_exp(e))
}

fn stages_fig9(e: &ExpOptions) -> Vec<StageRequest> {
    fig9::stages(&fig9::Fig9Options::from_exp(e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_resolves_names_and_aliases() {
        assert_eq!(find("table2").unwrap().name, "table2");
        assert_eq!(find("fig7").unwrap().name, "fig1", "fig7 is the fig1 alias");
        assert_eq!(find("table4").unwrap().name, "table3");
        assert!(find("bogus").is_none());
    }

    #[test]
    fn registry_names_are_unique() {
        let mut all: Vec<&str> = REGISTRY
            .iter()
            .flat_map(|s| std::iter::once(s.name).chain(s.aliases.iter().copied()))
            .collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate name or alias in REGISTRY");
    }

    #[test]
    fn usage_lists_every_experiment() {
        let u = usage();
        for spec in REGISTRY {
            assert!(u.contains(spec.name), "usage must mention {}", spec.name);
            for flag in spec.flags {
                assert!(u.contains(&format!("--{flag}")), "usage must mention --{flag}");
            }
        }
    }

    #[test]
    fn native_backend_capability_filter() {
        let rt = Runtime::native().unwrap();
        let o = ExpOptions::default();
        for name in ["table2", "fig5", "fig9"] {
            assert!(spec_supported(&rt, find(name).unwrap(), &o), "{name} runs natively");
        }
        for name in ["table1", "table3", "fig1", "fig2", "fig4"] {
            assert!(!spec_supported(&rt, find(name).unwrap(), &o), "{name} is PJRT-only");
        }
    }

    #[test]
    fn pjrt_only_experiment_on_native_fails_actionably() {
        let rt = Runtime::native().unwrap();
        let dir = std::env::temp_dir().join(format!("fitq_reg_native_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let pipe = Pipeline::new(&dir).unwrap();
        let err = run_all(&rt, &pipe, &[find("table1").unwrap()], &ExpOptions::default())
            .expect_err("table1 must not run on the native backend");
        let msg = format!("{err:#}");
        assert!(msg.contains("--backend pjrt"), "{msg}");
        assert!(msg.contains("table1"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_checkpoints_dedupe_across_experiments() {
        // table1 + fig1 + fig2 + table3 all ride the same four scale-model
        // checkpoints; the planned union must train each exactly once.
        let o = ExpOptions::default();
        let mut reqs = Vec::new();
        for name in ["table1", "fig1", "fig2", "table3"] {
            reqs.extend((find(name).unwrap().stages)(&o));
        }
        let plan = StageRequest::plan(reqs);
        let fp: Vec<_> = plan.iter().filter(|r| r.rank() == 0).collect();
        assert_eq!(fp.len(), 4, "one TrainFp per scale model: {fp:?}");
    }

    #[test]
    fn table2_declares_checkpoint_and_sensitivity_per_study() {
        let o = ExpOptions::default();
        let plan = StageRequest::plan((find("table2").unwrap().stages)(&o));
        let n_fp = plan.iter().filter(|r| r.rank() == 0).count();
        let n_dep = plan.iter().filter(|r| r.rank() == 1).count();
        assert_eq!((n_fp, n_dep), (4, 4), "{plan:?}");
    }
}
