//! The stage-graph experiment pipeline.
//!
//! The paper's evaluation is hundreds of cheap scorings layered over a
//! handful of expensive shared stages — FP training, trace estimation,
//! sensitivity gathering, the QAT study sweep. This subsystem makes those
//! stages first-class:
//!
//! - [`digest`] / [`codec`]: deterministic content digests and the binary
//!   serialization for stage outputs (`TraceResult`, `SensitivityReport`,
//!   study outcome tables) that had none.
//! - [`cache`]: the content-addressed store under `results/cache/`, keyed
//!   by a digest of each stage's full input set, with versioned
//!   self-validating headers — corrupt or stale entries fall back to
//!   recompute.
//! - [`stages`]: the typed stage graph (`train_fp → traces / sensitivity
//!   → study`) behind [`Pipeline`], memoized per process and cached
//!   across processes, with shared [`StageCounters`] pinning the
//!   exactly-once contract.
//! - [`registry`]: the declarative experiment registry and the
//!   cross-experiment scheduler that turns `experiment all` into a
//!   stage-deduping DAG walk over `coordinator::parallel`.
//! - [`fault`]: the deterministic fault-injection harness — named
//!   injection sites in the cache/lease/worker paths, armed via
//!   `$FITQ_FAULTS` or a test-scoped [`fault::FaultPlan`], no-ops when
//!   unarmed.

pub mod cache;
pub mod codec;
pub mod digest;
pub mod fault;
pub mod registry;
pub mod stages;

pub use cache::{
    ArtifactCache, Claim, GcReport, LeaseConfig, LeaseGuard, LeaseRecord, StatsReport,
    VerifyReport,
};
pub use digest::{digest_bytes, Digest, Hasher};
pub use fault::FaultPlan;
pub use registry::{ExpOptions, ExperimentSpec};
pub use stages::{Pipeline, StageCounters, StageRequest};
