//! The typed stage graph: `train_fp → traces / sensitivity → study`.
//!
//! A [`Pipeline`] is a handle over the artifact cache plus an in-process
//! memo, and each stage method is *idempotent*: it returns the memoized
//! value, else a validated cache entry, else computes, stores, and counts
//! the computation. Because every stage's stochastic inputs are a pure
//! function of its key (model identity, seed, epochs, trace options — the
//! same replay contract `coordinator::parallel` enforces for job indices),
//! a cache hit is bit-identical to a recompute, and the FP checkpoint and
//! sensitivity report for a given key are produced exactly once per
//! process (memo) *and* at most once across processes (cache).
//!
//! [`StageRequest`] is the declarative form of a stage used by the
//! experiment registry's DAG walk: experiments declare what they need,
//! `experiment all` dedupes the union, computes shared stages first
//! (fanned over the worker pool), and every experiment then runs against a
//! warm cache.
//!
//! `Pipeline` is deliberately not `Send` (like `Runtime`): parallel phases
//! give each worker its own `Pipeline` over the same cache directory,
//! sharing only the atomic [`StageCounters`].
//!
//! # Cross-process coordination
//!
//! When several *processes* share one cache directory, each cold stage is
//! claimed through the cache's lease layer before computing
//! (`ArtifactCache::try_claim`): the winner computes and publishes, the
//! losers poll for the published artifact and decode it. The contract is
//! exactly-once in the common case and at-least-once under faults — if a
//! lease holder dies, its lease expires and a waiter takes over; if the
//! wait budget is exhausted, the waiter computes without a claim. Both
//! fallbacks are harmless because stage outputs are deterministic in their
//! key and stores are atomic: a duplicate compute publishes byte-identical
//! bytes. Stage computations are panic-isolated (`catch_unwind`), so a
//! poisoned job surfaces as a typed error with the lease released, never a
//! stuck lease held by a dead thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::cache::{ArtifactCache, Claim, LeaseConfig, LeaseGuard};
use super::codec;
use super::fault::{self, site};
use crate::coordinator::parallel::panic_message;
use super::digest::{Digest, Hasher};
use crate::coordinator::evaluator::{StudyOptions, StudyResult};
use crate::coordinator::sensitivity::{gather, SensitivityReport};
use crate::coordinator::state::ModelState;
use crate::coordinator::traces::{Estimator, TraceEngine, TraceOptions, TraceResult};
use crate::coordinator::trainer::{dataset_for, Trainer};
use crate::data::EvalSet;
use crate::runtime::{ModelManifest, Runtime};

/// Cache kinds, one per stage output type.
pub const KIND_TRAIN_FP: &str = "train_fp";
pub const KIND_TRACES: &str = "traces";
pub const KIND_SENSITIVITY: &str = "sensitivity";
pub const KIND_STUDY: &str = "study";

/// How many times each stage was actually *computed* (cache/memo hits do
/// not count). Shared across worker pipelines via `Arc`, so `experiment
/// all` can assert its exactly-once contract at any `--jobs` setting.
#[derive(Debug, Default)]
pub struct StageCounters {
    train_fp: AtomicU64,
    traces: AtomicU64,
    sensitivity: AtomicU64,
    study: AtomicU64,
    claims_won: AtomicU64,
    claim_waits: AtomicU64,
}

impl StageCounters {
    pub fn train_fp_computed(&self) -> u64 {
        self.train_fp.load(Ordering::Relaxed)
    }

    pub fn traces_computed(&self) -> u64 {
        self.traces.load(Ordering::Relaxed)
    }

    pub fn sensitivity_computed(&self) -> u64 {
        self.sensitivity.load(Ordering::Relaxed)
    }

    pub fn study_computed(&self) -> u64 {
        self.study.load(Ordering::Relaxed)
    }

    /// Stage leases this process won (each corresponds to one exclusive
    /// compute-and-publish).
    pub fn claims_won(&self) -> u64 {
        self.claims_won.load(Ordering::Relaxed)
    }

    /// Cold stages this process waited out rather than computed — another
    /// process held the lease.
    pub fn claim_waits(&self) -> u64 {
        self.claim_waits.load(Ordering::Relaxed)
    }
}

/// A declared dependency on one stage output — the unit of the registry's
/// prepass DAG walk. Field-for-field this is the stage's cache key.
#[derive(Debug, Clone, PartialEq)]
pub enum StageRequest {
    /// FP training of `(model, epochs, seed)`.
    TrainFp { model: String, epochs: usize, seed: u64 },
    /// One estimator run over the FP checkpoint of `(model, fp_epochs, seed)`.
    Traces {
        model: String,
        fp_epochs: usize,
        seed: u64,
        est: Estimator,
        opt: TraceOptions,
    },
    /// Full sensitivity gathering over the FP checkpoint.
    Sensitivity { model: String, fp_epochs: usize, seed: u64, trace: TraceOptions },
}

impl StageRequest {
    /// The model this stage runs on (the registry's capability filter
    /// checks it against the runtime's manifest).
    pub fn model(&self) -> &str {
        match self {
            StageRequest::TrainFp { model, .. }
            | StageRequest::Traces { model, .. }
            | StageRequest::Sensitivity { model, .. } => model,
        }
    }

    /// Topological rank: checkpoints before everything that consumes them.
    pub fn rank(&self) -> u8 {
        match self {
            StageRequest::TrainFp { .. } => 0,
            StageRequest::Traces { .. } | StageRequest::Sensitivity { .. } => 1,
        }
    }

    /// Deterministic total order for the prepass (rank-major, then the
    /// request's own debug form — stable across runs and job counts).
    fn sort_key(&self) -> (u8, String) {
        (self.rank(), format!("{self:?}"))
    }

    /// Dedupe + topologically order a union of requests from many
    /// experiments: each distinct stage appears exactly once, checkpoints
    /// first.
    pub fn plan(mut reqs: Vec<StageRequest>) -> Vec<StageRequest> {
        reqs.sort_by_key(|r| r.sort_key());
        reqs.dedup();
        reqs
    }
}

fn hash_trace_options(h: &mut Hasher, o: &TraceOptions) {
    h.usize(o.batch);
    h.f64(o.tol);
    h.u64(o.min_iters);
    h.u64(o.max_iters);
    h.u64(o.seed);
}

/// Model identity inside a key: the executing backend, the model name,
/// plus the full block layout (count, offset and size of every weight
/// block, size of every activation block) — so regenerated artifacts
/// with a different layout can never validate against stale entries,
/// and the numerically independent backends (PJRT vs native) can never
/// serve each other's checkpoints, traces or studies.
fn hash_model(h: &mut Hasher, backend: &str, m: &ModelManifest) {
    h.str(backend);
    h.str(&m.name);
    h.usize(m.n_params);
    h.usize(m.n_weight_blocks());
    for wb in &m.weight_blocks {
        h.usize(wb.offset);
        h.usize(wb.size);
    }
    h.usize(m.n_act_blocks());
    for ab in &m.act_blocks {
        h.usize(ab.size);
    }
}

pub fn train_fp_key(backend: &str, m: &ModelManifest, epochs: usize, seed: u64) -> Digest {
    let mut h = Hasher::new();
    h.str("train_fp/v2");
    hash_model(&mut h, backend, m);
    h.usize(epochs);
    h.u64(seed);
    h.finish()
}

pub fn trace_key(
    backend: &str,
    m: &ModelManifest,
    fp_epochs: usize,
    seed: u64,
    est: Estimator,
    opt: &TraceOptions,
) -> Digest {
    let mut h = Hasher::new();
    h.str("traces/v2");
    hash_model(&mut h, backend, m);
    h.usize(fp_epochs);
    h.u64(seed);
    h.str(est.name());
    hash_trace_options(&mut h, opt);
    h.finish()
}

pub fn sensitivity_key(
    backend: &str,
    m: &ModelManifest,
    fp_epochs: usize,
    seed: u64,
    trace: &TraceOptions,
) -> Digest {
    let mut h = Hasher::new();
    h.str("sensitivity/v2");
    hash_model(&mut h, backend, m);
    h.usize(fp_epochs);
    h.u64(seed);
    h.usize(m.calib_b);
    hash_trace_options(&mut h, trace);
    h.finish()
}

/// Op-trace key: backend + model identity + workload label, nothing
/// else. Thread budgets, `--jobs`, kernel modes and the tracing switch
/// itself are all deliberately excluded — the counters they could
/// affect are wall-clock only, and profiling must never split a digest
/// (`tests/op_trace.rs` pins the exclusion).
pub fn optrace_key(backend: &str, m: &ModelManifest, workload: &str) -> Digest {
    let mut h = Hasher::new();
    h.str("optrace/v1");
    hash_model(&mut h, backend, m);
    h.str(workload);
    h.finish()
}

/// Study key: every `StudyOptions` field *except* `jobs` — results are
/// jobs-invariant by the parallel determinism contract, so a study cached
/// at `--jobs 1` must hit at `--jobs 8` and vice versa. `calib_b` rides
/// along because the study consumes the sensitivity stage, whose
/// calibration prefix it determines.
pub fn study_key(backend: &str, m: &ModelManifest, opt: &StudyOptions) -> Digest {
    let mut h = Hasher::new();
    h.str("study/v2");
    hash_model(&mut h, backend, m);
    h.usize(m.calib_b);
    h.usize(opt.n_configs);
    h.usize(opt.fp_epochs);
    h.usize(opt.qat_epochs);
    h.usize(opt.eval_n);
    h.u64(opt.seed);
    hash_trace_options(&mut h, &opt.trace);
    h.finish()
}

/// Handle over the stage graph: artifact cache + per-process memo +
/// shared computation counters. See the module docs for the idempotency
/// and exactly-once contract.
pub struct Pipeline {
    results_root: PathBuf,
    cache: ArtifactCache,
    counters: Arc<StageCounters>,
    memo_fp: RefCell<HashMap<Digest, Rc<ModelState>>>,
    memo_sens: RefCell<HashMap<Digest, Rc<SensitivityReport>>>,
}

impl Pipeline {
    /// Pipeline over `<results_root>/cache`.
    pub fn new(results_root: impl AsRef<Path>) -> Result<Pipeline> {
        Pipeline::with_counters(results_root, Arc::new(StageCounters::default()))
    }

    /// Pipeline sharing an existing counter set (worker pipelines of a
    /// parallel phase all report into their parent's counters).
    pub fn with_counters(
        results_root: impl AsRef<Path>,
        counters: Arc<StageCounters>,
    ) -> Result<Pipeline> {
        let results_root = results_root.as_ref().to_path_buf();
        let mut cache = ArtifactCache::new(results_root.join("cache"))?;
        cache.set_lease_config(LeaseConfig::from_env());
        Ok(Pipeline {
            results_root,
            cache,
            counters,
            memo_fp: RefCell::new(HashMap::new()),
            memo_sens: RefCell::new(HashMap::new()),
        })
    }

    /// Override the lease policy (tests shorten the TTL/poll/wait budget
    /// to exercise takeover paths in milliseconds).
    pub fn set_lease_config(&mut self, cfg: LeaseConfig) {
        self.cache.set_lease_config(cfg);
    }

    /// Pipeline over `$FITQ_RESULTS` (default `results/`), matching where
    /// the experiments drop their reports.
    pub fn from_env() -> Result<Pipeline> {
        Pipeline::new(results_root_from_env())
    }

    pub fn counters(&self) -> Arc<StageCounters> {
        self.counters.clone()
    }

    /// The results root this pipeline caches under (worker pipelines of a
    /// parallel phase are built over the same root).
    pub fn results_root(&self) -> &Path {
        &self.results_root
    }

    pub fn cache(&self) -> &ArtifactCache {
        &self.cache
    }

    /// Best-effort store: the artifact cache is an accelerator, not a
    /// correctness dependency, so a failed publish (disk full, injected
    /// fault) degrades to an uncached-but-correct run instead of aborting.
    fn store_stage(&self, kind: &str, schema: u32, key: &Digest, payload: &[u8]) {
        if let Err(e) = self.cache.store(kind, schema, key, payload) {
            eprintln!("  [warn] failed to store {kind} artifact ({e:#}); continuing uncached");
        }
    }

    /// Claim-coordinated compute-or-load of one stage artifact.
    ///
    /// Returns `(value, computed)` where `computed` is false when the value
    /// was decoded from a peer's published artifact. The sequence:
    ///
    /// 1. load — someone may already have published;
    /// 2. claim the key's lease; while another process holds it, poll for
    ///    the published artifact (counted in [`StageCounters::claim_waits`]);
    /// 3. on winning (fresh or by stale-lease takeover), re-check the cache
    ///    (the previous holder may have published between our miss and the
    ///    claim), else compute under `catch_unwind`, publish best-effort,
    ///    and release the lease;
    /// 4. if the wait budget (`LeaseConfig::max_wait`) is exhausted, compute
    ///    without a claim — duplicate work, identical bytes.
    ///
    /// A panicking compute surfaces as a typed error *after* the lease is
    /// released (release-on-drop), so a poisoned stage never wedges peers
    /// for longer than one poll interval.
    fn compute_exclusive<T>(
        &self,
        kind: &'static str,
        schema: u32,
        key: &Digest,
        try_load: impl Fn(&[u8]) -> Option<T>,
        encode: impl FnOnce(&T) -> Option<Vec<u8>>,
        compute: impl FnOnce() -> Result<T>,
    ) -> Result<(T, bool)> {
        if let Some(v) = self.cache.load(kind, schema, key).and_then(|b| try_load(&b)) {
            return Ok((v, false));
        }
        let lease = self.cache.lease_config();
        let deadline = Instant::now() + lease.max_wait;
        let mut waited = false;
        let guard: Option<LeaseGuard> = loop {
            match self.cache.try_claim(kind, key) {
                Ok(Claim::Won(g)) => break Some(g),
                Ok(Claim::Busy { .. }) => {}
                // claim-layer errors are policy failures, not correctness
                // failures: fall back to waiting, then to unguarded compute
                Err(e) => eprintln!("  [warn] claiming {kind} lease failed ({e:#}); waiting"),
            }
            if !waited {
                waited = true;
                self.counters.claim_waits.fetch_add(1, Ordering::Relaxed);
            }
            if Instant::now() >= deadline {
                eprintln!(
                    "  [warn] lease wait for {kind} exceeded {:?}; computing without a claim",
                    lease.max_wait
                );
                break None;
            }
            std::thread::sleep(lease.poll);
            if let Some(v) = self.cache.load(kind, schema, key).and_then(|b| try_load(&b)) {
                return Ok((v, false));
            }
        };
        let guard = match guard {
            Some(g) => {
                self.counters.claims_won.fetch_add(1, Ordering::Relaxed);
                if let Some(v) = self.cache.load(kind, schema, key).and_then(|b| try_load(&b)) {
                    g.release();
                    return Ok((v, false));
                }
                Some(g)
            }
            None => None,
        };
        let caught = catch_unwind(AssertUnwindSafe(|| {
            if fault::fires(site::STAGE_COMPUTE_PANIC) {
                panic!("injected fault: {}", site::STAGE_COMPUTE_PANIC);
            }
            compute()
        }));
        let out = match caught {
            Ok(Ok(v)) => {
                if let Some(payload) = encode(&v) {
                    self.store_stage(kind, schema, key, &payload);
                }
                Ok((v, true))
            }
            Ok(Err(e)) => Err(e.context(format!("{kind} stage failed"))),
            Err(p) => {
                Err(anyhow!("{kind} stage panicked: {}", panic_message(p.as_ref())))
            }
        };
        if let Some(g) = guard {
            g.release();
        }
        out
    }

    /// Load-or-train the FP checkpoint for `(model, epochs, seed)`.
    ///
    /// Training state is deterministic in the key (model init seed, data
    /// stream seed and epoch count all derive from it), so a cache hit
    /// replays the exact experiment inputs of the run that stored it.
    /// Pre-pipeline checkpoints under `results/ckpt/` are adopted into the
    /// cache when their parameter count still matches the manifest.
    pub fn train_fp(
        &self,
        rt: &Runtime,
        model: &str,
        epochs: usize,
        seed: u64,
    ) -> Result<Rc<ModelState>> {
        let key = train_fp_key(rt.backend_name(), rt.model(model)?, epochs, seed);
        if let Some(st) = self.memo_fp.borrow().get(&key) {
            return Ok(st.clone());
        }
        let n_params = rt.model(model)?.n_params;
        // legacy results/ckpt/ checkpoints predate the native backend, so
        // their provenance is necessarily PJRT — adopting one under a
        // native key would be exactly the cross-backend mixing the
        // backend-qualified digests forbid
        let adopted = if rt.backend_name() == "pjrt" {
            self.adopt_legacy_ckpt(model, epochs, seed, n_params, &key)?
        } else {
            None
        };
        let (st, computed) = match adopted {
            Some(st) => (st, false),
            None => self.compute_exclusive(
                KIND_TRAIN_FP,
                codec::CKPT_SCHEMA,
                &key,
                // undecodable or wrong-shape payloads fall through to recompute
                |bytes| {
                    ModelState::from_bytes(bytes, model)
                        .ok()
                        .filter(|st| st.n_params() == n_params)
                },
                |st| Some(st.to_bytes()),
                || {
                    let ds = dataset_for(rt, model, seed ^ 0xda7a)?;
                    let mut trainer = Trainer::new(rt, ds.as_ref());
                    let mut st = ModelState::init(rt, model, seed as u32)?;
                    let losses = trainer.train(&mut st, epochs)?;
                    eprintln!(
                        "  [{model}] FP trained {epochs} epochs, loss {:.4} -> {:.4}",
                        losses.first().copied().unwrap_or(f64::NAN),
                        losses.last().copied().unwrap_or(f64::NAN)
                    );
                    Ok(st)
                },
            )?,
        };
        if computed {
            self.counters.train_fp.fetch_add(1, Ordering::Relaxed);
        }
        let rc = Rc::new(st);
        self.memo_fp.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Migration path for checkpoints written by the pre-pipeline
    /// `get_trained` (`results/ckpt/{model}_s{seed}_e{epochs}.bin`): adopt
    /// them into the digest-validated cache instead of retraining.
    fn adopt_legacy_ckpt(
        &self,
        model: &str,
        epochs: usize,
        seed: u64,
        n_params: usize,
        key: &Digest,
    ) -> Result<Option<ModelState>> {
        let legacy = self
            .results_root
            .join("ckpt")
            .join(format!("{model}_s{seed}_e{epochs}.bin"));
        if !legacy.exists() {
            return Ok(None);
        }
        match ModelState::load(&legacy, model) {
            Ok(st) if st.n_params() == n_params => {
                eprintln!("  [{model}] adopting legacy checkpoint {}", legacy.display());
                self.store_stage(KIND_TRAIN_FP, codec::CKPT_SCHEMA, key, &st.to_bytes());
                Ok(Some(st))
            }
            _ => Ok(None),
        }
    }

    /// Gather (or load) the full sensitivity report over the FP checkpoint
    /// of `(model, fp_epochs, seed)` — EF traces, weight/activation
    /// ranges, BN scales. Calibration uses the model's own `calib_b` test
    /// prefix, so the report depends only on the key.
    pub fn sensitivity(
        &self,
        rt: &Runtime,
        model: &str,
        fp_epochs: usize,
        seed: u64,
        trace: TraceOptions,
    ) -> Result<Rc<SensitivityReport>> {
        let key = sensitivity_key(rt.backend_name(), rt.model(model)?, fp_epochs, seed, &trace);
        if let Some(rep) = self.memo_sens.borrow().get(&key) {
            return Ok(rep.clone());
        }
        let calib_b = rt.model(model)?.calib_b;
        // holding the sensitivity lease while waiting on the train_fp lease
        // cannot deadlock: lease acquisition follows the stage DAG, so no
        // process ever holds a downstream key while waiting on an upstream
        // holder of *its* key
        let (rep, computed) = self.compute_exclusive(
            KIND_SENSITIVITY,
            codec::SENSITIVITY_SCHEMA,
            &key,
            |bytes| codec::decode_sensitivity(bytes).ok(),
            |rep| Some(codec::encode_sensitivity(rep)),
            || {
                let st = self.train_fp(rt, model, fp_epochs, seed)?;
                let ds = dataset_for(rt, model, seed ^ 0xda7a)?;
                let trainer = Trainer::new(rt, ds.as_ref());
                let calib = EvalSet::materialize(ds.as_ref(), calib_b);
                gather(&trainer, ds.as_ref(), &st, &calib, trace)
            },
        )?;
        if computed {
            self.counters.sensitivity.fetch_add(1, Ordering::Relaxed);
        }
        let rc = Rc::new(rep);
        self.memo_sens.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Probe: is the sensitivity report for `(model, fp_epochs, seed, trace)`
    /// already available without computing anything — either memoized in
    /// this process or published (and decodable) in the artifact store?
    /// Never trains, never takes a lease; safe to call from a serving
    /// thread that wants to label a request cold-cached vs cold-computed.
    pub fn sensitivity_published(
        &self,
        rt: &Runtime,
        model: &str,
        fp_epochs: usize,
        seed: u64,
        trace: TraceOptions,
    ) -> Result<bool> {
        let key = sensitivity_key(rt.backend_name(), rt.model(model)?, fp_epochs, seed, &trace);
        if self.memo_sens.borrow().contains_key(&key) {
            return Ok(true);
        }
        Ok(self
            .cache
            .load(KIND_SENSITIVITY, codec::SENSITIVITY_SCHEMA, &key)
            .is_some_and(|bytes| codec::decode_sensitivity(&bytes).is_ok()))
    }

    /// Run (or load) a batch of trace estimations over the FP checkpoint
    /// of `(model, fp_epochs, seed)`, in `specs` order. Cached specs are
    /// served from the store; only the misses are fanned over `jobs`
    /// workers via [`TraceEngine::run_many`] — bit-identical either way,
    /// wall-clock `iter_time_s` included (it is part of the cached value,
    /// which is what makes warm experiment reruns byte-identical).
    pub fn traces_many(
        &self,
        rt: &Runtime,
        model: &str,
        fp_epochs: usize,
        seed: u64,
        specs: &[(Estimator, TraceOptions)],
        jobs: usize,
    ) -> Result<Vec<TraceResult>> {
        let keys: Vec<Digest> = {
            let mm = rt.model(model)?;
            specs
                .iter()
                .map(|(est, opt)| trace_key(rt.backend_name(), mm, fp_epochs, seed, *est, opt))
                .collect()
        };
        let load = |i: usize| {
            self.cache
                .load(KIND_TRACES, codec::TRACE_SCHEMA, &keys[i])
                .and_then(|b| codec::decode_trace(&b).ok())
        };
        let mut out: Vec<Option<TraceResult>> = (0..specs.len()).map(|i| load(i)).collect();
        let hits = out.iter().filter(|r| r.is_some()).count();
        if hits > 0 {
            // cached runs carry the wall-clock of their original
            // measurement conditions; flag that for timing-bearing tables
            eprintln!(
                "  [{model}] {hits}/{} trace runs from cache (ms/iter columns reflect \
                 the run that computed them; delete results/cache to remeasure)",
                specs.len()
            );
        }
        let missing: Vec<usize> = (0..specs.len()).filter(|&i| out[i].is_none()).collect();
        if missing.is_empty() {
            return Ok(out.into_iter().map(|r| r.expect("all trace slots filled")).collect());
        }
        // claim every miss up front; misses another process is already
        // computing are deferred and polled after our own batch runs
        let mut first: Vec<(usize, Option<LeaseGuard>)> = Vec::new();
        let mut deferred: Vec<usize> = Vec::new();
        for &i in &missing {
            match self.cache.try_claim(KIND_TRACES, &keys[i]) {
                Ok(Claim::Won(g)) => {
                    self.counters.claims_won.fetch_add(1, Ordering::Relaxed);
                    // the previous holder may have published before dying
                    if let Some(r) = load(i) {
                        g.release();
                        out[i] = Some(r);
                    } else {
                        first.push((i, Some(g)));
                    }
                }
                Ok(Claim::Busy { .. }) => deferred.push(i),
                Err(e) => {
                    eprintln!("  [warn] claiming a trace lease failed ({e:#}); waiting");
                    deferred.push(i);
                }
            }
        }
        self.compute_trace_batch(rt, model, fp_epochs, seed, specs, &keys, first, jobs, &mut out)?;
        // wait out the peers computing the deferred keys; takeover (holder
        // died) and wait-budget exhaustion both fall back to a local batch
        if !deferred.is_empty() {
            self.counters.claim_waits.fetch_add(deferred.len() as u64, Ordering::Relaxed);
        }
        let lease = self.cache.lease_config();
        let deadline = Instant::now() + lease.max_wait;
        let mut second: Vec<(usize, Option<LeaseGuard>)> = Vec::new();
        for i in deferred {
            loop {
                if let Some(r) = load(i) {
                    out[i] = Some(r);
                    break;
                }
                if let Ok(Claim::Won(g)) = self.cache.try_claim(KIND_TRACES, &keys[i]) {
                    self.counters.claims_won.fetch_add(1, Ordering::Relaxed);
                    if let Some(r) = load(i) {
                        g.release();
                        out[i] = Some(r);
                    } else {
                        second.push((i, Some(g)));
                    }
                    break;
                }
                if Instant::now() >= deadline {
                    eprintln!(
                        "  [{model}] lease wait for a trace run exceeded {:?}; \
                         computing without a claim",
                        lease.max_wait
                    );
                    second.push((i, None));
                    break;
                }
                std::thread::sleep(lease.poll);
            }
        }
        self.compute_trace_batch(rt, model, fp_epochs, seed, specs, &keys, second, jobs, &mut out)?;
        Ok(out.into_iter().map(|r| r.expect("all trace slots filled")).collect())
    }

    /// Run one batch of trace estimations (the slots this process owns),
    /// publish each best-effort, and release the accompanying leases.
    /// Guards travel with their slot so an error drops (= releases) them.
    #[allow(clippy::too_many_arguments)]
    fn compute_trace_batch(
        &self,
        rt: &Runtime,
        model: &str,
        fp_epochs: usize,
        seed: u64,
        specs: &[(Estimator, TraceOptions)],
        keys: &[Digest],
        batch: Vec<(usize, Option<LeaseGuard>)>,
        jobs: usize,
        out: &mut [Option<TraceResult>],
    ) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let n = batch.len() as u64;
        let st = self.train_fp(rt, model, fp_epochs, seed)?;
        let ds = dataset_for(rt, model, seed ^ 0xda7a)?;
        let engine = TraceEngine::new(rt, ds.as_ref());
        let sub: Vec<(Estimator, TraceOptions)> =
            batch.iter().map(|(i, _)| specs[*i]).collect();
        let results = engine.run_many(model, &st.params, &sub, jobs)?;
        for ((i, guard), r) in batch.into_iter().zip(results) {
            self.store_stage(KIND_TRACES, codec::TRACE_SCHEMA, &keys[i], &codec::encode_trace(&r));
            if let Some(g) = guard {
                g.release();
            }
            out[i] = Some(r);
        }
        self.counters.traces.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }

    /// Cached study outcome table for `(model, opt)`, if present and valid.
    pub fn study_cached(
        &self,
        rt: &Runtime,
        model: &str,
        opt: &StudyOptions,
    ) -> Option<StudyResult> {
        let mm = rt.model(model).ok()?;
        let key = study_key(rt.backend_name(), mm, opt);
        let bytes = self.cache.load(KIND_STUDY, codec::STUDY_SCHEMA, &key)?;
        codec::decode_study(&bytes).ok()
    }

    /// Store a freshly computed study outcome table.
    pub fn study_store(
        &self,
        rt: &Runtime,
        model: &str,
        opt: &StudyOptions,
        res: &StudyResult,
    ) -> Result<()> {
        let key = study_key(rt.backend_name(), rt.model(model)?, opt);
        self.cache.store(KIND_STUDY, codec::STUDY_SCHEMA, &key, &codec::encode_study(res))?;
        self.counters.study.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Claim-coordinated run of the full study for `(model, opt)`: load the
    /// cached table, else win the study lease and run `compute` (peers
    /// poll-and-decode instead of sweeping). A *degraded* study — one with
    /// a non-empty failure list — is returned to the caller but never
    /// cached, so a rerun after the fault is gone recomputes the complete
    /// table instead of serving the degraded one forever.
    pub fn study_coordinated(
        &self,
        rt: &Runtime,
        model: &str,
        opt: &StudyOptions,
        compute: impl FnOnce() -> Result<StudyResult>,
    ) -> Result<StudyResult> {
        let key = study_key(rt.backend_name(), rt.model(model)?, opt);
        let (res, computed) = self.compute_exclusive(
            KIND_STUDY,
            codec::STUDY_SCHEMA,
            &key,
            |bytes| codec::decode_study(bytes).ok(),
            |res| res.failures.is_empty().then(|| codec::encode_study(res)),
            compute,
        )?;
        if computed {
            self.counters.study.fetch_add(1, Ordering::Relaxed);
        } else {
            eprintln!("  [{model}] study loaded from cache");
        }
        Ok(res)
    }

    /// Materialize one declared stage (the prepass executor).
    pub fn ensure(&self, rt: &Runtime, req: &StageRequest) -> Result<()> {
        match req {
            StageRequest::TrainFp { model, epochs, seed } => {
                self.train_fp(rt, model, *epochs, *seed)?;
            }
            StageRequest::Traces { model, fp_epochs, seed, est, opt } => {
                self.traces_many(rt, model, *fp_epochs, *seed, &[(*est, *opt)], 1)?;
            }
            StageRequest::Sensitivity { model, fp_epochs, seed, trace } => {
                self.sensitivity(rt, model, *fp_epochs, *seed, *trace)?;
            }
        }
        Ok(())
    }
}

/// The results root the reports and the cache live under
/// (`$FITQ_RESULTS`, default `results`).
pub fn results_root_from_env() -> PathBuf {
    std::env::var_os("FITQ_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_fp(model: &str, epochs: usize, seed: u64) -> StageRequest {
        StageRequest::TrainFp { model: model.into(), epochs, seed }
    }

    #[test]
    fn plan_dedupes_and_ranks() {
        let trace = TraceOptions::default();
        let reqs = vec![
            StageRequest::Sensitivity {
                model: "m".into(),
                fp_epochs: 30,
                seed: 0,
                trace,
            },
            req_fp("m", 30, 0),
            req_fp("m", 30, 0),
            req_fp("a", 15, 0),
            StageRequest::Sensitivity {
                model: "m".into(),
                fp_epochs: 30,
                seed: 0,
                trace,
            },
        ];
        let plan = StageRequest::plan(reqs);
        assert_eq!(plan.len(), 3, "duplicates collapse: {plan:?}");
        assert_eq!(plan[0], req_fp("a", 15, 0));
        assert_eq!(plan[1], req_fp("m", 30, 0));
        assert_eq!(plan[2].rank(), 1, "checkpoints sort before consumers");
    }

    #[test]
    fn plan_is_order_invariant() {
        let mut reqs = vec![req_fp("c", 1, 2), req_fp("a", 1, 2), req_fp("b", 9, 9)];
        let forward = StageRequest::plan(reqs.clone());
        reqs.reverse();
        assert_eq!(StageRequest::plan(reqs), forward);
    }

    #[test]
    fn stage_keys_separate_every_field() {
        // a minimal manifest stand-in is overkill here; the key functions
        // are pure over (name, sizes, scalars), so exercise them via the
        // hasher contract instead: distinct field values => distinct keys
        let base = TraceOptions::default();
        let mut other = base;
        other.seed = 1;
        let mut h1 = Hasher::new();
        hash_trace_options(&mut h1, &base);
        let mut h2 = Hasher::new();
        hash_trace_options(&mut h2, &other);
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn backend_identity_separates_every_key() {
        // the native manifest doubles as a convenient real ModelManifest
        let m = crate::native::model::Plan::new(crate::native::model::STUDY_CNNS[0]).manifest();
        let t = TraceOptions::default();
        assert_ne!(train_fp_key("native", &m, 3, 0), train_fp_key("pjrt", &m, 3, 0));
        assert_ne!(
            trace_key("native", &m, 3, 0, Estimator::EmpiricalFisher, &t),
            trace_key("pjrt", &m, 3, 0, Estimator::EmpiricalFisher, &t)
        );
        assert_ne!(
            sensitivity_key("native", &m, 3, 0, &t),
            sensitivity_key("pjrt", &m, 3, 0, &t)
        );
        let opt = StudyOptions::default();
        assert_ne!(study_key("native", &m, &opt), study_key("pjrt", &m, &opt));
        // jobs stays excluded from the study key at any backend
        let opt8 = StudyOptions { jobs: 8, ..StudyOptions::default() };
        assert_eq!(study_key("native", &m, &opt), study_key("native", &m, &opt8));
    }

    #[test]
    fn optrace_key_separates_backend_model_and_workload_only() {
        let m = crate::native::model::Plan::new(crate::native::model::STUDY_CNNS[0]).manifest();
        let m2 = crate::native::model::Plan::new(crate::native::model::STUDY_CNNS[2]).manifest();
        let k = optrace_key("native", &m, "train_epoch");
        assert_eq!(k, optrace_key("native", &m, "train_epoch"), "pure in its inputs");
        assert_ne!(k, optrace_key("pjrt", &m, "train_epoch"));
        assert_ne!(k, optrace_key("native", &m2, "train_epoch"));
        assert_ne!(k, optrace_key("native", &m, "study"));
    }

    #[test]
    fn counters_start_at_zero() {
        let c = StageCounters::default();
        assert_eq!(
            (
                c.train_fp_computed(),
                c.traces_computed(),
                c.sensitivity_computed(),
                c.study_computed(),
                c.claims_won(),
                c.claim_waits()
            ),
            (0, 0, 0, 0, 0, 0)
        );
    }
}
