//! Deterministic fault injection for the artifact store and worker pool.
//!
//! Every failure path the pipeline claims to survive — torn writes,
//! rename failures, header corruption, abandoned leases, mid-sweep worker
//! panics — has a *named injection site* in `cache.rs` / `stages.rs` /
//! `parallel.rs`. A [`FaultPlan`] arms a subset of those sites with a
//! firing rule (first hit, Nth hit, or every hit); code at a site asks
//! [`fires`] whether to inject. Unarmed, `fires` is a single relaxed
//! atomic load returning `false`, so the hooks compile to effectively
//! nothing on the production path.
//!
//! Arming is process-global:
//!
//! - the CLI arms from `$FITQ_FAULTS` at startup (`site`, `site@N` for
//!   the Nth hit, `site@*` for every hit, comma-separated) — this is how
//!   the CI fault smoke drives the real binary;
//! - tests use [`scoped`], which holds a global lock for the scope's
//!   lifetime so concurrently running fault tests serialize instead of
//!   contaminating each other, and disarms on drop.
//!
//! The hit/fired counters are part of the contract: a fault test asserts
//! its armed site actually fired, so a refactor that silently removes an
//! injection site fails the suite instead of quietly passing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use anyhow::{bail, Result};

/// Injection-site names, one constant per fault the harness can inject.
/// `SITES` is the registry the fault suite iterates over.
pub mod site {
    /// Entry bytes truncated to half before the tmp-file write (torn write
    /// published by a non-atomic filesystem / lost tail on power cut).
    pub const CACHE_STORE_SHORT_WRITE: &str = "cache.store.short_write";
    /// One container-header byte flipped before the write.
    pub const CACHE_STORE_HEADER_CORRUPT: &str = "cache.store.header_corrupt";
    /// One payload byte flipped before the write (header parses, payload
    /// digest must catch it).
    pub const CACHE_STORE_PAYLOAD_CORRUPT: &str = "cache.store.payload_corrupt";
    /// The tmp-file write itself fails (disk full / EIO); nothing is left.
    pub const CACHE_STORE_TMP_WRITE_FAIL: &str = "cache.store.tmp_write_fail";
    /// The publishing rename fails; the orphaned tmp file stays behind
    /// for `cache gc` to reap.
    pub const CACHE_STORE_RENAME_FAIL: &str = "cache.store.rename_fail";
    /// An entry read fails outright (EIO) — load degrades to a miss.
    pub const CACHE_LOAD_READ_FAIL: &str = "cache.load.read_fail";
    /// An entry read returns only a prefix of the file (torn read).
    pub const CACHE_LOAD_TORN_READ: &str = "cache.load.torn_read";
    /// The claimant writes its lease, then dies without computing or
    /// releasing — peers must take the lease over once it expires.
    pub const LEASE_ACQUIRE_HOLDER_DEATH: &str = "lease.acquire.holder_death";
    /// The lease record is corrupted as written — peers must treat it as
    /// stale-and-reapable, never as held.
    pub const LEASE_ACQUIRE_RECORD_CORRUPT: &str = "lease.acquire.record_corrupt";
    /// Releasing the lease fails to unlink it — the abandoned lease must
    /// age out via its expiry, not wedge the key.
    pub const LEASE_RELEASE_UNLINK_FAIL: &str = "lease.release.unlink_fail";
    /// Reaping a stale lease during takeover fails once — the claimant
    /// must retry, not give up or corrupt the store.
    pub const LEASE_TAKEOVER_REAP_FAIL: &str = "lease.takeover.reap_fail";
    /// A pooled worker job panics mid-flight — `run_pool_fallible` must
    /// degrade that one job to a typed error.
    pub const PARALLEL_JOB_PANIC: &str = "parallel.job.panic";
    /// A stage computation panics under the claim guard — the guard must
    /// release on unwind and the stage must surface a typed error.
    pub const STAGE_COMPUTE_PANIC: &str = "stage.compute.panic";
    /// The autotuner wins the tuning lease but dies before publishing its
    /// route table — the run must continue on the unpersisted table and a
    /// later resolver must be able to tune-and-publish cleanly.
    pub const TUNER_PUBLISH_FAIL: &str = "tuner.publish.fail";
}

/// Every registered injection site (the fault suite's iteration set).
pub const SITES: &[&str] = &[
    site::CACHE_STORE_SHORT_WRITE,
    site::CACHE_STORE_HEADER_CORRUPT,
    site::CACHE_STORE_PAYLOAD_CORRUPT,
    site::CACHE_STORE_TMP_WRITE_FAIL,
    site::CACHE_STORE_RENAME_FAIL,
    site::CACHE_LOAD_READ_FAIL,
    site::CACHE_LOAD_TORN_READ,
    site::LEASE_ACQUIRE_HOLDER_DEATH,
    site::LEASE_ACQUIRE_RECORD_CORRUPT,
    site::LEASE_RELEASE_UNLINK_FAIL,
    site::LEASE_TAKEOVER_REAP_FAIL,
    site::PARALLEL_JOB_PANIC,
    site::STAGE_COMPUTE_PANIC,
    site::TUNER_PUBLISH_FAIL,
];

/// When an armed site injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Rule {
    /// Fire exactly once, on the Nth hit (1-based).
    Nth(u64),
    /// Fire on every hit.
    Every,
}

/// A set of armed sites with firing rules. Parsed from `$FITQ_FAULTS` or
/// built programmatically; arming validates site names fail-closed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    entries: Vec<(String, Rule)>,
}

impl FaultPlan {
    /// Fire `site` once, on its first hit.
    pub fn single(site: &str) -> FaultPlan {
        FaultPlan { entries: vec![(site.to_string(), Rule::Nth(1))] }
    }

    /// Parse a `$FITQ_FAULTS` spec: comma-separated `site` (first hit),
    /// `site@N` (Nth hit, 1-based), or `site@*` (every hit). Unknown site
    /// names are an error — a typo must not silently disarm a fault run.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut entries = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, rule) = match part.split_once('@') {
                None => (part, Rule::Nth(1)),
                Some((name, "*")) => (name, Rule::Every),
                Some((name, n)) => {
                    let n: u64 = n
                        .parse()
                        .ok()
                        .filter(|&n| n >= 1)
                        .ok_or_else(|| anyhow::anyhow!("bad fault hit count in {part:?}"))?;
                    (name, Rule::Nth(n))
                }
            };
            if !SITES.contains(&name) {
                bail!(
                    "unknown fault site {name:?}; registered sites: {}",
                    SITES.join(", ")
                );
            }
            entries.push((name.to_string(), rule));
        }
        Ok(FaultPlan { entries })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[derive(Debug, Default)]
struct State {
    plan: FaultPlan,
    /// site -> (times reached, times fired)
    counts: HashMap<&'static str, (u64, u64)>,
}

/// Fast-path gate: `fires` returns immediately when unarmed.
static ARMED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<State>> = Mutex::new(None);
/// Held by [`scoped`] for a fault scope's whole lifetime, so concurrent
/// fault tests in one process serialize instead of cross-firing.
static SCOPE_LOCK: Mutex<()> = Mutex::new(());

fn state() -> MutexGuard<'static, Option<State>> {
    STATE.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Record a hit at `site` and report whether the armed plan injects here.
/// `site` must be one of [`SITES`] (hit accounting is keyed by the
/// canonical `&'static str`).
pub fn fires(site: &'static str) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let mut guard = state();
    let Some(st) = guard.as_mut() else {
        return false;
    };
    let counts = st.counts.entry(site).or_insert((0, 0));
    counts.0 += 1;
    let n = counts.0;
    let fire = st.plan.entries.iter().any(|(name, rule)| {
        name == site
            && match rule {
                Rule::Every => true,
                Rule::Nth(k) => *k == n,
            }
    });
    if fire {
        if let Some(c) = st.counts.get_mut(site) {
            c.1 += 1;
        }
    }
    fire
}

/// How many times `site` injected under the currently armed plan.
pub fn fired(site: &str) -> u64 {
    state()
        .as_ref()
        .and_then(|st| st.counts.get(site))
        .map(|&(_, fired)| fired)
        .unwrap_or(0)
}

pub fn is_armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm from `$FITQ_FAULTS` for the process lifetime (the CLI entry path).
/// Unset or empty leaves the harness disarmed; a malformed spec is an
/// error so a fault run can't silently become a clean run.
pub fn arm_from_env() -> Result<()> {
    let Some(spec) = std::env::var_os("FITQ_FAULTS") else {
        return Ok(());
    };
    let plan = FaultPlan::parse(&spec.to_string_lossy())?;
    if plan.is_empty() {
        return Ok(());
    }
    eprintln!("[fault] armed from $FITQ_FAULTS: {plan:?}");
    *state() = Some(State { plan, counts: HashMap::new() });
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Exclusive fault scope for tests: arms `plan`, serializes against every
/// other scope in the process, disarms and clears counters on drop.
pub fn scoped(plan: FaultPlan) -> FaultScope {
    let lock = SCOPE_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    *state() = Some(State { plan, counts: HashMap::new() });
    ARMED.store(true, Ordering::Relaxed);
    FaultScope { _lock: lock }
}

/// Guard returned by [`scoped`]; dropping it disarms the harness.
pub struct FaultScope {
    _lock: MutexGuard<'static, ()>,
}

impl FaultScope {
    /// Fired count for `site` within this scope.
    pub fn fired(&self, site: &str) -> u64 {
        fired(site)
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::Relaxed);
        *state() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_and_disarm_never_fire() {
        {
            let scope = scoped(FaultPlan::default());
            assert!(!fires(site::CACHE_STORE_SHORT_WRITE), "empty plan fires nothing");
            assert_eq!(scope.fired(site::CACHE_STORE_SHORT_WRITE), 0);
        }
        // scope dropped: fully disarmed again, counters cleared
        let scope = scoped(FaultPlan::default());
        assert!(is_armed());
        assert_eq!(scope.fired(site::CACHE_STORE_SHORT_WRITE), 0);
    }

    #[test]
    fn single_fires_exactly_once() {
        let scope = scoped(FaultPlan::single(site::CACHE_LOAD_READ_FAIL));
        assert!(fires(site::CACHE_LOAD_READ_FAIL), "first hit fires");
        assert!(!fires(site::CACHE_LOAD_READ_FAIL), "second hit does not");
        assert!(!fires(site::CACHE_LOAD_TORN_READ), "unarmed site never fires");
        assert_eq!(scope.fired(site::CACHE_LOAD_READ_FAIL), 1);
        assert_eq!(scope.fired(site::CACHE_LOAD_TORN_READ), 0);
    }

    #[test]
    fn nth_and_every_rules() {
        let plan = FaultPlan::parse(&format!(
            "{}@2, {}@*",
            site::CACHE_STORE_RENAME_FAIL,
            site::PARALLEL_JOB_PANIC
        ))
        .unwrap();
        let scope = scoped(plan);
        assert!(!fires(site::CACHE_STORE_RENAME_FAIL), "hit 1 of @2");
        assert!(fires(site::CACHE_STORE_RENAME_FAIL), "hit 2 of @2");
        assert!(!fires(site::CACHE_STORE_RENAME_FAIL), "hit 3 of @2");
        for _ in 0..3 {
            assert!(fires(site::PARALLEL_JOB_PANIC), "@* fires every hit");
        }
        assert_eq!(scope.fired(site::PARALLEL_JOB_PANIC), 3);
    }

    #[test]
    fn parse_rejects_unknown_sites_and_bad_counts() {
        assert!(FaultPlan::parse("no.such.site").is_err());
        assert!(FaultPlan::parse(&format!("{}@0", site::PARALLEL_JOB_PANIC)).is_err());
        assert!(FaultPlan::parse(&format!("{}@x", site::PARALLEL_JOB_PANIC)).is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        let p = FaultPlan::parse(&format!(" {} , {}@3 ", SITES[0], SITES[1])).unwrap();
        assert_eq!(p.entries.len(), 2);
    }

    #[test]
    fn sites_registry_is_unique() {
        let mut names: Vec<&str> = SITES.to_vec();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate site name");
        assert!(n >= 10, "acceptance floor: at least 10 registered sites");
    }
}
