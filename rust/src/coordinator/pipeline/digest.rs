//! Deterministic content digests for stage keys and cache payloads.
//!
//! FNV-1a in its 128-bit variant, streamed through a tiny typed writer so
//! every stage key is a pure function of the values fed in — not of struct
//! layout, platform, or pointer identity. 128 bits keeps accidental
//! collisions out of reach for any realistic number of cache entries while
//! staying dependency-free (the vendored set has no hash crate).
//!
//! The digest of a stage key is part of the on-disk cache contract
//! (`results/cache/<kind>_<digest>.bin`): changing the byte encoding of any
//! primitive below silently orphans every existing cache entry, so the
//! encodings are pinned by unit tests.

/// A 128-bit content digest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Digest(pub u128);

impl Digest {
    /// Lower-case hex form used in cache file names.
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }

    /// Inverse of [`hex`](Digest::hex): parse the lower-case 32-char file
    /// name form. `None` for anything else — used by `cache verify` to
    /// decide whether a `.bin` file is even addressable by the store.
    pub fn from_hex(hex: &str) -> Option<Digest> {
        if hex.len() != 32 || !hex.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f')) {
            return None;
        }
        u128::from_str_radix(hex, 16).ok().map(Digest)
    }

    /// The raw 16 bytes, little-endian (cache header form).
    pub fn to_le_bytes(self) -> [u8; 16] {
        self.0.to_le_bytes()
    }

    pub fn from_le_bytes(b: [u8; 16]) -> Digest {
        Digest(u128::from_le_bytes(b))
    }
}

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

/// Streaming FNV-1a-128 hasher with typed, length-prefixed primitives.
#[derive(Debug, Clone)]
pub struct Hasher(u128);

impl Default for Hasher {
    fn default() -> Self {
        Hasher(FNV128_OFFSET)
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher::default()
    }

    pub fn bytes(&mut self, data: &[u8]) -> &mut Self {
        for &b in data {
            self.0 ^= b as u128;
            self.0 = self.0.wrapping_mul(FNV128_PRIME);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    pub fn usize(&mut self, v: usize) -> &mut Self {
        self.u64(v as u64)
    }

    /// Hashes the IEEE-754 bit pattern, so `-0.0 != 0.0` and every NaN
    /// payload is distinct — exactly the identity the bit-replay contract
    /// wants.
    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    /// Length-prefixed, so `("ab", "c")` and `("a", "bc")` differ.
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    pub fn finish(&self) -> Digest {
        Digest(self.0)
    }
}

/// One-shot digest of a byte slice (cache payload checksums).
pub fn digest_bytes(data: &[u8]) -> Digest {
    Hasher::new().bytes(data).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv128_vectors_are_pinned() {
        // Pinned against an independent implementation: changing the
        // constants or the byte feed silently orphans every cache entry,
        // so this test fails loudly instead.
        assert_eq!(digest_bytes(b"").hex(), "6c62272e07bb014262b821756295c58d");
        assert_eq!(digest_bytes(b"fitq").hex(), "696a1d50c4757277b806e974d49234ff");
    }

    #[test]
    fn typed_encodings_are_pinned() {
        let mut h = Hasher::new();
        h.u64(7).str("fit");
        assert_eq!(h.finish().hex(), "f5e32390e200d40590c2a7578b2c07c0");
        let mut h = Hasher::new();
        h.f64(1.5);
        assert_eq!(h.finish().hex(), "9d30c2325565995be47dda5e4e7280c0");
    }

    #[test]
    fn length_prefix_separates_concatenations() {
        let d1 = Hasher::new().str("ab").str("c").finish();
        let d2 = Hasher::new().str("a").str("bc").finish();
        assert_ne!(d1, d2);
    }

    #[test]
    fn float_identity_is_bitwise() {
        let pos = Hasher::new().f64(0.0).finish();
        let neg = Hasher::new().f64(-0.0).finish();
        assert_ne!(pos, neg);
    }

    #[test]
    fn hex_roundtrips_le_bytes() {
        let d = digest_bytes(b"roundtrip");
        assert_eq!(Digest::from_le_bytes(d.to_le_bytes()), d);
        assert_eq!(d.hex().len(), 32);
        assert_eq!(Digest::from_hex(&d.hex()), Some(d));
    }

    #[test]
    fn from_hex_rejects_non_filename_forms() {
        let ok = digest_bytes(b"x").hex();
        assert!(Digest::from_hex(&ok).is_some());
        assert_eq!(Digest::from_hex(&ok[..31]), None, "short");
        assert_eq!(Digest::from_hex(&format!("{ok}0")), None, "long");
        assert_eq!(Digest::from_hex(&ok.to_uppercase()), None, "uppercase");
        assert_eq!(Digest::from_hex(&format!("+{}", &ok[..31])), None, "sign");
        assert_eq!(Digest::from_hex(&format!("g{}", &ok[..31])), None, "non-hex");
    }
}
