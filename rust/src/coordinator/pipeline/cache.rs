//! Content-addressed artifact store under `results/cache/`.
//!
//! Every entry is keyed by a [`Digest`](super::digest::Digest) of the full
//! input set of the stage that produced it (model identity, seed, epochs,
//! trace options, …) and stored as `<kind>_<key-hex>.bin` with a versioned
//! header:
//!
//! ```text
//! [magic "FITQCACH"][container u32][kind str][schema u32]
//! [key digest 16B][payload len u64][payload digest 16B][payload]
//! ```
//!
//! `load` re-validates *everything* — magic, container and schema versions,
//! kind, key digest, length, and the payload's own digest — and returns
//! `None` on any mismatch, so corrupt, truncated, renamed, or stale entries
//! degrade to a recompute, never to wrong results. Writes go through a
//! temp file + rename so a crash mid-write leaves no half-entry behind.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::codec::{ByteReader, ByteWriter};
use super::digest::{digest_bytes, Digest};

const MAGIC: &[u8; 8] = b"FITQCACH";
/// Version of the container layout itself (headers), independent of the
/// per-kind payload schema versions in `codec`.
pub const CONTAINER_VERSION: u32 = 1;

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory of digest-keyed, header-validated binary entries.
#[derive(Debug, Clone)]
pub struct ArtifactCache {
    dir: PathBuf,
}

impl ArtifactCache {
    pub fn new(dir: impl AsRef<Path>) -> Result<ArtifactCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(ArtifactCache { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// On-disk location of an entry (exists or not).
    pub fn entry_path(&self, kind: &str, key: &Digest) -> PathBuf {
        self.dir.join(format!("{kind}_{}.bin", key.hex()))
    }

    /// Write an entry atomically (temp file + rename). Overwrites any
    /// previous entry for the same `(kind, key)`.
    pub fn store(&self, kind: &str, schema: u32, key: &Digest, payload: &[u8]) -> Result<PathBuf> {
        let mut w = ByteWriter::new();
        w.raw(MAGIC);
        w.u32(CONTAINER_VERSION);
        w.str(kind);
        w.u32(schema);
        w.raw(&key.to_le_bytes());
        w.u64(payload.len() as u64);
        w.raw(&digest_bytes(payload).to_le_bytes());
        w.raw(payload);
        let path = self.entry_path(kind, key);
        let tmp = self.dir.join(format!(
            ".{kind}_{}.{}.{}.tmp",
            key.hex(),
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, w.into_bytes())
            .with_context(|| format!("writing cache entry {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cache entry {}", path.display()))?;
        Ok(path)
    }

    /// Load and fully validate an entry; any mismatch (missing file, bad
    /// magic, version skew, wrong kind/key, truncation, payload-digest
    /// mismatch) is a miss.
    pub fn load(&self, kind: &str, schema: u32, key: &Digest) -> Option<Vec<u8>> {
        let bytes = std::fs::read(self.entry_path(kind, key)).ok()?;
        Self::validate(&bytes, kind, schema, key).ok()
    }

    fn validate(bytes: &[u8], kind: &str, schema: u32, key: &Digest) -> Result<Vec<u8>> {
        let mut r = ByteReader::new(bytes);
        if r.raw(8)? != MAGIC {
            bail!("bad magic");
        }
        if r.u32()? != CONTAINER_VERSION {
            bail!("container version skew");
        }
        if r.str()? != kind {
            bail!("kind mismatch");
        }
        if r.u32()? != schema {
            bail!("schema version skew");
        }
        if Digest::from_le_bytes(r.raw(16)?.try_into().unwrap()) != *key {
            bail!("key digest mismatch");
        }
        let len = r.u64()? as usize;
        let stored = Digest::from_le_bytes(r.raw(16)?.try_into().unwrap());
        let payload = r.raw(len)?.to_vec();
        r.done()?;
        if digest_bytes(&payload) != stored {
            bail!("payload digest mismatch");
        }
        Ok(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::digest::Hasher;

    fn tmp_cache(tag: &str) -> ArtifactCache {
        let dir = std::env::temp_dir().join(format!("fitq_cache_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ArtifactCache::new(&dir).unwrap()
    }

    fn key(n: u64) -> Digest {
        Hasher::new().u64(n).finish()
    }

    #[test]
    fn roundtrip_hits() {
        let c = tmp_cache("roundtrip");
        let k = key(1);
        let payload = b"stage output bytes".to_vec();
        c.store("trace", 1, &k, &payload).unwrap();
        assert_eq!(c.load("trace", 1, &k), Some(payload));
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn missing_wrong_kind_or_wrong_key_miss() {
        let c = tmp_cache("miss");
        let k = key(2);
        assert_eq!(c.load("trace", 1, &k), None, "missing file");
        c.store("trace", 1, &k, b"x").unwrap();
        assert_eq!(c.load("sens", 1, &k), None, "different kind");
        assert_eq!(c.load("trace", 1, &key(3)), None, "different key");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn schema_bump_invalidates() {
        let c = tmp_cache("schema");
        let k = key(4);
        c.store("study", 1, &k, b"v1 payload").unwrap();
        assert!(c.load("study", 1, &k).is_some());
        assert_eq!(c.load("study", 2, &k), None, "bumped schema is a miss");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn truncated_and_corrupt_entries_miss() {
        let c = tmp_cache("corrupt");
        let k = key(5);
        let path = c.store("ckpt", 1, &k, b"a long enough payload").unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in [0, 4, 12, full.len() - 3] {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert_eq!(c.load("ckpt", 1, &k), None, "truncated at {cut}");
        }
        // flip one payload byte: header parses, payload digest catches it
        let mut flipped = full.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0xff;
        std::fs::write(&path, &flipped).unwrap();
        assert_eq!(c.load("ckpt", 1, &k), None, "payload bitflip");
        // restore and confirm it hits again
        std::fs::write(&path, &full).unwrap();
        assert!(c.load("ckpt", 1, &k).is_some());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn entry_paths_are_digest_addressed() {
        let c = tmp_cache("paths");
        let k = key(6);
        let p = c.entry_path("trace", &k);
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.starts_with("trace_"));
        assert!(name.ends_with(".bin"));
        assert!(name.contains(&k.hex()));
        std::fs::remove_dir_all(c.dir()).ok();
    }
}
