//! Content-addressed artifact store under `results/cache/`.
//!
//! Every entry is keyed by a [`Digest`](super::digest::Digest) of the full
//! input set of the stage that produced it (model identity, seed, epochs,
//! trace options, …) and stored as `<kind>_<key-hex>.bin` with a versioned
//! header:
//!
//! ```text
//! [magic "FITQCACH"][container u32][kind str][schema u32]
//! [key digest 16B][payload len u64][payload digest 16B][payload]
//! ```
//!
//! `load` re-validates *everything* — magic, container and schema versions,
//! kind, key digest, length, and the payload's own digest — and returns
//! `None` on any mismatch, so corrupt, truncated, renamed, or stale entries
//! degrade to a recompute, never to wrong results. Writes go through a
//! per-writer temp file (pid + counter suffix, so concurrent writers never
//! truncate each other's in-flight bytes) + rename, so a crash mid-write
//! leaves no half-entry behind.
//!
//! # Leases
//!
//! N processes sharing one cache dir coordinate cold stages through
//! `<kind>_<key-hex>.lease` files and [`try_claim`](ArtifactCache::try_claim):
//! an atomic create-new of the lease file wins the claim; the record inside
//! carries `(pid, monotonic token, expiry)` plus a self-digest, and peers
//! that lose the race poll the entry until the winner publishes or the
//! lease expires. A lease that is expired *or unparsable* is stale and
//! gets reaped (rename to a `.tmp` name, then unlink — only one reaper's
//! rename succeeds), after which the takeover retries the create-new.
//! [`LeaseGuard`] releases on drop — including on panic unwind — and only
//! unlinks the file if it still holds this guard's own `(pid, token)`.
//!
//! The contract is intentionally *exactly-once in the common case, at-least
//! once under faults*: artifacts are deterministic, stores are atomic, and
//! a duplicate computation publishes byte-identical content, so the rare
//! takeover race (a lease released and re-acquired in the instant between
//! a peer's staleness check and its reap rename) costs a redundant compute
//! and never a wrong or corrupt result.
//!
//! # Recovery
//!
//! [`verify`](ArtifactCache::verify) rescans the store and moves entries
//! that fail validation (or `.bin` files the store cannot even address)
//! into `quarantine/`; [`gc`](ArtifactCache::gc) reaps expired or mangled
//! leases and aged-out temp files; [`stats`](ArtifactCache::stats)
//! summarizes what is on disk. All three back the `fitq cache` CLI.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, bail, Context, Result};

use super::codec::{ByteReader, ByteWriter};
use super::digest::{digest_bytes, Digest};
use super::fault::{self, site};

const MAGIC: &[u8; 8] = b"FITQCACH";
/// Version of the container layout itself (headers), independent of the
/// per-kind payload schema versions in `codec`.
pub const CONTAINER_VERSION: u32 = 1;

const LEASE_MAGIC: &[u8; 8] = b"FITQLEAS";
/// Version of the lease-record layout.
pub const LEASE_VERSION: u32 = 1;

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);
/// Per-process monotonic lease token; `(pid, token)` identifies one
/// acquisition uniquely, so a guard never unlinks a lease it no longer
/// owns (e.g. after an expiry + takeover by a peer).
static LEASE_TOKEN: AtomicU64 = AtomicU64::new(1);

fn now_unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Timing policy for lease coordination. All three knobs have env
/// overrides (`FITQ_LEASE_TTL_MS`, `FITQ_LEASE_POLL_MS`,
/// `FITQ_LEASE_MAX_WAIT_MS`) so tests and operators can shrink or stretch
/// the windows without recompiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseConfig {
    /// How long a freshly written lease is considered held. Must exceed
    /// the slowest stage computation; an expired lease is taken over.
    pub ttl: Duration,
    /// Sleep between polls while waiting for a peer's computation.
    pub poll: Duration,
    /// Total time a non-holder waits before giving up on the peer and
    /// computing locally (the at-least-once fallback).
    pub max_wait: Duration,
}

impl Default for LeaseConfig {
    fn default() -> Self {
        LeaseConfig {
            ttl: Duration::from_secs(600),
            poll: Duration::from_millis(50),
            max_wait: Duration::from_secs(600),
        }
    }
}

impl LeaseConfig {
    /// Defaults with `FITQ_LEASE_{TTL,POLL,MAX_WAIT}_MS` applied on top.
    /// Unparsable values are ignored (the default wins) — lease timing is
    /// policy, not correctness, so this knob does not fail closed.
    pub fn from_env() -> LeaseConfig {
        fn ms(var: &str) -> Option<Duration> {
            std::env::var(var).ok()?.trim().parse::<u64>().ok().map(Duration::from_millis)
        }
        let d = LeaseConfig::default();
        LeaseConfig {
            ttl: ms("FITQ_LEASE_TTL_MS").unwrap_or(d.ttl),
            poll: ms("FITQ_LEASE_POLL_MS").unwrap_or(d.poll),
            max_wait: ms("FITQ_LEASE_MAX_WAIT_MS").unwrap_or(d.max_wait),
        }
    }
}

/// The record inside a lease file. Encoded with a trailing self-digest;
/// [`parse`](LeaseRecord::parse) fails closed, and *any* parse failure is
/// treated by readers as stale-and-reapable — a mangled lease can delay a
/// claim by one reap, never wedge a key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseRecord {
    pub pid: u32,
    pub token: u64,
    pub expires_unix_ms: u64,
}

impl LeaseRecord {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.raw(LEASE_MAGIC);
        w.u32(LEASE_VERSION);
        w.u32(self.pid);
        w.u64(self.token);
        w.u64(self.expires_unix_ms);
        let mut bytes = w.into_bytes();
        let digest = digest_bytes(&bytes);
        bytes.extend_from_slice(&digest.to_le_bytes());
        bytes
    }

    pub fn parse(bytes: &[u8]) -> Result<LeaseRecord> {
        let mut r = ByteReader::new(bytes);
        if r.raw(8)? != LEASE_MAGIC {
            bail!("bad lease magic");
        }
        if r.u32()? != LEASE_VERSION {
            bail!("lease version skew");
        }
        let rec = LeaseRecord { pid: r.u32()?, token: r.u64()?, expires_unix_ms: r.u64()? };
        let stored = Digest::from_le_bytes(r.raw(16)?.try_into().unwrap());
        r.done()?;
        let body_len = bytes.len() - 16;
        if digest_bytes(&bytes[..body_len]) != stored {
            bail!("lease record digest mismatch");
        }
        Ok(rec)
    }

    pub fn expired(&self, now_ms: u64) -> bool {
        self.expires_unix_ms <= now_ms
    }
}

/// Outcome of a single (non-blocking) claim attempt.
#[derive(Debug)]
pub enum Claim {
    /// This caller holds the lease and must compute + publish (then
    /// release, or let the guard's drop release on unwind).
    Won(LeaseGuard),
    /// A peer holds a valid lease; poll the cache entry and retry after
    /// `expires_unix_ms` if it never appears.
    Busy { expires_unix_ms: u64 },
}

/// Held lease; releasing unlinks the file iff it still contains this
/// guard's `(pid, token)`. Drop releases too, so a panicking stage
/// computation cannot leave the key wedged for a full TTL.
#[derive(Debug)]
pub struct LeaseGuard {
    path: PathBuf,
    pid: u32,
    token: u64,
    released: bool,
}

impl LeaseGuard {
    /// Explicit release (same as drop, but callable at the natural point
    /// right after the entry is published).
    pub fn release(mut self) {
        self.release_inner();
    }

    fn release_inner(&mut self) {
        if self.released {
            return;
        }
        self.released = true;
        if fault::fires(site::LEASE_RELEASE_UNLINK_FAIL) {
            // Injected: the unlink is lost. The abandoned lease must age
            // out via its expiry, not wedge the key forever.
            return;
        }
        // Only unlink our own record — after an expiry + takeover the
        // path may hold a peer's fresh lease.
        let ours = std::fs::read(&self.path)
            .ok()
            .and_then(|b| LeaseRecord::parse(&b).ok())
            .is_some_and(|rec| rec.pid == self.pid && rec.token == self.token);
        if ours {
            std::fs::remove_file(&self.path).ok();
        }
    }
}

impl Drop for LeaseGuard {
    fn drop(&mut self) {
        self.release_inner();
    }
}

/// A directory of digest-keyed, header-validated binary entries.
#[derive(Debug, Clone)]
pub struct ArtifactCache {
    dir: PathBuf,
    lease: LeaseConfig,
}

impl ArtifactCache {
    pub fn new(dir: impl AsRef<Path>) -> Result<ArtifactCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(ArtifactCache { dir, lease: LeaseConfig::default() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn lease_config(&self) -> LeaseConfig {
        self.lease
    }

    pub fn set_lease_config(&mut self, cfg: LeaseConfig) {
        self.lease = cfg;
    }

    /// On-disk location of an entry (exists or not).
    pub fn entry_path(&self, kind: &str, key: &Digest) -> PathBuf {
        self.dir.join(format!("{kind}_{}.bin", key.hex()))
    }

    /// On-disk location of the lease coordinating an entry's computation.
    pub fn lease_path(&self, kind: &str, key: &Digest) -> PathBuf {
        self.dir.join(format!("{kind}_{}.lease", key.hex()))
    }

    /// Unique in-flight temp name for an entry write: pid + per-process
    /// counter suffix, so concurrent writers (threads *or* processes)
    /// never collide on the same temp path.
    fn tmp_path(&self, kind: &str, key: &Digest) -> PathBuf {
        self.dir.join(format!(
            ".{kind}_{}.{}.{}.tmp",
            key.hex(),
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ))
    }

    /// Write an entry atomically (temp file + rename). Overwrites any
    /// previous entry for the same `(kind, key)`.
    pub fn store(&self, kind: &str, schema: u32, key: &Digest, payload: &[u8]) -> Result<PathBuf> {
        let mut w = ByteWriter::new();
        w.raw(MAGIC);
        w.u32(CONTAINER_VERSION);
        w.str(kind);
        w.u32(schema);
        w.raw(&key.to_le_bytes());
        w.u64(payload.len() as u64);
        w.raw(&digest_bytes(payload).to_le_bytes());
        w.raw(payload);
        let mut bytes = w.into_bytes();
        // Injection sites: the first three publish a *corrupt* entry (the
        // write "succeeds" but the bytes are wrong — torn tail, flipped
        // header byte, flipped payload byte); load-side validation must
        // turn each into a miss. The last two fail the write itself.
        if fault::fires(site::CACHE_STORE_SHORT_WRITE) {
            bytes.truncate(bytes.len() / 2);
        }
        if fault::fires(site::CACHE_STORE_HEADER_CORRUPT) {
            bytes[9] ^= 0xff; // inside the container-version u32
        }
        if fault::fires(site::CACHE_STORE_PAYLOAD_CORRUPT) {
            let last = bytes.len() - 1;
            bytes[last] ^= 0xff;
        }
        if fault::fires(site::CACHE_STORE_TMP_WRITE_FAIL) {
            bail!("injected fault: cache tmp write failed for {kind}_{}", key.hex());
        }
        let path = self.entry_path(kind, key);
        let tmp = self.tmp_path(kind, key);
        std::fs::write(&tmp, bytes)
            .with_context(|| format!("writing cache entry {}", tmp.display()))?;
        if fault::fires(site::CACHE_STORE_RENAME_FAIL) {
            // The orphaned temp file stays behind — `cache gc` fodder.
            bail!("injected fault: cache publish rename failed for {}", tmp.display());
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cache entry {}", path.display()))?;
        Ok(path)
    }

    /// Load and fully validate an entry; any mismatch (missing file, bad
    /// magic, version skew, wrong kind/key, truncation, payload-digest
    /// mismatch) is a miss.
    pub fn load(&self, kind: &str, schema: u32, key: &Digest) -> Option<Vec<u8>> {
        if fault::fires(site::CACHE_LOAD_READ_FAIL) {
            return None; // injected EIO: degrade to a miss
        }
        let mut bytes = std::fs::read(self.entry_path(kind, key)).ok()?;
        if fault::fires(site::CACHE_LOAD_TORN_READ) {
            bytes.truncate(bytes.len() / 2);
        }
        Self::validate(&bytes, kind, Some(schema), key).ok()
    }

    fn validate(bytes: &[u8], kind: &str, schema: Option<u32>, key: &Digest) -> Result<Vec<u8>> {
        let mut r = ByteReader::new(bytes);
        if r.raw(8)? != MAGIC {
            bail!("bad magic");
        }
        if r.u32()? != CONTAINER_VERSION {
            bail!("container version skew");
        }
        if r.str()? != kind {
            bail!("kind mismatch");
        }
        let got_schema = r.u32()?;
        if schema.is_some_and(|s| s != got_schema) {
            bail!("schema version skew");
        }
        if Digest::from_le_bytes(r.raw(16)?.try_into().unwrap()) != *key {
            bail!("key digest mismatch");
        }
        let len = r.u64()? as usize;
        let stored = Digest::from_le_bytes(r.raw(16)?.try_into().unwrap());
        let payload = r.raw(len)?.to_vec();
        r.done()?;
        if digest_bytes(&payload) != stored {
            bail!("payload digest mismatch");
        }
        Ok(payload)
    }

    /// One non-blocking claim pass over `(kind, key)`'s lease: win it,
    /// report it busy, or (transparently) reap a stale lease and retry the
    /// create, a bounded number of times. Never sleeps — the block/poll
    /// loop lives in the caller so it can interleave cache polls.
    pub fn try_claim(&self, kind: &str, key: &Digest) -> Result<Claim> {
        let path = self.lease_path(kind, key);
        // Bounded retries: each iteration either creates the lease or
        // observes/reaps an existing one. Contention can consume
        // iterations, so on exhaustion we report Busy (callers poll and
        // come back) rather than erroring.
        for _ in 0..8 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let rec = LeaseRecord {
                        pid: std::process::id(),
                        token: LEASE_TOKEN.fetch_add(1, Ordering::Relaxed),
                        expires_unix_ms: now_unix_ms() + self.lease.ttl.as_millis() as u64,
                    };
                    let mut bytes = rec.encode();
                    if fault::fires(site::LEASE_ACQUIRE_RECORD_CORRUPT) {
                        let mid = bytes.len() / 2;
                        bytes[mid] ^= 0xff;
                    }
                    f.write_all(&bytes)
                        .with_context(|| format!("writing lease {}", path.display()))?;
                    drop(f);
                    if fault::fires(site::LEASE_ACQUIRE_HOLDER_DEATH) {
                        // Injected: the holder dies after writing its
                        // lease — no guard, no release. Peers (and this
                        // process's own retries) must take over after TTL.
                        bail!(
                            "injected fault: lease holder died before releasing {}",
                            path.display()
                        );
                    }
                    return Ok(Claim::Won(LeaseGuard {
                        path,
                        pid: rec.pid,
                        token: rec.token,
                        released: false,
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let now = now_unix_ms();
                    let held = std::fs::read(&path)
                        .ok()
                        .and_then(|b| LeaseRecord::parse(&b).ok())
                        .filter(|rec| !rec.expired(now));
                    if let Some(rec) = held {
                        return Ok(Claim::Busy { expires_unix_ms: rec.expires_unix_ms });
                    }
                    // Stale (expired) or unparsable (corrupt / torn /
                    // foreign bytes): reap and retry. Rename first so only
                    // one of several concurrent reapers proceeds.
                    if fault::fires(site::LEASE_TAKEOVER_REAP_FAIL) {
                        continue; // injected: this reap attempt is lost
                    }
                    let reap = self.dir.join(format!(
                        ".{kind}_{}.reap.{}.{}.tmp",
                        key.hex(),
                        std::process::id(),
                        TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
                    ));
                    if std::fs::rename(&path, &reap).is_ok() {
                        std::fs::remove_file(&reap).ok();
                    }
                    // Either way, loop: create_new decides the next winner.
                }
                Err(e) => {
                    return Err(anyhow!(e).context(format!("creating lease {}", path.display())));
                }
            }
        }
        Ok(Claim::Busy { expires_unix_ms: now_unix_ms() })
    }

    /// Scan every `.bin` entry, re-validating headers and payload digests
    /// (schema-agnostic: version skew is staleness, not corruption), and
    /// move entries that fail — or `.bin` files whose names the store
    /// cannot even address — into `quarantine/`. Read-only for valid
    /// entries; never deletes bytes, only relocates them.
    pub fn verify(&self) -> Result<VerifyReport> {
        let mut report = VerifyReport::default();
        for (path, name) in self.scan(".bin")? {
            let ok = match parse_entry_name(&name) {
                Some((kind, key)) => std::fs::read(&path)
                    .ok()
                    .and_then(|bytes| Self::validate(&bytes, &kind, None, &key).ok())
                    .is_some(),
                None => false, // unaddressable .bin in the store's namespace
            };
            if ok {
                report.valid += 1;
            } else {
                let qdir = self.dir.join("quarantine");
                std::fs::create_dir_all(&qdir)
                    .with_context(|| format!("creating {}", qdir.display()))?;
                let dest = qdir.join(&name);
                std::fs::rename(&path, &dest)
                    .with_context(|| format!("quarantining {}", path.display()))?;
                report.quarantined.push(dest);
            }
        }
        Ok(report)
    }

    /// Reap expired or unparsable leases and temp files older than
    /// `tmp_max_age` (orphans from crashed or fault-injected writers).
    /// Live leases are counted but left alone.
    pub fn gc(&self, tmp_max_age: Duration) -> Result<GcReport> {
        let mut report = GcReport::default();
        let now = now_unix_ms();
        for (path, _) in self.scan(".lease")? {
            let live = std::fs::read(&path)
                .ok()
                .and_then(|b| LeaseRecord::parse(&b).ok())
                .is_some_and(|rec| !rec.expired(now));
            if live {
                report.leases_live += 1;
            } else if std::fs::remove_file(&path).is_ok() {
                report.leases_reaped += 1;
            }
        }
        for (path, _) in self.scan(".tmp")? {
            let old = std::fs::metadata(&path)
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| SystemTime::now().duration_since(t).ok())
                .is_some_and(|age| age >= tmp_max_age);
            if old && std::fs::remove_file(&path).is_ok() {
                report.tmp_reaped += 1;
            }
        }
        Ok(report)
    }

    /// Per-kind entry counts and sizes, plus lease / temp / quarantine
    /// counts. Purely informational.
    pub fn stats(&self) -> Result<StatsReport> {
        let mut kinds: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        let mut unaddressable = 0_u64;
        for (path, name) in self.scan(".bin")? {
            let size = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            match parse_entry_name(&name) {
                Some((kind, _)) => {
                    let e = kinds.entry(kind).or_insert((0, 0));
                    e.0 += 1;
                    e.1 += size;
                }
                None => unaddressable += 1,
            }
        }
        let leases = self.scan(".lease")?.len() as u64;
        let tmp_files = self.scan(".tmp")?.len() as u64;
        let quarantined = match std::fs::read_dir(self.dir.join("quarantine")) {
            Ok(rd) => rd.filter_map(|e| e.ok()).count() as u64,
            Err(_) => 0,
        };
        Ok(StatsReport { kinds, unaddressable, leases, tmp_files, quarantined })
    }

    /// Sorted `(path, file name)` list of regular files in the cache dir
    /// with the given suffix. Skips subdirectories (`quarantine/`).
    fn scan(&self, suffix: &str) -> Result<Vec<(PathBuf, String)>> {
        let rd = std::fs::read_dir(&self.dir)
            .with_context(|| format!("reading cache dir {}", self.dir.display()))?;
        let mut out = Vec::new();
        for entry in rd {
            let entry = entry.with_context(|| format!("reading {}", self.dir.display()))?;
            if !entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
                continue;
            }
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.ends_with(suffix) {
                out.push((entry.path(), name));
            }
        }
        out.sort();
        Ok(out)
    }
}

/// `<kind>_<32 lower hex>.bin` → `(kind, key)`; `None` for anything the
/// store would never have written (kind may itself contain `_`, so the
/// split is anchored at the *last* underscore).
fn parse_entry_name(name: &str) -> Option<(String, Digest)> {
    let stem = name.strip_suffix(".bin")?;
    let (kind, hex) = stem.rsplit_once('_')?;
    if kind.is_empty() || kind.starts_with('.') {
        return None;
    }
    Some((kind.to_string(), Digest::from_hex(hex)?))
}

/// Outcome of [`ArtifactCache::verify`].
#[derive(Debug, Default)]
pub struct VerifyReport {
    pub valid: u64,
    /// New (post-move) locations of everything quarantined.
    pub quarantined: Vec<PathBuf>,
}

/// Outcome of [`ArtifactCache::gc`].
#[derive(Debug, Default)]
pub struct GcReport {
    pub leases_live: u64,
    pub leases_reaped: u64,
    pub tmp_reaped: u64,
}

/// Outcome of [`ArtifactCache::stats`].
#[derive(Debug, Default)]
pub struct StatsReport {
    /// kind → (entry count, total bytes).
    pub kinds: BTreeMap<String, (u64, u64)>,
    pub unaddressable: u64,
    pub leases: u64,
    pub tmp_files: u64,
    pub quarantined: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::digest::Hasher;

    /// Each test holds a quiet fault scope alongside its cache: the empty
    /// plan fires nothing, but holding the process-wide scope lock keeps
    /// a sibling fault-harness test from injecting into this test's IO.
    fn tmp_cache(tag: &str) -> (fault::FaultScope, ArtifactCache) {
        let dir = std::env::temp_dir().join(format!("fitq_cache_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        (fault::scoped(fault::FaultPlan::default()), ArtifactCache::new(&dir).unwrap())
    }

    fn key(n: u64) -> Digest {
        Hasher::new().u64(n).finish()
    }

    #[test]
    fn roundtrip_hits() {
        let (_quiet, c) = tmp_cache("roundtrip");
        let k = key(1);
        let payload = b"stage output bytes".to_vec();
        c.store("trace", 1, &k, &payload).unwrap();
        assert_eq!(c.load("trace", 1, &k), Some(payload));
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn missing_wrong_kind_or_wrong_key_miss() {
        let (_quiet, c) = tmp_cache("miss");
        let k = key(2);
        assert_eq!(c.load("trace", 1, &k), None, "missing file");
        c.store("trace", 1, &k, b"x").unwrap();
        assert_eq!(c.load("sens", 1, &k), None, "different kind");
        assert_eq!(c.load("trace", 1, &key(3)), None, "different key");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn schema_bump_invalidates() {
        let (_quiet, c) = tmp_cache("schema");
        let k = key(4);
        c.store("study", 1, &k, b"v1 payload").unwrap();
        assert!(c.load("study", 1, &k).is_some());
        assert_eq!(c.load("study", 2, &k), None, "bumped schema is a miss");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn truncated_and_corrupt_entries_miss() {
        let (_quiet, c) = tmp_cache("corrupt");
        let k = key(5);
        let path = c.store("ckpt", 1, &k, b"a long enough payload").unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in [0, 4, 12, full.len() - 3] {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert_eq!(c.load("ckpt", 1, &k), None, "truncated at {cut}");
        }
        // flip one payload byte: header parses, payload digest catches it
        let mut flipped = full.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0xff;
        std::fs::write(&path, &flipped).unwrap();
        assert_eq!(c.load("ckpt", 1, &k), None, "payload bitflip");
        // restore and confirm it hits again
        std::fs::write(&path, &full).unwrap();
        assert!(c.load("ckpt", 1, &k).is_some());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn entry_paths_are_digest_addressed() {
        let (_quiet, c) = tmp_cache("paths");
        let k = key(6);
        let p = c.entry_path("trace", &k);
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.starts_with("trace_"));
        assert!(name.ends_with(".bin"));
        assert!(name.contains(&k.hex()));
        std::fs::remove_dir_all(c.dir()).ok();
    }

    /// Regression pin for the tmp-file collision fix: concurrent writers
    /// of the same `(kind, key)` must get distinct in-flight temp paths
    /// (pid + per-process counter suffix), so one can never truncate a
    /// peer's half-written bytes.
    #[test]
    fn tmp_paths_are_unique_per_writer() {
        let (_quiet, c) = tmp_cache("tmpnames");
        let k = key(7);
        let a = c.tmp_path("trace", &k);
        let b = c.tmp_path("trace", &k);
        assert_ne!(a, b, "same process, same key: still distinct temp names");
        let name = a.file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.starts_with('.') && name.ends_with(".tmp"));
        assert!(
            name.contains(&format!(".{}.", std::process::id())),
            "tmp name {name} must embed the writer pid"
        );
        std::fs::remove_dir_all(c.dir()).ok();
    }

    /// The end-to-end face of the same fix: writers racing one key each
    /// publish through their own temp file, so the survivor is a complete
    /// valid entry and nothing in-flight is left behind.
    #[test]
    fn racing_stores_to_one_key_leave_a_single_valid_entry_and_no_tmps() {
        let (_quiet, c) = tmp_cache("racingstores");
        let k = key(9);
        let payload: Vec<u8> = (0..1024u32).map(|i| (i % 253) as u8).collect();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let (c, payload) = (&c, &payload);
                s.spawn(move || c.store("trace", 1, &k, payload).unwrap());
            }
        });
        assert_eq!(c.load("trace", 1, &k), Some(payload), "survivor must validate");
        let leftovers: Vec<String> = std::fs::read_dir(c.dir())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "in-flight temp files left behind: {leftovers:?}");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn lease_record_roundtrip_and_any_bitflip_rejected() {
        let rec = LeaseRecord { pid: 4321, token: 99, expires_unix_ms: 1_700_000_000_123 };
        let bytes = rec.encode();
        assert_eq!(LeaseRecord::parse(&bytes).unwrap(), rec);
        for i in 0..bytes.len() {
            let mut m = bytes.clone();
            m[i] ^= 0x01;
            assert!(LeaseRecord::parse(&m).is_err(), "bitflip at {i} accepted");
        }
        assert!(LeaseRecord::parse(&bytes[..bytes.len() - 1]).is_err(), "truncated");
        assert!(LeaseRecord::parse(&[]).is_err(), "empty");
    }

    #[test]
    fn claim_win_busy_release_cycle() {
        let (_quiet, c) = tmp_cache("claim");
        let k = key(8);
        let guard = match c.try_claim("trace", &k).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("cold key must be claimable"),
        };
        assert!(c.lease_path("trace", &k).exists());
        // Same process, second claimant: busy (leases are per-key, not
        // per-process — a second pipeline in this process must also wait).
        match c.try_claim("trace", &k).unwrap() {
            Claim::Busy { expires_unix_ms } => assert!(expires_unix_ms > now_unix_ms()),
            Claim::Won(_) => panic!("held lease re-won"),
        }
        guard.release();
        assert!(!c.lease_path("trace", &k).exists(), "release unlinks");
        assert!(matches!(c.try_claim("trace", &k).unwrap(), Claim::Won(_)));
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn guard_drop_releases_even_without_explicit_release() {
        let (_quiet, c) = tmp_cache("drop");
        let k = key(9);
        {
            let _guard = match c.try_claim("sens", &k).unwrap() {
                Claim::Won(g) => g,
                Claim::Busy { .. } => panic!("cold key must be claimable"),
            };
            assert!(c.lease_path("sens", &k).exists());
        }
        assert!(!c.lease_path("sens", &k).exists(), "drop released the lease");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn expired_lease_is_taken_over() {
        let (_quiet, mut c) = tmp_cache("takeover");
        c.set_lease_config(LeaseConfig { ttl: Duration::ZERO, ..LeaseConfig::default() });
        let k = key(10);
        let guard = match c.try_claim("trace", &k).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("cold key must be claimable"),
        };
        // Simulate the holder dying without releasing.
        std::mem::forget(guard);
        assert!(c.lease_path("trace", &k).exists());
        // ttl=0 ⇒ already expired: the next claim reaps and wins.
        let g2 = match c.try_claim("trace", &k).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("expired lease must be taken over"),
        };
        g2.release();
        assert!(!c.lease_path("trace", &k).exists());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn mangled_lease_is_stale_never_held() {
        let (_quiet, c) = tmp_cache("mangled");
        let k = key(11);
        std::fs::write(c.lease_path("trace", &k), b"not a lease record at all").unwrap();
        match c.try_claim("trace", &k).unwrap() {
            Claim::Won(g) => g.release(),
            Claim::Busy { .. } => panic!("unparsable lease treated as held"),
        }
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn stale_guard_does_not_unlink_successor_lease() {
        let (_quiet, mut c) = tmp_cache("staleguard");
        c.set_lease_config(LeaseConfig { ttl: Duration::ZERO, ..LeaseConfig::default() });
        let k = key(12);
        let old = match c.try_claim("trace", &k).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("cold key must be claimable"),
        };
        // A peer takes over the expired lease with a long-ttl config...
        let mut c2 = ArtifactCache::new(c.dir()).unwrap();
        c2.set_lease_config(LeaseConfig::default());
        let fresh = match c2.try_claim("trace", &k).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("expired lease must be taken over"),
        };
        // ...and the original guard's late release must NOT unlink the
        // successor's lease.
        old.release();
        assert!(c.lease_path("trace", &k).exists(), "successor lease survived");
        fresh.release();
        assert!(!c.lease_path("trace", &k).exists());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn verify_quarantines_corrupt_and_foreign_entries() {
        let (_quiet, c) = tmp_cache("verify");
        c.store("trace", 1, &key(13), b"good one").unwrap();
        let bad = c.store("trace", 1, &key(14), b"about to corrupt").unwrap();
        let mut bytes = std::fs::read(&bad).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&bad, bytes).unwrap();
        std::fs::write(c.dir().join("garbage_entry.bin"), b"not ours").unwrap();

        let report = c.verify().unwrap();
        assert_eq!(report.valid, 1);
        assert_eq!(report.quarantined.len(), 2);
        for q in &report.quarantined {
            assert!(q.exists(), "quarantined file kept at {}", q.display());
            assert!(q.parent().unwrap().ends_with("quarantine"));
        }
        assert!(!bad.exists(), "corrupt entry moved out of the store");
        assert!(c.load("trace", 1, &key(13)).is_some(), "good entry untouched");
        // Idempotent: a second pass finds a clean store.
        let again = c.verify().unwrap();
        assert_eq!((again.valid, again.quarantined.len()), (1, 0));
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn gc_reaps_expired_leases_and_old_tmps_only() {
        let (_quiet, mut c) = tmp_cache("gc");
        c.set_lease_config(LeaseConfig { ttl: Duration::ZERO, ..LeaseConfig::default() });
        let abandoned = match c.try_claim("trace", &key(15)).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("cold key must be claimable"),
        };
        std::mem::forget(abandoned); // expired (ttl=0) and never released
        std::fs::write(c.lease_path("sens", &key(16)), b"mangled").unwrap();
        std::fs::write(c.tmp_path("study", &key(17)), b"orphan write").unwrap();
        let mut live_cache = ArtifactCache::new(c.dir()).unwrap();
        live_cache.set_lease_config(LeaseConfig::default());
        let live = match live_cache.try_claim("study", &key(18)).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("cold key must be claimable"),
        };

        let report = c.gc(Duration::ZERO).unwrap();
        assert_eq!(report.leases_reaped, 2, "expired + mangled");
        assert_eq!(report.leases_live, 1);
        assert_eq!(report.tmp_reaped, 1);
        assert!(live_cache.lease_path("study", &key(18)).exists(), "live lease kept");
        // A generous age threshold leaves young tmps alone.
        std::fs::write(c.tmp_path("study", &key(19)), b"fresh write").unwrap();
        let report = c.gc(Duration::from_secs(3600)).unwrap();
        assert_eq!(report.tmp_reaped, 0);
        live.release();
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn stats_summarize_kinds_leases_tmps_quarantine() {
        let (_quiet, c) = tmp_cache("stats");
        c.store("trace", 1, &key(20), b"aaaa").unwrap();
        c.store("trace", 1, &key(21), b"bbbb").unwrap();
        c.store("train_fp", 1, &key(22), b"cc").unwrap();
        std::fs::write(c.dir().join("garbage_entry.bin"), b"not ours").unwrap();
        let g = match c.try_claim("study", &key(23)).unwrap() {
            Claim::Won(g) => g,
            Claim::Busy { .. } => panic!("cold key must be claimable"),
        };
        std::fs::write(c.tmp_path("study", &key(24)), b"orphan").unwrap();

        let s = c.stats().unwrap();
        assert_eq!(s.kinds.get("trace").map(|&(n, _)| n), Some(2));
        assert_eq!(s.kinds.get("train_fp").map(|&(n, _)| n), Some(1));
        assert!(s.kinds.get("trace").is_some_and(|&(_, b)| b > 0));
        assert_eq!(s.unaddressable, 1);
        assert_eq!(s.leases, 1);
        assert_eq!(s.tmp_files, 1);
        assert_eq!(s.quarantined, 0);
        c.verify().unwrap(); // quarantines the garbage entry
        assert_eq!(c.stats().unwrap().quarantined, 1);
        g.release();
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn entry_name_parse_is_anchored_at_last_underscore() {
        let k = key(25);
        let hex = k.hex();
        assert_eq!(
            parse_entry_name(&format!("train_fp_{hex}.bin")),
            Some(("train_fp".to_string(), k))
        );
        assert_eq!(parse_entry_name(&format!("trace_{hex}.txt")), None, "wrong suffix");
        assert_eq!(parse_entry_name(&format!("_{hex}.bin")), None, "empty kind");
        assert_eq!(parse_entry_name("trace_deadbeef.bin"), None, "short hex");
        assert_eq!(parse_entry_name(&format!(".trace_{hex}.bin")), None, "hidden file");
        assert_eq!(parse_entry_name("no-underscore.bin"), None);
    }
}
