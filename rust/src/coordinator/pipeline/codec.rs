//! Binary serialization for stage outputs that lack an on-disk form.
//!
//! `ModelState` already has its own checkpoint layout (`state.rs`); this
//! module gives the remaining stage outputs — `TraceResult`,
//! `SensitivityReport`, and the `StudyResult` outcome tables — a compact
//! little-endian encoding for the artifact cache. Every numeric field round
//! trips bit-exactly (floats travel as IEEE-754 bit patterns), which is
//! what makes "warm run reproduces the cold run's CSVs byte-for-byte" hold.
//!
//! Each payload kind carries a schema version (`*_SCHEMA` below) in the
//! cache header; bump it whenever the field list changes and old entries
//! invalidate themselves into recomputes instead of misparsing.

use anyhow::{bail, Result};

use super::super::evaluator::{ConfigFailure, ConfigOutcome, StudyResult};
use super::super::sensitivity::SensitivityReport;
use super::super::traces::{Estimator, TraceResult};
use super::super::trainer::ActRanges;
use crate::metrics::{Metric, SensitivityInputs};
use crate::native::simd::Isa;
use crate::native::trace::{OpAggregate, OpTraceReport, TracedOp};
use crate::native::tune::Lowering;
use crate::quant::BitConfig;

/// Schema versions, one per cached payload kind (the checkpoint kind
/// reuses `ModelState`'s own layout and versions independently).
///
/// Study entries embed a copy of their sensitivity report (see
/// [`encode_study`]), so a fix that invalidates sensitivity *values* —
/// not just their layout — must bump `STUDY_SCHEMA` alongside
/// `SENSITIVITY_SCHEMA`.
pub const TRACE_SCHEMA: u32 = 1;
pub const SENSITIVITY_SCHEMA: u32 = 1;
/// v2: appended the per-config failure list (degraded sweep slots).
pub const STUDY_SCHEMA: u32 = 2;
pub const CKPT_SCHEMA: u32 = 1;
/// Op-trace payloads (kind `optrace`, `native::trace::OPTRACE_KIND`).
pub const OPTRACE_SCHEMA: u32 = 1;

/// Little-endian byte sink for cache payloads and headers.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.raw(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.raw(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.raw(s.as_bytes());
    }

    pub fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u32(x);
        }
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f64(x);
            }
            None => self.bool(false),
        }
    }
}

/// Bounds-checked reader over a payload; every overrun is a plain error so
/// a truncated or corrupt entry decodes into a cache miss, never a panic.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("payload truncated: need {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.raw(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.raw(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.raw(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("invalid bool byte {other}"),
        }
    }

    /// Element-count prefix, pre-validated against the bytes actually left
    /// so a corrupt length can't trigger a huge allocation.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        match n.checked_mul(elem_bytes) {
            Some(total) if total <= self.remaining() => Ok(n),
            _ => bail!("payload truncated: length prefix {n} exceeds remaining bytes"),
        }
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        Ok(String::from_utf8_lossy(self.raw(n)?).into_owned())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len_prefix(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len_prefix(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    /// Assert the payload was fully consumed (trailing garbage is corruption).
    pub fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("payload has {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

fn estimator_tag(e: Estimator) -> u8 {
    match e {
        Estimator::EmpiricalFisher => 0,
        Estimator::Hutchinson => 1,
    }
}

fn estimator_from_tag(tag: u8) -> Result<Estimator> {
    Ok(match tag {
        0 => Estimator::EmpiricalFisher,
        1 => Estimator::Hutchinson,
        other => bail!("unknown estimator tag {other}"),
    })
}

fn metric_tag(m: Metric) -> u8 {
    Metric::ALL.iter().position(|x| *x == m).expect("metric in ALL") as u8
}

fn metric_from_tag(tag: u8) -> Result<Metric> {
    match Metric::ALL.get(tag as usize) {
        Some(m) => Ok(*m),
        None => bail!("unknown metric tag {tag}"),
    }
}

fn write_trace(w: &mut ByteWriter, t: &TraceResult) {
    w.u8(estimator_tag(t.estimator));
    w.f64s(&t.w_traces);
    w.f64s(&t.a_traces);
    w.f64s(&t.w_std_errors);
    w.u64(t.iterations);
    w.f64(t.iter_time_s);
    w.f64(t.norm_variance);
    w.f64s(&t.history_total);
}

fn read_trace(r: &mut ByteReader) -> Result<TraceResult> {
    Ok(TraceResult {
        estimator: estimator_from_tag(r.u8()?)?,
        w_traces: r.f64s()?,
        a_traces: r.f64s()?,
        w_std_errors: r.f64s()?,
        iterations: r.u64()?,
        iter_time_s: r.f64()?,
        norm_variance: r.f64()?,
        history_total: r.f64s()?,
    })
}

fn write_sensitivity(w: &mut ByteWriter, s: &SensitivityReport) {
    w.f64s(&s.inputs.w_traces);
    w.f64s(&s.inputs.a_traces);
    w.f64s(&s.inputs.w_lo);
    w.f64s(&s.inputs.w_hi);
    w.f64s(&s.inputs.a_lo);
    w.f64s(&s.inputs.a_hi);
    w.u64(s.inputs.bn_gamma.len() as u64);
    for &g in &s.inputs.bn_gamma {
        w.opt_f64(g);
    }
    w.f32s(&s.act.lo);
    w.f32s(&s.act.hi);
    write_trace(w, &s.trace);
}

fn read_sensitivity(r: &mut ByteReader) -> Result<SensitivityReport> {
    let w_traces = r.f64s()?;
    let a_traces = r.f64s()?;
    let w_lo = r.f64s()?;
    let w_hi = r.f64s()?;
    let a_lo = r.f64s()?;
    let a_hi = r.f64s()?;
    let n_gamma = r.u64()? as usize;
    let mut bn_gamma = Vec::with_capacity(n_gamma.min(r.remaining()));
    for _ in 0..n_gamma {
        bn_gamma.push(r.opt_f64()?);
    }
    let inputs = SensitivityInputs { w_traces, a_traces, w_lo, w_hi, a_lo, a_hi, bn_gamma };
    let act = ActRanges { lo: r.f32s()?, hi: r.f32s()? };
    let trace = read_trace(r)?;
    Ok(SensitivityReport { inputs, act, trace })
}

/// Serialize a converged trace run for the `traces` cache kind.
pub fn encode_trace(t: &TraceResult) -> Vec<u8> {
    let mut w = ByteWriter::new();
    write_trace(&mut w, t);
    w.into_bytes()
}

pub fn decode_trace(bytes: &[u8]) -> Result<TraceResult> {
    let mut r = ByteReader::new(bytes);
    let t = read_trace(&mut r)?;
    r.done()?;
    Ok(t)
}

/// Serialize a gathered sensitivity report for the `sensitivity` cache kind.
pub fn encode_sensitivity(s: &SensitivityReport) -> Vec<u8> {
    let mut w = ByteWriter::new();
    write_sensitivity(&mut w, s);
    w.into_bytes()
}

pub fn decode_sensitivity(bytes: &[u8]) -> Result<SensitivityReport> {
    let mut r = ByteReader::new(bytes);
    let s = read_sensitivity(&mut r)?;
    r.done()?;
    Ok(s)
}

/// Serialize a full study outcome table for the `study` cache kind.
///
/// Deliberately self-contained: the embedded `SensitivityReport`
/// duplicates the sensitivity stage's own cache entry, so a study entry
/// stays valid even if the sensitivity entry is evicted or its schema
/// bumped. The cost is one extra copy of the per-block vectors per study
/// — small next to the outcome table it annotates.
pub fn encode_study(s: &StudyResult) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.str(&s.model);
    w.f64(s.fp_test_score);
    w.u64(s.outcomes.len() as u64);
    for o in &s.outcomes {
        w.u32s(&o.cfg.bits_w);
        w.u32s(&o.cfg.bits_a);
        w.u64(o.metrics.len() as u64);
        for &(m, v) in &o.metrics {
            w.u8(metric_tag(m));
            w.opt_f64(v);
        }
        w.f64(o.test_score);
        w.f64(o.train_score);
        w.f64(o.mean_bits);
    }
    write_sensitivity(&mut w, &s.sens);
    w.u64(s.correlations.len() as u64);
    for &(m, v) in &s.correlations {
        w.u8(metric_tag(m));
        w.opt_f64(v);
    }
    w.u64(s.failures.len() as u64);
    for f in &s.failures {
        w.u64(f.index as u64);
        w.str(&f.label);
        w.bool(f.panicked);
        w.str(&f.error);
    }
    w.into_bytes()
}

pub fn decode_study(bytes: &[u8]) -> Result<StudyResult> {
    let mut r = ByteReader::new(bytes);
    let model = r.str()?;
    let fp_test_score = r.f64()?;
    let n_out = r.u64()? as usize;
    let mut outcomes = Vec::with_capacity(n_out.min(r.remaining()));
    for _ in 0..n_out {
        let cfg = BitConfig { bits_w: r.u32s()?, bits_a: r.u32s()? };
        let n_m = r.u64()? as usize;
        let mut metrics = Vec::with_capacity(n_m.min(r.remaining()));
        for _ in 0..n_m {
            let m = metric_from_tag(r.u8()?)?;
            metrics.push((m, r.opt_f64()?));
        }
        outcomes.push(ConfigOutcome {
            cfg,
            metrics,
            test_score: r.f64()?,
            train_score: r.f64()?,
            mean_bits: r.f64()?,
        });
    }
    let sens = read_sensitivity(&mut r)?;
    let n_c = r.u64()? as usize;
    let mut correlations = Vec::with_capacity(n_c.min(r.remaining()));
    for _ in 0..n_c {
        let m = metric_from_tag(r.u8()?)?;
        correlations.push((m, r.opt_f64()?));
    }
    let n_f = r.u64()? as usize;
    let mut failures = Vec::with_capacity(n_f.min(r.remaining()));
    for _ in 0..n_f {
        failures.push(ConfigFailure {
            index: r.u64()? as usize,
            label: r.str()?,
            panicked: r.bool()?,
            error: r.str()?,
        });
    }
    r.done()?;
    Ok(StudyResult { model, fp_test_score, outcomes, sens, correlations, failures })
}

fn write_op_aggregate(w: &mut ByteWriter, row: &OpAggregate) {
    w.u8(row.op as u8);
    w.str(&row.layer);
    match row.variant {
        Some((isa, lowering)) => {
            w.bool(true);
            w.u8(isa as u8);
            w.u8(lowering as u8);
        }
        None => w.bool(false),
    }
    w.u32(row.width);
    w.str(&row.shape);
    w.u64(row.calls);
    w.u64(row.elems_read);
    w.u64(row.elems_written);
    w.u64(row.flops);
    w.u64(row.wall_ns);
}

fn read_op_aggregate(r: &mut ByteReader) -> Result<OpAggregate> {
    let op = match TracedOp::from_u8(r.u8()?) {
        Some(op) => op,
        None => bail!("unknown optrace op tag"),
    };
    let layer = r.str()?;
    let variant = if r.bool()? {
        let isa = match Isa::from_u8(r.u8()?) {
            Some(isa) => isa,
            None => bail!("unknown optrace isa tag"),
        };
        let lowering = match Lowering::from_u8(r.u8()?) {
            Some(l) => l,
            None => bail!("unknown optrace lowering tag"),
        };
        Some((isa, lowering))
    } else {
        None
    };
    Ok(OpAggregate {
        op,
        layer,
        variant,
        width: r.u32()?,
        shape: r.str()?,
        calls: r.u64()?,
        elems_read: r.u64()?,
        elems_written: r.u64()?,
        flops: r.u64()?,
        wall_ns: r.u64()?,
    })
}

/// Serialize an op-trace report for the `optrace` cache kind. Every
/// counter round trips bit-exactly; byte-stable comparisons go through
/// [`OpTraceReport::normalized`] first (wall clock is the one
/// nondeterministic field).
pub fn encode_optrace(t: &OpTraceReport) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.str(&t.model);
    w.str(&t.workload);
    w.u32(t.threads);
    w.u64(t.rows.len() as u64);
    for row in &t.rows {
        write_op_aggregate(&mut w, row);
    }
    w.into_bytes()
}

/// Decode an `optrace` payload; fail-closed on truncation, trailing
/// bytes, and unknown op/isa/lowering tags.
pub fn decode_optrace(bytes: &[u8]) -> Result<OpTraceReport> {
    let mut r = ByteReader::new(bytes);
    let model = r.str()?;
    let workload = r.str()?;
    let threads = r.u32()?;
    let n_rows = r.u64()? as usize;
    let mut rows = Vec::with_capacity(n_rows.min(r.remaining()));
    for _ in 0..n_rows {
        rows.push(read_op_aggregate(&mut r)?);
    }
    r.done()?;
    Ok(OpTraceReport { model, workload, threads, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> TraceResult {
        TraceResult {
            estimator: Estimator::Hutchinson,
            w_traces: vec![1.5, -2.25, 0.0],
            a_traces: vec![3.5],
            w_std_errors: vec![0.1, 0.2, 0.3],
            iterations: 42,
            iter_time_s: 0.0125,
            norm_variance: 7.75,
            history_total: vec![1.0, 1.25, 1.5],
        }
    }

    fn sample_sensitivity() -> SensitivityReport {
        SensitivityReport {
            inputs: SensitivityInputs {
                w_traces: vec![10.0, 2.0],
                a_traces: vec![4.0],
                w_lo: vec![-1.0, -0.5],
                w_hi: vec![1.0, 0.5],
                a_lo: vec![0.0],
                a_hi: vec![6.0],
                bn_gamma: vec![Some(1.0), None],
            },
            act: ActRanges { lo: vec![0.0], hi: vec![5.5] },
            trace: sample_trace(),
        }
    }

    #[test]
    fn trace_roundtrip_is_bit_exact() {
        let t = sample_trace();
        let back = decode_trace(&encode_trace(&t)).unwrap();
        assert_eq!(back.estimator, t.estimator);
        assert_eq!(back.w_traces, t.w_traces);
        assert_eq!(back.a_traces, t.a_traces);
        assert_eq!(back.w_std_errors, t.w_std_errors);
        assert_eq!(back.iterations, t.iterations);
        assert_eq!(back.iter_time_s.to_bits(), t.iter_time_s.to_bits());
        assert_eq!(back.norm_variance.to_bits(), t.norm_variance.to_bits());
        assert_eq!(back.history_total, t.history_total);
    }

    #[test]
    fn sensitivity_roundtrip_keeps_optionals() {
        let s = sample_sensitivity();
        let back = decode_sensitivity(&encode_sensitivity(&s)).unwrap();
        assert_eq!(back.inputs.bn_gamma, s.inputs.bn_gamma);
        assert_eq!(back.inputs.w_traces, s.inputs.w_traces);
        assert_eq!(back.act.lo, s.act.lo);
        assert_eq!(back.act.hi, s.act.hi);
        assert_eq!(back.trace.iterations, s.trace.iterations);
    }

    #[test]
    fn study_roundtrip_reencodes_identically() {
        let s = StudyResult {
            model: "cnn_mnist".into(),
            fp_test_score: 0.91,
            outcomes: vec![ConfigOutcome {
                cfg: BitConfig { bits_w: vec![8, 4], bits_a: vec![3] },
                metrics: vec![(Metric::Fit, Some(0.5)), (Metric::Bn, None)],
                test_score: 0.8,
                train_score: 0.85,
                mean_bits: 5.0,
            }],
            sens: sample_sensitivity(),
            correlations: vec![(Metric::Fit, Some(0.86)), (Metric::Qr, Some(f64::NAN))],
            failures: vec![ConfigFailure {
                index: 17,
                label: "w[8,4] a[3]".into(),
                panicked: true,
                error: "worker job 17 panicked".into(),
            }],
        };
        let bytes = encode_study(&s);
        let back = decode_study(&bytes).unwrap();
        // bit-exact: re-encoding the decoded value reproduces the bytes,
        // NaN correlations included
        assert_eq!(encode_study(&back), bytes);
        assert_eq!(back.outcomes[0].cfg, s.outcomes[0].cfg);
        assert_eq!(back.outcomes[0].metrics, s.outcomes[0].metrics);
        assert_eq!(back.failures, s.failures);
    }

    #[test]
    fn truncated_payloads_error_instead_of_panicking() {
        let bytes = encode_trace(&sample_trace());
        for cut in [0, 1, 5, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_trace(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage is also rejected
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_trace(&long).is_err());
    }

    #[test]
    fn corrupt_length_prefix_is_an_error_not_an_alloc() {
        let mut w = ByteWriter::new();
        w.u64(u64::MAX); // claims ~2^64 f64s
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f64s().is_err());
    }

    #[test]
    fn unknown_tags_are_rejected() {
        let mut w = ByteWriter::new();
        w.u8(9);
        w.f64s(&[]);
        w.f64s(&[]);
        w.f64s(&[]);
        w.u64(0);
        w.f64(0.0);
        w.f64(0.0);
        w.f64s(&[]);
        assert!(decode_trace(&w.into_bytes()).is_err(), "estimator tag 9");
    }

    fn sample_optrace() -> OpTraceReport {
        OpTraceReport {
            model: "cnn_mnist".into(),
            workload: "train_epoch".into(),
            threads: 2,
            rows: vec![
                OpAggregate {
                    op: TracedOp::ConvFwd,
                    layer: "conv0".into(),
                    variant: Some((Isa::Scalar, Lowering::Im2col)),
                    width: 8,
                    shape: "b32 16x16 1->8".into(),
                    calls: 10,
                    elems_read: 81_920,
                    elems_written: 655_360,
                    flops: 11_796_480,
                    wall_ns: 1_234_567,
                },
                OpAggregate {
                    op: TracedOp::Relu,
                    layer: "conv0".into(),
                    variant: None,
                    width: 0,
                    shape: "b32 16x16 c8".into(),
                    calls: 10,
                    elems_read: 655_360,
                    elems_written: 655_360,
                    flops: 655_360,
                    wall_ns: 7_890,
                },
            ],
        }
    }

    #[test]
    fn optrace_roundtrip_reencodes_identically() {
        let t = sample_optrace();
        let bytes = encode_optrace(&t);
        let back = decode_optrace(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(encode_optrace(&back), bytes);
    }

    #[test]
    fn optrace_truncations_error_instead_of_panicking() {
        let bytes = encode_optrace(&sample_optrace());
        for cut in [0, 1, 5, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_optrace(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_optrace(&long).is_err(), "trailing garbage");
    }

    #[test]
    fn optrace_unknown_tags_are_rejected() {
        // unknown op tag
        let mut t = sample_optrace();
        let mut bytes = encode_optrace(&t);
        // first row's op tag sits right after the two header strings +
        // threads + row count
        let op_at = 8 + t.model.len() + 8 + t.workload.len() + 4 + 8;
        bytes[op_at] = 200;
        assert!(decode_optrace(&bytes).is_err(), "op tag 200");
        // unknown isa tag inside the variant
        let isa_at = op_at + 1 + 8 + t.rows[0].layer.len() + 1;
        let mut bytes = encode_optrace(&t);
        bytes[isa_at] = 201;
        assert!(decode_optrace(&bytes).is_err(), "isa tag 201");
        // unknown lowering tag
        let mut bytes = encode_optrace(&t);
        bytes[isa_at + 1] = 202;
        assert!(decode_optrace(&bytes).is_err(), "lowering tag 202");
        // normalized() then roundtrip stays byte-stable (the comparison
        // form op_trace.rs relies on)
        for row in &mut t.rows {
            row.wall_ns = 7;
        }
        let norm = t.normalized();
        assert_eq!(
            encode_optrace(&decode_optrace(&encode_optrace(&norm)).unwrap()),
            encode_optrace(&norm)
        );
    }
}
