//! Exact bit allocation (the HAWQ-V3-style ILP, solved exactly).
//!
//! The greedy allocator in `search.rs` is fast but can land off the true
//! optimum; for the block counts the paper works with (<= ~12 quantizable
//! weight blocks) the integer program
//!
//! ```text
//! minimize   FIT(bits)
//! subject to model_bits(bits) <= budget
//! ```
//!
//! decomposes per block (FIT and size are both separable sums), so a
//! branch-and-bound over per-block precision choices with a lower-bound
//! prune finds the exact optimum quickly. The per-block candidate terms
//! come straight from the shared [`FitTable`] — the same table the greedy
//! and Pareto paths score against — and the final configuration's FIT
//! (including the activation terms) is the table's gather-sum, bit-identical
//! to the naive `metrics::fit`. Activation bits do not affect stored size;
//! their FIT terms are independent, so each activation block takes its
//! highest precision (optimal for any pure-size budget).

use crate::metrics::{FitTable, SensitivityInputs};
use crate::quant::BitConfig;

use super::search::ScoredConfig;

/// Exact minimum-FIT configuration under a weight-storage budget (bits).
/// Returns None when even all-minimum-precision misses the budget — or
/// when a non-finite trace poisons the fit lower bound (a NaN keeps every
/// leaf from beating the `f64::INFINITY` incumbent), in which case the
/// sensitivity inputs, not the budget, are the thing to debug.
pub fn exact_allocate(
    s: &SensitivityInputs,
    block_sizes: &[usize],
    n_unq: usize,
    precisions: &[u32],
    budget_bits: u64,
) -> Option<ScoredConfig> {
    let table = FitTable::new(s, block_sizes, n_unq, precisions);
    exact_allocate_table(&table, budget_bits)
}

/// [`exact_allocate`] over a prebuilt (shared) [`FitTable`].
pub fn exact_allocate_table(table: &FitTable, budget_bits: u64) -> Option<ScoredConfig> {
    let lw = table.n_weight_blocks();
    let la = table.n_act_blocks();
    let precs = table.precisions();

    // candidate precisions in ascending order, as indices into the
    // table's precision set
    let mut asc: Vec<usize> = (0..precs.len()).collect();
    asc.sort_by(|&a, &b| precs[a].cmp(&precs[b]));
    let (min_idx, max_idx) = (asc[0], *asc.last().unwrap());

    let floor: u64 =
        table.base_bits() + (0..lw).map(|l| table.w_size_bits(l, min_idx)).sum::<u64>();
    if floor > budget_bits {
        return None;
    }

    // lower bounds for pruning: best possible remaining fit / smallest
    // possible remaining size from block l onward.
    let mut min_fit_suffix = vec![0.0f64; lw + 1];
    let mut min_size_suffix = vec![0u64; lw + 1];
    for l in (0..lw).rev() {
        let best_fit = asc.iter().map(|&p| table.w_term(l, p)).fold(f64::INFINITY, f64::min);
        let best_size = asc.iter().map(|&p| table.w_size_bits(l, p)).min().unwrap();
        min_fit_suffix[l] = min_fit_suffix[l + 1] + best_fit;
        min_size_suffix[l] = min_size_suffix[l + 1] + best_size;
    }

    // per-block visit order: lower-fit (higher precision) choices first so
    // the incumbent tightens quickly. The order is branch-independent, so
    // it is hoisted out of the recursion (the naive path re-sorted at
    // every node); total_cmp keeps a NaN trace from aborting the study.
    let visit: Vec<Vec<usize>> = (0..lw)
        .map(|l| {
            let mut o = asc.clone();
            o.sort_by(|&a, &b| table.w_term(l, a).total_cmp(&table.w_term(l, b)));
            o
        })
        .collect();

    struct Search<'a> {
        table: &'a FitTable,
        visit: &'a [Vec<usize>],
        min_fit_suffix: &'a [f64],
        min_size_suffix: &'a [u64],
        budget_for_blocks: u64,
        best: f64,
        best_prec: Vec<usize>,
        cur: Vec<usize>,
    }

    impl Search<'_> {
        fn go(&mut self, l: usize, fit_acc: f64, size_acc: u64) {
            if fit_acc + self.min_fit_suffix[l] >= self.best {
                return; // cannot beat incumbent
            }
            if size_acc + self.min_size_suffix[l] > self.budget_for_blocks {
                return; // cannot satisfy budget
            }
            if l == self.visit.len() {
                self.best = fit_acc;
                self.best_prec = self.cur.clone();
                return;
            }
            let visit = self.visit;
            for &p in &visit[l] {
                self.cur.push(p);
                self.go(
                    l + 1,
                    fit_acc + self.table.w_term(l, p),
                    size_acc + self.table.w_size_bits(l, p),
                );
                self.cur.pop();
            }
        }
    }

    let mut search = Search {
        table,
        visit: &visit,
        min_fit_suffix: &min_fit_suffix,
        min_size_suffix: &min_size_suffix,
        budget_for_blocks: budget_bits.saturating_sub(table.base_bits()),
        best: f64::INFINITY,
        best_prec: Vec::new(),
        cur: Vec::with_capacity(lw),
    };
    search.go(0, 0.0, 0);
    if search.best_prec.is_empty() {
        return None;
    }
    let cfg = BitConfig {
        bits_w: search.best_prec.iter().map(|&p| precs[p]).collect(),
        bits_a: vec![precs[max_idx]; la],
    };
    let packed = table.pack(&cfg);
    Some(ScoredConfig { fit: table.score(&packed), size_bits: table.size_bits(&packed), cfg })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::search::greedy_allocate;
    use crate::metrics::test_inputs;
    use crate::quant::{model_bits, PRECISIONS};

    fn setup() -> (SensitivityInputs, Vec<usize>) {
        (test_inputs(), vec![100, 400, 50])
    }

    #[test]
    fn exact_meets_budget_and_never_loses_to_greedy() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        for num in [95, 80, 65, 50, 45] {
            let budget = full * num / 100;
            let Some(exact) = exact_allocate(&s, &sizes, 10, &PRECISIONS, budget) else {
                // below the 3-bit floor: greedy must agree it's infeasible
                assert!(greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget).is_none());
                continue;
            };
            assert!(exact.size_bits <= budget);
            if let Some(g) = greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget) {
                // greedy config may quantize activations; compare on the
                // weight term + max-precision activations for fairness
                let mut gcfg = g.cfg.clone();
                gcfg.bits_a = vec![8; gcfg.bits_a.len()];
                let gfit = crate::metrics::fit(&s, &gcfg);
                assert!(
                    exact.fit <= gfit + 1e-12,
                    "exact {} must be <= greedy {} at {num}%",
                    exact.fit,
                    gfit
                );
            }
        }
    }

    #[test]
    fn exact_matches_brute_force_enumeration() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let budget = full * 60 / 100;
        let exact = exact_allocate(&s, &sizes, 10, &PRECISIONS, budget).unwrap();

        // brute force over all 4^3 weight configs
        let mut best = f64::INFINITY;
        for &b0 in &PRECISIONS {
            for &b1 in &PRECISIONS {
                for &b2 in &PRECISIONS {
                    let cfg = BitConfig { bits_w: vec![b0, b1, b2], bits_a: vec![8, 8] };
                    if model_bits(&sizes, 10, &cfg) <= budget {
                        best = best.min(crate::metrics::fit(&s, &cfg));
                    }
                }
            }
        }
        assert!((exact.fit - best).abs() < 1e-12, "{} vs {}", exact.fit, best);
    }

    #[test]
    fn infeasible_budget_is_none() {
        let (s, sizes) = setup();
        assert!(exact_allocate(&s, &sizes, 10, &PRECISIONS, 1).is_none());
    }

    #[test]
    fn generous_budget_keeps_max_precision() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let exact = exact_allocate(&s, &sizes, 10, &PRECISIONS, full).unwrap();
        assert_eq!(exact.cfg.bits_w, vec![8, 8, 8]);
    }

    #[test]
    fn nan_trace_does_not_panic() {
        // the old per-node partial_cmp().unwrap() ordering could abort on
        // a NaN trace; total_cmp must rank it (last) instead. The NaN also
        // poisons the fit lower bound, so no config can beat the f64::min
        // incumbent — the allocator reports infeasible rather than panics.
        let (mut s, sizes) = setup();
        s.w_traces[1] = f64::NAN;
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        assert!(exact_allocate(&s, &sizes, 10, &PRECISIONS, full * 60 / 100).is_none());
    }

    #[test]
    fn table_reuse_matches_fresh_table() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let table = FitTable::new(&s, &sizes, 10, &PRECISIONS);
        for num in [95u64, 60, 45] {
            let budget = full * num / 100;
            let a = exact_allocate(&s, &sizes, 10, &PRECISIONS, budget).unwrap();
            let b = exact_allocate_table(&table, budget).unwrap();
            assert_eq!(a.cfg, b.cfg);
            assert_eq!(a.fit.to_bits(), b.fit.to_bits());
            assert_eq!(a.size_bits, b.size_bits);
        }
    }

    #[test]
    fn scales_to_twelve_blocks() {
        // u-net sized problem: 12 blocks, 4 precisions -> 16.7M leaves;
        // pruning must make this instant.
        let lw = 12;
        let s = SensitivityInputs {
            w_traces: (0..lw).map(|i| 1.0 + (i as f64 * 1.7) % 5.0).collect(),
            a_traces: vec![],
            w_lo: vec![-1.0; lw],
            w_hi: vec![1.0; lw],
            a_lo: vec![],
            a_hi: vec![],
            bn_gamma: vec![None; lw],
        };
        let sizes: Vec<usize> = (0..lw).map(|i| 100 + i * 37).collect();
        let full = model_bits(&sizes, 0, &BitConfig::uniform(lw, 0, 8));
        let t0 = std::time::Instant::now();
        let exact = exact_allocate(&s, &sizes, 0, &PRECISIONS, full / 2).unwrap();
        assert!(exact.size_bits <= full / 2);
        assert!(t0.elapsed().as_secs_f64() < 5.0, "{:?}", t0.elapsed());
    }
}
