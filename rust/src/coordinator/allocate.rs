//! Exact bit allocation (the HAWQ-V3-style ILP, solved exactly).
//!
//! The greedy allocator in `search.rs` is fast but can land off the true
//! optimum; for the block counts the paper works with (<= ~12 quantizable
//! weight blocks) the integer program
//!
//! ```text
//! minimize   FIT(bits)
//! subject to model_bits(bits) <= budget
//! ```
//!
//! decomposes per block (FIT and size are both separable sums), so a
//! branch-and-bound over per-block precision choices with a lower-bound
//! prune finds the exact optimum quickly. Activation bits do not affect
//! stored size; their FIT terms are independent, so each activation block
//! takes its highest precision (optimal for any pure-size budget).

use crate::metrics::SensitivityInputs;
use crate::quant::{model_bits, noise_power, BitConfig};

use super::search::ScoredConfig;

/// Exact minimum-FIT configuration under a weight-storage budget (bits).
/// Returns None when even all-minimum-precision misses the budget.
pub fn exact_allocate(
    s: &SensitivityInputs,
    block_sizes: &[usize],
    n_unq: usize,
    precisions: &[u32],
    budget_bits: u64,
) -> Option<ScoredConfig> {
    let lw = s.n_weight_blocks();
    let la = s.n_act_blocks();
    assert_eq!(block_sizes.len(), lw);
    let mut prec = precisions.to_vec();
    prec.sort_unstable();
    let (min_p, max_p) = (prec[0], *prec.last().unwrap());

    let base_bits = n_unq as u64 * 32;
    let floor: u64 =
        base_bits + block_sizes.iter().map(|&n| n as u64 * min_p as u64).sum::<u64>();
    if floor > budget_bits {
        return None;
    }

    // per-block candidate (cost = FIT contribution, size) per precision
    let cand: Vec<Vec<(f64, u64, u32)>> = (0..lw)
        .map(|l| {
            prec.iter()
                .map(|&b| {
                    let fitc = s.w_traces[l] * noise_power(s.w_lo[l], s.w_hi[l], b as f64);
                    (fitc, block_sizes[l] as u64 * b as u64, b)
                })
                .collect()
        })
        .collect();

    // lower bounds for pruning: best possible remaining fit / smallest
    // possible remaining size from block l onward.
    let mut min_fit_suffix = vec![0.0f64; lw + 1];
    let mut min_size_suffix = vec![0u64; lw + 1];
    for l in (0..lw).rev() {
        let best_fit = cand[l].iter().map(|c| c.0).fold(f64::INFINITY, f64::min);
        let best_size = cand[l].iter().map(|c| c.1).min().unwrap();
        min_fit_suffix[l] = min_fit_suffix[l + 1] + best_fit;
        min_size_suffix[l] = min_size_suffix[l + 1] + best_size;
    }

    struct Search<'a> {
        cand: &'a [Vec<(f64, u64, u32)>],
        min_fit_suffix: &'a [f64],
        min_size_suffix: &'a [u64],
        budget_for_blocks: u64,
        best: f64,
        best_bits: Vec<u32>,
        cur: Vec<u32>,
    }

    impl Search<'_> {
        fn go(&mut self, l: usize, fit_acc: f64, size_acc: u64) {
            if fit_acc + self.min_fit_suffix[l] >= self.best {
                return; // cannot beat incumbent
            }
            if size_acc + self.min_size_suffix[l] > self.budget_for_blocks {
                return; // cannot satisfy budget
            }
            if l == self.cand.len() {
                self.best = fit_acc;
                self.best_bits = self.cur.clone();
                return;
            }
            // visit lower-fit (higher precision) choices first so the
            // incumbent tightens quickly
            let mut order: Vec<usize> = (0..self.cand[l].len()).collect();
            order.sort_by(|&a, &b| {
                self.cand[l][a].0.partial_cmp(&self.cand[l][b].0).unwrap()
            });
            for i in order {
                let (f, sz, b) = self.cand[l][i];
                self.cur.push(b);
                self.go(l + 1, fit_acc + f, size_acc + sz);
                self.cur.pop();
            }
        }
    }

    let mut search = Search {
        cand: &cand,
        min_fit_suffix: &min_fit_suffix,
        min_size_suffix: &min_size_suffix,
        budget_for_blocks: budget_bits.saturating_sub(base_bits),
        best: f64::INFINITY,
        best_bits: Vec::new(),
        cur: Vec::with_capacity(lw),
    };
    search.go(0, 0.0, 0);
    if search.best_bits.is_empty() {
        return None;
    }
    let cfg = BitConfig { bits_w: search.best_bits, bits_a: vec![max_p; la] };
    let size_bits = model_bits(block_sizes, n_unq, &cfg);
    Some(ScoredConfig { fit: crate::metrics::fit(s, &cfg), size_bits, cfg })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::search::greedy_allocate;
    use crate::metrics::test_inputs;
    use crate::quant::PRECISIONS;

    fn setup() -> (SensitivityInputs, Vec<usize>) {
        (test_inputs(), vec![100, 400, 50])
    }

    #[test]
    fn exact_meets_budget_and_never_loses_to_greedy() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        for num in [95, 80, 65, 50, 45] {
            let budget = full * num / 100;
            let Some(exact) = exact_allocate(&s, &sizes, 10, &PRECISIONS, budget) else {
                // below the 3-bit floor: greedy must agree it's infeasible
                assert!(greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget).is_none());
                continue;
            };
            assert!(exact.size_bits <= budget);
            if let Some(g) = greedy_allocate(&s, &sizes, 10, &PRECISIONS, budget) {
                // greedy config may quantize activations; compare on the
                // weight term + max-precision activations for fairness
                let mut gcfg = g.cfg.clone();
                gcfg.bits_a = vec![8; gcfg.bits_a.len()];
                let gfit = crate::metrics::fit(&s, &gcfg);
                assert!(
                    exact.fit <= gfit + 1e-12,
                    "exact {} must be <= greedy {} at {num}%",
                    exact.fit,
                    gfit
                );
            }
        }
    }

    #[test]
    fn exact_matches_brute_force_enumeration() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let budget = full * 60 / 100;
        let exact = exact_allocate(&s, &sizes, 10, &PRECISIONS, budget).unwrap();

        // brute force over all 4^3 weight configs
        let mut best = f64::INFINITY;
        for &b0 in &PRECISIONS {
            for &b1 in &PRECISIONS {
                for &b2 in &PRECISIONS {
                    let cfg = BitConfig { bits_w: vec![b0, b1, b2], bits_a: vec![8, 8] };
                    if model_bits(&sizes, 10, &cfg) <= budget {
                        best = best.min(crate::metrics::fit(&s, &cfg));
                    }
                }
            }
        }
        assert!((exact.fit - best).abs() < 1e-12, "{} vs {}", exact.fit, best);
    }

    #[test]
    fn infeasible_budget_is_none() {
        let (s, sizes) = setup();
        assert!(exact_allocate(&s, &sizes, 10, &PRECISIONS, 1).is_none());
    }

    #[test]
    fn generous_budget_keeps_max_precision() {
        let (s, sizes) = setup();
        let full = model_bits(&sizes, 10, &BitConfig::uniform(3, 2, 8));
        let exact = exact_allocate(&s, &sizes, 10, &PRECISIONS, full).unwrap();
        assert_eq!(exact.cfg.bits_w, vec![8, 8, 8]);
    }

    #[test]
    fn scales_to_twelve_blocks() {
        // u-net sized problem: 12 blocks, 4 precisions -> 16.7M leaves;
        // pruning must make this instant.
        let lw = 12;
        let s = SensitivityInputs {
            w_traces: (0..lw).map(|i| 1.0 + (i as f64 * 1.7) % 5.0).collect(),
            a_traces: vec![],
            w_lo: vec![-1.0; lw],
            w_hi: vec![1.0; lw],
            a_lo: vec![],
            a_hi: vec![],
            bn_gamma: vec![None; lw],
        };
        let sizes: Vec<usize> = (0..lw).map(|i| 100 + i * 37).collect();
        let full = model_bits(&sizes, 0, &BitConfig::uniform(lw, 0, 8));
        let t0 = std::time::Instant::now();
        let exact = exact_allocate(&s, &sizes, 0, &PRECISIONS, full / 2).unwrap();
        assert!(exact.size_bits <= full / 2);
        assert!(t0.elapsed().as_secs_f64() < 5.0, "{:?}", t0.elapsed());
    }
}
