//! Owned model state: the flat (params, m, v, step) Adam carry.
//!
//! The Rust side is the single owner of all model state between PJRT
//! dispatches (Python never runs at this point); checkpointing is a plain
//! binary dump of the four buffers.

use anyhow::{bail, Context, Result};

use crate::runtime::{Arg, Runtime};

#[derive(Debug, Clone)]
pub struct ModelState {
    pub model: String,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl ModelState {
    /// Fresh state from the model's `init` executable.
    pub fn init(rt: &Runtime, model: &str, seed: u32) -> Result<ModelState> {
        let n = rt.model(model)?.n_params;
        let exe = rt.load(model, "init")?;
        let params = exe.run(&[Arg::U32Scalar(seed)])?.f32("params")?.to_vec();
        assert_eq!(params.len(), n);
        Ok(ModelState {
            model: model.to_string(),
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0.0,
        })
    }

    /// Reset the optimizer (paper Appendix D: QAT fine-tuning restarts the
    /// optimizer from the FP checkpoint).
    pub fn reset_optimizer(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.step = 0.0;
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Checkpoint bytes: `[n: u64][step: f32][params][m][v]`, little
    /// endian — the layout both `save` files and pipeline cache payloads
    /// use.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + 12 * self.params.len());
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        for buf in [&self.params, &self.m, &self.v] {
            for x in buf.iter() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Binary checkpoint file (see [`ModelState::to_bytes`] for the layout).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<std::path::Path>, model: &str) -> Result<ModelState> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading checkpoint {}", path.as_ref().display()))?;
        Self::from_bytes(&bytes, model)
            .with_context(|| format!("decoding checkpoint {}", path.as_ref().display()))
    }

    /// Decode checkpoint bytes; size mismatches are hard errors (the
    /// pipeline cache treats them as misses and recomputes).
    pub fn from_bytes(bytes: &[u8], model: &str) -> Result<ModelState> {
        if bytes.len() < 12 {
            bail!("checkpoint too short");
        }
        let n = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        if bytes.len() != 12 + 12 * n {
            bail!("checkpoint size mismatch: {} bytes for n={n}", bytes.len());
        }
        let step = f32::from_le_bytes(bytes[8..12].try_into().unwrap());
        let read_vec = |off: usize| -> Vec<f32> {
            bytes[off..off + 4 * n]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        Ok(ModelState {
            model: model.to_string(),
            params: read_vec(12),
            m: read_vec(12 + 4 * n),
            v: read_vec(12 + 8 * n),
            step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let st = ModelState {
            model: "t".into(),
            params: vec![1.0, -2.5, 3.0],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.0, 0.5, 1.0],
            step: 42.0,
        };
        let path = std::env::temp_dir().join("fitq_ckpt_test.bin");
        st.save(&path).unwrap();
        let back = ModelState::load(&path, "t").unwrap();
        assert_eq!(back.params, st.params);
        assert_eq!(back.m, st.m);
        assert_eq!(back.v, st.v);
        assert_eq!(back.step, 42.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_checkpoint_rejected() {
        let path = std::env::temp_dir().join("fitq_ckpt_bad.bin");
        std::fs::write(&path, [1, 2, 3]).unwrap();
        assert!(ModelState::load(&path, "t").is_err());
        std::fs::write(&path, 100u64.to_le_bytes()).unwrap();
        assert!(ModelState::load(&path, "t").is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn reset_optimizer_clears_moments() {
        let mut st = ModelState {
            model: "t".into(),
            params: vec![1.0],
            m: vec![9.0],
            v: vec![9.0],
            step: 7.0,
        };
        st.reset_optimizer();
        assert_eq!((st.m[0], st.v[0], st.step), (0.0, 0.0, 0.0));
        assert_eq!(st.params[0], 1.0, "params untouched");
    }
}
