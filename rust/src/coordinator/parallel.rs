//! Scoped-thread worker pool for the coordinator's embarrassingly parallel
//! loops (std-only; no rayon/crossbeam in the vendored dependency set).
//!
//! Two things make the pool safe for experiment code:
//!
//! 1. **Determinism contract.** Work items are addressed by index and every
//!    stochastic input a job consumes must be a pure function of that index
//!    (derive per-job seeds/cursors with [`derive_seed`], never from shared
//!    mutable state). Under that contract the pool returns results in index
//!    order and a run with `jobs = N` is bit-identical to `jobs = 1` — the
//!    equivalence is enforced by `tests/parallel_equivalence.rs`.
//!
//! 2. **Per-worker state.** The PJRT `Runtime` is deliberately
//!    single-threaded (`Rc` + `RefCell` executable cache), so it cannot be
//!    shared across workers. [`run_pool`] therefore takes an `init` closure
//!    that builds one worker-local state value (e.g. its own `Runtime` over
//!    the same artifact root) on the worker's own thread; compilation cost
//!    is paid once per worker and amortized over its share of the jobs.
//!
//! Wall-clock timing fields of results (e.g. `TraceResult::iter_time_s`)
//! remain *measurements*: running jobs concurrently contends for cores, so
//! timing-sensitive experiments (Table 1/3 speedups) should use `jobs = 1`
//! when the per-iteration times are the quantity of interest. All numeric
//! outputs other than wall-clock are unaffected.
//!
//! **Panic isolation.** Every job (and every worker `init`) runs under
//! `catch_unwind`: a panicking job becomes an error instead of tearing
//! down the worker thread (and with it the whole process via scope join).
//! [`run_pool`] keeps its abort-on-first-error contract — a panic is just
//! another failing job. [`run_pool_fallible`] is the degrading variant the
//! study sweep uses: every job's outcome is returned as a
//! `Result<T, JobError>` slot, a panicked worker's state is rebuilt with a
//! fresh `init()` before it claims more work (the old state may hold a
//! broken invariant), and non-failing jobs keep bit-identity with the
//! serial path because job→result assignment stays a pure function of the
//! index. [`run_static_caught`] is the same idea for the static scheduler.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::{anyhow, Result};

use crate::coordinator::pipeline::fault::{self, site};

/// Derive an independent 64-bit seed for job `index` of a study seeded with
/// `study_seed` (splitmix64-style finalizer).
///
/// The derivation is a pure function of `(study_seed, index)` and is part of
/// the on-disk reproducibility contract: per-configuration QAT data cursors
/// and probe seeds are derived through this function, so re-running a study
/// at any `--jobs` value replays identical per-configuration streams. The
/// constants and the mapping are pinned by a unit test below — changing them
/// changes every seeded study result.
pub fn derive_seed(study_seed: u64, index: u64) -> u64 {
    let mut z = study_seed
        .wrapping_add(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(index.wrapping_mul(0xbf58_476d_1ce4_e5b9));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// One job's failure inside a fallible pool: which index, whether it
/// panicked (vs returned an error), and the stringified cause. Stringified
/// deliberately — job errors cross thread and serialization boundaries
/// (study reports persist them), so they carry no live error chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobError {
    pub index: usize,
    pub panicked: bool,
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let how = if self.panicked { "panicked" } else { "failed" };
        write!(f, "job {} {how}: {}", self.index, self.message)
    }
}

impl std::error::Error for JobError {}

/// Best-effort human message out of a caught panic payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `work(state, i)` under `catch_unwind`, flattening panics and errors
/// into [`JobError`]. The `parallel.job.panic` injection site lives here,
/// inside the catch region, so the harness exercises the real unwind path.
///
/// `AssertUnwindSafe` caveat: after a panic the state may hold a broken
/// invariant — callers must either stop using it (abort-on-error pool) or
/// rebuild it via `init` (fallible pool) before the next job.
fn call_caught<W, T, F>(state: &mut W, i: usize, work: &F) -> std::result::Result<T, JobError>
where
    F: Fn(&mut W, usize) -> Result<T>,
{
    let caught = catch_unwind(AssertUnwindSafe(|| {
        if fault::fires(site::PARALLEL_JOB_PANIC) {
            panic!("injected fault: worker job {i} panicked");
        }
        work(state, i)
    }));
    match caught {
        Ok(Ok(t)) => Ok(t),
        Ok(Err(e)) => Err(JobError { index: i, panicked: false, message: format!("{e:#}") }),
        Err(p) => Err(JobError { index: i, panicked: true, message: panic_message(&p) }),
    }
}

/// Worker-state construction under `catch_unwind`: a panicking `init`
/// (e.g. inside Runtime bring-up) degrades to an init error instead of
/// aborting the scope.
fn init_caught<W, I>(init: &I) -> Result<W>
where
    I: Fn() -> Result<W>,
{
    match catch_unwind(AssertUnwindSafe(init)) {
        Ok(r) => r,
        Err(p) => Err(anyhow!("worker init panicked: {}", panic_message(&p))),
    }
}

/// Resolve a `--jobs` setting: `0` means "one worker per available core",
/// anything else is taken literally; the result is clamped to `n` jobs.
pub fn effective_jobs(jobs: usize, n: usize) -> usize {
    let requested = if jobs == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        jobs
    };
    requested.clamp(1, n.max(1))
}

/// Run `n` indexed jobs on a pool of `jobs` scoped worker threads and
/// return the results in index order.
///
/// - `init` builds one worker-local state value per worker, on the worker's
///   own thread (so the state does not need to be `Send`);
/// - `work` maps `(worker state, job index)` to a result. Under the module
///   determinism contract it must depend only on the index and on immutable
///   captured inputs.
///
/// `jobs <= 1` (after [`effective_jobs`] resolution) runs everything inline
/// on the caller's thread with a single `init` — the serial reference path.
/// A failing job makes the pool stop claiming new work (jobs already in
/// flight finish), and the lowest-index failure among the executed jobs is
/// returned as the error; if a worker fails to initialize and some jobs
/// were consequently never executed, that initialization error is returned
/// instead. A *panicking* job is caught and counts as a failing job — it
/// aborts the sweep with a typed error, never the process. Sweeps that
/// should degrade per job instead of aborting use [`run_pool_fallible`].
pub fn run_pool<W, T, I, F>(n: usize, jobs: usize, init: I, work: F) -> Result<Vec<T>>
where
    T: Send,
    I: Fn() -> Result<W> + Sync,
    F: Fn(&mut W, usize) -> Result<T> + Sync,
{
    let jobs = effective_jobs(jobs, n);
    if jobs <= 1 {
        let mut w = init_caught(&init)?;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let r = catch_unwind(AssertUnwindSafe(|| {
                if fault::fires(site::PARALLEL_JOB_PANIC) {
                    panic!("injected fault: worker job {i} panicked");
                }
                work(&mut w, i)
            }));
            match r {
                Ok(Ok(t)) => out.push(t),
                Ok(Err(e)) => return Err(e.context(format!("parallel job {i} failed"))),
                Err(p) => {
                    return Err(anyhow!("parallel job {i} panicked: {}", panic_message(&p)))
                }
            }
        }
        return Ok(out);
    }

    let counter = AtomicUsize::new(0);
    // raised on the first failure so workers stop claiming new jobs instead
    // of burning through the whole remaining sweep before the error surfaces
    let stop = AtomicBool::new(false);
    // (per-worker (index, result) lists, per-worker init failure)
    let per_worker: Vec<(Vec<(usize, Result<T>)>, Option<anyhow::Error>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..jobs)
                .map(|_| {
                    scope.spawn(|| {
                        let mut out = Vec::new();
                        let mut state = match init_caught(&init) {
                            Ok(w) => w,
                            Err(e) => {
                                stop.store(true, Ordering::Relaxed);
                                return (out, Some(e));
                            }
                        };
                        while !stop.load(Ordering::Relaxed) {
                            let i = counter.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            let r = match catch_unwind(AssertUnwindSafe(|| {
                                if fault::fires(site::PARALLEL_JOB_PANIC) {
                                    panic!("injected fault: worker job {i} panicked");
                                }
                                work(&mut state, i)
                            })) {
                                Ok(r) => r,
                                Err(p) => Err(anyhow!(
                                    "parallel job {i} panicked: {}",
                                    panic_message(&p)
                                )),
                            };
                            // A panic (or error) raises `stop`, so the
                            // possibly-poisoned state is never handed
                            // another job before the loop exits.
                            if r.is_err() {
                                stop.store(true, Ordering::Relaxed);
                            }
                            out.push((i, r));
                        }
                        (out, None)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fitq worker thread panicked"))
                .collect()
        });

    let mut init_errors = Vec::new();
    let mut slots: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
    for (results, init_err) in per_worker {
        for (i, r) in results {
            slots[i] = Some(r);
        }
        if let Some(e) = init_err {
            init_errors.push(e);
        }
    }

    // a real job failure (lowest executed index) outranks gaps left by the
    // early-abort, which in turn fall back to a worker's init error
    let mut out = Vec::with_capacity(n);
    let mut missing = None;
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(t)) => out.push(t),
            Some(Err(e)) => return Err(e.context(format!("parallel job {i} failed"))),
            None if missing.is_none() => missing = Some(i),
            None => {}
        }
    }
    if let Some(i) = missing {
        let e = match init_errors.pop() {
            Some(e) => e.context("worker initialization failed"),
            None => anyhow!("parallel job {i} was never scheduled (pool aborted early)"),
        };
        return Err(e);
    }
    Ok(out)
}

/// Streaming variant of [`run_pool`]: results are handed to `on_result`
/// on the *calling* thread as workers finish them, instead of being
/// collected into an index-ordered `Vec` at the end. This is the search
/// service's fan-out primitive — per-shard Pareto fronts are folded (and
/// streamed to the client) the moment each shard lands, not after the
/// slowest one.
///
/// Contract:
/// - `on_result(index, value)` runs on the caller's thread, serially, in
///   *completion* order — which is nondeterministic for `jobs > 1`.
///   Callers needing bit-identical outcomes at every jobs value must fold
///   order-invariantly (e.g. [`ParetoAccumulator`], or writing into a slot
///   keyed by `index`). `jobs <= 1` runs inline in index order and is the
///   serial reference path.
/// - A failing or panicking job stops the pool claiming new work and the
///   call returns that error (lowest-index error among those seen);
///   results that were already in flight are dropped, not folded.
/// - An error from `on_result` likewise stops the pool and is returned.
///
/// [`ParetoAccumulator`]: crate::coordinator::search::ParetoAccumulator
pub fn run_pool_streaming<W, T, I, F, C>(
    n: usize,
    jobs: usize,
    init: I,
    work: F,
    mut on_result: C,
) -> Result<()>
where
    T: Send,
    I: Fn() -> Result<W> + Sync,
    F: Fn(&mut W, usize) -> Result<T> + Sync,
    C: FnMut(usize, T) -> Result<()>,
{
    let jobs = effective_jobs(jobs, n);
    if jobs <= 1 {
        let mut w = init_caught(&init)?;
        for i in 0..n {
            match call_caught(&mut w, i, &work) {
                Ok(t) => on_result(i, t)?,
                Err(je) => return Err(anyhow!(je)),
            }
        }
        return Ok(());
    }

    let counter = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    // workers push (index, outcome) into an unbounded channel; the caller
    // drains it and folds on its own thread. `Sender` is cheaply cloned
    // per worker; dropping the last clone ends the caller's drain loop.
    let (tx, rx) = std::sync::mpsc::channel::<(usize, std::result::Result<T, JobError>)>();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let (init, work, counter, stop) = (&init, &work, &counter, &stop);
            scope.spawn(move || {
                let mut state = match init_caught(init) {
                    Ok(w) => w,
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        // surface init failure as a job error on the next
                        // unclaimed index (nothing was executed for it)
                        let i = counter.fetch_add(1, Ordering::Relaxed).min(n);
                        let je =
                            JobError { index: i, panicked: false, message: format!("{e:#}") };
                        let _ = tx.send((i, Err(je)));
                        return;
                    }
                };
                while !stop.load(Ordering::Relaxed) {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = call_caught(&mut state, i, work);
                    if r.is_err() {
                        stop.store(true, Ordering::Relaxed);
                    }
                    if tx.send((i, r)).is_err() {
                        break; // caller stopped draining
                    }
                }
            });
        }
        drop(tx);

        let mut first_err: Option<JobError> = None;
        let mut consumer_err: Option<anyhow::Error> = None;
        for (i, r) in rx {
            match r {
                Ok(t) => {
                    if first_err.is_none() && consumer_err.is_none() {
                        if let Err(e) = on_result(i, t) {
                            stop.store(true, Ordering::Relaxed);
                            consumer_err = Some(e);
                        }
                    }
                }
                Err(je) => {
                    if first_err.as_ref().is_none_or(|f| je.index < f.index) {
                        first_err = Some(je);
                    }
                }
            }
        }
        match (consumer_err, first_err) {
            (Some(e), _) => Err(e),
            (None, Some(je)) => Err(anyhow!(je)),
            (None, None) => Ok(()),
        }
    })
}

/// Degrading variant of [`run_pool`]: every job's outcome comes back as a
/// `Result<T, JobError>` slot in index order, and a failing (or panicking)
/// job never stops the sweep — one poisoned config degrades one slot, not
/// a million-config run.
///
/// After a *panicked* job the worker's state is rebuilt with a fresh
/// `init()` before it claims more work, since the old state may have been
/// unwound mid-update. Errors returned by `work` leave the state in place
/// (returning `Err` is a normal, invariant-preserving exit). If a worker's
/// (re-)`init` fails its remaining share is picked up by the other
/// workers; jobs that never executed because *every* worker died are
/// reported as failed slots carrying the init error, and the call itself
/// only errors when no worker ever initialized (nothing executed at all).
///
/// Bit-identity: non-failing jobs produce the same bytes at every `jobs`
/// value — job→result assignment is a pure function of the index, exactly
/// as in [`run_pool`].
pub fn run_pool_fallible<W, T, I, F>(
    n: usize,
    jobs: usize,
    init: I,
    work: F,
) -> Result<Vec<std::result::Result<T, JobError>>>
where
    T: Send,
    I: Fn() -> Result<W> + Sync,
    F: Fn(&mut W, usize) -> Result<T> + Sync,
{
    let jobs = effective_jobs(jobs, n);
    if jobs <= 1 {
        let mut w = init_caught(&init)?;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let r = call_caught(&mut w, i, &work);
            let poisoned = r.as_ref().is_err_and(|je| je.panicked);
            out.push(r);
            if poisoned && i + 1 < n {
                w = init_caught(&init)?;
            }
        }
        return Ok(out);
    }

    let counter = AtomicUsize::new(0);
    let per_worker: Vec<(Vec<(usize, std::result::Result<T, JobError>)>, Option<anyhow::Error>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..jobs)
                .map(|_| {
                    scope.spawn(|| {
                        let mut out = Vec::new();
                        let mut state = match init_caught(&init) {
                            Ok(w) => w,
                            Err(e) => return (out, Some(e)),
                        };
                        loop {
                            let i = counter.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            let r = call_caught(&mut state, i, &work);
                            let poisoned = r.as_ref().is_err_and(|je| je.panicked);
                            out.push((i, r));
                            if poisoned {
                                // the unwound state may hold a broken
                                // invariant — rebuild before the next job
                                state = match init_caught(&init) {
                                    Ok(w) => w,
                                    Err(e) => return (out, Some(e)),
                                };
                            }
                        }
                        (out, None)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fitq worker thread panicked"))
                .collect()
        });

    let mut init_errors = Vec::new();
    let mut executed = 0usize;
    let mut slots: Vec<Option<std::result::Result<T, JobError>>> = (0..n).map(|_| None).collect();
    for (results, init_err) in per_worker {
        executed += results.len();
        for (i, r) in results {
            slots[i] = Some(r);
        }
        if let Some(e) = init_err {
            init_errors.push(e);
        }
    }
    if executed == 0 {
        if let Some(e) = init_errors.pop() {
            return Err(e.context("worker initialization failed"));
        }
    }
    let init_msg = init_errors
        .first()
        .map(|e| format!("never executed: worker init failed: {e:#}"))
        .unwrap_or_else(|| "never executed: pool exited early".to_string());
    Ok(slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.unwrap_or_else(|| {
                Err(JobError { index: i, panicked: false, message: init_msg.clone() })
            })
        })
        .collect())
}

/// Serial counterpart of [`run_pool_fallible`] for state that cannot
/// cross threads (the caller's own non-`Sync` `Runtime`): every job runs
/// under `catch_unwind` on the calling thread and degrades to a
/// [`JobError`] slot, same injection site included. Unlike the pool, the
/// state is *reused* after a panic — it must be unwind-tolerant (at worst
/// losing interior cache entries), which `Runtime` is: its interior
/// mutability is memoization, and unwinding drops any live borrow guards.
pub fn run_serial_fallible<W, T, F>(
    n: usize,
    state: &mut W,
    work: F,
) -> Vec<std::result::Result<T, JobError>>
where
    F: Fn(&mut W, usize) -> Result<T>,
{
    (0..n).map(|i| call_caught(state, i, &work)).collect()
}

/// Run one closure per item on `threads` scoped worker threads with a
/// *static* contiguous schedule, returning nothing: each item is consumed
/// by `f(index, item)` for its original index.
///
/// This is the intra-op fan-out primitive of the native backend's GEMM
/// layer (`native::gemm`): items are typically disjoint `&mut` output
/// panels, so workers write results in place and no collection step (or
/// `Result` plumbing) is needed. Where [`run_pool`] hands out jobs
/// dynamically through an atomic counter, `run_static` fixes the
/// item→worker assignment up front (worker `t` gets a contiguous run of
/// `n/threads` items, earlier workers taking the remainder): combined
/// with the determinism contract above (each item's result is a pure
/// function of its index), the output is bit-identical at every thread
/// count — the schedule only decides *who* computes a panel, never what
/// the panel contains. The calling thread executes the first chunk
/// itself, so `threads = 1` spawns nothing and is the serial reference
/// path.
pub fn run_static<T, F>(items: Vec<T>, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        for (i, item) in items.into_iter().enumerate() {
            f(i, item);
        }
        return;
    }
    // contiguous static split: chunk t covers indices [base_t, base_t + len_t)
    let mut chunks: Vec<(usize, Vec<T>)> = Vec::with_capacity(threads);
    let mut it = items.into_iter();
    let mut base = 0usize;
    for t in 0..threads {
        let len = n / threads + usize::from(t < n % threads);
        chunks.push((base, it.by_ref().take(len).collect()));
        base += len;
    }
    std::thread::scope(|scope| {
        let mut own = None;
        for (i, chunk) in chunks.into_iter().enumerate() {
            if i == 0 {
                own = Some(chunk);
                continue;
            }
            let fr = &f;
            scope.spawn(move || {
                let (cbase, citems) = chunk;
                for (off, item) in citems.into_iter().enumerate() {
                    fr(cbase + off, item);
                }
            });
        }
        if let Some((cbase, citems)) = own {
            for (off, item) in citems.into_iter().enumerate() {
                f(cbase + off, item);
            }
        }
    });
}

/// Fallible variant of [`run_static`]: each `f(index, item)` call runs
/// under `catch_unwind`, a panicking item degrades to a [`JobError`] while
/// the rest of its chunk (and every other chunk) still executes, and the
/// collected errors come back sorted by index. `Ok(())` means every item
/// ran clean. `f` must be per-item stateless (it is `Fn`), so continuing
/// a chunk after one item unwound is sound.
pub fn run_static_caught<T, F>(
    items: Vec<T>,
    threads: usize,
    f: F,
) -> std::result::Result<(), Vec<JobError>>
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let call = |i: usize, item: T| -> Option<JobError> {
        match catch_unwind(AssertUnwindSafe(|| f(i, item))) {
            Ok(()) => None,
            Err(p) => Some(JobError { index: i, panicked: true, message: panic_message(&p) }),
        }
    };
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    let mut errors: Vec<JobError> = if threads <= 1 {
        items.into_iter().enumerate().filter_map(|(i, item)| call(i, item)).collect()
    } else {
        let mut chunks: Vec<(usize, Vec<T>)> = Vec::with_capacity(threads);
        let mut it = items.into_iter();
        let mut base = 0usize;
        for t in 0..threads {
            let len = n / threads + usize::from(t < n % threads);
            chunks.push((base, it.by_ref().take(len).collect()));
            base += len;
        }
        std::thread::scope(|scope| {
            let mut own = None;
            let handles: Vec<_> = chunks
                .into_iter()
                .enumerate()
                .filter_map(|(t, chunk)| {
                    if t == 0 {
                        own = Some(chunk);
                        return None;
                    }
                    let callr = &call;
                    Some(scope.spawn(move || {
                        let (cbase, citems) = chunk;
                        citems
                            .into_iter()
                            .enumerate()
                            .filter_map(|(off, item)| callr(cbase + off, item))
                            .collect::<Vec<_>>()
                    }))
                })
                .collect();
            let mut errs: Vec<JobError> = own
                .map(|(cbase, citems)| {
                    citems
                        .into_iter()
                        .enumerate()
                        .filter_map(|(off, item)| call(cbase + off, item))
                        .collect()
                })
                .unwrap_or_default();
            for h in handles {
                errs.extend(h.join().expect("fitq worker thread panicked"));
            }
            errs
        })
    };
    if errors.is_empty() {
        Ok(())
    } else {
        errors.sort_by_key(|e| e.index);
        Err(errors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_pinned() {
        // pinned values: changing the derivation silently changes every
        // seeded study, so this test fails loudly instead.
        assert_eq!(derive_seed(0, 0), 16294208416658607535);
        assert_eq!(derive_seed(0, 1), 16481712997681181849);
        assert_eq!(derive_seed(0, 2), 392536317241979068);
        assert_eq!(derive_seed(42, 7), 13611663889625010092);
        assert_eq!(derive_seed(7, 0), 7191089600892374487);
    }

    #[test]
    fn derive_seed_separates_indices_and_seeds() {
        let mut seen = std::collections::HashSet::new();
        for seed in 0..8u64 {
            for idx in 0..256u64 {
                assert!(seen.insert(derive_seed(seed, idx)), "collision at {seed}/{idx}");
            }
        }
    }

    #[test]
    fn effective_jobs_resolution() {
        assert_eq!(effective_jobs(1, 100), 1);
        assert_eq!(effective_jobs(4, 100), 4);
        assert_eq!(effective_jobs(8, 3), 3, "clamped to job count");
        assert_eq!(effective_jobs(3, 0), 1, "empty input still gets one lane");
        assert!(effective_jobs(0, 64) >= 1, "auto resolves to at least one");
    }

    #[test]
    fn pool_returns_results_in_index_order() {
        let out = run_pool(50, 4, || Ok(0u64), |_, i| Ok(i * i)).unwrap();
        let expect: Vec<usize> = (0..50).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pool_serial_path_reuses_one_state() {
        let out = run_pool(
            5,
            1,
            || Ok(0usize),
            |w, i| {
                *w += 1;
                Ok((*w, i))
            },
        )
        .unwrap();
        // one worker state counts all five jobs in order
        assert_eq!(out, vec![(1, 0), (2, 1), (3, 2), (4, 3), (5, 4)]);
    }

    #[test]
    fn pool_reports_lowest_failing_index() {
        let r: Result<Vec<usize>> = run_pool(
            20,
            4,
            || Ok(()),
            |_, i| {
                if i % 7 == 3 {
                    Err(anyhow!("boom at {i}"))
                } else {
                    Ok(i)
                }
            },
        );
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("job 3"), "{msg}");
        assert!(msg.contains("boom at 3"), "{msg}");
    }

    #[test]
    fn pool_surfaces_init_failure() {
        let r: Result<Vec<usize>> =
            run_pool(4, 3, || Err::<(), _>(anyhow!("no runtime")), |_, i| Ok(i));
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("no runtime"), "{msg}");
    }

    #[test]
    fn pool_zero_jobs_is_auto() {
        let out = run_pool(8, 0, || Ok(()), |_, i| Ok(i)).unwrap();
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn run_static_visits_every_index_once() {
        // disjoint &mut panels of one buffer, exactly the GEMM use case
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let mut buf = vec![0u32; 11 * 3];
            let panels: Vec<(usize, &mut [u32])> =
                buf.chunks_mut(3).enumerate().collect();
            run_static(panels, threads, |i, (pi, panel)| {
                assert_eq!(i, pi, "schedule must preserve item order");
                for v in panel.iter_mut() {
                    *v += 1 + pi as u32;
                }
            });
            let expect: Vec<u32> =
                (0..11u32).flat_map(|p| [p + 1, p + 1, p + 1]).collect();
            assert_eq!(buf, expect, "threads={threads}");
        }
    }

    #[test]
    fn run_static_handles_empty_and_oversubscribed() {
        run_static(Vec::<usize>::new(), 4, |_, _| panic!("no items"));
        let mut hits = vec![0u8; 2];
        let items: Vec<&mut u8> = hits.iter_mut().collect();
        run_static(items, 9, |_, h| *h += 1);
        assert_eq!(hits, vec![1, 1]);
    }

    #[test]
    fn pool_converts_job_panic_to_typed_error() {
        for jobs in [1usize, 4] {
            let r: Result<Vec<usize>> = run_pool(
                12,
                jobs,
                || Ok(()),
                |_, i| {
                    if i == 2 {
                        panic!("wrecked at {i}");
                    }
                    Ok(i)
                },
            );
            let msg = format!("{:#}", r.unwrap_err());
            assert!(msg.contains("panicked"), "jobs={jobs}: {msg}");
            assert!(msg.contains("wrecked at 2"), "jobs={jobs}: {msg}");
        }
    }

    #[test]
    fn pool_converts_init_panic_to_error() {
        let r: Result<Vec<usize>> = run_pool(
            4,
            2,
            || -> Result<()> { panic!("init exploded") },
            |_, i| Ok(i),
        );
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("init panicked"), "{msg}");
        assert!(msg.contains("init exploded"), "{msg}");
    }

    #[test]
    fn fallible_pool_degrades_per_job_and_keeps_the_rest() {
        for jobs in [1usize, 3] {
            let out = run_pool_fallible(
                10,
                jobs,
                || Ok(()),
                |_, i| match i {
                    3 => Err(anyhow!("bad config {i}")),
                    5 => panic!("poisoned config {i}"),
                    _ => Ok(i * 10),
                },
            )
            .unwrap();
            assert_eq!(out.len(), 10);
            for (i, slot) in out.iter().enumerate() {
                match i {
                    3 => {
                        let e = slot.as_ref().unwrap_err();
                        assert!(!e.panicked);
                        assert!(e.message.contains("bad config 3"), "{e}");
                        assert_eq!(e.index, 3);
                    }
                    5 => {
                        let e = slot.as_ref().unwrap_err();
                        assert!(e.panicked);
                        assert!(e.message.contains("poisoned config 5"), "{e}");
                    }
                    _ => assert_eq!(*slot.as_ref().unwrap(), i * 10, "jobs={jobs}"),
                }
            }
        }
    }

    #[test]
    fn fallible_pool_rebuilds_state_after_panic() {
        use std::sync::atomic::AtomicUsize;
        let inits = AtomicUsize::new(0);
        let out = run_pool_fallible(
            4,
            1,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                Ok(0u64)
            },
            |w, i| {
                if i == 0 {
                    *w = 999; // poison, then unwind mid-update
                    panic!("die at 0");
                }
                *w += 1;
                Ok(*w)
            },
        )
        .unwrap();
        assert_eq!(inits.load(Ordering::Relaxed), 2, "state rebuilt after the panic");
        assert!(out[0].as_ref().unwrap_err().panicked);
        // jobs 1..3 ran on the *fresh* state: 1, 2, 3 — never 1000
        let rest: Vec<u64> = out[1..].iter().map(|r| *r.as_ref().unwrap()).collect();
        assert_eq!(rest, vec![1, 2, 3]);
    }

    #[test]
    fn fallible_pool_surfaces_total_init_failure() {
        let r = run_pool_fallible(4, 3, || Err::<(), _>(anyhow!("no runtime")), |_, i| Ok(i));
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("no runtime"), "{msg}");
    }

    #[test]
    fn fallible_pool_fires_injected_panic_site() {
        use crate::coordinator::pipeline::fault::FaultPlan;
        let scope = fault::scoped(FaultPlan::single(site::PARALLEL_JOB_PANIC));
        let out = run_pool_fallible(6, 2, || Ok(()), |_, i| Ok(i)).unwrap();
        let failed: Vec<&JobError> = out.iter().filter_map(|r| r.as_ref().err()).collect();
        assert_eq!(failed.len(), 1, "exactly one injected failure");
        assert!(failed[0].panicked);
        assert!(failed[0].message.contains("injected fault"), "{}", failed[0]);
        assert_eq!(scope.fired(site::PARALLEL_JOB_PANIC), 1);
        let ok: Vec<usize> = out.iter().filter_map(|r| r.as_ref().ok().copied()).collect();
        assert_eq!(ok.len(), 5, "non-failing jobs all survived");
    }

    #[test]
    fn run_static_caught_collects_panics_and_finishes_the_rest() {
        use std::sync::atomic::AtomicU32;
        for threads in [1usize, 3] {
            let ran = AtomicU32::new(0);
            let err = run_static_caught((0..7).collect::<Vec<usize>>(), threads, |i, item| {
                assert_eq!(i, item);
                ran.fetch_add(1 << i, Ordering::Relaxed);
                if i == 2 || i == 5 {
                    panic!("item {i} down");
                }
            })
            .unwrap_err();
            let idx: Vec<usize> = err.iter().map(|e| e.index).collect();
            assert_eq!(idx, vec![2, 5], "threads={threads}");
            assert!(err.iter().all(|e| e.panicked));
            assert_eq!(ran.load(Ordering::Relaxed), 0b111_1111, "every item executed");
        }
        assert!(run_static_caught(vec![1, 2], 2, |_, _| {}).is_ok());
    }

    #[test]
    fn streaming_pool_delivers_every_result_exactly_once() {
        for jobs in [1usize, 2, 4, 7] {
            let mut seen = vec![0u8; 40];
            run_pool_streaming(
                40,
                jobs,
                || Ok(()),
                |_, i| Ok(i * 3),
                |i, v| {
                    assert_eq!(v, i * 3);
                    seen[i] += 1;
                    Ok(())
                },
            )
            .unwrap();
            assert!(seen.iter().all(|&c| c == 1), "jobs={jobs}: {seen:?}");
        }
    }

    #[test]
    fn streaming_pool_serial_path_is_index_ordered() {
        let mut order = Vec::new();
        run_pool_streaming(
            6,
            1,
            || Ok(()),
            |_, i| Ok(i),
            |i, _| {
                order.push(i);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn streaming_pool_surfaces_job_and_consumer_errors() {
        for jobs in [1usize, 4] {
            let r = run_pool_streaming(
                20,
                jobs,
                || Ok(()),
                |_, i| if i == 5 { Err(anyhow!("shard 5 bad")) } else { Ok(i) },
                |_, _| Ok(()),
            );
            let msg = format!("{:#}", r.unwrap_err());
            assert!(msg.contains("shard 5 bad"), "jobs={jobs}: {msg}");

            let r = run_pool_streaming(
                20,
                jobs,
                || Ok(()),
                |_, i| Ok(i),
                |_, _| Err(anyhow!("client went away")),
            );
            let msg = format!("{:#}", r.unwrap_err());
            assert!(msg.contains("client went away"), "jobs={jobs}: {msg}");
        }
    }

    #[test]
    fn streaming_pool_converts_panics_and_init_failures() {
        let r = run_pool_streaming(
            12,
            3,
            || Ok(()),
            |_, i: usize| -> Result<usize> {
                if i == 2 {
                    panic!("shard 2 wrecked");
                }
                Ok(i)
            },
            |_, _| Ok(()),
        );
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("shard 2 wrecked"), "{msg}");

        let r = run_pool_streaming(
            4,
            2,
            || Err::<(), _>(anyhow!("no runtime")),
            |_, i| Ok(i),
            |_, _| Ok(()),
        );
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("no runtime"), "{msg}");
    }

    #[test]
    fn job_error_display_names_index_and_mode() {
        let e = JobError { index: 7, panicked: true, message: "kaboom".into() };
        assert_eq!(e.to_string(), "job 7 panicked: kaboom");
        let e = JobError { index: 3, panicked: false, message: "bad input".into() };
        assert_eq!(e.to_string(), "job 3 failed: bad input");
    }
}
