//! Scoped-thread worker pool for the coordinator's embarrassingly parallel
//! loops (std-only; no rayon/crossbeam in the vendored dependency set).
//!
//! Two things make the pool safe for experiment code:
//!
//! 1. **Determinism contract.** Work items are addressed by index and every
//!    stochastic input a job consumes must be a pure function of that index
//!    (derive per-job seeds/cursors with [`derive_seed`], never from shared
//!    mutable state). Under that contract the pool returns results in index
//!    order and a run with `jobs = N` is bit-identical to `jobs = 1` — the
//!    equivalence is enforced by `tests/parallel_equivalence.rs`.
//!
//! 2. **Per-worker state.** The PJRT `Runtime` is deliberately
//!    single-threaded (`Rc` + `RefCell` executable cache), so it cannot be
//!    shared across workers. [`run_pool`] therefore takes an `init` closure
//!    that builds one worker-local state value (e.g. its own `Runtime` over
//!    the same artifact root) on the worker's own thread; compilation cost
//!    is paid once per worker and amortized over its share of the jobs.
//!
//! Wall-clock timing fields of results (e.g. `TraceResult::iter_time_s`)
//! remain *measurements*: running jobs concurrently contends for cores, so
//! timing-sensitive experiments (Table 1/3 speedups) should use `jobs = 1`
//! when the per-iteration times are the quantity of interest. All numeric
//! outputs other than wall-clock are unaffected.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::{anyhow, Result};

/// Derive an independent 64-bit seed for job `index` of a study seeded with
/// `study_seed` (splitmix64-style finalizer).
///
/// The derivation is a pure function of `(study_seed, index)` and is part of
/// the on-disk reproducibility contract: per-configuration QAT data cursors
/// and probe seeds are derived through this function, so re-running a study
/// at any `--jobs` value replays identical per-configuration streams. The
/// constants and the mapping are pinned by a unit test below — changing them
/// changes every seeded study result.
pub fn derive_seed(study_seed: u64, index: u64) -> u64 {
    let mut z = study_seed
        .wrapping_add(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(index.wrapping_mul(0xbf58_476d_1ce4_e5b9));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Resolve a `--jobs` setting: `0` means "one worker per available core",
/// anything else is taken literally; the result is clamped to `n` jobs.
pub fn effective_jobs(jobs: usize, n: usize) -> usize {
    let requested = if jobs == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        jobs
    };
    requested.clamp(1, n.max(1))
}

/// Run `n` indexed jobs on a pool of `jobs` scoped worker threads and
/// return the results in index order.
///
/// - `init` builds one worker-local state value per worker, on the worker's
///   own thread (so the state does not need to be `Send`);
/// - `work` maps `(worker state, job index)` to a result. Under the module
///   determinism contract it must depend only on the index and on immutable
///   captured inputs.
///
/// `jobs <= 1` (after [`effective_jobs`] resolution) runs everything inline
/// on the caller's thread with a single `init` — the serial reference path.
/// A failing job makes the pool stop claiming new work (jobs already in
/// flight finish), and the lowest-index failure among the executed jobs is
/// returned as the error; if a worker fails to initialize and some jobs
/// were consequently never executed, that initialization error is returned
/// instead.
pub fn run_pool<W, T, I, F>(n: usize, jobs: usize, init: I, work: F) -> Result<Vec<T>>
where
    T: Send,
    I: Fn() -> Result<W> + Sync,
    F: Fn(&mut W, usize) -> Result<T> + Sync,
{
    let jobs = effective_jobs(jobs, n);
    if jobs <= 1 {
        let mut w = init()?;
        return (0..n).map(|i| work(&mut w, i)).collect();
    }

    let counter = AtomicUsize::new(0);
    // raised on the first failure so workers stop claiming new jobs instead
    // of burning through the whole remaining sweep before the error surfaces
    let stop = AtomicBool::new(false);
    // (per-worker (index, result) lists, per-worker init failure)
    let per_worker: Vec<(Vec<(usize, Result<T>)>, Option<anyhow::Error>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..jobs)
                .map(|_| {
                    scope.spawn(|| {
                        let mut out = Vec::new();
                        let mut state = match init() {
                            Ok(w) => w,
                            Err(e) => {
                                stop.store(true, Ordering::Relaxed);
                                return (out, Some(e));
                            }
                        };
                        while !stop.load(Ordering::Relaxed) {
                            let i = counter.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            let r = work(&mut state, i);
                            if r.is_err() {
                                stop.store(true, Ordering::Relaxed);
                            }
                            out.push((i, r));
                        }
                        (out, None)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fitq worker thread panicked"))
                .collect()
        });

    let mut init_errors = Vec::new();
    let mut slots: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
    for (results, init_err) in per_worker {
        for (i, r) in results {
            slots[i] = Some(r);
        }
        if let Some(e) = init_err {
            init_errors.push(e);
        }
    }

    // a real job failure (lowest executed index) outranks gaps left by the
    // early-abort, which in turn fall back to a worker's init error
    let mut out = Vec::with_capacity(n);
    let mut missing = None;
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(t)) => out.push(t),
            Some(Err(e)) => return Err(e.context(format!("parallel job {i} failed"))),
            None if missing.is_none() => missing = Some(i),
            None => {}
        }
    }
    if let Some(i) = missing {
        let e = match init_errors.pop() {
            Some(e) => e.context("worker initialization failed"),
            None => anyhow!("parallel job {i} was never scheduled (pool aborted early)"),
        };
        return Err(e);
    }
    Ok(out)
}

/// Run one closure per item on `threads` scoped worker threads with a
/// *static* contiguous schedule, returning nothing: each item is consumed
/// by `f(index, item)` for its original index.
///
/// This is the intra-op fan-out primitive of the native backend's GEMM
/// layer (`native::gemm`): items are typically disjoint `&mut` output
/// panels, so workers write results in place and no collection step (or
/// `Result` plumbing) is needed. Where [`run_pool`] hands out jobs
/// dynamically through an atomic counter, `run_static` fixes the
/// item→worker assignment up front (worker `t` gets a contiguous run of
/// `n/threads` items, earlier workers taking the remainder): combined
/// with the determinism contract above (each item's result is a pure
/// function of its index), the output is bit-identical at every thread
/// count — the schedule only decides *who* computes a panel, never what
/// the panel contains. The calling thread executes the first chunk
/// itself, so `threads = 1` spawns nothing and is the serial reference
/// path.
pub fn run_static<T, F>(items: Vec<T>, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        for (i, item) in items.into_iter().enumerate() {
            f(i, item);
        }
        return;
    }
    // contiguous static split: chunk t covers indices [base_t, base_t + len_t)
    let mut chunks: Vec<(usize, Vec<T>)> = Vec::with_capacity(threads);
    let mut it = items.into_iter();
    let mut base = 0usize;
    for t in 0..threads {
        let len = n / threads + usize::from(t < n % threads);
        chunks.push((base, it.by_ref().take(len).collect()));
        base += len;
    }
    std::thread::scope(|scope| {
        let mut own = None;
        for (i, chunk) in chunks.into_iter().enumerate() {
            if i == 0 {
                own = Some(chunk);
                continue;
            }
            let fr = &f;
            scope.spawn(move || {
                let (cbase, citems) = chunk;
                for (off, item) in citems.into_iter().enumerate() {
                    fr(cbase + off, item);
                }
            });
        }
        if let Some((cbase, citems)) = own {
            for (off, item) in citems.into_iter().enumerate() {
                f(cbase + off, item);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_pinned() {
        // pinned values: changing the derivation silently changes every
        // seeded study, so this test fails loudly instead.
        assert_eq!(derive_seed(0, 0), 16294208416658607535);
        assert_eq!(derive_seed(0, 1), 16481712997681181849);
        assert_eq!(derive_seed(0, 2), 392536317241979068);
        assert_eq!(derive_seed(42, 7), 13611663889625010092);
        assert_eq!(derive_seed(7, 0), 7191089600892374487);
    }

    #[test]
    fn derive_seed_separates_indices_and_seeds() {
        let mut seen = std::collections::HashSet::new();
        for seed in 0..8u64 {
            for idx in 0..256u64 {
                assert!(seen.insert(derive_seed(seed, idx)), "collision at {seed}/{idx}");
            }
        }
    }

    #[test]
    fn effective_jobs_resolution() {
        assert_eq!(effective_jobs(1, 100), 1);
        assert_eq!(effective_jobs(4, 100), 4);
        assert_eq!(effective_jobs(8, 3), 3, "clamped to job count");
        assert_eq!(effective_jobs(3, 0), 1, "empty input still gets one lane");
        assert!(effective_jobs(0, 64) >= 1, "auto resolves to at least one");
    }

    #[test]
    fn pool_returns_results_in_index_order() {
        let out = run_pool(50, 4, || Ok(0u64), |_, i| Ok(i * i)).unwrap();
        let expect: Vec<usize> = (0..50).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pool_serial_path_reuses_one_state() {
        let out = run_pool(
            5,
            1,
            || Ok(0usize),
            |w, i| {
                *w += 1;
                Ok((*w, i))
            },
        )
        .unwrap();
        // one worker state counts all five jobs in order
        assert_eq!(out, vec![(1, 0), (2, 1), (3, 2), (4, 3), (5, 4)]);
    }

    #[test]
    fn pool_reports_lowest_failing_index() {
        let r: Result<Vec<usize>> = run_pool(
            20,
            4,
            || Ok(()),
            |_, i| {
                if i % 7 == 3 {
                    Err(anyhow!("boom at {i}"))
                } else {
                    Ok(i)
                }
            },
        );
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("job 3"), "{msg}");
        assert!(msg.contains("boom at 3"), "{msg}");
    }

    #[test]
    fn pool_surfaces_init_failure() {
        let r: Result<Vec<usize>> =
            run_pool(4, 3, || Err::<(), _>(anyhow!("no runtime")), |_, i| Ok(i));
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("no runtime"), "{msg}");
    }

    #[test]
    fn pool_zero_jobs_is_auto() {
        let out = run_pool(8, 0, || Ok(()), |_, i| Ok(i)).unwrap();
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn run_static_visits_every_index_once() {
        // disjoint &mut panels of one buffer, exactly the GEMM use case
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let mut buf = vec![0u32; 11 * 3];
            let panels: Vec<(usize, &mut [u32])> =
                buf.chunks_mut(3).enumerate().collect();
            run_static(panels, threads, |i, (pi, panel)| {
                assert_eq!(i, pi, "schedule must preserve item order");
                for v in panel.iter_mut() {
                    *v += 1 + pi as u32;
                }
            });
            let expect: Vec<u32> =
                (0..11u32).flat_map(|p| [p + 1, p + 1, p + 1]).collect();
            assert_eq!(buf, expect, "threads={threads}");
        }
    }

    #[test]
    fn run_static_handles_empty_and_oversubscribed() {
        run_static(Vec::<usize>::new(), 4, |_, _| panic!("no items"));
        let mut hits = vec![0u8; 2];
        let items: Vec<&mut u8> = hits.iter_mut().collect();
        run_static(items, 9, |_, h| *h += 1);
        assert_eq!(hits, vec![1, 1]);
    }
}
