//! Gathers `SensitivityInputs` for a trained model: converged EF traces
//! (weights + activations), min-max weight ranges, calibrated activation
//! ranges, and BN scales — everything each metric in the Table-2 zoo needs,
//! collected once per trained model and reused across every configuration.

use anyhow::Result;

use super::state::ModelState;
use super::traces::{Estimator, TraceEngine, TraceOptions, TraceResult};
use super::trainer::{ActRanges, Trainer};
use crate::data::{Dataset, EvalSet};
use crate::metrics::SensitivityInputs;

#[derive(Debug, Clone)]
pub struct SensitivityReport {
    pub inputs: SensitivityInputs,
    pub act: ActRanges,
    pub trace: TraceResult,
}

/// Collect metric inputs for a trained state. `opt` controls the EF trace
/// run (tolerance / iteration cap).
pub fn gather(
    trainer: &Trainer,
    ds: &dyn Dataset,
    state: &ModelState,
    ev: &EvalSet,
    opt: TraceOptions,
) -> Result<SensitivityReport> {
    let rt = trainer.runtime();
    let engine = TraceEngine::new(rt, ds);
    let trace = engine.run(&state.model, &state.params, Estimator::EmpiricalFisher, opt)?;
    let (w_lo, w_hi) = trainer.param_ranges(state)?;
    let act = trainer.calibrate(state, ev)?;
    let bn_gamma = trainer.bn_gammas(state)?;
    let inputs = SensitivityInputs {
        w_traces: trace.w_traces.clone(),
        a_traces: trace.a_traces.clone(),
        w_lo: w_lo.iter().map(|&x| x as f64).collect(),
        w_hi: w_hi.iter().map(|&x| x as f64).collect(),
        a_lo: act.lo.iter().map(|&x| x as f64).collect(),
        a_hi: act.hi.iter().map(|&x| x as f64).collect(),
        bn_gamma,
    };
    Ok(SensitivityReport { inputs, act, trace })
}
