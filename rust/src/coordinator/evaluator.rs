//! The rank-correlation evaluation pipeline (paper §4.2, Table 2, Fig. 3).
//!
//! For one (model, dataset) experiment:
//!   1. train a full-precision model to convergence;
//!   2. gather sensitivity inputs (EF traces, ranges, BN scales) once;
//!   3. sample N distinct random MPQ configurations;
//!   4. for each: score every metric, QAT-fine-tune from the FP
//!      checkpoint (identical initialisation across configs, paper
//!      Appendix D), evaluate the quantized model;
//!   5. rank-correlate each metric against final performance.
//!
//! Steps 1-2 are stage-graph lookups (`coordinator::pipeline`): the FP
//! checkpoint and sensitivity report are computed at most once per key and
//! shared across every experiment and process, and the finished study is
//! itself a cached stage output.
//!
//! Step 4 dominates wall-clock (hundreds of QAT fine-tunes) and every
//! configuration is independent, so it fans out over the
//! `coordinator::parallel` worker pool. Each configuration's QAT data
//! stream starts at a cursor derived from `(study seed, config index)` —
//! never from shared trainer state — so `jobs = 1` and `jobs = N` produce
//! bit-identical outcomes and correlations.
//!
//! The sweep *degrades* instead of aborting: a configuration whose QAT run
//! errors or panics becomes a [`ConfigFailure`] entry (surfaced in the
//! study report) while every other configuration completes normally, and
//! correlations are computed over the surviving outcomes. A degraded study
//! is never cached — rerunning after the fault is fixed recomputes the
//! full table, bit-identical to a run that never faulted.

use anyhow::{bail, Result};

use super::parallel::{self, derive_seed};
use super::pipeline::Pipeline;
use super::sensitivity::SensitivityReport;
use super::state::ModelState;
use super::trainer::{dataset_for, Trainer};
use super::traces::TraceOptions;
use crate::data::{Dataset, EvalSet, TrainView};
use crate::metrics::{FitTable, Metric};
use crate::quant::{BitConfig, BitConfigSampler, PRECISIONS};
use crate::runtime::Runtime;
use crate::stats::spearman;

/// Study dimensions (counts chosen so a full 4-experiment Table-2 run fits
/// a single-core CPU budget; the paper's counts are 100 configs / 50 FP +
/// 30 QAT epochs on GPUs).
#[derive(Debug, Clone)]
pub struct StudyOptions {
    pub n_configs: usize,
    pub fp_epochs: usize,
    pub qat_epochs: usize,
    pub eval_n: usize,
    pub seed: u64,
    pub trace: TraceOptions,
    /// Worker threads for the per-configuration sweep: `1` = serial (the
    /// reference path), `0` = one per available core, `N` = exactly N.
    /// Results are identical at every setting (see `coordinator::parallel`).
    pub jobs: usize,
}

impl Default for StudyOptions {
    fn default() -> Self {
        StudyOptions {
            n_configs: 100,
            fp_epochs: 30,
            qat_epochs: 4,
            eval_n: 1024,
            seed: 0,
            trace: TraceOptions::default(),
            jobs: 1,
        }
    }
}

/// One trained-and-evaluated configuration.
#[derive(Debug, Clone)]
pub struct ConfigOutcome {
    pub cfg: BitConfig,
    /// metric name -> value (missing where metric doesn't apply)
    pub metrics: Vec<(Metric, Option<f64>)>,
    pub test_score: f64,
    pub train_score: f64,
    pub mean_bits: f64,
}

/// One configuration of the sweep that failed to train or evaluate —
/// recorded in the study instead of aborting the run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigFailure {
    /// Sweep index of the failed configuration.
    pub index: usize,
    /// Compact human identity of the configuration (bit widths).
    pub label: String,
    /// Whether the job panicked (vs returned an error).
    pub panicked: bool,
    /// Stringified cause.
    pub error: String,
}

#[derive(Debug, Clone)]
pub struct StudyResult {
    pub model: String,
    pub fp_test_score: f64,
    pub outcomes: Vec<ConfigOutcome>,
    pub sens: SensitivityReport,
    /// metric name -> spearman rank correlation of (-metric) vs test score.
    pub correlations: Vec<(Metric, Option<f64>)>,
    /// Configurations that failed (empty on a clean run). Correlations and
    /// outcomes cover only the surviving configurations; a study with
    /// failures is reported but never cached.
    pub failures: Vec<ConfigFailure>,
}

impl StudyResult {
    pub fn correlation(&self, m: Metric) -> Option<f64> {
        self.correlations.iter().find(|(k, _)| *k == m).and_then(|(_, v)| *v)
    }
}

/// Run one full experiment (one row-pair of Table 2).
///
/// The expensive inputs are pipeline stages: the FP checkpoint and the
/// sensitivity report come from `pipe` (computed once per process and
/// across processes), and the finished outcome table is itself cached —
/// a warm rerun with the same options (any `jobs` value) decodes the
/// stored study and reproduces the cold run bit-for-bit. Processes racing
/// the same cold study coordinate through the cache's lease layer
/// ([`Pipeline::study_coordinated`]), so only one of them sweeps.
pub fn run_study(
    rt: &Runtime,
    pipe: &Pipeline,
    model: &str,
    opt: &StudyOptions,
) -> Result<StudyResult> {
    pipe.study_coordinated(rt, model, opt, || compute_study(rt, pipe, model, opt))
}

/// The uncached study computation (stages 1-5 above); callers go through
/// [`run_study`], which wraps this in cache + lease coordination.
fn compute_study(
    rt: &Runtime,
    pipe: &Pipeline,
    model: &str,
    opt: &StudyOptions,
) -> Result<StudyResult> {
    let ds = dataset_for(rt, model, opt.seed ^ 0xda7a)?;
    let mm = rt.model(model)?.clone();
    let trainer = Trainer::new(rt, ds.as_ref());
    let ev = EvalSet::materialize(ds.as_ref(), opt.eval_n);
    // train-split eval set for the Fig-5b overfitting analysis: the
    // train-stream head, i.e. the indices the trainer consumed first
    let ev_train = EvalSet::materialize(&TrainView::new(ds.as_ref()), opt.eval_n);

    // 1. full-precision training (pipeline stage)
    let fp_rc = pipe.train_fp(rt, model, opt.fp_epochs, opt.seed)?;
    let fp: &ModelState = &fp_rc;
    let fp_eval = trainer.evaluate(fp, &ev)?;

    // 2. sensitivity inputs, once (pipeline stage) — plus the per-study
    // scoring table: every FIT evaluation in the sweep is a flat gather
    // over it (bit-identical to the naive metric; see metrics::FitTable)
    let sens_rc = pipe.sensitivity(rt, model, opt.fp_epochs, opt.seed, opt.trace)?;
    let sens: &SensitivityReport = &sens_rc;
    let ftab = FitTable::new(&sens.inputs, &mm.block_sizes(), mm.n_unquantized(), &PRECISIONS);

    // 3-4. config sweep — distinct configs drawn serially (the sampler is
    // order-dependent), then trained/evaluated independently per index.
    let mut sampler = BitConfigSampler::new(
        mm.n_weight_blocks(),
        mm.n_act_blocks(),
        &PRECISIONS,
        opt.seed ^ 0x5a395a39,
    );
    let configs = sampler.take(opt.n_configs);
    let slots = if parallel::effective_jobs(opt.jobs, configs.len()) <= 1 {
        parallel::run_serial_fallible(configs.len(), &mut (), |_, i| {
            let r = evaluate_config(
                rt, ds.as_ref(), fp, sens, &ftab, &ev, &ev_train, &configs[i], opt, i,
            );
            if (i + 1) % 20 == 0 {
                eprintln!("  [{model}] config {}/{}", i + 1, configs.len());
            }
            r
        })
    } else {
        eprintln!(
            "  [{model}] sweeping {} configs on {} workers",
            configs.len(),
            parallel::effective_jobs(opt.jobs, configs.len())
        );
        // per-config QAT workers run the backend serially: the sweep
        // already saturates the budget with independent configs
        let spec = rt.spec().intra_serial();
        parallel::run_pool_fallible(
            configs.len(),
            opt.jobs,
            || Runtime::from_spec(&spec),
            |wrt, i| {
                evaluate_config(
                    wrt, ds.as_ref(), fp, sens, &ftab, &ev, &ev_train, &configs[i], opt, i,
                )
            },
        )?
    };

    // Degrade, don't abort: failed configs become report entries and the
    // survivors carry the study (the sweep is N independent experiments).
    let mut outcomes = Vec::with_capacity(slots.len());
    let mut failures = Vec::new();
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Ok(o) => outcomes.push(o),
            Err(e) => {
                let label = configs[i].label();
                eprintln!(
                    "  [{model}] config {}/{} {label} degraded: {e}",
                    i + 1,
                    configs.len()
                );
                failures.push(ConfigFailure {
                    index: i,
                    label,
                    panicked: e.panicked,
                    error: e.message,
                });
            }
        }
    }
    if outcomes.is_empty() {
        bail!(
            "[{model}] every configuration of the sweep failed ({} failures; first: {})",
            failures.len(),
            failures.first().map(|f| f.error.as_str()).unwrap_or("?")
        );
    }

    // 5. correlations: metric predicts degradation, so correlate against
    // -metric (higher metric -> lower accuracy); report positive rho for a
    // good metric, exactly as the paper tabulates.
    let scores: Vec<f64> = outcomes.iter().map(|o| o.test_score).collect();
    let correlations = Metric::ALL
        .iter()
        .map(|m| {
            let vals: Option<Vec<f64>> =
                outcomes.iter().map(|o| metric_value(o, *m)).collect();
            let rho = vals.map(|v| {
                let neg: Vec<f64> = v.iter().map(|x| -x).collect();
                spearman(&neg, &scores)
            });
            (*m, rho)
        })
        .collect();

    Ok(StudyResult {
        model: model.to_string(),
        fp_test_score: fp_eval.score,
        outcomes,
        sens: sens.clone(),
        correlations,
        failures,
    })
}

/// Score, QAT-fine-tune and evaluate one configuration of the sweep.
///
/// Pure in `(inputs, index)`: the QAT data stream starts at a cursor
/// derived from `(opt.seed, index)`, the model starts from a clone of the
/// shared FP checkpoint with a fresh optimizer, and nothing is read from
/// sweep-order-dependent state — the property that makes the parallel and
/// serial sweeps bit-identical.
#[allow(clippy::too_many_arguments)]
fn evaluate_config(
    rt: &Runtime,
    ds: &dyn Dataset,
    fp: &ModelState,
    sens: &SensitivityReport,
    ftab: &FitTable,
    ev: &EvalSet,
    ev_train: &EvalSet,
    cfg: &BitConfig,
    opt: &StudyOptions,
    index: usize,
) -> Result<ConfigOutcome> {
    // FIT and its _W/_A ablations gather from the shared study table;
    // the rest of the zoo stays on the (cheap) naive path
    let packed = ftab.pack(cfg);
    let metrics: Vec<_> = Metric::ALL
        .iter()
        .map(|m| {
            let v = match m {
                Metric::Fit => Some(ftab.score(&packed)),
                Metric::FitW => Some(ftab.score_w(&packed)),
                Metric::FitA => Some(ftab.score_a(&packed)),
                _ => m.eval(&sens.inputs, cfg),
            };
            (*m, v)
        })
        .collect();
    // QAT fine-tune from the FP checkpoint (fresh optimizer, own stream)
    let mut trainer = Trainer::with_cursor(rt, ds, derive_seed(opt.seed, index as u64));
    let mut st = fp.clone();
    st.reset_optimizer();
    trainer.qat_train(&mut st, cfg, &sens.act, opt.qat_epochs)?;
    let test = trainer.evaluate_q(&st, ev, cfg, &sens.act)?;
    let train = trainer.evaluate_q(&st, ev_train, cfg, &sens.act)?;
    Ok(ConfigOutcome {
        mean_bits: cfg.mean_bits(),
        cfg: cfg.clone(),
        metrics,
        test_score: test.score,
        train_score: train.score,
    })
}

pub fn metric_value(o: &ConfigOutcome, m: Metric) -> Option<f64> {
    o.metrics.iter().find(|(k, _)| *k == m).and_then(|(_, v)| *v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_match_paper_shape() {
        let o = StudyOptions::default();
        assert_eq!(o.n_configs, 100); // paper: 100 configs per experiment
        assert!((o.trace.tol - 0.01).abs() < 1e-12); // paper §4.3 tolerance
        assert_eq!(o.jobs, 1); // serial reference path by default
    }
}
