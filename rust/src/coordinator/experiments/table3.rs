//! Tables 3 & 4 (Appendix C): estimator variance and iteration time as a
//! function of batch size {4, 8, 16, 32}, for both estimators across the
//! scale ladder. The paper's observations to reproduce: EF variance decays
//! ~1/B and is orders of magnitude below the Hessian's at every batch
//! size; iteration time grows with batch for both, with the Hessian's
//! double backward costing a model-dependent multiple.

use anyhow::Result;

use crate::coordinator::experiments::SCALE_MODELS;
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::{md_table, Reporter};
use crate::coordinator::traces::{Estimator, TraceOptions};
use crate::runtime::Runtime;
use crate::stats::RunningStats;

pub struct Table3Options {
    pub batches: Vec<usize>,
    pub iters: u64,
    pub runs: usize,
    pub fp_epochs: usize,
    pub seed: u64,
    pub models: Vec<String>,
    /// Worker threads for the per-batch estimator runs (default 1). The
    /// variance columns are identical at any setting; the ms/iter columns
    /// are wall-clock, so keep `jobs = 1` when timing is the result.
    pub jobs: usize,
}

impl Default for Table3Options {
    fn default() -> Self {
        Table3Options {
            batches: vec![4, 8, 16, 32],
            iters: 40,
            runs: 3,
            fp_epochs: 15,
            seed: 0,
            models: SCALE_MODELS.iter().map(|(m, _)| m.to_string()).collect(),
            jobs: 1,
        }
    }
}

impl Table3Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Table3Options::default();
        Table3Options {
            iters: e.iters.unwrap_or(d.iters),
            runs: e.runs.unwrap_or(d.runs),
            fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
            seed: e.seed,
            models: if e.models.is_empty() { d.models.clone() } else { e.models.clone() },
            jobs: e.jobs,
            ..d
        }
    }
}

/// The estimator runs of one (model, batch) cell, est-major run-minor —
/// the same visit order as the original serial loop.
fn trace_specs(opt: &Table3Options, batch: usize) -> Vec<(Estimator, TraceOptions)> {
    let mut specs = Vec::with_capacity(2 * opt.runs);
    for est in [Estimator::EmpiricalFisher, Estimator::Hutchinson] {
        for r_i in 0..opt.runs {
            let o = TraceOptions::fixed_iters(batch, opt.iters, opt.seed + 31 * r_i as u64);
            specs.push((est, o));
        }
    }
    specs
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Table3Options) -> Vec<StageRequest> {
    let mut reqs = Vec::new();
    for model in &opt.models {
        reqs.push(StageRequest::TrainFp {
            model: model.clone(),
            epochs: opt.fp_epochs,
            seed: opt.seed,
        });
        for &b in &opt.batches {
            for (est, o) in trace_specs(opt, b) {
                reqs.push(StageRequest::Traces {
                    model: model.clone(),
                    fp_epochs: opt.fp_epochs,
                    seed: opt.seed,
                    est,
                    opt: o,
                });
            }
        }
    }
    reqs
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Table3Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();
    let mut md = String::from("# Tables 3-4 — estimator variance / iteration time vs batch size\n\n");

    for model in &opt.models {
        eprintln!("[table3] {model}");
        let mut md_rows = Vec::new();
        for &b in &opt.batches {
            let mut cells = vec![format!("{b}")];
            let mut row = vec![model_index(model) as f64, b as f64];
            let specs = trace_specs(opt, b);
            let results =
                pipe.traces_many(rt, model, opt.fp_epochs, opt.seed, &specs, opt.jobs)?;
            // always emit both estimator column groups, even at --runs 0,
            // so rows stay aligned with the CSV/markdown headers
            for ei in 0..2 {
                let per_est = &results[ei * opt.runs..(ei + 1) * opt.runs];
                let mut var = RunningStats::new();
                let mut time = RunningStats::new();
                for r in per_est {
                    var.push(r.norm_variance);
                    time.push(r.iter_time_s * 1e3);
                }
                cells.push(format!("{:.2} ± {:.2}", var.mean(), var.std()));
                cells.push(format!("{:.2} ± {:.2}", time.mean(), time.std()));
                row.extend([var.mean(), var.std(), time.mean(), time.std()]);
            }
            md_rows.push(cells);
            csv_rows.push(row);
        }
        md.push_str(&format!(
            "## {model}\n\n{}\n",
            md_table(
                &["batch", "EF var", "EF ms/iter", "Hessian var", "Hessian ms/iter"],
                &md_rows
            )
        ));
    }

    rep.csv(
        "table3_table4.csv",
        &[
            "model_idx", "batch", "ef_var", "ef_var_std", "ef_ms", "ef_ms_std", "h_var",
            "h_var_std", "h_ms", "h_ms_std",
        ],
        &csv_rows,
    )?;
    rep.markdown("table3_table4.md", &md)?;
    println!("{md}");
    Ok(())
}

fn model_index(model: &str) -> usize {
    SCALE_MODELS.iter().position(|(m, _)| *m == model).unwrap_or(99)
}
