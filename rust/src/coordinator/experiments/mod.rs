//! One module per paper table/figure (see DESIGN.md per-experiment index).
//!
//! Every experiment writes its raw series as CSV plus a markdown summary
//! under results/ and prints the headline numbers; EXPERIMENTS.md records
//! paper-vs-measured.

pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig9;
pub mod table1;
pub mod table2;
pub mod table3;

use anyhow::Result;

use super::state::ModelState;
use super::trainer::{dataset_for, Trainer};
use crate::runtime::Runtime;

/// The Table-1 / Fig-1/2/7 scale ladder and the paper models they stand
/// in for (DESIGN.md substitutions).
pub const SCALE_MODELS: [(&str, &str); 4] = [
    ("cnn_s", "ResNet-18"),
    ("cnn_m", "ResNet-50"),
    ("cnn_l", "MobileNet-V2"),
    ("cnn_xl", "Inception-V3"),
];

/// The Table-2 studies: (experiment id, model, dataset label, has BN).
pub const STUDIES: [(&str, &str, &str, bool); 4] = [
    ("A", "cnn_cifar_bn", "syncifar", true),
    ("B", "cnn_cifar", "syncifar", false),
    ("C", "cnn_mnist_bn", "synmnist", true),
    ("D", "cnn_mnist", "synmnist", false),
];

/// Load a cached FP checkpoint or train one (results/ckpt/<model>.bin).
/// Training state is deterministic in (model, seed, epochs), so a cache
/// hit replays the same experiment inputs.
pub fn get_trained(
    rt: &Runtime,
    model: &str,
    epochs: usize,
    seed: u64,
) -> Result<ModelState> {
    let dir = std::path::PathBuf::from(
        std::env::var_os("FITQ_RESULTS").unwrap_or_else(|| "results".into()),
    )
    .join("ckpt");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{model}_s{seed}_e{epochs}.bin"));
    if path.exists() {
        if let Ok(st) = ModelState::load(&path, model) {
            if st.n_params() == rt.model(model)?.n_params {
                return Ok(st);
            }
        }
    }
    let ds = dataset_for(rt, model, seed ^ 0xda7a)?;
    let mut trainer = Trainer::new(rt, ds.as_ref());
    let mut st = ModelState::init(rt, model, seed as u32)?;
    let losses = trainer.train(&mut st, epochs)?;
    eprintln!(
        "  [{model}] FP trained {epochs} epochs, loss {:.4} -> {:.4}",
        losses.first().copied().unwrap_or(f64::NAN),
        losses.last().copied().unwrap_or(f64::NAN)
    );
    st.save(&path)?;
    Ok(st)
}
