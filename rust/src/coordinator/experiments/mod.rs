//! One module per paper table/figure (see DESIGN.md per-experiment index).
//!
//! Every experiment writes its raw series as CSV plus a markdown summary
//! under results/ and prints the headline numbers; EXPERIMENTS.md records
//! paper-vs-measured.

pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig9;
pub mod table1;
pub mod table2;
pub mod table3;

use anyhow::Result;

use super::pipeline::Pipeline;
use super::state::ModelState;
use crate::runtime::Runtime;

/// The Table-1 / Fig-1/2/7 scale ladder and the paper models they stand
/// in for (DESIGN.md substitutions).
pub const SCALE_MODELS: [(&str, &str); 4] = [
    ("cnn_s", "ResNet-18"),
    ("cnn_m", "ResNet-50"),
    ("cnn_l", "MobileNet-V2"),
    ("cnn_xl", "Inception-V3"),
];

/// The Table-2 studies: (experiment id, model, dataset label, has BN).
pub const STUDIES: [(&str, &str, &str, bool); 4] = [
    ("A", "cnn_cifar_bn", "syncifar", true),
    ("B", "cnn_cifar", "syncifar", false),
    ("C", "cnn_mnist_bn", "synmnist", true),
    ("D", "cnn_mnist", "synmnist", false),
];

/// Load-or-train the FP checkpoint for `(model, seed, epochs)` — a thin
/// wrapper over the pipeline's `train_fp` stage for callers (examples,
/// one-off CLI commands) that don't carry a [`Pipeline`] of their own.
///
/// Checkpoints live in the content-addressed cache at
/// `results/cache/train_fp_<digest>.bin`, keyed by a digest of the full
/// input set (model identity, seed, epochs) and validated by the cache
/// header's digests — not by parameter count alone. Pre-pipeline
/// checkpoints under `results/ckpt/{model}_s{seed}_e{epochs}.bin` are
/// adopted into the cache on first use. Training state is deterministic
/// in the key, so a cache hit replays the same experiment inputs.
pub fn get_trained(rt: &Runtime, model: &str, epochs: usize, seed: u64) -> Result<ModelState> {
    let pipe = Pipeline::from_env()?;
    let st = pipe.train_fp(rt, model, epochs, seed)?;
    Ok((*st).clone())
}
