//! Figure 5a (+ §4.4 small-perturbation check): quantization noise vs
//! parameter magnitude.
//!
//! For a trained model and a sample of random MPQ configurations, plot
//! |Q(theta) - theta| against |theta| for every parameter in every
//! quantizable block. The paper's claim: almost all points lie below the
//! equal-magnitude line, validating the second-order (small-perturbation)
//! expansion FIT rests on. We also report the fraction above the line.
//!
//! The per-configuration scans are independent, so they fan over the
//! worker pool; each configuration's subsample RNG is derived from
//! `(seed, config index)`, never from scan order, so every `--jobs`
//! setting emits identical rows.
//!
//! (Fig 5b — FIT vs training accuracy — is emitted by the Table-2
//! experiment, which owns the trained configurations.)

use anyhow::Result;

use crate::coordinator::parallel::{self, derive_seed};
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::Reporter;
use crate::quant::{BitConfig, BitConfigSampler, UniformQuantizer, PRECISIONS};
use crate::runtime::{ModelManifest, Runtime};
use crate::tensor::Pcg32;

pub struct Fig5Options {
    pub model: String,
    pub n_configs: usize,
    pub max_points: usize,
    pub fp_epochs: usize,
    pub seed: u64,
    /// Worker threads for the per-configuration scans (default 1; rows
    /// are bit-identical at every setting).
    pub jobs: usize,
}

impl Default for Fig5Options {
    fn default() -> Self {
        // experiment-A model, as in the paper
        Fig5Options {
            model: "cnn_cifar_bn".into(),
            n_configs: 20,
            max_points: 20_000,
            fp_epochs: 30,
            seed: 0,
            jobs: 1,
        }
    }
}

impl Fig5Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Fig5Options::default();
        Fig5Options {
            n_configs: e.configs.unwrap_or(d.n_configs),
            fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
            seed: e.seed,
            jobs: e.jobs,
            ..d
        }
    }
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Fig5Options) -> Vec<StageRequest> {
    vec![StageRequest::TrainFp {
        model: opt.model.clone(),
        epochs: opt.fp_epochs,
        seed: opt.seed,
    }]
}

/// Scan one configuration: (sampled scatter rows, points above the
/// equal-magnitude line, points examined). Pure in `(inputs, index)`.
fn scan_config(
    mm: &ModelManifest,
    params: &[f32],
    cfg: &BitConfig,
    stride: usize,
    seed: u64,
    index: usize,
) -> (Vec<Vec<f64>>, u64, u64) {
    let mut rows = Vec::new();
    let mut above = 0u64;
    let mut count = 0u64;
    let mut k = 0usize;
    let mut rng = Pcg32::new(derive_seed(seed, index as u64), 55);
    for wb in &mm.weight_blocks {
        let slab = &params[wb.offset..wb.offset + wb.size];
        let q = UniformQuantizer::fit(slab, cfg.bits_w[wb.index]);
        for &theta in slab {
            let noise = (q.apply(theta) - theta).abs() as f64;
            let mag = theta.abs() as f64;
            count += 1;
            if noise > mag {
                above += 1;
            }
            if k % stride == 0 || (noise > mag && rng.uniform() < 0.1) {
                rows.push(vec![mag, noise, cfg.bits_w[wb.index] as f64]);
            }
            k += 1;
        }
    }
    (rows, above, count)
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Fig5Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    eprintln!("[fig5] {} noise-vs-magnitude over {} configs", opt.model, opt.n_configs);
    let st = pipe.train_fp(rt, &opt.model, opt.fp_epochs, opt.seed)?;
    let mm = rt.model(&opt.model)?.clone();

    let mut sampler = BitConfigSampler::new(
        mm.n_weight_blocks(),
        mm.n_act_blocks(),
        &PRECISIONS,
        opt.seed ^ 0xf195,
    );
    let configs: Vec<BitConfig> = sampler.take(opt.n_configs);

    let total_points: usize = configs.len() * mm.n_params;
    let stride = (total_points / opt.max_points).max(1);

    // per-config scans are pure in (inputs, index): fan them out and
    // merge in config order
    let params: &[f32] = &st.params;
    let scans = parallel::run_pool(
        configs.len(),
        opt.jobs,
        || Ok(()),
        |_, i| Ok(scan_config(&mm, params, &configs[i], stride, opt.seed, i)),
    )?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut above = 0u64;
    let mut count = 0u64;
    for (r, a, c) in scans {
        rows.extend(r);
        above += a;
        count += c;
    }
    rep.csv(
        "fig5a_noise_vs_magnitude.csv",
        &["param_magnitude", "noise_magnitude", "bits"],
        &rows,
    )?;

    let frac = above as f64 / count as f64;
    let md = format!(
        "# Fig 5a — quantization noise vs parameter magnitude ({})\n\n\
         - parameters x configs examined: {}\n\
         - fraction with |noise| > |theta| (above the line): **{:.3}%**\n\
         - paper: \"almost all parameters adhere to this approximation\"\n\n\
         Scatter sample: results/fig5a_noise_vs_magnitude.csv\n\
         (Fig 5b is produced by `fitq experiment table2` as fig3_expD.csv's\n\
         train_score column; the summary table reports rho(FIT, train acc).)\n",
        opt.model,
        count,
        100.0 * frac,
    );
    rep.markdown("fig5a.md", &md)?;
    println!("{md}");
    Ok(())
}
