//! Figure 5a (+ §4.4 small-perturbation check): quantization noise vs
//! parameter magnitude.
//!
//! For a trained model and a sample of random MPQ configurations, plot
//! |Q(theta) - theta| against |theta| for every parameter in every
//! quantizable block. The paper's claim: almost all points lie below the
//! equal-magnitude line, validating the second-order (small-perturbation)
//! expansion FIT rests on. We also report the fraction above the line.
//!
//! (Fig 5b — FIT vs training accuracy — is emitted by the Table-2
//! experiment, which owns the trained configurations.)

use anyhow::Result;

use crate::coordinator::experiments::get_trained;
use crate::coordinator::report::Reporter;
use crate::quant::{BitConfig, BitConfigSampler, UniformQuantizer, PRECISIONS};
use crate::runtime::Runtime;
use crate::tensor::Pcg32;

pub struct Fig5Options {
    pub model: String,
    pub n_configs: usize,
    pub max_points: usize,
    pub fp_epochs: usize,
    pub seed: u64,
}

impl Default for Fig5Options {
    fn default() -> Self {
        // experiment-A model, as in the paper
        Fig5Options {
            model: "cnn_cifar_bn".into(),
            n_configs: 20,
            max_points: 20_000,
            fp_epochs: 30,
            seed: 0,
        }
    }
}

pub fn run(rt: &Runtime, opt: &Fig5Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    eprintln!("[fig5] {} noise-vs-magnitude over {} configs", opt.model, opt.n_configs);
    let st = get_trained(rt, &opt.model, opt.fp_epochs, opt.seed)?;
    let mm = rt.model(&opt.model)?.clone();

    let mut sampler = BitConfigSampler::new(
        mm.n_weight_blocks(),
        mm.n_act_blocks(),
        &PRECISIONS,
        opt.seed ^ 0xf195,
    );
    let configs: Vec<BitConfig> = sampler.take(opt.n_configs);

    let total_points: usize = configs.len() * mm.n_params;
    let stride = (total_points / opt.max_points).max(1);

    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut above = 0u64;
    let mut count = 0u64;
    let mut k = 0usize;
    let mut rng = Pcg32::new(opt.seed, 55);
    for cfg in &configs {
        for wb in &mm.weight_blocks {
            let slab = &st.params[wb.offset..wb.offset + wb.size];
            let q = UniformQuantizer::fit(slab, cfg.bits_w[wb.index]);
            for &theta in slab {
                let noise = (q.apply(theta) - theta).abs() as f64;
                let mag = theta.abs() as f64;
                count += 1;
                if noise > mag {
                    above += 1;
                }
                if k % stride == 0 || (noise > mag && rng.uniform() < 0.1) {
                    rows.push(vec![mag, noise, cfg.bits_w[wb.index] as f64]);
                }
                k += 1;
            }
        }
    }
    rep.csv("fig5a_noise_vs_magnitude.csv", &["param_magnitude", "noise_magnitude", "bits"], &rows)?;

    let frac = above as f64 / count as f64;
    let md = format!(
        "# Fig 5a — quantization noise vs parameter magnitude ({})\n\n\
         - parameters x configs examined: {}\n\
         - fraction with |noise| > |theta| (above the line): **{:.3}%**\n\
         - paper: \"almost all parameters adhere to this approximation\"\n\n\
         Scatter sample: results/fig5a_noise_vs_magnitude.csv\n\
         (Fig 5b is produced by `fitq experiment table2` as fig3_expD.csv's\n\
         train_score column; the summary table reports rho(FIT, train acc).)\n",
        opt.model,
        count,
        100.0 * frac,
    );
    rep.markdown("fig5a.md", &md)?;
    println!("{md}");
    Ok(())
}
