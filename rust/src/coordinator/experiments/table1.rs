//! Table 1: EF vs Hessian estimator variance, iteration time and relative
//! speedup across the model scale ladder (batch size 32).
//!
//! Paper protocol (Appendix C): statistics over `runs` runs of `iters`
//! iterations each; variances normalized w.r.t. trace magnitude and
//! averaged across blocks; speedup s = (sigma_H^2 t_H)/(sigma_EF^2 t_EF).

use anyhow::Result;

use crate::coordinator::experiments::{get_trained, SCALE_MODELS};
use crate::coordinator::report::{md_table, Reporter};
use crate::coordinator::traces::{Estimator, TraceEngine, TraceOptions};
use crate::coordinator::trainer::dataset_for;
use crate::runtime::Runtime;
use crate::stats::RunningStats;

pub struct Table1Options {
    pub batch: usize,
    pub iters: u64,
    pub runs: usize,
    pub fp_epochs: usize,
    pub seed: u64,
    /// Worker threads for the per-model estimator runs (default 1). The
    /// variance statistics are identical at any setting; the ms/iter and
    /// speedup columns are wall-clock measurements, so keep `jobs = 1` when
    /// the timings themselves are the result being reported.
    pub jobs: usize,
}

impl Default for Table1Options {
    fn default() -> Self {
        Table1Options { batch: 32, iters: 60, runs: 3, fp_epochs: 15, seed: 0, jobs: 1 }
    }
}

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub model: String,
    pub stands_for: String,
    pub var_ef: (f64, f64),
    pub var_h: (f64, f64),
    pub time_ef_ms: (f64, f64),
    pub time_h_ms: (f64, f64),
    pub speedup: f64,
}

pub fn run(rt: &Runtime, opt: &Table1Options) -> Result<Vec<Table1Row>> {
    let rep = Reporter::from_env()?;
    let mut rows = Vec::new();
    for (model, stands_for) in SCALE_MODELS {
        eprintln!("[table1] {model} ({stands_for})");
        let st = get_trained(rt, model, opt.fp_epochs, opt.seed)?;
        let ds = dataset_for(rt, model, opt.seed ^ 0xda7a)?;
        let engine = TraceEngine::new(rt, ds.as_ref());

        let mut stats = [[RunningStats::new(), RunningStats::new()], [
            RunningStats::new(),
            RunningStats::new(),
        ]]; // [est][var|time]
        let mut specs = Vec::with_capacity(opt.runs * 2);
        for run_i in 0..opt.runs {
            let seed = opt.seed + run_i as u64 + 1;
            for est in [Estimator::EmpiricalFisher, Estimator::Hutchinson] {
                specs.push((est, TraceOptions::fixed_iters(opt.batch, opt.iters, seed)));
            }
        }
        let results = engine.run_many(model, &st.params, &specs, opt.jobs)?;
        for ((est, _), r) in specs.iter().zip(&results) {
            let ei = match est {
                Estimator::EmpiricalFisher => 0,
                Estimator::Hutchinson => 1,
            };
            stats[ei][0].push(r.norm_variance);
            stats[ei][1].push(r.iter_time_s * 1e3);
        }
        let g = |s: &RunningStats| (s.mean(), s.std());
        let (var_ef, time_ef) = (g(&stats[0][0]), g(&stats[0][1]));
        let (var_h, time_h) = (g(&stats[1][0]), g(&stats[1][1]));
        let speedup = (var_h.0 * time_h.0) / (var_ef.0 * time_ef.0).max(1e-300);
        eprintln!(
            "  var EF {:.3} vs H {:.3}; time EF {:.1}ms vs H {:.1}ms; speedup {speedup:.1}x",
            var_ef.0, var_h.0, time_ef.0, time_h.0
        );
        rows.push(Table1Row {
            model: model.to_string(),
            stands_for: stands_for.to_string(),
            var_ef,
            var_h,
            time_ef_ms: time_ef,
            time_h_ms: time_h,
            speedup,
        });
    }

    rep.csv(
        "table1.csv",
        &[
            "model", "var_ef", "var_ef_std", "var_h", "var_h_std", "t_ef_ms", "t_ef_std",
            "t_h_ms", "t_h_std", "speedup",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    rows.iter().position(|x| x.model == r.model).unwrap() as f64,
                    r.var_ef.0,
                    r.var_ef.1,
                    r.var_h.0,
                    r.var_h.1,
                    r.time_ef_ms.0,
                    r.time_ef_ms.1,
                    r.time_h_ms.0,
                    r.time_h_ms.1,
                    r.speedup,
                ]
            })
            .collect::<Vec<_>>(),
    )?;

    let md_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{} ({})", r.model, r.stands_for),
                format!("{:.2} ± {:.2}", r.var_ef.0, r.var_ef.1),
                format!("{:.2} ± {:.2}", r.var_h.0, r.var_h.1),
                format!("{:.2} ± {:.2}", r.time_ef_ms.0, r.time_ef_ms.1),
                format!("{:.2} ± {:.2}", r.time_h_ms.0, r.time_h_ms.1),
                format!("**{:.2}**", r.speedup),
            ]
        })
        .collect();
    let md = format!(
        "# Table 1 — EF vs Hessian estimator (bs={}, {} iters x {} runs)\n\n{}\n",
        opt.batch,
        opt.iters,
        opt.runs,
        md_table(
            &["model", "EF var", "Hessian var", "EF ms/iter", "Hessian ms/iter", "speedup"],
            &md_rows
        )
    );
    rep.markdown("table1.md", &md)?;
    println!("{md}");
    Ok(rows)
}
