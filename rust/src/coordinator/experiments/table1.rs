//! Table 1: EF vs Hessian estimator variance, iteration time and relative
//! speedup across the model scale ladder (batch size 32).
//!
//! Paper protocol (Appendix C): statistics over `runs` runs of `iters`
//! iterations each; variances normalized w.r.t. trace magnitude and
//! averaged across blocks; speedup s = (sigma_H^2 t_H)/(sigma_EF^2 t_EF).
//!
//! The FP checkpoints and estimator runs are pipeline stages: warm reruns
//! reproduce the cold run's CSV byte-for-byte from cache (the wall-clock
//! columns are part of the cached stage outputs).

use anyhow::Result;

use crate::coordinator::experiments::SCALE_MODELS;
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::{md_table, Reporter};
use crate::coordinator::traces::{Estimator, TraceOptions};
use crate::runtime::Runtime;
use crate::stats::RunningStats;

pub struct Table1Options {
    pub batch: usize,
    pub iters: u64,
    pub runs: usize,
    pub fp_epochs: usize,
    pub seed: u64,
    /// Worker threads for the per-model estimator runs (default 1). The
    /// variance statistics are identical at any setting; the ms/iter and
    /// speedup columns are wall-clock measurements, so keep `jobs = 1` when
    /// the timings themselves are the result being reported.
    pub jobs: usize,
}

impl Default for Table1Options {
    fn default() -> Self {
        Table1Options { batch: 32, iters: 60, runs: 3, fp_epochs: 15, seed: 0, jobs: 1 }
    }
}

impl Table1Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Table1Options::default();
        Table1Options {
            iters: e.iters.unwrap_or(d.iters),
            runs: e.runs.unwrap_or(d.runs),
            fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
            seed: e.seed,
            jobs: e.jobs,
            ..d
        }
    }
}

/// The estimator runs of one model row, in sweep order (run-major).
fn trace_specs(opt: &Table1Options) -> Vec<(Estimator, TraceOptions)> {
    let mut specs = Vec::with_capacity(opt.runs * 2);
    for run_i in 0..opt.runs {
        let seed = opt.seed + run_i as u64 + 1;
        for est in [Estimator::EmpiricalFisher, Estimator::Hutchinson] {
            specs.push((est, TraceOptions::fixed_iters(opt.batch, opt.iters, seed)));
        }
    }
    specs
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Table1Options) -> Vec<StageRequest> {
    let mut reqs = Vec::new();
    for (model, _) in SCALE_MODELS {
        reqs.push(StageRequest::TrainFp {
            model: model.to_string(),
            epochs: opt.fp_epochs,
            seed: opt.seed,
        });
        for (est, o) in trace_specs(opt) {
            reqs.push(StageRequest::Traces {
                model: model.to_string(),
                fp_epochs: opt.fp_epochs,
                seed: opt.seed,
                est,
                opt: o,
            });
        }
    }
    reqs
}

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub model: String,
    pub stands_for: String,
    pub var_ef: (f64, f64),
    pub var_h: (f64, f64),
    pub time_ef_ms: (f64, f64),
    pub time_h_ms: (f64, f64),
    pub speedup: f64,
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Table1Options) -> Result<Vec<Table1Row>> {
    let rep = Reporter::from_env()?;
    let mut rows = Vec::new();
    for (model, stands_for) in SCALE_MODELS {
        eprintln!("[table1] {model} ({stands_for})");
        let mut stats = [[RunningStats::new(), RunningStats::new()], [
            RunningStats::new(),
            RunningStats::new(),
        ]]; // [est][var|time]
        let specs = trace_specs(opt);
        let results = pipe.traces_many(rt, model, opt.fp_epochs, opt.seed, &specs, opt.jobs)?;
        for ((est, _), r) in specs.iter().zip(&results) {
            let ei = match est {
                Estimator::EmpiricalFisher => 0,
                Estimator::Hutchinson => 1,
            };
            stats[ei][0].push(r.norm_variance);
            stats[ei][1].push(r.iter_time_s * 1e3);
        }
        let g = |s: &RunningStats| (s.mean(), s.std());
        let (var_ef, time_ef) = (g(&stats[0][0]), g(&stats[0][1]));
        let (var_h, time_h) = (g(&stats[1][0]), g(&stats[1][1]));
        let speedup = (var_h.0 * time_h.0) / (var_ef.0 * time_ef.0).max(1e-300);
        eprintln!(
            "  var EF {:.3} vs H {:.3}; time EF {:.1}ms vs H {:.1}ms; speedup {speedup:.1}x",
            var_ef.0, var_h.0, time_ef.0, time_h.0
        );
        rows.push(Table1Row {
            model: model.to_string(),
            stands_for: stands_for.to_string(),
            var_ef,
            var_h,
            time_ef_ms: time_ef,
            time_h_ms: time_h,
            speedup,
        });
    }

    rep.csv(
        "table1.csv",
        &[
            "model", "var_ef", "var_ef_std", "var_h", "var_h_std", "t_ef_ms", "t_ef_std",
            "t_h_ms", "t_h_std", "speedup",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    rows.iter().position(|x| x.model == r.model).unwrap() as f64,
                    r.var_ef.0,
                    r.var_ef.1,
                    r.var_h.0,
                    r.var_h.1,
                    r.time_ef_ms.0,
                    r.time_ef_ms.1,
                    r.time_h_ms.0,
                    r.time_h_ms.1,
                    r.speedup,
                ]
            })
            .collect::<Vec<_>>(),
    )?;

    let md_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{} ({})", r.model, r.stands_for),
                format!("{:.2} ± {:.2}", r.var_ef.0, r.var_ef.1),
                format!("{:.2} ± {:.2}", r.var_h.0, r.var_h.1),
                format!("{:.2} ± {:.2}", r.time_ef_ms.0, r.time_ef_ms.1),
                format!("{:.2} ± {:.2}", r.time_h_ms.0, r.time_h_ms.1),
                format!("**{:.2}**", r.speedup),
            ]
        })
        .collect();
    let md = format!(
        "# Table 1 — EF vs Hessian estimator (bs={}, {} iters x {} runs)\n\n{}\n",
        opt.batch,
        opt.iters,
        opt.runs,
        md_table(
            &["model", "EF var", "Hessian var", "EF ms/iter", "Hessian ms/iter", "speedup"],
            &md_rows
        )
    );
    rep.markdown("table1.md", &md)?;
    println!("{md}");
    Ok(rows)
}
