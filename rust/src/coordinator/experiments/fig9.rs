//! Figure 9 (Appendix E): the uniform quantization-noise assumption.
//!
//! For trained weight blocks, quantize at each candidate precision and
//! histogram the per-parameter error (Q(theta) - theta) / delta in
//! [-1/2, 1/2]. The paper's claim: the error is approximately uniform, so
//! E[dtheta^2] = delta^2/12 is the right noise power. We report the
//! chi-squared statistic against uniformity and the empirical/model noise
//! power ratio per block.
//!
//! Each (block, precision) histogram is an independent pure computation,
//! so the scan fans over the worker pool with bit-identical output at
//! every `--jobs` setting.

use anyhow::Result;

use crate::coordinator::parallel;
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::{md_table, Reporter};
use crate::quant::UniformQuantizer;
use crate::runtime::Runtime;
use crate::stats::Histogram;

pub struct Fig9Options {
    pub model: String,
    pub bits: Vec<u32>,
    pub n_bins: usize,
    pub fp_epochs: usize,
    pub seed: u64,
    /// Worker threads for the per-(block, precision) histograms
    /// (default 1; output is bit-identical at every setting).
    pub jobs: usize,
}

impl Default for Fig9Options {
    fn default() -> Self {
        Fig9Options {
            model: "cnn_cifar".into(),
            bits: vec![8, 6, 4, 3],
            n_bins: 21,
            fp_epochs: 30,
            seed: 0,
            jobs: 1,
        }
    }
}

impl Fig9Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Fig9Options::default();
        Fig9Options {
            fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
            seed: e.seed,
            jobs: e.jobs,
            ..d
        }
    }
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Fig9Options) -> Vec<StageRequest> {
    vec![StageRequest::TrainFp {
        model: opt.model.clone(),
        epochs: opt.fp_epochs,
        seed: opt.seed,
    }]
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Fig9Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    eprintln!("[fig9] {} quantization-error distribution", opt.model);
    let st = pipe.train_fp(rt, &opt.model, opt.fp_epochs, opt.seed)?;
    let mm = rt.model(&opt.model)?.clone();

    // one independent job per (block, precision) cell, in emission order
    let cells: Vec<(usize, u32)> = mm
        .weight_blocks
        .iter()
        .flat_map(|wb| opt.bits.iter().map(|&b| (wb.index, b)))
        .collect();
    let params: &[f32] = &st.params;
    let scanned = parallel::run_pool(
        cells.len(),
        opt.jobs,
        || Ok(()),
        |_, i| {
            let (bi, bits) = cells[i];
            let wb = &mm.weight_blocks[bi];
            let slab = &params[wb.offset..wb.offset + wb.size];
            let q = UniformQuantizer::fit(slab, bits);
            let delta = q.delta() as f64;
            if delta == 0.0 {
                return Ok(None);
            }
            let mut h = Histogram::new(-0.5, 0.5, opt.n_bins);
            for &theta in slab {
                h.push(((q.apply(theta) - theta) as f64) / delta);
            }
            let chi2 = h.chi2_uniform();
            let dof = (opt.n_bins - 1) as f64;
            let emp = q.empirical_noise_power(slab);
            let model_np = q.noise_power();
            let md_row = vec![
                wb.name.clone(),
                bits.to_string(),
                format!("{:.1}", chi2),
                format!("{:.1}", chi2 / dof),
                format!("{:.3}", emp / model_np.max(1e-300)),
            ];
            // histogram row: block_idx, bits, then normalized bin masses
            let total: u64 = h.counts().iter().sum();
            let mut row = vec![wb.index as f64, bits as f64];
            row.extend(h.counts().iter().map(|&c| c as f64 / total.max(1) as f64));
            Ok(Some((md_row, row)))
        },
    )?;
    let mut md_rows = Vec::new();
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();
    for cell in scanned.into_iter().flatten() {
        md_rows.push(cell.0);
        csv_rows.push(cell.1);
    }

    let bin_headers: Vec<String> = (0..opt.n_bins).map(|i| format!("bin{i}")).collect();
    let mut header: Vec<&str> = vec!["block", "bits"];
    header.extend(bin_headers.iter().map(|s| s.as_str()));
    rep.csv("fig9_histograms.csv", &header, &csv_rows)?;

    let md = format!(
        "# Fig 9 — quantization error distribution vs uniform (model {})\n\n\
         chi2/dof near 1 indicates uniform error; emp/model near 1 validates\n\
         the delta^2/12 noise power (paper Appendix E).\n\n{}\n",
        opt.model,
        md_table(&["block", "bits", "chi2", "chi2/dof", "emp/model noise"], &md_rows)
    );
    rep.markdown("fig9.md", &md)?;
    println!("{md}");
    Ok(())
}
