//! Figure 9 (Appendix E): the uniform quantization-noise assumption.
//!
//! For trained weight blocks, quantize at each candidate precision and
//! histogram the per-parameter error (Q(theta) - theta) / delta in
//! [-1/2, 1/2]. The paper's claim: the error is approximately uniform, so
//! E[dtheta^2] = delta^2/12 is the right noise power. We report the
//! chi-squared statistic against uniformity and the empirical/model noise
//! power ratio per block.

use anyhow::Result;

use crate::coordinator::experiments::get_trained;
use crate::coordinator::report::{md_table, Reporter};
use crate::quant::UniformQuantizer;
use crate::runtime::Runtime;
use crate::stats::Histogram;

pub struct Fig9Options {
    pub model: String,
    pub bits: Vec<u32>,
    pub n_bins: usize,
    pub fp_epochs: usize,
    pub seed: u64,
}

impl Default for Fig9Options {
    fn default() -> Self {
        Fig9Options {
            model: "cnn_cifar".into(),
            bits: vec![8, 6, 4, 3],
            n_bins: 21,
            fp_epochs: 30,
            seed: 0,
        }
    }
}

pub fn run(rt: &Runtime, opt: &Fig9Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    eprintln!("[fig9] {} quantization-error distribution", opt.model);
    let st = get_trained(rt, &opt.model, opt.fp_epochs, opt.seed)?;
    let mm = rt.model(&opt.model)?.clone();

    let mut md_rows = Vec::new();
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();
    for wb in &mm.weight_blocks {
        let slab = &st.params[wb.offset..wb.offset + wb.size];
        for &bits in &opt.bits {
            let q = UniformQuantizer::fit(slab, bits);
            let delta = q.delta() as f64;
            if delta == 0.0 {
                continue;
            }
            let mut h = Histogram::new(-0.5, 0.5, opt.n_bins);
            for &theta in slab {
                h.push(((q.apply(theta) - theta) as f64) / delta);
            }
            let chi2 = h.chi2_uniform();
            let dof = (opt.n_bins - 1) as f64;
            let emp = q.empirical_noise_power(slab);
            let model_np = q.noise_power();
            md_rows.push(vec![
                wb.name.clone(),
                bits.to_string(),
                format!("{:.1}", chi2),
                format!("{:.1}", chi2 / dof),
                format!("{:.3}", emp / model_np.max(1e-300)),
            ]);
            // histogram row: block_idx, bits, then normalized bin masses
            let total: u64 = h.counts().iter().sum();
            let mut row = vec![wb.index as f64, bits as f64];
            row.extend(h.counts().iter().map(|&c| c as f64 / total.max(1) as f64));
            csv_rows.push(row);
        }
    }

    let bin_headers: Vec<String> = (0..opt.n_bins).map(|i| format!("bin{i}")).collect();
    let mut header: Vec<&str> = vec!["block", "bits"];
    header.extend(bin_headers.iter().map(|s| s.as_str()));
    rep.csv("fig9_histograms.csv", &header, &csv_rows)?;

    let md = format!(
        "# Fig 9 — quantization error distribution vs uniform (model {})\n\n\
         chi2/dof near 1 indicates uniform error; emp/model near 1 validates\n\
         the delta^2/12 noise power (paper Appendix E).\n\n{}\n",
        opt.model,
        md_table(&["block", "bits", "chi2", "chi2/dof", "emp/model noise"], &md_rows)
    );
    rep.markdown("fig9.md", &md)?;
    println!("{md}");
    Ok(())
}
