//! Figures 1 & 7: per-block traces.
//!
//! Fig 1 — Hessian vs EF *parameter* traces for the four scale models:
//! the EF must preserve the Hessian's relative block profile (rank
//! correlation close to 1 per model; Inception-V3 matched only up to a
//! constant scale in the paper — scale-free agreement is the claim).
//!
//! Fig 7 — EF *activation* traces for the same models.

use anyhow::Result;

use crate::coordinator::experiments::SCALE_MODELS;
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::{md_table, Reporter};
use crate::coordinator::traces::{Estimator, TraceOptions};
use crate::runtime::Runtime;
use crate::stats::spearman;

pub struct Fig1Options {
    pub batch: usize,
    pub tol: f64,
    pub max_iters: u64,
    pub fp_epochs: usize,
    pub seed: u64,
    /// Worker threads: the EF and Hessian estimations per model are
    /// independent, so `jobs = 2` runs them concurrently (default 1).
    pub jobs: usize,
}

impl Default for Fig1Options {
    fn default() -> Self {
        Fig1Options { batch: 32, tol: 0.02, max_iters: 300, fp_epochs: 15, seed: 0, jobs: 1 }
    }
}

impl Fig1Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Fig1Options::default();
        Fig1Options {
            fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
            seed: e.seed,
            jobs: e.jobs,
            ..d
        }
    }
}

/// The one EF + one Hessian run per model.
fn trace_specs(opt: &Fig1Options) -> [(Estimator, TraceOptions); 2] {
    let o = TraceOptions {
        batch: opt.batch,
        tol: opt.tol,
        min_iters: 16,
        max_iters: opt.max_iters,
        seed: opt.seed,
    };
    [(Estimator::EmpiricalFisher, o), (Estimator::Hutchinson, o)]
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Fig1Options) -> Vec<StageRequest> {
    let mut reqs = Vec::new();
    for (model, _) in SCALE_MODELS {
        reqs.push(StageRequest::TrainFp {
            model: model.to_string(),
            epochs: opt.fp_epochs,
            seed: opt.seed,
        });
        for (est, o) in trace_specs(opt) {
            reqs.push(StageRequest::Traces {
                model: model.to_string(),
                fp_epochs: opt.fp_epochs,
                seed: opt.seed,
                est,
                opt: o,
            });
        }
    }
    reqs
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Fig1Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    let mut md = String::from("# Fig 1 / Fig 7 — per-block EF vs Hessian traces\n\n");
    let mut summary_rows = Vec::new();

    for (model, stands_for) in SCALE_MODELS {
        eprintln!("[fig1] {model}");
        let results =
            pipe.traces_many(rt, model, opt.fp_epochs, opt.seed, &trace_specs(opt), opt.jobs)?;
        let (ef, hess) = (&results[0], &results[1]);

        let lw = ef.w_traces.len();
        let mut rows = Vec::with_capacity(lw);
        for i in 0..lw {
            rows.push(vec![
                i as f64,
                ef.w_traces[i],
                hess.w_traces[i],
                ef.a_traces.get(i).copied().unwrap_or(f64::NAN),
            ]);
        }
        rep.csv(
            &format!("fig1_{model}.csv"),
            &["block", "ef_w_trace", "hessian_w_trace", "ef_a_trace"],
            &rows,
        )?;

        let rho = spearman(&ef.w_traces, &hess.w_traces);
        // least-squares scale between the profiles (Inception-style offset)
        let scale = {
            let num: f64 = ef.w_traces.iter().zip(&hess.w_traces).map(|(e, h)| e * h).sum();
            let den: f64 = ef.w_traces.iter().map(|e| e * e).sum();
            num / den.max(1e-300)
        };
        summary_rows.push(vec![
            format!("{model} ({stands_for})"),
            format!("{rho:.3}"),
            format!("{scale:.2}"),
            format!("{} / {}", ef.iterations, hess.iterations),
        ]);
        eprintln!("  spearman(EF_w, Hessian_w) = {rho:.3}");
    }

    md.push_str(&md_table(
        &["model", "spearman(EF, Hessian) blocks", "LS scale H/EF", "iters EF/H"],
        &summary_rows,
    ));
    md.push_str("\nPer-block series: results/fig1_<model>.csv (ef_a_trace column is Fig 7).\n");
    rep.markdown("fig1_fig7.md", &md)?;
    println!("{md}");
    Ok(())
}
