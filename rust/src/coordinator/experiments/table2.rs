//! Table 2 + Fig 3 (+ Fig 5b): the rank-correlation study.
//!
//! Four experiments (A: syncifar+BN, B: syncifar, C: synmnist+BN,
//! D: synmnist), each training `n_configs` random MPQ configurations by
//! QAT fine-tuning from a shared FP checkpoint, then rank-correlating
//! every sensitivity metric against final test performance.
//!
//! Reproduced claims:
//! - FIT correlates consistently highly across all four experiments;
//! - FIT_W + FIT_A -> FIT *increases* correlation (well-scaled fusion),
//!   while QR_W + QR_A -> QR does not;
//! - (Fig 5b) correlation against *training* accuracy exceeds the test
//!   correlation (distributional-shift note, §4.4).

use anyhow::Result;

use crate::coordinator::evaluator::{metric_value, run_study, StudyOptions, StudyResult};
use crate::coordinator::experiments::STUDIES;
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::{degraded_section, fmt, md_table, Reporter};
use crate::metrics::Metric;
use crate::quant::PRECISIONS;
use crate::runtime::Runtime;
use crate::stats::{bootstrap_ci, spearman};
use crate::tensor::Pcg32;

pub struct Table2Options {
    pub study: StudyOptions,
    /// restrict to experiment ids, e.g. ["D"]; empty = all four.
    pub only: Vec<String>,
}

impl Default for Table2Options {
    fn default() -> Self {
        Table2Options { study: StudyOptions::default(), only: vec![] }
    }
}

impl Table2Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = StudyOptions::default();
        Table2Options {
            study: StudyOptions {
                n_configs: e.configs.unwrap_or(d.n_configs),
                fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
                qat_epochs: e.qat_epochs.unwrap_or(d.qat_epochs),
                eval_n: e.eval_n.unwrap_or(d.eval_n),
                seed: e.seed,
                jobs: e.jobs,
                ..d
            },
            only: e.only.clone(),
        }
    }

    /// The studies this run covers, in `STUDIES` order.
    fn selected(&self) -> Vec<(&'static str, &'static str, &'static str, bool)> {
        STUDIES
            .into_iter()
            .filter(|(exp, ..)| self.only.is_empty() || self.only.iter().any(|o| o == exp))
            .collect()
    }
}

/// Stage-graph dependencies (registry prepass): one checkpoint + one
/// sensitivity report per selected study.
pub fn stages(opt: &Table2Options) -> Vec<StageRequest> {
    let mut reqs = Vec::new();
    for (_, model, _, _) in opt.selected() {
        reqs.push(StageRequest::TrainFp {
            model: model.to_string(),
            epochs: opt.study.fp_epochs,
            seed: opt.study.seed,
        });
        reqs.push(StageRequest::Sensitivity {
            model: model.to_string(),
            fp_epochs: opt.study.fp_epochs,
            seed: opt.study.seed,
            trace: opt.study.trace,
        });
    }
    reqs
}

pub fn run(
    rt: &Runtime,
    pipe: &Pipeline,
    opt: &Table2Options,
) -> Result<Vec<(String, StudyResult)>> {
    let rep = Reporter::from_env()?;
    let mut results = Vec::new();

    for (exp, model, dataset, has_bn) in opt.selected() {
        eprintln!("[table2] experiment {exp}: {model} on {dataset} (bn={has_bn})");
        let res = run_study(rt, pipe, model, &opt.study)?;

        // scatter data for Fig 3 (every metric value + outcome per config)
        let header: Vec<&str> = ["config", "mean_bits", "test_score", "train_score"]
            .into_iter()
            .chain(Metric::ALL.iter().map(|m| m.name()))
            .collect();
        let rows: Vec<Vec<f64>> = res
            .outcomes
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let mut row = vec![i as f64, o.mean_bits, o.test_score, o.train_score];
                row.extend(
                    Metric::ALL
                        .iter()
                        .map(|m| metric_value(o, *m).unwrap_or(f64::NAN)),
                );
                row
            })
            .collect();
        rep.csv(&format!("fig3_exp{exp}.csv"), &header, &rows)?;
        let pts: Vec<(f64, f64)> = res
            .outcomes
            .iter()
            .filter_map(|o| metric_value(o, Metric::Fit).map(|f| (f, o.test_score)))
            .collect();
        rep.markdown(
            &format!("fig3_exp{exp}.txt"),
            &crate::stats::ascii_plot::scatter(
                &format!("Fig 3 (exp {exp}) — FIT vs final accuracy"),
                "FIT",
                "accuracy",
                &pts,
                64,
                20,
            ),
        )?;
        results.push((exp.to_string(), res));
    }

    // Table-2 matrix
    let mut md_rows = Vec::new();
    let mut csv_rows = Vec::new();
    for (exp, res) in &results {
        let mut cells = vec![exp.clone(), res.model.clone()];
        let mut row = vec![0.0f64; 0];
        for m in Metric::ALL {
            let rho = res.correlation(m);
            cells.push(fmt(rho, 2));
            row.push(rho.unwrap_or(f64::NAN));
        }
        // Fig 5b: FIT vs training score
        let train_rho = {
            let fit_vals: Option<Vec<f64>> = res
                .outcomes
                .iter()
                .map(|o| metric_value(o, Metric::Fit).map(|v| -v))
                .collect();
            fit_vals.map(|v| {
                let tr: Vec<f64> = res.outcomes.iter().map(|o| o.train_score).collect();
                spearman(&v, &tr)
            })
        };
        cells.push(fmt(train_rho, 2));
        cells.push(format!("{:.3}", res.fp_test_score));
        row.push(train_rho.unwrap_or(f64::NAN));
        row.push(res.fp_test_score);
        md_rows.push(cells);
        csv_rows.push(row);
    }

    let metric_names: Vec<&str> = Metric::ALL.iter().map(|m| m.name()).collect();
    let mut header = vec!["exp", "model"];
    header.extend(metric_names.iter());
    header.push("FIT(vs train acc)");
    header.push("FP score");

    // per-experiment failure sections (empty strings on clean runs)
    let degraded: String = results
        .iter()
        .map(|(exp, res)| degraded_section(&format!("experiment {exp}"), &res.failures))
        .collect();
    let md = format!(
        "# Table 2 — rank correlation (Spearman) of sensitivity metrics vs final accuracy\n\n\
         {} configs per experiment, bits in {:?}, QAT fine-tune {} epochs.\n\n{}\n\n\
         ## FIT fusion check (paper: FIT_A inclusion helps, QR_A hurts)\n\n{}\n{degraded}",
        opt.study.n_configs,
        PRECISIONS,
        opt.study.qat_epochs,
        md_table(&header, &md_rows),
        fusion_summary(&results),
    );
    rep.markdown("table2.md", &md)?;

    let csv_header: Vec<&str> = metric_names
        .iter()
        .copied()
        .chain(["fit_vs_train", "fp_score"])
        .collect();
    rep.csv("table2.csv", &csv_header, &csv_rows)?;
    println!("{md}");

    // bootstrap CI for FIT correlations (extension beyond the paper)
    let mut ci_md = String::from("# Table 2 FIT correlation 95% bootstrap CIs\n\n| exp | rho(FIT) | CI |\n|---|---|---|\n");
    let mut rng = Pcg32::new(1234, 9);
    for (exp, res) in &results {
        let vals: Vec<f64> = res
            .outcomes
            .iter()
            .map(|o| -metric_value(o, Metric::Fit).unwrap_or(f64::NAN))
            .collect();
        let scores: Vec<f64> = res.outcomes.iter().map(|o| o.test_score).collect();
        let (lo, hi) = bootstrap_ci(&vals, &scores, spearman, 500, 0.95, &mut rng);
        ci_md.push_str(&format!(
            "| {exp} | {:.2} | [{lo:.2}, {hi:.2}] |\n",
            res.correlation(Metric::Fit).unwrap_or(f64::NAN)
        ));
    }
    rep.markdown("table2_ci.md", &ci_md)?;
    Ok(results)
}

fn fusion_summary(results: &[(String, StudyResult)]) -> String {
    let mut rows = Vec::new();
    for (exp, res) in results {
        let g = |m: Metric| res.correlation(m).unwrap_or(f64::NAN);
        rows.push(vec![
            exp.clone(),
            format!("{:+.2}", g(Metric::Fit) - g(Metric::FitW)),
            format!("{:+.2}", g(Metric::Qr) - g(Metric::QrW)),
        ]);
    }
    md_table(&["exp", "rho(FIT) - rho(FIT_W)", "rho(QR) - rho(QR_W)"], &rows)
}
