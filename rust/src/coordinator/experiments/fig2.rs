//! Figure 2: trace-estimate convergence, EF vs Hessian.
//!
//! Emits the running mean of the total weight trace per iteration for both
//! estimators on each scale model. The paper's claim: the EF stabilizes in
//! far fewer iterations than the Hutchinson Hessian estimator.

use anyhow::Result;

use crate::coordinator::experiments::SCALE_MODELS;
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::Reporter;
use crate::coordinator::traces::{Estimator, TraceOptions};
use crate::runtime::Runtime;

pub struct Fig2Options {
    pub batch: usize,
    pub iters: u64,
    pub fp_epochs: usize,
    pub seed: u64,
    /// Worker threads: the EF and Hessian estimations per model are
    /// independent, so `jobs = 2` runs them concurrently (default 1).
    pub jobs: usize,
}

impl Default for Fig2Options {
    fn default() -> Self {
        Fig2Options { batch: 32, iters: 150, fp_epochs: 15, seed: 0, jobs: 1 }
    }
}

impl Fig2Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Fig2Options::default();
        Fig2Options {
            iters: e.iters.unwrap_or(d.iters),
            fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
            seed: e.seed,
            jobs: e.jobs,
            ..d
        }
    }
}

/// The one EF + one Hessian run per model.
fn trace_specs(opt: &Fig2Options) -> [(Estimator, TraceOptions); 2] {
    let o = TraceOptions::fixed_iters(opt.batch, opt.iters, opt.seed + 7);
    [(Estimator::EmpiricalFisher, o), (Estimator::Hutchinson, o)]
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Fig2Options) -> Vec<StageRequest> {
    let mut reqs = Vec::new();
    for (model, _) in SCALE_MODELS {
        reqs.push(StageRequest::TrainFp {
            model: model.to_string(),
            epochs: opt.fp_epochs,
            seed: opt.seed,
        });
        for (est, o) in trace_specs(opt) {
            reqs.push(StageRequest::Traces {
                model: model.to_string(),
                fp_epochs: opt.fp_epochs,
                seed: opt.seed,
                est,
                opt: o,
            });
        }
    }
    reqs
}

/// Iterations for the running mean to stay within ±band of its final value.
fn settle_iteration(history: &[f64], band: f64) -> usize {
    let last = *history.last().unwrap_or(&f64::NAN);
    if !last.is_finite() || last == 0.0 {
        return history.len();
    }
    let mut settle = history.len();
    for (i, &v) in history.iter().enumerate().rev() {
        if (v - last).abs() / last.abs() > band {
            break;
        }
        settle = i;
    }
    settle
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Fig2Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    let mut md = String::from("# Fig 2 — trace convergence (running mean of total weight trace)\n\n");
    md.push_str("| model | EF settle iters (±5%) | Hessian settle iters (±5%) |\n|---|---|---|\n");

    for (model, _) in SCALE_MODELS {
        eprintln!("[fig2] {model}");
        let results =
            pipe.traces_many(rt, model, opt.fp_epochs, opt.seed, &trace_specs(opt), opt.jobs)?;
        let (ef, hess) = (&results[0], &results[1]);

        let rows: Vec<Vec<f64>> = (0..opt.iters as usize)
            .map(|i| {
                vec![
                    i as f64 + 1.0,
                    ef.history_total[i],
                    hess.history_total[i],
                ]
            })
            .collect();
        rep.csv(
            &format!("fig2_{model}.csv"),
            &["iteration", "ef_running_total", "hessian_running_total"],
            &rows,
        )?;
        rep.markdown(
            &format!("fig2_{model}.txt"),
            &crate::stats::ascii_plot::lines(
                &format!("Fig 2 — {model}: running total weight trace"),
                &[("EF", &ef.history_total), ("Hessian", &hess.history_total)],
                72,
                18,
            ),
        )?;
        let se = settle_iteration(&ef.history_total, 0.05);
        let sh = settle_iteration(&hess.history_total, 0.05);
        md.push_str(&format!("| {model} | {se} | {sh} |\n"));
        eprintln!("  settle: EF {se} vs Hessian {sh}");
    }
    rep.markdown("fig2.md", &md)?;
    println!("{md}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::settle_iteration;

    #[test]
    fn settle_detects_late_convergence() {
        // converges immediately
        let flat = vec![1.0; 50];
        assert_eq!(settle_iteration(&flat, 0.05), 0);
        // drifts until iteration 30
        let mut h: Vec<f64> = (0..30).map(|i| 2.0 - i as f64 * 0.03).collect();
        h.extend(vec![1.1; 20]);
        let s = settle_iteration(&h, 0.05);
        assert!(s >= 25, "{s}");
    }
}
