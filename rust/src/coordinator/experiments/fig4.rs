//! Figure 4: U-Net on the synthetic segmentation task.
//!
//! (a)/(b) converged EF weight and activation traces per block (the trace
//! run uses the paper's tol = 0.01 early stopping — the iteration count at
//! convergence is part of the reproduced result; the paper reports 82);
//! (c) FIT vs mIoU over random MPQ configurations, with the headline rank
//! correlation (paper: 0.86 over 50 configs).

use anyhow::Result;

use crate::coordinator::evaluator::{metric_value, run_study, StudyOptions};
use crate::coordinator::pipeline::{ExpOptions, Pipeline, StageRequest};
use crate::coordinator::report::{degraded_section, md_table, Reporter};
use crate::metrics::Metric;
use crate::runtime::Runtime;

pub struct Fig4Options {
    pub study: StudyOptions,
}

impl Default for Fig4Options {
    fn default() -> Self {
        let mut study = StudyOptions {
            n_configs: 50, // paper: 50 configs for the U-Net study
            fp_epochs: 40,
            qat_epochs: 3,
            eval_n: 128,
            ..Default::default()
        };
        study.trace.tol = 0.01; // paper §4.3
        study.trace.max_iters = 400;
        Fig4Options { study }
    }
}

impl Fig4Options {
    /// Typed options from the registry's uniform flag schema.
    pub fn from_exp(e: &ExpOptions) -> Self {
        let d = Fig4Options::default().study;
        Fig4Options {
            study: StudyOptions {
                n_configs: e.configs.unwrap_or(d.n_configs),
                fp_epochs: e.fp_epochs.unwrap_or(d.fp_epochs),
                qat_epochs: e.qat_epochs.unwrap_or(d.qat_epochs),
                eval_n: e.eval_n.unwrap_or(d.eval_n),
                seed: e.seed,
                jobs: e.jobs,
                ..d
            },
        }
    }
}

/// Stage-graph dependencies (registry prepass).
pub fn stages(opt: &Fig4Options) -> Vec<StageRequest> {
    vec![
        StageRequest::TrainFp {
            model: "unet".to_string(),
            epochs: opt.study.fp_epochs,
            seed: opt.study.seed,
        },
        StageRequest::Sensitivity {
            model: "unet".to_string(),
            fp_epochs: opt.study.fp_epochs,
            seed: opt.study.seed,
            trace: opt.study.trace,
        },
    ]
}

pub fn run(rt: &Runtime, pipe: &Pipeline, opt: &Fig4Options) -> Result<()> {
    let rep = Reporter::from_env()?;
    eprintln!("[fig4] unet study ({} configs)", opt.study.n_configs);
    let res = run_study(rt, pipe, "unet", &opt.study)?;

    // (a)/(b): trace profiles
    let lw = res.sens.inputs.w_traces.len();
    let la = res.sens.inputs.a_traces.len();
    let rows: Vec<Vec<f64>> = (0..lw.max(la))
        .map(|i| {
            vec![
                i as f64,
                res.sens.inputs.w_traces.get(i).copied().unwrap_or(f64::NAN),
                res.sens.inputs.a_traces.get(i).copied().unwrap_or(f64::NAN),
            ]
        })
        .collect();
    rep.csv("fig4_traces.csv", &["block", "ef_w_trace", "ef_a_trace"], &rows)?;

    // (c): FIT vs mIoU scatter
    let scatter: Vec<Vec<f64>> = res
        .outcomes
        .iter()
        .map(|o| {
            vec![
                metric_value(o, Metric::Fit).unwrap_or(f64::NAN),
                o.test_score,
                o.mean_bits,
            ]
        })
        .collect();
    rep.csv("fig4_scatter.csv", &["fit", "miou", "mean_bits"], &scatter)?;
    let pts: Vec<(f64, f64)> = scatter.iter().map(|r| (r[0], r[1])).collect();
    rep.markdown(
        "fig4_scatter.txt",
        &crate::stats::ascii_plot::scatter("Fig 4c — FIT vs mIoU", "FIT", "mIoU", &pts, 64, 20),
    )?;

    let rho = res.correlation(Metric::Fit).unwrap_or(f64::NAN);
    let degraded = degraded_section("unet", &res.failures);
    let md = format!(
        "# Fig 4 — U-Net / synthetic segmentation\n\n\
         - FP mIoU: {:.3}\n\
         - EF trace early-stopped at tol={} after **{} iterations** (paper: 82)\n\
         - rank correlation FIT vs mIoU over {} configs: **{:.2}** (paper: 0.86)\n\n{}\n{degraded}",
        res.fp_test_score,
        opt.study.trace.tol,
        res.sens.trace.iterations,
        res.outcomes.len(),
        rho,
        md_table(
            &["metric", "rho vs mIoU"],
            &Metric::ALL
                .iter()
                .map(|m| vec![
                    m.name().to_string(),
                    crate::coordinator::report::fmt(res.correlation(*m), 2)
                ])
                .collect::<Vec<_>>()
        )
    );
    rep.markdown("fig4.md", &md)?;
    println!("{md}");
    Ok(())
}
