//! fitq — FIT (Fisher Information Trace) model-sensitivity framework CLI.
//!
//! Subcommands map 1:1 to the paper's tables and figures plus a few
//! utilities; see DESIGN.md for the per-experiment index.
//!
//!   fitq info
//!   fitq train --model cnn_mnist --epochs 30
//!   fitq traces --model cnn_m [--estimator ef|hessian] [--tol 0.01]
//!   fitq search --model cnn_cifar --budget-ratio 0.15
//!   fitq experiment table1|table2|table3|fig1|fig2|fig4|fig5|fig9|all
//!                   [--seed N] [--jobs N] [per-experiment flags]
//!
//! Every command takes `--backend native|pjrt` (default: pjrt when the
//! artifact root has a manifest, else the zero-setup native interpreter).
//!
//! Experiments dispatch through the declarative registry
//! (`coordinator::pipeline::registry`); their expensive stages flow
//! through the content-addressed artifact cache under `results/cache/`.
//!
//! (clap is not in the vendored dependency set; the small parser below is
//! part of the from-scratch substrate.)

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use fitq::coordinator::analysis;
use fitq::coordinator::pipeline::{
    codec, fault, registry, stages, ArtifactCache, ExpOptions, Pipeline,
};
use fitq::coordinator::service::{
    bind, fetch_stats, serve_on, Budget, Request, SearchMode, ServiceConfig, ServiceCore,
    ServiceWorker, StudySpec,
};
use fitq::coordinator::{
    dataset_for, Estimator, ModelState, TraceEngine, TraceOptions, Trainer,
};
use fitq::data::EvalSet;
use fitq::native::{simd, trace, tune};
use fitq::quant::BitConfig;
use fitq::runtime::{Json, Runtime};

/// Tiny positional+flag argument parser: `cmd [positionals] --key value`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Flags take values in this parser, so booleans are spelled
    /// `--stream true` / `--stream false` (a bare `--stream` would eat
    /// the next argument as its value).
    fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(other) => bail!("--{key} must be true or false, got {other:?}"),
        }
    }
}

const USAGE: &str = "fitq <command>\n\
  info                                   list models and entry points\n\
  train      --model M [--epochs N] [--trace-ops true]\n\
     train FP model, report accuracy. --trace-ops true arms the native\n\
     op profiler (also $FITQ_TRACE_OPS) and stores the per-op aggregates\n\
     as an `optrace` artifact for `fitq trace-report` — outputs stay\n\
     bit-identical to an untraced run.\n\
  traces     --model M [--estimator ef|hessian] [--tol T] [--batch B]\n\
  search     --model M [--budget-ratio R] [--samples N] [--jobs N]\n\
             [--seed N] [--shards K] [--stream true|false] [--fp-epochs E]\n\
     random-sample + greedy + exact search over one FIT table. Routes\n\
     through the serve core: the sensitivity stage is pipeline-cached,\n\
     scoring is sharded, and the front is bit-identical at every\n\
     --jobs/--shards setting; --stream true prints front updates as\n\
     shards land.\n\
  serve      [--host H] [--port P] [--jobs N] [--tables N]\n\
             [--shard-target N] [--models zoo1.json,...] [--results DIR]\n\
     long-running search service over a line-JSON protocol (DESIGN.md\n\
     \"Search service\"): resident FIT tables, sharded scoring, streamed\n\
     Pareto fronts. --port 0 picks an ephemeral port; the resolved\n\
     address is printed as `listening on HOST:PORT`.\n\
     `fitq serve --stats HOST:PORT` prints a running server's counters.\n\
  query      --connect HOST:PORT ['{\"method\":\"ping\"}' ...]\n\
     send request lines (arguments, or stdin when none) to a running\n\
     server and print the raw response lines; exits nonzero if any\n\
     response is an error event.\n\
  experiment <name>|all [--seed N] [--jobs N] [flags]\n\
     run `fitq experiment` with no name for the per-experiment flag list.\n\
     Every experiment takes --seed/--jobs; --jobs N fans independent work\n\
     over N workers (0 = all cores) with bit-identical results at every\n\
     setting — but ms/iter and speedup columns are wall-clock, so keep\n\
     --jobs 1 when the timing itself is the result. `all` walks the\n\
     experiment DAG once, deduping shared pipeline stages.\n\
  zoo-check  zoo/<name>.json ...          validate model manifests (parse + compile)\n\
  cache      verify|gc|stats [--results DIR] [--tmp-age-secs N]\n\
     verify quarantines corrupt store entries (nonzero exit if any);\n\
     gc reaps expired leases and stale temp files; stats summarizes.\n\
  tune       [--results DIR] [--threads N]  measure per-host kernel routing\n\
     micro-benchmarks every (op, shape-class, SIMD-variant) triple at the\n\
     given intra-op thread budget and persists the winner table in the\n\
     artifact cache keyed by (host, budget); native runs do the same\n\
     lazily on first dispatch, so `tune` just runs it eagerly and prints\n\
     the table. --trace-model M [--trace-workload W] appends a trailer\n\
     checking the routing against a stored op trace's real shapes.\n\
  trace-report --model M [--workload W] [--results DIR]\n\
             [--bench BENCH_kernels.json] [--json OUT.json]\n\
     render the cost report for a stored op trace: per-(op, layer,\n\
     variant) wall-time share, GFLOP/s, GB/s, and roofline ratio against\n\
     the measured kernel peaks. Needs a prior\n\
     `fitq train --trace-ops true --backend native` run.\n\
  A config that fails mid-sweep degrades to a report entry (the study\n\
     completes on the survivors) instead of aborting the experiment.\n\
  Every command takes --backend native|pjrt (also $FITQ_BACKEND):\n\
     native = pure-Rust interpreter, zero setup, study models only;\n\
     pjrt   = compiled HLO artifacts ($FITQ_ARTIFACTS, `make artifacts`).\n\
     Default: pjrt when the artifact root has a manifest, else native.\n\
     $FITQ_NATIVE_THREADS=N threads the native GEMM kernels intra-op\n\
     (default 1, 0 = all cores; bit-identical output at every setting —\n\
     parallel phases switch workers back to serial on their own).\n\
     $FITQ_NATIVE_KERNEL=auto|scalar|sse2|avx2|neon pins the native SIMD\n\
     kernel variant (default auto = the tuned per-host routing; every\n\
     variant is bit-identical — only wall clock differs). Unknown or\n\
     unavailable values are a hard error, never a silent fallback.\n\
  --model also accepts the path of a zoo model manifest ending in .json\n\
     (e.g. --model zoo/cnn_cifar_deep.json): the manifest is strictly\n\
     validated, compiled into a native plan, and runs on the native\n\
     backend under the name it declares (DESIGN.md \"Model manifests\").\n";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    // arm the deterministic fault-injection harness when $FITQ_FAULTS is
    // set; a malformed spec is a hard error (a typo silently running the
    // *fault-free* path would defeat the point of a fault drill)
    fault::arm_from_env()?;
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "traces" => cmd_traces(&args),
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        "experiment" => cmd_experiment(&args),
        "zoo-check" => cmd_zoo_check(&args),
        "cache" => cmd_cache(&args),
        "tune" => cmd_tune(&args),
        "trace-report" => cmd_trace_report(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Backend resolution shared by every command: `--backend` flag first,
/// then `$FITQ_BACKEND`, then automatic (pjrt when artifacts exist).
/// `zoo` carries any manifest paths `--model` resolved; a non-empty zoo
/// forces the native backend (zoo models exist nowhere else).
fn runtime_for(args: &Args, zoo: Vec<PathBuf>) -> Result<Runtime> {
    let env_backend = std::env::var("FITQ_BACKEND").ok();
    let arg = args.get("backend").or_else(|| env_backend.as_deref());
    Runtime::from_backend_arg_with_zoo(arg, zoo)
}

/// Resolve one `--model` value: a path ending in `.json` is a zoo model
/// manifest — validate it *now* (fail-closed, before any `Runtime`
/// exists), record the path for backend construction, and substitute the
/// model name the manifest declares. Anything else is a builtin name,
/// passed through untouched.
fn resolve_model(value: &str, zoo: &mut Vec<PathBuf>) -> Result<String> {
    if !value.ends_with(".json") {
        return Ok(value.to_string());
    }
    let path = PathBuf::from(value);
    let model = fitq::native::manifest::load_file(&path)?;
    if !zoo.contains(&path) {
        zoo.push(path);
    }
    Ok(model.spec.name)
}

/// Validate model manifests from the command line (what
/// `make check-manifests` runs over every committed `zoo/*.json`).
fn cmd_zoo_check(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("zoo-check needs at least one manifest path, e.g. `fitq zoo-check zoo/*.json`");
    }
    for p in &args.positional {
        let path = PathBuf::from(p);
        let model = fitq::native::manifest::load_file(&path)?;
        let plan = fitq::native::model::Plan::from_spec(model.spec.clone());
        println!(
            "{p}: ok — model {}: {} conv layers, {} classes, {} params",
            model.spec.name,
            model.spec.convs.len(),
            model.spec.n_classes,
            plan.n_params
        );
    }
    Ok(())
}

/// Operate on the artifact store directly (no Runtime/backend needed):
/// `fitq cache verify|gc|stats [--results DIR] [--tmp-age-secs N]`.
fn cmd_cache(args: &Args) -> Result<()> {
    let Some(op) = args.positional.first() else {
        bail!("cache needs an operation: verify, gc or stats");
    };
    let root = args
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(stages::results_root_from_env);
    let cache = ArtifactCache::new(root.join("cache"))?;
    match op.as_str() {
        "verify" => {
            let rep = cache.verify()?;
            let total = rep.valid + rep.quarantined.len() as u64;
            println!("verified {total} entries: {} valid", rep.valid);
            for p in &rep.quarantined {
                println!("  quarantined {}", p.display());
            }
            if !rep.quarantined.is_empty() {
                bail!(
                    "{} corrupt entries moved to {} (they will recompute on next use)",
                    rep.quarantined.len(),
                    cache.dir().join("quarantine").display()
                );
            }
            Ok(())
        }
        "gc" => {
            let age = std::time::Duration::from_secs(args.usize_or("tmp-age-secs", 3600)? as u64);
            let rep = cache.gc(age)?;
            println!(
                "gc: {} live leases kept, {} stale leases reaped, {} temp files (older than {:?}) reaped",
                rep.leases_live, rep.leases_reaped, rep.tmp_reaped, age
            );
            Ok(())
        }
        "stats" => {
            let rep = cache.stats()?;
            println!("cache {}", cache.dir().display());
            for (kind, (n, bytes)) in &rep.kinds {
                println!("  {kind}: {n} entries, {bytes} bytes");
            }
            println!(
                "  leases: {}, temp files: {}, quarantined: {}, unaddressable: {}",
                rep.leases, rep.tmp_files, rep.quarantined, rep.unaddressable
            );
            Ok(())
        }
        other => bail!("unknown cache operation {other:?} (want verify, gc or stats)"),
    }
}

/// `fitq tune`: resolve this host's kernel route table — cache hit, or
/// micro-benchmark under the tuning lease and publish — and print it.
/// This is exactly the path a native run takes lazily on its first
/// conv/dense dispatch; the command just runs it eagerly and shows the
/// winners plus the measurements they were picked from.
fn cmd_tune(args: &Args) -> Result<()> {
    let root = args
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(stages::results_root_from_env);
    let cache = ArtifactCache::new(root.join("cache"))?;
    let threads = args.usize_or("threads", 1)?;
    let (table, how) = tune::resolve_at(&cache, threads);

    let isas: Vec<&str> = simd::Isa::detected().into_iter().map(|i| i.name()).collect();
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    println!(
        "host {} (arch {}, isas [{}], {cores} cores, {threads} intra-op threads): {}",
        tune::host_fingerprint(threads).hex(),
        std::env::consts::ARCH,
        isas.join(" "),
        how.name()
    );
    let class_names = ["<=4", "<=8", "<=16", "<=32", ">32"];
    println!("routes (per vector-axis width class):");
    for op in tune::OPS {
        let cells: Vec<String> = (0..tune::N_CLASSES)
            .map(|c| {
                let ch = table.choice(op, tune::CLASS_WIDTHS[c]);
                format!("{}:{}/{}", class_names[c], ch.lowering.name(), ch.isa.name())
            })
            .collect();
        println!("  {:<11} {}", op.name(), cells.join("  "));
    }
    if table.measurements.is_empty() {
        println!("(no stored measurements — table was built without tuning)");
    } else {
        println!("measurements (nominal GFLOP/s, min-of-reps; comparable within a row):");
        for op in tune::OPS {
            for c in 0..tune::N_CLASSES {
                let row: Vec<String> = table
                    .measurements
                    .iter()
                    .filter(|m| m.op == op && m.class == c)
                    .map(|m| format!("{}/{} {:.3}", m.lowering.name(), m.isa.name(), m.gflops))
                    .collect();
                if !row.is_empty() {
                    println!("  {:<11} {:<5} {}", op.name(), class_names[c], row.join(" | "));
                }
            }
        }
    }
    // optional trailer: sanity-check the width-class routing against the
    // shape distribution of a *real* traced workload (micro-benchmarks
    // tune on synthetic shapes; the trace says what actually ran)
    if let Some(trace_model) = args.get("trace-model") {
        let workload = args.str_or("trace-workload", "train_epoch");
        let report = load_optrace(&cache, trace_model, workload, &[])?;
        println!("routing check vs traced {trace_model}/{workload}:");
        for line in analysis::routing_trailer(&report, &table) {
            println!("  {line}");
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = runtime_for(args, Vec::new())?;
    println!("backend: {} (root: {})", rt.backend_name(), rt.manifest.root.display());
    for (name, m) in &rt.manifest.models {
        println!(
            "  {name}: {} params, {} weight blocks, {} act blocks, task {:?}, entries: {}",
            m.n_params,
            m.n_weight_blocks(),
            m.n_act_blocks(),
            m.task,
            m.entries.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_mnist"), &mut zoo)?;
    let epochs = args.usize_or("epochs", 30)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let trace_ops = args.bool_or("trace-ops", false)?;
    if trace_ops {
        // the backend arms its profiler at creation time by reading this
        // env var, so it must be set before `runtime_for` builds one;
        // tracing never changes outputs or digests, only observes them
        std::env::set_var("FITQ_TRACE_OPS", "1");
    }
    let rt = runtime_for(args, zoo)?;
    let ds = dataset_for(&rt, &model, seed ^ 0xda7a)?;
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, &model, seed as u32)?;
    let losses = trainer.train(&mut st, epochs)?;
    let ev = EvalSet::materialize(ds.as_ref(), 512);
    let res = trainer.evaluate(&st, &ev)?;
    println!(
        "{model}: {} epochs, loss {:.4} -> {:.4}, eval score {:.3} over {} samples",
        epochs,
        losses.first().unwrap_or(&f64::NAN),
        losses.last().unwrap_or(&f64::NAN),
        res.score,
        res.n
    );
    if trace_ops {
        let mut report = rt.op_trace().ok_or_else(|| {
            anyhow!(
                "--trace-ops true: the {} backend does not expose an op trace \
                 (tracing is native-only)",
                rt.backend_name()
            )
        })?;
        report.model = model.clone();
        report.workload = "train_epoch".to_string();
        let root = args
            .get("results")
            .map(PathBuf::from)
            .unwrap_or_else(stages::results_root_from_env);
        let cache = ArtifactCache::new(root.join("cache"))?;
        let key = stages::optrace_key(rt.backend_name(), rt.model(&model)?, &report.workload);
        let path = cache.store(
            trace::OPTRACE_KIND,
            codec::OPTRACE_SCHEMA,
            &key,
            &codec::encode_optrace(&report),
        )?;
        println!(
            "op trace: {} aggregate rows over {:.3} ms stored at {} \
             (render with `fitq trace-report --model {model}`)",
            report.rows.len(),
            report.total_wall_ns() as f64 / 1e6,
            path.display()
        );
    }
    Ok(())
}

/// `fitq trace-report`: decode a stored `optrace` artifact and render
/// the cost table (`coordinator::analysis`) against the measured kernel
/// peaks in `BENCH_kernels.json`. `--json OUT.json` additionally writes
/// the machine-readable report (schema checked by
/// `scripts/check_bench_schema.py`).
fn cmd_trace_report(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_mnist"), &mut zoo)?;
    let workload = args.str_or("workload", "train_epoch").to_string();
    let root = args
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(stages::results_root_from_env);
    let cache = ArtifactCache::new(root.join("cache"))?;
    let report = load_optrace(&cache, &model, &workload, &zoo)?;

    let bench_path = args.str_or("bench", "BENCH_kernels.json");
    let bench_text = std::fs::read_to_string(bench_path)
        .with_context(|| format!("reading bench peaks from {bench_path}"))?;
    let peaks = analysis::parse_bench_kernels(&bench_text)
        .map_err(|e| anyhow!("{bench_path}: {e}"))?;

    let cost = analysis::cost_report(&report, &peaks)?;
    print!("{}", analysis::render_text(&cost));
    if let Some(out) = args.get("json") {
        std::fs::write(out, analysis::render_json(&cost))
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Load and decode the stored op trace for `(model, workload)` on the
/// native backend, with an actionable error when none exists. Traces are
/// native-only, so the key's backend leg is always `"native"`.
fn load_optrace(
    cache: &ArtifactCache,
    model: &str,
    workload: &str,
    zoo: &[PathBuf],
) -> Result<trace::OpTraceReport> {
    let (_, manifest) = fitq::native::NativeBackend::create_with_zoo(1, zoo)?;
    let mm = manifest.model(model)?;
    let key = stages::optrace_key("native", mm, workload);
    let bytes = cache
        .load(trace::OPTRACE_KIND, codec::OPTRACE_SCHEMA, &key)
        .ok_or_else(|| {
            anyhow!(
                "no stored op trace for {model}/{workload} under {} — run \
                 `fitq train --model {model} --backend native --trace-ops true` first",
                cache.entry_path(trace::OPTRACE_KIND, &key).display()
            )
        })?;
    codec::decode_optrace(&bytes)
        .map_err(|e| anyhow!(analysis::AnalysisError::TraceDecode(format!("{e:#}"))))
}

fn cmd_traces(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_m"), &mut zoo)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let epochs = args.usize_or("epochs", 15)?;
    let est = match args.str_or("estimator", "ef") {
        "ef" => Estimator::EmpiricalFisher,
        "hessian" => Estimator::Hutchinson,
        other => bail!("unknown estimator {other:?}"),
    };
    let rt = runtime_for(args, zoo)?;
    let st = fitq::coordinator::experiments::get_trained(&rt, &model, epochs, seed)?;
    let ds = dataset_for(&rt, &model, seed ^ 0xda7a)?;
    let engine = TraceEngine::new(&rt, ds.as_ref());
    let opt = TraceOptions {
        batch: args.usize_or("batch", 32)?,
        tol: args.f64_or("tol", 0.01)?,
        min_iters: 8,
        max_iters: args.usize_or("max-iters", 500)? as u64,
        seed,
    };
    let r = engine.run(&model, &st.params, est, opt)?;
    println!(
        "{model} {} trace: {} iterations ({:.1} ms/iter), norm variance {:.3}",
        r.estimator.name(),
        r.iterations,
        r.iter_time_s * 1e3,
        r.norm_variance
    );
    for (i, (t, se)) in r.w_traces.iter().zip(&r.w_std_errors).enumerate() {
        println!("  block {i}: {t:.4} ± {se:.4}");
    }
    if !r.a_traces.is_empty() {
        let fmt: Vec<String> = r.a_traces.iter().map(|t| format!("{t:.3}")).collect();
        println!("  activation traces: [{}]", fmt.join(", "));
    }
    Ok(())
}

/// `fitq search`: the one-shot CLI over the serve core — same table
/// residency, sharding and dominance merge as `fitq serve`, with an
/// in-process worker. The sensitivity stage flows through the pipeline
/// cache, so a re-run (or a later `fitq serve`) reuses it.
fn cmd_search(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_cifar"), &mut zoo)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let ratio = args.f64_or("budget-ratio", 0.15)?;
    let samples = args.usize_or("samples", 100_000)? as u64;
    let jobs = args.usize_or("jobs", 0)?;
    let fp_epochs = args.usize_or("fp-epochs", 30)?;
    let stream = args.bool_or("stream", false)?;
    let shards = match args.get("shards") {
        None => None,
        Some(_) => {
            let k = args.usize_or("shards", 1)?;
            if k == 0 {
                bail!("--shards must be >= 1");
            }
            Some(k)
        }
    };
    if samples == 0 {
        bail!("--samples must be >= 1");
    }
    let rt = runtime_for(args, zoo)?;
    let pipe = Pipeline::from_env()?;
    let fp32_bits = rt.model(&model)?.n_params as u64 * 32;
    let (lw, la) = (rt.model(&model)?.n_weight_blocks(), rt.model(&model)?.n_act_blocks());
    let core = ServiceCore::new(
        rt.spec(),
        pipe.results_root().to_path_buf(),
        ServiceConfig { jobs, ..ServiceConfig::default() },
    );
    let worker = ServiceWorker::new(rt, pipe);
    let study = StudySpec { model, fp_epochs, seed, trace: TraceOptions::default() };

    run_service_request(
        &core,
        &worker,
        &Request::Search {
            study: study.clone(),
            mode: SearchMode::Random { samples, seed },
            shards,
            stream,
        },
        fp32_bits,
    )?;
    for mode in [
        SearchMode::Greedy(Budget::Ratio(ratio)),
        SearchMode::Exact(Budget::Ratio(ratio)),
    ] {
        run_service_request(
            &core,
            &worker,
            &Request::Search { study: study.clone(), mode, shards: None, stream: false },
            fp32_bits,
        )?;
    }
    println!("reference uniform-4bit:");
    run_service_request(
        &core,
        &worker,
        &Request::Score { study, configs: vec![BitConfig::uniform(lw, la, 4)] },
        fp32_bits,
    )
}

/// Execute one request against an in-process core, rendering the JSON
/// event lines human-readably. Error events of kind `budget` print and
/// continue (an infeasible budget is an answer, not a failure); every
/// other error kind fails the command.
fn run_service_request(
    core: &ServiceCore,
    worker: &ServiceWorker,
    req: &Request,
    fp32_bits: u64,
) -> Result<()> {
    let mut err: Option<(String, String)> = None;
    let mut emit = |line: &str| render_service_event(line, fp32_bits, &mut err);
    core.execute(worker, req, &mut emit)?;
    if let Some((kind, message)) = err {
        if kind == "budget" {
            println!("{message}");
        } else {
            bail!("{kind}: {message}");
        }
    }
    Ok(())
}

fn config_label(cfg: &Json) -> Result<String> {
    let bits = |key: &str| -> Result<Vec<u32>> {
        Ok(cfg.usize_array(key).map_err(|e| anyhow!(e))?.into_iter().map(|b| b as u32).collect())
    };
    Ok(BitConfig { bits_w: bits("w")?, bits_a: bits("a")? }.label())
}

fn render_front(front: &[Json], fp32_bits: u64) -> Result<()> {
    println!("Pareto front has {} points:", front.len());
    for p in front.iter().take(10) {
        let fit = p.field("fit").map_err(|e| anyhow!(e))?.as_f64().unwrap_or(f64::NAN);
        let size_bits = p.usize_field("size_bits").map_err(|e| anyhow!(e))? as u64;
        println!(
            "  size {:>8} bits ({:.2}x comp)  FIT {:.5}  {}",
            size_bits,
            fp32_bits as f64 / size_bits as f64,
            fit,
            config_label(p.field("config").map_err(|e| anyhow!(e))?)?
        );
    }
    Ok(())
}

/// One service event line -> CLI output. Protocol errors land in `err`
/// for the caller to classify; only transport-level problems (a line
/// that is not valid event JSON) return `Err`.
fn render_service_event(
    line: &str,
    fp32_bits: u64,
    err: &mut Option<(String, String)>,
) -> Result<()> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad service event line: {e}"))?;
    match j.str_field("event").map_err(|e| anyhow!(e))? {
        "error" => {
            *err = Some((
                j.str_field("kind").map_err(|e| anyhow!(e))?.to_string(),
                j.str_field("message").map_err(|e| anyhow!(e))?.to_string(),
            ));
            Ok(())
        }
        "front" => {
            let front = j.arr_field("front").map_err(|e| anyhow!(e))?;
            println!(
                "  [front] {}/{} shards: {} points",
                j.usize_field("shards_done").map_err(|e| anyhow!(e))?,
                j.usize_field("shards").map_err(|e| anyhow!(e))?,
                front.len()
            );
            Ok(())
        }
        "done" => {
            let result = j.field("result").map_err(|e| anyhow!(e))?;
            let metrics = j.field("metrics").map_err(|e| anyhow!(e))?;
            if let Ok(front) = result.arr_field("front") {
                render_front(front, fp32_bits)?;
            }
            if let Ok(mode) = result.str_field("mode") {
                println!(
                    "{mode} @ {} bits budget: size {} FIT {:.5} {}",
                    result.usize_field("budget_bits").map_err(|e| anyhow!(e))?,
                    result.usize_field("size_bits").map_err(|e| anyhow!(e))?,
                    result.field("fit").map_err(|e| anyhow!(e))?.as_f64().unwrap_or(f64::NAN),
                    config_label(result.field("config").map_err(|e| anyhow!(e))?)?
                );
            }
            if let Ok(scores) = result.arr_field("scores") {
                for (i, s) in scores.iter().enumerate() {
                    let pair = s.as_arr().ok_or_else(|| anyhow!("bad score entry"))?;
                    let fit = pair[0].as_f64().unwrap_or(f64::NAN);
                    let size = pair[1].as_f64().unwrap_or(f64::NAN) as u64;
                    println!(
                        "  config {i}: size {size} bits ({:.2}x comp)  FIT {fit:.5}",
                        fp32_bits as f64 / size as f64
                    );
                }
            }
            let scored = metrics.usize_field("configs_scored").map_err(|e| anyhow!(e))?;
            if scored > 0 {
                let per_sec = metrics
                    .field("configs_per_sec")
                    .ok()
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN);
                println!(
                    "scored {scored} configs in {:.1} ms ({per_sec:.3e} configs/s, {} shards, \
                     {} jobs, table {})",
                    metrics.field("elapsed_ms").map_err(|e| anyhow!(e))?.as_f64().unwrap_or(0.0),
                    metrics.usize_field("shards").map_err(|e| anyhow!(e))?,
                    metrics.usize_field("jobs").map_err(|e| anyhow!(e))?,
                    metrics.str_field("table").map_err(|e| anyhow!(e))?
                );
            }
            Ok(())
        }
        other => bail!("unknown service event {other:?}"),
    }
}

/// `fitq serve`: bind, print the resolved address, serve forever.
/// `fitq serve --stats HOST:PORT` instead queries a running server and
/// pretty-prints its aggregate counters.
fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(addr) = args.get("stats") {
        let line = fetch_stats(addr)?;
        let j = Json::parse(&line).map_err(|e| anyhow!("bad stats response: {e}"))?;
        let r = j.field("result").map_err(|e| anyhow!(e))?;
        println!("server {addr}:");
        for key in
            ["uptime_ms", "requests", "errors", "configs_scored", "table_hits", "table_misses"]
        {
            let v = r.field(key).map_err(|e| anyhow!(e))?.as_f64().unwrap_or(f64::NAN);
            println!("  {key}: {v}");
        }
        let stages = r.field("stages").map_err(|e| anyhow!(e))?;
        for key in ["sensitivity_computed", "claims_won", "claim_waits"] {
            let v = stages.field(key).map_err(|e| anyhow!(e))?.as_f64().unwrap_or(f64::NAN);
            println!("  stages.{key}: {v}");
        }
        let tables = r.arr_field("tables").map_err(|e| anyhow!(e))?;
        println!("  resident tables ({}):", tables.len());
        for t in tables {
            println!(
                "    {} @ {}",
                t.str_field("model").map_err(|e| anyhow!(e))?,
                t.str_field("digest").map_err(|e| anyhow!(e))?
            );
        }
        return Ok(());
    }
    let mut zoo = Vec::new();
    if let Some(models) = args.get("models") {
        for m in models.split(',') {
            resolve_model(m.trim(), &mut zoo)?;
        }
    }
    let host = args.str_or("host", "127.0.0.1").to_string();
    let port = args.usize_or("port", 7151)?;
    if port > u16::MAX as usize {
        bail!("--port must fit in 16 bits");
    }
    let jobs = args.usize_or("jobs", 0)?;
    let tables = args.usize_or("tables", 8)?.max(1);
    let shard_target = (args.usize_or("shard-target", 65_536)? as u64).max(1);
    let results = args
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(stages::results_root_from_env);
    // build one runtime now so a bad backend/zoo fails before binding,
    // then keep only its spec — each connection builds its own worker
    let spec = runtime_for(args, zoo)?.spec();
    let core = Arc::new(ServiceCore::new(
        spec.clone(),
        results,
        ServiceConfig { jobs, table_capacity: tables, shard_target },
    ));
    let listener = bind(&host, port as u16)?;
    let addr = listener.local_addr().context("resolving bound address")?;
    println!("fitq serve: listening on {addr} (backend {}, jobs {jobs}, tables {tables}, shard target {shard_target})", spec.name());
    serve_on(core, listener)
}

/// `fitq query`: raw line client for a running server.
fn cmd_query(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("query needs --connect HOST:PORT"))?;
    let mut requests: Vec<String> =
        args.positional.iter().filter(|l| !l.trim().is_empty()).cloned().collect();
    if requests.is_empty() {
        use std::io::BufRead;
        for line in std::io::stdin().lock().lines() {
            let line = line.context("reading stdin")?;
            if !line.trim().is_empty() {
                requests.push(line);
            }
        }
    }
    if requests.is_empty() {
        bail!("query needs at least one request line (arguments or stdin)");
    }
    let any_error =
        fitq::coordinator::service::query(addr, &requests, &mut std::io::stdout().lock())?;
    if any_error {
        bail!("server returned an error event");
    }
    Ok(())
}

/// Registry-driven experiment dispatch. Name, flag and value validation
/// all happen before the runtime (and its artifact manifest) is touched,
/// so `fitq experiment bogus` and bad flags fail fast with usage text.
fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(which) = args.positional.first() else {
        bail!("experiment needs a name\n{}", registry::usage());
    };
    let specs: Vec<&'static registry::ExperimentSpec> = if which == "all" {
        registry::REGISTRY.iter().collect()
    } else {
        vec![registry::find(which)
            .ok_or_else(|| anyhow!("unknown experiment {which:?}\n{}", registry::usage()))?]
    };
    for key in args.flags.keys() {
        let known = registry::GLOBAL_FLAGS.contains(&key.as_str())
            || specs.iter().any(|s| s.flags.contains(&key.as_str()));
        if !known {
            bail!("unknown flag --{key} for experiment {which}\n{}", registry::usage());
        }
    }
    let mut o = exp_options(args)?;
    // `--models` entries may be zoo manifest paths; resolve them to the
    // declared names and collect the paths for backend construction
    let mut zoo = Vec::new();
    for m in &mut o.models {
        *m = resolve_model(m, &mut zoo)?;
    }
    let rt = runtime_for(args, zoo)?;
    let pipe = Pipeline::from_env()?;
    registry::run_all(&rt, &pipe, &specs, &o)
}

/// Parse the registry's uniform option schema from raw flags. `None`
/// keeps the experiment's own default for that dimension.
fn exp_options(args: &Args) -> Result<ExpOptions> {
    let opt_usize = |key: &str| -> Result<Option<usize>> {
        args.get(key)
            .map(|v| v.parse().with_context(|| format!("--{key} must be an integer")))
            .transpose()
    };
    let list = |key: &str, upper: bool| -> Vec<String> {
        args.get(key)
            .map(|v| {
                v.split(',')
                    .map(|s| if upper { s.trim().to_uppercase() } else { s.trim().to_string() })
                    .collect()
            })
            .unwrap_or_default()
    };
    Ok(ExpOptions {
        seed: args.usize_or("seed", 0)? as u64,
        jobs: args.usize_or("jobs", 1)?,
        iters: opt_usize("iters")?.map(|v| v as u64),
        runs: opt_usize("runs")?,
        configs: opt_usize("configs")?,
        fp_epochs: opt_usize("fp-epochs")?,
        qat_epochs: opt_usize("qat-epochs")?,
        eval_n: opt_usize("eval-n")?,
        only: list("only", true),
        models: list("models", false),
    })
}
