//! fitq — FIT (Fisher Information Trace) model-sensitivity framework CLI.
//!
//! Subcommands map 1:1 to the paper's tables and figures plus a few
//! utilities; see DESIGN.md for the per-experiment index.
//!
//!   fitq info
//!   fitq train --model cnn_mnist --epochs 30
//!   fitq traces --model cnn_m [--estimator ef|hessian] [--tol 0.01]
//!   fitq search --model cnn_cifar --budget-ratio 0.15
//!   fitq experiment table1|table2|table3|fig1|fig2|fig4|fig5|fig9|all
//!                   [--seed N] [--jobs N] [per-experiment flags]
//!
//! Every command takes `--backend native|pjrt` (default: pjrt when the
//! artifact root has a manifest, else the zero-setup native interpreter).
//!
//! Experiments dispatch through the declarative registry
//! (`coordinator::pipeline::registry`); their expensive stages flow
//! through the content-addressed artifact cache under `results/cache/`.
//!
//! (clap is not in the vendored dependency set; the small parser below is
//! part of the from-scratch substrate.)

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use fitq::coordinator::pipeline::{fault, registry, stages, ArtifactCache, ExpOptions, Pipeline};
use fitq::coordinator::{
    dataset_for, exact_allocate_table, gather, greedy_allocate_table, pareto_front_scores,
    Estimator, ModelState, TraceEngine, TraceOptions, Trainer,
};
use fitq::data::EvalSet;
use fitq::metrics::{FitTable, PackedConfig};
use fitq::native::{simd, tune};
use fitq::quant::{model_bits, BitConfig, BitConfigSampler, PRECISIONS};
use fitq::runtime::Runtime;

/// Tiny positional+flag argument parser: `cmd [positionals] --key value`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

const USAGE: &str = "fitq <command>\n\
  info                                   list models and entry points\n\
  train      --model M [--epochs N]      train FP model, report accuracy\n\
  traces     --model M [--estimator ef|hessian] [--tol T] [--batch B]\n\
  search     --model M [--budget-ratio R] [--samples N] [--jobs N]\n\
  experiment <name>|all [--seed N] [--jobs N] [flags]\n\
     run `fitq experiment` with no name for the per-experiment flag list.\n\
     Every experiment takes --seed/--jobs; --jobs N fans independent work\n\
     over N workers (0 = all cores) with bit-identical results at every\n\
     setting — but ms/iter and speedup columns are wall-clock, so keep\n\
     --jobs 1 when the timing itself is the result. `all` walks the\n\
     experiment DAG once, deduping shared pipeline stages.\n\
  zoo-check  zoo/<name>.json ...          validate model manifests (parse + compile)\n\
  cache      verify|gc|stats [--results DIR] [--tmp-age-secs N]\n\
     verify quarantines corrupt store entries (nonzero exit if any);\n\
     gc reaps expired leases and stale temp files; stats summarizes.\n\
  tune       [--results DIR] [--threads N]  measure per-host kernel routing\n\
     micro-benchmarks every (op, shape-class, SIMD-variant) triple and\n\
     persists the winner table in the artifact cache keyed by a host\n\
     fingerprint; native runs do the same lazily on first dispatch, so\n\
     `tune` just runs it eagerly and prints the table.\n\
  A config that fails mid-sweep degrades to a report entry (the study\n\
     completes on the survivors) instead of aborting the experiment.\n\
  Every command takes --backend native|pjrt (also $FITQ_BACKEND):\n\
     native = pure-Rust interpreter, zero setup, study models only;\n\
     pjrt   = compiled HLO artifacts ($FITQ_ARTIFACTS, `make artifacts`).\n\
     Default: pjrt when the artifact root has a manifest, else native.\n\
     $FITQ_NATIVE_THREADS=N threads the native GEMM kernels intra-op\n\
     (default 1, 0 = all cores; bit-identical output at every setting —\n\
     parallel phases switch workers back to serial on their own).\n\
     $FITQ_NATIVE_KERNEL=auto|scalar|sse2|avx2|neon pins the native SIMD\n\
     kernel variant (default auto = the tuned per-host routing; every\n\
     variant is bit-identical — only wall clock differs). Unknown or\n\
     unavailable values are a hard error, never a silent fallback.\n\
  --model also accepts the path of a zoo model manifest ending in .json\n\
     (e.g. --model zoo/cnn_cifar_deep.json): the manifest is strictly\n\
     validated, compiled into a native plan, and runs on the native\n\
     backend under the name it declares (DESIGN.md \"Model manifests\").\n";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    // arm the deterministic fault-injection harness when $FITQ_FAULTS is
    // set; a malformed spec is a hard error (a typo silently running the
    // *fault-free* path would defeat the point of a fault drill)
    fault::arm_from_env()?;
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "traces" => cmd_traces(&args),
        "search" => cmd_search(&args),
        "experiment" => cmd_experiment(&args),
        "zoo-check" => cmd_zoo_check(&args),
        "cache" => cmd_cache(&args),
        "tune" => cmd_tune(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Backend resolution shared by every command: `--backend` flag first,
/// then `$FITQ_BACKEND`, then automatic (pjrt when artifacts exist).
/// `zoo` carries any manifest paths `--model` resolved; a non-empty zoo
/// forces the native backend (zoo models exist nowhere else).
fn runtime_for(args: &Args, zoo: Vec<PathBuf>) -> Result<Runtime> {
    let env_backend = std::env::var("FITQ_BACKEND").ok();
    let arg = args.get("backend").or_else(|| env_backend.as_deref());
    Runtime::from_backend_arg_with_zoo(arg, zoo)
}

/// Resolve one `--model` value: a path ending in `.json` is a zoo model
/// manifest — validate it *now* (fail-closed, before any `Runtime`
/// exists), record the path for backend construction, and substitute the
/// model name the manifest declares. Anything else is a builtin name,
/// passed through untouched.
fn resolve_model(value: &str, zoo: &mut Vec<PathBuf>) -> Result<String> {
    if !value.ends_with(".json") {
        return Ok(value.to_string());
    }
    let path = PathBuf::from(value);
    let model = fitq::native::manifest::load_file(&path)?;
    if !zoo.contains(&path) {
        zoo.push(path);
    }
    Ok(model.spec.name)
}

/// Validate model manifests from the command line (what
/// `make check-manifests` runs over every committed `zoo/*.json`).
fn cmd_zoo_check(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("zoo-check needs at least one manifest path, e.g. `fitq zoo-check zoo/*.json`");
    }
    for p in &args.positional {
        let path = PathBuf::from(p);
        let model = fitq::native::manifest::load_file(&path)?;
        let plan = fitq::native::model::Plan::from_spec(model.spec.clone());
        println!(
            "{p}: ok — model {}: {} conv layers, {} classes, {} params",
            model.spec.name,
            model.spec.convs.len(),
            model.spec.n_classes,
            plan.n_params
        );
    }
    Ok(())
}

/// Operate on the artifact store directly (no Runtime/backend needed):
/// `fitq cache verify|gc|stats [--results DIR] [--tmp-age-secs N]`.
fn cmd_cache(args: &Args) -> Result<()> {
    let Some(op) = args.positional.first() else {
        bail!("cache needs an operation: verify, gc or stats");
    };
    let root = args
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(stages::results_root_from_env);
    let cache = ArtifactCache::new(root.join("cache"))?;
    match op.as_str() {
        "verify" => {
            let rep = cache.verify()?;
            let total = rep.valid + rep.quarantined.len() as u64;
            println!("verified {total} entries: {} valid", rep.valid);
            for p in &rep.quarantined {
                println!("  quarantined {}", p.display());
            }
            if !rep.quarantined.is_empty() {
                bail!(
                    "{} corrupt entries moved to {} (they will recompute on next use)",
                    rep.quarantined.len(),
                    cache.dir().join("quarantine").display()
                );
            }
            Ok(())
        }
        "gc" => {
            let age = std::time::Duration::from_secs(args.usize_or("tmp-age-secs", 3600)? as u64);
            let rep = cache.gc(age)?;
            println!(
                "gc: {} live leases kept, {} stale leases reaped, {} temp files (older than {:?}) reaped",
                rep.leases_live, rep.leases_reaped, rep.tmp_reaped, age
            );
            Ok(())
        }
        "stats" => {
            let rep = cache.stats()?;
            println!("cache {}", cache.dir().display());
            for (kind, (n, bytes)) in &rep.kinds {
                println!("  {kind}: {n} entries, {bytes} bytes");
            }
            println!(
                "  leases: {}, temp files: {}, quarantined: {}, unaddressable: {}",
                rep.leases, rep.tmp_files, rep.quarantined, rep.unaddressable
            );
            Ok(())
        }
        other => bail!("unknown cache operation {other:?} (want verify, gc or stats)"),
    }
}

/// `fitq tune`: resolve this host's kernel route table — cache hit, or
/// micro-benchmark under the tuning lease and publish — and print it.
/// This is exactly the path a native run takes lazily on its first
/// conv/dense dispatch; the command just runs it eagerly and shows the
/// winners plus the measurements they were picked from.
fn cmd_tune(args: &Args) -> Result<()> {
    let root = args
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(stages::results_root_from_env);
    let cache = ArtifactCache::new(root.join("cache"))?;
    let threads = args.usize_or("threads", 1)?;
    let (table, how) = tune::resolve_at(&cache, threads);

    let isas: Vec<&str> = simd::Isa::detected().into_iter().map(|i| i.name()).collect();
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    println!(
        "host {} (arch {}, isas [{}], {cores} cores): {}",
        tune::host_fingerprint().hex(),
        std::env::consts::ARCH,
        isas.join(" "),
        how.name()
    );
    let class_names = ["<=4", "<=8", "<=16", "<=32", ">32"];
    println!("routes (per vector-axis width class):");
    for op in tune::OPS {
        let cells: Vec<String> = (0..tune::N_CLASSES)
            .map(|c| {
                let ch = table.choice(op, tune::CLASS_WIDTHS[c]);
                format!("{}:{}/{}", class_names[c], ch.lowering.name(), ch.isa.name())
            })
            .collect();
        println!("  {:<11} {}", op.name(), cells.join("  "));
    }
    if table.measurements.is_empty() {
        println!("(no stored measurements — table was built without tuning)");
        return Ok(());
    }
    println!("measurements (nominal GFLOP/s, min-of-reps; comparable within a row):");
    for op in tune::OPS {
        for c in 0..tune::N_CLASSES {
            let row: Vec<String> = table
                .measurements
                .iter()
                .filter(|m| m.op == op && m.class == c)
                .map(|m| format!("{}/{} {:.3}", m.lowering.name(), m.isa.name(), m.gflops))
                .collect();
            if !row.is_empty() {
                println!("  {:<11} {:<5} {}", op.name(), class_names[c], row.join(" | "));
            }
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = runtime_for(args, Vec::new())?;
    println!("backend: {} (root: {})", rt.backend_name(), rt.manifest.root.display());
    for (name, m) in &rt.manifest.models {
        println!(
            "  {name}: {} params, {} weight blocks, {} act blocks, task {:?}, entries: {}",
            m.n_params,
            m.n_weight_blocks(),
            m.n_act_blocks(),
            m.task,
            m.entries.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_mnist"), &mut zoo)?;
    let epochs = args.usize_or("epochs", 30)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let rt = runtime_for(args, zoo)?;
    let ds = dataset_for(&rt, &model, seed ^ 0xda7a)?;
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, &model, seed as u32)?;
    let losses = trainer.train(&mut st, epochs)?;
    let ev = EvalSet::materialize(ds.as_ref(), 512);
    let res = trainer.evaluate(&st, &ev)?;
    println!(
        "{model}: {} epochs, loss {:.4} -> {:.4}, eval score {:.3} over {} samples",
        epochs,
        losses.first().unwrap_or(&f64::NAN),
        losses.last().unwrap_or(&f64::NAN),
        res.score,
        res.n
    );
    Ok(())
}

fn cmd_traces(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_m"), &mut zoo)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let epochs = args.usize_or("epochs", 15)?;
    let est = match args.str_or("estimator", "ef") {
        "ef" => Estimator::EmpiricalFisher,
        "hessian" => Estimator::Hutchinson,
        other => bail!("unknown estimator {other:?}"),
    };
    let rt = runtime_for(args, zoo)?;
    let st = fitq::coordinator::experiments::get_trained(&rt, &model, epochs, seed)?;
    let ds = dataset_for(&rt, &model, seed ^ 0xda7a)?;
    let engine = TraceEngine::new(&rt, ds.as_ref());
    let opt = TraceOptions {
        batch: args.usize_or("batch", 32)?,
        tol: args.f64_or("tol", 0.01)?,
        min_iters: 8,
        max_iters: args.usize_or("max-iters", 500)? as u64,
        seed,
    };
    let r = engine.run(&model, &st.params, est, opt)?;
    println!(
        "{model} {} trace: {} iterations ({:.1} ms/iter), norm variance {:.3}",
        r.estimator.name(),
        r.iterations,
        r.iter_time_s * 1e3,
        r.norm_variance
    );
    for (i, (t, se)) in r.w_traces.iter().zip(&r.w_std_errors).enumerate() {
        println!("  block {i}: {t:.4} ± {se:.4}");
    }
    if !r.a_traces.is_empty() {
        let fmt: Vec<String> = r.a_traces.iter().map(|t| format!("{t:.3}")).collect();
        println!("  activation traces: [{}]", fmt.join(", "));
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let mut zoo = Vec::new();
    let model = resolve_model(args.str_or("model", "cnn_cifar"), &mut zoo)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let ratio = args.f64_or("budget-ratio", 0.15)?;
    let samples = args.usize_or("samples", 100_000)?;
    let jobs = args.usize_or("jobs", 0)?;
    let rt = runtime_for(args, zoo)?;
    let mm = rt.model(&model)?.clone();
    let st = fitq::coordinator::experiments::get_trained(&rt, &model, 30, seed)?;
    let ds = dataset_for(&rt, &model, seed ^ 0xda7a)?;
    let trainer = Trainer::new(&rt, ds.as_ref());
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, TraceOptions::default())?;

    let sizes = mm.block_sizes();
    let n_unq = mm.n_unquantized();
    let fp32_bits = (mm.n_params as u64) * 32;
    let budget = (fp32_bits as f64 * ratio) as u64;

    // one scoring table for everything below: the Pareto sweep, the
    // greedy walk and the exact allocator all gather from it
    let table = FitTable::new(&sens.inputs, &sizes, n_unq, &PRECISIONS);

    // random sample -> batch scores -> Pareto front
    let mut sampler =
        BitConfigSampler::new(mm.n_weight_blocks(), mm.n_act_blocks(), &PRECISIONS, seed);
    let configs = sampler.take(samples);
    let packed: Vec<PackedConfig> = configs.iter().map(|c| table.pack(c)).collect();
    let t0 = std::time::Instant::now();
    let scores = table.score_batch(&packed, jobs);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "scored {} configs in {:.1} ms ({:.3e} configs/s)",
        scores.len(),
        dt * 1e3,
        scores.len() as f64 / dt.max(1e-9)
    );
    let front = pareto_front_scores(&scores);
    println!("Pareto front has {} points:", front.len());
    for &i in front.iter().take(10) {
        let (fit, size_bits) = scores[i];
        println!(
            "  size {:>8} bits ({:.2}x comp)  FIT {:.5}  {}",
            size_bits,
            fp32_bits as f64 / size_bits as f64,
            fit,
            configs[i].label()
        );
    }

    // greedy allocation under the budget
    match greedy_allocate_table(&table, budget) {
        Some(g) => println!(
            "greedy @ {:.0}% of fp32 ({budget} bits): size {} FIT {:.5} {}",
            100.0 * ratio,
            g.size_bits,
            g.fit,
            g.cfg.label()
        ),
        None => println!("budget {budget} bits is below the all-minimum-precision floor"),
    }
    match exact_allocate_table(&table, budget) {
        Some(e) => println!(
            "exact  @ {:.0}% of fp32: size {} FIT {:.5} {}",
            100.0 * ratio,
            e.size_bits,
            e.fit,
            e.cfg.label()
        ),
        None => println!(
            "exact: no allocation found (budget below the floor, or a \
             non-finite sensitivity input poisoned the bound)"
        ),
    }
    let uniform = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 4);
    println!(
        "reference uniform-4bit: size {} bits FIT {:.5}",
        model_bits(&sizes, n_unq, &uniform),
        fitq::metrics::fit(&sens.inputs, &uniform)
    );
    Ok(())
}

/// Registry-driven experiment dispatch. Name, flag and value validation
/// all happen before the runtime (and its artifact manifest) is touched,
/// so `fitq experiment bogus` and bad flags fail fast with usage text.
fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(which) = args.positional.first() else {
        bail!("experiment needs a name\n{}", registry::usage());
    };
    let specs: Vec<&'static registry::ExperimentSpec> = if which == "all" {
        registry::REGISTRY.iter().collect()
    } else {
        vec![registry::find(which)
            .ok_or_else(|| anyhow!("unknown experiment {which:?}\n{}", registry::usage()))?]
    };
    for key in args.flags.keys() {
        let known = registry::GLOBAL_FLAGS.contains(&key.as_str())
            || specs.iter().any(|s| s.flags.contains(&key.as_str()));
        if !known {
            bail!("unknown flag --{key} for experiment {which}\n{}", registry::usage());
        }
    }
    let mut o = exp_options(args)?;
    // `--models` entries may be zoo manifest paths; resolve them to the
    // declared names and collect the paths for backend construction
    let mut zoo = Vec::new();
    for m in &mut o.models {
        *m = resolve_model(m, &mut zoo)?;
    }
    let rt = runtime_for(args, zoo)?;
    let pipe = Pipeline::from_env()?;
    registry::run_all(&rt, &pipe, &specs, &o)
}

/// Parse the registry's uniform option schema from raw flags. `None`
/// keeps the experiment's own default for that dimension.
fn exp_options(args: &Args) -> Result<ExpOptions> {
    let opt_usize = |key: &str| -> Result<Option<usize>> {
        args.get(key)
            .map(|v| v.parse().with_context(|| format!("--{key} must be an integer")))
            .transpose()
    };
    let list = |key: &str, upper: bool| -> Vec<String> {
        args.get(key)
            .map(|v| {
                v.split(',')
                    .map(|s| if upper { s.trim().to_uppercase() } else { s.trim().to_string() })
                    .collect()
            })
            .unwrap_or_default()
    };
    Ok(ExpOptions {
        seed: args.usize_or("seed", 0)? as u64,
        jobs: args.usize_or("jobs", 1)?,
        iters: opt_usize("iters")?.map(|v| v as u64),
        runs: opt_usize("runs")?,
        configs: opt_usize("configs")?,
        fp_epochs: opt_usize("fp-epochs")?,
        qat_epochs: opt_usize("qat-epochs")?,
        eval_n: opt_usize("eval-n")?,
        only: list("only", true),
        models: list("models", false),
    })
}
