//! fitq — FIT (Fisher Information Trace) model-sensitivity framework CLI.
//!
//! Subcommands map 1:1 to the paper's tables and figures plus a few
//! utilities; see DESIGN.md for the per-experiment index.
//!
//!   fitq info
//!   fitq train --model cnn_mnist --epochs 30
//!   fitq traces --model cnn_m [--estimator ef|hessian] [--tol 0.01]
//!   fitq search --model cnn_cifar --budget-ratio 0.15
//!   fitq experiment table1|table2|table3|fig1|fig2|fig4|fig5|fig9|all
//!                   [--configs N] [--iters N] [--runs N] [--only A,B]
//!
//! (clap is not in the vendored dependency set; the small parser below is
//! part of the from-scratch substrate.)

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use fitq::coordinator::experiments::{fig1, fig2, fig4, fig5, fig9, table1, table2, table3};
use fitq::coordinator::{
    dataset_for, exact_allocate_table, gather, greedy_allocate_table, pareto_front_scores,
    Estimator, ModelState, StudyOptions, TraceEngine, TraceOptions, Trainer,
};
use fitq::data::EvalSet;
use fitq::metrics::{FitTable, PackedConfig};
use fitq::quant::{model_bits, BitConfig, BitConfigSampler, PRECISIONS};
use fitq::runtime::Runtime;

/// Tiny positional+flag argument parser: `cmd [positionals] --key value`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

const USAGE: &str = "fitq <command>\n\
  info                                   list models and artifacts\n\
  train      --model M [--epochs N]      train FP model, report accuracy\n\
  traces     --model M [--estimator ef|hessian] [--tol T] [--batch B]\n\
  search     --model M [--budget-ratio R] [--samples N] [--jobs N]\n\
  experiment <table1|table2|table3|fig1|fig2|fig4|fig5|fig9|all> [opts]\n\
     table2/fig4: [--configs N] [--fp-epochs N] [--qat-epochs N] [--only A,B]\n\
     table1/3:    [--iters N] [--runs N]\n\
     table1/2/3, fig1/2/4:\n\
                  [--jobs N]  worker threads (1 = serial, 0 = all cores);\n\
                  results are bit-identical at every setting, but ms/iter\n\
                  and speedup columns are wall-clock — keep --jobs 1 when\n\
                  the timing itself is the result\n";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(),
        "train" => cmd_train(&args),
        "traces" => cmd_traces(&args),
        "search" => cmd_search(&args),
        "experiment" => cmd_experiment(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::from_env()?;
    println!("artifact root: {}", rt.manifest.root.display());
    for (name, m) in &rt.manifest.models {
        println!(
            "  {name}: {} params, {} weight blocks, {} act blocks, task {:?}, entries: {}",
            m.n_params,
            m.n_weight_blocks(),
            m.n_act_blocks(),
            m.task,
            m.entries.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "cnn_mnist");
    let epochs = args.usize_or("epochs", 30)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let rt = Runtime::from_env()?;
    let ds = dataset_for(&rt, model, seed ^ 0xda7a)?;
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, seed as u32)?;
    let losses = trainer.train(&mut st, epochs)?;
    let ev = EvalSet::materialize(ds.as_ref(), 512);
    let res = trainer.evaluate(&st, &ev)?;
    println!(
        "{model}: {} epochs, loss {:.4} -> {:.4}, eval score {:.3} over {} samples",
        epochs,
        losses.first().unwrap_or(&f64::NAN),
        losses.last().unwrap_or(&f64::NAN),
        res.score,
        res.n
    );
    Ok(())
}

fn cmd_traces(args: &Args) -> Result<()> {
    let model = args.str_or("model", "cnn_m");
    let seed = args.usize_or("seed", 0)? as u64;
    let epochs = args.usize_or("epochs", 15)?;
    let est = match args.str_or("estimator", "ef") {
        "ef" => Estimator::EmpiricalFisher,
        "hessian" => Estimator::Hutchinson,
        other => bail!("unknown estimator {other:?}"),
    };
    let rt = Runtime::from_env()?;
    let st = fitq::coordinator::experiments::get_trained(&rt, model, epochs, seed)?;
    let ds = dataset_for(&rt, model, seed ^ 0xda7a)?;
    let engine = TraceEngine::new(&rt, ds.as_ref());
    let opt = TraceOptions {
        batch: args.usize_or("batch", 32)?,
        tol: args.f64_or("tol", 0.01)?,
        min_iters: 8,
        max_iters: args.usize_or("max-iters", 500)? as u64,
        seed,
    };
    let r = engine.run(model, &st.params, est, opt)?;
    println!(
        "{model} {} trace: {} iterations ({:.1} ms/iter), norm variance {:.3}",
        r.estimator.name(),
        r.iterations,
        r.iter_time_s * 1e3,
        r.norm_variance
    );
    for (i, (t, se)) in r.w_traces.iter().zip(&r.w_std_errors).enumerate() {
        println!("  block {i}: {t:.4} ± {se:.4}");
    }
    if !r.a_traces.is_empty() {
        let fmt: Vec<String> = r.a_traces.iter().map(|t| format!("{t:.3}")).collect();
        println!("  activation traces: [{}]", fmt.join(", "));
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let model = args.str_or("model", "cnn_cifar");
    let seed = args.usize_or("seed", 0)? as u64;
    let ratio = args.f64_or("budget-ratio", 0.15)?;
    let samples = args.usize_or("samples", 100_000)?;
    let jobs = args.usize_or("jobs", 0)?;
    let rt = Runtime::from_env()?;
    let mm = rt.model(model)?.clone();
    let st = fitq::coordinator::experiments::get_trained(&rt, model, 30, seed)?;
    let ds = dataset_for(&rt, model, seed ^ 0xda7a)?;
    let trainer = Trainer::new(&rt, ds.as_ref());
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, TraceOptions::default())?;

    let sizes = mm.block_sizes();
    let n_unq = mm.n_unquantized();
    let fp32_bits = (mm.n_params as u64) * 32;
    let budget = (fp32_bits as f64 * ratio) as u64;

    // one scoring table for everything below: the Pareto sweep, the
    // greedy walk and the exact allocator all gather from it
    let table = FitTable::new(&sens.inputs, &sizes, n_unq, &PRECISIONS);

    // random sample -> batch scores -> Pareto front
    let mut sampler =
        BitConfigSampler::new(mm.n_weight_blocks(), mm.n_act_blocks(), &PRECISIONS, seed);
    let configs = sampler.take(samples);
    let packed: Vec<PackedConfig> = configs.iter().map(|c| table.pack(c)).collect();
    let t0 = std::time::Instant::now();
    let scores = table.score_batch(&packed, jobs);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "scored {} configs in {:.1} ms ({:.3e} configs/s)",
        scores.len(),
        dt * 1e3,
        scores.len() as f64 / dt.max(1e-9)
    );
    let front = pareto_front_scores(&scores);
    println!("Pareto front has {} points:", front.len());
    for &i in front.iter().take(10) {
        let (fit, size_bits) = scores[i];
        println!(
            "  size {:>8} bits ({:.2}x comp)  FIT {:.5}  {}",
            size_bits,
            fp32_bits as f64 / size_bits as f64,
            fit,
            configs[i].label()
        );
    }

    // greedy allocation under the budget
    match greedy_allocate_table(&table, budget) {
        Some(g) => println!(
            "greedy @ {:.0}% of fp32 ({budget} bits): size {} FIT {:.5} {}",
            100.0 * ratio,
            g.size_bits,
            g.fit,
            g.cfg.label()
        ),
        None => println!("budget {budget} bits is below the all-minimum-precision floor"),
    }
    match exact_allocate_table(&table, budget) {
        Some(e) => println!(
            "exact  @ {:.0}% of fp32: size {} FIT {:.5} {}",
            100.0 * ratio,
            e.size_bits,
            e.fit,
            e.cfg.label()
        ),
        None => println!(
            "exact: no allocation found (budget below the floor, or a \
             non-finite sensitivity input poisoned the bound)"
        ),
    }
    let uniform = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 4);
    println!(
        "reference uniform-4bit: size {} bits FIT {:.5}",
        model_bits(&sizes, n_unq, &uniform),
        fitq::metrics::fit(&sens.inputs, &uniform)
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(which) = args.positional.first() else {
        bail!("experiment needs a name\n{USAGE}");
    };
    let rt = Runtime::from_env()?;
    let run_one = |which: &str| -> Result<()> {
        match which {
            "table1" => {
                let mut o = table1::Table1Options::default();
                o.iters = args.usize_or("iters", o.iters as usize)? as u64;
                o.runs = args.usize_or("runs", o.runs)?;
                o.jobs = args.usize_or("jobs", o.jobs)?;
                table1::run(&rt, &o)?;
            }
            "table2" => {
                let mut o = table2::Table2Options::default();
                o.study = study_opts(args, o.study)?;
                if let Some(only) = args.get("only") {
                    o.only = only.split(',').map(|s| s.trim().to_uppercase()).collect();
                }
                table2::run(&rt, &o)?;
            }
            "table3" => {
                let mut o = table3::Table3Options::default();
                o.iters = args.usize_or("iters", o.iters as usize)? as u64;
                o.runs = args.usize_or("runs", o.runs)?;
                o.jobs = args.usize_or("jobs", o.jobs)?;
                if let Some(models) = args.get("models") {
                    o.models = models.split(',').map(|s| s.trim().to_string()).collect();
                }
                table3::run(&rt, &o)?;
            }
            "fig1" | "fig7" => {
                let mut o = fig1::Fig1Options::default();
                o.jobs = args.usize_or("jobs", o.jobs)?;
                fig1::run(&rt, &o)?;
            }
            "fig2" => {
                let mut o = fig2::Fig2Options::default();
                o.iters = args.usize_or("iters", o.iters as usize)? as u64;
                o.jobs = args.usize_or("jobs", o.jobs)?;
                fig2::run(&rt, &o)?;
            }
            "fig4" => {
                let mut o = fig4::Fig4Options::default();
                o.study = study_opts(args, o.study)?;
                fig4::run(&rt, &o)?;
            }
            "fig5" => fig5::run(&rt, &fig5::Fig5Options::default())?,
            "fig9" => fig9::run(&rt, &fig9::Fig9Options::default())?,
            other => bail!("unknown experiment {other:?}"),
        }
        Ok(())
    };
    if which == "all" {
        for w in ["fig9", "fig5", "table1", "fig1", "fig2", "table3", "table2", "fig4"] {
            run_one(w)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn study_opts(args: &Args, mut s: StudyOptions) -> Result<StudyOptions> {
    s.n_configs = args.usize_or("configs", s.n_configs)?;
    s.fp_epochs = args.usize_or("fp-epochs", s.fp_epochs)?;
    s.qat_epochs = args.usize_or("qat-epochs", s.qat_epochs)?;
    s.eval_n = args.usize_or("eval-n", s.eval_n)?;
    s.seed = args.usize_or("seed", s.seed as usize)? as u64;
    s.jobs = args.usize_or("jobs", s.jobs)?;
    Ok(s)
}
