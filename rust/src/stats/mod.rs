//! Statistics substrate: streaming moments, correlation coefficients,
//! bootstrap confidence intervals, histograms, and the trace-convergence
//! monitor the paper's fixed-tolerance early stopping relies on (§4.3).

pub mod ascii_plot;
mod bootstrap;
mod convergence;
mod corr;
mod histogram;
mod streaming;

pub use bootstrap::bootstrap_ci;
pub use convergence::ConvergenceMonitor;
pub use corr::{kendall_tau, pearson, spearman};
pub use histogram::Histogram;
pub use streaming::{RunningStats, VecStats};
