//! Correlation coefficients — the paper's evaluation methodology (§4.2)
//! scores sensitivity metrics by the *rank* correlation between the metric
//! and the final accuracy across hundreds of MPQ configurations.

/// Pearson product-moment correlation.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

/// Fractional ranks with ties averaged (midranks), as used by Spearman.
pub fn ranks(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let rank = (i + j) as f64 / 2.0 + 1.0; // average of 1-based ranks
        for k in i..=j {
            out[idx[k]] = rank;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation (Pearson over midranks) — the coefficient
/// reported in the paper's Table 2 and Figs 3-4.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Kendall's tau-b (tie-corrected), O(n^2) — n is a few hundred configs.
pub fn kendall_tau(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return f64::NAN;
    }
    let (mut conc, mut disc, mut tx, mut ty) = (0i64, 0i64, 0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = x[i] - x[j];
            let dy = y[i] - y[j];
            if dx == 0.0 && dy == 0.0 {
                continue;
            } else if dx == 0.0 {
                tx += 1;
            } else if dy == 0.0 {
                ty += 1;
            } else if (dx > 0.0) == (dy > 0.0) {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    let n0 = (n * (n - 1) / 2) as f64;
    let denom = ((n0 - tx as f64) * (n0 - ty as f64)).sqrt();
    if denom == 0.0 {
        return f64::NAN;
    }
    (conc - disc) as f64 / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_linear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let x: Vec<f64> = (1..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| v.exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        assert!(pearson(&x, &y) < 0.999); // pearson is fooled, spearman is not
    }

    #[test]
    fn ranks_with_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_known_value() {
        // classic example: one swapped pair
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 2.0, 3.0, 5.0, 4.0];
        assert!((spearman(&x, &y) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn kendall_known_value() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 2.0, 3.0, 5.0, 4.0];
        // 9 concordant, 1 discordant of 10 pairs -> tau = 0.8
        assert!((kendall_tau(&x, &y) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn bounds_hold_on_random_data() {
        let mut r = crate::tensor::Pcg32::new(3, 1);
        for _ in 0..20 {
            let x: Vec<f64> = (0..30).map(|_| r.normal() as f64).collect();
            let y: Vec<f64> = (0..30).map(|_| r.normal() as f64).collect();
            for c in [pearson(&x, &y), spearman(&x, &y), kendall_tau(&x, &y)] {
                assert!((-1.0..=1.0).contains(&c), "{c}");
            }
        }
    }

    #[test]
    fn constant_input_is_nan() {
        let x = [1.0, 1.0, 1.0];
        let y = [1.0, 2.0, 3.0];
        assert!(pearson(&x, &y).is_nan());
        assert!(spearman(&x, &y).is_nan());
    }
}
