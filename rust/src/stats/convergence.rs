//! Trace-estimation convergence monitor (paper §4.3).
//!
//! The paper early-stops trace estimation "at a fixed tolerance, which can
//! be practically computed via a moving variation of the mean trace" —
//! e.g. the U-Net EF trace stops at tol = 0.01 after 82 iterations. We
//! implement that: after each estimator iteration the per-block running
//! means are pushed in; convergence is declared when the *relative* moving
//! standard error of every block mean drops below the tolerance (blocks
//! with near-zero trace are compared on an absolute floor instead).

use super::streaming::VecStats;

#[derive(Debug, Clone)]
pub struct ConvergenceMonitor {
    stats: VecStats,
    tol: f64,
    min_iters: u64,
    max_iters: u64,
}

impl ConvergenceMonitor {
    /// Monitor over `dim` blocks stopping at relative tolerance `tol`
    /// (after at least `min_iters`, at most `max_iters` iterations).
    pub fn new(dim: usize, tol: f64, min_iters: u64, max_iters: u64) -> Self {
        assert!(tol > 0.0 && min_iters >= 1 && max_iters >= min_iters);
        ConvergenceMonitor { stats: VecStats::new(dim), tol, min_iters, max_iters }
    }

    /// Push one estimator iteration's per-block values; returns true when
    /// estimation should stop (converged or iteration cap reached).
    pub fn push(&mut self, values: &[f32]) -> bool {
        self.stats.push(values);
        self.is_done()
    }

    /// Whether estimation should stop now (converged or at the cap).
    pub fn is_done(&self) -> bool {
        let n = self.stats.count();
        if n < self.min_iters {
            return false;
        }
        if n >= self.max_iters {
            return true;
        }
        self.converged()
    }

    /// Relative standard error of every block mean below tolerance.
    pub fn converged(&self) -> bool {
        if self.stats.count() < self.min_iters {
            return false;
        }
        // Blocks are compared on relative standard error; blocks whose mean
        // is negligible next to the largest block use an absolute floor so
        // a dead layer cannot stall convergence forever.
        let scale = self
            .stats
            .means()
            .iter()
            .map(|m| m.abs())
            .fold(0.0f64, f64::max)
            .max(1e-12);
        (0..self.stats.dim()).all(|i| {
            let c = self.stats.component(i);
            let target = self.tol * c.mean().abs().max(0.01 * scale);
            c.std_error() <= target
        })
    }

    /// Iterations pushed so far.
    pub fn iterations(&self) -> u64 {
        self.stats.count()
    }

    /// Per-block running means.
    pub fn means(&self) -> Vec<f64> {
        self.stats.means()
    }

    /// Per-block standard errors of the running means.
    pub fn std_errors(&self) -> Vec<f64> {
        self.stats.std_errors()
    }

    /// The underlying componentwise accumulator.
    pub fn stats(&self) -> &VecStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn stops_early_on_low_noise() {
        let mut m = ConvergenceMonitor::new(3, 0.05, 4, 10_000);
        let mut r = Pcg32::new(1, 1);
        let mut iters = 0;
        loop {
            let v = [
                10.0 + 0.1 * r.normal(),
                5.0 + 0.05 * r.normal(),
                1.0 + 0.01 * r.normal(),
            ];
            iters += 1;
            if m.push(&v) {
                break;
            }
        }
        assert!(iters < 100, "should converge fast, took {iters}");
        assert!((m.means()[0] - 10.0).abs() < 0.5);
    }

    #[test]
    fn noisier_signals_take_longer() {
        let run = |noise: f32| {
            let mut m = ConvergenceMonitor::new(1, 0.02, 4, 100_000);
            let mut r = Pcg32::new(2, 2);
            loop {
                if m.push(&[4.0 + noise * r.normal()]) {
                    return m.iterations();
                }
            }
        };
        assert!(run(2.0) > 4 * run(0.2));
    }

    #[test]
    fn respects_min_and_max_iters() {
        let mut m = ConvergenceMonitor::new(1, 0.5, 8, 12);
        for i in 0..12 {
            let done = m.push(&[1.0]); // zero variance: converged immediately
            if i < 7 {
                assert!(!done, "must not stop before min_iters");
            }
        }
        assert!(m.is_done());

        // never-converging noise hits the cap
        let mut m = ConvergenceMonitor::new(1, 1e-9, 2, 20);
        let mut r = Pcg32::new(3, 3);
        let mut n = 0;
        while !m.push(&[r.normal()]) {
            n += 1;
            assert!(n < 1000);
        }
        assert_eq!(m.iterations(), 20);
    }

    #[test]
    fn zero_blocks_do_not_block_convergence() {
        // one block is exactly zero (e.g. a dead layer); convergence must
        // still be reachable via the absolute floor.
        let mut m = ConvergenceMonitor::new(2, 0.05, 4, 50_000);
        let mut r = Pcg32::new(4, 4);
        loop {
            if m.push(&[8.0 + 0.2 * r.normal(), 1e-9 * r.normal()]) {
                break;
            }
        }
        assert!(m.iterations() < 1000);
    }
}
