//! Percentile bootstrap confidence intervals — used to put error bars on
//! the rank-correlation coefficients the experiments report (the paper
//! reports point estimates; we add CIs since our studies are seeded).

use crate::tensor::Pcg32;

/// Percentile bootstrap CI for a paired statistic (e.g. a correlation).
///
/// Resamples (x, y) pairs with replacement `n_boot` times and returns the
/// (lo, hi) percentile interval at the given confidence level.
pub fn bootstrap_ci(
    x: &[f64],
    y: &[f64],
    stat: impl Fn(&[f64], &[f64]) -> f64,
    n_boot: usize,
    confidence: f64,
    rng: &mut Pcg32,
) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty());
    assert!((0.0..1.0).contains(&(1.0 - confidence)));
    let n = x.len();
    let mut draws = Vec::with_capacity(n_boot);
    let mut bx = vec![0.0; n];
    let mut by = vec![0.0; n];
    for _ in 0..n_boot {
        for i in 0..n {
            let j = rng.below(n as u32) as usize;
            bx[i] = x[j];
            by[i] = y[j];
        }
        let s = stat(&bx, &by);
        if s.is_finite() {
            draws.push(s);
        }
    }
    if draws.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    draws.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - confidence) / 2.0;
    let pick = |q: f64| {
        let idx = ((draws.len() as f64 - 1.0) * q).round() as usize;
        draws[idx.min(draws.len() - 1)]
    };
    (pick(alpha), pick(1.0 - alpha))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{pearson, spearman};

    #[test]
    fn ci_brackets_point_estimate() {
        let mut r = Pcg32::new(1, 1);
        let x: Vec<f64> = (0..80).map(|_| r.normal() as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| v + 0.5 * r.normal() as f64).collect();
        let point = spearman(&x, &y);
        let (lo, hi) = bootstrap_ci(&x, &y, spearman, 500, 0.95, &mut r);
        assert!(lo <= point && point <= hi, "{lo} {point} {hi}");
        assert!(lo > 0.3, "strongly correlated data should have high lower bound");
    }

    #[test]
    fn ci_narrows_with_sample_size() {
        let mut r = Pcg32::new(2, 1);
        let make = |n: usize, r: &mut Pcg32| {
            let x: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
            let y: Vec<f64> = x.iter().map(|v| v + r.normal() as f64).collect();
            (x, y)
        };
        let (x1, y1) = make(20, &mut r);
        let (x2, y2) = make(400, &mut r);
        let (lo1, hi1) = bootstrap_ci(&x1, &y1, pearson, 400, 0.95, &mut r);
        let (lo2, hi2) = bootstrap_ci(&x2, &y2, pearson, 400, 0.95, &mut r);
        assert!(hi2 - lo2 < hi1 - lo1);
    }

    #[test]
    fn degenerate_stat_gives_nan() {
        let x = [1.0, 1.0, 1.0, 1.0];
        let y = [2.0, 2.0, 2.0, 2.0];
        let mut r = Pcg32::new(3, 1);
        let (lo, hi) = bootstrap_ci(&x, &y, pearson, 50, 0.9, &mut r);
        assert!(lo.is_nan() && hi.is_nan());
    }
}
