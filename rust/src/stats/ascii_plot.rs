//! ASCII scatter/line plots — the experiments render their figures as
//! plain-text plots next to the CSVs so a terminal-only workflow can see
//! the shape the paper's matplotlib figures show.

/// Render a scatter plot of (x, y) points into a `width` x `height`
/// character grid with axis labels.
pub fn scatter(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    points: &[(f64, f64)],
    width: usize,
    height: usize,
) -> String {
    let finite: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if finite.is_empty() {
        return format!("{title}\n(no finite points)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &finite {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 == x0 {
        x1 = x0 + 1.0;
    }
    if y1 == y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let mut counts = vec![vec![0u32; width]; height];
    for &(x, y) in &finite {
        let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
        let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
        let row = height - 1 - cy.min(height - 1);
        let col = cx.min(width - 1);
        counts[row][col] += 1;
    }
    for (r, row) in counts.iter().enumerate() {
        for (c, &n) in row.iter().enumerate() {
            grid[r][c] = match n {
                0 => b' ',
                1 => b'.',
                2..=3 => b'o',
                4..=8 => b'O',
                _ => b'@',
            };
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("{ylabel} ({y1:.3} top, {y0:.3} bottom)\n"));
    for row in grid {
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(" {xlabel}: {x0:.4} .. {x1:.4}\n"));
    out
}

/// Render one or more named line series (shared x = index).
pub fn lines(title: &str, series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    let markers = [b'*', b'+', b'x', b'#'];
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    let mut max_len = 0usize;
    for (_, ys) in series {
        for &y in ys.iter().filter(|v| v.is_finite()) {
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        max_len = max_len.max(ys.len());
    }
    if !y0.is_finite() || max_len < 2 {
        out.push_str("(no data)\n");
        return out;
    }
    if y1 == y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let col = (i as f64 / (max_len - 1) as f64 * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][col.min(width - 1)] = markers[si % markers.len()];
        }
    }
    for row in grid {
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!(" {} = {}\n", markers[si % markers.len()] as char, name));
    }
    out.push_str(&format!(" y: {y0:.4} .. {y1:.4}, x: 0 .. {}\n", max_len - 1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_renders_extremes() {
        let pts = [(0.0, 0.0), (1.0, 1.0), (0.5, 0.5)];
        let s = scatter("t", "x", "y", &pts, 21, 11);
        // top-right and bottom-left corners are hit
        let rows: Vec<&str> = s.lines().collect();
        assert!(rows[2].ends_with('.'), "{s}");
        assert!(rows[12].starts_with("|."), "{s}");
        assert!(s.contains("x: 0.0000 .. 1.0000"));
    }

    #[test]
    fn scatter_handles_degenerate_input() {
        assert!(scatter("t", "x", "y", &[], 10, 5).contains("no finite"));
        let s = scatter("t", "x", "y", &[(1.0, 2.0)], 10, 5);
        assert!(s.contains('.'));
    }

    #[test]
    fn scatter_density_markers() {
        let pts: Vec<(f64, f64)> = (0..50).map(|_| (0.5, 0.5)).collect();
        let s = scatter("t", "x", "y", &pts, 9, 5);
        assert!(s.contains('@'), "{s}");
    }

    #[test]
    fn lines_renders_two_series() {
        let a: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..30).map(|i| 30.0 - i as f64).collect();
        let s = lines("conv", &[("up", &a), ("down", &b)], 40, 10);
        assert!(s.contains('*') && s.contains('+'), "{s}");
        assert!(s.contains("* = up"));
    }
}
