//! Fixed-range histogram — used by the Fig. 9 experiment to test the
//! uniformity of the quantization-error distribution.

#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    below: u64,
    above: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Histogram { lo, hi, bins: vec![0; n_bins], below: 0, above: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            // the half-open top bin gets exact-hi values
            if x == self.hi {
                *self.bins.last_mut().unwrap() += 1;
            } else {
                self.above += 1;
            }
        } else {
            let f = (x - self.lo) / (self.hi - self.lo);
            let idx = ((f * self.bins.len() as f64) as usize).min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.below + self.above
    }

    pub fn outliers(&self) -> u64 {
        self.below + self.above
    }

    /// Chi-squared statistic against the uniform distribution over the
    /// in-range mass. Small values (relative to dof = bins-1) mean the
    /// sample is consistent with uniform noise — the paper's Appendix E
    /// assumption.
    pub fn chi2_uniform(&self) -> f64 {
        let n: u64 = self.bins.iter().sum();
        if n == 0 {
            return f64::NAN;
        }
        let expected = n as f64 / self.bins.len() as f64;
        self.bins
            .iter()
            .map(|&c| {
                let d = c as f64 - expected;
                d * d / expected
            })
            .sum()
    }

    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn bins_and_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for x in [0.1, 0.3, 0.6, 0.9, -0.5, 1.5, 1.0] {
            h.push(x);
        }
        assert_eq!(h.counts(), &[1, 1, 1, 2]); // 1.0 lands in top bin
        assert_eq!(h.outliers(), 2);
        assert_eq!(h.total(), 7);
    }

    #[test]
    fn chi2_small_for_uniform_large_for_point_mass() {
        let mut r = Pcg32::new(1, 1);
        let mut hu = Histogram::new(0.0, 1.0, 20);
        let mut hp = Histogram::new(0.0, 1.0, 20);
        for _ in 0..20_000 {
            hu.push(r.uniform() as f64);
            hp.push(0.42);
        }
        // uniform: chi2 ~ dof = 19; point mass: enormous
        assert!(hu.chi2_uniform() < 60.0, "{}", hu.chi2_uniform());
        assert!(hp.chi2_uniform() > 10_000.0);
    }
}
