//! Welford streaming moments — numerically stable single-pass mean/variance.

/// Scalar running statistics.
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        RunningStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations pushed so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 for an empty accumulator).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (n denominator).
    pub fn variance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.sample_variance().sqrt()
    }

    /// Standard error of the running mean.
    pub fn std_error(&self) -> f64 {
        if self.n == 0 { f64::INFINITY } else { self.std() / (self.n as f64).sqrt() }
    }

    /// Smallest observation seen (infinity for an empty accumulator).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation seen (-infinity for an empty accumulator).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge two accumulators (parallel reduction; Chan et al.).
    pub fn merge(&self, other: &RunningStats) -> RunningStats {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        RunningStats { n, mean, m2, min: self.min.min(other.min), max: self.max.max(other.max) }
    }
}

/// Per-component running statistics over fixed-length vectors (e.g. the
/// per-block trace values streamed out of the estimator executables).
#[derive(Debug, Clone)]
pub struct VecStats {
    comps: Vec<RunningStats>,
}

impl VecStats {
    /// Empty accumulator over `dim` components.
    pub fn new(dim: usize) -> Self {
        VecStats { comps: vec![RunningStats::new(); dim] }
    }

    /// Fold one `dim`-length observation vector in, componentwise.
    pub fn push(&mut self, xs: &[f32]) {
        assert_eq!(xs.len(), self.comps.len(), "VecStats dimension mismatch");
        for (c, &x) in self.comps.iter_mut().zip(xs) {
            c.push(x as f64);
        }
    }

    /// Number of components per observation.
    pub fn dim(&self) -> usize {
        self.comps.len()
    }

    /// Number of observation vectors pushed so far.
    pub fn count(&self) -> u64 {
        self.comps.first().map_or(0, |c| c.count())
    }

    /// Per-component running means.
    pub fn means(&self) -> Vec<f64> {
        self.comps.iter().map(|c| c.mean()).collect()
    }

    /// Per-component standard errors of the running means.
    pub fn std_errors(&self) -> Vec<f64> {
        self.comps.iter().map(|c| c.std_error()).collect()
    }

    /// Scalar accumulator of component `i`.
    pub fn component(&self, i: usize) -> &RunningStats {
        &self.comps[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn matches_naive_two_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin() * 5.0 + 2.0).collect();
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        let (mean, var) = naive(&xs);
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.count(), 100);
    }

    #[test]
    fn stable_for_large_offset() {
        // classic catastrophic-cancellation case for naive sum-of-squares
        let mut s = RunningStats::new();
        for i in 0..1000 {
            s.push(1e9 + (i % 2) as f64);
        }
        assert!((s.variance() - 0.25).abs() < 1e-6, "var={}", s.variance());
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..57).map(|i| (i as f64).sqrt()).collect();
        let mut all = RunningStats::new();
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i < 20 { a.push(x) } else { b.push(x) }
        }
        let m = a.merge(&b);
        assert!((m.mean() - all.mean()).abs() < 1e-12);
        assert!((m.variance() - all.variance()).abs() < 1e-12);
        assert_eq!(m.count(), all.count());
    }

    #[test]
    fn std_error_shrinks() {
        let mut s = RunningStats::new();
        let mut prev = f64::INFINITY;
        let mut r = crate::tensor::Pcg32::new(5, 5);
        for k in 1..=5 {
            for _ in 0..(200 * k) {
                s.push(r.normal() as f64);
            }
            let se = s.std_error();
            assert!(se < prev);
            prev = se;
        }
    }

    #[test]
    fn vec_stats_componentwise() {
        let mut vs = VecStats::new(2);
        vs.push(&[1.0, 10.0]);
        vs.push(&[3.0, 30.0]);
        assert_eq!(vs.means(), vec![2.0, 20.0]);
        assert_eq!(vs.count(), 2);
    }

    #[test]
    #[should_panic]
    fn vec_stats_rejects_wrong_dim() {
        let mut vs = VecStats::new(2);
        vs.push(&[1.0]);
    }
}
