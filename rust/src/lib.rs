//! fitq — a three-layer Rust + JAX + Pallas reproduction of
//! "FIT: A Metric for Model Sensitivity" (ICLR 2023).
//!
//! Layer map (see DESIGN.md):
//! - L1/L2 live in python/compile (build-time only) and arrive here as AOT
//!   HLO artifacts + manifest.
//! - L3 is this crate: `runtime` talks PJRT, `coordinator` orchestrates the
//!   paper's methodology (fanning independent work over the
//!   `coordinator::parallel` worker pool), and
//!   `data`/`quant`/`stats`/`metrics`/`tensor` are the from-scratch
//!   substrates it stands on. (Two deliberate upward edges:
//!   `metrics::FitTable::score_batch` and the native backend's
//!   `native::gemm` kernels both fan over `coordinator::parallel`,
//!   which is itself a std-only substrate that happens to live under the
//!   coordinator.)
//!
//! The workspace builds hermetically: the `anyhow` and `xla` dependencies
//! are vendored path crates under `vendor/` (the `xla` build is an
//! API-compatible stub that reports the backend as unavailable at runtime —
//! DESIGN.md explains how to swap in the real one). The `native` backend
//! makes the whole experiment pipeline runnable without artifacts or
//! PJRT: a from-scratch interpreter for the study models behind the same
//! `runtime::Backend` dispatch contract.

pub mod bench_util;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod native;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod tensor;
