//! Typed wrapper over a compiled PJRT executable.
//!
//! Every dispatch is validated against the manifest's IoSpecs (shape,
//! dtype, argument count) before touching PJRT, and outputs come back as
//! name-addressable f32/i32 host vectors. Input literals are allocated
//! once and refilled in place across calls (`copy_raw_from`) — literal
//! construction is the dominant host-side cost on the training hot loop.

use std::cell::RefCell;

use anyhow::{bail, Context, Result};

use super::artifact::{DType, EntrySpec, IoSpec};

/// A borrowed argument for one dispatch.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    U32Scalar(u32),
    F32Scalar(f32),
}

impl Arg<'_> {
    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) | Arg::F32Scalar(_) => DType::F32,
            Arg::I32(_) => DType::I32,
            Arg::U32Scalar(_) => DType::U32,
        }
    }

    fn len(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::I32(v) => v.len(),
            Arg::U32Scalar(_) | Arg::F32Scalar(_) => 1,
        }
    }

    fn bytes(&self) -> &[u8] {
        unsafe {
            match self {
                Arg::F32(v) => std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4),
                Arg::I32(v) => std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4),
                Arg::U32Scalar(v) => std::slice::from_raw_parts(v as *const u32 as *const u8, 4),
                Arg::F32Scalar(v) => std::slice::from_raw_parts(v as *const f32 as *const u8, 4),
            }
        }
    }
}

/// One named output, copied back to the host.
#[derive(Debug, Clone)]
pub struct OutValue {
    pub spec: IoSpec,
    pub f32: Vec<f32>,
    pub i32: Vec<i32>,
}

impl OutValue {
    /// The output as an f32 slice (empty for i32 outputs).
    pub fn as_f32(&self) -> &[f32] {
        &self.f32
    }

    /// First element of an f32 output (scalar outputs).
    pub fn scalar_f32(&self) -> f32 {
        self.f32[0]
    }
}

/// Outputs of one dispatch, addressable by name or index.
#[derive(Debug)]
pub struct Outputs(pub Vec<OutValue>);

impl Outputs {
    /// Output by manifest name.
    pub fn get(&self, name: &str) -> Result<&OutValue> {
        self.0
            .iter()
            .find(|o| o.spec.name == name)
            .with_context(|| format!("no output named {name:?}"))
    }

    /// Named f32 output as a slice.
    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        Ok(self.get(name)?.as_f32())
    }

    /// Named scalar f32 output.
    pub fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.get(name)?.scalar_f32())
    }
}

/// A compiled entry point plus its manifest specs.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    /// Input literals, allocated at first dispatch and refilled in place.
    literals: RefCell<Vec<xla::Literal>>,
    pub dispatches: std::cell::Cell<u64>,
}

impl Executable {
    /// Parse the HLO text at `hlo_path` and compile it for `client`.
    pub fn compile(client: &xla::PjRtClient, spec: EntrySpec, hlo_path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(Executable {
            spec,
            exe,
            literals: RefCell::new(Vec::new()),
            dispatches: std::cell::Cell::new(0),
        })
    }

    fn validate(&self, args: &[Arg]) -> Result<()> {
        let ins = &self.spec.inputs;
        if args.len() != ins.len() {
            bail!(
                "{}: expected {} args ({:?}), got {}",
                self.spec.name,
                ins.len(),
                ins.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
                args.len()
            );
        }
        for (a, spec) in args.iter().zip(ins) {
            if a.dtype() != spec.dtype {
                bail!("{}: arg {:?} dtype mismatch", self.spec.name, spec.name);
            }
            if a.len() != spec.numel() {
                bail!(
                    "{}: arg {:?} has {} elements, spec {:?} wants {}",
                    self.spec.name,
                    spec.name,
                    a.len(),
                    spec.shape,
                    spec.numel()
                );
            }
        }
        Ok(())
    }

    fn fill_literals(&self, args: &[Arg]) -> Result<()> {
        let mut lits = self.literals.borrow_mut();
        // §Perf escape hatch: FITQ_NO_LITERAL_REUSE=1 rebuilds input
        // literals every dispatch (the naive baseline the reuse path is
        // measured against in EXPERIMENTS.md §Perf L3).
        if std::env::var_os("FITQ_NO_LITERAL_REUSE").is_some() {
            lits.clear();
        }
        if lits.is_empty() {
            for (a, spec) in args.iter().zip(&self.spec.inputs) {
                lits.push(xla::Literal::create_from_shape_and_untyped_data(
                    spec.dtype.element_type(),
                    &spec.shape,
                    a.bytes(),
                )?);
            }
        } else {
            for (a, lit) in args.iter().zip(lits.iter_mut()) {
                match a {
                    Arg::F32(v) => lit.copy_raw_from(v)?,
                    Arg::I32(v) => lit.copy_raw_from(v)?,
                    Arg::U32Scalar(v) => lit.copy_raw_from(&[*v])?,
                    Arg::F32Scalar(v) => lit.copy_raw_from(&[*v])?,
                }
            }
        }
        Ok(())
    }

    /// Dispatch once; outputs are copied back to host vectors.
    pub fn run(&self, args: &[Arg]) -> Result<Outputs> {
        self.validate(args)?;
        self.fill_literals(args)?;
        let lits = self.literals.borrow();
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        self.dispatches.set(self.dispatches.get() + 1);
        let root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.spec.outputs) {
            let mut v = OutValue { spec: spec.clone(), f32: Vec::new(), i32: Vec::new() };
            match spec.dtype {
                DType::F32 => v.f32 = lit.to_vec::<f32>()?,
                DType::I32 => v.i32 = lit.to_vec::<i32>()?,
                DType::U32 => bail!("u32 outputs unsupported"),
            }
            out.push(v);
        }
        Ok(Outputs(out))
    }
}
