//! Typed, backend-agnostic wrapper over a compiled entry point.
//!
//! Every dispatch is validated against the manifest's IoSpecs (shape,
//! dtype, argument count) before touching the backend, and outputs come
//! back as name-addressable f32/i32 host vectors, validated against the
//! manifest on the way out. The backend-specific execution lives behind
//! the [`Dispatcher`] trait (`runtime::backend`); this wrapper is the
//! shared contract both PJRT and the native interpreter honor.

use anyhow::{bail, Context, Result};

use super::artifact::{DType, EntrySpec, IoSpec};
use super::backend::{Dispatcher, OutBuf};

/// A borrowed argument for one dispatch.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    U32Scalar(u32),
    F32Scalar(f32),
}

impl Arg<'_> {
    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) | Arg::F32Scalar(_) => DType::F32,
            Arg::I32(_) => DType::I32,
            Arg::U32Scalar(_) => DType::U32,
        }
    }

    fn len(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::I32(v) => v.len(),
            Arg::U32Scalar(_) | Arg::F32Scalar(_) => 1,
        }
    }

    /// Raw little-endian bytes (PJRT literal transfer).
    pub fn bytes(&self) -> &[u8] {
        unsafe {
            match self {
                Arg::F32(v) => std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4),
                Arg::I32(v) => std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4),
                Arg::U32Scalar(v) => std::slice::from_raw_parts(v as *const u32 as *const u8, 4),
                Arg::F32Scalar(v) => std::slice::from_raw_parts(v as *const f32 as *const u8, 4),
            }
        }
    }
}

/// One named output, copied back to the host.
#[derive(Debug, Clone)]
pub struct OutValue {
    pub spec: IoSpec,
    pub f32: Vec<f32>,
    pub i32: Vec<i32>,
}

impl OutValue {
    /// The output as an f32 slice (empty for i32 outputs).
    pub fn as_f32(&self) -> &[f32] {
        &self.f32
    }

    /// First element of an f32 output (scalar outputs).
    pub fn scalar_f32(&self) -> f32 {
        self.f32[0]
    }
}

/// Outputs of one dispatch, addressable by name or index.
#[derive(Debug)]
pub struct Outputs(pub Vec<OutValue>);

impl Outputs {
    /// Output by manifest name.
    pub fn get(&self, name: &str) -> Result<&OutValue> {
        self.0
            .iter()
            .find(|o| o.spec.name == name)
            .with_context(|| format!("no output named {name:?}"))
    }

    /// Named f32 output as a slice.
    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        Ok(self.get(name)?.as_f32())
    }

    /// Named scalar f32 output.
    pub fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.get(name)?.scalar_f32())
    }
}

/// A compiled entry point plus its manifest specs.
pub struct Executable {
    pub spec: EntrySpec,
    inner: Box<dyn Dispatcher>,
    pub dispatches: std::cell::Cell<u64>,
}

impl Executable {
    /// Wrap a backend dispatcher under the shared validation contract.
    pub fn new(spec: EntrySpec, inner: Box<dyn Dispatcher>) -> Executable {
        Executable { spec, inner, dispatches: std::cell::Cell::new(0) }
    }

    fn validate(&self, args: &[Arg]) -> Result<()> {
        let ins = &self.spec.inputs;
        if args.len() != ins.len() {
            bail!(
                "{}: expected {} args ({:?}), got {}",
                self.spec.name,
                ins.len(),
                ins.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
                args.len()
            );
        }
        for (a, spec) in args.iter().zip(ins) {
            if a.dtype() != spec.dtype {
                bail!("{}: arg {:?} dtype mismatch", self.spec.name, spec.name);
            }
            if a.len() != spec.numel() {
                bail!(
                    "{}: arg {:?} has {} elements, spec {:?} wants {}",
                    self.spec.name,
                    spec.name,
                    a.len(),
                    spec.shape,
                    spec.numel()
                );
            }
        }
        Ok(())
    }

    /// Dispatch once; outputs are validated and copied back to host vectors.
    pub fn run(&self, args: &[Arg]) -> Result<Outputs> {
        self.validate(args)?;
        let bufs = self.inner.run(args)?;
        self.dispatches.set(self.dispatches.get() + 1);
        if bufs.len() != self.spec.outputs.len() {
            bail!(
                "{}: backend returned {} outputs, manifest says {}",
                self.spec.name,
                bufs.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(bufs.len());
        for (buf, spec) in bufs.into_iter().zip(&self.spec.outputs) {
            let mut v = OutValue { spec: spec.clone(), f32: Vec::new(), i32: Vec::new() };
            let n = match (buf, spec.dtype) {
                (OutBuf::F32(x), DType::F32) => {
                    v.f32 = x;
                    v.f32.len()
                }
                (OutBuf::I32(x), DType::I32) => {
                    v.i32 = x;
                    v.i32.len()
                }
                _ => bail!("{}: output {:?} dtype mismatch", self.spec.name, spec.name),
            };
            if n != spec.numel() {
                bail!(
                    "{}: output {:?} has {} elements, spec {:?} wants {}",
                    self.spec.name,
                    spec.name,
                    n,
                    spec.shape,
                    spec.numel()
                );
            }
            out.push(v);
        }
        Ok(Outputs(out))
    }
}
