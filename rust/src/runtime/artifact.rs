//! Artifact manifest: the typed view of artifacts/manifest.json.
//!
//! aot.py records, per model, the flat parameter layout (so the Rust side
//! can address quantizable blocks and BN tensors inside the parameter
//! buffer it owns) and, per entry point, the exact input/output shapes and
//! dtypes of the lowered HLO. The runtime validates every dispatch against
//! these specs — a shape mistake fails loudly at the call site instead of
//! inside PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn element_type(&self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
        }
    }
}

/// One input or output of an entry point.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One quantizable weight block inside the flat parameter vector.
#[derive(Debug, Clone)]
pub struct WeightBlock {
    pub index: usize,
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

/// One activation site.
#[derive(Debug, Clone)]
pub struct ActBlock {
    pub index: usize,
    pub shape: Vec<usize>,
    pub size: usize,
}

/// One named tensor of the flat layout (includes non-quantized tensors).
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub kind: String,
    pub block: i64,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub n_params: usize,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub task: Task,
    pub train_k: usize,
    pub train_b: usize,
    pub eval_b: usize,
    pub calib_b: usize,
    pub predict_b: usize,
    pub trace_bs: Vec<usize>,
    pub weight_blocks: Vec<WeightBlock>,
    pub act_blocks: Vec<ActBlock>,
    pub tensors: Vec<TensorInfo>,
    pub entries: BTreeMap<String, EntrySpec>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Classify,
    Segment,
}

impl ModelManifest {
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no entry {name:?}", self.name))
    }

    pub fn n_weight_blocks(&self) -> usize {
        self.weight_blocks.len()
    }

    pub fn n_act_blocks(&self) -> usize {
        self.act_blocks.len()
    }

    /// Per-block parameter counts (model size accounting).
    pub fn block_sizes(&self) -> Vec<usize> {
        self.weight_blocks.iter().map(|b| b.size).collect()
    }

    /// Parameters not covered by any quantizable block (biases, BN).
    pub fn n_unquantized(&self) -> usize {
        self.n_params - self.block_sizes().iter().sum::<usize>()
    }

    /// Per-weight-block mean |gamma| (None if the layer has no BN tensor).
    /// Convention from layers.py: "convI.w" pairs with "convI.gamma".
    pub fn bn_gamma_views(&self) -> Vec<Option<TensorInfo>> {
        self.weight_blocks
            .iter()
            .map(|wb| {
                let gname = wb.name.replace(".w", ".gamma");
                self.tensors.iter().find(|t| t.name == gname).cloned()
            })
            .collect()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

fn parse_io(v: &Json) -> Result<IoSpec, String> {
    Ok(IoSpec {
        name: v.str_field("name")?.to_string(),
        shape: v.usize_array("shape")?,
        dtype: DType::parse(v.str_field("dtype")?).map_err(|e| e.to_string())?,
    })
}

fn parse_model(name: &str, v: &Json) -> Result<ModelManifest> {
    let err = |e: String| anyhow!("model {name}: {e}");
    let task = match v.str_field("task").map_err(err)? {
        "classify" => Task::Classify,
        "segment" => Task::Segment,
        other => bail!("model {name}: unknown task {other:?}"),
    };
    let weight_blocks = v
        .arr_field("weight_blocks")
        .map_err(err)?
        .iter()
        .map(|b| -> Result<WeightBlock, String> {
            Ok(WeightBlock {
                index: b.usize_field("index")?,
                name: b.str_field("name")?.to_string(),
                offset: b.usize_field("offset")?,
                size: b.usize_field("size")?,
                shape: b.usize_array("shape")?,
            })
        })
        .collect::<Result<Vec<_>, String>>()
        .map_err(err)?;
    let act_blocks = v
        .arr_field("act_blocks")
        .map_err(err)?
        .iter()
        .map(|b| -> Result<ActBlock, String> {
            Ok(ActBlock {
                index: b.usize_field("index")?,
                shape: b.usize_array("shape")?,
                size: b.usize_field("size")?,
            })
        })
        .collect::<Result<Vec<_>, String>>()
        .map_err(err)?;
    let tensors = v
        .arr_field("tensors")
        .map_err(err)?
        .iter()
        .map(|t| -> Result<TensorInfo, String> {
            Ok(TensorInfo {
                name: t.str_field("name")?.to_string(),
                shape: t.usize_array("shape")?,
                offset: t.usize_field("offset")?,
                size: t.usize_field("size")?,
                kind: t.str_field("kind")?.to_string(),
                block: t.field("block")?.as_f64().ok_or("block not a number")? as i64,
            })
        })
        .collect::<Result<Vec<_>, String>>()
        .map_err(err)?;
    let mut entries = BTreeMap::new();
    for (ename, ev) in v.field("entries").map_err(err)?.as_obj().context("entries")? {
        let spec = EntrySpec {
            name: ename.clone(),
            file: ev.str_field("file").map_err(err)?.to_string(),
            inputs: ev
                .arr_field("inputs")
                .map_err(err)?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>, String>>()
                .map_err(err)?,
            outputs: ev
                .arr_field("outputs")
                .map_err(err)?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>, String>>()
                .map_err(err)?,
        };
        entries.insert(ename.clone(), spec);
    }
    Ok(ModelManifest {
        name: name.to_string(),
        n_params: v.usize_field("n_params").map_err(err)?,
        input_shape: v.usize_array("input_shape").map_err(err)?,
        n_classes: v.usize_field("n_classes").map_err(err)?,
        task,
        train_k: v.usize_field("train_k").map_err(err)?,
        train_b: v.usize_field("train_b").map_err(err)?,
        eval_b: v.usize_field("eval_b").map_err(err)?,
        calib_b: v.usize_field("calib_b").map_err(err)?,
        predict_b: v.usize_field("predict_b").map_err(err)?,
        trace_bs: v.usize_array("trace_bs").map_err(err)?,
        weight_blocks,
        act_blocks,
        tensors,
        entries,
    })
}

impl Manifest {
    /// Load artifacts/manifest.json from the artifact root directory.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let mut models = BTreeMap::new();
        for (name, mv) in v.field("models").map_err(|e| anyhow!(e))?.as_obj().context("models")? {
            models.insert(name.clone(), parse_model(name, mv)?);
        }
        Ok(Manifest { root, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model {name:?} (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        hlo_path(&self.root, entry)
    }
}

/// HLO text location for an entry under an artifact root — the single
/// path rule shared by [`Manifest::hlo_path`] and the PJRT backend.
pub fn hlo_path(root: &Path, entry: &EntrySpec) -> PathBuf {
    root.join(&entry.file)
}

/// Default artifact root: $FITQ_ARTIFACTS or ./artifacts.
pub fn default_artifact_root() -> PathBuf {
    std::env::var_os("FITQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Manifest::load(root).ok()
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        let model = m.model("cnn_mnist").unwrap();
        assert_eq!(model.input_shape, vec![16, 16, 1]);
        assert_eq!(model.n_weight_blocks(), 4);
        assert_eq!(model.n_act_blocks(), 3);
        assert_eq!(model.task, Task::Classify);
        // layout covers the whole parameter vector
        let covered: usize = model.tensors.iter().map(|t| t.size).sum();
        assert_eq!(covered, model.n_params);
        // entries carry consistent specs
        let e = model.entry("ef_trace_bs32").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.outputs[0].shape, vec![model.n_weight_blocks()]);
        assert!(m.hlo_path(e).exists());
    }

    #[test]
    fn bn_views_follow_naming_convention() {
        let Some(m) = manifest() else { return };
        let bn = m.model("cnn_mnist_bn").unwrap();
        let views = bn.bn_gamma_views();
        assert_eq!(views.len(), 4);
        assert!(views[0].is_some() && views[1].is_some() && views[2].is_some());
        assert!(views[3].is_none(), "fc layer has no BN");
        let plain = m.model("cnn_mnist").unwrap();
        assert!(plain.bn_gamma_views().iter().all(|v| v.is_none()));
    }

    #[test]
    fn unet_manifest_is_segment() {
        let Some(m) = manifest() else { return };
        let u = m.model("unet").unwrap();
        assert_eq!(u.task, Task::Segment);
        assert_eq!(u.n_weight_blocks(), 10);
        let e = u.entry("eval").unwrap();
        assert_eq!(e.outputs.len(), 3); // loss, inter, union
        assert_eq!(e.outputs[1].shape, vec![u.n_classes]);
    }

    #[test]
    fn missing_model_is_error() {
        let Some(m) = manifest() else { return };
        assert!(m.model("nope").is_err());
    }
}
