//! The backend abstraction: who actually executes an entry point.
//!
//! [`Executable::run`](super::Executable::run) always meant the same
//! contract — validated named arguments in, name-addressable host vectors
//! out, per `(model, entry)` — but the implementation was welded to PJRT.
//! This module lifts the contract into two traits:
//!
//! - [`Backend`]: compiles one manifest entry into a [`Dispatcher`]
//!   (lazily, once per `(model, entry)`, cached by the `Runtime`);
//! - [`Dispatcher`]: executes one dispatch. Argument validation against
//!   the manifest [`EntrySpec`](super::EntrySpec) happens *before* the
//!   dispatcher is called, and output shape/dtype validation after, in
//!   the shared `Executable` wrapper — a backend only moves numbers.
//!
//! Two backends exist: [`PjrtBackend`](super::client::PjrtBackend)
//! (compiled HLO artifacts through xla-rs) and
//! [`NativeBackend`](crate::native::NativeBackend) (the from-scratch
//! pure-Rust interpreter, no artifacts required). [`BackendSpec`] is the
//! `Clone + Send` recipe for rebuilding a `Runtime` on a worker thread —
//! the `Runtime` itself stays deliberately single-threaded.
//!
//! **Cache-key rule.** Backend identity is part of every pipeline stage
//! digest (`coordinator::pipeline::stages`): the two backends are
//! numerically independent implementations, so a native-trained
//! checkpoint must never validate against a PJRT key or vice versa.

use std::path::PathBuf;

use anyhow::Result;

use super::artifact::{EntrySpec, ModelManifest};
use super::executable::Arg;

/// One raw output buffer, typed but not yet named/validated.
#[derive(Debug, Clone)]
pub enum OutBuf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Executes one compiled entry point. Arguments are pre-validated against
/// the entry's `IoSpec`s; outputs are returned in manifest order and
/// validated by the caller.
pub trait Dispatcher {
    fn run(&self, args: &[Arg]) -> Result<Vec<OutBuf>>;
}

/// A runtime execution backend: turns manifest entries into dispatchers.
pub trait Backend {
    /// Stable identity used in reports and pipeline cache keys.
    fn name(&self) -> &'static str;

    /// Compile (or build) the dispatcher for one entry point.
    fn compile(&self, model: &ModelManifest, entry: &EntrySpec) -> Result<Box<dyn Dispatcher>>;

    /// Snapshot the op-level trace accumulated so far, if this backend
    /// profiles ops and profiling is armed (`FITQ_TRACE_OPS`, see
    /// [`native::trace`](crate::native::trace)). The default — and the
    /// PJRT backend, whose compiled HLO is opaque at op granularity —
    /// reports `None`. Tracing observes results, never changes them,
    /// so nothing here may feed a pipeline cache key.
    fn op_trace(&self) -> Option<crate::native::trace::OpTraceReport> {
        None
    }
}

/// A serializable recipe for constructing a `Runtime` — what parallel
/// phases hand to worker threads instead of the non-`Send` runtime itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendSpec {
    /// PJRT over an artifact root (`artifacts/manifest.json` + HLO text).
    Pjrt(PathBuf),
    /// The pure-Rust interpreter with its built-in model manifest and an
    /// intra-op GEMM thread budget (`native::gemm`). The budget is a
    /// wall-clock knob only — outputs are bit-identical at every value —
    /// so it is deliberately *not* part of any pipeline cache digest
    /// (those hash [`BackendSpec::name`], which ignores it).
    Native {
        /// Threads the GEMM layer may fan panels over (`1` = serial).
        threads: usize,
        /// Zoo model-manifest paths (`zoo/*.json`) loaded alongside the
        /// builtins. Part of the spec so worker threads rebuild the same
        /// model set — but *not* part of any pipeline cache digest:
        /// those hash the compiled model's block layout (`hash_model`),
        /// so an equivalent manifest shares the builtin's digests and a
        /// different one separates automatically.
        zoo: Vec<PathBuf>,
    },
}

impl BackendSpec {
    /// The backend name this spec resolves to.
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt(_) => "pjrt",
            BackendSpec::Native { .. } => "native",
        }
    }

    /// This spec with intra-op parallelism disabled — what outer
    /// parallel phases (`run_study` sweeps, `TraceEngine::run_many`,
    /// `experiment all`) hand their workers, so a `--jobs` fan-out never
    /// multiplies into `jobs x threads` oversubscription. Inter-op
    /// parallelism always wins that conflict: the outer pool already
    /// fills the cores with independent work (DESIGN.md "Native math
    /// kernels").
    pub fn intra_serial(&self) -> BackendSpec {
        match self {
            BackendSpec::Pjrt(root) => BackendSpec::Pjrt(root.clone()),
            BackendSpec::Native { zoo, .. } => {
                BackendSpec::Native { threads: 1, zoo: zoo.clone() }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_names_are_stable() {
        // these strings are part of the pipeline cache-key contract; the
        // native thread budget must never leak into the name (cache keys
        // are thread-count invariant because outputs are)
        assert_eq!(BackendSpec::Native { threads: 1, zoo: vec![] }.name(), "native");
        assert_eq!(BackendSpec::Native { threads: 8, zoo: vec![] }.name(), "native");
        assert_eq!(
            BackendSpec::Native { threads: 1, zoo: vec![PathBuf::from("zoo/x.json")] }.name(),
            "native",
            "zoo manifests must not leak into the name either — digests \
             separate on the compiled block layout, not the file list"
        );
        assert_eq!(BackendSpec::Pjrt(PathBuf::from("x")).name(), "pjrt");
    }

    #[test]
    fn intra_serial_strips_only_the_thread_budget() {
        let zoo = vec![PathBuf::from("zoo/deep.json")];
        let s = BackendSpec::Native { threads: 6, zoo: zoo.clone() }.intra_serial();
        assert_eq!(s, BackendSpec::Native { threads: 1, zoo });
        let p = BackendSpec::Pjrt(PathBuf::from("a/b")).intra_serial();
        assert_eq!(p, BackendSpec::Pjrt(PathBuf::from("a/b")));
    }
}
