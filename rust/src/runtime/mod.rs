//! Runtime layer: execution backends (PJRT + native interpreter), the
//! artifact manifest, typed executables, and the JSON substrate the
//! manifest parser is built on.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod executable;
pub mod json;

pub use artifact::{default_artifact_root, DType, EntrySpec, IoSpec, Manifest, ModelManifest, Task};
pub use backend::{Backend, BackendSpec, Dispatcher, OutBuf};
pub use client::Runtime;
pub use executable::{Arg, Executable, Outputs};
pub use json::Json;
