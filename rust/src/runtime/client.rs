//! The PJRT runtime: one CPU client, lazily compiled executables.
//!
//! `Runtime` is the single entry point the coordinator uses to talk to
//! XLA: it owns the PJRT client, the manifest, and a cache of compiled
//! executables keyed by (model, entry). Compilation happens on first use
//! and is reported through `CompileStats` so experiments can separate
//! one-time compile cost from steady-state dispatch cost.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::artifact::{Manifest, ModelManifest};
use super::executable::Executable;

/// One-time compilation cost accounting (separated from dispatch cost in
/// the experiment reports).
#[derive(Debug, Default, Clone)]
pub struct CompileStats {
    /// Number of entry points compiled so far.
    pub compiled: usize,
    /// Total wall-clock spent compiling.
    pub total_time: Duration,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<(String, String), Rc<Executable>>>,
    stats: RefCell<CompileStats>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact root.
    pub fn new(artifact_root: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_root)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(CompileStats::default()),
        })
    }

    /// Default artifact location ($FITQ_ARTIFACTS or ./artifacts).
    pub fn from_env() -> Result<Runtime> {
        Runtime::new(super::artifact::default_artifact_root())
    }

    /// Manifest entry for a model, by name.
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest.model(name)
    }

    /// Fetch (compiling on first use) an entry-point executable.
    pub fn load(&self, model: &str, entry: &str) -> Result<Rc<Executable>> {
        let key = (model.to_string(), entry.to_string());
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.model(model)?.entry(entry)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let exe = Rc::new(Executable::compile(&self.client, spec, &path)?);
        {
            let mut s = self.stats.borrow_mut();
            s.compiled += 1;
            s.total_time += t0.elapsed();
        }
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Snapshot of the compilation cost so far.
    pub fn compile_stats(&self) -> CompileStats {
        self.stats.borrow().clone()
    }

    /// Drop compiled executables (frees PJRT memory between experiments).
    pub fn evict_model(&self, model: &str) {
        self.cache.borrow_mut().retain(|(m, _), _| m != model);
    }
}
