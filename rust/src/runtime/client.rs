//! The runtime: one execution backend, lazily compiled executables.
//!
//! `Runtime` is the single entry point the coordinator uses to execute
//! entry points: it owns a [`Backend`], the manifest, and a cache of
//! compiled executables keyed by (model, entry). Compilation happens on
//! first use and is reported through `CompileStats` so experiments can
//! separate one-time compile cost from steady-state dispatch cost.
//!
//! Backend selection (`--backend` flag / `FITQ_BACKEND` env / automatic):
//! - `pjrt` — compiled HLO artifacts through xla-rs; needs `artifacts/`
//!   (from `make artifacts`) and a real (non-stub) `xla` crate.
//! - `native` — the pure-Rust interpreter (`crate::native`); zero setup,
//!   study models only.
//! - automatic ([`Runtime::from_env`]): `pjrt` when the artifact root has
//!   a manifest, `native` otherwise.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::artifact::{default_artifact_root, DType, EntrySpec, IoSpec, Manifest, ModelManifest};
use super::backend::{Backend, BackendSpec, Dispatcher, OutBuf};
use super::executable::{Arg, Executable};

/// One-time compilation cost accounting (separated from dispatch cost in
/// the experiment reports).
#[derive(Debug, Default, Clone)]
pub struct CompileStats {
    /// Number of entry points compiled so far.
    pub compiled: usize,
    /// Total wall-clock spent compiling.
    pub total_time: Duration,
}

/// The hint appended to every PJRT bring-up failure: both missing
/// artifacts and the vendored `xla` stub should steer users to the
/// zero-setup path.
const PJRT_HINT: &str = "PJRT backend unavailable — rerun with `--backend native` \
     (pure-Rust interpreter, no artifacts needed), or point FITQ_ARTIFACTS at a root \
     built by `make artifacts` and build against the real xla-rs crate (DESIGN.md \
     \"Backends\")";

pub struct Runtime {
    backend: Box<dyn Backend>,
    spec: BackendSpec,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<(String, String), Rc<Executable>>>,
    stats: RefCell<CompileStats>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact root (the historical
    /// constructor; equivalent to [`Runtime::pjrt`]).
    pub fn new(artifact_root: impl AsRef<Path>) -> Result<Runtime> {
        Runtime::pjrt(artifact_root)
    }

    /// PJRT over an artifact root.
    pub fn pjrt(artifact_root: impl AsRef<Path>) -> Result<Runtime> {
        let root = artifact_root.as_ref().to_path_buf();
        let manifest = Manifest::load(&root).context(PJRT_HINT)?;
        let client = match xla::PjRtClient::cpu() {
            Ok(c) => c,
            Err(e) => bail!("{e}\n{PJRT_HINT}"),
        };
        Ok(Runtime::assemble(
            Box::new(PjrtBackend { client, root: root.clone() }),
            BackendSpec::Pjrt(root),
            manifest,
        ))
    }

    /// The pure-Rust native backend with its built-in manifest — no
    /// artifacts, no PJRT, study models only. The intra-op GEMM thread
    /// budget comes from `$FITQ_NATIVE_THREADS` (default 1; `0` = one
    /// per core); outputs are bit-identical at every budget.
    pub fn native() -> Result<Runtime> {
        Runtime::native_with_threads(native_threads_from_env())
    }

    /// [`Runtime::native`] with an explicit intra-op thread budget
    /// (`0` = one thread per available core).
    pub fn native_with_threads(threads: usize) -> Result<Runtime> {
        Runtime::native_with_zoo(threads, Vec::new())
    }

    /// [`Runtime::native_with_threads`] plus zoo model manifests
    /// (`zoo/*.json`), each strictly validated and compiled alongside
    /// the builtins (`native::manifest`). Fail-closed: any manifest
    /// rejection aborts runtime construction with the path and the
    /// offending field.
    pub fn native_with_zoo(threads: usize, zoo: Vec<PathBuf>) -> Result<Runtime> {
        let threads = resolve_native_threads(threads);
        let (backend, manifest) = crate::native::NativeBackend::create_with_zoo(threads, &zoo)?;
        Ok(Runtime::assemble(Box::new(backend), BackendSpec::Native { threads, zoo }, manifest))
    }

    /// Rebuild a runtime from a worker-portable spec (`Runtime` itself is
    /// deliberately not `Send`; parallel phases ship the spec instead —
    /// usually [`BackendSpec::intra_serial`]'d first, so outer `--jobs`
    /// fan-outs don't multiply into the intra-op budget).
    pub fn from_spec(spec: &BackendSpec) -> Result<Runtime> {
        match spec {
            BackendSpec::Pjrt(root) => Runtime::pjrt(root),
            BackendSpec::Native { threads, zoo } => {
                Runtime::native_with_zoo(*threads, zoo.clone())
            }
        }
    }

    /// Backend resolution for the CLI/env: `FITQ_BACKEND=native|pjrt`
    /// forces a backend; otherwise `pjrt` when the default artifact root
    /// ($FITQ_ARTIFACTS or ./artifacts) holds a manifest, else `native`.
    pub fn from_env() -> Result<Runtime> {
        let forced = std::env::var("FITQ_BACKEND").ok();
        Runtime::from_backend_arg(forced.as_deref())
    }

    /// Resolve an explicit backend name (`--backend` flag), falling back
    /// to the automatic rule of [`Runtime::from_env`] when `None`.
    pub fn from_backend_arg(arg: Option<&str>) -> Result<Runtime> {
        match arg {
            Some("native") => Runtime::native(),
            Some("pjrt") => Runtime::pjrt(default_artifact_root()),
            Some(other) => bail!("unknown backend {other:?} (expected native|pjrt)"),
            None => {
                let root = default_artifact_root();
                if root.join("manifest.json").exists() {
                    Runtime::pjrt(root)
                } else {
                    Runtime::native()
                }
            }
        }
    }

    /// [`Runtime::from_backend_arg`] plus zoo model manifests. Zoo
    /// models exist only in the native interpreter, so a non-empty zoo
    /// forces the native backend; asking for PJRT alongside one is a
    /// contradiction, refused rather than silently re-routed.
    pub fn from_backend_arg_with_zoo(arg: Option<&str>, zoo: Vec<PathBuf>) -> Result<Runtime> {
        if zoo.is_empty() {
            return Runtime::from_backend_arg(arg);
        }
        match arg {
            Some("native") | None => Runtime::native_with_zoo(native_threads_from_env(), zoo),
            Some("pjrt") => bail!(
                "zoo model manifests run on the native backend only — drop \
                 `--backend pjrt` or pass a builtin model name"
            ),
            Some(other) => bail!("unknown backend {other:?} (expected native|pjrt)"),
        }
    }

    /// Snapshot of this runtime's intra-op thread budget (native: the
    /// GEMM fan-out width; PJRT: always 1 — XLA owns its own threading).
    pub fn intra_threads(&self) -> usize {
        match &self.spec {
            BackendSpec::Pjrt(_) => 1,
            BackendSpec::Native { threads, .. } => *threads,
        }
    }

    fn assemble(backend: Box<dyn Backend>, spec: BackendSpec, manifest: Manifest) -> Runtime {
        Runtime {
            backend,
            spec,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(CompileStats::default()),
        }
    }

    /// The backend's stable identity ("pjrt" / "native") — part of every
    /// pipeline stage digest.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Worker-portable recipe for rebuilding this runtime.
    pub fn spec(&self) -> BackendSpec {
        self.spec.clone()
    }

    /// Manifest entry for a model, by name.
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest.model(name)
    }

    /// Fetch (compiling on first use) an entry-point executable.
    pub fn load(&self, model: &str, entry: &str) -> Result<Rc<Executable>> {
        let key = (model.to_string(), entry.to_string());
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let mm = self.manifest.model(model)?;
        let spec = mm.entry(entry)?.clone();
        let t0 = Instant::now();
        let inner = self.backend.compile(mm, &spec)?;
        let exe = Rc::new(Executable::new(spec, inner));
        {
            let mut s = self.stats.borrow_mut();
            s.compiled += 1;
            s.total_time += t0.elapsed();
        }
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Snapshot of the compilation cost so far.
    pub fn compile_stats(&self) -> CompileStats {
        self.stats.borrow().clone()
    }

    /// Snapshot the backend's accumulated op-level trace, if it profiles
    /// ops and profiling is armed (`FITQ_TRACE_OPS` — native backend
    /// only; see [`native::trace`](crate::native::trace)). `model` and
    /// `workload` arrive empty: the caller labels the run before
    /// persisting.
    pub fn op_trace(&self) -> Option<crate::native::trace::OpTraceReport> {
        self.backend.op_trace()
    }

    /// Drop compiled executables (frees backend memory between experiments).
    pub fn evict_model(&self, model: &str) {
        self.cache.borrow_mut().retain(|(m, _), _| m != model);
    }
}

/// `$FITQ_NATIVE_THREADS` resolution: unset/unparseable = 1 (serial).
fn native_threads_from_env() -> usize {
    std::env::var("FITQ_NATIVE_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// `0` means "one thread per available core", like `--jobs 0`.
fn resolve_native_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// The PJRT backend: parses HLO text from the artifact root and compiles
/// it through the xla-rs CPU client.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    root: PathBuf,
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, _model: &ModelManifest, entry: &EntrySpec) -> Result<Box<dyn Dispatcher>> {
        let path = super::artifact::hlo_path(&self.root, entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", entry.name))?;
        Ok(Box::new(PjrtExec {
            name: entry.name.clone(),
            inputs: entry.inputs.clone(),
            out_dtypes: entry.outputs.iter().map(|o| o.dtype).collect(),
            exe,
            literals: RefCell::new(Vec::new()),
        }))
    }
}

/// One compiled PJRT executable with reusable input literals (literal
/// construction is the dominant host-side cost on the training hot
/// loop). Holds only the spec slices it needs — the full `EntrySpec`
/// lives in the wrapping `Executable`, which owns output validation.
struct PjrtExec {
    name: String,
    inputs: Vec<IoSpec>,
    out_dtypes: Vec<DType>,
    exe: xla::PjRtLoadedExecutable,
    /// Input literals, allocated at first dispatch and refilled in place.
    literals: RefCell<Vec<xla::Literal>>,
}

impl PjrtExec {
    fn fill_literals(&self, args: &[Arg]) -> Result<()> {
        let mut lits = self.literals.borrow_mut();
        // §Perf escape hatch: FITQ_NO_LITERAL_REUSE=1 rebuilds input
        // literals every dispatch (the naive baseline the reuse path is
        // measured against in EXPERIMENTS.md §Perf L3).
        if std::env::var_os("FITQ_NO_LITERAL_REUSE").is_some() {
            lits.clear();
        }
        if lits.is_empty() {
            for (a, spec) in args.iter().zip(&self.inputs) {
                lits.push(xla::Literal::create_from_shape_and_untyped_data(
                    spec.dtype.element_type(),
                    &spec.shape,
                    a.bytes(),
                )?);
            }
        } else {
            for (a, lit) in args.iter().zip(lits.iter_mut()) {
                match a {
                    Arg::F32(v) => lit.copy_raw_from(v)?,
                    Arg::I32(v) => lit.copy_raw_from(v)?,
                    Arg::U32Scalar(v) => lit.copy_raw_from(&[*v])?,
                    Arg::F32Scalar(v) => lit.copy_raw_from(&[*v])?,
                }
            }
        }
        Ok(())
    }
}

impl Dispatcher for PjrtExec {
    fn run(&self, args: &[Arg]) -> Result<Vec<OutBuf>> {
        self.fill_literals(args)?;
        let lits = self.literals.borrow();
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        // guard the zip below from silently truncating extra parts; the
        // wrapping Executable re-validates count, shape and dtype
        if parts.len() != self.out_dtypes.len() {
            bail!(
                "{}: executable returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.out_dtypes.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, dtype) in parts.into_iter().zip(&self.out_dtypes) {
            out.push(match dtype {
                DType::F32 => OutBuf::F32(lit.to_vec::<f32>()?),
                DType::I32 => OutBuf::I32(lit.to_vec::<i32>()?),
                DType::U32 => bail!("u32 outputs unsupported"),
            });
        }
        Ok(out)
    }
}
