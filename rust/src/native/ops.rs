//! Tensor-op kernels of the native interpreter: forward and backward.
//!
//! All buffers are flat `f32` slices in NHWC layout (HWIO conv kernels),
//! matching the L2 graphs. Storage and elementwise math stay in `f32`;
//! reductions (BN statistics, backward channel sums) accumulate in `f64`
//! — the backward of each op is the exact derivative of the forward *as
//! implemented here*, which is what the finite-difference gradient checks
//! in `tests/native_backend.rs` pin down.
//!
//! The conv/dense family executes through the math-kernel layer in
//! [`gemm`](super::gemm), with the scratch matrices, the intra-op
//! thread budget, and the kernel-variant policy carried by the caller's
//! [`ExecCtx`]. The routing is *measured on the running host*, not
//! assumed (see the `gemm` module docs and DESIGN.md "Kernel dispatch &
//! autotuning"): each wrapper asks [`ExecCtx::choice`] which (ISA,
//! lowering) won the autotuner's micro-benchmark for its op and
//! vector-axis width class — direct loop vs im2col+GEMM for the convs
//! ([`conv2d_im2col`], [`conv2d_bwd_w_im2col`] are first-class tunable
//! variants, 0-ULP property-tested), the rank-1 `sgemm` form for
//! backward-by-input and dense. PR 5's hand-pinned routing survives
//! only as the deterministic fallback under a forced
//! `FITQ_NATIVE_KERNEL` (its one-host evidence — "im2col loses for the
//! study models' narrow `c_out`" — turned out width- and host-specific;
//! BENCH_kernels.json has the multi-width data). Every variant of every
//! route is bit-identical, so routing can never change a result, only
//! wall-clock. The original scalar loop nests live on in [`reference`]
//! as the oracles every path is pinned against (`tests/native_gemm.rs`)
//! — and as the measured "before" of the before/after benchmark
//! (`FITQ_NATIVE_REFERENCE=1`). Elementwise and reduction ops (ReLU,
//! max-pool, batch-norm, softmax-CE) are memory-bound and stay scalar.
//!
//! When the caller's [`ExecCtx`] carries an armed profiler
//! (`--trace-ops` / `FITQ_TRACE_OPS`, see [`trace`](super::trace)), each
//! tuned wrapper also records its invocation — chosen (ISA, lowering),
//! shape, elements moved, FLOPs, wall time — after the kernel returns.
//! Disarmed (the default) this is one branch per op, and the
//! `FITQ_NATIVE_REFERENCE` oracle path is deliberately untraced.
//!
//! **Rule for new ops** (DESIGN.md "Native math kernels"): an op may use
//! the threaded kernel layer only if it can state its per-output-element
//! `f32` operation chain and show it unchanged from the scalar reference
//! at every thread count, and a measurement shows the lowering actually
//! wins for its shapes; anything whose reduction order would depend on
//! the fan-out (e.g. a tree-reduced batch sum) must stay serial or keep
//! a per-element sequential accumulator.

/// Re-exported execution context (scratch arena + thread budget) every
/// conv/dense wrapper below takes — defined in [`gemm`](super::gemm).
pub use super::gemm::ExecCtx;
use super::gemm::{self, Init};
use super::simd::{self, Isa};
use super::trace::{OpRecord, TracedOp};
use super::tune::{Lowering, TunedOp};

/// The scalar loop-nest kernels the GEMM path replaced, kept as oracles.
///
/// These are PR 4's implementations, bit-for-bit: `tests/native_gemm.rs`
/// pins the GEMM wrappers to them at 0 ULP, the FD gradchecks in
/// `tests/native_backend.rs` run against them, and
/// `FITQ_NATIVE_REFERENCE=1` routes whole dispatches through them for
/// A/B measurement. They take no [`ExecCtx`]: no scratch, no threads.
pub mod reference {
    /// Valid output-row range for kernel tap `d` (SAME padding, 3-tap).
    #[inline]
    pub(crate) fn tap_range(d: usize, len: usize) -> (usize, usize) {
        (if d == 0 { 1 } else { 0 }, if d == 2 { len - 1 } else { len })
    }

    /// SAME-padded 3x3 stride-1 conv: `out[n,i,j,o] += x[n,i+di-1,j+dj-1,ci]
    /// * w[di,dj,ci,o]`, then `+ bias[o]`. `out` is overwritten.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        x: &[f32],
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        wgt: &[f32],
        cout: usize,
        bias: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), n * h * w * cin);
        debug_assert_eq!(wgt.len(), 9 * cin * cout);
        debug_assert_eq!(out.len(), n * h * w * cout);
        for orow in out.chunks_exact_mut(cout) {
            orow.copy_from_slice(bias);
        }
        for ni in 0..n {
            for di in 0..3 {
                let (i0, i1) = tap_range(di, h);
                for dj in 0..3 {
                    let (j0, j1) = tap_range(dj, w);
                    for i in i0..i1 {
                        let xi = i + di - 1;
                        for j in j0..j1 {
                            let xj = j + dj - 1;
                            let xrow = &x[((ni * h + xi) * w + xj) * cin..][..cin];
                            let orow = &mut out[((ni * h + i) * w + j) * cout..][..cout];
                            for (ci, &xv) in xrow.iter().enumerate() {
                                let wrow = &wgt[((di * 3 + dj) * cin + ci) * cout..][..cout];
                                for (o, &wv) in orow.iter_mut().zip(wrow) {
                                    *o += xv * wv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Conv backward w.r.t. kernel and bias; accumulates into `dw`/`db`
    /// (callers zero them).
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_bwd_w(
        x: &[f32],
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        dout: &[f32],
        cout: usize,
        dw: &mut [f32],
        db: &mut [f32],
    ) {
        for ni in 0..n {
            for di in 0..3 {
                let (i0, i1) = tap_range(di, h);
                for dj in 0..3 {
                    let (j0, j1) = tap_range(dj, w);
                    for i in i0..i1 {
                        let xi = i + di - 1;
                        for j in j0..j1 {
                            let xj = j + dj - 1;
                            let xrow = &x[((ni * h + xi) * w + xj) * cin..][..cin];
                            let drow = &dout[((ni * h + i) * w + j) * cout..][..cout];
                            for (ci, &xv) in xrow.iter().enumerate() {
                                let dwrow =
                                    &mut dw[((di * 3 + dj) * cin + ci) * cout..][..cout];
                                for (dwv, &dv) in dwrow.iter_mut().zip(drow) {
                                    *dwv += xv * dv;
                                }
                            }
                        }
                    }
                }
            }
        }
        for drow in dout.chunks_exact(cout) {
            for (b, &dv) in db.iter_mut().zip(drow) {
                *b += dv;
            }
        }
    }

    /// Conv backward w.r.t. the input; overwrites `dx`.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_bwd_x(
        wgt: &[f32],
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        dout: &[f32],
        cout: usize,
        dx: &mut [f32],
    ) {
        dx.fill(0.0);
        for ni in 0..n {
            for di in 0..3 {
                let (i0, i1) = tap_range(di, h);
                for dj in 0..3 {
                    let (j0, j1) = tap_range(dj, w);
                    for i in i0..i1 {
                        let xi = i + di - 1;
                        for j in j0..j1 {
                            let xj = j + dj - 1;
                            let drow = &dout[((ni * h + i) * w + j) * cout..][..cout];
                            let dxrow = &mut dx[((ni * h + xi) * w + xj) * cin..][..cin];
                            for (ci, dxv) in dxrow.iter_mut().enumerate() {
                                let wrow = &wgt[((di * 3 + dj) * cin + ci) * cout..][..cout];
                                let mut acc = 0.0f32;
                                for (&wv, &dv) in wrow.iter().zip(drow) {
                                    acc += wv * dv;
                                }
                                *dxv += acc;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Dense layer: `out[n,o] = sum_i x[n,i] w[i,o] + b[o]`; overwrites
    /// `out`.
    #[allow(clippy::too_many_arguments)]
    pub fn dense(
        x: &[f32],
        n: usize,
        fin: usize,
        wgt: &[f32],
        fout: usize,
        bias: &[f32],
        out: &mut [f32],
    ) {
        for ni in 0..n {
            let orow = &mut out[ni * fout..][..fout];
            orow.copy_from_slice(bias);
            let xrow = &x[ni * fin..][..fin];
            for (fi, &xv) in xrow.iter().enumerate() {
                let wrow = &wgt[fi * fout..][..fout];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    }

    /// Dense backward: accumulates `dw`/`db`, overwrites `dx`.
    #[allow(clippy::too_many_arguments)]
    pub fn dense_bwd(
        x: &[f32],
        wgt: &[f32],
        n: usize,
        fin: usize,
        fout: usize,
        dout: &[f32],
        dw: &mut [f32],
        db: &mut [f32],
        dx: &mut [f32],
    ) {
        for ni in 0..n {
            let xrow = &x[ni * fin..][..fin];
            let drow = &dout[ni * fout..][..fout];
            for (fi, &xv) in xrow.iter().enumerate() {
                let dwrow = &mut dw[fi * fout..][..fout];
                for (dwv, &dv) in dwrow.iter_mut().zip(drow) {
                    *dwv += xv * dv;
                }
            }
            for (b, &dv) in db.iter_mut().zip(drow) {
                *b += dv;
            }
            let dxrow = &mut dx[ni * fin..][..fin];
            for (fi, dxv) in dxrow.iter_mut().enumerate() {
                let wrow = &wgt[fi * fout..][..fout];
                let mut acc = 0.0f32;
                for (&wv, &dv) in wrow.iter().zip(drow) {
                    acc += wv * dv;
                }
                *dxv = acc;
            }
        }
    }
}

/// SAME-padded 3x3 stride-1 conv: routed per the tuned [`ExecCtx::choice`]
/// for [`TunedOp::ConvFwd`] at this `c_out` — the threaded direct kernel
/// ([`gemm::conv2d_direct`]) or the im2col+GEMM lowering
/// ([`conv2d_im2col`]), both bit-identical to [`reference::conv2d`] at
/// every ISA. `out` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    cout: usize,
    bias: &[f32],
    out: &mut [f32],
    ctx: &mut ExecCtx,
) {
    if ctx.use_reference {
        return reference::conv2d(x, n, h, w, cin, wgt, cout, bias, out);
    }
    let c = ctx.choice(TunedOp::ConvFwd, cout);
    let t0 = ctx.prof.start();
    match c.lowering {
        Lowering::Im2col => conv2d_im2col_at(x, n, h, w, cin, wgt, cout, bias, out, ctx, c.isa),
        _ => gemm::conv2d_direct(x, n, h, w, cin, wgt, cout, bias, out, ctx.threads, c.isa),
    }
    ctx.prof.record(t0, || OpRecord {
        op: TracedOp::ConvFwd,
        variant: Some((c.isa, c.lowering)),
        width: cout as u32,
        shape: format!("b{n} {h}x{w} {cin}->{cout}"),
        elems_read: (x.len() + wgt.len() + bias.len()) as u64,
        elems_written: out.len() as u64,
        flops: (2 * n * h * w * 9 * cin * cout) as u64,
    });
}

/// The im2col + GEMM conv lowering (`out = im2col(x) * W + bias`);
/// bit-identical to [`reference::conv2d`] and [`conv2d`]. A first-class
/// tunable variant: the autotuner routes [`conv2d`] here whenever the
/// micro-benchmark shows the GEMM's locality edge beating the 9x im2col
/// materialization for the op's width class on the running host (PR 5
/// pinned this off everywhere from one host's narrow-`c_out` evidence —
/// the tuner re-decides per host and per width).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_im2col(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    cout: usize,
    bias: &[f32],
    out: &mut [f32],
    ctx: &mut ExecCtx,
) {
    let isa = ctx.choice(TunedOp::ConvFwd, cout).isa;
    conv2d_im2col_at(x, n, h, w, cin, wgt, cout, bias, out, ctx, isa);
}

#[allow(clippy::too_many_arguments)]
fn conv2d_im2col_at(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    cout: usize,
    bias: &[f32],
    out: &mut [f32],
    ctx: &mut ExecCtx,
    isa: Isa,
) {
    let m = n * h * w;
    let k = 9 * cin;
    gemm::im2col3x3(x, n, h, w, cin, &mut ctx.scratch.a);
    gemm::sgemm(m, cout, k, &ctx.scratch.a, wgt, Init::Bias(bias), out, ctx.threads, isa);
}

/// Conv backward w.r.t. kernel and bias: routed per the tuned
/// [`ExecCtx::choice`] for [`TunedOp::ConvBwdW`] — the tap-threaded
/// direct kernel with exact-zero skipping
/// ([`gemm::conv2d_bwd_w_direct`]) or the im2col+GEMM lowering
/// ([`conv2d_bwd_w_im2col`]); accumulates into `dw`/`db` (callers zero
/// them). Bit-identical to [`reference::conv2d_bwd_w`] at every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_w(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dout: &[f32],
    cout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    ctx: &mut ExecCtx,
) {
    if ctx.use_reference {
        return reference::conv2d_bwd_w(x, n, h, w, cin, dout, cout, dw, db);
    }
    let c = ctx.choice(TunedOp::ConvBwdW, cout);
    let t0 = ctx.prof.start();
    match c.lowering {
        Lowering::Im2col => {
            conv2d_bwd_w_im2col_at(x, n, h, w, cin, dout, cout, dw, db, ctx, c.isa)
        }
        _ => gemm::conv2d_bwd_w_direct(x, n, h, w, cin, dout, cout, dw, db, ctx.threads, c.isa),
    }
    ctx.prof.record(t0, || OpRecord {
        op: TracedOp::ConvBwdW,
        variant: Some((c.isa, c.lowering)),
        width: cout as u32,
        shape: format!("b{n} {h}x{w} {cin}->{cout}"),
        elems_read: (x.len() + dout.len()) as u64,
        elems_written: (dw.len() + db.len()) as u64,
        flops: (2 * n * h * w * 9 * cin * cout) as u64,
    });
}

/// The im2col + GEMM backward-by-weights lowering (`dw += im2col(x)^T *
/// dout`); bit-identical to [`reference::conv2d_bwd_w`] and
/// [`conv2d_bwd_w`]. A first-class tunable variant (same per-host
/// reasoning as [`conv2d_im2col`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_w_im2col(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dout: &[f32],
    cout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    ctx: &mut ExecCtx,
) {
    let isa = ctx.choice(TunedOp::ConvBwdW, cout).isa;
    conv2d_bwd_w_im2col_at(x, n, h, w, cin, dout, cout, dw, db, ctx, isa);
}

#[allow(clippy::too_many_arguments)]
fn conv2d_bwd_w_im2col_at(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dout: &[f32],
    cout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    ctx: &mut ExecCtx,
    isa: Isa,
) {
    let m = n * h * w;
    let k = 9 * cin;
    gemm::im2col3x3(x, n, h, w, cin, &mut ctx.scratch.a);
    gemm::sgemm_atb(m, cout, k, &ctx.scratch.a, dout, dw, ctx.threads, isa);
    simd::col_sum(isa, db, dout, cout);
}

/// Conv backward w.r.t. the input (`G = dout * W^T`, then the col2im
/// gather); overwrites `dx`. Bit-identical to
/// [`reference::conv2d_bwd_x`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_x(
    wgt: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dout: &[f32],
    cout: usize,
    dx: &mut [f32],
    ctx: &mut ExecCtx,
) {
    if ctx.use_reference {
        return reference::conv2d_bwd_x(wgt, n, h, w, cin, dout, cout, dx);
    }
    // the vector axis of both the G GEMM and the col2im gather is c_in
    let c = ctx.choice(TunedOp::ConvBwdX, cin);
    let t0 = ctx.prof.start();
    let m = n * h * w;
    let k = 9 * cin;
    gemm::transpose(wgt, k, cout, &mut ctx.scratch.b);
    // size (don't re-zero) the G buffer: the Init::Zero sgemm overwrites
    // every element before accumulating
    ctx.scratch.a.resize(m * k, 0.0);
    gemm::sgemm(
        m,
        k,
        cout,
        dout,
        &ctx.scratch.b,
        Init::Zero,
        &mut ctx.scratch.a,
        ctx.threads,
        c.isa,
    );
    gemm::col2im3x3(&ctx.scratch.a, n, h, w, cin, dx, ctx.threads, c.isa);
    ctx.prof.record(t0, || OpRecord {
        op: TracedOp::ConvBwdX,
        variant: Some((c.isa, c.lowering)),
        width: cin as u32,
        shape: format!("b{n} {h}x{w} {cin}->{cout}"),
        elems_read: (dout.len() + wgt.len()) as u64,
        elems_written: dx.len() as u64,
        flops: (2 * n * h * w * 9 * cin * cout) as u64,
    });
}

/// Dense layer as one GEMM (`out = x * W + bias`); overwrites `out`.
/// Bit-identical to [`reference::dense`].
#[allow(clippy::too_many_arguments)]
pub fn dense(
    x: &[f32],
    n: usize,
    fin: usize,
    wgt: &[f32],
    fout: usize,
    bias: &[f32],
    out: &mut [f32],
    ctx: &mut ExecCtx,
) {
    if ctx.use_reference {
        return reference::dense(x, n, fin, wgt, fout, bias, out);
    }
    let c = ctx.choice(TunedOp::DenseFwd, fout);
    let t0 = ctx.prof.start();
    gemm::sgemm(n, fout, fin, x, wgt, Init::Bias(bias), out, ctx.threads, c.isa);
    ctx.prof.record(t0, || OpRecord {
        op: TracedOp::DenseFwd,
        variant: Some((c.isa, c.lowering)),
        width: fout as u32,
        shape: format!("b{n} {fin}->{fout}"),
        elems_read: (x.len() + wgt.len() + bias.len()) as u64,
        elems_written: out.len() as u64,
        flops: (2 * n * fin * fout) as u64,
    });
}

/// Dense backward (`dw += x^T * dout`, `db += column sums`, `dx = dout *
/// W^T`): accumulates `dw`/`db`, overwrites `dx`. Bit-identical to
/// [`reference::dense_bwd`].
#[allow(clippy::too_many_arguments)]
pub fn dense_bwd(
    x: &[f32],
    wgt: &[f32],
    n: usize,
    fin: usize,
    fout: usize,
    dout: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    ctx: &mut ExecCtx,
) {
    if ctx.use_reference {
        return reference::dense_bwd(x, wgt, n, fin, fout, dout, dw, db, dx);
    }
    let c = ctx.choice(TunedOp::DenseBwd, fout);
    let t0 = ctx.prof.start();
    gemm::sgemm_atb(n, fout, fin, x, dout, dw, ctx.threads, c.isa);
    simd::col_sum(c.isa, db, dout, fout);
    gemm::transpose(wgt, fin, fout, &mut ctx.scratch.b);
    gemm::sgemm(n, fin, fout, dout, &ctx.scratch.b, Init::Zero, dx, ctx.threads, c.isa);
    ctx.prof.record(t0, || OpRecord {
        op: TracedOp::DenseBwd,
        variant: Some((c.isa, c.lowering)),
        width: fout as u32,
        shape: format!("b{n} {fin}->{fout}"),
        elems_read: (x.len() + wgt.len() + dout.len()) as u64,
        elems_written: (dw.len() + db.len() + dx.len()) as u64,
        flops: (6 * n * fin * fout) as u64,
    });
}

/// ReLU; overwrites `out` (the backward masks on this output).
pub fn relu(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v.max(0.0);
    }
}

/// ReLU backward in place: zero where the *output* activation is <= 0
/// (the jax.nn.relu convention: zero subgradient at 0).
pub fn relu_bwd_inplace(act: &[f32], da: &mut [f32]) {
    for (d, &a) in da.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// 2x2 stride-2 max pool (h, w even). `idx` records the winning position
/// (0..4, first max in (di, dj) scan order) for the backward pass.
#[allow(clippy::too_many_arguments)]
pub fn max_pool(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
    idx: &mut [u8],
) {
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), n * oh * ow * c);
    for ni in 0..n {
        for oi in 0..oh {
            for oj in 0..ow {
                let obase = ((ni * oh + oi) * ow + oj) * c;
                for ci in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_k = 0u8;
                    for (k, (di, dj)) in
                        [(0, 0), (0, 1), (1, 0), (1, 1)].into_iter().enumerate()
                    {
                        let v = x[((ni * h + 2 * oi + di) * w + 2 * oj + dj) * c + ci];
                        if v > best {
                            best = v;
                            best_k = k as u8;
                        }
                    }
                    out[obase + ci] = best;
                    idx[obase + ci] = best_k;
                }
            }
        }
    }
}

/// Max-pool backward: routes each output gradient to the recorded winner.
/// Overwrites `dx`.
#[allow(clippy::too_many_arguments)]
pub fn max_pool_bwd(
    dout: &[f32],
    idx: &[u8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    dx: &mut [f32],
) {
    dx.fill(0.0);
    let (oh, ow) = (h / 2, w / 2);
    for ni in 0..n {
        for oi in 0..oh {
            for oj in 0..ow {
                let obase = ((ni * oh + oi) * ow + oj) * c;
                for ci in 0..c {
                    let k = idx[obase + ci] as usize;
                    let (di, dj) = (k / 2, k % 2);
                    dx[((ni * h + 2 * oi + di) * w + 2 * oj + dj) * c + ci] += dout[obase + ci];
                }
            }
        }
    }
}

/// Batch-statistics normalization over (N, H, W) per channel (layers.py
/// `batch_norm`, eps 1e-5). Writes `out`, and caches `xhat` (normalized
/// input) and per-channel `ivar` = rsqrt(var + eps) for the backward.
#[allow(clippy::too_many_arguments)]
pub fn batch_norm(
    x: &[f32],
    m: usize,
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    out: &mut [f32],
    xhat: &mut [f32],
    ivar: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * c);
    let mut sum = vec![0.0f64; c];
    for row in x.chunks_exact(c) {
        for (s, &v) in sum.iter_mut().zip(row) {
            *s += v as f64;
        }
    }
    let mean: Vec<f32> = sum.iter().map(|s| (s / m as f64) as f32).collect();
    let mut var = vec![0.0f64; c];
    for row in x.chunks_exact(c) {
        for ((s, &v), &mu) in var.iter_mut().zip(row).zip(&mean) {
            let d = (v - mu) as f64;
            *s += d * d;
        }
    }
    for (iv, v) in ivar.iter_mut().zip(&var) {
        *iv = (1.0 / (v / m as f64 + 1e-5).sqrt()) as f32;
    }
    for ((xrow, xh_row), orow) in x
        .chunks_exact(c)
        .zip(xhat.chunks_exact_mut(c))
        .zip(out.chunks_exact_mut(c))
    {
        for ci in 0..c {
            let xh = (xrow[ci] - mean[ci]) * ivar[ci];
            xh_row[ci] = xh;
            orow[ci] = gamma[ci] * xh + beta[ci];
        }
    }
}

/// Batch-norm backward (the exact derivative of [`batch_norm`] through
/// the batch statistics):
/// `dx = ivar/M * (M*dxhat - sum(dxhat) - xhat * sum(dxhat * xhat))`,
/// `dgamma = sum(dout * xhat)`, `dbeta = sum(dout)`.
/// Accumulates `dgamma`/`dbeta`; overwrites `dx` (may alias `dout` — it
/// does not, callers pass distinct buffers).
#[allow(clippy::too_many_arguments)]
pub fn batch_norm_bwd(
    dout: &[f32],
    xhat: &[f32],
    ivar: &[f32],
    gamma: &[f32],
    m: usize,
    c: usize,
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let mut s1 = vec![0.0f64; c]; // sum dxhat
    let mut s2 = vec![0.0f64; c]; // sum dxhat * xhat
    let mut sg = vec![0.0f64; c]; // sum dout * xhat
    let mut sb = vec![0.0f64; c]; // sum dout
    for (drow, xh_row) in dout.chunks_exact(c).zip(xhat.chunks_exact(c)) {
        for ci in 0..c {
            let d = drow[ci] as f64;
            let xh = xh_row[ci] as f64;
            let dxh = d * gamma[ci] as f64;
            s1[ci] += dxh;
            s2[ci] += dxh * xh;
            sg[ci] += d * xh;
            sb[ci] += d;
        }
    }
    for ci in 0..c {
        dgamma[ci] += sg[ci] as f32;
        dbeta[ci] += sb[ci] as f32;
    }
    let mf = m as f32;
    for ((drow, xh_row), dxrow) in dout
        .chunks_exact(c)
        .zip(xhat.chunks_exact(c))
        .zip(dx.chunks_exact_mut(c))
    {
        for ci in 0..c {
            let dxh = drow[ci] * gamma[ci];
            dxrow[ci] = (ivar[ci] / mf)
                * (mf * dxh - s1[ci] as f32 - xh_row[ci] * s2[ci] as f32);
        }
    }
}

/// Per-example softmax cross entropy: `per[n] = logsumexp(logits[n]) -
/// logits[n][y[n]]` (layers.py `softmax_xent`).
pub fn softmax_xent(logits: &[f32], labels: &[i32], n: usize, ncls: usize, per: &mut [f32]) {
    for ni in 0..n {
        let row = &logits[ni * ncls..][..ncls];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut s = 0.0f64;
        for &v in row {
            s += ((v - mx) as f64).exp();
        }
        let lse = (s.ln() as f32) + mx;
        per[ni] = lse - row[labels[ni] as usize];
    }
}

/// Backward: `dlogits[n] = (softmax(logits[n]) - onehot(y[n])) * dper[n]`.
#[allow(clippy::too_many_arguments)]
pub fn softmax_xent_bwd(
    logits: &[f32],
    labels: &[i32],
    n: usize,
    ncls: usize,
    dper: &[f32],
    dlogits: &mut [f32],
) {
    for ni in 0..n {
        let row = &logits[ni * ncls..][..ncls];
        let drow = &mut dlogits[ni * ncls..][..ncls];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut s = 0.0f64;
        for &v in row {
            s += ((v - mx) as f64).exp();
        }
        let inv = (1.0 / s) as f32;
        for (d, &v) in drow.iter_mut().zip(row) {
            *d = ((v - mx).exp() * inv) * dper[ni];
        }
        drow[labels[ni] as usize] -= dper[ni];
    }
}

/// Index of the first maximum (jnp.argmax tie convention).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel_recovers_input() {
        // center-tap identity: w[1,1,ci,co] = (ci == co)
        let (n, h, w, c) = (1, 4, 4, 2);
        let x: Vec<f32> = (0..n * h * w * c).map(|i| i as f32 * 0.1).collect();
        let mut wgt = vec![0.0f32; 9 * c * c];
        for ci in 0..c {
            // tap (di=1, dj=1) is flat index 4
            wgt[(4 * c + ci) * c + ci] = 1.0;
        }
        let mut out = vec![0.0f32; x.len()];
        let mut ctx = ExecCtx::serial();
        conv2d(&x, n, h, w, c, &wgt, c, &[0.0, 0.0], &mut out, &mut ctx);
        assert_eq!(out, x);
    }

    #[test]
    fn conv_bias_broadcasts() {
        let (n, h, w, cin, cout) = (1, 2, 2, 1, 3);
        let x = vec![0.0f32; n * h * w * cin];
        let wgt = vec![0.0f32; 9 * cin * cout];
        let mut out = vec![0.0f32; n * h * w * cout];
        let mut ctx = ExecCtx::serial();
        conv2d(&x, n, h, w, cin, &wgt, cout, &[1.0, 2.0, 3.0], &mut out, &mut ctx);
        assert_eq!(&out[..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&out[9..12], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn conv_same_padding_shrinks_border_sums() {
        // all-ones input and kernel: interior = 9*cin, corner = 4*cin
        let (n, h, w, cin, cout) = (1, 5, 5, 2, 1);
        let x = vec![1.0f32; n * h * w * cin];
        let wgt = vec![1.0f32; 9 * cin * cout];
        let mut out = vec![0.0f32; n * h * w * cout];
        let mut ctx = ExecCtx::serial();
        conv2d(&x, n, h, w, cin, &wgt, cout, &[0.0], &mut out, &mut ctx);
        assert_eq!(out[2 * 5 + 2], 18.0, "interior: 9 taps x 2 channels");
        assert_eq!(out[0], 8.0, "corner: 4 taps x 2 channels");
    }

    #[test]
    fn gemm_and_reference_paths_agree_through_the_ctx_switch() {
        // the FITQ_NATIVE_REFERENCE escape hatch flows through
        // `use_reference`; both paths must agree bitwise (the full
        // property sweep lives in tests/native_gemm.rs)
        let (n, h, w, cin, cout) = (2, 5, 4, 3, 6);
        let x: Vec<f32> = (0..n * h * w * cin).map(|i| (i as f32 * 0.37).sin()).collect();
        let wgt: Vec<f32> = (0..9 * cin * cout).map(|i| (i as f32 * 0.11).cos()).collect();
        let bias: Vec<f32> = (0..cout).map(|i| i as f32 * 0.25 - 0.5).collect();
        let mut a = vec![0.0f32; n * h * w * cout];
        let mut b = vec![0.0f32; n * h * w * cout];
        let mut ctx = ExecCtx::serial();
        conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut a, &mut ctx);
        ctx.use_reference = true;
        conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut b, &mut ctx);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn max_pool_picks_first_max_and_routes_back() {
        let (n, h, w, c) = (1, 2, 2, 1);
        let x = vec![3.0f32, 7.0, 7.0, 1.0];
        let mut out = vec![0.0f32; 1];
        let mut idx = vec![0u8; 1];
        max_pool(&x, n, h, w, c, &mut out, &mut idx);
        assert_eq!(out[0], 7.0);
        assert_eq!(idx[0], 1, "first max in scan order");
        let mut dx = vec![0.0f32; 4];
        max_pool_bwd(&[2.0], &idx, n, h, w, c, &mut dx);
        assert_eq!(dx, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn batch_norm_normalizes_and_scales() {
        let (m, c) = (8, 2);
        let x: Vec<f32> = (0..m * c).map(|i| (i % 5) as f32 - 1.0).collect();
        let mut out = vec![0.0f32; m * c];
        let mut xhat = vec![0.0f32; m * c];
        let mut ivar = vec![0.0f32; c];
        batch_norm(&x, m, c, &[2.0, 1.0], &[0.5, 0.0], &mut out, &mut xhat, &mut ivar);
        for ci in 0..c {
            let mean: f32 = (0..m).map(|i| xhat[i * c + ci]).sum::<f32>() / m as f32;
            let var: f32 = (0..m).map(|i| xhat[i * c + ci].powi(2)).sum::<f32>() / m as f32;
            assert!(mean.abs() < 1e-5, "xhat mean ~ 0, got {mean}");
            assert!((var - 1.0).abs() < 1e-3, "xhat var ~ 1, got {var}");
        }
        // out = gamma * xhat + beta
        assert!((out[0] - (2.0 * xhat[0] + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn softmax_xent_matches_closed_form() {
        // two logits, label 0: loss = ln(1 + e^(b-a))
        let logits = vec![1.0f32, 3.0];
        let mut per = vec![0.0f32];
        softmax_xent(&logits, &[0], 1, 2, &mut per);
        assert!((per[0] - (1.0 + (2.0f32).exp()).ln()).abs() < 1e-6);
        // gradient sums to zero per example (softmax - onehot)
        let mut dl = vec![0.0f32; 2];
        softmax_xent_bwd(&logits, &[0], 1, 2, &[1.0], &mut dl);
        assert!((dl[0] + dl[1]).abs() < 1e-6);
        assert!(dl[0] < 0.0 && dl[1] > 0.0);
    }

    #[test]
    fn relu_and_mask() {
        let x = vec![-1.0f32, 0.0, 2.0];
        let mut a = vec![0.0f32; 3];
        relu(&x, &mut a);
        assert_eq!(a, vec![0.0, 0.0, 2.0]);
        let mut da = vec![1.0f32, 1.0, 1.0];
        relu_bwd_inplace(&a, &mut da);
        assert_eq!(da, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
