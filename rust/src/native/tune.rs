//! Per-host kernel autotuner: measures every (op, shape-class,
//! kernel-variant) triple once per host and persists the winner table
//! through the content-addressed artifact cache.
//!
//! PR 5 hand-pinned the direct-vs-im2col routing from measurements on
//! one box; this module replaces that with evidence gathered where the
//! code actually runs. Because every variant is bit-identical to
//! `ops::reference` (the `native::simd` contract), routing is *purely*
//! a wall-clock decision: the tuner table, the host it came from, and
//! `FITQ_NATIVE_KERNEL` must never enter a pipeline stage digest —
//! `tests/kernel_dispatch.rs` pins that exclusion.
//!
//! # Persistence and coordination
//!
//! The table is stored under artifact kind `"tuner"` keyed by
//! [`host_fingerprint`] (arch + detected-ISA bitmask + core count +
//! intra-op thread budget + tuner schema version — retune when any of
//! them changes, share otherwise). The budget is part of the key
//! because it is part of the *measurement*: a table tuned serially can
//! route differently than one tuned at the `threads` the `ExecCtx`
//! actually runs under, so budgets never share (or overwrite) each
//! other's tables. Concurrent `--jobs` workers reuse the PR 7 lease layer:
//! the first resolver claims the lease and tunes; peers poll and adopt
//! the published table; a resolver that loses the race to a dead lease
//! or hits the wait deadline tunes privately without publishing
//! ([`Resolution::TunedUnpersisted`]) — tuning is an accelerator, never
//! a correctness dependency, so every failure path degrades to
//! "measure again locally". The `tuner.publish.fail` fault site drills
//! the crash between tuning and publishing: the lease must release and
//! the next resolver must retune and publish cleanly.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::pipeline::cache::{ArtifactCache, Claim};
use crate::coordinator::pipeline::codec::{ByteReader, ByteWriter};
use crate::coordinator::pipeline::digest::{Digest, Hasher};
use crate::coordinator::pipeline::fault::{self, site};
use crate::coordinator::pipeline::stages::results_root_from_env;
use crate::tensor::Pcg32;

use super::gemm::{self, Init};
use super::simd::{self, Isa};

/// Artifact kind of persisted route tables.
pub const TUNER_KIND: &str = "tuner";

/// Payload schema version of [`encode`]/[`decode`]. Also folded into
/// [`host_fingerprint`], so bumping it retunes rather than misparses.
pub const TUNER_SCHEMA: u32 = 1;

/// How the dispatch layer selects kernel variants, parsed fail-closed
/// from `FITQ_NATIVE_KERNEL` (unset = `Auto`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Route per (op, shape-class) by the host's autotuned table,
    /// resolved lazily on first kernel dispatch.
    Auto,
    /// Force one ISA everywhere, with each op's static default
    /// lowering — the escape hatch and the A/B leg of benches and CI.
    Forced(Isa),
}

impl Default for KernelMode {
    /// Contexts built without consulting the environment (op-level
    /// tests, oracles) force the best available ISA — deterministic and
    /// IO-free, no tuner resolution.
    fn default() -> KernelMode {
        KernelMode::Forced(Isa::best())
    }
}

impl KernelMode {
    /// Parse a `FITQ_NATIVE_KERNEL` value. Fail-closed: unknown names
    /// and ISAs this host lacks are hard errors, not silent fallbacks.
    pub fn parse(s: &str) -> Result<KernelMode> {
        if s == "auto" {
            return Ok(KernelMode::Auto);
        }
        let Some(isa) = Isa::parse(s) else {
            bail!("unknown FITQ_NATIVE_KERNEL value {s:?} (want auto, scalar, sse2, avx2 or neon)");
        };
        if !isa.available() {
            let have: Vec<&str> = Isa::detected().iter().map(|i| i.name()).collect();
            bail!(
                "FITQ_NATIVE_KERNEL={s}: ISA not available on this host (detected: {})",
                have.join(", ")
            );
        }
        Ok(KernelMode::Forced(isa))
    }

    /// Read `FITQ_NATIVE_KERNEL` from the environment; unset = `Auto`.
    pub fn from_env() -> Result<KernelMode> {
        match std::env::var("FITQ_NATIVE_KERNEL") {
            Ok(v) => KernelMode::parse(v.trim()),
            Err(std::env::VarError::NotPresent) => Ok(KernelMode::Auto),
            Err(e) => bail!("FITQ_NATIVE_KERNEL: {e}"),
        }
    }
}

/// The ops the tuner routes. Discriminants are persisted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TunedOp {
    /// 3x3 conv forward (vector axis: `c_out`).
    ConvFwd = 0,
    /// Conv backward-by-weights (vector axis: `c_out`).
    ConvBwdW = 1,
    /// Conv backward-by-input (vector axis: `c_in` — the `W^T` GEMM and
    /// col2im both stream `c_in` lanes).
    ConvBwdX = 2,
    /// Dense forward (vector axis: `f_out`).
    DenseFwd = 3,
    /// Dense backward (vector axis: `f_out`).
    DenseBwd = 4,
}

/// Number of tuned ops (first axis of the route table).
pub const N_OPS: usize = 5;

/// All tuned ops, in discriminant order.
pub const OPS: [TunedOp; N_OPS] = [
    TunedOp::ConvFwd,
    TunedOp::ConvBwdW,
    TunedOp::ConvBwdX,
    TunedOp::DenseFwd,
    TunedOp::DenseBwd,
];

impl TunedOp {
    /// Stable name (CLI output, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            TunedOp::ConvFwd => "conv_fwd",
            TunedOp::ConvBwdW => "conv_bwd_w",
            TunedOp::ConvBwdX => "conv_bwd_x",
            TunedOp::DenseFwd => "dense_fwd",
            TunedOp::DenseBwd => "dense_bwd",
        }
    }

    /// Inverse of `op as u8`; `None` on an unknown tag (fail-closed
    /// decoding — also reused by the `optrace` codec).
    pub fn from_u8(v: u8) -> Option<TunedOp> {
        OPS.into_iter().find(|op| *op as u8 == v)
    }
}

/// Which algorithm an op runs (orthogonal to the ISA). Discriminants
/// are persisted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lowering {
    /// The direct loop-nest kernel (`conv2d_direct` /
    /// `conv2d_bwd_w_direct`).
    Direct = 0,
    /// im2col materialization + GEMM (`ops::conv2d_im2col` /
    /// `ops::conv2d_bwd_w_im2col`).
    Im2col = 1,
    /// The op is inherently a GEMM (dense, conv backward-by-input).
    Gemm = 2,
}

impl Lowering {
    /// Stable name (CLI output, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Lowering::Direct => "direct",
            Lowering::Im2col => "im2col",
            Lowering::Gemm => "gemm",
        }
    }

    /// Inverse of `lowering as u8`; `None` on an unknown tag (fail-closed
    /// decoding — also reused by the `optrace` codec).
    pub fn from_u8(v: u8) -> Option<Lowering> {
        [Lowering::Direct, Lowering::Im2col, Lowering::Gemm]
            .into_iter()
            .find(|l| *l as u8 == v)
    }
}

/// The lowering an op runs when no tuned table applies
/// ([`KernelMode::Forced`]) — the PR 5 hand-pinned routing, kept as the
/// deterministic fallback.
pub fn static_lowering(op: TunedOp) -> Lowering {
    match op {
        TunedOp::ConvFwd | TunedOp::ConvBwdW => Lowering::Direct,
        _ => Lowering::Gemm,
    }
}

fn candidate_lowerings(op: TunedOp) -> &'static [Lowering] {
    match op {
        TunedOp::ConvFwd | TunedOp::ConvBwdW => &[Lowering::Direct, Lowering::Im2col],
        _ => &[Lowering::Gemm],
    }
}

/// Number of vector-axis width classes (second axis of the table).
pub const N_CLASSES: usize = 5;

/// Representative width micro-benchmarked for each class.
pub const CLASS_WIDTHS: [usize; N_CLASSES] = [4, 8, 16, 32, 64];

/// Map an op's vector-axis width (`c_out`, `c_in` or `f_out`) to its
/// width class. Classes exist because the winner genuinely flips with
/// width: on the measurement host, AVX2 wins wide convs but loses to
/// SSE2 at `c_out = 8` (8-lane vectors never fill; see
/// BENCH_kernels.json).
pub fn shape_class(width: usize) -> usize {
    match width {
        0..=4 => 0,
        5..=8 => 1,
        9..=16 => 2,
        17..=32 => 3,
        _ => 4,
    }
}

/// One routing decision: which ISA runs which lowering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Choice {
    pub isa: Isa,
    pub lowering: Lowering,
}

/// One micro-benchmark sample (kept in the table for `fitq tune`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub op: TunedOp,
    pub class: usize,
    pub isa: Isa,
    pub lowering: Lowering,
    /// Nominal-FLOP throughput, min-of-reps (comparable within one
    /// (op, class) cell; not across ops).
    pub gflops: f64,
}

/// The per-host winner table: one [`Choice`] per (op, width-class),
/// plus the measurements it was derived from.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteTable {
    choices: [[Choice; N_CLASSES]; N_OPS],
    pub measurements: Vec<Measurement>,
}

impl RouteTable {
    /// A table that routes every cell to `isa` with the op's static
    /// lowering (the untuned baseline the tuner refines).
    pub fn static_for(isa: Isa) -> RouteTable {
        let mut choices = [[Choice { isa, lowering: Lowering::Gemm }; N_CLASSES]; N_OPS];
        for op in OPS {
            for cell in &mut choices[op as usize] {
                cell.lowering = static_lowering(op);
            }
        }
        RouteTable { choices, measurements: Vec::new() }
    }

    /// The tuned choice for `op` at vector-axis width `width`.
    pub fn choice(&self, op: TunedOp, width: usize) -> Choice {
        self.choices[op as usize][shape_class(width)]
    }
}

/// Host identity the table is keyed by: retune when the architecture,
/// the detected ISA set, the core count, the intra-op thread budget, or
/// the tuner schema changes; reuse otherwise. The budget is hashed
/// because the micro-benchmarks run *at* it — a serial table and a
/// 4-thread table are different measurements and must not collide.
/// Deliberately *not* part of any stage digest.
pub fn host_fingerprint(threads: usize) -> Digest {
    let mut h = Hasher::new();
    h.str("tuner/v1");
    h.str(std::env::consts::ARCH);
    let mut mask = 0u64;
    for isa in Isa::detected() {
        mask |= 1 << (isa as u64);
    }
    h.u64(mask);
    h.usize(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    h.usize(threads.max(1));
    h.u64(TUNER_SCHEMA as u64);
    h.finish()
}

/// Serialize a table (artifact payload).
pub fn encode(table: &RouteTable) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(N_OPS as u32);
    w.u32(N_CLASSES as u32);
    for op in 0..N_OPS {
        for class in 0..N_CLASSES {
            let c = table.choices[op][class];
            w.u8(c.isa as u8);
            w.u8(c.lowering as u8);
        }
    }
    w.u64(table.measurements.len() as u64);
    for m in &table.measurements {
        w.u8(m.op as u8);
        w.u8(m.class as u8);
        w.u8(m.isa as u8);
        w.u8(m.lowering as u8);
        w.f64(m.gflops);
    }
    w.into_bytes()
}

/// Deserialize a table, refusing shape skew and (defensively) ISAs the
/// current host cannot run — the fingerprint key should make that
/// impossible, but a bad route must fail closed, not crash in dispatch.
pub fn decode(bytes: &[u8]) -> Result<RouteTable> {
    let mut r = ByteReader::new(bytes);
    if r.u32()? as usize != N_OPS || r.u32()? as usize != N_CLASSES {
        bail!("tuner table has a different op/class grid than this build");
    }
    fn read_choice(r: &mut ByteReader) -> Result<Choice> {
        let isa = Isa::from_u8(r.u8()?).ok_or_else(|| anyhow::anyhow!("bad tuner isa"))?;
        let lowering =
            Lowering::from_u8(r.u8()?).ok_or_else(|| anyhow::anyhow!("bad tuner lowering"))?;
        if !isa.available() {
            bail!("tuner table routes to {isa}, unavailable on this host");
        }
        Ok(Choice { isa, lowering })
    }
    fn read_meas(r: &mut ByteReader) -> Result<Measurement> {
        let op = TunedOp::from_u8(r.u8()?).ok_or_else(|| anyhow::anyhow!("bad tuner op"))?;
        let class = r.u8()? as usize;
        let isa = Isa::from_u8(r.u8()?).ok_or_else(|| anyhow::anyhow!("bad tuner isa"))?;
        let lowering =
            Lowering::from_u8(r.u8()?).ok_or_else(|| anyhow::anyhow!("bad tuner lowering"))?;
        let gflops = r.f64()?;
        Ok(Measurement { op, class, isa, lowering, gflops })
    }
    let mut table = RouteTable::static_for(Isa::Scalar);
    for op in 0..N_OPS {
        for class in 0..N_CLASSES {
            table.choices[op][class] = read_choice(&mut r)?;
        }
    }
    let n = r.u64()? as usize;
    table.measurements = (0..n).map(|_| read_meas(&mut r)).collect::<Result<_>>()?;
    r.done()?;
    Ok(table)
}

/// How [`resolve_at`] obtained its table — lets callers (and the
/// exactly-once test) distinguish the lease outcomes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// A previously published table was loaded.
    CacheHit,
    /// This process won the lease, tuned, and published.
    TunedPublished,
    /// A peer tuned while this process polled; the peer's table was
    /// adopted.
    PeerPublished,
    /// Tuned locally without publishing (cache unusable, injected
    /// publish fault, or the lease wait deadline expired).
    TunedUnpersisted,
}

impl Resolution {
    /// Stable name (CLI output).
    pub fn name(self) -> &'static str {
        match self {
            Resolution::CacheHit => "cache hit",
            Resolution::TunedPublished => "tuned + published",
            Resolution::PeerPublished => "published by a peer",
            Resolution::TunedUnpersisted => "tuned (unpersisted)",
        }
    }
}

fn load_table(cache: &ArtifactCache, key: &Digest) -> Option<RouteTable> {
    cache.load(TUNER_KIND, TUNER_SCHEMA, key).and_then(|b| decode(&b).ok())
}

/// Resolve this host's route table through `cache`: load if published,
/// otherwise lease-coordinate so concurrent workers tune exactly once.
/// Never fails — every degraded path returns a locally tuned table.
pub fn resolve_at(cache: &ArtifactCache, threads: usize) -> (RouteTable, Resolution) {
    let key = host_fingerprint(threads);
    if let Some(table) = load_table(cache, &key) {
        return (table, Resolution::CacheHit);
    }
    let cfg = cache.lease_config();
    let deadline = Instant::now() + cfg.max_wait;
    loop {
        match cache.try_claim(TUNER_KIND, &key) {
            Ok(Claim::Won(guard)) => {
                let table = tune(threads);
                if fault::fires(site::TUNER_PUBLISH_FAIL) {
                    // injected crash between tuning and publishing: the
                    // guard drop releases the lease, nothing is stored,
                    // and the next resolver retunes cleanly
                    drop(guard);
                    return (table, Resolution::TunedUnpersisted);
                }
                let published =
                    cache.store(TUNER_KIND, TUNER_SCHEMA, &key, &encode(&table)).is_ok();
                guard.release();
                let how = if published {
                    Resolution::TunedPublished
                } else {
                    Resolution::TunedUnpersisted
                };
                return (table, how);
            }
            Ok(Claim::Busy { .. }) => {
                std::thread::sleep(cfg.poll);
                if let Some(table) = load_table(cache, &key) {
                    return (table, Resolution::PeerPublished);
                }
                if Instant::now() >= deadline {
                    return (tune(threads), Resolution::TunedUnpersisted);
                }
            }
            Err(_) => return (tune(threads), Resolution::TunedUnpersisted),
        }
    }
}

/// Process-wide lazy resolution against the default results root
/// (`FITQ_RESULTS` or `./results`) — what `KernelMode::Auto` dispatch
/// uses. Resolved once per *thread budget* per process (a `BTreeMap`
/// keyed by the budget, not a single `OnceLock`): a serial worker and a
/// 4-thread dispatcher in one process get the tables tuned at their own
/// budgets instead of whichever resolved first.
pub fn resolve(threads: usize) -> Arc<RouteTable> {
    static TABLES: OnceLock<Mutex<BTreeMap<usize, Arc<RouteTable>>>> = OnceLock::new();
    let threads = threads.max(1);
    let tables = TABLES.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut map = tables.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(table) = map.get(&threads) {
        return table.clone();
    }
    let table = match ArtifactCache::new(results_root_from_env().join("cache")) {
        Ok(cache) => resolve_at(&cache, threads).0,
        Err(_) => tune(threads),
    };
    let table = Arc::new(table);
    map.insert(threads, table.clone());
    table
}

/// Micro-benchmark every (op, class, lowering, ISA) candidate and keep
/// the winners. Problems are synthetic but shaped like the study nets
/// (post-ReLU zero density included, so the skip paths are priced in);
/// timing is min-of-3 on purpose — minimum, not mean, rejects scheduler
/// noise on loaded hosts.
pub fn tune(threads: usize) -> RouteTable {
    let mut table = RouteTable::static_for(Isa::best());
    let isas = Isa::detected();
    for op in OPS {
        for (class, &width) in CLASS_WIDTHS.iter().enumerate() {
            let mut best: Option<(f64, Choice)> = None;
            for &lowering in candidate_lowerings(op) {
                for &isa in &isas {
                    let gflops = bench_variant(op, lowering, isa, width, threads);
                    table.measurements.push(Measurement { op, class, isa, lowering, gflops });
                    if best.is_none_or(|(g, _)| gflops > g) {
                        best = Some((gflops, Choice { isa, lowering }));
                    }
                }
            }
            if let Some((_, choice)) = best {
                table.choices[op as usize][class] = choice;
            }
        }
    }
    table
}

fn sparse_randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, 53);
    // ~half exact zeros: the post-ReLU density the zero-skip paths see
    (0..n).map(|_| rng.normal().max(0.0)).collect()
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, 59);
    (0..n).map(|_| rng.normal()).collect()
}

const REPS: usize = 3;

fn min_time(mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Time one candidate on a synthetic problem whose vector axis is
/// `width`; returns nominal GFLOP/s. The batch/row dimension scales
/// with the thread budget so `gemm::effective_threads`' panel and
/// work-per-thread caps actually let the budget engage — a serial-sized
/// problem would silently measure every budget at 1 thread, which is
/// exactly the bug this scaling fixes (`threads = 1` keeps the
/// original serial problem sizes).
fn bench_variant(op: TunedOp, lowering: Lowering, isa: Isa, width: usize, threads: usize) -> f64 {
    match op {
        TunedOp::ConvFwd | TunedOp::ConvBwdW | TunedOp::ConvBwdX => {
            // ConvFwd/ConvBwdW vectorize over c_out; ConvBwdX over c_in.
            let n = if threads > 1 { 4 * threads } else { 2 };
            let (h, w) = (12usize, 12);
            let (cin, cout) =
                if op == TunedOp::ConvBwdX { (width, 8) } else { (8, width) };
            let x = sparse_randv(n * h * w * cin, 7 + width as u64);
            let wgt = randv(9 * cin * cout, 11 + width as u64);
            let bias = randv(cout, 13);
            let dout = sparse_randv(n * h * w * cout, 17 + width as u64);
            let flops = (2 * n * h * w * 9 * cin * cout) as f64;
            let mut scratch = gemm::Scratch::default();
            let secs = match (op, lowering) {
                (TunedOp::ConvFwd, Lowering::Im2col) => {
                    let mut out = vec![0.0f32; n * h * w * cout];
                    min_time(|| {
                        gemm::im2col3x3(&x, n, h, w, cin, &mut scratch.a);
                        let m = n * h * w;
                        gemm::sgemm(
                            m,
                            cout,
                            9 * cin,
                            &scratch.a,
                            &wgt,
                            Init::Bias(&bias),
                            &mut out,
                            threads,
                            isa,
                        );
                    })
                }
                (TunedOp::ConvFwd, _) => {
                    let mut out = vec![0.0f32; n * h * w * cout];
                    min_time(|| {
                        gemm::conv2d_direct(
                            &x, n, h, w, cin, &wgt, cout, &bias, &mut out, threads, isa,
                        );
                    })
                }
                (TunedOp::ConvBwdW, Lowering::Im2col) => {
                    let mut dw = vec![0.0f32; 9 * cin * cout];
                    let mut db = vec![0.0f32; cout];
                    min_time(|| {
                        dw.fill(0.0);
                        db.fill(0.0);
                        gemm::im2col3x3(&x, n, h, w, cin, &mut scratch.a);
                        let m = n * h * w;
                        gemm::sgemm_atb(
                            m, cout, 9 * cin, &scratch.a, &dout, &mut dw, threads, isa,
                        );
                        simd::col_sum(isa, &mut db, &dout, cout);
                    })
                }
                (TunedOp::ConvBwdW, _) => {
                    let mut dw = vec![0.0f32; 9 * cin * cout];
                    let mut db = vec![0.0f32; cout];
                    min_time(|| {
                        dw.fill(0.0);
                        db.fill(0.0);
                        gemm::conv2d_bwd_w_direct(
                            &x, n, h, w, cin, &dout, cout, &mut dw, &mut db, threads, isa,
                        );
                    })
                }
                (TunedOp::ConvBwdX, _) => {
                    let mut dx = vec![0.0f32; n * h * w * cin];
                    let m = n * h * w;
                    let k = 9 * cin;
                    min_time(|| {
                        gemm::transpose(&wgt, k, cout, &mut scratch.b);
                        scratch.a.clear();
                        scratch.a.resize(m * k, 0.0);
                        gemm::sgemm(
                            m,
                            k,
                            cout,
                            &dout,
                            &scratch.b,
                            Init::Zero,
                            &mut scratch.a,
                            threads,
                            isa,
                        );
                        gemm::col2im3x3(&scratch.a, n, h, w, cin, &mut dx, threads, isa);
                    })
                }
                _ => unreachable!("conv op with dense lowering"),
            };
            flops / secs / 1e9
        }
        TunedOp::DenseFwd | TunedOp::DenseBwd => {
            let (rows, fin, fout) = (64 * threads.max(1), 128, width);
            let x = sparse_randv(rows * fin, 19 + width as u64);
            let wgt = randv(fin * fout, 23 + width as u64);
            let bias = randv(fout, 29);
            let dout = randv(rows * fout, 31 + width as u64);
            let mut scratch = gemm::Scratch::default();
            let secs = if op == TunedOp::DenseFwd {
                let mut out = vec![0.0f32; rows * fout];
                min_time(|| {
                    gemm::sgemm(
                        rows,
                        fout,
                        fin,
                        &x,
                        &wgt,
                        Init::Bias(&bias),
                        &mut out,
                        threads,
                        isa,
                    );
                })
            } else {
                let mut dw = vec![0.0f32; fin * fout];
                let mut db = vec![0.0f32; fout];
                let mut dx = vec![0.0f32; rows * fin];
                min_time(|| {
                    dw.fill(0.0);
                    db.fill(0.0);
                    gemm::sgemm_atb(rows, fout, fin, &x, &dout, &mut dw, threads, isa);
                    simd::col_sum(isa, &mut db, &dout, fout);
                    gemm::transpose(&wgt, fin, fout, &mut scratch.b);
                    gemm::sgemm(
                        rows,
                        fin,
                        fout,
                        &dout,
                        &scratch.b,
                        Init::Zero,
                        &mut dx,
                        threads,
                        isa,
                    );
                })
            };
            let mults = if op == TunedOp::DenseFwd { 2.0 } else { 6.0 };
            mults * (rows * fin * fout) as f64 / secs / 1e9
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_round_trips_and_rejects_garbage() {
        let mut table = RouteTable::static_for(Isa::Scalar);
        table.measurements.push(Measurement {
            op: TunedOp::DenseBwd,
            class: 3,
            isa: Isa::Scalar,
            lowering: Lowering::Gemm,
            gflops: 3.25,
        });
        let bytes = encode(&table);
        assert_eq!(decode(&bytes).unwrap(), table);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err(), "truncation");
        assert!(decode(&[]).is_err(), "empty");
        let mut skew = bytes.clone();
        skew[0] ^= 0xff; // N_OPS field
        assert!(decode(&skew).is_err(), "grid skew");
    }

    #[test]
    fn static_table_uses_pinned_lowerings() {
        let t = RouteTable::static_for(Isa::Scalar);
        assert_eq!(t.choice(TunedOp::ConvFwd, 16).lowering, Lowering::Direct);
        assert_eq!(t.choice(TunedOp::ConvBwdW, 16).lowering, Lowering::Direct);
        assert_eq!(t.choice(TunedOp::ConvBwdX, 16).lowering, Lowering::Gemm);
        assert_eq!(t.choice(TunedOp::DenseFwd, 16).lowering, Lowering::Gemm);
        assert_eq!(t.choice(TunedOp::DenseBwd, 16).lowering, Lowering::Gemm);
    }

    #[test]
    fn shape_classes_partition_widths() {
        assert_eq!(shape_class(1), 0);
        assert_eq!(shape_class(4), 0);
        assert_eq!(shape_class(8), 1);
        assert_eq!(shape_class(10), 2);
        assert_eq!(shape_class(32), 3);
        assert_eq!(shape_class(1000), 4);
        for (class, &w) in CLASS_WIDTHS.iter().enumerate() {
            assert_eq!(shape_class(w), class, "representative width maps to its class");
        }
    }

    #[test]
    fn fingerprint_is_stable_within_a_process() {
        assert_eq!(host_fingerprint(1), host_fingerprint(1));
    }

    #[test]
    fn fingerprint_separates_thread_budgets() {
        assert_ne!(
            host_fingerprint(1),
            host_fingerprint(4),
            "thread budget must be part of the persisted-table key"
        );
        // 0 is clamped to the serial budget, not a distinct key.
        assert_eq!(host_fingerprint(0), host_fingerprint(1));
    }
}
