//! The taped CNN forward/backward of the native interpreter.
//!
//! One [`forward`] supports the same three orthogonal modes as the L2
//! `Model.apply` (model.py): the plain FP pass, the fake-quant (QAT)
//! pass, and the activation-tap pass — so the EF trace's eps-trick
//! gradients fall out of the same backward as the training gradients.
//! The tape stores exactly what the backward needs; [`backward`] returns
//! the flat parameter gradient plus the gradient at every activation
//! site (the post-relu tensor, i.e. the `eps_l` insertion point of
//! fisher.py — for a zero eps, `dL/d eps_l = dL/d a_l`).
//!
//! Straight-through estimators need no backward code: quantization nodes
//! are simply skipped on the way back (see `native::quant`).

use super::model::Plan;
use super::ops::ExecCtx;
use super::trace::{Layer, TracedOp};
use super::{ops, quant};

/// Borrowed runtime quantization configuration (QAT mode).
#[derive(Debug, Clone, Copy)]
pub struct QuantArgs<'a> {
    pub bits_w: &'a [f32],
    pub bits_a: &'a [f32],
    pub act_lo: &'a [f32],
    pub act_hi: &'a [f32],
}

/// Per-conv-layer tape record.
struct ConvTape {
    /// Layer input (post previous pool), (B, h, w, c_in).
    xin: Vec<f32>,
    /// The kernel actually convolved (fake-quantized under QAT).
    wq: Vec<f32>,
    /// BN cache: normalized input + per-channel rsqrt(var + eps).
    xhat: Vec<f32>,
    ivar: Vec<f32>,
    /// Post-relu activation (the eps site), (B, h, w, c_out).
    act: Vec<f32>,
    /// Pool winner indices (pooled layers).
    pool_idx: Vec<u8>,
}

/// Everything [`backward`] needs from one forward pass.
pub struct Tape {
    batch: usize,
    convs: Vec<ConvTape>,
    /// Flattened features entering fc, (B, feat).
    feat: Vec<f32>,
    /// The fc weight actually applied (fake-quantized under QAT).
    fwq: Vec<f32>,
    pub logits: Vec<f32>,
}

impl Tape {
    /// Post-relu activation of conv layer `i` (the calibration tap).
    pub fn act(&self, i: usize) -> &[f32] {
        &self.convs[i].act
    }
}

/// Run the forward pass for a batch; `x` is (B, H, W, C) flattened.
/// `ctx` carries the GEMM scratch arena and the intra-op thread budget
/// (see `native::gemm`); outputs are bit-identical at every budget.
pub fn forward(
    plan: &Plan,
    params: &[f32],
    x: &[f32],
    batch: usize,
    q: Option<QuantArgs>,
    ctx: &mut ExecCtx,
) -> Tape {
    debug_assert_eq!(x.len(), batch * plan.sample_len());
    debug_assert_eq!(params.len(), plan.n_params);
    let mut convs = Vec::with_capacity(plan.convs.len());
    let mut cur: Vec<f32> = x.to_vec();
    for (i, layer) in plan.convs.iter().enumerate() {
        ctx.prof.set_layer(Layer::Conv(i as u8));
        let (h, w, cin, cout) = (layer.h, layer.w, layer.c_in, layer.c_out);
        let xin = cur;
        let wsize = layer.w_size();
        let raw_w = &params[layer.w_off..layer.w_off + wsize];
        let wq: Vec<f32> = match q {
            Some(qa) => {
                let mut buf = vec![0.0f32; wsize];
                quant::fake_quant_minmax(raw_w, qa.bits_w[i], &mut buf);
                buf
            }
            None => raw_w.to_vec(),
        };
        let bias = &params[layer.b_off..layer.b_off + cout];
        let mut z = vec![0.0f32; batch * h * w * cout];
        ops::conv2d(&xin, batch, h, w, cin, &wq, cout, bias, &mut z, ctx);
        let (mut xhat, mut ivar) = (Vec::new(), Vec::new());
        if let (Some(g_off), Some(b_off)) = (layer.gamma_off, layer.beta_off) {
            let gamma = &params[g_off..g_off + cout];
            let beta = &params[b_off..b_off + cout];
            let mut out = vec![0.0f32; z.len()];
            xhat = vec![0.0f32; z.len()];
            ivar = vec![0.0f32; cout];
            let t0 = ctx.prof.start();
            ops::batch_norm(&z, batch * h * w, cout, gamma, beta, &mut out, &mut xhat, &mut ivar);
            ctx.prof.record_untuned(
                t0,
                TracedOp::BatchNorm,
                z.len() + 2 * cout,
                out.len() + xhat.len() + cout,
                10 * batch * h * w * cout,
                || format!("b{batch} {h}x{w} c{cout}"),
            );
            z = out;
        }
        let mut act = vec![0.0f32; z.len()];
        let t0 = ctx.prof.start();
        ops::relu(&z, &mut act);
        ctx.prof.record_untuned(t0, TracedOp::Relu, z.len(), act.len(), act.len(), || {
            format!("b{batch} {h}x{w} c{cout}")
        });
        let aq = q.map(|qa| {
            let mut buf = vec![0.0f32; act.len()];
            quant::fake_quant(&act, qa.act_lo[i], qa.act_hi[i], qa.bits_a[i], &mut buf);
            buf
        });
        // the fake-quantized activation (QAT) feeds pool / the next layer
        // but is not needed by the backward (STE) — it stays local
        let post: &[f32] = aq.as_deref().unwrap_or(&act);
        let mut pool_idx = Vec::new();
        cur = if layer.pooled {
            let mut out = vec![0.0f32; batch * (h / 2) * (w / 2) * cout];
            pool_idx = vec![0u8; out.len()];
            let t0 = ctx.prof.start();
            ops::max_pool(post, batch, h, w, cout, &mut out, &mut pool_idx);
            ctx.prof.record_untuned(
                t0,
                TracedOp::MaxPool,
                post.len(),
                out.len(),
                4 * out.len(),
                || format!("b{batch} {h}x{w} c{cout}"),
            );
            out
        } else {
            post.to_vec()
        };
        convs.push(ConvTape { xin, wq, xhat, ivar, act, pool_idx });
    }
    let ncls = plan.spec.n_classes;
    let fc_w = &params[plan.fc_w_off..plan.fc_w_off + plan.feat * ncls];
    let fwq: Vec<f32> = match q {
        Some(qa) => {
            let mut buf = vec![0.0f32; fc_w.len()];
            quant::fake_quant_minmax(fc_w, qa.bits_w[plan.convs.len()], &mut buf);
            buf
        }
        None => fc_w.to_vec(),
    };
    let fc_b = &params[plan.fc_b_off..plan.fc_b_off + ncls];
    let mut logits = vec![0.0f32; batch * ncls];
    ctx.prof.set_layer(Layer::Fc);
    ops::dense(&cur, batch, plan.feat, &fwq, ncls, fc_b, &mut logits, ctx);
    Tape { batch, convs, feat: cur, fwq, logits }
}

/// Gradients of one backward pass.
pub struct Grads {
    /// d loss / d params over the full flat vector.
    pub flat: Vec<f32>,
    /// d loss / d (post-relu activation) per site — the eps-trick values.
    pub act: Vec<Vec<f32>>,
}

/// Backpropagate `dlogits` through the tape. STE convention: weight
/// gradients land on the *raw* parameter slots even when the forward
/// convolved fake-quantized copies.
pub fn backward(
    plan: &Plan,
    params: &[f32],
    tape: &Tape,
    dlogits: &[f32],
    ctx: &mut ExecCtx,
) -> Grads {
    let batch = tape.batch;
    let ncls = plan.spec.n_classes;
    let mut flat = vec![0.0f32; plan.n_params];
    let mut act_grads: Vec<Vec<f32>> = Vec::with_capacity(plan.convs.len());

    // fc layer
    let mut dfeat = vec![0.0f32; tape.feat.len()];
    ctx.prof.set_layer(Layer::Fc);
    {
        let (dw, rest) = flat[plan.fc_w_off..].split_at_mut(plan.feat * ncls);
        let db = &mut rest[..ncls];
        ops::dense_bwd(
            &tape.feat, &tape.fwq, batch, plan.feat, ncls, dlogits, dw, db, &mut dfeat, ctx,
        );
    }

    // conv stack, last to first
    let mut da = dfeat;
    for (i, layer) in plan.convs.iter().enumerate().rev() {
        ctx.prof.set_layer(Layer::Conv(i as u8));
        let t = &tape.convs[i];
        let (h, w, cin, cout) = (layer.h, layer.w, layer.c_in, layer.c_out);
        if layer.pooled {
            let mut dx = vec![0.0f32; batch * h * w * cout];
            let t0 = ctx.prof.start();
            ops::max_pool_bwd(&da, &t.pool_idx, batch, h, w, cout, &mut dx);
            ctx.prof.record_untuned(t0, TracedOp::MaxPoolBwd, da.len(), dx.len(), da.len(), || {
                format!("b{batch} {h}x{w} c{cout}")
            });
            da = dx;
        }
        // activation fake-quant is a straight-through node: `da` is now
        // the gradient at the post-relu site (the eps-trick gradient)
        act_grads.push(da.clone());
        let t0 = ctx.prof.start();
        ops::relu_bwd_inplace(&t.act, &mut da);
        ctx.prof.record_untuned(
            t0,
            TracedOp::ReluBwd,
            t.act.len() + da.len(),
            da.len(),
            da.len(),
            || format!("b{batch} {h}x{w} c{cout}"),
        );
        if let (Some(g_off), Some(b_off)) = (layer.gamma_off, layer.beta_off) {
            let gamma = params[g_off..g_off + cout].to_vec();
            let mut dx = vec![0.0f32; da.len()];
            {
                let (head, tail) = flat.split_at_mut(b_off);
                let dgamma = &mut head[g_off..g_off + cout];
                let dbeta = &mut tail[..cout];
                let t0 = ctx.prof.start();
                ops::batch_norm_bwd(
                    &da, &t.xhat, &t.ivar, &gamma, batch * h * w, cout, &mut dx, dgamma, dbeta,
                );
                ctx.prof.record_untuned(
                    t0,
                    TracedOp::BatchNormBwd,
                    da.len() + t.xhat.len() + 2 * cout,
                    dx.len() + 2 * cout,
                    12 * batch * h * w * cout,
                    || format!("b{batch} {h}x{w} c{cout}"),
                );
            }
            da = dx;
        }
        {
            let (dw, rest) = flat[layer.w_off..].split_at_mut(layer.w_size());
            let db = &mut rest[..cout];
            ops::conv2d_bwd_w(&t.xin, batch, h, w, cin, &da, cout, dw, db, ctx);
        }
        if i > 0 {
            let mut dx = vec![0.0f32; batch * h * w * cin];
            ops::conv2d_bwd_x(&t.wq, batch, h, w, cin, &da, cout, &mut dx, ctx);
            da = dx;
        }
    }
    act_grads.reverse();
    Grads { flat, act: act_grads }
}

/// Mean cross-entropy loss + full backward for a labeled batch — the
/// shared core of `train_epoch`, `qat_epoch` and `ef_trace`.
pub fn mean_loss_grad(
    plan: &Plan,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    batch: usize,
    q: Option<QuantArgs>,
    ctx: &mut ExecCtx,
) -> (f32, Grads) {
    let ncls = plan.spec.n_classes;
    let tape = forward(plan, params, x, batch, q, ctx);
    let mut per = vec![0.0f32; batch];
    ctx.prof.set_layer(Layer::Loss);
    let t0 = ctx.prof.start();
    ops::softmax_xent(&tape.logits, y, batch, ncls, &mut per);
    ctx.prof.record_untuned(
        t0,
        TracedOp::SoftmaxXent,
        tape.logits.len() + batch,
        batch,
        8 * batch * ncls,
        || format!("b{batch} c{ncls}"),
    );
    let loss = (per.iter().map(|&v| v as f64).sum::<f64>() / batch as f64) as f32;
    let dper = vec![1.0f32 / batch as f32; batch];
    let mut dlogits = vec![0.0f32; tape.logits.len()];
    let t0 = ctx.prof.start();
    ops::softmax_xent_bwd(&tape.logits, y, batch, ncls, &dper, &mut dlogits);
    ctx.prof.record_untuned(
        t0,
        TracedOp::SoftmaxXentBwd,
        tape.logits.len() + 2 * batch,
        dlogits.len(),
        6 * batch * ncls,
        || format!("b{batch} c{ncls}"),
    );
    let grads = backward(plan, params, &tape, &dlogits, ctx);
    (loss, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::model::{Plan, STUDY_CNNS};
    use crate::tensor::Pcg32;

    fn ctx() -> ExecCtx {
        ExecCtx::serial()
    }

    fn rand_batch(plan: &Plan, batch: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg32::new(seed, 5);
        let x: Vec<f32> = (0..batch * plan.sample_len()).map(|_| rng.normal()).collect();
        let y: Vec<i32> =
            (0..batch).map(|_| rng.below(plan.spec.n_classes as u32) as i32).collect();
        (x, y)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        for spec in STUDY_CNNS {
            let plan = Plan::new(*spec);
            let params = plan.init_flat(1);
            let (x, _) = rand_batch(&plan, 4, 2);
            let tape = forward(&plan, &params, &x, 4, None, &mut ctx());
            assert_eq!(tape.logits.len(), 4 * spec.n_classes);
            assert!(tape.logits.iter().all(|v| v.is_finite()), "{}", spec.name);
            for (i, layer) in plan.convs.iter().enumerate() {
                assert_eq!(tape.act(i).len(), 4 * layer.act_size());
            }
        }
    }

    #[test]
    fn backward_grad_shapes_match_layout() {
        let plan = Plan::new(STUDY_CNNS[1]); // BN variant
        let params = plan.init_flat(3);
        let (x, y) = rand_batch(&plan, 4, 7);
        let (loss, g) = mean_loss_grad(&plan, &params, &x, &y, 4, None, &mut ctx());
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(g.flat.len(), plan.n_params);
        assert_eq!(g.act.len(), plan.n_act_blocks());
        assert!(g.flat.iter().any(|&v| v != 0.0), "gradient must be nonzero");
        // act-grad shapes follow the activation sites
        for (i, layer) in plan.convs.iter().enumerate() {
            assert_eq!(g.act[i].len(), 4 * layer.act_size());
        }
    }

    #[test]
    fn quant_mode_changes_forward_but_not_shapes() {
        let plan = Plan::new(STUDY_CNNS[0]);
        let params = plan.init_flat(5);
        let (x, _) = rand_batch(&plan, 2, 9);
        let plain = forward(&plan, &params, &x, 2, None, &mut ctx());
        let (lw, la) = (plan.n_weight_blocks(), plan.n_act_blocks());
        let (bits_w, bits_a) = (vec![3.0f32; lw], vec![3.0f32; la]);
        let (act_lo, act_hi) = (vec![0.0f32; la], vec![4.0f32; la]);
        let q = QuantArgs { bits_w: &bits_w, bits_a: &bits_a, act_lo: &act_lo, act_hi: &act_hi };
        let quanted = forward(&plan, &params, &x, 2, Some(q), &mut ctx());
        assert_eq!(plain.logits.len(), quanted.logits.len());
        assert_ne!(plain.logits, quanted.logits, "3-bit quant must perturb logits");
    }

    #[test]
    fn deterministic_forward_backward() {
        let plan = Plan::new(STUDY_CNNS[1]);
        let params = plan.init_flat(11);
        let (x, y) = rand_batch(&plan, 3, 13);
        let (l1, g1) = mean_loss_grad(&plan, &params, &x, &y, 3, None, &mut ctx());
        let (l2, g2) = mean_loss_grad(&plan, &params, &x, &y, 3, None, &mut ctx());
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1.flat, g2.flat);
    }
}
