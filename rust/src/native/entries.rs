//! Entry-point execution: the native [`Dispatcher`] for one `(model,
//! entry)` pair.
//!
//! Each entry mirrors the L2 program of the same name (train.py /
//! fisher.py / layers.py): the scanned train/QAT epoch (K Adam steps per
//! dispatch), masked evaluation, predict, weight/activation range
//! extraction, and the one-backward EF-trace iteration. Arguments arrive
//! pre-validated against the manifest IoSpecs (shape, dtype, arity), so
//! this module only moves numbers.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Result};

use super::model::{Plan, FP_LR, QAT_LR};
use super::net::{self, QuantArgs};
use super::ops::{self, ExecCtx};
use super::trace::{Layer, TracedOp};
use crate::runtime::backend::{Dispatcher, OutBuf};
use crate::runtime::Arg;

/// Which program a dispatcher executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryKind {
    Init,
    /// `train_epoch` / `train_step` / `qat_epoch`: K scanned Adam steps.
    Train { k: usize, qat: bool },
    /// `eval` / `qat_eval`: masked batch evaluation.
    Eval { qat: bool },
    Predict,
    ParamRanges,
    ActRanges,
    /// One EF-trace estimator iteration at the given batch size.
    EfTrace { batch: usize },
}

impl EntryKind {
    /// Map a manifest entry name to its program.
    pub fn parse(name: &str, train_k: usize) -> Result<EntryKind> {
        Ok(match name {
            "init" => EntryKind::Init,
            "train_epoch" => EntryKind::Train { k: train_k, qat: false },
            "train_step" => EntryKind::Train { k: 1, qat: false },
            "qat_epoch" => EntryKind::Train { k: train_k, qat: true },
            "eval" => EntryKind::Eval { qat: false },
            "qat_eval" => EntryKind::Eval { qat: true },
            "predict" => EntryKind::Predict,
            "param_ranges" => EntryKind::ParamRanges,
            "act_ranges" => EntryKind::ActRanges,
            other => match other.strip_prefix("ef_trace_bs").and_then(|b| b.parse().ok()) {
                Some(batch) => EntryKind::EfTrace { batch },
                None => bail!("native backend has no entry {other:?}"),
            },
        })
    }
}

/// The native executable: a plan, the program to run over it, and the
/// per-dispatcher GEMM execution context.
///
/// The context lives behind a `RefCell` because [`Dispatcher::run`]
/// takes `&self` (the `Runtime` is single-threaded by design): its
/// scratch arena is allocated lazily by the first conv lowering and then
/// reused across every op, scanned train step and dispatch this
/// executable serves — the loop-nest implementation re-derived those
/// buffers per batch. The thread budget comes from the backend
/// ([`NativeBackend`](super::NativeBackend)); it only affects wall
/// clock, never bits.
pub struct NativeExec {
    pub plan: Rc<Plan>,
    pub kind: EntryKind,
    /// GEMM scratch + intra-op thread budget (interior-mutable: `run`
    /// takes `&self`, and dispatches never nest).
    pub ctx: RefCell<ExecCtx>,
}

fn f32_arg<'a>(args: &'a [Arg], i: usize) -> Result<&'a [f32]> {
    match args[i] {
        Arg::F32(v) => Ok(v),
        _ => bail!("native: argument {i} must be an f32 buffer"),
    }
}

fn i32_arg<'a>(args: &'a [Arg], i: usize) -> Result<&'a [i32]> {
    match args[i] {
        Arg::I32(v) => Ok(v),
        _ => bail!("native: argument {i} must be an i32 buffer"),
    }
}

fn scalar_arg(args: &[Arg], i: usize) -> Result<f32> {
    match args[i] {
        Arg::F32Scalar(v) => Ok(v),
        Arg::F32(v) if v.len() == 1 => Ok(v[0]),
        _ => bail!("native: argument {i} must be an f32 scalar"),
    }
}

/// One Adam step on the flat carry (layers.py `adam_update`; runtime
/// bias correction with the f32 step count).
fn adam_update(params: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], step: f32, lr: f32) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let c1 = 1.0 - B1.powf(step);
    let c2 = 1.0 - B2.powf(step);
    for i in 0..params.len() {
        let gi = g[i];
        m[i] = B1 * m[i] + (1.0 - B1) * gi;
        v[i] = B2 * v[i] + (1.0 - B2) * gi * gi;
        let mhat = m[i] / c1;
        let vhat = v[i] / c2;
        params[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

impl NativeExec {
    fn quant_args<'a>(&self, args: &'a [Arg], at: usize) -> Result<QuantArgs<'a>> {
        Ok(QuantArgs {
            bits_w: f32_arg(args, at)?,
            bits_a: f32_arg(args, at + 1)?,
            act_lo: f32_arg(args, at + 2)?,
            act_hi: f32_arg(args, at + 3)?,
        })
    }

    fn run_train(&self, args: &[Arg], k: usize, qat: bool) -> Result<Vec<OutBuf>> {
        let plan = &*self.plan;
        let mut ctx_guard = self.ctx.borrow_mut();
        let ctx = &mut *ctx_guard;
        let mut params = f32_arg(args, 0)?.to_vec();
        let mut m = f32_arg(args, 1)?.to_vec();
        let mut v = f32_arg(args, 2)?.to_vec();
        let mut step = scalar_arg(args, 3)?;
        let xs = f32_arg(args, 4)?;
        let ys = i32_arg(args, 5)?;
        let q = if qat { Some(self.quant_args(args, 6)?) } else { None };
        let lr = if qat { QAT_LR } else { FP_LR };
        let b = xs.len() / (k * plan.sample_len());
        let mut loss_sum = 0.0f64;
        for ki in 0..k {
            let x = &xs[ki * b * plan.sample_len()..][..b * plan.sample_len()];
            let y = &ys[ki * b..][..b];
            let (loss, grads) = net::mean_loss_grad(plan, &params, x, y, b, q, ctx);
            step += 1.0;
            ctx.prof.set_layer(Layer::Opt);
            let t0 = ctx.prof.start();
            adam_update(&mut params, &mut m, &mut v, &grads.flat, step, lr);
            let np = params.len();
            ctx.prof.record_untuned(t0, TracedOp::AdamStep, 4 * np, 3 * np, 12 * np, || {
                format!("n{np}")
            });
            loss_sum += loss as f64;
        }
        Ok(vec![
            OutBuf::F32(params),
            OutBuf::F32(m),
            OutBuf::F32(v),
            OutBuf::F32(vec![step]),
            OutBuf::F32(vec![(loss_sum / k as f64) as f32]),
        ])
    }

    fn run_eval(&self, args: &[Arg], qat: bool) -> Result<Vec<OutBuf>> {
        let plan = &*self.plan;
        let mut ctx_guard = self.ctx.borrow_mut();
        let ctx = &mut *ctx_guard;
        let params = f32_arg(args, 0)?;
        let x = f32_arg(args, 1)?;
        let y = i32_arg(args, 2)?;
        let mask = f32_arg(args, 3)?;
        let q = if qat { Some(self.quant_args(args, 4)?) } else { None };
        let b = mask.len();
        let ncls = plan.spec.n_classes;
        let tape = net::forward(plan, params, x, b, q, ctx);
        let mut per = vec![0.0f32; b];
        ops::softmax_xent(&tape.logits, y, b, ncls, &mut per);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0.0f64;
        for i in 0..b {
            loss_sum += (per[i] * mask[i]) as f64;
            let pred = ops::argmax(&tape.logits[i * ncls..][..ncls]);
            if pred as i32 == y[i] {
                correct += mask[i] as f64;
            }
            n += mask[i] as f64;
        }
        Ok(vec![
            OutBuf::F32(vec![loss_sum as f32]),
            OutBuf::F32(vec![correct as f32]),
            OutBuf::F32(vec![n as f32]),
        ])
    }

    fn run_ef_trace(&self, args: &[Arg], batch: usize) -> Result<Vec<OutBuf>> {
        let plan = &*self.plan;
        let mut ctx_guard = self.ctx.borrow_mut();
        let ctx = &mut *ctx_guard;
        let params = f32_arg(args, 0)?;
        let x = f32_arg(args, 1)?;
        let y = i32_arg(args, 2)?;
        let (_, grads) = net::mean_loss_grad(plan, params, x, y, batch, None, ctx);
        let bf = batch as f64;
        let w_tr: Vec<f32> = (0..plan.n_weight_blocks())
            .map(|l| {
                let (off, size) = plan.weight_block(l);
                let s: f64 =
                    grads.flat[off..off + size].iter().map(|&g| g as f64 * g as f64).sum();
                (s * bf) as f32
            })
            .collect();
        let a_tr: Vec<f32> = grads
            .act
            .iter()
            .map(|ag| {
                let s: f64 = ag.iter().map(|&g| g as f64 * g as f64).sum();
                (s * bf) as f32
            })
            .collect();
        Ok(vec![OutBuf::F32(w_tr), OutBuf::F32(a_tr)])
    }
}

impl Dispatcher for NativeExec {
    fn run(&self, args: &[Arg]) -> Result<Vec<OutBuf>> {
        let plan = &*self.plan;
        match self.kind {
            EntryKind::Init => {
                let seed = match args[0] {
                    Arg::U32Scalar(s) => s,
                    _ => bail!("native: init takes a u32 seed"),
                };
                Ok(vec![OutBuf::F32(plan.init_flat(seed))])
            }
            EntryKind::Train { k, qat } => self.run_train(args, k, qat),
            EntryKind::Eval { qat } => self.run_eval(args, qat),
            EntryKind::Predict => {
                let params = f32_arg(args, 0)?;
                let x = f32_arg(args, 1)?;
                let b = x.len() / plan.sample_len();
                let tape =
                    net::forward(plan, params, x, b, None, &mut self.ctx.borrow_mut());
                Ok(vec![OutBuf::F32(tape.logits)])
            }
            EntryKind::ParamRanges => {
                let params = f32_arg(args, 0)?;
                let mut lo = Vec::with_capacity(plan.n_weight_blocks());
                let mut hi = Vec::with_capacity(plan.n_weight_blocks());
                for l in 0..plan.n_weight_blocks() {
                    let (off, size) = plan.weight_block(l);
                    let (mn, mx) = crate::tensor::min_max(&params[off..off + size])
                        .expect("weight blocks are non-empty");
                    lo.push(mn);
                    hi.push(mx);
                }
                Ok(vec![OutBuf::F32(lo), OutBuf::F32(hi)])
            }
            EntryKind::ActRanges => {
                let params = f32_arg(args, 0)?;
                let x = f32_arg(args, 1)?;
                let b = x.len() / plan.sample_len();
                let tape =
                    net::forward(plan, params, x, b, None, &mut self.ctx.borrow_mut());
                let mut lo = Vec::with_capacity(plan.n_act_blocks());
                let mut hi = Vec::with_capacity(plan.n_act_blocks());
                for i in 0..plan.n_act_blocks() {
                    let (mn, mx) =
                        crate::tensor::min_max(tape.act(i)).expect("activations are non-empty");
                    lo.push(mn);
                    hi.push(mx);
                }
                Ok(vec![OutBuf::F32(lo), OutBuf::F32(hi)])
            }
            EntryKind::EfTrace { batch } => self.run_ef_trace(args, batch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_kind_parsing() {
        assert_eq!(EntryKind::parse("init", 10).unwrap(), EntryKind::Init);
        assert_eq!(
            EntryKind::parse("train_epoch", 10).unwrap(),
            EntryKind::Train { k: 10, qat: false }
        );
        assert_eq!(
            EntryKind::parse("train_step", 10).unwrap(),
            EntryKind::Train { k: 1, qat: false }
        );
        assert_eq!(
            EntryKind::parse("qat_epoch", 10).unwrap(),
            EntryKind::Train { k: 10, qat: true }
        );
        assert_eq!(
            EntryKind::parse("ef_trace_bs32", 10).unwrap(),
            EntryKind::EfTrace { batch: 32 }
        );
        assert!(EntryKind::parse("hutch_bs4", 10).is_err(), "no Hessian entry natively");
        assert!(EntryKind::parse("bogus", 10).is_err());
    }

    #[test]
    fn adam_first_step_is_sign_scaled() {
        // step 1 bias correction makes mhat = g, vhat = g^2, so the
        // update is -lr * sign(g) (up to eps)
        let mut p = vec![0.0f32; 2];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adam_update(&mut p, &mut m, &mut v, &[0.5, -2.0], 1.0, 0.01);
        assert!((p[0] + 0.01).abs() < 1e-5, "p0 {}", p[0]);
        assert!((p[1] - 0.01).abs() < 1e-5, "p1 {}", p[1]);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize (x - 3)^2 — Adam should land near 3
        let mut p = vec![0.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        for step in 1..=2000 {
            let g = 2.0 * (p[0] - 3.0);
            adam_update(&mut p, &mut m, &mut v, &[g], step as f32, 0.05);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "{}", p[0]);
    }
}
