//! Math-kernel layer of the native backend: im2col/col2im lowering, a
//! panel-parallel rank-1 `sgemm` with a fixed-order `f32` accumulation
//! contract, and threaded direct-convolution kernels — everything
//! fanned over
//! [`coordinator::parallel::run_static`](crate::coordinator::parallel::run_static).
//!
//! # Determinism contract (why this can replace the loop nests)
//!
//! Every kernel here reproduces the scalar reference implementation in
//! [`ops::reference`](super::ops::reference) to 0 ULP, because for every
//! output element the chain of `f32` operations is *identical*, not
//! merely mathematically equivalent:
//!
//! - [`sgemm`] keeps one running `f32` accumulator chain per output
//!   element, initialized from the bias (or zero) and advanced strictly
//!   in ascending-`k` order (a rank-1 update per `k`). Rust never
//!   contracts `a*b + c` into an FMA on its own, so `acc += a * b`
//!   rounds exactly like the reference loop nest.
//! - The im2col layout (rows = output pixels in `(n, i, j)` order,
//!   columns = `(di, dj, ci)`) matches both the HWIO kernel layout and
//!   the reference tap order, so "ascending k" *is* the reference's
//!   `(di, dj, ci)` visitation order.
//! - **Exact-zero skipping is bit-exact.** [`sgemm`], [`sgemm_atb`] and
//!   [`conv2d_bwd_w_direct`] skip `A` entries that are exactly `0.0`
//!   (im2col padding, post-ReLU zeros, relu-masked gradients). Adding
//!   the skipped `±0.0` product could only differ from skipping it in
//!   the sign of a zero result, and a `-0.0` accumulator is unreachable
//!   here: IEEE-754 round-to-nearest produces `-0.0` only from
//!   `(-0.0) + (-0.0)`, and every accumulator chain in this backend
//!   starts from a `+0.0`-zeroed buffer or a bias Adam can never drive
//!   to `-0.0`. (The contract assumes finite inputs — `0 * inf = NaN`
//!   would distinguish a skipped term, but a NaN forward pass is
//!   already outside every other contract.)
//! - [`col2im3x3`] is a *gather*, not a scatter: each `dx` element sums
//!   its (at most 9) tap contributions in ascending `(di, dj)` order —
//!   the reference `conv2d_bwd_x` order — rather than streaming over
//!   `dout` pixels, which would visit taps in descending order and
//!   round differently.
//! - [`conv2d_direct`] *is* the reference loop run per image-range, and
//!   [`conv2d_bwd_w_direct`] re-nests the reference loops tap-outermost;
//!   each `dw` element belongs to exactly one tap, so its `(ni, i, j)`
//!   accumulation order is untouched.
//!
//! Because outputs are bit-identical to the reference (and therefore to
//! PR 4's kernels), pipeline cache digests are untouched: a checkpoint
//! trained before this layer existed validates against one trained
//! through it. The whole contract is pinned by `tests/native_gemm.rs`
//! and was cross-validated bitwise in C (`tools/cmirror/`) through full
//! multi-epoch train loops before this layer shipped.
//!
//! # SIMD variants and routing (measured per host, not assumed)
//!
//! Every kernel here takes an [`Isa`] argument and bottoms out in the
//! `native::simd` panel routines, which vectorize across *independent
//! output elements* (the channel axis) with explicit SSE2/AVX2/NEON
//! intrinsics — mul-then-add, never FMA — so the per-element chain
//! above is literally unchanged and the 0-ULP contract holds for every
//! variant (pinned by the variant matrix in `tests/native_gemm.rs`).
//!
//! *Which* variant and *which* lowering (direct loop vs im2col+GEMM)
//! runs for a given op and shape is no longer hand-pinned: PR 5's
//! routing was measured on one 2-core box, and re-measurement showed
//! the winner flips with both the host and the channel width — e.g. on
//! the AVX2 measurement host, AVX2 wins the wide CIFAR convs while
//! SSE2 wins the `c_out = 8` MNIST stem, where 8-lane vectors never
//! fill (BENCH_kernels.json). The per-host autotuner (`native::tune`)
//! micro-benchmarks each (op, width-class, lowering, ISA) candidate
//! once, persists the winner table in the artifact cache keyed by a
//! host fingerprint, and [`ExecCtx::choice`] consults it per dispatch;
//! `FITQ_NATIVE_KERNEL={auto,scalar,sse2,avx2,neon}` forces a single
//! variant instead. A register-tiled micro-kernel variant measured
//! *slower* than the plain rank-1 stream, which is why [`sgemm`] keeps
//! the simple form.
//!
//! **Rule for new ops**: route through the threaded GEMM layer only if
//! (a) the per-output-element `f32` chain is provably identical to the
//! scalar reference at every thread count, and (b) a measurement (not
//! an assumption) shows the lowering beats the direct loop for the
//! shapes that op actually runs. Reductions whose order would depend on
//! the fan-out (e.g. a tree-reduced batch sum) must stay serial or keep
//! a per-element sequential accumulator.
//!
//! # Parallelism
//!
//! Only loops whose iterations own disjoint output slices are fanned
//! out: `sgemm` over M-panels of `C`, `sgemm_atb` over row-panels of
//! `dw`, `conv2d_direct` over image ranges, `conv2d_bwd_w_direct` over
//! kernel taps (each tap owns a contiguous `dw` block — an
//! output-channel split was measured and discarded: adjacent workers
//! false-share `dw` cache lines), `col2im3x3` over images. The schedule
//! is static ([`run_static`]) and the per-element operation chain is
//! independent of the panel assignment, so results are bit-identical at
//! every thread budget — `threads` is purely a wall-clock knob, which
//! is why it is *not* part of any pipeline cache key.
//! [`effective_threads`] caps the fan-out by a FLOP threshold so
//! dispatch-sized problems never pay a thread spawn for microseconds of
//! work.

use std::sync::Arc;

use crate::coordinator::parallel::run_static;
use super::simd::{self, Isa};
use super::trace::Prof;
use super::tune::{self, Choice, KernelMode, RouteTable, TunedOp};

/// M-dimension panel height of [`sgemm`]: the unit of intra-op
/// parallelism and the write-locality granule (one panel of `C` rows
/// per work item).
pub const MC: usize = 64;

/// Minimum multiply-add FLOPs that justify one additional worker thread
/// (a scoped spawn costs ~tens of microseconds; at a few GFLOP/s this
/// keeps spawn overhead under a few percent of the fanned-out work).
const PAR_FLOPS_PER_THREAD: usize = 4_000_000;

/// Resolve an intra-op thread budget for a kernel invocation: never more
/// than `budget` (the backend's configured budget), than `panels`
/// (disjoint work items), or than the FLOP count supports.
pub fn effective_threads(budget: usize, panels: usize, flops: usize) -> usize {
    budget.max(1).min(panels.max(1)).min(1 + flops / PAR_FLOPS_PER_THREAD)
}

/// How a [`sgemm`] output buffer is initialized before accumulation.
#[derive(Debug, Clone, Copy)]
pub enum Init<'a> {
    /// Each of the M output rows starts as a copy of this length-N bias
    /// row (the conv/dense forward shape).
    Bias(&'a [f32]),
    /// Output starts at `+0.0` (the `G = dout * W^T` backward shape).
    Zero,
}

/// Reusable scratch for the GEMM lowering of one dispatcher: `a` holds
/// the current im2col / `G` matrix, `b` the transposed weight panel.
/// Buffers grow to the largest layer of the plan once and are then
/// reused across ops, scanned train steps and dispatches — hoisting the
/// per-batch allocation churn the loop-nest implementation paid into a
/// per-worker arena.
#[derive(Debug, Default)]
pub struct Scratch {
    /// im2col / `G` matrix buffer (`M x K`).
    pub a: Vec<f32>,
    /// Transposed-weights buffer (`N x K` packs of `W^T`).
    pub b: Vec<f32>,
}

/// Per-dispatcher execution context of the GEMM layer: the intra-op
/// thread budget, the kernel-variant selection policy, the
/// reference-kernel escape hatch, and the scratch arena. One lives
/// behind a `RefCell` in every
/// [`NativeExec`](super::entries::NativeExec); tests and oracles use
/// [`ExecCtx::serial`].
#[derive(Debug, Default)]
pub struct ExecCtx {
    /// Intra-op thread budget for the kernel fan-out (`0`/`1` = serial).
    pub threads: usize,
    /// Route conv/dense ops through the scalar
    /// [`ops::reference`](super::ops::reference) kernels instead of this
    /// layer (`FITQ_NATIVE_REFERENCE=1`) — the measured "before" of the
    /// before/after benchmark, and an A/B oracle for debugging.
    pub use_reference: bool,
    /// Kernel-variant policy. The backend parses it from
    /// `FITQ_NATIVE_KERNEL` (unset = `Auto`); contexts built directly
    /// default to forcing the best available ISA (no tuner IO).
    pub mode: KernelMode,
    /// The resolved route table (`Auto` mode only, installed lazily on
    /// the first [`ExecCtx::choice`] or up front by
    /// [`ExecCtx::with_routes`]).
    routes: Option<Arc<RouteTable>>,
    /// The per-worker scratch arena.
    pub scratch: Scratch,
    /// Opt-in op-level profiler (see [`trace`](super::trace)). Disarmed
    /// by default — every record site is one branch when off, and the
    /// collected aggregates never enter any stage digest.
    pub prof: Prof,
}

impl ExecCtx {
    /// A context with the given intra-op thread budget.
    pub fn new(threads: usize) -> ExecCtx {
        ExecCtx { threads, ..ExecCtx::default() }
    }

    /// The serial kernel-path context (what op-level tests use).
    pub fn serial() -> ExecCtx {
        ExecCtx::new(1)
    }

    /// A serial context forced to one kernel variant — the variant
    /// matrix in `tests/native_gemm.rs` is built from these.
    pub fn forced(isa: Isa) -> ExecCtx {
        ExecCtx { threads: 1, mode: KernelMode::Forced(isa), ..ExecCtx::default() }
    }

    /// An `Auto`-mode context with a pre-resolved route table — lets
    /// tests exercise tuned routing without touching any cache
    /// directory.
    pub fn with_routes(threads: usize, routes: Arc<RouteTable>) -> ExecCtx {
        ExecCtx {
            threads,
            mode: KernelMode::Auto,
            routes: Some(routes),
            ..ExecCtx::default()
        }
    }

    /// Resolve the (ISA, lowering) choice for `op` at vector-axis width
    /// `width`. `Forced` mode pairs the forced ISA with the op's static
    /// lowering; `Auto` consults the host's tuned table, resolving it
    /// through the artifact cache on first use
    /// ([`tune::resolve`](super::tune::resolve)).
    pub fn choice(&mut self, op: TunedOp, width: usize) -> Choice {
        match self.mode {
            KernelMode::Forced(isa) => Choice { isa, lowering: tune::static_lowering(op) },
            KernelMode::Auto => {
                let routes =
                    self.routes.get_or_insert_with(|| tune::resolve(self.threads));
                routes.choice(op, width)
            }
        }
    }
}

/// Lower an NHWC batch to the im2col matrix of the 3x3 SAME stride-1
/// conv: row `m = (ni*h + i)*w + j` holds the `9*cin` input values under
/// the kernel window centered on output pixel `(i, j)`, in `(di, dj,
/// ci)` column order; out-of-image taps are `+0.0`. `out` is resized
/// (and fully re-zeroed) to `n*h*w * 9*cin`.
pub fn im2col3x3(x: &[f32], n: usize, h: usize, w: usize, cin: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), n * h * w * cin);
    let k = 9 * cin;
    out.clear();
    out.resize(n * h * w * k, 0.0);
    for ni in 0..n {
        for i in 0..h {
            for j in 0..w {
                let row = &mut out[((ni * h + i) * w + j) * k..][..k];
                for di in 0..3 {
                    let ii = i + di;
                    if ii < 1 || ii - 1 >= h {
                        continue;
                    }
                    let xi = ii - 1;
                    for dj in 0..3 {
                        let jj = j + dj;
                        if jj < 1 || jj - 1 >= w {
                            continue;
                        }
                        let xj = jj - 1;
                        let src = &x[((ni * h + xi) * w + xj) * cin..][..cin];
                        row[(di * 3 + dj) * cin..][..cin].copy_from_slice(src);
                    }
                }
            }
        }
    }
}

/// The adjoint of [`im2col3x3`] as a *gather*: `dx[ni, xi, xj, ci]` sums
/// `g[m(i, j)][k(di, dj, ci)]` over the valid taps in ascending `(di,
/// dj)` order — exactly the reference `conv2d_bwd_x` accumulation order.
/// Overwrites `dx`; fans out over batch images.
pub fn col2im3x3(
    g: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dx: &mut [f32],
    threads: usize,
    isa: Isa,
) {
    let k = 9 * cin;
    debug_assert_eq!(g.len(), n * h * w * k);
    debug_assert_eq!(dx.len(), n * h * w * cin);
    let threads = effective_threads(threads, n, 2 * n * h * w * k);
    let panels: Vec<(usize, &mut [f32])> = dx.chunks_mut(h * w * cin).enumerate().collect();
    run_static(panels, threads, |_, (ni, panel)| {
        simd::col2im_image(isa, g, panel, h, w, cin, ni);
    });
}

/// Transpose a row-major `rows x cols` matrix into `out` (`cols x rows`,
/// resized) — the weight pack `W^T` the backward-by-input GEMM streams.
pub fn transpose(src: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(src.len(), rows * cols);
    // size only — every element is overwritten below, so no re-zeroing
    out.resize(rows * cols, 0.0);
    for r in 0..rows {
        for (c, &v) in src[r * cols..][..cols].iter().enumerate() {
            out[c * rows + r] = v;
        }
    }
}

/// `C = init + A * B` over row-major `A (m x k)`, `B (k x n)`, `C (m x
/// n)`: per `C` row, `k`-outer rank-1 updates with exact-zero `A`
/// entries skipped; M-panels of [`MC`] rows fanned over `threads`
/// scoped workers. Per output element the `f32` accumulation is `init`
/// then strictly ascending `k` — see the module determinism contract.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    init: Init,
    c: &mut [f32],
    threads: usize,
    isa: Isa,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if let Init::Bias(bias) = init {
        debug_assert_eq!(bias.len(), n);
    }
    if m == 0 || n == 0 {
        return;
    }
    let bias = match init {
        Init::Bias(bias) => Some(bias),
        Init::Zero => None,
    };
    let n_panels = m.div_ceil(MC);
    let threads = effective_threads(threads, n_panels, 2 * m * n * k);
    let panels: Vec<(usize, &mut [f32])> = c.chunks_mut(MC * n).enumerate().collect();
    run_static(panels, threads, |_, (pi, c_panel)| {
        simd::sgemm_panel(isa, c_panel, pi * MC, n, k, a, b, bias);
    });
}

/// `DW += A^T * D` over row-major `A (m x k)`, `D (m x n)`, `DW (k x
/// n)` — the dense backward-by-weights shape. Per `dw` element the
/// reduction runs over `m` in strictly ascending order (the reference
/// batch scan); exact-zero `A` entries are skipped (bit-exact, see the
/// module contract). Fans out over row-panels of `DW`.
pub fn sgemm_atb(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    d: &[f32],
    dw: &mut [f32],
    threads: usize,
    isa: Isa,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    if k == 0 || n == 0 {
        return;
    }
    let n_panels = k.div_ceil(MC.min(k));
    let threads = effective_threads(threads, n_panels, 2 * m * n * k);
    let panel_rows = k.div_ceil(threads.max(1));
    let panels: Vec<(usize, &mut [f32])> =
        dw.chunks_mut(panel_rows * n).enumerate().collect();
    run_static(panels, threads, |_, (pi, dw_panel)| {
        simd::sgemm_atb_panel(isa, dw_panel, pi * panel_rows, m, n, k, a, d);
    });
}

/// Direct 3x3 SAME conv forward, threaded over contiguous image ranges:
/// each range executes the reference loop nest
/// (`simd::conv_fwd_block`, the `ops::reference::conv2d` order at the
/// chosen ISA) on its disjoint slice of `x`/`out`, so `threads = 1`
/// *is* the reference chain and every budget is bit-identical. The
/// default forward lowering (see the module routing notes).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_direct(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    cout: usize,
    bias: &[f32],
    out: &mut [f32],
    threads: usize,
    isa: Isa,
) {
    let threads = effective_threads(threads, n, 2 * n * h * w * 9 * cin * cout);
    if threads <= 1 {
        return simd::conv_fwd_block(isa, x, n, h, w, cin, wgt, cout, bias, out);
    }
    let per = n.div_ceil(threads);
    let panels: Vec<(usize, &mut [f32])> =
        out.chunks_mut(per * h * w * cout).enumerate().collect();
    run_static(panels, threads, |_, (t, out_panel)| {
        let n0 = t * per;
        let nn = out_panel.len() / (h * w * cout);
        let x_panel = &x[n0 * h * w * cin..][..nn * h * w * cin];
        simd::conv_fwd_block(isa, x_panel, nn, h, w, cin, wgt, cout, bias, out_panel);
    });
}

/// Direct conv backward-by-weights, threaded over the 9 kernel taps:
/// each tap owns the contiguous `dw` rows `[(di*3 + dj)*cin, +cin)` so
/// writes never collide (an output-channel split was measured and
/// discarded for false sharing), and per `dw` element the `(ni, i, j)`
/// scan is the reference order — each element belongs to exactly one
/// tap. Exact-zero inputs (post-ReLU/pool activations) are skipped.
/// Accumulates into `dw`/`db` (callers zero them).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_w_direct(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dout: &[f32],
    cout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    threads: usize,
    isa: Isa,
) {
    let threads = effective_threads(threads, 9, 2 * n * h * w * 9 * cin * cout);
    let taps: Vec<(usize, &mut [f32])> = dw.chunks_mut(cin * cout).enumerate().collect();
    run_static(taps, threads, |_, (tap, dw_tap)| {
        simd::conv_bwd_w_tap(isa, x, n, h, w, cin, dout, cout, dw_tap, tap / 3, tap % 3);
    });
    simd::col_sum(isa, db, dout, cout);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 21);
        (0..n).map(|_| rng.normal()).collect()
    }

    /// The plainest possible oracle: one accumulator, ascending k.
    fn naive(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], bias: Option<&[f32]>) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias.map_or(0.0, |bs| bs[j]);
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn sgemm_matches_naive_bitwise_on_odd_shapes() {
        // shapes straddling the panel boundary, single rows/cols, and a
        // zero-sparse A exercising the skip path — for every detected
        // SIMD variant (the naive oracle is the reference chain)
        for isa in Isa::detected() {
            for &(m, n, k) in
                &[(1, 1, 1), (3, 5, 7), (63, 8, 40), (65, 10, 27), (130, 3, 259)]
            {
                let mut a = randv(m * k, 1000 + m as u64);
                for v in a.iter_mut().step_by(3) {
                    *v = v.max(0.0); // exact zeros, post-ReLU style
                }
                let b = randv(k * n, 2000 + n as u64);
                let bias = randv(n, 3000 + k as u64);
                let want = naive(m, n, k, &a, &b, Some(&bias));
                let mut got = vec![0.0f32; m * n];
                sgemm(m, n, k, &a, &b, Init::Bias(&bias), &mut got, 1, isa);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "({m},{n},{k}) {isa}"
                );
                let want0 = naive(m, n, k, &a, &b, None);
                sgemm(m, n, k, &a, &b, Init::Zero, &mut got, 1, isa);
                assert_eq!(got, want0, "zero-init ({m},{n},{k}) {isa}");
            }
        }
    }

    #[test]
    fn sgemm_bit_identical_across_thread_budgets() {
        let (m, n, k) = (517, 13, 40);
        let a = randv(m * k, 7);
        let b = randv(k * n, 8);
        let bias = randv(n, 9);
        let mut c1 = vec![0.0f32; m * n];
        sgemm(m, n, k, &a, &b, Init::Bias(&bias), &mut c1, 1, Isa::Scalar);
        for isa in Isa::detected() {
            for threads in [2usize, 4, 16] {
                let mut ct = vec![0.0f32; m * n];
                sgemm(m, n, k, &a, &b, Init::Bias(&bias), &mut ct, threads, isa);
                assert_eq!(
                    c1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    ct.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "threads={threads} {isa}"
                );
            }
        }
    }

    #[test]
    fn sgemm_atb_matches_naive_and_threads() {
        let (m, n, k) = (91, 6, 35);
        let mut a = randv(m * k, 11);
        // inject exact zeros (the post-ReLU pattern the skip targets)
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let d = randv(m * n, 12);
        let mut want = vec![0.0f32; k * n];
        for mi in 0..m {
            for kk in 0..k {
                for o in 0..n {
                    want[kk * n + o] += a[mi * k + kk] * d[mi * n + o];
                }
            }
        }
        for isa in Isa::detected() {
            for threads in [1usize, 2, 4] {
                let mut got = vec![0.0f32; k * n];
                sgemm_atb(m, n, k, &a, &d, &mut got, threads, isa);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "threads={threads} {isa}"
                );
            }
        }
    }

    #[test]
    fn im2col_layout_and_padding() {
        // 1x2x2x1 image, values 1..4: check tap placement + zero padding
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut a = Vec::new();
        im2col3x3(&x, 1, 2, 2, 1, &mut a);
        assert_eq!(a.len(), 4 * 9);
        // output pixel (0,0): center tap (1,1)=k4 is x[0,0]=1, right
        // (1,2)=k5 is x[0,1]=2, down (2,1)=k7 is x[1,0]=3, diag k8 = 4
        assert_eq!(&a[0..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
        // output pixel (1,1): center is x[1,1]=4, up-left k0 = x[0,0]=1
        assert_eq!(&a[3 * 9..4 * 9], &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn col2im_of_im2col_is_tap_multiplicity() {
        // col2im(im2col(x))[p] = x[p] * (# valid taps covering p): 9 in
        // the interior, 6 on edges, 4 in corners. Integer-valued x keeps
        // the small repeated sums exact in f32.
        let (n, h, w, cin) = (2usize, 5, 4, 3);
        let mut rng = Pcg32::new(31, 2);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.below(17) as f32 - 8.0).collect();
        let mut a = Vec::new();
        im2col3x3(&x, n, h, w, cin, &mut a);
        let mut back = vec![0.0f32; x.len()];
        col2im3x3(&a, n, h, w, cin, &mut back, 1, Isa::Scalar);
        for ni in 0..n {
            for i in 0..h {
                let ri = if i == 0 || i == h - 1 { 2 } else { 3 };
                for j in 0..w {
                    let rj = if j == 0 || j == w - 1 { 2 } else { 3 };
                    for ci in 0..cin {
                        let at = ((ni * h + i) * w + j) * cin + ci;
                        assert_eq!(
                            back[at],
                            x[at] * (ri * rj) as f32,
                            "pixel ({ni},{i},{j},{ci})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn transpose_round_trips() {
        let src = randv(7 * 3, 41);
        let mut t = Vec::new();
        transpose(&src, 7, 3, &mut t);
        assert_eq!(t[4], src[4 * 3], "t[0][4] == src[4][0]");
        let mut back = Vec::new();
        transpose(&t, 3, 7, &mut back);
        assert_eq!(back, src);
    }

    #[test]
    fn effective_threads_caps_by_work_and_panels() {
        assert_eq!(effective_threads(8, 1, usize::MAX), 1, "one panel, one thread");
        assert_eq!(effective_threads(8, 100, 1000), 1, "tiny work stays serial");
        assert_eq!(effective_threads(4, 100, usize::MAX), 4, "budget is the cap");
        assert_eq!(effective_threads(0, 4, usize::MAX), 1, "zero budget means serial");
    }
}
