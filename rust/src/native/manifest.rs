//! Declarative model manifests — the JSON zoo (`zoo/*.json`) and its
//! fail-closed compiler into the interpreter's [`ModelSpec`].
//!
//! A manifest describes one model in the native op vocabulary: a chain
//! of `conv3x3` stages (bias, optional batch-norm, relu, optional 2x2
//! max-pool) ending in one `dense` classifier head, plus input shape,
//! init scheme and explicit quantizer placement. Parsing is strict in
//! the serde `deny_unknown_fields` sense, hand-rolled over the
//! [`Json`] substrate: unknown fields, missing fields, wrong types,
//! schema-version skew, duplicate or dangling layer references,
//! non-topological declaration order, shape mismatches and contradictory
//! quantizer placement are all *typed* errors ([`ManifestError`]) —
//! never a fallback or a best-effort guess.
//!
//! **Digest rule.** A compiled zoo model feeds the same
//! [`Plan`](super::model::Plan) builder and generated
//! [`ModelManifest`](crate::runtime::ModelManifest) as the builtins, so
//! pipeline cache keys hash its *layout* (`stages::hash_model`): a
//! manifest equivalent to a builtin shares the builtin's digests
//! bit-for-bit, and any structural difference separates them.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::anyhow;

use super::model::{ConvSpec, ModelSpec};
use crate::runtime::json::Json;

/// The manifest schema revision this build understands. A bump is a
/// deliberate breaking change: any other value is a typed
/// [`ManifestError::SchemaVersion`], never a best-effort parse.
pub const SCHEMA_VERSION: u64 = 1;

/// The usage line appended to every CLI-facing manifest failure.
pub const ZOO_USAGE: &str = "usage: --model takes a builtin name (see `fitq info`) or the \
     path of a zoo model manifest ending in .json (schema: DESIGN.md \"Model manifests\"; \
     validate with `fitq zoo-check zoo/*.json`)";

/// A manifest rejection: every variant names what failed and where.
///
/// The negative corpus (`tests/corpus/manifests/bad/`) keys on
/// [`ManifestError::kind`], so the variants and their kind strings are a
/// stable contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ManifestError {
    /// The text is not well-formed JSON, or the top level is not an object.
    Json(String),
    /// `schema_version` is missing or not exactly [`SCHEMA_VERSION`].
    SchemaVersion(String),
    /// A field this schema does not define (typos must never silently
    /// change meaning).
    UnknownField { context: String, field: String },
    /// A required field is absent.
    MissingField { context: String, field: String },
    /// A field holds the wrong JSON type.
    WrongType { context: String, field: String, expected: &'static str },
    /// A field parses but holds a value outside the schema's vocabulary.
    BadValue { context: String, detail: String },
    /// Two layers share a name, or a layer claims the reserved `"input"`.
    DuplicateLayer { name: String },
    /// `after` or `output` names a layer that does not exist.
    DanglingRef { context: String, target: String },
    /// A layer consumes itself or a later layer — declaration order must
    /// be topological, so this is the non-DAG case.
    CyclicOrder { layer: String, after: String },
    /// The layer graph is not a single `input -> conv3x3* -> dense` chain.
    Structure { detail: String },
    /// An op outside the native vocabulary (`conv3x3` | `dense`).
    UnsupportedOp { layer: String, op: String },
    /// Shape arithmetic fails (odd dims under pool, zero-size dims, …).
    ShapeMismatch { context: String, detail: String },
    /// Quantizer placement contradicts the interpreter's block structure.
    QuantPlacement { layer: String, detail: String },
}

impl ManifestError {
    /// Stable machine-readable name of this rejection class — the
    /// `<kind>__*.json` filename convention of the negative corpus.
    pub fn kind(&self) -> &'static str {
        match self {
            ManifestError::Json(_) => "json",
            ManifestError::SchemaVersion(_) => "schema-version",
            ManifestError::UnknownField { .. } => "unknown-field",
            ManifestError::MissingField { .. } => "missing-field",
            ManifestError::WrongType { .. } => "wrong-type",
            ManifestError::BadValue { .. } => "bad-value",
            ManifestError::DuplicateLayer { .. } => "duplicate-layer",
            ManifestError::DanglingRef { .. } => "dangling-ref",
            ManifestError::CyclicOrder { .. } => "cyclic-order",
            ManifestError::Structure { .. } => "structure",
            ManifestError::UnsupportedOp { .. } => "unsupported-op",
            ManifestError::ShapeMismatch { .. } => "shape-mismatch",
            ManifestError::QuantPlacement { .. } => "quant-placement",
        }
    }
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Json(detail) => write!(f, "not valid JSON: {detail}"),
            ManifestError::SchemaVersion(found) => write!(
                f,
                "schema_version {found} is not supported (this build reads version \
                 {SCHEMA_VERSION} only)"
            ),
            ManifestError::UnknownField { context, field } => {
                write!(f, "{context}: unknown field {field:?}")
            }
            ManifestError::MissingField { context, field } => {
                write!(f, "{context}: missing field {field:?}")
            }
            ManifestError::WrongType { context, field, expected } => {
                write!(f, "{context}: field {field:?} must be {expected}")
            }
            ManifestError::BadValue { context, detail } => write!(f, "field {context}: {detail}"),
            ManifestError::DuplicateLayer { name } => {
                write!(f, "duplicate layer name {name:?} (\"input\" is reserved)")
            }
            ManifestError::DanglingRef { context, target } => {
                write!(f, "{context} references unknown layer {target:?}")
            }
            ManifestError::CyclicOrder { layer, after } => write!(
                f,
                "layer {layer:?} consumes {after:?}, which is not declared before it \
                 (layers must be declared in topological order)"
            ),
            ManifestError::Structure { detail } => write!(f, "bad model structure: {detail}"),
            ManifestError::UnsupportedOp { layer, op } => write!(
                f,
                "layer {layer:?}: op {op:?} is outside the native vocabulary \
                 (conv3x3 | dense)"
            ),
            ManifestError::ShapeMismatch { context, detail } => {
                write!(f, "shape mismatch at {context}: {detail}")
            }
            ManifestError::QuantPlacement { layer, detail } => {
                write!(f, "layer {layer:?}: bad quantizer placement: {detail}")
            }
        }
    }
}

impl std::error::Error for ManifestError {}

/// A parsed (not yet validated) model manifest — the typed form of one
/// `zoo/*.json` document. `PartialEq` backs the round-trip contract:
/// `parse(m.to_json()) == m`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ZooManifest {
    pub name: String,
    /// Task vocabulary: `"classify"`.
    pub task: String,
    /// `[h, w, c]` input shape.
    pub input: Vec<usize>,
    /// Weight-init scheme vocabulary: `"he_normal"`.
    pub init: String,
    /// Layers in declaration (= execution) order.
    pub layers: Vec<ZooLayer>,
    /// Name of the layer whose output is the model output.
    pub output: String,
}

/// One declared layer of a [`ZooManifest`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ZooLayer {
    pub name: String,
    /// Producer this layer consumes: `"input"` or an earlier layer name.
    pub after: String,
    pub op: ZooOp,
    /// Declared weight-quantizer placement.
    pub quant_weight: bool,
    /// Declared activation-quantizer placement.
    pub quant_act: bool,
}

/// The native op vocabulary a manifest layer may use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZooOp {
    /// 3x3 SAME stride-1 convolution + bias (+ optional batch-norm) +
    /// relu (+ optional 2x2 max-pool) — the interpreter's conv stage.
    Conv3x3 { filters: usize, batch_norm: bool, pool: bool },
    /// The terminal dense classifier head (`units` = classes).
    Dense { units: usize },
}

/// One zoo model ready for the backend: the parsed manifest plus its
/// compiled spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ZooModel {
    pub manifest: ZooManifest,
    pub spec: ModelSpec,
}

// -- strict field extraction over the Json substrate ---------------------

fn check_fields(
    ctx: &str,
    obj: &BTreeMap<String, Json>,
    allowed: &[&str],
) -> Result<(), ManifestError> {
    for key in obj.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(ManifestError::UnknownField {
                context: ctx.to_string(),
                field: key.clone(),
            });
        }
    }
    Ok(())
}

fn req<'a>(
    ctx: &str,
    obj: &'a BTreeMap<String, Json>,
    field: &str,
) -> Result<&'a Json, ManifestError> {
    obj.get(field).ok_or_else(|| ManifestError::MissingField {
        context: ctx.to_string(),
        field: field.to_string(),
    })
}

fn wrong(ctx: &str, field: &str, expected: &'static str) -> ManifestError {
    ManifestError::WrongType { context: ctx.to_string(), field: field.to_string(), expected }
}

fn req_str(ctx: &str, obj: &BTreeMap<String, Json>, field: &str) -> Result<String, ManifestError> {
    req(ctx, obj, field)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| wrong(ctx, field, "a string"))
}

fn req_bool(ctx: &str, obj: &BTreeMap<String, Json>, field: &str) -> Result<bool, ManifestError> {
    match req(ctx, obj, field)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(wrong(ctx, field, "a boolean")),
    }
}

fn req_usize(ctx: &str, obj: &BTreeMap<String, Json>, field: &str) -> Result<usize, ManifestError> {
    req(ctx, obj, field)?
        .as_usize()
        .ok_or_else(|| wrong(ctx, field, "a non-negative integer"))
}

fn req_obj<'a>(
    ctx: &str,
    obj: &'a BTreeMap<String, Json>,
    field: &str,
) -> Result<&'a BTreeMap<String, Json>, ManifestError> {
    req(ctx, obj, field)?.as_obj().ok_or_else(|| wrong(ctx, field, "an object"))
}

fn req_usize_arr(
    ctx: &str,
    obj: &BTreeMap<String, Json>,
    field: &str,
) -> Result<Vec<usize>, ManifestError> {
    let arr = req(ctx, obj, field)?
        .as_arr()
        .ok_or_else(|| wrong(ctx, field, "an array of non-negative integers"))?;
    arr.iter()
        .map(|v| v.as_usize())
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| wrong(ctx, field, "an array of non-negative integers"))
}

fn parse_layer(i: usize, v: &Json) -> Result<ZooLayer, ManifestError> {
    let slot = format!("layers[{i}]");
    let m = v.as_obj().ok_or_else(|| wrong("layers", &slot, "an object"))?;
    let name = req_str(&slot, m, "name")?;
    let ctx = format!("layer {name:?}");
    let op_name = req_str(&ctx, m, "op")?;
    let after = req_str(&ctx, m, "after")?;
    let quant = req_obj(&ctx, m, "quant")?;
    let qctx = format!("{ctx}.quant");
    check_fields(&qctx, quant, &["weight", "act"])?;
    let quant_weight = req_bool(&qctx, quant, "weight")?;
    let quant_act = req_bool(&qctx, quant, "act")?;
    let op = match op_name.as_str() {
        "conv3x3" => {
            let allowed = ["name", "op", "after", "filters", "batch_norm", "pool", "quant"];
            check_fields(&ctx, m, &allowed)?;
            ZooOp::Conv3x3 {
                filters: req_usize(&ctx, m, "filters")?,
                batch_norm: req_bool(&ctx, m, "batch_norm")?,
                pool: req_bool(&ctx, m, "pool")?,
            }
        }
        "dense" => {
            check_fields(&ctx, m, &["name", "op", "after", "units", "quant"])?;
            ZooOp::Dense { units: req_usize(&ctx, m, "units")? }
        }
        other => return Err(ManifestError::UnsupportedOp { layer: name, op: other.to_string() }),
    };
    Ok(ZooLayer { name, after, op, quant_weight, quant_act })
}

impl ZooManifest {
    /// Strictly parse one manifest document: typed rejection on malformed
    /// JSON, schema-version skew, unknown fields, missing fields and
    /// wrong types. Semantic validation (references, structure, shapes,
    /// quantizer placement) happens in [`ZooManifest::compile`].
    pub fn parse(text: &str) -> Result<ZooManifest, ManifestError> {
        let v = Json::parse(text).map_err(ManifestError::Json)?;
        let top = v
            .as_obj()
            .ok_or_else(|| ManifestError::Json("top level is not an object".to_string()))?;
        // version gate first: a future-schema file should fail as a
        // version skew, not trip over whatever field that version added
        let sv = req("manifest", top, "schema_version")?;
        match sv.as_f64() {
            Some(n) if n == SCHEMA_VERSION as f64 => {}
            Some(n) => return Err(ManifestError::SchemaVersion(n.to_string())),
            None => return Err(ManifestError::SchemaVersion("(not a number)".to_string())),
        }
        check_fields(
            "manifest",
            top,
            &["schema_version", "name", "task", "input", "init", "layers", "output"],
        )?;
        let name = req_str("manifest", top, "name")?;
        let task = req_str("manifest", top, "task")?;
        let input_obj = req_obj("manifest", top, "input")?;
        check_fields("input", input_obj, &["shape"])?;
        let input = req_usize_arr("input", input_obj, "shape")?;
        let init_obj = req_obj("manifest", top, "init")?;
        check_fields("init", init_obj, &["scheme"])?;
        let init = req_str("init", init_obj, "scheme")?;
        let layers_v = req("manifest", top, "layers")?
            .as_arr()
            .ok_or_else(|| wrong("manifest", "layers", "an array"))?;
        let mut layers = Vec::with_capacity(layers_v.len());
        for (i, lv) in layers_v.iter().enumerate() {
            layers.push(parse_layer(i, lv)?);
        }
        let output = req_str("manifest", top, "output")?;
        Ok(ZooManifest { name, task, input, init, layers, output })
    }

    /// Validate the manifest's semantics and compile it into the
    /// interpreter's [`ModelSpec`]. Fail-closed: any structural doubt is
    /// a typed error, never a guessed fallback.
    pub fn compile(&self) -> Result<ModelSpec, ManifestError> {
        if self.name.is_empty() {
            return Err(ManifestError::BadValue {
                context: "name".to_string(),
                detail: "must be non-empty".to_string(),
            });
        }
        if self.task != "classify" {
            return Err(ManifestError::BadValue {
                context: "task".to_string(),
                detail: format!("{:?} (vocabulary: \"classify\")", self.task),
            });
        }
        if self.init != "he_normal" {
            return Err(ManifestError::BadValue {
                context: "init.scheme".to_string(),
                detail: format!("{:?} (vocabulary: \"he_normal\")", self.init),
            });
        }
        if self.input.len() != 3 || self.input.contains(&0) {
            return Err(ManifestError::ShapeMismatch {
                context: "input.shape".to_string(),
                detail: format!("need [h, w, c] with every dim >= 1, got {:?}", self.input),
            });
        }
        if self.layers.len() < 2 {
            return Err(ManifestError::Structure {
                detail: "a model needs at least one conv3x3 stage and a terminal dense head"
                    .to_string(),
            });
        }
        // layer names: unique, non-empty, "input" reserved for the source
        let mut seen = std::collections::BTreeSet::new();
        for l in &self.layers {
            if l.name.is_empty() {
                return Err(ManifestError::BadValue {
                    context: "layers[].name".to_string(),
                    detail: "must be non-empty".to_string(),
                });
            }
            if l.name == "input" || !seen.insert(l.name.as_str()) {
                return Err(ManifestError::DuplicateLayer { name: l.name.clone() });
            }
        }
        // references: declaration order is the chain order, so layer i
        // must consume layer i-1 ("input" for the first). Anything else
        // is classified precisely: a self/forward reference breaks the
        // topological order; a backward reference that skips the
        // predecessor is a branch or an orphan; an unknown name dangles.
        for (i, l) in self.layers.iter().enumerate() {
            let expected = match i {
                0 => "input",
                _ => self.layers[i - 1].name.as_str(),
            };
            if l.after == expected {
                continue;
            }
            if l.after == l.name || self.layers[i..].iter().any(|m| m.name == l.after) {
                return Err(ManifestError::CyclicOrder {
                    layer: l.name.clone(),
                    after: l.after.clone(),
                });
            }
            if l.after == "input" || self.layers[..i].iter().any(|m| m.name == l.after) {
                return Err(ManifestError::Structure {
                    detail: format!(
                        "layer {:?} consumes {:?}, but the vocabulary is a single chain \
                         (expected {:?})",
                        l.name, l.after, expected
                    ),
                });
            }
            return Err(ManifestError::DanglingRef {
                context: format!("layer {:?} field \"after\"", l.name),
                target: l.after.clone(),
            });
        }
        let last = self.layers.last().expect("layers checked non-empty");
        if self.output != last.name {
            if self.layers.iter().any(|l| l.name == self.output) {
                return Err(ManifestError::Structure {
                    detail: format!(
                        "output is {:?}, but the chain ends at {:?}",
                        self.output, last.name
                    ),
                });
            }
            return Err(ManifestError::DanglingRef {
                context: "field \"output\"".to_string(),
                target: self.output.clone(),
            });
        }
        let units = match last.op {
            ZooOp::Dense { units } => units,
            ZooOp::Conv3x3 { .. } => {
                return Err(ManifestError::Structure {
                    detail: format!("the final layer ({:?}) must be the dense head", last.name),
                })
            }
        };
        if units < 2 {
            return Err(ManifestError::ShapeMismatch {
                context: format!("layer {:?} field \"units\"", last.name),
                detail: format!("a classifier head needs >= 2 classes, got {units}"),
            });
        }
        if !last.quant_weight || last.quant_act {
            return Err(ManifestError::QuantPlacement {
                layer: last.name.clone(),
                detail: "the dense head quantizes weights only — quant.weight must be true \
                         and quant.act false (logits are not an activation site)"
                    .to_string(),
            });
        }
        // conv chain: shape walk + structural quantizer placement
        let (mut h, mut w) = (self.input[0], self.input[1]);
        let mut convs = Vec::with_capacity(self.layers.len() - 1);
        for l in &self.layers[..self.layers.len() - 1] {
            let (filters, batch_norm, pool) = match l.op {
                ZooOp::Conv3x3 { filters, batch_norm, pool } => (filters, batch_norm, pool),
                ZooOp::Dense { .. } => {
                    return Err(ManifestError::Structure {
                        detail: format!(
                            "layer {:?}: dense must be the single terminal layer",
                            l.name
                        ),
                    })
                }
            };
            if filters == 0 {
                return Err(ManifestError::ShapeMismatch {
                    context: format!("layer {:?} field \"filters\"", l.name),
                    detail: "needs >= 1 output channel".to_string(),
                });
            }
            if !(l.quant_weight && l.quant_act) {
                return Err(ManifestError::QuantPlacement {
                    layer: l.name.clone(),
                    detail: "conv3x3 kernels and post-relu activations are always \
                             quantization blocks — quant.weight and quant.act must both \
                             be true"
                        .to_string(),
                });
            }
            if pool {
                if h % 2 != 0 || w % 2 != 0 {
                    return Err(ManifestError::ShapeMismatch {
                        context: format!("layer {:?} field \"pool\"", l.name),
                        detail: format!("2x2 max-pool needs even spatial dims, got {h}x{w}"),
                    });
                }
                h /= 2;
                w /= 2;
            }
            convs.push(ConvSpec { c_out: filters, batch_norm, pooled: pool });
        }
        Ok(ModelSpec {
            name: self.name.clone(),
            input: (self.input[0], self.input[1], self.input[2]),
            convs,
            n_classes: units,
        })
    }

    /// Canonical serialization: stable field order and layout, so
    /// `parse(m.to_json()) == m` and committed zoo files diff cleanly.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema_version\": {SCHEMA_VERSION},\n"));
        s.push_str(&format!("  \"name\": {},\n", quote(&self.name)));
        s.push_str(&format!("  \"task\": {},\n", quote(&self.task)));
        let dims: Vec<String> = self.input.iter().map(usize::to_string).collect();
        s.push_str(&format!("  \"input\": {{\"shape\": [{}]}},\n", dims.join(", ")));
        s.push_str(&format!("  \"init\": {{\"scheme\": {}}},\n", quote(&self.init)));
        s.push_str("  \"layers\": [\n");
        for (i, l) in self.layers.iter().enumerate() {
            let comma = if i + 1 < self.layers.len() { "," } else { "" };
            let quant = format!(
                "\"quant\": {{\"weight\": {}, \"act\": {}}}",
                l.quant_weight, l.quant_act
            );
            let line = match l.op {
                ZooOp::Conv3x3 { filters, batch_norm, pool } => format!(
                    "    {{\"name\": {}, \"op\": \"conv3x3\", \"after\": {}, \
                     \"filters\": {filters}, \"batch_norm\": {batch_norm}, \
                     \"pool\": {pool}, {quant}}}{comma}\n",
                    quote(&l.name),
                    quote(&l.after),
                ),
                ZooOp::Dense { units } => format!(
                    "    {{\"name\": {}, \"op\": \"dense\", \"after\": {}, \
                     \"units\": {units}, {quant}}}{comma}\n",
                    quote(&l.name),
                    quote(&l.after),
                ),
            };
            s.push_str(&line);
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"output\": {}\n", quote(&self.output)));
        s.push_str("}\n");
        s
    }
}

/// JSON string literal with the escapes [`Json::parse`] understands.
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse + compile one manifest document (the corpus-test entry point).
pub fn load_str(text: &str) -> Result<ZooModel, ManifestError> {
    let manifest = ZooManifest::parse(text)?;
    let spec = manifest.compile()?;
    Ok(ZooModel { manifest, spec })
}

/// Read, parse and compile a manifest file, wrapping every failure with
/// the file path and the zoo usage line — the fail-before-`Runtime`
/// surface the CLI and the backend share.
pub fn load_file(path: &Path) -> anyhow::Result<ZooModel> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("model manifest {}: {e}\n{ZOO_USAGE}", path.display()))?;
    load_str(&text).map_err(|e| anyhow!("model manifest {}: {e}\n{ZOO_USAGE}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> String {
        r#"{
  "schema_version": 1,
  "name": "tiny",
  "task": "classify",
  "input": {"shape": [8, 8, 1]},
  "init": {"scheme": "he_normal"},
  "layers": [
    {"name": "conv0", "op": "conv3x3", "after": "input", "filters": 4, "batch_norm": false, "pool": true, "quant": {"weight": true, "act": true}},
    {"name": "fc", "op": "dense", "after": "conv0", "units": 3, "quant": {"weight": true, "act": false}}
  ],
  "output": "fc"
}"#
        .to_string()
    }

    #[test]
    fn minimal_manifest_parses_and_compiles() {
        let m = load_str(&minimal()).unwrap();
        assert_eq!(m.spec.name, "tiny");
        assert_eq!(m.spec.input, (8, 8, 1));
        assert_eq!(m.spec.convs.len(), 1);
        assert_eq!(m.spec.convs[0], ConvSpec { c_out: 4, batch_norm: false, pooled: true });
        assert_eq!(m.spec.n_classes, 3);
    }

    #[test]
    fn round_trip_is_identity() {
        let m = ZooManifest::parse(&minimal()).unwrap();
        let re = ZooManifest::parse(&m.to_json()).unwrap();
        assert_eq!(re, m);
        assert_eq!(re.compile().unwrap(), m.compile().unwrap());
    }

    #[test]
    fn typed_rejections_carry_stable_kinds() {
        let sub = |from: &str, to: &str| minimal().replace(from, to);
        let v2 = "\"schema_version\": 2";
        let cases: Vec<(String, &str)> = vec![
            ("{".to_string(), "json"),
            ("[1, 2]".to_string(), "json"),
            (sub("\"schema_version\": 1", v2), "schema-version"),
            (sub("\"task\": \"classify\"", "\"task\": \"classify\", \"x\": 1"), "unknown-field"),
            (sub("\"filters\": 4", "\"filters\": \"4\""), "wrong-type"),
            (sub("\"after\": \"conv0\"", "\"after\": \"conv9\""), "dangling-ref"),
            (sub("\"op\": \"dense\"", "\"op\": \"upsample2\""), "unsupported-op"),
            (sub("[8, 8, 1]", "[7, 8, 1]"), "shape-mismatch"),
            (sub("\"act\": true", "\"act\": false"), "quant-placement"),
            (sub("\"name\": \"fc\"", "\"name\": \"conv0\""), "duplicate-layer"),
            (sub("\"after\": \"input\"", "\"after\": \"conv0\""), "cyclic-order"),
            (sub("\"scheme\": \"he_normal\"", "\"scheme\": \"xavier\""), "bad-value"),
        ];
        for (text, kind) in &cases {
            match load_str(text) {
                Ok(_) => panic!("case {kind} unexpectedly parsed"),
                Err(e) => assert_eq!(e.kind(), *kind, "got {e}"),
            }
        }
    }

    #[test]
    fn errors_name_the_offending_field() {
        let text = minimal().replace("\"filters\": 4", "\"filters\": 4, \"stride\": 2");
        let e = load_str(&text).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("stride"), "{msg}");
        assert!(msg.contains("conv0"), "{msg}");
    }
}
