//! The native execution backend: a from-scratch pure-Rust interpreter
//! for the study models — no artifacts, no PJRT, no Python.
//!
//! Where the PJRT backend executes HLO that aot.py lowered from the L2
//! JAX graphs, this backend *is* the graphs, re-implemented directly:
//!
//! - [`model`] — the study CNNs (`cnn_mnist[_bn]`, `cnn_cifar[_bn]`),
//!   their flat parameter layout (identical tensor order and block
//!   indexing to layers.py), He-normal init, and the generated
//!   [`Manifest`] with aot.py-shaped entry IoSpecs.
//! - [`ops`] — conv2d / dense / max-pool / batch-norm / relu /
//!   softmax-CE, forward *and* hand-derived backward.
//! - [`quant`] — `fake_quant` bit-faithful to the L1 Pallas kernel
//!   (ties-to-even, fused `q*delta+lo`), with the straight-through
//!   backward convention.
//! - [`net`] — the taped forward/backward supporting the same three
//!   modes as `Model.apply` (plain / QAT / activation taps).
//! - [`entries`] — the entry-point programs (`init`, `train_epoch`,
//!   `qat_epoch`, `eval`, `qat_eval`, `predict`, `param_ranges`,
//!   `act_ranges`, `ef_trace_bs{B}`), dispatched through the shared
//!   [`Dispatcher`] contract.
//!
//! Everything is deterministic: entry programs are pure functions of
//! their inputs (no global state, fixed summation order), so the same
//! seed replays bit-identically across runs, processes and `--jobs`
//! settings — `tests/native_backend.rs` pins this, along with
//! finite-difference checks of every backward kernel.
//!
//! The backends are numerically *independent* (different init RNG,
//! different accumulation orders): a checkpoint trained natively is not
//! comparable to a PJRT one, which is why backend identity is hashed
//! into every pipeline stage key (DESIGN.md "Backends").

pub mod entries;
pub mod model;
pub mod net;
pub mod ops;
pub mod quant;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::runtime::backend::{Backend, Dispatcher};
use crate::runtime::{EntrySpec, Manifest, ModelManifest};
use entries::{EntryKind, NativeExec};
use model::{Plan, STUDY_CNNS};

/// The native backend: execution plans for every built-in model.
pub struct NativeBackend {
    plans: BTreeMap<String, Rc<Plan>>,
}

impl NativeBackend {
    /// Build the backend plus its generated manifest (the pair
    /// `Runtime::native` assembles into a runtime).
    pub fn create() -> (NativeBackend, Manifest) {
        let mut plans = BTreeMap::new();
        let mut models = BTreeMap::new();
        for spec in STUDY_CNNS {
            let plan = Plan::new(*spec);
            models.insert(spec.name.to_string(), plan.manifest());
            plans.insert(spec.name.to_string(), Rc::new(plan));
        }
        (NativeBackend { plans }, Manifest { root: PathBuf::from("<native>"), models })
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(&self, model: &ModelManifest, entry: &EntrySpec) -> Result<Box<dyn Dispatcher>> {
        let plan = self
            .plans
            .get(&model.name)
            .ok_or_else(|| anyhow!("native backend has no model {:?}", model.name))?;
        // the manifest is the source of truth for dispatch shapes, so the
        // scanned-epoch K comes from it, not the global constant
        let kind = EntryKind::parse(&entry.name, model.train_k)?;
        Ok(Box::new(NativeExec { plan: plan.clone(), kind }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_exposes_all_study_models() {
        let (backend, manifest) = NativeBackend::create();
        for spec in STUDY_CNNS {
            assert!(manifest.model(spec.name).is_ok(), "{}", spec.name);
            assert!(backend.plans.contains_key(spec.name));
        }
        assert!(manifest.model("cnn_s").is_err(), "scale models are PJRT-only");
        assert!(manifest.model("unet").is_err(), "unet is PJRT-only");
    }

    #[test]
    fn compile_rejects_foreign_entries() {
        let (backend, manifest) = NativeBackend::create();
        let mm = manifest.model("cnn_mnist").unwrap();
        // an entry spec the manifest doesn't carry (defensive path)
        let fake = EntrySpec {
            name: "hutch_bs4".into(),
            file: String::new(),
            inputs: vec![],
            outputs: vec![],
        };
        assert!(backend.compile(mm, &fake).is_err());
    }
}
