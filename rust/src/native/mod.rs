//! The native execution backend: a from-scratch pure-Rust interpreter
//! for the study models — no artifacts, no PJRT, no Python.
//!
//! Where the PJRT backend executes HLO that aot.py lowered from the L2
//! JAX graphs, this backend *is* the graphs, re-implemented directly:
//!
//! - [`model`] — the study CNNs (`cnn_mnist[_bn]`, `cnn_cifar[_bn]`),
//!   their flat parameter layout (identical tensor order and block
//!   indexing to layers.py), He-normal init, and the generated
//!   [`Manifest`] with aot.py-shaped entry IoSpecs.
//! - [`manifest`] — the declarative model zoo: strict, fail-closed
//!   JSON manifests (`zoo/*.json`) compiled into the same plan
//!   representation, so new architectures in the op vocabulary run
//!   with zero Rust changes.
//! - [`gemm`] — the math-kernel layer: im2col/col2im lowering, a
//!   panel-parallel rank-1 `sgemm`, and threaded direct-conv kernels,
//!   all under a fixed-order `f32` accumulation contract and fanned
//!   over `coordinator::parallel::run_static`.
//! - [`simd`] — explicit SSE2/AVX2/NEON panel kernels behind runtime
//!   feature detection, vectorized across *independent outputs* so
//!   every variant reproduces the scalar accumulation order bit-exactly
//!   (the 0-ULP contract; see the module doc for the never-FMA rule).
//! - [`tune`] — the per-host autotuner: micro-benchmarks each
//!   (op, shape-class, variant) triple once, persists the winner table
//!   in the artifact cache under a host fingerprint, and honors the
//!   `FITQ_NATIVE_KERNEL` escape hatch.
//! - [`trace`] — opt-in op-level profiling (`--trace-ops` /
//!   `FITQ_TRACE_OPS`): per-(op, layer, variant) call/element/FLOP/wall
//!   aggregates, one branch per op when disarmed, bit-identical outputs
//!   either way, persisted as artifact kind `optrace` and rendered by
//!   `fitq trace-report`.
//! - [`ops`] — conv2d / dense / max-pool / batch-norm / relu /
//!   softmax-CE, forward *and* hand-derived backward; conv/dense run
//!   through [`gemm`] under the *measured* per-op routing from
//!   [`tune`], with the original scalar loop nests kept as
//!   `ops::reference` oracles (0-ULP pinned by `tests/native_gemm.rs`).
//! - [`quant`] — `fake_quant` bit-faithful to the L1 Pallas kernel
//!   (ties-to-even, fused `q*delta+lo`), with the straight-through
//!   backward convention.
//! - [`net`] — the taped forward/backward supporting the same three
//!   modes as `Model.apply` (plain / QAT / activation taps).
//! - [`entries`] — the entry-point programs (`init`, `train_epoch`,
//!   `qat_epoch`, `eval`, `qat_eval`, `predict`, `param_ranges`,
//!   `act_ranges`, `ef_trace_bs{B}`), dispatched through the shared
//!   [`Dispatcher`] contract.
//!
//! Everything is deterministic: entry programs are pure functions of
//! their inputs (no global state, fixed summation order), so the same
//! seed replays bit-identically across runs, processes and `--jobs`
//! settings — `tests/native_backend.rs` pins this, along with
//! finite-difference checks of every backward kernel.
//!
//! The backends are numerically *independent* (different init RNG,
//! different accumulation orders): a checkpoint trained natively is not
//! comparable to a PJRT one, which is why backend identity is hashed
//! into every pipeline stage key (DESIGN.md "Backends").

pub mod entries;
pub mod gemm;
pub mod manifest;
pub mod model;
pub mod net;
pub mod ops;
pub mod quant;
pub mod simd;
pub mod trace;
pub mod tune;

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::runtime::backend::{Backend, Dispatcher};
use crate::runtime::{EntrySpec, Manifest, ModelManifest};
use entries::{EntryKind, NativeExec};
use model::{Plan, STUDY_CNNS};
use ops::ExecCtx;

/// The native backend: execution plans for every built-in model, plus
/// the intra-op GEMM thread budget its dispatchers run under.
pub struct NativeBackend {
    plans: BTreeMap<String, Rc<Plan>>,
    /// Intra-op threads each compiled dispatcher may fan GEMM panels
    /// over (`1` = serial; only wall clock changes, never bits).
    threads: usize,
    /// Route conv/dense through the scalar `ops::reference` kernels
    /// (`FITQ_NATIVE_REFERENCE=1`) — the before/after benchmark's
    /// "before" leg.
    use_reference: bool,
    /// Shared op profiler: armed iff `FITQ_TRACE_OPS` was set at
    /// creation, cloned into every compiled dispatcher's `ExecCtx` so
    /// one backend accumulates one trace across all its dispatches.
    prof: trace::Prof,
}

impl NativeBackend {
    /// Build the backend plus its generated manifest (the pair
    /// `Runtime::native` assembles into a runtime) with an intra-op
    /// thread budget for the GEMM layer.
    pub fn create_with_threads(threads: usize) -> (NativeBackend, Manifest) {
        let mut plans = BTreeMap::new();
        let mut models = BTreeMap::new();
        for spec in STUDY_CNNS {
            let plan = Plan::new(*spec);
            models.insert(spec.name.to_string(), plan.manifest());
            plans.insert(spec.name.to_string(), Rc::new(plan));
        }
        let use_reference = std::env::var_os("FITQ_NATIVE_REFERENCE").is_some();
        let prof = if std::env::var_os("FITQ_TRACE_OPS").is_some() {
            trace::Prof::armed()
        } else {
            trace::Prof::default()
        };
        (
            NativeBackend { plans, threads: threads.max(1), use_reference, prof },
            Manifest { root: PathBuf::from("<native>"), models },
        )
    }

    /// [`NativeBackend::create_with_threads`] with the serial budget —
    /// the historical constructor.
    pub fn create() -> (NativeBackend, Manifest) {
        NativeBackend::create_with_threads(1)
    }

    /// [`NativeBackend::create_with_threads`] plus a set of zoo model
    /// manifests (`zoo/*.json`), each strictly validated and compiled
    /// into a plan alongside the builtins. A zoo model may shadow a
    /// builtin name (the bit-identity tests rely on the shadowed pair
    /// being equivalent anyway); two zoo files claiming the same name
    /// is an error, since "last file wins" would be a silent fallback.
    pub fn create_with_zoo(threads: usize, zoo: &[PathBuf]) -> Result<(NativeBackend, Manifest)> {
        let (mut backend, mut manifest) = NativeBackend::create_with_threads(threads);
        let mut zoo_names = BTreeSet::new();
        for path in zoo {
            let model = crate::native::manifest::load_file(path)?;
            let name = model.spec.name.clone();
            if !zoo_names.insert(name.clone()) {
                bail!(
                    "model manifest {}: a zoo model named {name:?} was already loaded",
                    path.display()
                );
            }
            let plan = Plan::from_spec(model.spec);
            manifest.models.insert(name.clone(), plan.manifest());
            backend.plans.insert(name, Rc::new(plan));
        }
        Ok((backend, manifest))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(&self, model: &ModelManifest, entry: &EntrySpec) -> Result<Box<dyn Dispatcher>> {
        let plan = self
            .plans
            .get(&model.name)
            .ok_or_else(|| anyhow!("native backend has no model {:?}", model.name))?;
        // the manifest is the source of truth for dispatch shapes, so the
        // scanned-epoch K comes from it, not the global constant
        let kind = EntryKind::parse(&entry.name, model.train_k)?;
        // fail-closed: an unknown/unavailable FITQ_NATIVE_KERNEL value is
        // a compile error, not a silent fallback to some other variant
        let mode = tune::KernelMode::from_env()?;
        let ctx = ExecCtx {
            threads: self.threads,
            use_reference: self.use_reference,
            prof: self.prof.clone(),
            mode,
            ..ExecCtx::default()
        };
        Ok(Box::new(NativeExec { plan: plan.clone(), kind, ctx: RefCell::new(ctx) }))
    }

    fn op_trace(&self) -> Option<trace::OpTraceReport> {
        self.prof.snapshot().map(|rows| trace::OpTraceReport {
            model: String::new(),
            workload: String::new(),
            threads: self.threads as u32,
            rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_exposes_all_study_models() {
        let (backend, manifest) = NativeBackend::create();
        for spec in STUDY_CNNS {
            assert!(manifest.model(spec.name).is_ok(), "{}", spec.name);
            assert!(backend.plans.contains_key(spec.name));
        }
        assert!(manifest.model("cnn_s").is_err(), "scale models are PJRT-only");
        assert!(manifest.model("unet").is_err(), "unet is PJRT-only");
    }

    #[test]
    fn compile_rejects_foreign_entries() {
        let (backend, manifest) = NativeBackend::create();
        let mm = manifest.model("cnn_mnist").unwrap();
        // an entry spec the manifest doesn't carry (defensive path)
        let fake = EntrySpec {
            name: "hutch_bs4".into(),
            file: String::new(),
            inputs: vec![],
            outputs: vec![],
        };
        assert!(backend.compile(mm, &fake).is_err());
    }
}
